# Test lanes. `make test` is the pre-review gate: the fast lane first
# (collection regressions surface in seconds), then the slow lane
# (subprocess dry-run compiles, multi-device collectives).
PY      := python
PYTEST  := PYTHONPATH=src $(PY) -m pytest -q

.PHONY: test test-fast test-slow tier1 bench-smoke

test: test-fast test-slow

test-fast:
	$(PYTEST) -m "not slow"

test-slow:
	$(PYTEST) -m slow

# The exact tier-1 command from ROADMAP.md (everything, fail-fast).
tier1:
	$(PYTEST) -x

# Sharded-retrieval scaling benchmark on the 1-device mesh (seconds, CI).
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.sharded_scaling --smoke
