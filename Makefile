# Test lanes. `make test` is the pre-review gate: the fast lane first
# (collection regressions surface in seconds), then the slow lane
# (subprocess dry-run compiles, multi-device collectives).
PY      := python
PYTEST  := PYTHONPATH=src $(PY) -m pytest -q

.PHONY: test test-fast test-slow test-api tier1 bench-smoke

test: test-fast test-slow

# Includes tests/test_retrieval_api.py, which exercises the engine
# registry end-to-end for every registered engine name.
test-fast:
	$(PYTEST) -m "not slow"

test-slow:
	$(PYTEST) -m slow

# Seconds-scale smoke of the unified search API alone (registry coverage,
# facade parity, k-bucketing) — the quickest pre-commit signal.
test-api:
	$(PYTEST) -m "not slow" tests/test_retrieval_api.py

# The exact tier-1 command from ROADMAP.md (everything, fail-fast).
tier1:
	$(PYTEST) -x

# Sharded-retrieval scaling benchmark on the 1-device mesh (seconds, CI).
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.sharded_scaling --smoke
