# Test lanes. `make test` is the pre-review gate: the fast lane first
# (collection regressions surface in seconds), then the slow lane
# (subprocess dry-run compiles, multi-device collectives).
PY      := python
PYTEST  := PYTHONPATH=src $(PY) -m pytest -q

.PHONY: test test-fast test-slow test-api test-traversal tier1 bench-smoke

test: test-fast test-slow

# Includes tests/test_retrieval_api.py, which exercises the engine
# registry end-to-end for every registered engine name.
test-fast:
	$(PYTEST) -m "not slow"

test-slow:
	$(PYTEST) -m slow

# Seconds-scale smoke of the unified search API alone (registry coverage,
# facade parity, k-bucketing) — the quickest pre-commit signal.
test-api:
	$(PYTEST) -m "not slow" tests/test_retrieval_api.py

# Traversal fast lane: the chunked/full/kernel parity + early-exit suite
# (the quickest signal when touching core/plan, core/traversal, or the
# guided_score kernels).
test-traversal:
	$(PYTEST) -m "not slow" tests/test_traversal.py tests/test_kernels.py

# The exact tier-1 command from ROADMAP.md (everything, fail-fast).
tier1:
	$(PYTEST) -x

# Seconds-scale CI benches: the sharded scaling smoke (1-device mesh) and
# the retrieval perf baseline — writes BENCH_retrieval.json (mrt_ms,
# tiles_visited, chunks_dispatched per method) for later PRs to diff.
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.sharded_scaling --smoke
	PYTHONPATH=src $(PY) -m benchmarks.retrieval_smoke
