# Test lanes. `make test` is the pre-review gate: the fast lane first
# (collection regressions surface in seconds), then the slow lane
# (subprocess dry-run compiles, multi-device collectives).
PY      := python
PYTEST  := PYTHONPATH=src $(PY) -m pytest -q

.PHONY: test test-fast test-slow test-api test-serve test-faults \
    test-stress test-traversal \
        test-quality test-index test-obs tier1 bench-smoke

test: test-fast test-slow

# Includes tests/test_retrieval_api.py, which exercises the engine
# registry end-to-end for every registered engine name.
test-fast:
	$(PYTEST) -m "not slow"

test-slow:
	$(PYTEST) -m slow

# Seconds-scale smoke of the unified search API alone (registry coverage,
# facade parity, k-bucketing) — the quickest pre-commit signal.
test-api:
	$(PYTEST) -m "not slow" tests/test_retrieval_api.py

# Serving fast lane: the async scheduler / router / response-cache suite,
# the executor-pool/backpressure tests (minus the threaded saturation
# soaks), and the deprecated-server shim edges (the quickest signal when
# touching serve/scheduler.py, serve/executor.py, serve/router.py, or
# serve/engine.py).
test-serve:
	$(PYTEST) -m "not slow and not stress" tests/test_scheduler.py \
	    tests/test_executor.py tests/test_serve_edges.py

# Fault-tolerance fast lane: deadlines, retries + hedging, breakers +
# degraded mode, generation-safe hot swap, and the fault-injection soak
# — all on a simulated clock, so the whole suite runs in seconds (the
# quickest signal when touching serve/health.py, serve/faults.py, or
# the scheduler's fault paths).
test-faults:
	$(PYTEST) tests/test_faults.py

# Multi-worker saturation soaks: executor pools under overload with
# shedding and concurrent submitters (threaded, timing-sensitive — kept
# out of the fast serve lane).
test-stress:
	$(PYTEST) -m stress

# Traversal fast lane: the chunked/full/kernel parity + early-exit suite
# (the quickest signal when touching core/plan, core/traversal, or the
# guided_score kernels).
test-traversal:
	$(PYTEST) -m "not slow" tests/test_traversal.py tests/test_kernels.py

# Relevance lane: metric properties, the eval harness (graded corpora,
# TREC round-trip, the small-k guided-degradation regression), and the
# hybrid cascade/rrf engine suite — the quickest signal when touching
# core/metrics.py, repro/eval/, or retrieval/hybrid.py.
test-quality:
	$(PYTEST) -m "not slow" tests/test_metrics.py tests/test_eval_harness.py \
	    tests/test_hybrid_engines.py

# Compressed-index lane: codec round-trips/bound-safety, q8 decode parity
# across every engine, and the streaming builder's chunked-vs-oneshot +
# kill-and-resume suite (the quickest signal when touching repro/index/,
# data/builder.py, or the q8 decode in kernels/guided_score.py). The
# 2^20-doc build runs in the slow lane (`-m slow tests/test_builder.py`).
test-index:
	$(PYTEST) -m "not slow" tests/test_index_codec.py \
	    tests/test_compressed_index.py tests/test_builder.py

# Observability lane: exact-rank quantiles + mergeable histograms, the
# span tracer (simulated clocks, ring eviction, disabled-path overhead
# guard), Prometheus/JSON export + the metrics HTTP server, the cost
# model (monotonicity, predictor-vs-realized, cost-sorted dispatch
# parity), and the BENCH-JSON non-finite guard (the quickest signal when
# touching src/repro/obs/ or benchmarks/common.py).
test-obs:
	$(PYTEST) tests/test_obs.py

# The exact tier-1 command from ROADMAP.md (everything, fail-fast).
tier1:
	$(PYTEST) -x

# Seconds-scale CI benches: the sharded scaling smoke (1-device mesh),
# the retrieval perf baseline (BENCH_retrieval.json: mrt_ms,
# tiles_visited, chunks_dispatched per method), the Poisson-load
# serving benchmark (BENCH_serving.json: QPS/MRT/P99 + cache-hit and
# routing stats per policy), the relevance grid (BENCH_quality.json:
# MRR/nDCG/recall next to MRT per method x threshold_factor x engine),
# and the compressed-index smoke (size ratio / build rate / chunked MRT
# at 64k docs; the committed BENCH_index.json is the 2^20-doc run —
# re-record with REPRO_BENCH_FULL=1 or --full) for later PRs to diff.
bench-smoke:
	PYTHONPATH=src $(PY) -m benchmarks.sharded_scaling --smoke
	PYTHONPATH=src $(PY) -m benchmarks.retrieval_smoke
	PYTHONPATH=src $(PY) -m benchmarks.serving_bench
	PYTHONPATH=src $(PY) -m benchmarks.quality_bench
	PYTHONPATH=src $(PY) -m benchmarks.million_doc --out /tmp/BENCH_index_smoke.json
