"""End-to-end driver: train a SPLADE-style learned sparse encoder, then
serve its index with 2GTI — the full pipeline the paper sits inside.

  1. Train a bidirectional transformer encoder with the SPLADE head
     (log1p-relu-maxpool over vocab) on synthetic (query, doc+, doc-)
     pairs: InfoNCE with in-batch negatives + FLOP regularization.
     Fault-tolerant trainer: crash-safe checkpoints, auto-resume.
  2. Encode a document collection into a learned sparse index; build the
     corresponding BM25 index from raw term counts; merge (scaled fill).
  3. Retrieve with MaxScore-org vs 2GTI and report relevance + latency.

Defaults are CPU-demo scale (~7M params, minutes). ``--full`` selects the
~100M-parameter configuration for real hardware.

    PYTHONPATH=src python examples/train_sparse_encoder.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_index, merge_models, twolevel
from repro.core.metrics import evaluate_run, mean_and_p99
from repro.core.sparse import from_coo
from repro.core.bm25 import build_bm25
from repro.retrieval import Retriever
from repro.data.stream import pair_batch
from repro.models.transformer import (TransformerConfig, init_params,
                                      splade_encode)
from repro.train.optimizer import AdamWConfig, flop_regularizer
from repro.train.trainer import Trainer, TrainerConfig

VOCAB = 4096
SEQ = 48


def encoder_config(full: bool) -> TransformerConfig:
    if full:
        return TransformerConfig(n_layers=12, d_model=768, n_heads=12,
                                 n_kv_heads=12, d_ff=3072, vocab=30522,
                                 causal=False, rope=False, max_position=128,
                                 sparse_head=True, remat=False,
                                 compute_dtype=jnp.float32)
    return TransformerConfig(n_layers=4, d_model=256, n_heads=4,
                             n_kv_heads=4, d_ff=512, vocab=VOCAB,
                             causal=False, rope=False, max_position=SEQ,
                             sparse_head=True, remat=False,
                             compute_dtype=jnp.float32)


def make_loss(cfg, flop_weight=3e-4):
    def loss_fn(params, batch):
        ones = jnp.ones_like(batch["query"])
        rq = splade_encode(cfg, params, batch["query"], ones)
        rp = splade_encode(cfg, params, batch["doc_pos"], ones)
        rn = splade_encode(cfg, params, batch["doc_neg"], ones)
        docs = jnp.concatenate([rp, rn], axis=0)      # [2B, V]
        logits = rq @ docs.T / 10.0                   # in-batch negatives
        labels = jnp.arange(rq.shape[0])
        logp = jax.nn.log_softmax(logits, axis=-1)
        nce = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
        reg = flop_regularizer(rq) + flop_regularizer(docs)
        return nce + flop_weight * reg
    return loss_fn


def encode_collection(cfg, params, token_mat, batch=32, threshold=0.03):
    """Encode docs -> learned SparseModel (top weights above threshold)."""
    reps = []
    for i in range(0, len(token_mat), batch):
        chunk = jnp.asarray(token_mat[i:i + batch])
        reps.append(np.asarray(
            splade_encode(cfg, params, chunk, jnp.ones_like(chunk))))
    rep = np.concatenate(reps, axis=0)
    d, t = np.nonzero(rep > threshold)
    return from_coo(rep.shape[0], cfg.vocab, t, d,
                    rep[d, t].astype(np.float32)), rep


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default="runs/sparse_encoder")
    args = ap.parse_args()

    cfg = encoder_config(args.full)
    n_params = cfg.param_count()
    print(f"encoder: {n_params/1e6:.1f}M params, vocab {cfg.vocab}")

    trainer = Trainer(
        make_loss(cfg), lambda key: init_params(cfg, key),
        lambda step: pair_batch(step, batch=args.batch, seq=SEQ,
                                vocab=cfg.vocab),
        TrainerConfig(total_steps=args.steps, ckpt_every=50,
                      out_dir=args.out, log_every=10),
        AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps))
    t0 = time.time()
    res = trainer.run()
    print(f"trained {args.steps} steps in {time.time()-t0:.0f}s; "
          f"loss {res['losses'][0]:.3f} -> {res['losses'][-1]:.3f}")
    params = res["state"]["params"]

    # --- build an eval collection: docs share salient terms with queries
    rng = np.random.default_rng(7)
    n_docs, n_q = 1024, 32
    docs = rng.integers(1, cfg.vocab, (n_docs, SEQ)).astype(np.int32)
    queries = np.zeros((n_q, SEQ), np.int32)
    qrels = []
    for qi in range(n_q):
        rel = qi * (n_docs // n_q)
        sal = docs[rel, :6]
        queries[qi, :6] = sal
        queries[qi, 6:] = rng.integers(1, cfg.vocab, SEQ - 6)
        qrels.append({int(rel)})

    learned, _ = encode_collection(cfg, params, docs)
    print(f"learned index: {learned.nnz} postings "
          f"({learned.nnz/n_docs:.0f}/doc)")
    # BM25 from raw term counts of the same docs
    terms = docs.ravel().astype(np.int64)
    docids = np.repeat(np.arange(n_docs, dtype=np.int64), SEQ)
    tfs = np.ones_like(terms)
    lens = np.full(n_docs, float(SEQ), np.float32)
    bm25, stats = build_bm25(n_docs, cfg.vocab, terms, docids, tfs, lens)
    merged = merge_models(learned, bm25, "scaled")
    index = build_index(merged, tile_size=256)

    # query reps -> weighted query terms
    q_tokens = jnp.asarray(queries)
    q_reps = np.asarray(splade_encode(cfg, params, q_tokens,
                                      jnp.ones_like(q_tokens)))
    nq = 12
    q_terms = np.zeros((n_q, nq), np.int32)
    q_wl = np.zeros((n_q, nq), np.float32)
    for qi in range(n_q):
        top = np.argsort(-q_reps[qi])[:nq]
        q_terms[qi] = top
        q_wl[qi] = q_reps[qi, top]
    q_wb = np.ones_like(q_wl)

    for name, p in [("MaxScore-org", twolevel.original()),
                    ("2GTI-Fast", twolevel.fast()
                     .replace(schedule="impact"))]:
        r = Retriever.open(index, p, engine="sequential")
        res = r.search(terms=q_terms, weights_b=q_wb, weights_l=q_wl, k=10)
        m = evaluate_run(res.ids, qrels, 10)
        mrt, p99 = mean_and_p99(res.latencies_ms)
        print(f"{name:14s} MRR@10={m['mrr']:.3f} R@10={m['recall']:.3f} "
              f"MRT={mrt:.1f}ms P99={p99:.1f}ms")


if __name__ == "__main__":
    main()
