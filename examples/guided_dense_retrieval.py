"""2GTI transferred to dense retrieval (two-tower retrieval_cand path).

A cheap low-dim prefix score plays BM25's role: two pruning levels with
independent thresholds over blocked candidate scoring. Candidates are
norm-clustered (the docid-reordering analogue) so block bounds are tight.

    PYTHONPATH=src python examples/guided_dense_retrieval.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dense_guided import (build_dense_index, exhaustive_dense,
                                     retrieve_dense)
from repro.core.twolevel import TwoLevelParams


def main() -> None:
    rng = np.random.default_rng(0)
    n, d = 200_000, 128
    # clustered catalogue: a few popularity lobes (realistic embeddings)
    centers = rng.standard_normal((16, d)) * 2.0
    assign = rng.integers(0, 16, n)
    emb = centers[assign] + rng.standard_normal((n, d))
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    # cluster-sort = docid reordering: tightens block bounds
    order = np.argsort(assign, kind="stable")
    emb = jnp.asarray(emb[order], jnp.float32)
    index = build_dense_index(emb, block_size=2048, d_cheap=32)

    qs = rng.standard_normal((16, d)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)

    configs = [("exhaustive (a=b=g)", TwoLevelParams(0.0, 0.0, 0.0, k=10)),
               ("guided (a=1, b=0.3)", TwoLevelParams(1.0, 0.3, 0.0, k=10)),
               ("guided (a=1, b=1)", TwoLevelParams(1.0, 1.0, 0.0, k=10))]
    for name, p in configs:
        t0, recall, scored = time.time(), 0.0, 0.0
        for q in qs:
            q = jnp.asarray(q)
            vals, ids, st = retrieve_dense(index, q, p)
            _, eids = exhaustive_dense(index, q, 10)
            recall += len(set(ids.tolist()) & set(eids.tolist())) / 10
            scored += st["candidates_fully_scored"] / index.emb.shape[0]
        dt = (time.time() - t0) / len(qs) * 1e3
        print(f"{name:22s} recall@10={recall/len(qs):.3f} "
              f"fully-scored={scored/len(qs):6.1%}  {dt:6.1f} ms/q")


if __name__ == "__main__":
    main()
