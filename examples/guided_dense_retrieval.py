"""2GTI transferred to dense retrieval (two-tower retrieval_cand path).

A cheap low-dim prefix score plays BM25's role: two pruning levels with
independent thresholds over blocked candidate scoring. Candidates are
norm-clustered (the docid-reordering analogue) so block bounds are tight.

    PYTHONPATH=src python examples/guided_dense_retrieval.py
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core.dense_guided import build_dense_index, exhaustive_dense
from repro.core.twolevel import TwoLevelParams
from repro.retrieval import Retriever


def main() -> None:
    rng = np.random.default_rng(0)
    n, d = 200_000, 128
    # clustered catalogue: a few popularity lobes (realistic embeddings)
    centers = rng.standard_normal((16, d)) * 2.0
    assign = rng.integers(0, 16, n)
    emb = centers[assign] + rng.standard_normal((n, d))
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    # cluster-sort = docid reordering: tightens block bounds
    order = np.argsort(assign, kind="stable")
    emb = jnp.asarray(emb[order], jnp.float32)
    index = build_dense_index(emb, block_size=2048, d_cheap=32)

    qs = rng.standard_normal((16, d)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)

    configs = [("exhaustive (a=b=g)", TwoLevelParams(0.0, 0.0, 0.0)),
               ("guided (a=1, b=0.3)", TwoLevelParams(1.0, 0.3, 0.0)),
               ("guided (a=1, b=1)", TwoLevelParams(1.0, 1.0, 0.0))]
    for name, p in configs:
        r = Retriever.open(index, p, engine="dense")
        t0 = time.time()
        res = r.search(dense=qs, k=10)
        dt = (time.time() - t0) / len(qs) * 1e3
        recall = 0.0
        for i, q in enumerate(qs):
            _, eids = exhaustive_dense(index, jnp.asarray(q), 10)
            recall += len(set(res.ids[i].tolist())
                          & set(eids.tolist())) / 10
        scored = float(np.sum(res.stats["candidates_fully_scored"]
                              / index.emb.shape[0]))
        print(f"{name:22s} recall@10={recall/len(qs):.3f} "
              f"fully-scored={scored/len(qs):6.1%}  {dt:6.1f} ms/q")


if __name__ == "__main__":
    main()
