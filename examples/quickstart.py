"""Quickstart: build a misaligned synthetic corpus, align the BM25 index,
and compare MaxScore (org) vs GTI vs 2GTI on relevance + latency through
the unified search API (`repro.retrieval.Retriever`).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import build_index, twolevel
from repro.core.align import misalignment_fraction
from repro.core.metrics import evaluate_run, mean_and_p99
from repro.data import make_corpus
from repro.retrieval import Retriever


def main() -> None:
    corpus = make_corpus("splade_like", n_docs=32768, n_terms=4096,
                         n_queries=24, seed=0)
    mis = misalignment_fraction(corpus.learned, corpus.bm25)
    print(f"corpus: {corpus.n_docs} docs, misalignment {mis:.1%} "
          f"(SPLADE-like regime)\n")
    methods = [
        ("MaxScore (org)", "scaled", twolevel.original()),
        ("GTI  (zero-fill)", "zero", twolevel.gti()),
        ("GTI  (scaled)", "scaled", twolevel.gti()),
        ("2GTI-Accurate", "scaled", twolevel.accurate()),
        ("2GTI-Fast", "scaled",
         twolevel.fast().replace(schedule="impact")),
    ]
    # one index per fill mode, shared by every method that uses it
    indexes = {fill: build_index(corpus.merged(fill), tile_size=512)
               for fill in {fill for _, fill, _ in methods}}
    print(f"{'method':18s} {'MRR@10':>7s} {'R@10':>6s} {'MRT':>8s}"
          f" {'P99':>8s} {'tiles':>7s}")
    for name, fill, params in methods:
        r = Retriever.open(indexes[fill], params, engine="sequential")
        res = r.search(terms=corpus.queries, weights_b=corpus.q_weights_b,
                       weights_l=corpus.q_weights_l, k=10)
        m = evaluate_run(res.ids, corpus.qrels, 10)
        mrt, p99 = mean_and_p99(res.latencies_ms)
        tiles = res.stats["tiles_visited"].mean()
        print(f"{name:18s} {m['mrr']:7.3f} {m['recall']:6.3f} "
              f"{mrt:7.1f}ms {p99:7.1f}ms {tiles:5.1f}/64")


if __name__ == "__main__":
    main()
