"""Serve a learned sparse index with batched requests + latency accounting.

Drives the RetrievalServer (queue -> batch -> 2GTI engine) with a Poisson
workload and compares serving configurations.

    PYTHONPATH=src python examples/serve_retrieval.py --qps 300
"""
import argparse

import numpy as np

from repro.core import build_index, twolevel
from repro.core.metrics import evaluate_run
from repro.data import make_corpus
from repro.serve import Request, RetrievalServer, ServerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=300.0)
    ap.add_argument("--n-requests", type=int, default=256)
    ap.add_argument("--docs", type=int, default=32768)
    args = ap.parse_args()

    corpus = make_corpus("splade_like", n_docs=args.docs, n_terms=4096,
                         n_queries=64, seed=1)
    index = build_index(corpus.merged("scaled"), tile_size=1024)

    for name, params in [
            ("GTI", twolevel.gti()),
            ("2GTI-Fast", twolevel.fast()),
            ("2GTI-Fast+impact",
             twolevel.fast().replace(schedule="impact"))]:
        srv = RetrievalServer(index, params,
                              ServerConfig(max_batch=16, max_wait_ms=2.0),
                              k=10)
        reqs = []
        for i in range(args.n_requests):
            qi = i % len(corpus.queries)
            reqs.append(Request(corpus.queries[qi], corpus.q_weights_b[qi],
                                corpus.q_weights_l[qi]))
        stats = srv.run_workload(reqs, qps=args.qps)
        ids = np.stack([r.ids for r in srv.completed[:64]])
        qrels = [corpus.qrels[i % len(corpus.queries)] for i in range(64)]
        m = evaluate_run(ids, qrels, 10)
        print(f"{name:18s} MRT={stats['mrt_ms']:6.1f}ms "
              f"P99={stats['p99_ms']:6.1f}ms "
              f"qps={stats['qps_achieved']:5.0f} MRR@10={m['mrr']:.3f}")


if __name__ == "__main__":
    main()
