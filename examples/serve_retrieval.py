"""Serve a learned sparse index through the async scheduler.

Shows the v2 serving API end to end: ``submit(SearchRequest) ->
SearchHandle`` futures, mixed-k micro-batching, query-length routing
(Table 8), and the LRU response cache — then drives a Poisson workload
and compares serving policies on MRT/P99 and relevance.

    PYTHONPATH=src python examples/serve_retrieval.py --qps 300
"""
import argparse

import numpy as np

from repro.core import build_index, twolevel
from repro.core.metrics import evaluate_run
from repro.data import make_corpus
from repro.retrieval import SearchRequest
from repro.serve import (AsyncRetrievalScheduler, SchedulerConfig,
                         mixed_request_stream, run_workload, single_route,
                         table8_policy)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--qps", type=float, default=300.0)
    ap.add_argument("--n-requests", type=int, default=256)
    ap.add_argument("--docs", type=int, default=32768)
    args = ap.parse_args()

    corpus = make_corpus("splade_like", n_docs=args.docs, n_terms=4096,
                         n_queries=64, seed=1)
    index = build_index(corpus.merged("scaled"), tile_size=1024)
    params = twolevel.fast().replace(schedule="impact")

    # -- the handle lifecycle, one request at a time -------------------------
    sched = AsyncRetrievalScheduler(index, params,
                                    SchedulerConfig(max_batch=16))
    h = sched.submit(terms=corpus.queries[0],
                     weights_b=corpus.q_weights_b[0],
                     weights_l=corpus.q_weights_l[0], k=10)
    assert not h.done()          # queued, not yet dispatched
    sched.flush()                # (a worker thread would do this for us)
    resp = h.result()
    print(f"# single request: route={h.route} k-bucket={h.k_bucket} "
          f"top-3 ids={resp.ids[0, :3].tolist()} "
          f"latency={h.latency_ms:.2f}ms")

    # -- policy comparison under a Poisson workload --------------------------
    # the shared mixed stream: short/long alternating, mixed k, and a
    # 16-query pool so queries repeat (what the response cache is for)
    def requests(n):
        return mixed_request_stream(corpus, n, query_pool=16)

    policies = [
        ("no-routing", single_route("batched"), 0),
        ("table8-routed", table8_policy(), 0),
        ("table8+cache", table8_policy(), 512),
    ]
    for name, routing, cache in policies:
        def fresh():
            return AsyncRetrievalScheduler(
                index, params,
                SchedulerConfig(max_batch=16, max_wait_ms=2.0,
                                cache_size=cache),
                routing=routing)
        # warm the jit caches (global across schedulers) on a throwaway
        # instance, then measure a fresh one: the printed counters cover
        # only the measured run and the cache starts cold
        run_workload(fresh(), requests(32), qps=1e4)
        sched = fresh()
        stats = run_workload(sched, requests(args.n_requests), qps=args.qps)
        probe = [sched.submit(SearchRequest(
            terms=corpus.queries[i], weights_b=corpus.q_weights_b[i],
            weights_l=corpus.q_weights_l[i], k=10)) for i in range(64)]
        sched.flush()
        ids = np.stack([h.result().ids[0] for h in probe])
        m = evaluate_run(ids, corpus.qrels, 10)
        print(f"{name:14s} MRT={stats['mrt_ms']:6.2f}ms "
              f"P99={stats['p99_ms']:6.2f}ms "
              f"qps={stats['qps_achieved']:5.0f} "
              f"cache={stats['cache_hits']}/{stats['cache_hits'] + stats['cache_misses']} "
              f"routes={stats['requests_by_route']} MRR@10={m['mrr']:.3f}")


if __name__ == "__main__":
    main()
