"""Sharded retrieval: shard-plan construction invariants and sharded-vs-
single-device parity.

Fast lane runs on the 1 CPU device: the vmap emulation path executes the
identical per-shard scan + merge math as the ``shard_map`` path for any
shard count, and a 1-device mesh exercises the real shard_map plumbing at
n_shards=1. The slow lane spawns a subprocess with 8 fake host devices and
pins the full collective path (ring-gather merge, threshold exchange,
Pallas scorer) bit-identical to both the emulation path and single-device
``retrieve_batched``."""
import json
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.core.shard_plan import shard_index
from repro.core.traversal import retrieve_batched
from repro.serve.sharded import make_shard_mesh, shard_retrieve_batched

K = 10


@pytest.fixture(scope="module")
def setup(small_corpus):
    merged = small_corpus.merged("scaled")
    index = build_index(merged, tile_size=256)  # 2048 docs -> 8 tiles
    return small_corpus, index


def _q(corpus):
    return corpus.queries, corpus.q_weights_b, corpus.q_weights_l


# -- shard plan construction --------------------------------------------------

@pytest.mark.parametrize("n_shards", [1, 2, 3, 4, 8, 16])
def test_shard_plan_repacks_every_posting(setup, n_shards):
    """Per-shard slabs partition the postings: rebasing each shard's local
    docids by its doc_base and re-sorting (term, docid) recovers exactly
    the original flat arrays — nothing lost, duplicated, or re-weighted."""
    corpus, index = setup
    sh = shard_index(index, n_shards)
    assert sh.nnz_per_shard.sum() == index.nnz
    assert n_shards * sh.tiles_per_shard >= index.n_tiles
    doc_base = np.asarray(sh.doc_base)
    ptr = np.asarray(sh.tile_ptr)
    got = []
    for s in range(n_shards):
        nnz = int(sh.nnz_per_shard[s])
        docs = np.asarray(sh.docids[s][:nnz]) + doc_base[s]
        wb = np.asarray(sh.w_b[s][:nnz])
        wl = np.asarray(sh.w_l[s][:nnz])
        # term of each local posting from the local tile_ptr row bounds
        term_of = np.repeat(np.arange(index.n_terms),
                            ptr[s, :, -1] - ptr[s, :, 0])
        got.append(np.stack([term_of, docs, wb, wl]))
    term_of, docs, wb, wl = np.concatenate(got, axis=1)
    order = np.lexsort((docs, term_of))
    np.testing.assert_array_equal(docs[order], np.asarray(index.docids))
    np.testing.assert_array_equal(wb[order], np.asarray(index.w_b))
    np.testing.assert_array_equal(wl[order], np.asarray(index.w_l))


def test_shard_plan_padded_tiles_are_empty(setup):
    """n_shards that don't divide n_tiles pad the tail shard: padded tiles
    carry zero postings and zero block maxima."""
    corpus, index = setup
    sh = shard_index(index, 3)  # 8 tiles -> tps=3, last shard 2 real + 1 pad
    assert sh.tiles_per_shard == 3
    ptr = np.asarray(sh.tile_ptr[2])
    assert np.all(ptr[:, -1] == ptr[:, -2])  # pad tile: empty runs
    assert float(np.asarray(sh.tile_max_b[2][:, -1]).max()) == 0.0
    assert float(np.asarray(sh.tile_max_l[2][:, -1]).max()) == 0.0


# -- parity: emulation path (any shard count on 1 device) ---------------------

@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "pallas_kernel"])
@pytest.mark.parametrize("schedule", ["docid", "impact"])
def test_single_shard_exact_parity_guided(setup, schedule, use_kernel):
    """n_shards=1 is the same traversal: any config matches bit-exactly."""
    corpus, index = setup
    p = twolevel.fast().replace(schedule=schedule)
    ref = retrieve_batched(index, *_q(corpus), p, use_kernel=use_kernel)
    res = shard_retrieve_batched(shard_index(index, 1), *_q(corpus), p,
                                 use_kernel=use_kernel)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.scores, ref.scores)


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "pallas_kernel"])
@pytest.mark.parametrize("schedule", ["docid", "impact"])
@pytest.mark.parametrize("n_shards", [2, 4])
def test_multi_shard_rank_safe_exact_parity(setup, n_shards, schedule,
                                            use_kernel):
    """Rank-safe configs: pruning is bound-exact, so tile-range sharding
    (a traversal-order change) must return bit-identical top-k."""
    corpus, index = setup
    p = twolevel.original(gamma=0.2).replace(schedule=schedule)
    ref = retrieve_batched(index, *_q(corpus), p, use_kernel=use_kernel)
    res = shard_retrieve_batched(shard_index(index, n_shards), *_q(corpus),
                                 p, use_kernel=use_kernel)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.scores, ref.scores)


@pytest.mark.parametrize("schedule", ["docid", "impact"])
def test_multi_shard_guided_parity(setup, schedule):
    """Guided configs prune against order-dependent thresholds, so shard-
    local thresholds are only *looser* (never unsafe). On this corpus the
    kept sets coincide, pinning the merge end-to-end for unsafe configs."""
    corpus, index = setup
    p = twolevel.fast().replace(schedule=schedule)
    ref = retrieve_batched(index, *_q(corpus), p)
    res = shard_retrieve_batched(shard_index(index, 4), *_q(corpus), p)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.scores, ref.scores)


def test_multi_shard_guided_scores_dominate(setup):
    """The corpus-robust guided invariant: a shard's local theta trajectory
    is always <= the single-device one (its queue saw a subset of tiles),
    so every doc freezes no earlier and every returned score dominates
    elementwise. threshold_factor=1.5 forces aggressive pruning so the
    trajectories actually diverge."""
    corpus, index = setup
    p = twolevel.fast().replace(threshold_factor=1.5)
    ref = retrieve_batched(index, *_q(corpus), p)
    res = shard_retrieve_batched(shard_index(index, 4), *_q(corpus), p)
    assert np.all(res.scores >= ref.scores - 1e-5)


def test_threshold_exchange_rank_safe_exact(setup):
    """The exchanged floor is the exact global theta — a safe bound — so
    rank-safe results stay bit-identical at any exchange period."""
    corpus, index = setup
    p = twolevel.original(gamma=0.2)
    ref = retrieve_batched(index, *_q(corpus), p)
    sh = shard_index(index, 4)
    for every in (1, 2):
        res = shard_retrieve_batched(sh, *_q(corpus), p,
                                     exchange_every=every)
        np.testing.assert_array_equal(res.ids, ref.ids)
        np.testing.assert_array_equal(res.scores, ref.scores)


def test_fine_exchange_beyond_former_round_cap(small_corpus):
    """exchange_every=1 at 256 tiles (128 rounds/shard) — double the old
    64-segment unroll cap — compiles as one lax.scan over sentinel-padded
    rounds and stays bit-identical for rank-safe configs."""
    corpus = small_corpus
    index = build_index(corpus.merged("scaled"), tile_size=8)  # 256 tiles
    p = twolevel.original(gamma=0.2)
    ref = retrieve_batched(index, *_q(corpus), p)
    res = shard_retrieve_batched(shard_index(index, 2), *_q(corpus), p,
                                 exchange_every=1)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.scores, ref.scores)


def test_exchange_round_sentinel_padding_parity(setup):
    """Periods that don't divide tiles_per_shard exercise the sentinel
    tile: it must touch no queue or stat (tiles_visited unchanged)."""
    corpus, index = setup
    p = twolevel.original(gamma=0.2)
    sh = shard_index(index, 3)  # 8 tiles -> 3 tiles/shard
    ref = retrieve_batched(index, *_q(corpus), p)
    for every in (2, 4):  # 2: padded tail round; 4 > tps: single round
        res = shard_retrieve_batched(sh, *_q(corpus), p,
                                     exchange_every=every)
        np.testing.assert_array_equal(res.ids, ref.ids)
        np.testing.assert_array_equal(res.scores, ref.scores)
        np.testing.assert_allclose(res.stats["shard_tiles_visited"].sum(1),
                                   res.stats["tiles_visited"])


def test_one_device_mesh_equals_emulation(setup):
    """The real shard_map path on the 1-device mesh == the vmap path."""
    corpus, index = setup
    p = twolevel.fast()
    sh = shard_index(index, 1)
    emu = shard_retrieve_batched(sh, *_q(corpus), p)
    msh = shard_retrieve_batched(sh, *_q(corpus), p, mesh=make_shard_mesh(1))
    np.testing.assert_array_equal(msh.ids, emu.ids)
    np.testing.assert_array_equal(msh.scores, emu.scores)


# -- chunked per-shard traversal ----------------------------------------------

@pytest.mark.parametrize("exchange_every", [0, 2])
@pytest.mark.parametrize("n_shards", [1, 3, 4])
def test_sharded_chunked_bit_identical_to_full_impact(setup, n_shards,
                                                      exchange_every):
    """Per-shard chunk loops visit each shard's tiles in descending-bound
    order — bit-identical to the impact-schedule full sharded scan (ids,
    scores, tiles_visited), shape-padding tiles included (n_shards=3 pads
    the tail shard). chunks_dispatched never exceeds the chunk grid."""
    corpus, index = setup
    p = twolevel.fast().replace(chunk_tiles=2)
    sh = shard_index(index, n_shards)
    full = shard_retrieve_batched(sh, *_q(corpus),
                                  p.replace(schedule="impact"),
                                  exchange_every=exchange_every)
    ck = shard_retrieve_batched(sh, *_q(corpus), p, traversal="chunked",
                                exchange_every=exchange_every)
    np.testing.assert_array_equal(full.ids, ck.ids)
    np.testing.assert_array_equal(full.scores, ck.scores)
    np.testing.assert_array_equal(full.stats["tiles_visited"],
                                  ck.stats["tiles_visited"])
    assert (ck.stats["chunks_dispatched"] <= ck.stats["n_chunks"]).all()
    assert ck.stats["shard_chunks_dispatched"].shape == (
        len(corpus.queries), n_shards)
    np.testing.assert_allclose(ck.stats["shard_chunks_dispatched"].sum(1),
                               ck.stats["chunks_dispatched"])


def test_sharded_chunked_mesh_equals_emulation(setup):
    """The chunk while_loop under shard_map == the vmap emulation path
    (including the chunks_dispatched counters)."""
    corpus, index = setup
    p = twolevel.fast().replace(chunk_tiles=2)
    sh = shard_index(index, 1)
    emu = shard_retrieve_batched(sh, *_q(corpus), p, traversal="chunked",
                                 exchange_every=2)
    msh = shard_retrieve_batched(sh, *_q(corpus), p, traversal="chunked",
                                 exchange_every=2, mesh=make_shard_mesh(1))
    np.testing.assert_array_equal(msh.ids, emu.ids)
    np.testing.assert_array_equal(msh.scores, emu.scores)
    np.testing.assert_array_equal(msh.stats["chunks_dispatched"],
                                  emu.stats["chunks_dispatched"])


def test_sharded_chunked_rejects_unknown_traversal(setup):
    corpus, index = setup
    with pytest.raises(ValueError, match="traversal"):
        shard_retrieve_batched(shard_index(index, 2), *_q(corpus),
                               twolevel.fast(), traversal="fused")


def test_mesh_shard_count_mismatch_raises(setup):
    corpus, index = setup
    with pytest.raises(ValueError, match="shards"):
        shard_retrieve_batched(shard_index(index, 2), *_q(corpus),
                               twolevel.fast(), mesh=make_shard_mesh(1))


def test_sharded_stats_consistent(setup):
    corpus, index = setup
    res = shard_retrieve_batched(shard_index(index, 4), *_q(corpus),
                                 twolevel.fast())
    s = res.stats
    assert np.all(s["docs_survived"] <= s["docs_present"])
    assert np.all(s["docs_frozen"] <= s["docs_survived"])
    assert np.all(s["tiles_visited"] <= s["n_tiles"])
    assert s["shard_tiles_visited"].shape == (len(corpus.queries), 4)
    np.testing.assert_allclose(s["shard_tiles_visited"].sum(1),
                               s["tiles_visited"])


def test_sharded_server_matches_plain_server(setup):
    """ShardedRetrievalServer serves the same results through the queue/
    batch machinery as the single-device server."""
    from repro.serve import (Request, RetrievalServer, ServerConfig,
                             ShardedRetrievalServer)
    corpus, index = setup
    params = twolevel.fast()
    cfg = ServerConfig(max_batch=4)
    plain = RetrievalServer(index, params, cfg)
    sharded = ShardedRetrievalServer(index, params, cfg, n_shards=3)

    def reqs():
        return [Request(corpus.queries[i], corpus.q_weights_b[i],
                        corpus.q_weights_l[i]) for i in range(6)]

    for srv in (plain, sharded):
        for r in reqs():
            srv.submit(r, 0.0)
        while srv.pending:
            srv._flush()
    for a, b in zip(plain.completed, sharded.completed):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)


# -- slow lane: real 8-device collective path ---------------------------------

_MESH_PARITY_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np
    from repro.core import build_index, twolevel
    from repro.core.shard_plan import shard_index
    from repro.core.traversal import retrieve_batched
    from repro.data import make_corpus
    from repro.serve.sharded import make_shard_mesh, shard_retrieve_batched

    c = make_corpus("splade_like", n_docs=2048, n_terms=512, n_queries=12,
                    n_q_terms=5, n_rel=3, avg_doc_terms=24, seed=7)
    index = build_index(c.merged("scaled"), tile_size=256)
    q = (c.queries, c.q_weights_b, c.q_weights_l)
    sh = shard_index(index, 8)
    mesh = make_shard_mesh(8)
    out = {}

    def eq(a, b):
        return bool(np.array_equal(a.ids, b.ids)
                    and np.array_equal(a.scores, b.scores))

    # rank-safe: collective path bit-identical to single device
    p = twolevel.original(gamma=0.2)
    ref = retrieve_batched(index, *q, p)
    out["safe_docid"] = eq(shard_retrieve_batched(sh, *q, p, mesh=mesh), ref)
    pi = p.replace(schedule="impact")
    out["safe_impact"] = eq(
        shard_retrieve_batched(sh, *q, pi, mesh=mesh),
        retrieve_batched(index, *q, pi))
    # guided: mesh path == emulation path (same math, collective merge)
    pf = twolevel.fast()
    out["guided_mesh_eq_emu"] = eq(
        shard_retrieve_batched(sh, *q, pf, mesh=mesh),
        shard_retrieve_batched(sh, *q, pf))
    # threshold exchange stays exact for rank-safe configs
    out["exchange"] = eq(
        shard_retrieve_batched(sh, *q, p, mesh=mesh, exchange_every=1), ref)
    # Pallas scorer under shard_map
    out["kernel"] = eq(
        shard_retrieve_batched(sh, *q, p, mesh=mesh, use_kernel=True), ref)
    # chunked while_loop under shard_map == full impact scan per shard
    pc = pf.replace(chunk_tiles=2)
    out["chunked"] = eq(
        shard_retrieve_batched(sh, *q, pc, mesh=mesh, traversal="chunked"),
        shard_retrieve_batched(sh, *q, pc.replace(schedule="impact"),
                               mesh=mesh))
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_mesh_parity_multi_device_subprocess():
    res = subprocess.run([sys.executable, "-c", _MESH_PARITY_SCRIPT],
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [ln for ln in res.stdout.splitlines()
            if ln.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert all(out.values()), out
