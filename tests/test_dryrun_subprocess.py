"""Dry-run machinery test in a SUBPROCESS with 8 fake devices — the main
test process must keep its single CPU device (no global XLA_FLAGS)."""
import json
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    from repro.launch.mesh import make_mesh, dp_axes
    from repro.launch.dryrun import collective_bytes, cost_stats, lower_cell

    assert jax.device_count() == 8  # dryrun's setdefault kept our count
    mesh = make_mesh(dp=4, tp=2)
    assert mesh.devices.size == 8
    assert dp_axes(mesh) == ("data",)
    out = {}
    for arch, shape in [("internlm2-1.8b", "train_4k"),
                        ("schnet", "molecule"),
                        ("two-tower-retrieval", "retrieval_cand")]:
        with mesh:
            jitted, args = lower_cell(arch, shape, mesh)
            compiled = jitted.lower(*args).compile()
            cost = cost_stats(compiled)
            coll = collective_bytes(compiled.as_text())
            out[f"{arch}/{shape}"] = {
                "flops": float(cost.get("flops", -1)),
                "n_collectives": sum(v["count"] for v in coll.values())}
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_dryrun_small_mesh_compiles():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [ln for ln in res.stdout.splitlines()
            if ln.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert len(out) == 3
    lm = out["internlm2-1.8b/train_4k"]
    assert lm["flops"] > 0
    assert lm["n_collectives"] > 0, "sharded train step must communicate"
