"""Serving-engine edge cases: over-long queries (term truncation), partial
final batches flushing on drain, and zero batching delay accounting."""
import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.serve import Request, RetrievalServer, ServerConfig


@pytest.fixture(scope="module")
def served(small_corpus):
    index = build_index(small_corpus.merged("scaled"), tile_size=256)
    return small_corpus, index


def _request(corpus, qi):
    return Request(corpus.queries[qi], corpus.q_weights_b[qi],
                   corpus.q_weights_l[qi])


def test_overlong_query_truncates_to_lowest_impact_terms(served):
    """A request with more terms than pad_terms keeps the highest
    gamma-combined-weight terms, and still returns a full result."""
    corpus, index = served
    params = twolevel.fast()
    pad = 4
    srv = RetrievalServer(index, params, ServerConfig(max_batch=2,
                                                      max_wait_ms=0.1,
                                                      pad_terms=pad))
    # stitch two real queries into one 10-term request with hand-picked
    # weights: qw_b == qw_l makes the gamma-combined impact equal the raw
    # weight for ANY gamma, so the expected kept set is known a priori
    # (indices 1, 3, 6, 8) without re-deriving the production formula
    terms = np.concatenate([corpus.queries[0], corpus.queries[1]])
    w = np.array([.1, .9, .2, .8, .3, .4, .7, .05, .6, .15], np.float32)
    long_req = Request(terms, w.copy(), w.copy())
    srv.submit(long_req, 0.0)
    srv._flush()
    assert long_req.ids is not None and len(long_req.ids) == 10
    keep = np.array([1, 3, 6, 8])  # the four largest weights, in order
    short_req = Request(terms[keep], w[keep], w[keep])
    srv2 = RetrievalServer(index, params, ServerConfig(pad_terms=pad))
    srv2.submit(short_req, 0.0)
    srv2._flush()
    np.testing.assert_array_equal(long_req.ids, short_req.ids)
    np.testing.assert_allclose(long_req.scores, short_req.scores)


def test_truncation_prefers_high_weight_over_leading_terms(served):
    """The kept set is weight-ranked, not positional: put the heavy terms
    last and check they survive."""
    corpus, index = served
    params = twolevel.fast()
    pad = 2
    nq = len(corpus.queries[0])
    terms = corpus.queries[0].copy()
    qw_b = np.ones(nq, np.float32) * 0.01
    qw_l = np.ones(nq, np.float32) * 0.01
    qw_b[-2:] = 5.0
    qw_l[-2:] = 5.0
    srv = RetrievalServer(index, params, ServerConfig(pad_terms=pad))
    keep = srv._truncate(Request(terms, qw_b, qw_l))
    assert list(keep) == [nq - 2, nq - 1]


def test_partial_final_batch_flushes_on_drain(served):
    """Fewer pending requests than max_batch must still complete once the
    arrival stream ends (no stranded tail)."""
    corpus, index = served
    srv = RetrievalServer(index, twolevel.fast(),
                          ServerConfig(max_batch=8, max_wait_ms=50.0))
    reqs = [_request(corpus, i % len(corpus.queries)) for i in range(3)]
    stats = srv.run_workload(reqs, qps=2000.0)
    assert stats["n"] == 3
    assert len(srv.completed) == 3
    assert all(r.ids is not None and r.t_done >= r.t_enqueue
               for r in srv.completed)


def test_multiple_partial_batches_drain_in_order(served):
    """max_batch=1 forces one flush per request; results keep arrival
    order and every latency is positive."""
    corpus, index = served
    srv = RetrievalServer(index, twolevel.fast(),
                          ServerConfig(max_batch=1, max_wait_ms=0.0))
    reqs = [_request(corpus, i) for i in range(5)]
    stats = srv.run_workload(reqs, qps=1000.0)
    assert stats["n"] == 5
    lat = [r.latency_ms for r in srv.completed]
    assert all(v > 0 for v in lat)
    assert stats["p99_ms"] >= stats["p50_ms"]


def test_empty_workload_returns_zero_stats(served):
    """run_workload([]) must not reduce over empty latency arrays."""
    corpus, index = served
    srv = RetrievalServer(index, twolevel.fast())
    stats = srv.run_workload([], qps=100.0)
    assert stats["n"] == 0
    assert stats["qps_achieved"] == 0.0
    assert np.isnan(stats["mrt_ms"]) and np.isnan(stats["p99_ms"])


def test_default_config_not_shared_across_servers(served):
    """The default ServerConfig must be per-instance: mutating one
    server's config cannot leak into another's."""
    corpus, index = served
    a = RetrievalServer(index, twolevel.fast())
    b = RetrievalServer(index, twolevel.fast())
    assert a.cfg is not b.cfg
    a.cfg.max_batch = 1
    assert b.cfg.max_batch == ServerConfig().max_batch


def test_empty_padded_request_is_harmless(served):
    """All-zero weights (fully padded request) completes without NaNs."""
    corpus, index = served
    srv = RetrievalServer(index, twolevel.fast(), ServerConfig())
    req = Request(np.zeros(4, np.int32), np.zeros(4, np.float32),
                  np.zeros(4, np.float32))
    srv.submit(req, 0.0)
    srv._flush()
    assert req.ids is not None
    assert not np.isnan(req.scores).any()  # -inf padding ok, NaN never
