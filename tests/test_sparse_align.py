"""Unit tests: sparse models, BM25, alignment/filling (paper Section 4.3)."""
import numpy as np
import pytest

from repro.core.align import (FILL_METHODS, merge_models,
                              misalignment_fraction, scaled_fill_ratio)
from repro.core.bm25 import bm25_weight, build_bm25, one_fill_weight
from repro.core.sparse import SparseModel, exhaustive_topk, from_coo, score_all


def tiny_models():
    # learned has postings (t0: d1, d3), (t1: d0); bm25 has (t0: d1), (t1: d2)
    learned = from_coo(4, 2, np.array([0, 0, 1]), np.array([1, 3, 0]),
                       np.array([2.0, 4.0, 1.0]))
    bm25 = from_coo(4, 2, np.array([0, 1]), np.array([1, 2]),
                    np.array([3.0, 5.0]))
    return learned, bm25


def test_from_coo_sorts_and_dedupes():
    m = from_coo(8, 2, np.array([1, 0, 1, 1]), np.array([5, 2, 3, 5]),
                 np.array([1.0, 2.0, 3.0, 9.0]))
    assert m.nnz == 3
    d, w = m.postings(1)
    np.testing.assert_array_equal(d, [3, 5])
    np.testing.assert_array_equal(w, [3.0, 1.0])  # first duplicate kept
    m.validate()


def test_score_all_and_topk():
    m = from_coo(4, 2, np.array([0, 0, 1]), np.array([1, 3, 1]),
                 np.array([2.0, 4.0, 1.0]))
    s = score_all(m, np.array([0, 1]), np.array([1.0, 2.0]))
    np.testing.assert_allclose(s, [0.0, 4.0, 0.0, 4.0])
    ids, vals = exhaustive_topk(s, 2)
    np.testing.assert_array_equal(ids, [1, 3])  # docid-asc tiebreak


def test_bm25_monotone_tf_saturation():
    idf = np.array([1.5], dtype=np.float32)
    dl = np.array([10.0], dtype=np.float32)
    w1 = bm25_weight(np.array([1.0]), dl, idf, 10.0)
    w2 = bm25_weight(np.array([2.0]), dl, idf, 10.0)
    w8 = bm25_weight(np.array([8.0]), dl, idf, 10.0)
    assert w1 < w2 < w8
    assert w8 < idf * (0.9 + 1.0)  # saturation bound: idf*(k1+1)
    np.testing.assert_allclose(
        one_fill_weight(dl, idf, 10.0), w1)


def test_merge_zero_fill():
    learned, bm25 = tiny_models()
    mg = merge_models(learned, bm25, "zero")
    assert mg.nnz == 4  # union: (0,d1),(0,d3),(1,d0),(1,d2)
    d, wb, wl = mg.postings(0)
    np.testing.assert_array_equal(d, [1, 3])
    np.testing.assert_allclose(wb, [3.0, 0.0])  # d3 missing in BM25 -> 0
    np.testing.assert_allclose(wl, [2.0, 4.0])
    d, wb, wl = mg.postings(1)
    np.testing.assert_array_equal(d, [0, 2])
    np.testing.assert_allclose(wl, [1.0, 0.0])  # d2 BM25-only -> learned 0


def test_merge_scaled_fill_ratio():
    learned, bm25 = tiny_models()
    ratio = scaled_fill_ratio(bm25, learned)
    np.testing.assert_allclose(ratio, (8.0 / 2) / (7.0 / 3))
    mg = merge_models(learned, bm25, "scaled")
    d, wb, wl = mg.postings(0)
    np.testing.assert_allclose(wb[1], ratio * 4.0)  # filled
    np.testing.assert_allclose(wb[0], 3.0)          # existing untouched


def test_merge_never_changes_existing_weights():
    learned, bm25 = tiny_models()
    stats_dl = np.full(4, 10.0, np.float32)
    from repro.core.bm25 import Bm25Stats
    stats = Bm25Stats(4, 2, stats_dl, np.array([1.0, 2.0], np.float32))
    for fill in FILL_METHODS:
        mg = merge_models(learned, bm25, fill, bm25_stats=stats)
        d, wb, wl = mg.postings(0)
        assert wb[list(d).index(1)] == 3.0
        if fill != "zero":
            assert wb[list(d).index(3)] > 0.0


def test_misalignment_fraction():
    learned, bm25 = tiny_models()
    # learned postings: (0,1) present in bm25, (0,3) and (1,0) absent -> 2/3
    np.testing.assert_allclose(
        misalignment_fraction(learned, bm25), 2.0 / 3.0)


def test_corpus_presets_have_expected_misalignment(small_corpus,
                                                   aligned_corpus):
    mis_s = misalignment_fraction(small_corpus.learned, small_corpus.bm25)
    mis_u = misalignment_fraction(aligned_corpus.learned, aligned_corpus.bm25)
    assert mis_s > 0.6, "splade-like preset should be heavily misaligned"
    assert mis_u < 0.2, "unicoil-like preset should be mostly aligned"


def test_build_bm25_weights_positive():
    rng = np.random.default_rng(0)
    terms = rng.integers(0, 16, 200)
    docs = rng.integers(0, 64, 200)
    tfs = 1 + rng.integers(0, 5, 200)
    dl = np.maximum(np.bincount(docs, weights=tfs, minlength=64), 1.0)
    model, stats = build_bm25(64, 16, terms, docs, tfs, dl)
    assert model.weights.min() > 0
    assert stats.idf.shape == (16,)
