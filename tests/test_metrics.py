"""Property tests for core/metrics.py against brute-force references.

The metric contract the eval harness depends on (see the module
docstring of ``repro.core.metrics``): duplicates count once, sentinel
ids (< 0) are never relevant, k beyond the list degrades gracefully,
``mean_and_p99`` survives empty / NaN samples. Each metric is checked
against an independently written reference on randomized inputs, plus
the specific edge cases the guards exist for.
"""
import numpy as np
import pytest

from repro.core.metrics import (evaluate_run, mean_and_p99, mrr_at_k,
                                ndcg_at_k, recall_at_k)


# -- brute-force references (deliberately naive, set-based) -------------------

def _ref_mrr(ranked, relevant, k):
    for i, d in enumerate(list(ranked)[:k]):
        if d in relevant:
            return 1.0 / (i + 1)
    return 0.0


def _ref_recall(ranked, relevant, k):
    if not relevant:
        return 0.0
    return len(set(list(ranked)[:k]) & relevant) / len(relevant)


def _ref_ndcg(ranked, gains, k):
    dcg, seen = 0.0, set()
    for i, d in enumerate(list(ranked)[:k]):
        if d in seen:
            continue
        seen.add(d)
        dcg += (2.0 ** gains.get(d, 0.0) - 1.0) / np.log2(i + 2)
    ideal = sorted(gains.values(), reverse=True)[:k]
    idcg = sum((2.0 ** g - 1.0) / np.log2(i + 2)
               for i, g in enumerate(ideal))
    return dcg / idcg if idcg > 0 else 0.0


def _random_case(rng):
    n = int(rng.integers(1, 40))
    ranked = rng.integers(-1, 30, size=n)          # includes -1 sentinels
    relevant = {int(d) for d in rng.integers(0, 30,
                                             size=rng.integers(0, 8))}
    gains = {d: float(rng.integers(1, 4)) for d in relevant}
    k = int(rng.integers(1, 50))                   # often > len(ranked)
    return ranked, relevant, gains, k


@pytest.mark.parametrize("seed", range(20))
def test_metrics_match_bruteforce(seed):
    rng = np.random.default_rng(seed)
    for _ in range(25):
        ranked, relevant, gains, k = _random_case(rng)
        ref_ranked = [int(d) for d in ranked]
        assert mrr_at_k(ranked, relevant, k) == pytest.approx(
            _ref_mrr(ref_ranked, relevant, k))
        assert recall_at_k(ranked, relevant, k) == pytest.approx(
            _ref_recall(ref_ranked, relevant, k))
        assert ndcg_at_k(ranked, gains, k) == pytest.approx(
            _ref_ndcg(ref_ranked, gains, k))


def test_metrics_bounded_and_monotone_in_k():
    rng = np.random.default_rng(3)
    for _ in range(25):
        ranked, relevant, gains, _ = _random_case(rng)
        prev_r = prev_m = 0.0
        for k in range(1, len(ranked) + 3):
            m = mrr_at_k(ranked, relevant, k)
            r = recall_at_k(ranked, relevant, k)
            n = ndcg_at_k(ranked, gains, k)
            assert 0.0 <= m <= 1.0 and 0.0 <= r <= 1.0 and 0.0 <= n <= 1.0
            assert r >= prev_r - 1e-12      # recall never drops with k
            assert m >= prev_m - 1e-12      # first hit only gets closer
            prev_r, prev_m = r, m


# -- edge cases the guards exist for ------------------------------------------

def test_empty_relevant_set_scores_zero():
    ranked = np.array([3, 1, 2])
    assert mrr_at_k(ranked, set(), 10) == 0.0
    assert recall_at_k(ranked, set(), 10) == 0.0
    assert ndcg_at_k(ranked, {}, 10) == 0.0


def test_k_larger_than_ranked_list():
    ranked = np.array([5, 7])
    assert mrr_at_k(ranked, {7}, 100) == 0.5
    assert recall_at_k(ranked, {7, 9}, 100) == 0.5
    assert ndcg_at_k(ranked, {7: 1.0}, 100) == pytest.approx(
        (1.0 / np.log2(3)))


def test_duplicate_ids_count_once():
    ranked = np.array([4, 4, 4, 9])
    assert recall_at_k(ranked, {4, 9}, 4) == 1.0          # not 3/2
    # dup occurrences earn no extra DCG, and don't block later docs
    with_dups = ndcg_at_k(ranked, {4: 1.0, 9: 1.0}, 4)
    no_dups = ndcg_at_k(np.array([4, 9]), {4: 1.0, 9: 1.0}, 4)
    assert with_dups <= no_dups
    assert with_dups == pytest.approx(
        (1.0 + 1.0 / np.log2(5)) / (1.0 + 1.0 / np.log2(3)))


def test_sentinel_ids_never_relevant():
    ranked = np.array([-1, -1, 8])
    assert mrr_at_k(ranked, {8}, 10) == pytest.approx(1.0 / 3)
    assert recall_at_k(ranked, {8}, 10) == 1.0
    # a hostile relevant set containing -1 must not turn sentinels
    # into hits
    assert recall_at_k(np.array([-1, -1]), {-1, 8}, 10) == 0.0
    assert mrr_at_k(np.array([-1, 3]), {-1, 3}, 10) == 0.5
    assert ndcg_at_k(np.array([-1, 3]), {-1: 2.0, 3: 1.0}, 10) < 1.0


def test_mean_and_p99_guards():
    mean, p99 = mean_and_p99(np.array([]))
    assert np.isnan(mean) and np.isnan(p99)
    mean, p99 = mean_and_p99(np.array([np.nan, np.nan]))
    assert np.isnan(mean) and np.isnan(p99)
    # non-finite entries are dropped, not averaged in; p99 is the
    # exact-rank quantile (a latency some query took), not interpolated
    mean, p99 = mean_and_p99(np.array([1.0, np.nan, 3.0, np.inf]))
    assert mean == pytest.approx(2.0)
    assert p99 == pytest.approx(3.0)
    mean, p99 = mean_and_p99(np.array([5.0]))
    assert mean == 5.0 and p99 == 5.0


def test_evaluate_run_aggregates():
    ids = np.array([[1, 2, 3], [9, 9, 9]])
    qrels = [{1}, {7}]
    m = evaluate_run(ids, qrels, k=3)
    assert m["mrr"] == pytest.approx(0.5)
    assert m["recall"] == pytest.approx(0.5)
    assert 0.0 <= m["ndcg"] <= 1.0


# -- hypothesis deepening (these two skip cleanly when unavailable; the
# randomized-seed coverage above runs regardless) -----------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    ranked_lists = st.lists(st.integers(min_value=-1, max_value=25),
                            min_size=0, max_size=30)
    rel_sets = st.sets(st.integers(min_value=0, max_value=25), max_size=8)

    @settings(max_examples=200, deadline=None)
    @given(ranked=ranked_lists, relevant=rel_sets,
           k=st.integers(min_value=1, max_value=40))
    def test_hyp_binary_metrics_match_reference(ranked, relevant, k):
        arr = np.array(ranked, dtype=np.int64).reshape(-1)
        assert mrr_at_k(arr, relevant, k) == pytest.approx(
            _ref_mrr(ranked, relevant, k))
        assert recall_at_k(arr, relevant, k) == pytest.approx(
            _ref_recall(ranked, relevant, k))

    @settings(max_examples=200, deadline=None)
    @given(ranked=ranked_lists,
           gains=st.dictionaries(st.integers(min_value=0, max_value=25),
                                 st.floats(min_value=0.5, max_value=4.0),
                                 max_size=8),
           k=st.integers(min_value=1, max_value=40))
    def test_hyp_ndcg_matches_reference_and_is_bounded(ranked, gains, k):
        arr = np.array(ranked, dtype=np.int64).reshape(-1)
        got = ndcg_at_k(arr, gains, k)
        assert got == pytest.approx(_ref_ndcg(ranked, gains, k))
        assert 0.0 <= got <= 1.0 + 1e-9
else:
    @pytest.mark.skip(reason="hypothesis not installed; the randomized-"
                      "seed reference coverage above still ran")
    def test_hyp_property_suite():
        pass
