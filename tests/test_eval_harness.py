"""The relevance harness end-to-end: graded corpora, TREC interchange,
the committed quality baseline, and the paper's headline small-k claim.

The regression test at the bottom is the acceptance pin for this PR:
on the misaligned graded corpus at k=10, guided traversal (GTI,
alpha=beta=1) with over-estimated thresholds measurably degrades MRR@10
against the rank-safe baseline; the two-level 2GTI-Accurate preset
(beta=0 — learned-only local pruning) recovers to within tolerance; and
the inversion (keeping two-level pruning disabled, i.e. staying on GTI)
demonstrably fails that tolerance. All inputs are seed-pinned, so the
asserted margins are deterministic, not statistical.
"""
import json
import pathlib

import numpy as np
import pytest

from repro.core import twolevel
from repro.eval import (build_hybrid, evaluate_ranking,
                        evaluate_retriever, evaluate_trec, load_qrels,
                        load_run, make_graded_corpus, write_run)
from repro.eval.synthetic import _embed_queries_np
from repro.retrieval import Retriever

REPO = pathlib.Path(__file__).resolve().parent.parent


@pytest.fixture(scope="module")
def graded():
    """The quality_bench corpus (same knobs, same seed): the tests below
    pin the same numbers the committed BENCH_quality.json reports."""
    return make_graded_corpus(n_docs=4096, n_terms=1024, n_queries=32,
                              dim=32, seed=0)


@pytest.fixture(scope="module")
def hybrid(graded):
    return build_hybrid(graded, tile_size=128)


def _mrr10(hybrid, graded, engine, params, tf, **opts):
    r = Retriever.open(hybrid, params, engine=engine, **opts)
    resp = r.search(k=10, threshold_factor=tf, **graded.queries())
    return evaluate_ranking(resp.ids, graded.qrels)["mrr@10"]


# -- graded corpus properties -------------------------------------------------

def test_graded_corpus_structure(graded):
    c = graded.corpus
    assert len(graded.qrels) == 32
    for gains, rel, dis in zip(graded.qrels, c.qrels, c.q_distractors):
        grades = set(gains.values())
        assert grades <= {1.0, 2.0} and 2.0 in grades
        assert {d for d, g in gains.items() if g == 2.0} == rel
        assert sum(1 for g in gains.values() if g == 1.0) == 3
        assert not (set(gains) & dis)       # distractors are non-relevant
    assert graded.doc_emb.shape == (4096, 32)
    np.testing.assert_allclose(np.linalg.norm(graded.doc_emb, axis=1),
                               1.0, atol=1e-5)


def test_graded_corpus_is_seed_pinned(graded):
    again = make_graded_corpus(n_docs=4096, n_terms=1024, n_queries=32,
                               dim=32, seed=0)
    np.testing.assert_array_equal(again.doc_emb, graded.doc_emb)
    np.testing.assert_array_equal(again.q_proj, graded.q_proj)
    assert again.qrels == graded.qrels
    np.testing.assert_array_equal(again.corpus.queries,
                                  graded.corpus.queries)


def test_default_corpus_rng_unchanged_by_graded_knobs():
    """The graded tier and boost scale must not perturb the seeded draw
    sequence at their defaults: pinned parity baselines depend on
    bit-identical corpora."""
    from repro.data import make_corpus
    base = make_corpus("splade_like", n_docs=512, n_terms=256,
                       n_queries=4, seed=9)
    explicit = make_corpus("splade_like", n_docs=512, n_terms=256,
                           n_queries=4, seed=9, n_rel_partial=0,
                           rel_boost_scale=1.0)
    np.testing.assert_array_equal(base.learned.weights,
                                  explicit.learned.weights)
    np.testing.assert_array_equal(base.bm25.weights, explicit.bm25.weights)
    assert base.qrels == explicit.qrels
    assert base.qrels_graded == [{d: 2.0 for d in r} for r in base.qrels]


def test_planted_embeddings_separate_grades(graded):
    """Relevant docs must sit far above the noise floor in dense cosine,
    partials in between — and the planting must target the *query-time*
    embedding (the numpy twin of hybrid._embed_impl)."""
    q_emb = _embed_queries_np(graded.q_proj, graded.corpus.queries,
                              graded.corpus.q_weights_l)
    rel_cos, part_cos, noise_cos = [], [], []
    planted = set()
    for gains in graded.qrels:
        planted |= set(gains)
    for d in graded.corpus.q_distractors:
        planted |= d
    rng = np.random.default_rng(0)
    noise_docs = [d for d in rng.integers(0, 4096, 200) if d not in planted]
    for qi, gains in enumerate(graded.qrels):
        for d, g in gains.items():
            (rel_cos if g == 2.0 else part_cos).append(
                float(graded.doc_emb[d] @ q_emb[qi]))
        noise_cos.extend(float(graded.doc_emb[d] @ q_emb[qi])
                         for d in noise_docs[:10])
    assert np.mean(rel_cos) > np.mean(part_cos) > np.mean(noise_cos)
    assert np.mean(rel_cos) > 0.5
    assert abs(np.mean(noise_cos)) < 0.1


# -- TREC interchange ---------------------------------------------------------

def test_trec_round_trip(tmp_path, graded, hybrid):
    """write_run -> load_run -> evaluate gives the same metrics as the
    in-memory driver (integer docids survive the string round trip)."""
    r = Retriever.open(hybrid, twolevel.fast(), engine="cascade",
                       depth=100)
    resp = r.search(k=100, **graded.queries())
    direct = evaluate_ranking(resp.ids, graded.qrels)

    qids = [f"q{i}" for i in range(len(graded.qrels))]
    run_path, qrels_path = tmp_path / "run.txt", tmp_path / "qrels.txt"
    write_run(run_path, qids, resp.ids, resp.scores, tag="cascade")
    qrels_path.write_text("".join(
        f"{qid} 0 {d} {int(g)}\n"
        for qid, gains in zip(qids, graded.qrels)
        for d, g in sorted(gains.items())))
    via_files = evaluate_trec(run_path, qrels_path)
    for m in ("mrr@10", "ndcg@10", "recall@10", "recall@100"):
        assert via_files[m] == pytest.approx(direct[m], abs=1e-9)


def test_trec_loaders_edge_cases(tmp_path):
    qp = tmp_path / "qrels.txt"
    qp.write_text("q1 0 docA 2\nq1 0 docB 0\n\nq2 0 docA 1\n")
    qrels = load_qrels(qp)
    assert qrels.qids == ["q1", "q2"]
    # grade-0 lines are kept as judgments but carry no gain
    assert qrels.gains["q1"]["docB"] == 0.0
    assert qrels.graded(["q1", "q2", "q3"]) == [
        {qrels.doc_index["docA"]: 2.0}, {qrels.doc_index["docA"]: 1.0},
        {}]
    rp = tmp_path / "run.txt"
    rp.write_text("q1 Q0 docB 2 0.5 t\nq1 Q0 docNEW 1 0.9 t\n")
    qids, ids = load_run(rp, qrels, depth=4)
    assert qids == ["q1"]
    # rank column orders the row; unjudged docids get fresh indices
    assert ids[0].tolist() == [qrels.doc_index["docNEW"],
                               qrels.doc_index["docB"], -1, -1]
    bad = tmp_path / "bad.txt"
    bad.write_text("q1 0 docA\n")
    with pytest.raises(ValueError, match="expected"):
        load_qrels(bad)
    with pytest.raises(ValueError, match="expected"):
        load_run(bad, qrels)


# -- the committed quality baseline -------------------------------------------

def test_quality_bench_is_deterministic():
    """Two collections at the same seed produce identical quality
    metrics (latency fields excluded) — the property that makes
    BENCH_quality.json diffable across PRs."""
    from benchmarks.quality_bench import collect
    a, b = collect(smoke=True), collect(smoke=True)
    assert a["lanes"].keys() == b["lanes"].keys()
    metrics = ("mrr@10", "ndcg@10", "recall@10", "recall@100",
               "mrr@10_at_k10")
    for lane in a["lanes"]:
        for m in metrics:
            if m in a["lanes"][lane]:
                assert a["lanes"][lane][m] == b["lanes"][lane][m], (
                    lane, m)


def test_committed_baseline_cascade_beats_sparse():
    """The acceptance pin: in the committed BENCH_quality.json, the
    cascade lane's headline MRR@10 (k=10 execution) is strictly above
    the sparse-only lane under every (method, threshold_factor), and
    above the dense-only reference."""
    data = json.loads((REPO / "BENCH_quality.json").read_text())
    lanes = data["lanes"]
    compared = 0
    for name, row in lanes.items():
        if not name.endswith("/sparse"):
            continue
        casc = lanes[name.replace("/sparse", "/cascade")]
        assert casc["mrr@10_at_k10"] > row["mrr@10_at_k10"], name
        assert casc["recall@100"] >= row["recall@100"] - 1e-9, name
        compared += 1
    assert compared == 6            # 3 methods x 2 threshold factors
    best_casc = max(r["mrr@10_at_k10"] for n, r in lanes.items()
                    if n.endswith("/cascade"))
    assert best_casc > lanes["dense_only"]["mrr@10"]


def test_evaluate_retriever_reports_quality_and_latency(graded, hybrid):
    row = evaluate_retriever(
        Retriever.open(hybrid, twolevel.fast(), engine="rrf", depth=100),
        graded.queries(), graded.qrels, k=100)
    assert row["engine"] == "rrf" and row["n_queries"] == 32
    assert row["mrt_ms"] > 0 and np.isfinite(row["p99_ms"])
    assert 0.0 < row["mrr@10"] <= 1.0
    assert row["recall@100"] >= row["recall@10"] - 1e-9


# -- the headline small-k claim -----------------------------------------------

# Deterministic margins on the seed-0 corpus (measured: drop ~0.090,
# recovery overshoot ~+0.025). DROP_MARGIN is what "measurably degrades"
# means; RECOVERY_TOL is what "recovers" means — and the inversion check
# below proves GTI itself fails that tolerance, so the recovery is
# attributable to two-level pruning (beta=0), not slack in the bound.
TF_MISALIGNED = 3.0
DROP_MARGIN = 0.05
RECOVERY_TOL = 0.02


def test_small_k_guided_degradation_and_twolevel_recovery(graded, hybrid):
    safe = _mrr10(hybrid, graded, "batched",
                  twolevel.linear_combination(gamma=0.05), TF_MISALIGNED)
    gti = _mrr10(hybrid, graded, "batched", twolevel.gti(), TF_MISALIGNED)
    acc = _mrr10(hybrid, graded, "batched", twolevel.accurate(),
                 TF_MISALIGNED)
    # the claim: guided-only traversal measurably degrades MRR@10...
    assert safe - gti >= DROP_MARGIN, (safe, gti)
    # ...two-level pruning (beta=0) recovers within tolerance...
    assert safe - acc <= RECOVERY_TOL, (safe, acc)
    # ...and WITHOUT two-level pruning (stay on GTI) the recovery
    # criterion demonstrably fails — the inverted configuration.
    assert safe - gti > RECOVERY_TOL, (safe, gti)


def test_small_k_cascade_recovers_guided_loss(graded, hybrid):
    """The hybrid second stage recovers what guided pruning lost: at the
    misaligned operating point, cascade MRR@10 beats sparse GTI by more
    than the guided drop itself."""
    gti = _mrr10(hybrid, graded, "batched", twolevel.gti(), TF_MISALIGNED)
    casc = _mrr10(hybrid, graded, "cascade", twolevel.gti(),
                  TF_MISALIGNED, depth=100)
    rrf = _mrr10(hybrid, graded, "rrf", twolevel.gti(), TF_MISALIGNED,
                 depth=100)
    assert casc >= gti + DROP_MARGIN, (casc, gti)
    assert rrf >= gti + DROP_MARGIN, (rrf, gti)
