"""Round-trip and bound-safety tests for the compression primitives in
``repro.index.codec`` — the contract every decode path (jnp gather,
Pallas in-kernel, streaming builder) is built on:

- delta + bit-pack: strictly-increasing tile-local offsets -> (first,
  gap-1 at a per-run width from {1,2,4,8,16}) -> bit-identical offsets
  back, for every width and for runs packed together into one word
  stream (word alignment keeps runs self-contained);
- int8 quantization: ``fl(zero + scale * q) <= max(run)`` in exact
  float32 for every code — the property that keeps the *exact* fp32 tile
  maxima valid upper bounds, so chunk scheduling and theta pruning are
  untouched by compression.

Deterministic seeded cases run always; the hypothesis generalizations
run when hypothesis is installed (optional dev dependency).
"""
import numpy as np
import pytest

from repro.index import codec


def _roundtrip_runs(rng, n_runs, max_count, max_gap):
    """Encode random runs the way encode_runs does; return per-run
    (offsets, decoded) pairs."""
    counts = rng.integers(0, max_count + 1, size=n_runs)
    runs = []
    for c in counts:
        gaps = rng.integers(1, max_gap + 1, size=max(c - 1, 0))
        start = int(rng.integers(0, 64))
        offs = start + np.concatenate(([0], np.cumsum(gaps)))[:c]
        runs.append(offs.astype(np.int64))
    enc = [codec.delta_encode(o) for o in runs]
    maxv = np.array([int(v.max(initial=0)) for _, v in enc])
    width = codec.choose_width(maxv)
    words = codec.words_for(np.maximum(counts - 1, 0), width)
    word_start = np.concatenate(([0], np.cumsum(words)))[:-1]
    vals = np.concatenate([v for _, v in enc]) if runs else np.zeros(0)
    run_of = np.repeat(np.arange(n_runs), np.maximum(counts - 1, 0))
    val_idx = np.concatenate([np.arange(max(c - 1, 0)) for c in counts])
    packed = codec.pack_runs(vals, run_of, val_idx, width, word_start)
    out = []
    for r, offs in enumerate(runs):
        if counts[r] == 0:
            out.append((offs, offs))
            continue
        gaps = codec.unpack_run(packed, int(word_start[r]), int(width[r]),
                                int(counts[r] - 1))
        out.append((offs, codec.delta_decode(enc[r][0], gaps)))
    return out


def test_choose_width_boundaries():
    vals = np.array([0, 1, 2, 3, 4, 15, 16, 255, 256, 0xFFFF])
    want = np.array([1, 1, 2, 2, 4, 4, 8, 8, 16, 16])
    np.testing.assert_array_equal(codec.choose_width(vals), want)
    with pytest.raises(ValueError, match="exceeds 16 bits"):
        codec.choose_width(np.array([0x10000]))


def test_widths_divide_words():
    # the single-word decode (no two-word stitching) relies on this
    for w in codec.WIDTHS:
        assert 32 % w == 0


def test_delta_roundtrip_identity():
    offs = np.array([3, 4, 9, 100, 101])
    first, vals = codec.delta_encode(offs)
    assert first == 3
    np.testing.assert_array_equal(codec.delta_decode(first, vals), offs)
    with pytest.raises(ValueError, match="strictly increasing"):
        codec.delta_encode(np.array([5, 5]))


@pytest.mark.parametrize("max_gap", [1, 2, 9, 250, 60000])
def test_pack_unpack_roundtrip_all_widths(max_gap):
    rng = np.random.default_rng(max_gap)
    for offs, dec in _roundtrip_runs(rng, n_runs=50, max_count=40,
                                     max_gap=max_gap):
        np.testing.assert_array_equal(dec, offs)


def test_pack_runs_word_aligned():
    # two runs: widths 1 and 16; run 1 must start on a fresh word even
    # though run 0 occupies two bits of its word
    width = np.array([1, 16], dtype=np.uint8)
    word_start = np.array([0, 1])
    packed = codec.pack_runs(np.array([1, 1, 300]), np.array([0, 0, 1]),
                             np.array([0, 1, 0]), width, word_start)
    assert codec.unpack_run(packed, 0, 1, 2).tolist() == [1, 1]
    assert codec.unpack_run(packed, 1, 16, 1).tolist() == [300]


def test_fp16_down_is_lower_bound():
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 70000, size=4096).astype(np.float32)
    with np.errstate(over="ignore"):  # >65504 intentionally overflows fp16
        h = codec.fp16_down(x)
    assert h.dtype == np.float16
    assert np.all(h.astype(np.float32) <= x)
    # exact fp16 values pass through unchanged
    exact = np.float32(0.5)
    assert codec.fp16_down(exact) == np.float16(0.5)


def test_quantize_bound_safety_and_accuracy():
    rng = np.random.default_rng(1)
    n_runs = 256
    counts = rng.integers(0, 64, size=n_runs)
    run_of = np.repeat(np.arange(n_runs), counts)
    w = rng.gamma(2.0, 1.5, size=counts.sum()).astype(np.float32)
    q, scale, zero = codec.quantize_runs(w, run_of, n_runs)

    mx = np.full(n_runs, -np.inf, np.float32)
    np.maximum.at(mx, run_of, w)
    # the bound the pruning math depends on: dequant never exceeds the
    # exact run max — for the *stored* codes and for every q <= 255
    deq = codec.dequantize(q, scale[run_of], zero[run_of])
    assert np.all(deq <= mx[run_of])
    deq_top = codec.dequantize(np.full(counts.sum(), 255, np.uint8),
                               scale[run_of], zero[run_of])
    assert np.all(deq_top <= mx[run_of])
    # reconstruction error ~ one quantization step (the fp16 round-down
    # of scale/zero can cost up to one extra ulp each, hence 2x + rel)
    s32 = scale.astype(np.float32)[run_of]
    assert np.all(np.abs(deq - w)
                  <= 2 * np.maximum(s32, 1e-6) + 1e-3 * np.abs(w) + 1e-6)


def test_quantize_empty_and_constant_runs():
    # run 0 empty, run 1 constant: scale 0, dequant == fp16_down(value)
    w = np.array([2.5, 2.5, 2.5], np.float32)
    q, scale, zero = codec.quantize_runs(w, np.array([1, 1, 1]), 2)
    assert scale[0] == 0 and zero[0] == 0
    assert scale[1] == 0
    np.testing.assert_array_equal(
        codec.dequantize(q, scale[1], zero[1]), np.full(3, 2.5, np.float32))


# -- hypothesis generalizations (optional dev dependency) -------------------
# guarded import (not module-level importorskip: the deterministic tests
# above must run even without hypothesis installed)

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # pragma: no cover - placeholders keep defs valid
        return lambda f: f

    settings, st = given, None

needs_hypothesis = pytest.mark.skipif(
    not HAVE_HYPOTHESIS, reason="property tests need hypothesis "
                                "(pip install hypothesis)")


@needs_hypothesis
@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=0x10000),
                min_size=1, max_size=64) if HAVE_HYPOTHESIS else None,
       st.integers(min_value=0, max_value=0xFF) if HAVE_HYPOTHESIS else None)
def test_prop_delta_pack_roundtrip(gaps, start):
    offs = start + np.concatenate(([0], np.cumsum(gaps)))[:len(gaps)]
    offs = offs.astype(np.int64)
    first, vals = codec.delta_encode(offs)
    width = int(codec.choose_width(np.array([int(vals.max(initial=0))]))[0])
    packed = codec.pack_runs(vals, np.zeros(len(vals), np.int64),
                             np.arange(len(vals)),
                             np.array([width], np.uint8), np.array([0]))
    dec = codec.delta_decode(first,
                             codec.unpack_run(packed, 0, width, len(vals)))
    np.testing.assert_array_equal(dec, offs)


@needs_hypothesis
@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(min_value=0.0, max_value=1e4, width=32,
                          allow_nan=False),
                min_size=1, max_size=64) if HAVE_HYPOTHESIS else None)
def test_prop_quantize_never_exceeds_run_max(ws):
    w = np.asarray(ws, np.float32)
    q, scale, zero = codec.quantize_runs(w, np.zeros(len(w), np.int64), 1)
    deq = codec.dequantize(q, scale[0], zero[0])
    assert np.all(deq <= w.max())
    assert np.all(np.abs(deq - w)
                  <= 2 * max(float(scale[0]), 1e-6) + 1e-3 * np.abs(w) + 1e-6)
