"""Cross-validation: the batched tile-scan engine (pure-jnp and Pallas
guided_score kernel paths) against the sequential numpy DAAT oracle's Q_Rk.

Rank-safe configurations (alpha=beta=gamma) must agree exactly — same ids,
same scores — because pruning is bound-exact for the combined score and the
tiebreak (docid ascending) matches. Guided configurations are compared on
the returned score vector (both traversals keep every doc whose RankScore
makes the final queue; the oracle freezes docs eagerly per-document while
the tile engine freezes lazily per-tile, so ids may differ only in the tail
where scores tie)."""
import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.core.oracle import daat_2gti
from repro.core.traversal import retrieve_batched

K = 10


@pytest.fixture(scope="module")
def setup(small_corpus):
    merged = small_corpus.merged("scaled")
    index = build_index(merged, tile_size=256)
    return small_corpus, merged, index


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "pallas_kernel"])
@pytest.mark.parametrize("gamma", [0.0, 0.05, 0.3, 1.0])
def test_rank_safe_engine_matches_oracle_qrk(setup, use_kernel, gamma):
    corpus, merged, index = setup
    p = twolevel.original(gamma=gamma)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p, use_kernel=use_kernel)
    for qi in range(len(corpus.queries)):
        ids_o, vals_o, _ = daat_2gti(merged, corpus.queries[qi],
                                     corpus.q_weights_b[qi],
                                     corpus.q_weights_l[qi], p)
        valid = ids_o >= 0
        np.testing.assert_allclose(res.scores[qi][valid], vals_o[valid],
                                   rtol=2e-4, atol=1e-3)
        # ids must match except where adjacent scores tie (order of equal
        # scores is implementation-defined between the two traversals)
        eng, orc = res.ids[qi][valid], ids_o[valid]
        mism = eng != orc
        if mism.any():
            v = vals_o[valid]
            tied = np.zeros_like(mism)
            tied[1:] |= np.abs(np.diff(v)) < 1e-3
            tied[:-1] |= np.abs(np.diff(v)) < 1e-3
            assert mism[~tied].sum() == 0, (
                f"q{qi}: untied id mismatch engine={eng} oracle={orc}")


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "pallas_kernel"])
@pytest.mark.parametrize("preset", ["fast", "accurate", "gti"])
def test_guided_engine_scores_match_oracle_qrk(setup, use_kernel, preset):
    """Unsafe configs: the tile engine freezes docs lazily per-tile while
    the oracle freezes eagerly per-doc, so only the queue *boundary* may
    hold different docs — the head of Q_Rk must agree exactly and the tail
    scores must stay within 2% (either traversal may keep the slightly
    better boundary doc)."""
    corpus, merged, index = setup
    p = getattr(twolevel, preset)()
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p, use_kernel=use_kernel)
    for qi in range(len(corpus.queries)):
        ids_o, vals_o, _ = daat_2gti(merged, corpus.queries[qi],
                                     corpus.q_weights_b[qi],
                                     corpus.q_weights_l[qi], p)
        valid = ids_o >= 0
        eng, orc = res.scores[qi][valid], vals_o[valid]
        np.testing.assert_allclose(eng[:K - 2], orc[:K - 2],
                                   rtol=2e-4, atol=1e-3)
        np.testing.assert_allclose(eng[K - 2:], orc[K - 2:],
                                   rtol=2e-2, atol=1e-2)


def test_kernel_and_jnp_paths_identical_across_presets(setup):
    """Both execution paths of retrieve_batched are the same algorithm."""
    corpus, merged, index = setup
    for p in (twolevel.fast(), twolevel.original(gamma=0.2),
              twolevel.fast().replace(bound_mode="tile")):
        r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, p)
        r1 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, p, use_kernel=True)
        np.testing.assert_array_equal(r0.ids, r1.ids)
        np.testing.assert_allclose(r0.scores, r1.scores, rtol=1e-6)
