"""Integration tests: tile-scan engine vs exhaustive scoring and the
sequential numpy DAAT oracle; execution-mode and scheduling equivalences."""
import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.core.index import impact_doc_order
from repro.core.metrics import evaluate_run
from repro.core.oracle import daat_2gti, ranked_list
from repro.core.traversal import retrieve_batched, retrieve_sequential


@pytest.fixture(scope="module")
def setup(small_corpus):
    merged = small_corpus.merged("scaled")
    index = build_index(merged, tile_size=256)
    return small_corpus, merged, index


def _q(corpus, qi):
    return (corpus.queries[qi], corpus.q_weights_b[qi],
            corpus.q_weights_l[qi])


@pytest.mark.parametrize("gamma", [0.0, 0.3, 1.0])
def test_rank_safe_config_equals_exhaustive(setup, gamma):
    """alpha=beta=gamma: pruning is bound-exact for the combined score."""
    corpus, merged, index = setup
    p = twolevel.original(gamma=gamma)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p)
    for qi in range(len(corpus.queries)):
        ids_ref, vals_ref = ranked_list(merged, *_q(corpus, qi), gamma, 10)
        np.testing.assert_allclose(res.scores[qi], vals_ref,
                                   rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("schedule", ["docid", "impact"])
def test_sequential_equals_batched(setup, schedule):
    corpus, merged, index = setup
    p = twolevel.fast().replace(schedule=schedule)
    res_b = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, p)
    res_s = retrieve_sequential(index, corpus.queries[:4],
                                corpus.q_weights_b[:4],
                                corpus.q_weights_l[:4], p)
    np.testing.assert_array_equal(res_s.ids, res_b.ids[:4])
    np.testing.assert_allclose(res_s.scores, res_b.scores[:4], rtol=1e-6)


def test_impact_schedule_rank_safe_set_equality(setup):
    """Visit order must not change results for a rank-safe config."""
    corpus, merged, index = setup
    p0 = twolevel.original(gamma=0.2)
    p1 = p0.replace(schedule="impact")
    r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p0)
    r1 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p1)
    np.testing.assert_allclose(r0.scores, r1.scores, rtol=1e-6)
    assert all(set(a) == set(b) for a, b in zip(r0.ids, r1.ids))


def test_doc_reordering_preserves_rank_safe_results(setup):
    corpus, merged, index = setup
    order = impact_doc_order(merged)
    index_r = build_index(merged, tile_size=256, doc_order=order)
    p = twolevel.original(gamma=0.2)
    r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p)
    r1 = retrieve_batched(index_r, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p)
    np.testing.assert_allclose(r0.scores, r1.scores, rtol=1e-6)
    assert all(set(a) == set(b) for a, b in zip(r0.ids, r1.ids))


def test_gti_is_special_case_alpha_beta_one(setup):
    corpus, merged, index = setup
    gti = twolevel.gti(gamma=0.1)
    manual = twolevel.TwoLevelParams(alpha=1.0, beta=1.0, gamma=0.1)
    r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, gti)
    r1 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, manual)
    np.testing.assert_array_equal(r0.ids, r1.ids)


def test_engine_matches_oracle_relevance(setup):
    """Tile engine prunes lazily vs per-doc DAAT: relevance metrics match."""
    corpus, merged, index = setup
    p = twolevel.fast()
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p)
    oracle_ids = np.array([daat_2gti(merged, *_q(corpus, qi), p)[0]
                           for qi in range(len(corpus.queries))])
    m_e = evaluate_run(res.ids, corpus.qrels, 10)
    m_o = evaluate_run(oracle_ids, corpus.qrels, 10)
    assert abs(m_e["mrr"] - m_o["mrr"]) < 0.05
    assert m_e["recall"] >= m_o["recall"] - 0.05


def test_overestimation_prunes_more_and_degrades(setup):
    """Table 3: threshold over-estimation trades relevance for pruning."""
    corpus, merged, index = setup
    base = twolevel.original(gamma=0.0)
    over = base.replace(threshold_factor=1.5)
    r_base = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, base)
    r_over = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, over)
    assert (r_over.stats["docs_survived"].mean()
            <= r_base.stats["docs_survived"].mean())
    m_b = evaluate_run(r_base.ids, corpus.qrels, 10)
    m_o = evaluate_run(r_over.ids, corpus.qrels, 10)
    assert m_o["recall"] <= m_b["recall"] + 1e-9


def test_guided_prunes_more_than_unguided(small_corpus):
    """BM25 guidance must create skipping the learned weights cannot.

    Uses the zero-filled index: there BM25's skewed weight distribution is
    undiluted, the regime where the paper observes GT/GTI's pruning power.
    """
    corpus = small_corpus
    index = build_index(corpus.merged("zero"), tile_size=256)
    r_org = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, twolevel.original())
    r_gti = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, twolevel.gti())
    assert (r_gti.stats["docs_survived"].mean()
            < r_org.stats["docs_survived"].mean())


def test_stats_are_consistent(setup):
    corpus, merged, index = setup
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, twolevel.fast())
    s = res.stats
    assert np.all(s["docs_survived"] <= s["docs_present"])
    assert np.all(s["docs_frozen"] <= s["docs_survived"])
    assert np.all(s["tiles_visited"] <= s["n_tiles"])


def test_k_larger_than_matches(setup):
    corpus, merged, index = setup
    p = twolevel.fast()
    res = retrieve_batched(index, corpus.queries[:2], corpus.q_weights_b[:2],
                           corpus.q_weights_l[:2], p, k=500)
    assert res.ids.shape == (2, 500)
    # padded tail exists but scored entries are sorted desc
    sc = res.scores[0]
    finite = sc[np.isfinite(sc)]
    assert np.all(np.diff(finite) <= 1e-6)


def test_kernel_path_matches_jnp_path(setup):
    """Engine with the fused Pallas guided_score kernel (interpret mode)
    must match the pure-jnp tile scorer exactly."""
    corpus, merged, index = setup
    p = twolevel.fast()
    r_jnp = retrieve_batched(index, corpus.queries[:4],
                             corpus.q_weights_b[:4],
                             corpus.q_weights_l[:4], p)
    r_ker = retrieve_batched(index, corpus.queries[:4],
                             corpus.q_weights_b[:4],
                             corpus.q_weights_l[:4], p, use_kernel=True)
    np.testing.assert_array_equal(r_jnp.ids, r_ker.ids)
    np.testing.assert_allclose(r_jnp.scores, r_ker.scores, rtol=1e-6)
