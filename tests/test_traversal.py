"""Integration tests: tile-scan engine vs exhaustive scoring and the
sequential numpy DAAT oracle; execution-mode and scheduling equivalences."""
import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.core.index import impact_doc_order
from repro.core.metrics import evaluate_run
from repro.core.oracle import daat_2gti, ranked_list
from repro.core.traversal import retrieve_batched, retrieve_sequential


@pytest.fixture(scope="module")
def setup(small_corpus):
    merged = small_corpus.merged("scaled")
    index = build_index(merged, tile_size=256)
    return small_corpus, merged, index


def _q(corpus, qi):
    return (corpus.queries[qi], corpus.q_weights_b[qi],
            corpus.q_weights_l[qi])


@pytest.mark.parametrize("gamma", [0.0, 0.3, 1.0])
def test_rank_safe_config_equals_exhaustive(setup, gamma):
    """alpha=beta=gamma: pruning is bound-exact for the combined score."""
    corpus, merged, index = setup
    p = twolevel.original(gamma=gamma)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p)
    for qi in range(len(corpus.queries)):
        ids_ref, vals_ref = ranked_list(merged, *_q(corpus, qi), gamma, 10)
        np.testing.assert_allclose(res.scores[qi], vals_ref,
                                   rtol=2e-5, atol=1e-4)


@pytest.mark.parametrize("schedule", ["docid", "impact"])
def test_sequential_equals_batched(setup, schedule):
    corpus, merged, index = setup
    p = twolevel.fast().replace(schedule=schedule)
    res_b = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, p)
    res_s = retrieve_sequential(index, corpus.queries[:4],
                                corpus.q_weights_b[:4],
                                corpus.q_weights_l[:4], p)
    np.testing.assert_array_equal(res_s.ids, res_b.ids[:4])
    np.testing.assert_allclose(res_s.scores, res_b.scores[:4], rtol=1e-6)


def test_impact_schedule_rank_safe_set_equality(setup):
    """Visit order must not change results for a rank-safe config."""
    corpus, merged, index = setup
    p0 = twolevel.original(gamma=0.2)
    p1 = p0.replace(schedule="impact")
    r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p0)
    r1 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p1)
    np.testing.assert_allclose(r0.scores, r1.scores, rtol=1e-6)
    assert all(set(a) == set(b) for a, b in zip(r0.ids, r1.ids))


def test_doc_reordering_preserves_rank_safe_results(setup):
    corpus, merged, index = setup
    order = impact_doc_order(merged)
    index_r = build_index(merged, tile_size=256, doc_order=order)
    p = twolevel.original(gamma=0.2)
    r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p)
    r1 = retrieve_batched(index_r, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p)
    np.testing.assert_allclose(r0.scores, r1.scores, rtol=1e-6)
    assert all(set(a) == set(b) for a, b in zip(r0.ids, r1.ids))


def test_gti_is_special_case_alpha_beta_one(setup):
    corpus, merged, index = setup
    gti = twolevel.gti(gamma=0.1)
    manual = twolevel.TwoLevelParams(alpha=1.0, beta=1.0, gamma=0.1)
    r0 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, gti)
    r1 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, manual)
    np.testing.assert_array_equal(r0.ids, r1.ids)


def test_engine_matches_oracle_relevance(setup):
    """Tile engine prunes lazily vs per-doc DAAT: relevance metrics match."""
    corpus, merged, index = setup
    p = twolevel.fast()
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p)
    oracle_ids = np.array([daat_2gti(merged, *_q(corpus, qi), p)[0]
                           for qi in range(len(corpus.queries))])
    m_e = evaluate_run(res.ids, corpus.qrels, 10)
    m_o = evaluate_run(oracle_ids, corpus.qrels, 10)
    assert abs(m_e["mrr"] - m_o["mrr"]) < 0.05
    assert m_e["recall"] >= m_o["recall"] - 0.05


def test_overestimation_prunes_more_and_degrades(setup):
    """Table 3: threshold over-estimation trades relevance for pruning."""
    corpus, merged, index = setup
    base = twolevel.original(gamma=0.0)
    over = base.replace(threshold_factor=1.5)
    r_base = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, base)
    r_over = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, over)
    assert (r_over.stats["docs_survived"].mean()
            <= r_base.stats["docs_survived"].mean())
    m_b = evaluate_run(r_base.ids, corpus.qrels, 10)
    m_o = evaluate_run(r_over.ids, corpus.qrels, 10)
    assert m_o["recall"] <= m_b["recall"] + 1e-9


def test_guided_prunes_more_than_unguided(small_corpus):
    """BM25 guidance must create skipping the learned weights cannot.

    Uses the zero-filled index: there BM25's skewed weight distribution is
    undiluted, the regime where the paper observes GT/GTI's pruning power.
    """
    corpus = small_corpus
    index = build_index(corpus.merged("zero"), tile_size=256)
    r_org = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, twolevel.original())
    r_gti = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, twolevel.gti())
    assert (r_gti.stats["docs_survived"].mean()
            < r_org.stats["docs_survived"].mean())


def test_stats_are_consistent(setup):
    corpus, merged, index = setup
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, twolevel.fast())
    s = res.stats
    assert np.all(s["docs_survived"] <= s["docs_present"])
    assert np.all(s["docs_frozen"] <= s["docs_survived"])
    assert np.all(s["tiles_visited"] <= s["n_tiles"])


def test_k_larger_than_matches(setup):
    corpus, merged, index = setup
    p = twolevel.fast()
    res = retrieve_batched(index, corpus.queries[:2], corpus.q_weights_b[:2],
                           corpus.q_weights_l[:2], p, k=500)
    assert res.ids.shape == (2, 500)
    # padded tail exists but scored entries are sorted desc
    sc = res.scores[0]
    finite = sc[np.isfinite(sc)]
    assert np.all(np.diff(finite) <= 1e-6)


def test_kernel_path_matches_jnp_path(setup):
    """Engine with the fused Pallas guided_score kernel (interpret mode)
    must match the pure-jnp tile scorer exactly."""
    corpus, merged, index = setup
    p = twolevel.fast()
    r_jnp = retrieve_batched(index, corpus.queries[:4],
                             corpus.q_weights_b[:4],
                             corpus.q_weights_l[:4], p)
    r_ker = retrieve_batched(index, corpus.queries[:4],
                             corpus.q_weights_b[:4],
                             corpus.q_weights_l[:4], p, use_kernel=True)
    np.testing.assert_array_equal(r_jnp.ids, r_ker.ids)
    np.testing.assert_allclose(r_jnp.scores, r_ker.scores, rtol=1e-6)


# -- chunked traversal: real skipping under jit -------------------------------

PARITY_STATS = ("tiles_visited", "docs_present", "docs_survived",
                "docs_frozen", "postings_touched")


def _assert_identical(full, chunked):
    np.testing.assert_array_equal(full.ids, chunked.ids)
    np.testing.assert_array_equal(full.scores, chunked.scores)
    for key in PARITY_STATS:
        np.testing.assert_array_equal(full.stats[key], chunked.stats[key])


def test_chunk_schedule_covers_all_tiles(setup):
    """The chunk order is a permutation of all tiles (plus the sentinel
    tail padding) with descending per-chunk max bounds."""
    import jax.numpy as jnp
    from repro.core.plan import chunk_schedule, plan_query
    corpus, merged, index = setup
    plan = plan_query(jnp.asarray(corpus.queries[0]),
                      jnp.asarray(corpus.q_weights_b[0]),
                      jnp.asarray(corpus.q_weights_l[0]),
                      index.sigma_b, index.sigma_l, jnp.float32(1.0))
    sched = chunk_schedule(plan, index.tile_max_b, index.tile_max_l,
                           jnp.float32(1.0), index.n_tiles, 3)
    chunks = np.asarray(sched.chunks)
    assert chunks.shape == (-(-index.n_tiles // 3), 3)
    real = chunks[chunks < index.n_tiles]
    np.testing.assert_array_equal(np.sort(real), np.arange(index.n_tiles))
    assert (chunks[chunks >= index.n_tiles] == index.n_tiles).all()
    ub = np.asarray(sched.chunk_ub)
    assert (np.diff(ub) <= 0).all()


@pytest.mark.parametrize("use_kernel", [False, True],
                         ids=["jnp", "pallas_kernel"])
@pytest.mark.parametrize("preset", ["rank_safe", "guided"])
def test_chunked_bit_identical_to_full_scan(setup, preset, use_kernel):
    """traversal='chunked' visits the descending-bound order, so it must
    be bit-identical — ids, scores, and every pruning stat — to the full
    impact-schedule scan, for rank-safe and guided configs alike."""
    corpus, merged, index = setup
    p = (twolevel.original(gamma=0.2) if preset == "rank_safe"
         else twolevel.fast()).replace(chunk_tiles=2)
    full = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                            corpus.q_weights_l,
                            p.replace(schedule="impact"),
                            use_kernel=use_kernel)
    ck = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, traversal="chunked",
                          use_kernel=use_kernel)
    _assert_identical(full, ck)
    assert "chunks_dispatched" in ck.stats
    assert (ck.stats["chunks_dispatched"] <= ck.stats["n_chunks"]).all()


def test_chunked_early_exit_dispatches_fewer_chunks(small_corpus):
    """On a guided config whose full scan skips tiles, the chunk loop must
    stop early: strictly fewer chunks dispatched than n_chunks, while
    results stay bit-identical to the full impact scan."""
    corpus = small_corpus
    index = build_index(corpus.merged("scaled"), tile_size=64)  # 32 tiles
    p = twolevel.gti().replace(chunk_tiles=4)                   # 8 chunks
    full = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                            corpus.q_weights_l,
                            p.replace(schedule="impact"))
    ck = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, traversal="chunked")
    _assert_identical(full, ck)
    # the full scan skips tiles here, so the chunk loop must exit early:
    # strictly fewer chunks dispatched than the grid holds, in aggregate
    # and for most queries (a query that never converges keeps its own
    # count at n_chunks; the batch-level reduction is the contract)
    assert (full.stats["tiles_visited"] < full.stats["n_tiles"]).any()
    disp, n_chunks = ck.stats["chunks_dispatched"], ck.stats["n_chunks"]
    assert disp.sum() < n_chunks.sum()
    assert (disp < n_chunks).mean() > 0.5
    # dispatched chunks at least cover the visited tiles
    assert (disp * p.chunk_tiles >= ck.stats["tiles_visited"]).all()


def test_chunked_fused_kernel_rank_safe_exact(setup):
    """The multi-tile guided_score_chunk kernel scores with chunk-start
    thresholds — for rank-safe configs that is still bound-exact, so the
    top-k must match the full impact scan bit-for-bit."""
    corpus, merged, index = setup
    p = twolevel.original(gamma=0.2).replace(chunk_tiles=2)
    full = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                            corpus.q_weights_l,
                            p.replace(schedule="impact"))
    fu = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, traversal="chunked_fused",
                          use_kernel=True)
    np.testing.assert_array_equal(full.ids, fu.ids)
    np.testing.assert_allclose(full.scores, fu.scores, rtol=1e-6)


def test_chunked_fused_guided_tolerance(small_corpus):
    """Guided configs under the fused chunk kernel: chunk-start thresholds
    shift the pruning trajectory (looser within a chunk, so the queues
    tighten faster across chunks) — the usual guided tolerance. At the
    default threshold_factor the trajectories coincide on this corpus
    (pinned as a regression, like the sharded guided parity test); under
    aggressive over-estimation heads must still agree almost everywhere."""
    corpus = small_corpus
    index = build_index(corpus.merged("scaled"), tile_size=64)
    q = (corpus.queries, corpus.q_weights_b, corpus.q_weights_l)
    p = twolevel.fast().replace(chunk_tiles=4)
    ck = retrieve_batched(index, *q, p, traversal="chunked")
    fu = retrieve_batched(index, *q, p, traversal="chunked_fused",
                          use_kernel=True)
    np.testing.assert_array_equal(ck.ids, fu.ids)
    np.testing.assert_allclose(ck.scores, fu.scores, rtol=1e-5, atol=1e-4)

    p_over = twolevel.fast(threshold_factor=1.5).replace(chunk_tiles=4)
    ck = retrieve_batched(index, *q, p_over, traversal="chunked")
    fu = retrieve_batched(index, *q, p_over, traversal="chunked_fused",
                          use_kernel=True)
    overlap = np.mean([len(set(a) & set(b)) / len(a)
                       for a, b in zip(ck.ids, fu.ids)])
    assert overlap > 0.9


def test_chunked_rejects_unknown_traversal(setup):
    corpus, merged, index = setup
    with pytest.raises(ValueError, match="traversal"):
        retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                         corpus.q_weights_l, twolevel.fast(),
                         traversal="tiled")


def test_chunk_tiles_argument_overrides_params(setup):
    """The per-call chunk_tiles override changes the chunk grid but not
    the results (both are the same descending-order traversal)."""
    corpus, merged, index = setup
    p = twolevel.fast()  # default chunk_tiles=8 -> 1 chunk on 8 tiles
    r8 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, traversal="chunked")
    r2 = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, traversal="chunked",
                          chunk_tiles=2)
    _assert_identical(r8, r2)
    assert r8.stats["n_chunks"][0] == 1.0
    assert r2.stats["n_chunks"][0] == 4.0
