"""Data pipeline: determinism (resume-safety), neighbor sampler shape/degree
invariants, molecule batch physics proxy."""
import numpy as np

from repro.data.stream import (GraphStore, lm_batch, molecule_batch,
                               pair_batch, recsys_batch)


def test_lm_batch_deterministic_per_step():
    a = lm_batch(5, batch=4, seq=16, vocab=100)
    b = lm_batch(5, batch=4, seq=16, vocab=100)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    c = lm_batch(6, batch=4, seq=16, vocab=100)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].max() < 100
    # targets are next-token shifted
    raw_a = np.asarray(a["tokens"])
    np.testing.assert_array_equal(np.asarray(a["targets"])[:, :-1],
                                  raw_a[:, 1:])


def test_pair_batch_salient_terms_shared():
    b = pair_batch(3, batch=4, seq=16, vocab=100, n_rel_terms=4)
    np.testing.assert_array_equal(np.asarray(b["query"])[:, :4],
                                  np.asarray(b["doc_pos"])[:, :4])


def test_graph_store_sampler_shapes_and_locality():
    store = GraphStore(n_nodes=1000, n_edges=8000, d_feat=16, n_classes=5)
    sub = store.sample(0, batch_nodes=32, fanouts=(5, 3))
    n = sub["x"].shape[0]
    assert sub["x"].shape == (n, 16)
    assert sub["edge_src"].max() < n and sub["edge_dst"].max() < n
    assert sub["edge_src"].shape == sub["edge_dst"].shape
    assert sub["train_mask"].sum() == 32  # seeds masked for loss
    # deterministic per step
    sub2 = store.sample(0, batch_nodes=32, fanouts=(5, 3))
    np.testing.assert_array_equal(sub["edge_src"], sub2["edge_src"])
    sub3 = store.sample(1, batch_nodes=32, fanouts=(5, 3))
    assert sub3["x"].shape[0] > 0


def test_molecule_batch_energy_depends_on_geometry():
    a = molecule_batch(0, batch=4, atoms=8, edges=16, n_types=10)
    assert np.all(np.isfinite(np.asarray(a["energy"])))
    assert np.asarray(a["z"]).min() >= 1


def test_recsys_batches():
    from repro.models.recsys import DINConfig, DLRMConfig
    d = recsys_batch(2, kind="dlrm", cfg=DLRMConfig(vocab_per_field=50),
                     batch=8)
    assert d["sparse"].shape == (8, 26, 1)
    assert int(d["sparse"].max()) < 50
    d = recsys_batch(2, kind="din", cfg=DINConfig(n_items=30), batch=8)
    assert d["hist"].shape == (8, 100)
