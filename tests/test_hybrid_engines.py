"""Hybrid cascade/RRF engines: substrate units, registry/facade parity,
mixed-k discipline, compile-once, and the serving path.

Layering mirrors the implementation: ``retrieval/hybrid.py`` primitives
(fusion math, sentinel discipline, index validation) are pinned with
hand-checkable cases; the registry engines are checked for *plumbing*
parity against the same primitives composed manually; then the whole
stack is driven through ``AsyncRetrievalScheduler`` — mixed-k batches
bit-match direct ``Retriever.search``, jit caches stay cold across
depth/threshold sweeps, and response-cache keys distinguish engines.
"""
import numpy as np
import pytest

from repro.core import twolevel
from repro.eval import build_hybrid, make_graded_corpus
from repro.retrieval import Retriever, SearchRequest, build_hybrid_index
from repro.retrieval.hybrid import (dense_topk, embed_queries,
                                    rerank_candidates, rrf_fuse)
from repro.serve import (AsyncRetrievalScheduler, RoutingPolicy,
                         SchedulerConfig, route, single_route)

PARAMS = twolevel.fast()


@pytest.fixture(scope="module")
def graded():
    return make_graded_corpus(n_docs=1024, n_terms=512, n_queries=8,
                              n_q_terms=5, dim=16, seed=5)


@pytest.fixture(scope="module")
def hybrid(graded):
    return build_hybrid(graded, tile_size=128)


def _q(graded):
    return graded.queries()


def _req(graded, i, k=10, threshold_factor=None):
    c = graded.corpus
    return SearchRequest(terms=c.queries[i], weights_b=c.q_weights_b[i],
                         weights_l=c.q_weights_l[i], k=k,
                         threshold_factor=threshold_factor)


# -- substrate units ----------------------------------------------------------

def test_rrf_fuse_hand_example():
    """score(d) = sum 1/(60 + rank); agreement on both lists wins, ties
    break docid-ascending."""
    a = np.array([[1, 2, 3]])
    b = np.array([[2, 1, 9]])
    ids, scores = rrf_fuse(a, b, k=4, rrf_k=60.0)
    s1 = 1 / 61 + 1 / 62          # doc 1: rank 1 + rank 2
    s2 = 1 / 62 + 1 / 61          # doc 2: rank 2 + rank 1 (== s1)
    s3 = 1 / 63                   # single-list docs
    assert ids[0].tolist() == [1, 2, 3, 9]      # tie 1-vs-2: docid asc
    np.testing.assert_allclose(scores[0], [s1, s2, s3, s3], rtol=1e-6)


def test_rrf_fuse_sentinels_and_padding():
    a = np.array([[4, -1, -1]])
    b = np.array([[-1, -1, -1]])
    ids, scores = rrf_fuse(a, b, k=3)
    assert ids[0].tolist() == [4, -1, -1]
    assert scores[0][0] == pytest.approx(1 / 61)
    assert np.isneginf(scores[0][1:]).all()
    with pytest.raises(ValueError, match="row mismatch"):
        rrf_fuse(np.zeros((2, 3)), np.zeros((3, 3)), k=2)


def test_build_hybrid_index_validates(hybrid):
    sparse = hybrid.sparse
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="original"):
        build_hybrid_index(sparse,
                           rng.standard_normal((sparse.n_docs - 1, 8)),
                           rng.standard_normal((sparse.n_terms, 8)))
    with pytest.raises(ValueError, match="q_proj"):
        build_hybrid_index(sparse,
                           rng.standard_normal((sparse.n_docs, 8)),
                           rng.standard_normal((sparse.n_terms, 4)))


def test_embed_queries_dense_override_rotates(graded, hybrid):
    """A caller-supplied embedding must land in the same rotated basis
    as the bridged one (exactly what the dense index's scorer expects)."""
    raw = np.asarray(
        (np.asarray(hybrid.q_proj)[graded.corpus.queries]
         * graded.corpus.q_weights_l[..., None]).sum(axis=-2))
    raw /= np.maximum(np.linalg.norm(raw, axis=1, keepdims=True), 1e-9)
    via_bridge = embed_queries(hybrid, graded.corpus.queries,
                               graded.corpus.q_weights_l)
    via_override = embed_queries(hybrid, None, None, dense=raw)
    np.testing.assert_allclose(np.asarray(via_bridge),
                               np.asarray(via_override), atol=1e-5)
    with pytest.raises(ValueError, match="B, 16"):
        embed_queries(hybrid, None, None,
                      dense=np.zeros((3, 7), np.float32))


def test_rerank_sentinels_never_resurface(graded, hybrid):
    q_rot = embed_queries(hybrid, graded.corpus.queries,
                          graded.corpus.q_weights_l)[:1]
    cands = np.array([[5, -1, 17, -1]], np.int32)
    scores, ids = rerank_candidates(hybrid, q_rot, cands, k=4)
    assert set(ids[0].tolist()) <= {5, 17, -1}
    assert (ids[0][:2] >= 0).all()              # two live candidates lead
    assert ids[0][2:].tolist() == [-1, -1]
    assert np.isneginf(scores[0][2:]).all()
    assert scores[0][0] >= scores[0][1]


# -- registry engines vs the primitives composed by hand ----------------------

def test_cascade_matches_manual_composition(graded, hybrid):
    r = Retriever.open(hybrid, PARAMS, engine="cascade", depth=100,
                       k_buckets=None)
    resp = r.search(**_q(graded), k=10)
    first = Retriever.open(hybrid, PARAMS, engine="batched",
                           k_buckets=None).search(**_q(graded), k=100)
    q_rot = embed_queries(hybrid, graded.corpus.queries,
                          graded.corpus.q_weights_l)
    want_scores, want_ids = rerank_candidates(hybrid, q_rot, first.ids,
                                              k=10)
    np.testing.assert_array_equal(resp.ids, want_ids)
    np.testing.assert_allclose(resp.scores, want_scores, rtol=1e-6)
    assert resp.stats["cascade_depth"] == 100.0
    # every result is a first-stage candidate (cascade never invents docs)
    for row, cand in zip(resp.ids, first.ids):
        assert set(row.tolist()) <= set(cand.tolist()) | {-1}


def test_rrf_engine_matches_manual_fusion(graded, hybrid):
    r = Retriever.open(hybrid, PARAMS, engine="rrf", depth=100,
                       rrf_k=42.0, k_buckets=None)
    resp = r.search(**_q(graded), k=10)
    first = Retriever.open(hybrid, PARAMS, engine="batched",
                           k_buckets=None).search(**_q(graded), k=100)
    q_rot = embed_queries(hybrid, graded.corpus.queries,
                          graded.corpus.q_weights_l)
    _, dense_ids = dense_topk(hybrid, q_rot, k=100)
    want_ids, want_scores = rrf_fuse(first.ids, dense_ids, k=10,
                                     rrf_k=42.0)
    np.testing.assert_array_equal(resp.ids, want_ids)
    np.testing.assert_allclose(resp.scores, want_scores, rtol=1e-6)
    assert resp.stats["rrf_k"] == 42.0 and resp.stats["fusion_depth"] == 100


def test_hybrid_engine_open_guards(graded, hybrid):
    with pytest.raises(TypeError, match="HybridIndex"):
        Retriever.open(hybrid.sparse, PARAMS, engine="cascade")
    with pytest.raises(ValueError, match="first_stage"):
        Retriever.open(hybrid, PARAMS, engine="cascade",
                       first_stage="dense")
    with pytest.raises(ValueError, match="depth"):
        Retriever.open(hybrid, PARAMS, engine="rrf", depth=0)
    with pytest.raises(ValueError, match="rrf_k"):
        Retriever.open(hybrid, PARAMS, engine="rrf", rrf_k=0.0)


def test_sparse_engines_unwrap_hybrid_index(graded, hybrid):
    """A HybridIndex opened under a sparse engine serves its .sparse side
    bit-identically — the contract that lets one scheduler index back a
    mixed sparse+hybrid routing policy."""
    via_hybrid = Retriever.open(hybrid, PARAMS,
                                engine="batched").search(**_q(graded),
                                                         k=10)
    via_sparse = Retriever.open(hybrid.sparse, PARAMS,
                                engine="batched").search(**_q(graded),
                                                         k=10)
    np.testing.assert_array_equal(via_hybrid.ids, via_sparse.ids)
    np.testing.assert_array_equal(via_hybrid.scores, via_sparse.scores)


def test_dense_engine_unwraps_hybrid_index(graded, hybrid):
    rng = np.random.default_rng(1)
    q = rng.standard_normal((2, 16)).astype(np.float32)
    via_hybrid = Retriever.open(hybrid, twolevel.original(gamma=0.0),
                                engine="dense").search(dense=q, k=5)
    via_dense = Retriever.open(hybrid.dense, twolevel.original(gamma=0.0),
                               engine="dense").search(dense=q, k=5)
    np.testing.assert_array_equal(via_hybrid.ids, via_dense.ids)


# -- mixed-k and compile discipline -------------------------------------------

@pytest.mark.parametrize("engine", ["cascade", "rrf"])
def test_mixed_k_batch_matches_per_row_calls(graded, engine, hybrid):
    r = Retriever.open(hybrid, PARAMS, engine=engine, depth=100)
    ks = [3, 10, 5, 10, 7, 10, 2, 9]
    resp = r.search(**_q(graded), k=ks)
    assert resp.ks.tolist() == ks
    c = graded.corpus
    for i, k in enumerate(ks):
        solo = r.search(terms=c.queries[i:i + 1],
                        weights_b=c.q_weights_b[i:i + 1],
                        weights_l=c.q_weights_l[i:i + 1], k=k)
        np.testing.assert_array_equal(resp.ids[i, :k], solo.ids[0])
        assert (resp.ids[i, k:] == -1).all()
        assert np.isneginf(resp.scores[i, k:]).all()


def test_hybrid_compile_once_per_bucket_pair(graded, hybrid):
    """Within-bucket k sweeps and threshold_factor sweeps retrace
    neither the sparse first stage nor the jitted rerank."""
    from repro.core.traversal import _retrieve_batched_impl
    from repro.retrieval.hybrid import _rerank_impl
    r = Retriever.open(hybrid, PARAMS, engine="cascade", depth=100)
    for k in (10, 100):                       # warm both k buckets
        r.search(**_q(graded), k=k)
    n_first = _retrieve_batched_impl._cache_size()
    n_rerank = _rerank_impl._cache_size()
    for k in (1, 5, 10, 42, 100):
        r.search(**_q(graded), k=k, threshold_factor=1.0 + k / 10)
    r.search(**_q(graded), k=[3, 10, 5, 10, 7, 10, 2, 9])
    assert _retrieve_batched_impl._cache_size() == n_first
    assert _rerank_impl._cache_size() == n_rerank


# -- the serving path ---------------------------------------------------------

@pytest.mark.parametrize("engine", ["cascade", "rrf"])
def test_scheduler_serves_hybrid_engine_mixed_k(graded, engine, hybrid):
    """A mixed-k stream through the scheduler bit-matches direct
    Retriever calls — hybrid engines ride the sparse serving path with
    no request-format change (embeddings come from the q_proj bridge)."""
    s = AsyncRetrievalScheduler(
        hybrid, PARAMS, SchedulerConfig(max_batch=4, cache_size=0),
        routing=single_route(engine, depth=100))
    direct = Retriever.open(hybrid, PARAMS, engine=engine, depth=100)
    ks = [10, 3, 7, 10, 5, 9]
    handles = [s.submit(_req(graded, i, k=k)) for i, k in enumerate(ks)]
    s.flush()
    c = graded.corpus
    for i, (h, k) in enumerate(zip(handles, ks)):
        resp = h.result()
        assert resp.engine == engine and resp.ids.shape == (1, k)
        solo = direct.search(terms=c.queries[i:i + 1],
                             weights_b=c.q_weights_b[i:i + 1],
                             weights_l=c.q_weights_l[i:i + 1], k=k)
        np.testing.assert_array_equal(resp.ids[0], solo.ids[0])
        np.testing.assert_allclose(resp.scores[0], solo.scores[0],
                                   rtol=1e-6)


def test_scheduler_mixed_sparse_hybrid_policy(graded, hybrid):
    """One HybridIndex backs a policy that routes short queries to the
    sparse engine and long ones to cascade."""
    policy = RoutingPolicy((
        route("short", 3, "batched", pad_terms=3),
        route("long", None, "cascade", depth=100)))
    s = AsyncRetrievalScheduler(hybrid, PARAMS,
                                SchedulerConfig(max_batch=4, cache_size=0),
                                routing=policy)
    c = graded.corpus
    short = SearchRequest(terms=c.queries[0][:3],
                          weights_b=c.q_weights_b[0][:3],
                          weights_l=c.q_weights_l[0][:3], k=5)
    hs, hl = s.submit(short), s.submit(_req(graded, 1, k=5))
    s.flush()
    assert hs.route == "short" and hs.result().engine == "batched"
    assert hl.route == "long" and hl.result().engine == "cascade"


def test_cache_distinguishes_hybrid_engines(graded, hybrid):
    """Identical queries served by different engines must never share a
    response-cache entry: the policy fingerprint (part of every cache
    key) pins the engine and its options."""
    fp_c = single_route("cascade", depth=100).fingerprint(PARAMS)
    fp_r = single_route("rrf", depth=100).fingerprint(PARAMS)
    fp_r2 = single_route("rrf", depth=100, rrf_k=10.0).fingerprint(PARAMS)
    assert len({fp_c, fp_r, fp_r2}) == 3
    # and a same-engine resubmit is a genuine hit
    s = AsyncRetrievalScheduler(
        hybrid, PARAMS, SchedulerConfig(max_batch=2, cache_size=8),
        routing=single_route("cascade", depth=100))
    s.submit(_req(graded, 0, k=5))
    s.flush()
    h = s.submit(_req(graded, 0, k=5))
    assert h.cached and h.done()
    assert s.stats()["cache_hits"] == 1
    # different engine opts -> different scheduler key -> miss
    s2 = AsyncRetrievalScheduler(
        hybrid, PARAMS, SchedulerConfig(max_batch=2, cache_size=8),
        routing=single_route("cascade", depth=1000))
    h2 = s2.submit(_req(graded, 0, k=5))
    assert not h2.done()
    s2.flush()
    np.testing.assert_array_equal(h2.result().ids.shape, (1, 5))
