"""Executor pool + backpressure: replica cloning, N-executor parity
(the acceptance contract: bit-identical to single-executor, cache hits
included), warmup-grid compile discipline, drain-on-close, snapshot-
consistent stats under concurrent workers, bounded admission
(block/reject/shed), and the priority-aging starvation bound.

The saturation soaks run under the ``stress`` marker (``make
test-stress``); the fast ``make test-serve`` lane excludes them.
"""
import threading
import time

import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.retrieval import Retriever, SearchRequest
from repro.serve import (AsyncRetrievalScheduler, ExecutorPool,
                         RoutingPolicy, SchedulerConfig, SchedulerSaturated,
                         mixed_request_stream, route, run_workload,
                         table8_policy, warmup_grid)

RANK_SAFE = twolevel.original(gamma=0.2)
SHORT, LONG = 3, 5   # live-term counts in the small_corpus stream


@pytest.fixture(scope="module")
def setup(small_corpus):
    index = build_index(small_corpus.merged("scaled"), tile_size=256)
    return small_corpus, index


def _req(corpus, i, qlen=None, k=10):
    q, wb, wl = (corpus.queries[i], corpus.q_weights_b[i],
                 corpus.q_weights_l[i])
    if qlen is not None:
        q, wb, wl = q[:qlen], wb[:qlen], wl[:qlen]
    return SearchRequest(terms=q, weights_b=wb, weights_l=wl, k=k)


def _two_class_policy(engine="batched", **opts):
    return RoutingPolicy((
        route("short", SHORT, engine, pad_terms=SHORT, **opts),
        route("long", None, engine, **opts)))


def _sched(index, executors=0, cache=0, routing=None, **cfg):
    return AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, max_wait_ms=5.0, cache_size=cache,
                        executors=executors, **cfg),
        routing=routing if routing is not None else _two_class_policy(),
        k_buckets=(10, 100))


def _stream(corpus, n):
    return mixed_request_stream(corpus, n, short_len=SHORT,
                                k_pool=(10, 100), query_pool=6)


def _invariant(st):
    return st["submitted"] == (st["completed"] + st["failed"] + st["shed"]
                               + st["rejected"] + st["pending"]
                               + st["in_flight"])


# -- replica cloning ----------------------------------------------------------

@pytest.mark.parametrize("engine,opts", [
    ("batched", {}), ("kernel", {}), ("sequential", {"warmup": False}),
    ("sharded", {"n_shards": 2})])
def test_replicate_shares_index_and_matches(setup, engine, opts):
    corpus, index = setup
    base = Retriever.open(index, RANK_SAFE, engine=engine, **opts)
    rep = base.replicate()
    assert rep is not base and rep.engine is not base.engine
    assert rep.engine_name == base.engine_name
    assert rep.k_buckets == base.k_buckets
    q = corpus.queries[:2]
    a = base.search(terms=q, weights_b=corpus.q_weights_b[:2],
                    weights_l=corpus.q_weights_l[:2], k=10)
    b = rep.search(terms=q, weights_b=corpus.q_weights_b[:2],
                   weights_l=corpus.q_weights_l[:2], k=10)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.scores, b.scores)


def test_replicate_shares_sharded_partition(setup):
    """A sharded replica must reuse the already-partitioned tile ranges
    (no re-partition at clone time)."""
    _, index = setup
    base = Retriever.open(index, RANK_SAFE, engine="sharded", n_shards=2)
    rep = base.replicate()
    assert rep.engine.sharded is base.engine.sharded


def test_replicate_requires_engine_support(setup):
    _, index = setup
    r = Retriever.open(index, RANK_SAFE)

    class NoReplica:
        name = "stub"
    r.engine = NoReplica()
    with pytest.raises(TypeError, match="replicate"):
        r.replicate()


# -- N-executor parity (the acceptance contract) ------------------------------

def test_pool_parity_bit_identical_with_cache_hits(setup):
    """A mixed-k, mixed-length stream — submitted twice, so the second
    pass is served from the response cache — returns bit-identical
    ids/scores through a 3-executor pool and through the sync
    single-dispatch path."""
    corpus, index = setup
    reqs = _stream(corpus, 16)

    def serve(executors):
        s = _sched(index, executors=executors, cache=64)
        if executors:
            with s:
                first = [h.result(timeout=60)
                         for h in [s.submit(r) for r in reqs]]
                second = [h.result(timeout=60)
                          for h in [s.submit(r) for r in reqs]]
        else:
            hs = [s.submit(r) for r in reqs]
            s.flush()
            first = [h.result() for h in hs]
            hs = [s.submit(r) for r in reqs]
            s.flush()
            second = [h.result() for h in hs]
        return first, second, s.stats()

    f0, s0, st0 = serve(0)
    f3, s3, st3 = serve(3)
    for a, b in zip(f0 + s0, f3 + s3):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)
        np.testing.assert_array_equal(a.ks, b.ks)
    # the replay pass hits the cache in both modes
    assert st0["cache_hits"] >= len(reqs)
    assert st3["cache_hits"] >= len(reqs)
    assert _invariant(st0) and _invariant(st3)


def test_pool_executors_share_the_work(setup):
    """Under a submit-then-drain burst every executor should pull
    batches; per-executor counters aggregate to the batch total."""
    corpus, index = setup
    s = _sched(index, executors=2)
    with s:
        hs = [s.submit(r) for r in _stream(corpus, 24)]
        for h in hs:
            h.result(timeout=60)
    st = s.stats()
    assert sum(st["batches_by_executor"].values()) == st["batches"]
    assert sum(st["rows_by_executor"].values()) == st["rows_executed"]
    assert len(st["batches_by_executor"]) >= 1
    assert _invariant(st)


# -- warmup grid / compile discipline ----------------------------------------

def test_warmup_compiles_exactly_the_routing_grid(small_corpus):
    """After ``warmup()``, the jitted traversal holds exactly one new
    cache entry per (route x k-bucket) cell, and serving any request
    shape afterwards adds none — compile-once discipline per replica
    (jit caches are process-global, so this covers every executor)."""
    from repro.core.traversal import _retrieve_batched_impl
    # fresh tile_size -> cold jit-cache rows for this test alone
    index = build_index(small_corpus.merged("scaled"), tile_size=16)
    s = _sched(index)
    grid = warmup_grid(s.routing, s.k_buckets, s.cfg.pad_terms)
    assert len(grid) == 4   # 2 routes x 2 buckets
    n0 = _retrieve_batched_impl._cache_size()
    s.warmup()
    assert _retrieve_batched_impl._cache_size() == n0 + len(grid)
    assert s.stats()["warmup_s"] > 0
    for i, k in enumerate((5, 10, 42, 100)):
        s.submit(_req(small_corpus, i, SHORT if i % 2 else LONG, k=k))
    s.flush()
    assert _retrieve_batched_impl._cache_size() == n0 + len(grid)


def test_pool_start_builds_replicas_and_warms(setup):
    corpus, index = setup
    s = _sched(index)
    pool = ExecutorPool(s, 2)
    pool.start()
    try:
        assert pool.is_running()
        assert set(pool.replicas) == {0, 1}
        for slot in (0, 1):
            assert set(pool.replicas[slot]) == {"short", "long"}
            for name, rep in pool.replicas[slot].items():
                assert rep is not s._retrievers[name]
        assert s.stats()["warmup_s"] > 0
    finally:
        pool.close()
    assert not pool.is_running()


# -- drain-on-close -----------------------------------------------------------

def test_pool_drains_backlog_on_close(setup):
    """close() lets the executors empty the group queues: every handle
    resolves even for requests whose deadline is far in the future."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, max_wait_ms=60_000.0, cache_size=0,
                        executors=2),
        routing=_two_class_policy(), k_buckets=(10, 100))
    s.start()
    hs = [s.submit(r) for r in _stream(corpus, 10)]
    s.close()
    assert all(h.done() for h in hs)
    st = s.stats()
    assert st["pending"] == 0 and st["in_flight"] == 0
    assert st["completed"] == len(hs)
    assert _invariant(st)


# -- stats consistency under concurrent workers -------------------------------

def test_stats_snapshots_consistent_under_pool(setup):
    """Every stats() snapshot taken while 2 executors race must satisfy
    the counter invariant — the whole dict is read under one lock
    acquisition, never a torn mix of before/after states."""
    corpus, index = setup
    s = _sched(index, executors=2, cache=16)
    reqs = _stream(corpus, 32)
    snapshots = []
    with s:
        hs = [s.submit(r) for r in reqs]
        while not all(h.done() for h in hs):
            snapshots.append(s.stats())
    snapshots.append(s.stats())
    assert all(_invariant(st) for st in snapshots)
    final = snapshots[-1]
    assert final["completed"] == len(reqs)
    assert final["admitted"] == final["submitted"] - final["rejected"]


def test_stats_returns_detached_dicts(setup):
    corpus, index = setup
    s = _sched(index)
    s.submit(_req(corpus, 0))
    st = s.stats()
    st["requests_by_route"]["long"] = 999
    st["batches_by_executor"][7] = 1
    assert s.stats()["requests_by_route"] != st["requests_by_route"]
    assert 7 not in s.stats()["batches_by_executor"]


# -- bounded admission --------------------------------------------------------

def test_admission_reject_raises_and_counts(setup):
    corpus, index = setup
    s = _sched(index, admission_limit=2, admission_policy="reject")
    s.submit(_req(corpus, 0), now=0.0)
    s.submit(_req(corpus, 1), now=0.0)
    with pytest.raises(SchedulerSaturated, match="rejected"):
        s.submit(_req(corpus, 2), now=0.0)
    st = s.stats()
    assert st["rejected"] == 1 and st["admitted"] == 2
    assert _invariant(st)
    s.flush()
    assert s.stats()["completed"] == 2


def test_admission_shed_drops_least_important(setup):
    """An important submission sheds the least-important queued request
    (its handle fails with SchedulerSaturated); an unimportant one is
    refused instead."""
    corpus, index = setup
    s = _sched(index, admission_limit=2, admission_policy="shed")
    h_low = s.submit(_req(corpus, 0), priority=5, now=0.0)
    h_mid = s.submit(_req(corpus, 1), priority=1, now=0.0)
    h_hi = s.submit(_req(corpus, 2), priority=0, now=0.0)   # sheds h_low
    with pytest.raises(SchedulerSaturated):
        h_low.result(timeout=0.1)
    with pytest.raises(SchedulerSaturated, match="shed at admission"):
        s.submit(_req(corpus, 3), priority=9, now=0.0)      # refused
    s.flush()
    assert h_mid.result().ids is not None
    assert h_hi.result().ids is not None
    st = s.stats()
    assert st["shed"] == 1 and st["rejected"] == 1
    assert st["completed"] == 2 and _invariant(st)


def test_admission_block_inline_drains_in_sync_mode(setup):
    """With no worker running, a blocked submit must dispatch the queue
    itself instead of deadlocking the only thread."""
    corpus, index = setup
    s = _sched(index, admission_limit=2, admission_policy="block")
    hs = [s.submit(r) for r in _stream(corpus, 8)]
    s.flush()
    assert all(h.done() for h in hs)
    assert s.stats()["completed"] == 8


def test_admission_block_waits_for_pool(setup):
    corpus, index = setup
    s = _sched(index, executors=2, admission_limit=4,
               admission_policy="block")
    with s:
        hs = [s.submit(r) for r in _stream(corpus, 12)]
        for h in hs:
            h.result(timeout=60)
    st = s.stats()
    assert st["completed"] == 12 and st["rejected"] == 0
    assert _invariant(st)


def test_admission_guards(setup):
    corpus, index = setup
    with pytest.raises(ValueError, match="admission_policy"):
        AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(admission_policy="nope"))
    with pytest.raises(ValueError, match="executors"):
        AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(executors=-1))
    with pytest.raises(ValueError, match="never be admitted"):
        s = _sched(index, admission_limit=2)
        s.submit(SearchRequest(terms=corpus.queries[:3],
                               weights_b=corpus.q_weights_b[:3],
                               weights_l=corpus.q_weights_l[:3], k=10))
    with pytest.raises(ValueError, match=">= 1 executors"):
        ExecutorPool(_sched(index), 0)


# -- priority aging: the starvation bound -------------------------------------

def _aging_rounds(s, corpus, h_low, rounds, dt=0.05):
    """Saturating high-priority stream on a simulated clock: each round
    submits a full batch of fresh priority-0 requests at t, then picks
    and executes exactly one batch. Returns the round index at which the
    low-priority handle completed (or ``rounds`` if starved)."""
    for r in range(rounds):
        t = (r + 1) * dt
        for j in range(4):
            s.submit(_req(corpus, (r * 4 + j) % 8, LONG, k=10),
                     priority=0, now=t)
        picked = s._pick_batch(t, False)
        assert picked is not None
        s._execute(*picked)
        if h_low.done():
            return r
    return rounds


def test_aging_bounds_starvation(setup):
    """With ``aging_ms=25`` a priority-5 request admitted at t=0 gains a
    level every 25 ms; by t=125ms it outranks fresh priority-0 traffic
    and must ride the next batch — within 3 rounds of 50 ms here. The
    strict-priority control (aging off) starves it for the whole run."""
    corpus, index = setup

    def build(aging_ms):
        s = AsyncRetrievalScheduler(
            index, RANK_SAFE,
            SchedulerConfig(max_batch=4, max_wait_ms=0.0, cache_size=0,
                            aging_ms=aging_ms),
            routing=_two_class_policy(), k_buckets=(10, 100))
        h_low = s.submit(_req(corpus, 11, LONG, k=10), priority=5, now=0.0)
        return s, h_low

    s, h_low = build(aging_ms=25.0)
    done_at = _aging_rounds(s, corpus, h_low, rounds=10)
    assert done_at <= 3, f"low-priority request starved {done_at} rounds"

    s, h_low = build(aging_ms=0.0)   # strict priority: starves
    done_at = _aging_rounds(s, corpus, h_low, rounds=10)
    assert done_at == 10 and not h_low.done()


# -- threaded workload driver -------------------------------------------------

def test_run_workload_threaded_over_pool(setup):
    corpus, index = setup
    s = _sched(index, executors=2, cache=16)
    with s:
        res = run_workload(s, _stream(corpus, 16), qps=400.0)
    assert res["n"] == 16 and res["completed"] == 16
    assert res["qps_achieved"] > 0 and np.isfinite(res["mrt_ms"])


# -- saturation soaks (the slow, threaded lane) -------------------------------

@pytest.mark.stress
def test_stress_pool_saturation_with_shedding(setup):
    """4 executors, a bounded shedding queue, and an offered load far
    above capacity: everything submitted either completes or is
    accounted shed/rejected, every snapshot satisfies the invariant,
    and the queue never exceeds its bound."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, max_wait_ms=2.0, cache_size=0,
                        executors=4, admission_limit=8,
                        admission_policy="shed", aging_ms=20.0),
        routing=_two_class_policy(), k_buckets=(10, 100))
    reqs = _stream(corpus, 96)
    bounds_ok = True
    with s:
        hs = []
        for i, r in enumerate(reqs):
            try:
                hs.append(s.submit(r, priority=i % 3))
            except SchedulerSaturated:
                pass
            st = s.stats()
            bounds_ok &= st["pending_rows"] <= 8 and _invariant(st)
        for h in hs:
            try:
                h.result(timeout=120)
            except SchedulerSaturated:
                pass
    st = s.stats()
    assert bounds_ok
    assert _invariant(st)
    assert st["pending"] == 0 and st["in_flight"] == 0
    assert st["completed"] + st["shed"] + st["rejected"] == st["submitted"]
    assert st["completed"] > 0


@pytest.mark.stress
def test_stress_concurrent_submitters(setup):
    """4 submitter threads x 2 executors racing on one scheduler: all
    requests complete, results match the sync path bit-for-bit."""
    corpus, index = setup
    reqs = _stream(corpus, 12)
    ref = _sched(index)
    ref_out = []
    for r in reqs:
        h = ref.submit(r)
        ref.flush()
        ref_out.append(h.result())

    s = _sched(index, executors=2)
    results = [None] * (4 * len(reqs))
    errors = []

    def submitter(tid):
        try:
            hs = [(i, s.submit(r)) for i, r in enumerate(reqs)]
            for i, h in hs:
                results[tid * len(reqs) + i] = h.result(timeout=120)
        except Exception as e:  # pragma: no cover - failure reporting
            errors.append(e)

    with s:
        threads = [threading.Thread(target=submitter, args=(t,))
                   for t in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    assert not errors
    for tid in range(4):
        for i, expect in enumerate(ref_out):
            got = results[tid * len(reqs) + i]
            np.testing.assert_array_equal(got.ids, expect.ids)
            np.testing.assert_array_equal(got.scores, expect.scores)
    st = s.stats()
    assert st["completed"] == 4 * len(reqs) and _invariant(st)
