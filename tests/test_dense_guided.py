"""Guided dense retrieval (2GTI transfer to the two-tower serve path)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dense_guided import (build_dense_index, exhaustive_dense,
                                     retrieve_dense, retrieve_dense_batched)
from repro.core.twolevel import TwoLevelParams


@pytest.fixture(scope="module")
def dense_index():
    rng = np.random.default_rng(0)
    centers = rng.standard_normal((8, 64)) * 2
    assign = rng.integers(0, 8, 4096)
    emb = centers[assign] + rng.standard_normal((4096, 64))
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    emb = emb[np.argsort(assign, kind="stable")]
    return build_dense_index(jnp.asarray(emb, jnp.float32),
                             block_size=512, d_cheap=16)


def _query(seed):
    rng = np.random.default_rng(seed)
    q = rng.standard_normal(64).astype(np.float32)
    return jnp.asarray(q / np.linalg.norm(q))


def test_rank_safe_equals_exhaustive(dense_index):
    p = TwoLevelParams(alpha=0.0, beta=0.0, gamma=0.0)
    for seed in range(3):
        q = _query(seed)
        vals, ids, _ = retrieve_dense(dense_index, q, p)
        ev, ei = exhaustive_dense(dense_index, q, 10)
        np.testing.assert_allclose(vals, ev, rtol=1e-5, atol=1e-5)
        assert set(ids.tolist()) == set(ei.tolist())


def test_pca_rotation_preserves_scores(dense_index):
    """Rotation must not change exact dot products (orthogonality)."""
    q = _query(7)
    r = np.asarray(dense_index.rotation)
    np.testing.assert_allclose(r @ r.T, np.eye(64), atol=1e-4)


def test_guided_small_beta_keeps_recall(dense_index):
    p = TwoLevelParams(alpha=1.0, beta=0.2, gamma=0.0)
    rec = 0.0
    for seed in range(4):
        q = _query(seed)
        _, ids, _ = retrieve_dense(dense_index, q, p)
        _, ei = exhaustive_dense(dense_index, q, 10)
        rec += len(set(ids.tolist()) & set(ei.tolist())) / 10
    assert rec / 4 >= 0.9


def test_guided_beta_one_prunes_hard(dense_index):
    p = TwoLevelParams(alpha=1.0, beta=1.0, gamma=0.0)
    _, _, st = retrieve_dense(dense_index, _query(0), p)
    assert st["candidates_fully_scored"] < st["n_candidates"] * 0.5


# -- registry-facade parity (mirrors test_engine_parity for the dense
# lane): the 'dense' engine behind Retriever.search must reproduce
# exhaustive search exactly when rank-safe, and never exceed it when
# guided ------------------------------------------------------------------

def _query_batch(n=4):
    return jnp.stack([_query(seed) for seed in range(n)])


def test_dense_engine_rank_safe_matches_exhaustive(dense_index):
    from repro.retrieval import Retriever
    p = TwoLevelParams(alpha=0.0, beta=0.0, gamma=0.0)
    r = Retriever.open(dense_index, p, engine="dense")
    q = _query_batch()
    resp = r.search(dense=q, k=10)
    for qi in range(q.shape[0]):
        ev, ei = exhaustive_dense(dense_index, q[qi], 10)
        np.testing.assert_allclose(resp.scores[qi], ev,
                                   rtol=1e-5, atol=1e-5)
        assert set(resp.ids[qi].tolist()) == set(ei.tolist())
        # untied positions must agree exactly (equal scores may swap)
        mism = resp.ids[qi] != np.asarray(ei)
        if mism.any():
            tied = np.zeros_like(mism)
            close = np.abs(np.diff(np.asarray(ev))) < 1e-5
            tied[1:] |= close
            tied[:-1] |= close
            assert mism[~tied].sum() == 0


@pytest.mark.parametrize("alpha,beta", [(1.0, 0.3), (1.0, 1.0)])
def test_dense_engine_guided_dominated_by_exhaustive(dense_index, alpha,
                                                     beta):
    """Guided configs prune candidates, so at every rank the returned
    score can only be <= the exhaustive score at that rank — pruning
    never invents a better document."""
    from repro.retrieval import Retriever
    p = TwoLevelParams(alpha=alpha, beta=beta, gamma=0.0)
    r = Retriever.open(dense_index, p, engine="dense")
    q = _query_batch()
    resp = r.search(dense=q, k=10)
    for qi in range(q.shape[0]):
        ev, _ = exhaustive_dense(dense_index, q[qi], 10)
        got = resp.scores[qi]
        assert np.all(got <= np.asarray(ev) + 1e-5)
        assert np.all(np.diff(got) <= 1e-6)    # sorted descending
        assert np.all(resp.ids[qi] >= 0)


@pytest.mark.parametrize("alpha,beta", [(0.0, 0.0), (1.0, 0.3)])
def test_batched_lane_matches_per_query(dense_index, alpha, beta):
    """The jitted [B, D] lane (vmap over the guided scan) must reproduce
    the per-query path — each row keeps its own block order and
    thresholds. Matched to float tolerance, not bit-exactly: vmap
    changes XLA's dot-product reduction order, so scores differ at the
    last ulp (and equal-score neighbors may swap ranks)."""
    p = TwoLevelParams(alpha=alpha, beta=beta, gamma=0.0)
    q = _query_batch(4)
    bv, bi, bst = retrieve_dense_batched(dense_index, q, p, k=10)
    assert bv.shape == bi.shape == (4, 10)
    assert bst["candidates_fully_scored"].shape == (4,)
    for qi in range(4):
        vals, ids, st = retrieve_dense(dense_index, q[qi], p, k=10)
        np.testing.assert_allclose(bv[qi], vals, rtol=1e-5, atol=1e-5)
        # ids may swap only across near-tied adjacent scores
        overlap = len(set(bi[qi].tolist()) & set(ids.tolist()))
        assert overlap >= 9, (bi[qi], ids)
        assert bst["candidates_fully_scored"][qi] == pytest.approx(
            st["candidates_fully_scored"], abs=16)


def test_batched_lane_rejects_single_queries(dense_index):
    with pytest.raises(ValueError, match=r"\[B, D\]"):
        retrieve_dense_batched(dense_index, _query(0),
                               TwoLevelParams(), k=10)


def test_dense_engine_compiles_once_per_batch_shape(dense_index):
    """The dense registry engine serves a [B, D] batch in one jitted
    call: repeated searches at the same (B, k) add no cache entries."""
    from repro.core.dense_guided import _retrieve_dense_batched_impl
    from repro.retrieval import Retriever
    r = Retriever.open(dense_index, TwoLevelParams(alpha=0.0, beta=0.0,
                                                   gamma=0.0),
                       engine="dense")
    r.search(dense=_query_batch(4), k=10)
    n0 = _retrieve_dense_batched_impl._cache_size()
    for seed in range(3):
        rng = np.random.default_rng(100 + seed)
        q = rng.standard_normal((4, 64)).astype(np.float32)
        r.search(dense=jnp.asarray(q / np.linalg.norm(q, axis=1,
                                                      keepdims=True)), k=10)
    assert _retrieve_dense_batched_impl._cache_size() == n0


def test_dense_engine_requires_dense_queries(dense_index):
    from repro.retrieval import Retriever
    r = Retriever.open(dense_index, TwoLevelParams(), engine="dense")
    with pytest.raises(ValueError, match="dense"):
        r.search(terms=np.zeros((1, 2), np.int32),
                 weights_b=np.zeros((1, 2), np.float32),
                 weights_l=np.zeros((1, 2), np.float32), k=5)
