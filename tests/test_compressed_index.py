"""CompressedImpactIndex parity: the q8 decode paths against the fp32
BlockedImpactIndex on the same corpus.

The compressed index keeps the *exact* fp32 tile maxima, so the planner
(chunk schedule, theta pruning) is identical; only scores move, by at
most the quantization step. The tests pin:

- gather-level decode: ``gather_tile_q`` offsets are bit-identical to
  the fp32 gather (lossless docid codec) and impacts are within the
  quantization step of fp32, never above the tile max;
- retrieval parity: rank-safe traversal on the compressed index returns
  the same top-k ids as fp32 (modulo quantization-score ties), for every
  registry engine including the hybrid cascade/rrf lanes and the
  in-kernel Pallas decode;
- save/load round-trip.
"""
import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.core.index import dispatch_gather, gather_tile
from repro.core.traversal import retrieve_batched, retrieve_sequential
from repro.eval import build_hybrid, make_graded_corpus
from repro.index import CompressedImpactIndex, compress_index
from repro.retrieval import Retriever

K = 10


@pytest.fixture(scope="module")
def setup(small_corpus):
    merged = small_corpus.merged("scaled")
    fp32 = build_index(merged, tile_size=256)
    q8 = compress_index(merged, tile_size=256)
    return small_corpus, fp32, q8


def _overlap(a, b):
    """Mean per-query top-k set overlap."""
    return np.mean([len(set(a[q].tolist()) & set(b[q].tolist())) / len(a[q])
                    for q in range(len(a))])


def test_geometry_and_bounds_match_fp32(setup):
    _, fp32, q8 = setup
    assert (q8.n_docs, q8.n_terms, q8.n_tiles, q8.pad_len) == \
        (fp32.n_docs, fp32.n_terms, fp32.n_tiles, fp32.pad_len)
    np.testing.assert_array_equal(np.asarray(q8.tile_ptr),
                                  np.asarray(fp32.tile_ptr))
    # exact bounds preserved -> identical plans/pruning decisions
    np.testing.assert_array_equal(np.asarray(q8.tile_max_b),
                                  np.asarray(fp32.tile_max_b))
    np.testing.assert_array_equal(np.asarray(q8.sigma_l),
                                  np.asarray(fp32.sigma_l))
    assert q8.nbytes()["total"] < 0.5 * q8.fp32_nbytes()


def test_gather_decode_matches_fp32(setup):
    corpus, fp32, q8 = setup
    # the gather contract is flat per-term rows: [Nq] terms, one tile each
    q_terms = corpus.queries.reshape(-1).astype(np.int32)
    qw_b = corpus.q_weights_b.reshape(-1)
    qw_l = corpus.q_weights_l.reshape(-1)
    tm_b = np.asarray(fp32.tile_max_b)
    for tile in range(0, fp32.n_tiles, 2):
        offs_f, wb_f, wl_f = gather_tile(
            fp32.docids, fp32.w_b, fp32.w_l, fp32.tile_ptr,
            q_terms, tile, qw_b, qw_l,
            pad_len=fp32.pad_len, tile_size=fp32.tile_size)
        offs_q, wb_q, wl_q = dispatch_gather(
            "q8", q8.gather_arrays(), q_terms, tile, qw_b, qw_l,
            pad_len=q8.pad_len, tile_size=q8.tile_size)
        # docids are lossless
        np.testing.assert_array_equal(np.asarray(offs_q),
                                      np.asarray(offs_f))
        # impacts: within the per-query quantization step, and the
        # unweighted impact never exceeds the exact tile max
        valid = np.asarray(offs_f) >= 0
        step = np.abs(np.asarray(wb_f)).max() * 2e-2 + 1e-3
        assert np.abs(np.asarray(wb_q) - np.asarray(wb_f))[valid].max() < step
        raw_b = np.asarray(wb_q) / np.where(qw_b[:, None] > 0,
                                            qw_b[:, None], 1.0)
        cap = tm_b[q_terms, tile][:, None] + 1e-6
        assert np.all(raw_b[valid] <= np.broadcast_to(cap, raw_b.shape)[valid])


@pytest.mark.parametrize("traversal,use_kernel",
                         [("full", False), ("full", True),
                          ("chunked", False), ("chunked", True),
                          ("chunked_fused", True)])
def test_retrieve_parity_fp32_vs_q8(setup, traversal, use_kernel):
    corpus, fp32, q8 = setup
    p = twolevel.original(gamma=0.05)  # rank-safe
    kw = dict(k=K, traversal=traversal,
              chunk_tiles=2 if traversal != "full" else None)
    rf = retrieve_batched(fp32, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, use_kernel=use_kernel, **kw)
    rq = retrieve_batched(q8, corpus.queries, corpus.q_weights_b,
                          corpus.q_weights_l, p, use_kernel=use_kernel, **kw)
    assert _overlap(rq.ids, rf.ids) >= 0.95
    # scores differ only by the quantization step
    np.testing.assert_allclose(rq.scores, rf.scores, rtol=5e-2, atol=5e-2)


def test_kernel_decode_matches_jnp_decode(setup):
    """Both q8 scorers decode the same integers — the Pallas in-kernel
    decode must agree with the jnp gather decode bit-for-bit on ids and
    to float tolerance on scores."""
    corpus, _, q8 = setup
    p = twolevel.fast()
    r_jnp = retrieve_batched(q8, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, p, use_kernel=False, k=K)
    r_pal = retrieve_batched(q8, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, p, use_kernel=True, k=K)
    np.testing.assert_array_equal(r_pal.ids, r_jnp.ids)
    np.testing.assert_allclose(r_pal.scores, r_jnp.scores,
                               rtol=2e-5, atol=1e-4)


def test_sequential_engine_on_q8(setup):
    corpus, fp32, q8 = setup
    p = twolevel.fast()
    rf = retrieve_sequential(fp32, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, p, k=K)
    rq = retrieve_sequential(q8, corpus.queries, corpus.q_weights_b,
                             corpus.q_weights_l, p, k=K)
    assert _overlap(rq.ids, rf.ids) >= 0.95


@pytest.mark.parametrize("engine,opts", [
    ("batched", {}),
    ("batched", {"traversal": "chunked", "chunk_tiles": 2}),
    ("kernel", {}),
    ("sequential", {}),
    ("sharded", {"n_shards": 3}),
    ("sharded", {"n_shards": 2, "traversal": "chunked", "chunk_tiles": 2,
                 "exchange_every": 1}),
])
def test_registry_engines_serve_q8(setup, engine, opts):
    """Every sparse registry engine opens on the compressed index and
    agrees with the batched fp32 reference."""
    corpus, fp32, q8 = setup
    p = twolevel.original(gamma=0.05)
    queries = dict(terms=corpus.queries, weights_b=corpus.q_weights_b,
                   weights_l=corpus.q_weights_l)
    ref = Retriever.open(fp32, p, engine="batched").search(k=K, **queries)
    r = Retriever.open(q8, p, engine=engine, **opts)
    resp = r.search(k=K, **queries)
    assert _overlap(resp.ids, ref.ids) >= 0.95


@pytest.mark.parametrize("engine", ["cascade", "rrf"])
def test_hybrid_engines_serve_q8(engine):
    """cascade/rrf with the compressed index as the sparse first stage:
    the second stage is exact (dense), so results match the fp32-hybrid
    lane whenever the candidate sets agree."""
    graded = make_graded_corpus(n_docs=1024, n_terms=256, n_queries=6,
                                dim=16, seed=3)
    merged = graded.corpus.merged("scaled")
    h_fp32 = build_hybrid(graded, tile_size=128)
    h_q8 = build_hybrid(graded, tile_size=128,
                        sparse_index=compress_index(merged, tile_size=128))
    p = twolevel.fast()
    queries = graded.queries()
    ref = Retriever.open(h_fp32, p, engine=engine, depth=50
                         ).search(k=K, **queries)
    resp = Retriever.open(h_q8, p, engine=engine, depth=50
                          ).search(k=K, **queries)
    assert _overlap(resp.ids, ref.ids) >= 0.9


def test_save_load_roundtrip(setup, tmp_path):
    corpus, _, q8 = setup
    path = tmp_path / "index.npz"
    q8.save(path)
    back = CompressedImpactIndex.load(path)
    for name in ("packed", "qb", "ql", "tile_ptr", "pack_ptr", "width",
                 "first", "scale_b", "zero_b", "scale_l", "zero_l",
                 "tile_max_b", "tile_max_l", "sigma_b", "sigma_l"):
        np.testing.assert_array_equal(np.asarray(getattr(back, name)),
                                      np.asarray(getattr(q8, name)))
    assert (back.n_docs, back.nnz, back.pad_len) == \
        (q8.n_docs, q8.nnz, q8.pad_len)
    p = twolevel.fast()
    a = retrieve_batched(q8, corpus.queries, corpus.q_weights_b,
                         corpus.q_weights_l, p, k=K)
    b = retrieve_batched(back, corpus.queries, corpus.q_weights_b,
                         corpus.q_weights_l, p, k=K)
    np.testing.assert_array_equal(a.ids, b.ids)
