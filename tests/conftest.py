"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on 1 CPU device;
only launch/dryrun.py (and its subprocess test) uses 512 fake devices."""
import numpy as np
import pytest

from repro.data import make_corpus


@pytest.fixture(scope="session")
def small_corpus():
    return make_corpus("splade_like", n_docs=2048, n_terms=512,
                       n_queries=12, n_q_terms=5, n_rel=3,
                       avg_doc_terms=24, seed=7)


@pytest.fixture(scope="session")
def aligned_corpus():
    return make_corpus("unicoil_like", n_docs=2048, n_terms=512,
                       n_queries=8, n_q_terms=5, n_rel=3,
                       avg_doc_terms=24, seed=11)


def topk_scores_match(a_scores, b_scores, rtol=2e-5, atol=1e-4):
    np.testing.assert_allclose(a_scores, b_scores, rtol=rtol, atol=atol)
