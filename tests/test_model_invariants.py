"""Model-level invariants beyond shape checks."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import schnet as S
from repro.models.transformer import (TransformerConfig, decode_step,
                                      init_params, logits_fn, forward,
                                      prefill)


def test_schnet_energy_translation_invariant():
    """SchNet energies depend on distances only: rigid translation of all
    atom positions must not change the prediction."""
    cfg = S.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=24,
                         cutoff=5.0, n_atom_types=8)
    params = S.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    batch = {
        "z": jnp.asarray(rng.integers(1, 8, (2, 6))),
        "pos": jnp.asarray(rng.standard_normal((2, 6, 3)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, 6, (2, 12))),
        "edge_dst": jnp.asarray(rng.integers(0, 6, (2, 12))),
    }
    e1 = S.molecule_energy(cfg, params, batch)
    shifted = dict(batch, pos=batch["pos"] + jnp.asarray([10., -3., 7.]))
    e2 = S.molecule_energy(cfg, params, shifted)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-4, atol=1e-4)


def test_schnet_rbf_cutoff_kills_long_edges():
    """Edges at the cutoff contribute (numerically) nothing."""
    from repro.models.schnet import rbf_expand
    r = rbf_expand(jnp.asarray([0.1, 4.9, 25.0]), 24, 5.0)
    assert float(r[0].max()) > 0.5
    assert float(r[2].max()) < 1e-6  # far beyond cutoff


def test_lm_greedy_decode_loop_consistency():
    """Greedy decode token-by-token == argmax of the full forward pass."""
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab=131, compute_dtype=jnp.float32,
                            remat=False)
    params = init_params(cfg, jax.random.PRNGKey(5))
    toks = jax.random.randint(jax.random.PRNGKey(6), (1, 8), 0, cfg.vocab)
    # reference: teacher-forced argmax continuation
    ctx = toks
    for _ in range(4):
        h, _, _ = forward(cfg, params, ctx)
        nxt = logits_fn(cfg, params, h)[:, -1].argmax(-1)[:, None]
        ctx = jnp.concatenate([ctx, nxt], axis=1)
    # decode loop with KV cache
    lg, cache = prefill(cfg, params, toks, max_len=16)
    cur = lg[:, -1].argmax(-1)[:, None]
    got = [int(cur[0, 0])]
    pos = 8
    for _ in range(3):
        lg, cache = decode_step(cfg, params, cur, cache, jnp.int32(pos))
        cur = lg[:, -1].argmax(-1)[:, None]
        got.append(int(cur[0, 0]))
        pos += 1
    expect = [int(t) for t in np.asarray(ctx[0, 8:])]
    assert got == expect, (got, expect)


def test_moe_group_count_invariance_no_drop():
    """With no-drop capacity, MoE output is identical for 1 vs 4 dispatch
    groups (group-wise capacity only changes *drop* behaviour)."""
    from repro.models.transformer import MoEConfig, Rules, lm_loss
    cfg = TransformerConfig(n_layers=1, d_model=32, n_heads=2, n_kv_heads=2,
                            d_ff=0, vocab=64,
                            moe=MoEConfig(4, 2, 16, capacity_factor=16.0),
                            compute_dtype=jnp.float32, remat=False)
    params = init_params(cfg, jax.random.PRNGKey(7))
    toks = jax.random.randint(jax.random.PRNGKey(8), (4, 8), 0, 64)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    l1 = lm_loss(cfg, params, batch, Rules(dp_size=1))
    l4 = lm_loss(cfg, params, batch, Rules(dp_size=4))
    np.testing.assert_allclose(float(l1), float(l4), rtol=1e-5)
