"""Observability subsystem (``repro.obs``): exact-rank quantiles and
mergeable histograms, the span tracer (simulated clock, bounded ring,
zero-cost disabled path), Prometheus/JSON export + the HTTP server, the
trace-fitted cost model (monotonicity by construction, predictor vs
realized chunks), cost-sorted dispatch parity, scheduler trace content,
and the non-finite BENCH-JSON guard.

Deterministic seeded cases run always; the hypothesis generalization of
the merge==pooled invariant runs when hypothesis is installed (optional
dev dependency).
"""
import json
import math
import time
import urllib.request

import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.obs import (FEATURES, NULL_SPAN, NULL_TRACER, CostModel,
                       Histogram, MetricsRegistry, MetricsServer,
                       NullTracer, QueryFeaturizer, Tracer,
                       exact_quantile, json_snapshot, prometheus_text)
from repro.retrieval import SearchRequest
from repro.serve import (AsyncRetrievalScheduler, SchedulerConfig,
                         aggregate_latencies, single_route)

RANK_SAFE = twolevel.original(gamma=0.2)


@pytest.fixture(scope="module")
def setup(small_corpus):
    index = build_index(small_corpus.merged("scaled"), tile_size=256)
    return small_corpus, index


def _req(corpus, i, qlen=None, k=10):
    q, wb, wl = (corpus.queries[i], corpus.q_weights_b[i],
                 corpus.q_weights_l[i])
    if qlen is not None:
        q, wb, wl = q[:qlen], wb[:qlen], wl[:qlen]
    return SearchRequest(terms=q, weights_b=wb, weights_l=wl, k=k)


def _chunked_route():
    return single_route("batched", traversal="chunked", chunk_tiles=2)


# -- exact-rank quantiles -----------------------------------------------------

def test_exact_quantile_is_an_observed_sample():
    # the convention the repo standardizes on: p99 of {1, 3} is 3.0 (a
    # sample), not numpy's interpolated 2.98
    assert exact_quantile([1.0, 3.0], 0.99) == 3.0
    assert exact_quantile([100.0, 50.0], 0.99) == 100.0
    assert exact_quantile([5.0], 0.5) == 5.0
    x = np.arange(1, 101, dtype=np.float64)
    assert exact_quantile(x, 0.5) == 50.0
    assert exact_quantile(x, 0.99) == 99.0
    assert exact_quantile(x, 1.0) == 100.0
    assert exact_quantile(x, 0.0) == 1.0    # clamped to rank 1

def test_exact_quantile_guards():
    assert math.isnan(exact_quantile([], 0.5))
    assert math.isnan(exact_quantile([math.nan, math.inf], 0.99))
    assert exact_quantile([1.0, math.nan, 3.0, math.inf], 0.99) == 3.0


def test_aggregate_latencies_uses_exact_rank():
    agg = aggregate_latencies([1.0, 3.0], wall_s=1.0)
    assert agg["p99_ms"] == 3.0 and agg["p50_ms"] == 1.0
    assert agg["mrt_ms"] == 2.0 and agg["n"] == 2
    empty = aggregate_latencies([math.nan], wall_s=1.0)
    assert empty["n"] == 0 and math.isnan(empty["mrt_ms"])


# -- histograms ---------------------------------------------------------------

def test_histogram_basic_and_bucket_resolution():
    h = Histogram("lat")
    for v in (1.0, 2.0, 3.0, 10.0):
        h.record(v)
    assert h.n == 4
    assert h.mean == pytest.approx(4.0)
    # quantiles are bucket upper edges clamped to [min, max]: within one
    # bucket width (2%) above the exact sample quantile, never below min,
    # and the top rank is exactly the max
    assert h.quantile(1.0) == 10.0
    assert 3.0 <= h.quantile(0.75) <= 3.0 * h.growth
    assert h.quantile(0.0) >= 1.0

def test_histogram_nonpos_bucket_and_empty_summary():
    h = Histogram()
    assert h.summary() == {"n": 0}          # no NaN fields: bench-safe
    assert math.isnan(h.quantile(0.5))
    h.record(0.0)                            # zero-service cache hit
    h.record(0.0)
    h.record(5.0)
    assert h.quantile(0.5) == 0.0
    assert h.quantile(1.0) == 5.0

def test_histogram_record_many_matches_loop():
    rng = np.random.default_rng(0)
    xs = rng.lognormal(1.0, 2.0, size=500)
    a, b = Histogram(), Histogram()
    a.record_many(xs)
    for v in xs:
        b.record(v)
    assert a.state() == b.state()

def test_histogram_merge_equals_pooled():
    """The merge invariant: merge(h1, h2) answers every quantile exactly
    as one histogram fed the pooled samples would."""
    rng = np.random.default_rng(7)
    xs = rng.lognormal(0.0, 1.5, size=300)
    ys = rng.lognormal(2.0, 0.5, size=111)
    h1, h2, pooled = Histogram(), Histogram(), Histogram()
    h1.record_many(xs)
    h2.record_many(ys)
    pooled.record_many(np.concatenate([xs, ys]))
    h1.merge(h2)
    assert h1.n == pooled.n
    assert h1.mean == pytest.approx(pooled.mean)
    for q in (0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 1.0):
        assert h1.quantile(q) == pooled.quantile(q), q

def test_histogram_merge_growth_mismatch_raises():
    with pytest.raises(ValueError, match="growth"):
        Histogram(growth=1.02).merge(Histogram(growth=1.1))

def test_histogram_state_roundtrip():
    h = Histogram("x")
    h.record_many([0.0, 0.5, 7.0, 7.0, 123.4])
    h2 = Histogram.from_state(h.state(), name="x")
    assert h2.state() == h.state()
    for q in (0.2, 0.5, 0.9, 1.0):
        assert h2.quantile(q) == h.quantile(q)


# -- hypothesis generalization (optional dev dependency) ----------------------
# guarded import: the deterministic tests above run without hypothesis

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*a, **k):  # pragma: no cover - placeholders keep defs valid
        return lambda f: f

    settings, st = given, None

if HAVE_HYPOTHESIS:
    finite = st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                       allow_infinity=False)

    @settings(max_examples=50, deadline=None)
    @given(st.lists(finite, max_size=80), st.lists(finite, max_size=80))
    def test_histogram_merge_pooled_property(xs, ys):
        h1, h2, pooled = Histogram(), Histogram(), Histogram()
        h1.record_many(xs)
        h2.record_many(ys)
        pooled.record_many(xs + ys)
        h1.merge(h2)
        assert h1.n == pooled.n
        for q in (0.1, 0.5, 0.9, 0.99):
            a, b = h1.quantile(q), pooled.quantile(q)
            assert (a == b) or (math.isnan(a) and math.isnan(b))


# -- registry -----------------------------------------------------------------

def test_registry_kinds_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("served").inc(3)
    reg.gauge("depth").set(7.5)
    reg.histogram("lat").record_many([1.0, 2.0])
    snap = reg.snapshot()
    assert snap["counters"]["served"] == 3
    assert snap["gauges"]["depth"] == 7.5
    assert snap["histograms"]["lat"]["n"] == 2
    # a name is permanently one kind
    with pytest.raises(TypeError, match="Counter"):
        reg.histogram("served")
    # same-name lookup returns the same object
    assert reg.counter("served") is reg.counter("served")

def test_registry_merge():
    a, b = MetricsRegistry(), MetricsRegistry()
    a.counter("c").inc(1)
    b.counter("c").inc(2)
    b.gauge("g").set(9.0)
    b.histogram("h").record(4.0)
    a.merge(b)
    snap = a.snapshot()
    assert snap["counters"]["c"] == 3
    assert snap["gauges"]["g"] == 9.0
    assert snap["histograms"]["h"]["n"] == 1


# -- tracer -------------------------------------------------------------------

def test_span_lifecycle_on_simulated_clock():
    clock = iter([10.0, 12.5])
    tr = Tracer(now=lambda: next(clock))
    s = tr.start("work", foo=1)
    assert math.isnan(s.t_end) and len(tr) == 0   # live spans not in ring
    tr.finish(s)
    assert s.t_start == 10.0 and s.t_end == 12.5
    assert s.duration_ms == pytest.approx(2500.0)
    assert len(tr) == 1
    d = tr.export()[0]
    assert d["name"] == "work" and d["attrs"] == {"foo": 1}

def test_emit_is_retroactive_and_parents_link():
    tr = Tracer()
    root = tr.emit("request", 1.0, 2.0, trace_id=42, route="all")
    child = tr.emit("queue", 1.0, 1.5, trace_id=42, parent=root)
    assert child.parent_id == root.span_id
    spans = tr.trace(42)
    assert [s["name"] for s in spans] == ["request", "queue"]
    assert tr.slowest("request") == 42

def test_ring_eviction_is_deterministic_fifo():
    tr = Tracer(capacity=3)
    for i in range(5):
        tr.emit("s", float(i), float(i) + 0.1, trace_id=i)
    assert [s["trace_id"] for s in tr.export()] == [2, 3, 4]
    tr.clear()
    assert len(tr) == 0
    with pytest.raises(ValueError, match="capacity"):
        Tracer(capacity=0)

def test_null_tracer_is_free_and_shared():
    assert NULL_TRACER.enabled is False
    assert NULL_TRACER.emit("x", 0.0, 1.0) is NULL_SPAN
    assert NULL_TRACER.start("x") is NULL_SPAN
    assert NULL_SPAN.set(a=1) is NULL_SPAN and NULL_SPAN.attrs == {}
    with NULL_TRACER.span("x") as s:
        assert s is NULL_SPAN
    assert NULL_TRACER.export() == [] and len(NULL_TRACER) == 0
    assert isinstance(NULL_TRACER, NullTracer)

def test_disabled_tracer_overhead_guard():
    """The disabled path must stay no-op cheap: one attribute check per
    request plus (at worst) a no-op emit. The bound is deliberately
    generous — it guards against accidentally putting allocation or
    locking on the disabled path, not against scheduler jitter."""
    tr = NULL_TRACER
    n = 20_000
    t0 = time.perf_counter()
    for _ in range(n):
        if tr.enabled:  # pragma: no cover - the guarded (never-taken) arm
            tr.emit("request", 0.0, 1.0, big="attrs", would="cost")
    elapsed_check = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(n):
        tr.emit("request", 0.0, 1.0)
    elapsed_emit = time.perf_counter() - t0
    assert elapsed_check / n < 5e-6     # the scheduler's per-delivery cost
    assert elapsed_emit / n < 20e-6


# -- export -------------------------------------------------------------------

def _demo_registry():
    reg = MetricsRegistry()
    reg.counter("batches").inc(4)
    reg.gauge("generation").set(1.0)
    reg.histogram("queue_wait_ms").record_many([1.0, 2.0, 8.0])
    return reg

def test_prometheus_text_format():
    text = prometheus_text(_demo_registry())
    assert "# TYPE repro_batches counter" in text
    assert "repro_batches 4" in text
    assert "# TYPE repro_generation gauge" in text
    assert "# TYPE repro_queue_wait_ms summary" in text
    assert 'repro_queue_wait_ms{quantile="0.5"}' in text
    assert "repro_queue_wait_ms_count 3" in text
    # name sanitization: '/' is not a legal prometheus name char
    reg = MetricsRegistry()
    reg.histogram("search_ms/batched").record(1.0)
    assert "repro_search_ms_batched" in prometheus_text(reg)

def test_json_snapshot_shape():
    tr = Tracer()
    tr.emit("request", 0.0, 0.5, trace_id=9)
    out = json_snapshot(_demo_registry(), tr, extra={"k": 1})
    assert out["metrics"]["counters"]["batches"] == 4
    assert out["traces"] == {"spans": 1, "slowest_request": 9}
    assert out["extra"] == {"k": 1}
    json.dumps(out)   # JSON-able end to end
    # disabled tracer: no traces key
    assert "traces" not in json_snapshot(_demo_registry(), NULL_TRACER)

def test_metrics_server_serves_all_endpoints():
    tr = Tracer()
    # numpy-scalar attr: callers driving the scheduler with numpy clocks
    # leak these into spans — the JSON endpoints must coerce, not 500
    tr.emit("request", 0.0, 1.0, trace_id=1,
            queue_wait_ms=np.float64(3.5))
    with MetricsServer(_demo_registry(), tr,
                       extra=lambda: {"live": True}) as srv:
        base = f"http://127.0.0.1:{srv.port}"
        text = urllib.request.urlopen(f"{base}/metrics").read().decode()
        assert "repro_batches 4" in text
        snap = json.loads(
            urllib.request.urlopen(f"{base}/metrics.json").read())
        assert snap["extra"] == {"live": True}
        spans = json.loads(
            urllib.request.urlopen(f"{base}/traces").read())
        assert len(spans) == 1 and spans[0]["name"] == "request"
        assert spans[0]["attrs"]["queue_wait_ms"] == 3.5
        with pytest.raises(urllib.error.HTTPError):
            urllib.request.urlopen(f"{base}/nope")


# -- cost model ---------------------------------------------------------------

def test_cost_model_fit_recovers_nonneg_linear():
    rng = np.random.default_rng(0)
    X = rng.uniform(0.0, 10.0, size=(400, len(FEATURES)))
    w_true = np.array([2.0, 0.5, 0.0, 1.5, 3.0])
    y = 1.0 + X @ w_true + rng.normal(0.0, 0.05, size=400)
    m = CostModel.fit(X, y)
    assert (m.weights >= 0).all()
    assert m.r2 > 0.99
    assert m.n_samples == 400
    pred = m.predict(X)
    assert np.corrcoef(pred, y)[0, 1] > 0.99

def test_cost_model_guards(tmp_path):
    with pytest.raises(ValueError, match="zero samples"):
        CostModel.fit(np.zeros((0, 5)), [])
    with pytest.raises(ValueError, match="no .*samples"):
        CostModel.fit_from_traces([{"attrs": {"unrelated": 1}}])
    m = CostModel.fit(np.ones((4, 5)), [1.0, 1.0, 1.0, 1.0])
    with pytest.raises(ValueError, match="feature width"):
        m.predict(np.ones((2, 3)))
    # persistence round-trip
    p = tmp_path / "cost_model.json"
    m.save(p)
    m2 = CostModel.load(p)
    assert np.allclose(m2.weights, m.weights)
    assert m2.intercept == pytest.approx(m.intercept)
    assert m2.features == m.features

def test_cost_prediction_is_monotone(setup):
    """A heavier query can never predict fewer chunks: every feature is
    nondecreasing under adding a term or increasing a weight, and the
    fitted weights are nonnegative."""
    corpus, index = setup
    feat = QueryFeaturizer(index, RANK_SAFE)
    rng = np.random.default_rng(3)
    X = rng.uniform(0.0, 5.0, size=(200, len(FEATURES)))
    y = 0.5 + X @ np.array([1.0, 2.0, 0.3, 0.7, 1.1])
    model = CostModel.fit(X, y)
    width = 8
    for trial in range(20):
        t = rng.choice(index.sigma_b.shape[0], width,
                       replace=False).astype(np.int32)
        w = rng.uniform(0.1, 2.0, width).astype(np.float32)
        live = rng.integers(2, width - 1)
        base_w = w.copy()
        base_w[live:] = 0.0          # only `live` terms active
        f_base = feat(t[None], base_w[None], base_w[None])
        # (a) add a term
        more_w = w.copy()
        more_w[live + 1:] = 0.0
        f_more = feat(t[None], more_w[None], more_w[None])
        # (b) increase one live weight
        heavier = base_w.copy()
        heavier[0] *= 3.0
        f_heavy = feat(t[None], heavier[None], heavier[None])
        assert (f_more >= f_base - 1e-9).all(), trial
        assert (f_heavy >= f_base - 1e-9).all(), trial
        p = model.predict(np.concatenate([f_base, f_more, f_heavy]))
        assert p[1] >= p[0] - 1e-9
        assert p[2] >= p[0] - 1e-9

def test_sort_without_model_raises(setup):
    corpus, index = setup
    with pytest.raises(ValueError, match="cost_model"):
        AsyncRetrievalScheduler(
            index, RANK_SAFE,
            SchedulerConfig(sort_batches_by_cost=True))


# -- scheduler integration ----------------------------------------------------

def _serve(scheduler, corpus, n=10, mixed=True):
    handles = []
    for i in range(n):
        qlen = 3 if (mixed and i % 2 == 0) else None
        handles.append(scheduler.submit(_req(corpus, i % 12, qlen=qlen)))
    scheduler.flush()
    return [h.result(timeout=30.0) for h in handles]

def test_stats_carry_queue_wait_and_service_histograms(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE, SchedulerConfig(max_batch=4, cache_size=0))
    _serve(s, corpus, n=6)
    st = s.stats()
    assert st["queue_wait_ms"]["n"] == 6     # one sample per request
    assert st["service_ms"]["n"] == st["batches"]
    assert st["queue_wait_ms"]["p99"] >= st["queue_wait_ms"]["p50"] >= 0.0
    # the snapshot-consistency invariant stays intact with the new keys
    assert st["submitted"] == (st["completed"] + st["failed"] + st["shed"]
                               + st["rejected"] + st["expired"]
                               + st["pending"] + st["in_flight"])

def test_one_trace_explains_a_slow_request(setup):
    """The acceptance trace: with tracing on, a single exported trace
    shows the queue wait, the batch token, the executor id, and the
    traversal's chunks_dispatched."""
    corpus, index = setup
    tracer = Tracer()
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, tracer=tracer),
        routing=_chunked_route())
    _serve(s, corpus, n=8)
    trace_id = tracer.slowest("request")
    assert trace_id is not None
    spans = {sp["name"]: sp for sp in tracer.trace(trace_id)}
    assert set(spans) == {"request", "queue", "execute"}
    assert spans["queue"]["attrs"]["queue_wait_ms"] >= 0.0
    ex = spans["execute"]["attrs"]
    assert isinstance(ex["batch"], int)
    assert ex["executor"] == -1              # sync dispatch: no pool slot
    assert ex["chunks_dispatched"] >= 1.0
    assert ex["n_chunks"] >= ex["chunks_dispatched"]
    assert len(ex["cost_features"]) == len(FEATURES)
    # children link to the root request span
    root_id = spans["request"]["span_id"]
    assert spans["queue"]["parent_id"] == root_id
    assert spans["execute"]["parent_id"] == root_id

def test_cached_hits_and_expiries_emit_request_spans(setup):
    corpus, index = setup
    tracer = Tracer()
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=16, tracer=tracer))
    h1 = s.submit(_req(corpus, 0))
    s.flush()
    h1.result(timeout=30.0)
    h2 = s.submit(_req(corpus, 0))
    assert h2.result(timeout=30.0) is not None and h2.cached
    outcomes = [sp["attrs"].get("outcome") for sp in tracer.export()
                if sp["name"] == "request"]
    assert outcomes.count("completed") == 1
    assert outcomes.count("cached") == 1
    # expiry: a dead-on-arrival deadline sheds at pick time with a span
    h3 = s.submit(SearchRequest(terms=corpus.queries[1],
                                weights_b=corpus.q_weights_b[1],
                                weights_l=corpus.q_weights_l[1],
                                k=10, deadline_ms=1e-6))
    time.sleep(0.002)
    s.flush()
    with pytest.raises(Exception):
        h3.result(timeout=5.0)
    expired = [sp for sp in tracer.export()
               if sp["attrs"].get("outcome") == "expired"]
    assert len(expired) == 1

def test_cost_sorted_dispatch_is_bit_identical(setup):
    """The parity acceptance: per-query results are batch-composition
    independent, so cost-sorted dispatch returns bit-identical
    ids/scores to unsorted dispatch for every request."""
    corpus, index = setup
    # fit a model from a traced run over the same route
    tracer = Tracer()
    traced = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, tracer=tracer),
        routing=_chunked_route())
    _serve(traced, corpus, n=10)
    model = CostModel.fit_from_traces(tracer.export())
    assert (model.weights >= 0).all()

    def responses(sort):
        s = AsyncRetrievalScheduler(
            index, RANK_SAFE,
            SchedulerConfig(max_batch=4, cache_size=0,
                            cost_model=model if sort else None,
                            sort_batches_by_cost=sort),
            routing=_chunked_route())
        return _serve(s, corpus, n=10)

    plain, sorted_ = responses(False), responses(True)
    for a, b in zip(plain, sorted_):
        np.testing.assert_array_equal(a.ids, b.ids)
        np.testing.assert_array_equal(a.scores, b.scores)

def test_predictor_tracks_realized_chunks(setup):
    """Fit from one traced run, predict on a second: predicted chunk
    counts must correlate with realized chunks_dispatched (the mixed
    short/long stream spans a real cost range)."""
    corpus, index = setup
    tracer = Tracer()
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, tracer=tracer),
        routing=_chunked_route())
    _serve(s, corpus, n=12)
    spans = tracer.export()
    model = CostModel.fit_from_traces(spans)
    X, y = [], []
    for sp in spans:
        attrs = sp["attrs"]
        if "cost_features" in attrs and "chunks_dispatched" in attrs:
            X.append(attrs["cost_features"])
            y.append(attrs["chunks_dispatched"])
    assert len(y) >= 10
    pred = model.predict(np.asarray(X))
    y = np.asarray(y)
    if y.std() > 0 and pred.std() > 0:
        assert np.corrcoef(pred, y)[0, 1] > 0.5
    else:                     # degenerate corpus: constant chunk counts
        assert np.allclose(pred, pred[0])

def test_featurizer_resets_on_swap(setup):
    corpus, index = setup
    tracer = Tracer()
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, tracer=tracer))
    _serve(s, corpus, n=2)
    assert s._featurizer is not None
    s.swap_index(index, warm=False)
    assert s._featurizer is None


# -- the bench-JSON non-finite guard ------------------------------------------

def test_check_finite_and_write_guard(tmp_path):
    from benchmarks.common import (check_finite, validate_bench_files,
                                   write_bench_json)
    clean = {"a": 1.0, "b": [0, 2.5], "c": {"d": True, "e": "nan"}}
    assert check_finite(clean) == []
    dirty = {"a": math.nan, "b": [1.0, math.inf], "c": {"d": -math.inf}}
    bad = check_finite(dirty)
    assert sorted(bad) == ["$.a", "$.b[1]", "$.c.d"]
    # the writer refuses non-finite payloads...
    with pytest.raises(ValueError, match=r"\$\.a"):
        write_bench_json(tmp_path / "BENCH_x.json", dirty)
    assert not (tmp_path / "BENCH_x.json").exists()
    # ...and writes deterministic JSON for clean ones
    write_bench_json(tmp_path / "BENCH_x.json", clean)
    assert json.loads((tmp_path / "BENCH_x.json").read_text()) == clean
    # the post-run scan flags a bad recorded file
    (tmp_path / "BENCH_y.json").write_text('{"v": Infinity}')
    assert list(validate_bench_files(tmp_path)) == ["BENCH_y.json"]
