"""Per-kernel interpret-mode validation: shape/dtype sweeps vs ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.kernels.guided_score import guided_score_chunk, guided_score_tile


def _tile_inputs(rng, nq, p, tile_size, density=0.5):
    n_valid = int(p * density)
    offs = np.full((nq, p), -1, np.int32)
    for i in range(nq):
        offs[i, :n_valid] = np.sort(
            rng.choice(tile_size, size=n_valid, replace=False))
    wb = (rng.random((nq, p)) * 3).astype(np.float32) * (offs >= 0)
    wl = (rng.random((nq, p)) * 5).astype(np.float32) * (offs >= 0)
    return jnp.asarray(offs), jnp.asarray(wb), jnp.asarray(wl)


@pytest.mark.parametrize("nq,p,tile_size,block_s", [
    (4, 64, 256, 128), (8, 128, 512, 512), (16, 128, 1024, 256),
    (5, 96, 384, 128),  # non-power-of-two nq/p
])
def test_guided_score_matches_ref(nq, p, tile_size, block_s):
    rng = np.random.default_rng(nq * 1000 + p)
    offs, wb, wl = _tile_inputs(rng, nq, p, tile_size)
    essential = jnp.asarray(rng.random(nq) < 0.5, jnp.float32)
    prefix_beta = jnp.asarray(np.cumsum(rng.random(nq)), jnp.float32)
    args = (offs, wb, wl, essential, prefix_beta, jnp.float32(2.0),
            jnp.float32(1.0), jnp.float32(0.3), jnp.float32(0.05))
    out_k = guided_score_tile(*args, tile_size=tile_size, block_s=block_s)
    out_r = ref.guided_score_tile_ref(*args, tile_size=tile_size)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("alpha,beta,gamma,th_lo", [
    (0.0, 0.0, 0.0, -np.inf), (1.0, 1.0, 0.05, 0.5), (0.7, 0.2, 0.0, 5.0)])
def test_guided_score_param_sweep(alpha, beta, gamma, th_lo):
    rng = np.random.default_rng(0)
    offs, wb, wl = _tile_inputs(rng, 8, 64, 256)
    essential = jnp.asarray(rng.random(8) < 0.6, jnp.float32)
    prefix_beta = jnp.asarray(np.cumsum(rng.random(8)), jnp.float32)
    args = (offs, wb, wl, essential, prefix_beta, jnp.float32(th_lo),
            jnp.float32(alpha), jnp.float32(beta), jnp.float32(gamma))
    out_k = guided_score_tile(*args, tile_size=256, block_s=128)
    out_r = ref.guided_score_tile_ref(*args, tile_size=256)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_guided_score_matches_traversal_scorer(small_corpus):
    """Kernel == the engine's jnp score_tile on real index data."""
    from repro.core import build_index
    from repro.core.traversal import _gather_tile, _combine
    corpus = small_corpus
    index = build_index(corpus.merged("scaled"), tile_size=256)
    qt = jnp.asarray(corpus.queries[0])
    qwb = jnp.asarray(corpus.q_weights_b[0])
    qwl = jnp.asarray(corpus.q_weights_l[0])
    offs, wb, wl = _gather_tile(index.docids, index.w_b, index.w_l,
                                index.tile_ptr, qt, qwb, qwl, jnp.int32(2),
                                pad_len=index.pad_len,
                                tile_size=index.tile_size)
    sig_b = qwb * index.sigma_b[qt]
    sig_l = qwl * index.sigma_l[qt]
    alpha, beta = 1.0, 0.3
    m_alpha = _combine(alpha, sig_b, sig_l)
    m_beta = _combine(beta, sig_b, sig_l)
    essential = (jnp.cumsum(m_alpha) > 1.0).astype(jnp.float32)
    prefix_beta = jnp.cumsum(m_beta)
    # pad P to a lane multiple for the kernel
    padp = (-index.pad_len) % 128
    pad = lambda a, fill: jnp.pad(a, ((0, 0), (0, padp)),
                                  constant_values=fill)
    out_k = guided_score_tile(pad(offs, -1), pad(wb, 0), pad(wl, 0),
                              essential, prefix_beta, jnp.float32(2.0),
                              jnp.float32(alpha), jnp.float32(beta),
                              jnp.float32(0.05), tile_size=256, block_s=256)
    out_r = ref.guided_score_tile_ref(offs, wb, wl, essential, prefix_beta,
                                      jnp.float32(2.0),
                                      jnp.float32(alpha), jnp.float32(beta),
                                      jnp.float32(0.05), tile_size=256)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("n_chunk,nq,p,tile_size,block_s", [
    (4, 8, 64, 256, 128), (3, 5, 96, 384, 128), (2, 8, 128, 512, 512)])
def test_guided_score_chunk_matches_per_tile(n_chunk, nq, p, tile_size,
                                             block_s):
    """The multi-tile chunk kernel must equal per-tile guided_score_tile
    calls on every live tile and publish all-zero planes for skipped ones
    (the SMEM skip predicate gating the scatter/freeze passes)."""
    rng = np.random.default_rng(n_chunk * 100 + nq)
    tiles = [_tile_inputs(rng, nq, p, tile_size) for _ in range(n_chunk)]
    offs = jnp.stack([t[0] for t in tiles])
    wb = jnp.stack([t[1] for t in tiles])
    wl = jnp.stack([t[2] for t in tiles])
    essential = jnp.asarray(rng.random((n_chunk, nq)) < 0.5, jnp.float32)
    prefix_beta = jnp.asarray(np.cumsum(rng.random((n_chunk, nq)), axis=1),
                              jnp.float32)
    skip = jnp.asarray([i % 2 for i in range(n_chunk)], jnp.int32)
    scal = (jnp.float32(2.0), jnp.float32(1.0), jnp.float32(0.3),
            jnp.float32(0.05))
    out = guided_score_chunk(offs, wb, wl, essential, prefix_beta, skip,
                             *scal, tile_size=tile_size, block_s=block_s)
    assert out.shape == (n_chunk, 5, tile_size)
    for c in range(n_chunk):
        if int(skip[c]):
            np.testing.assert_array_equal(np.asarray(out[c]), 0.0)
        else:
            per_tile = guided_score_tile(
                offs[c], wb[c], wl[c], essential[c], prefix_beta[c],
                *scal, tile_size=tile_size, block_s=block_s)
            np.testing.assert_allclose(np.asarray(out[c]),
                                       np.asarray(per_tile),
                                       rtol=1e-5, atol=1e-5)


def test_guided_score_chunk_all_skipped_is_zero():
    rng = np.random.default_rng(0)
    offs, wb, wl = _tile_inputs(rng, 4, 32, 128)
    offs, wb, wl = (jnp.stack([a, a]) for a in (offs, wb, wl))
    essential = jnp.ones((2, 4), jnp.float32)
    prefix_beta = jnp.ones((2, 4), jnp.float32)
    out = guided_score_chunk(offs, wb, wl, essential, prefix_beta,
                             jnp.ones(2, jnp.int32), jnp.float32(0.0),
                             jnp.float32(1.0), jnp.float32(0.3),
                             jnp.float32(0.05), tile_size=128)
    np.testing.assert_array_equal(np.asarray(out), 0.0)


@pytest.mark.parametrize("h,hkv,sq,skv,d,causal,off", [
    (4, 4, 128, 128, 64, True, 0),
    (8, 2, 128, 256, 64, True, 128),   # GQA + decode-style offset
    (4, 1, 64, 128, 128, False, 0),    # MQA, bidirectional
    (2, 2, 256, 256, 32, True, 0),
])
def test_flash_attention_matches_ref(h, hkv, sq, skv, d, causal, off):
    rng = np.random.default_rng(h * 100 + skv)
    q = jnp.asarray(rng.standard_normal((h, sq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((hkv, skv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((hkv, skv, d)), jnp.float32)
    out_k = flash_attention(q, k, v, causal=causal, kv_offset=off,
                            block_q=64, block_k=64)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal, kv_offset=off)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), dtype)
    k = jnp.asarray(rng.standard_normal((2, 128, 64)), dtype)
    v = jnp.asarray(rng.standard_normal((2, 128, 64)), dtype)
    out_k = flash_attention(q, k, v, causal=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True)
    tol = 2e-4 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_flash_attention_batched_vmap():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((3, 4, 128, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((3, 2, 128, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((3, 2, 128, 64)), jnp.float32)
    f = lambda q, k, v: flash_attention(q, k, v, causal=True)
    out_k = jax.vmap(f)(q, k, v)
    out_r = jax.vmap(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=True))(q, k, v)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("v,d,b,l", [
    (64, 32, 16, 4), (256, 128, 32, 8), (1000, 64, 8, 12)])
def test_embedding_bag_matches_ref(v, d, b, l):
    rng = np.random.default_rng(v + b)
    table = jnp.asarray(rng.standard_normal((v, d)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, v, (b, l)), jnp.int32)
    w = jnp.asarray(rng.random((b, l)), jnp.float32)
    out_k = embedding_bag(table, idx, w, block_b=min(8, b))
    out_r = ref.embedding_bag_ref(table, idx, w)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_padding_weights():
    table = jnp.asarray(np.eye(8, 4), jnp.float32)
    idx = jnp.asarray([[1, 2, 0], [3, 0, 0]], jnp.int32)
    w = jnp.asarray([[1.0, 1.0, 0.0], [2.0, 0.0, 0.0]], jnp.float32)
    out = embedding_bag(table, idx, w, block_b=2)
    expect = np.zeros((2, 4), np.float32)
    expect[0, 1] = 1.0
    expect[0, 2] = 1.0
    expect[1, 3] = 2.0
    np.testing.assert_allclose(np.asarray(out), expect)
