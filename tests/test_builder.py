"""StreamingIndexBuilder: chunked build == one-shot compress, idempotent
add, checkpointed crash-resume.

The load-bearing property: per-(term, tile) runs are word-aligned and
self-contained, so per-chunk encodes concatenated in global run order
are *bit-identical* to one ``compress_index`` over the whole corpus —
resume therefore never changes the produced index, only the wall clock.

The kill-and-resume test SIGKILLs a child build inside the durability
window (chunk spilled, manifest not yet written — the crash point the
atomic-replace protocol is designed around) and pins that reopening the
builder and replaying the stream yields the one-shot index bit-for-bit.
"""
import os
import signal
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.data import (StreamingIndexBuilder, make_corpus,
                        synthetic_chunk_stream)
from repro.index import compress_index

_ARRAYS = ("packed", "qb", "ql", "tile_ptr", "pack_ptr", "width", "first",
           "scale_b", "zero_b", "scale_l", "zero_l", "tile_max_b",
           "tile_max_l", "sigma_b", "sigma_l")

N_DOCS = 2048
TILE = 256
CHUNK_DOCS = 512  # 4 chunks, 2 tiles each


def _assert_indexes_equal(a, b):
    assert (a.n_docs, a.n_terms, a.n_tiles, a.nnz, a.pad_len) == \
        (b.n_docs, b.n_terms, b.n_tiles, b.nnz, b.pad_len)
    for name in _ARRAYS:
        np.testing.assert_array_equal(
            np.asarray(getattr(a, name)), np.asarray(getattr(b, name)),
            err_msg=name)


@pytest.fixture(scope="module")
def corpus():
    return make_corpus("splade_like", n_docs=N_DOCS, n_terms=256,
                       n_queries=4, avg_doc_terms=24, seed=5)


def test_chunked_equals_oneshot(corpus, tmp_path):
    oneshot = compress_index(corpus.merged("scaled"), tile_size=TILE)
    b = StreamingIndexBuilder(tmp_path / "idx", n_terms=corpus.n_terms,
                              tile_size=TILE, chunk_docs=CHUNK_DOCS)
    for ch in corpus.iter_chunks(CHUNK_DOCS):
        assert b.add_chunk(ch)
    _assert_indexes_equal(b.finalize(), oneshot)


def test_short_last_chunk(corpus, tmp_path):
    # last chunk holds fewer docs than chunk_docs (non-divisible corpus)
    oneshot = compress_index(corpus.merged("scaled"), tile_size=TILE)
    b = StreamingIndexBuilder(tmp_path / "idx", n_terms=corpus.n_terms,
                              tile_size=TILE, chunk_docs=768)
    for ch in corpus.iter_chunks(768):  # 768 = 3 tiles; 2048 = 2x768+512
        b.add_chunk(ch)
    _assert_indexes_equal(b.finalize(), oneshot)


def test_add_chunk_idempotent(corpus, tmp_path):
    b = StreamingIndexBuilder(tmp_path / "idx", n_terms=corpus.n_terms,
                              tile_size=TILE, chunk_docs=CHUNK_DOCS)
    chunks = list(corpus.iter_chunks(CHUNK_DOCS))
    for ch in chunks:
        assert b.add_chunk(ch) is True
    for ch in chunks:  # replay: every add is a recorded no-op
        assert b.add_chunk(ch) is False
    _assert_indexes_equal(b.finalize(),
                          compress_index(corpus.merged("scaled"),
                                         tile_size=TILE))


def test_geometry_validation(corpus, tmp_path):
    with pytest.raises(ValueError, match="multiple of"):
        StreamingIndexBuilder(tmp_path / "a", n_terms=256, tile_size=256,
                              chunk_docs=300)
    StreamingIndexBuilder(tmp_path / "b", n_terms=256, tile_size=256,
                          chunk_docs=512)
    with pytest.raises(ValueError, match="geometry mismatch"):
        StreamingIndexBuilder(tmp_path / "b", n_terms=256, tile_size=128,
                              chunk_docs=512)
    # misplaced chunk: doc_start must equal chunk_id * chunk_docs
    b = StreamingIndexBuilder(tmp_path / "c", n_terms=corpus.n_terms,
                              tile_size=TILE, chunk_docs=CHUNK_DOCS)
    ch = next(iter(corpus.iter_chunks(CHUNK_DOCS)))
    bad = type(ch)(chunk_id=1, doc_start=ch.doc_start, n_docs=ch.n_docs,
                   terms=ch.terms, docids=ch.docids, w_b=ch.w_b, w_l=ch.w_l)
    with pytest.raises(ValueError, match="starts at doc"):
        b.add_chunk(bad)
    with pytest.raises(ValueError, match="no chunks"):
        StreamingIndexBuilder(tmp_path / "d", n_terms=256, tile_size=256,
                              chunk_docs=512).finalize()


def test_finalize_rejects_gaps(corpus, tmp_path):
    b = StreamingIndexBuilder(tmp_path / "idx", n_terms=corpus.n_terms,
                              tile_size=TILE, chunk_docs=CHUNK_DOCS)
    for ch in corpus.iter_chunks(CHUNK_DOCS):
        if ch.chunk_id != 1:  # hole in the chunk sequence
            b.add_chunk(ch)
    with pytest.raises(ValueError, match="contiguous"):
        b.finalize()


def test_stream_chunks_are_seed_pure():
    """Each chunk is a pure function of (seed, chunk_id): regenerating
    chunk 2 via start_chunk matches the full stream — the property that
    makes 'reopen and replay from the first missing chunk' a valid
    resume."""
    full = list(synthetic_chunk_stream(4, 512, 128, seed=9))
    tail = list(synthetic_chunk_stream(4, 512, 128, seed=9, start_chunk=2))
    assert [c.chunk_id for c in tail] == [2, 3]
    for a, b in zip(full[2:], tail):
        np.testing.assert_array_equal(a.terms, b.terms)
        np.testing.assert_array_equal(a.docids, b.docids)
        np.testing.assert_array_equal(a.w_b, b.w_b)
        np.testing.assert_array_equal(a.w_l, b.w_l)


_CRASH_CHILD = textwrap.dedent("""\
    import os, signal, sys
    from repro.data import StreamingIndexBuilder, synthetic_chunk_stream

    out = sys.argv[1]

    class CrashingBuilder(StreamingIndexBuilder):
        calls = 0
        def _write_manifest(self):
            # call 1: __init__; calls 2-3: chunks 0-1; call 4: chunk 2 —
            # die with the spill on disk but unrecorded (the orphan-spill
            # crash window between os.replace and the manifest update)
            CrashingBuilder.calls += 1
            if CrashingBuilder.calls == 4:
                os.kill(os.getpid(), signal.SIGKILL)
            super()._write_manifest()

    b = CrashingBuilder(out, n_terms=128, tile_size=256, chunk_docs=512)
    for ch in synthetic_chunk_stream(4, 512, 128, seed=9):
        b.add_chunk(ch)
    raise SystemExit("child survived past the kill point")
""")


def test_kill_and_resume(tmp_path):
    out = tmp_path / "idx"
    env = dict(os.environ, PYTHONPATH="src")
    proc = subprocess.run([sys.executable, "-c", _CRASH_CHILD, str(out)],
                          env=env, cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))),
                          capture_output=True, text=True, timeout=300)
    assert proc.returncode == -signal.SIGKILL, proc.stderr

    # crash state: chunks 0-1 recorded, chunk 2 spilled but orphaned
    b = StreamingIndexBuilder(out, n_terms=128, tile_size=256,
                              chunk_docs=512)
    assert b.completed_chunks == [0, 1]
    assert (out / "chunk_00002.npz").exists()

    # resume: replay from the first missing chunk; the orphan spill is
    # simply rewritten, recorded chunks are skipped
    start = min(set(range(4)) - set(b.completed_chunks))
    assert start == 2
    for ch in synthetic_chunk_stream(4, 512, 128, seed=9, start_chunk=start):
        assert b.add_chunk(ch) is True
    resumed = b.finalize()

    # bit-identical to a build that never crashed
    clean = StreamingIndexBuilder(tmp_path / "clean", n_terms=128,
                                  tile_size=256, chunk_docs=512)
    for ch in synthetic_chunk_stream(4, 512, 128, seed=9):
        clean.add_chunk(ch)
    _assert_indexes_equal(resumed, clean.finalize())


@pytest.mark.slow
def test_million_doc_build():
    """The acceptance-scale build: 2^20 docs streamed through the
    builder; the compressed index must stay under 25% of the fp32
    bytes (the BENCH_index.json headline, pinned here as a test)."""
    import tempfile
    n_chunks, chunk_docs = 16, 65536
    with tempfile.TemporaryDirectory() as d:
        b = StreamingIndexBuilder(d, n_terms=256, tile_size=8192,
                                  chunk_docs=chunk_docs)
        for ch in synthetic_chunk_stream(n_chunks, chunk_docs, 256,
                                         avg_doc_terms=64, seed=0,
                                         zipf_a=1.2):
            b.add_chunk(ch)
        index = b.finalize()
    assert index.n_docs == n_chunks * chunk_docs == 1 << 20
    ratio = index.nbytes()["total"] / index.fp32_nbytes()
    assert ratio < 0.25, f"compression ratio {ratio:.3f} >= 0.25"
