"""Unified search API: registry coverage, facade-vs-legacy bit parity for
every registered engine, the k-bucketing path, the no-recompile-within-a-
bucket guarantee, and the TwoLevelParams.k deprecation shim.

The parity tests are the API contract: ``Retriever.search`` is a facade,
not a fork — for every engine it must return exactly what the legacy
entry point returns (ids and scores bit-identical), on rank-safe *and*
guided configs when k sits on a bucket, and on rank-safe configs even
when k is bucketed up and truncated back (exact top-k is prefix-closed
under the stable tie discipline).
"""
import warnings

import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.core.shard_plan import shard_index
from repro.core.traversal import retrieve_batched, retrieve_sequential
from repro.retrieval import (K_BUCKETS, Retriever, SearchRequest,
                             bucket_k, engine_names, get_engine)
from repro.serve.sharded import shard_retrieve_batched

ALL_ENGINES = ("batched", "cascade", "dense", "kernel", "rrf",
               "sequential", "sharded")


@pytest.fixture(scope="module")
def setup(small_corpus):
    index = build_index(small_corpus.merged("scaled"), tile_size=256)
    return small_corpus, index


def _q(corpus):
    return dict(terms=corpus.queries, weights_b=corpus.q_weights_b,
                weights_l=corpus.q_weights_l)


# -- registry -----------------------------------------------------------------

def test_registry_has_all_engines():
    assert engine_names() == tuple(sorted(ALL_ENGINES))


def test_unknown_engine_lists_alternatives():
    with pytest.raises(KeyError, match="batched"):
        get_engine("bm25")


@pytest.mark.parametrize("engine", ALL_ENGINES)
def test_every_engine_serves_a_request(setup, engine):
    """Registry smoke: each name opens and answers a small request with
    the uniform response shape (the make test-api / fast-lane gate)."""
    corpus, index = setup
    if engine == "dense":
        import jax.numpy as jnp
        from repro.core.dense_guided import build_dense_index
        rng = np.random.default_rng(0)
        emb = rng.standard_normal((1024, 16)).astype(np.float32)
        r = Retriever.open(build_dense_index(jnp.asarray(emb),
                                             block_size=256, d_cheap=4),
                           twolevel.original(gamma=0.0), engine="dense")
        resp = r.search(dense=rng.standard_normal((3, 16)).astype(
            np.float32), k=5)
    elif engine in ("cascade", "rrf"):
        from repro.retrieval import build_hybrid_index
        rng = np.random.default_rng(0)
        hybrid = build_hybrid_index(
            index,
            rng.standard_normal((index.n_docs, 16)).astype(np.float32),
            rng.standard_normal((index.n_terms, 16)).astype(np.float32),
            block_size=256, d_cheap=4)
        r = Retriever.open(hybrid, twolevel.fast(), engine=engine,
                           depth=20)
        resp = r.search(**_q(corpus), k=5)
    else:
        r = Retriever.open(index, twolevel.fast(), engine=engine)
        resp = r.search(**_q(corpus), k=5)
    assert resp.engine == engine
    assert resp.k == 5 and resp.k_exec == 10
    assert resp.ids.shape == resp.scores.shape == (resp.ids.shape[0], 5)
    assert resp.latency_ms > 0
    assert resp.stats


@pytest.mark.parametrize("engine,traversal", [
    ("batched", "chunked"), ("kernel", "chunked"),
    ("kernel", "chunked_fused"), ("sharded", "chunked")])
def test_traversal_knob_serves_and_reports_chunks(setup, engine, traversal):
    """The chunked-traversal knob opens through the facade for every
    engine that supports it and surfaces the chunks_dispatched stat."""
    corpus, index = setup
    p = twolevel.fast().replace(chunk_tiles=2)
    opts = {"n_shards": 2} if engine == "sharded" else {}
    r = Retriever.open(index, p, engine=engine, traversal=traversal, **opts)
    resp = r.search(**_q(corpus), k=5)
    assert resp.ids.shape == (len(corpus.queries), 5)
    assert "chunks_dispatched" in resp.stats
    assert (resp.stats["chunks_dispatched"]
            <= resp.stats["n_chunks"]).all()


@pytest.mark.parametrize("engine,traversal", [
    ("batched", "chunked_fused"), ("batched", "nope"),
    ("kernel", "nope"), ("sharded", "chunked_fused")])
def test_unsupported_traversal_raises_at_open(setup, engine, traversal):
    corpus, index = setup
    opts = {"n_shards": 2} if engine == "sharded" else {}
    with pytest.raises(ValueError, match="traversal"):
        Retriever.open(index, twolevel.fast(), engine=engine,
                       traversal=traversal, **opts)


def test_chunked_facade_matches_legacy_chunked(setup):
    """Facade + chunked knob == the legacy entry point's chunked path."""
    corpus, index = setup
    p = twolevel.fast().replace(chunk_tiles=2)
    legacy = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, p, k=10,
                              traversal="chunked")
    r = Retriever.open(index, p, engine="batched", traversal="chunked",
                       k_buckets=None)
    resp = r.search(**_q(corpus), k=10)
    np.testing.assert_array_equal(resp.ids, legacy.ids)
    np.testing.assert_array_equal(resp.scores, legacy.scores)
    np.testing.assert_array_equal(resp.stats["chunks_dispatched"],
                                  legacy.stats["chunks_dispatched"])


# -- facade vs legacy entry points, bit-identical -----------------------------

@pytest.mark.parametrize("params", [twolevel.original(gamma=0.2),
                                    twolevel.fast()],
                         ids=["rank_safe", "guided"])
def test_batched_and_kernel_match_legacy(setup, params):
    corpus, index = setup
    for engine, use_kernel in (("batched", False), ("kernel", True)):
        ref = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                               corpus.q_weights_l, params,
                               use_kernel=use_kernel, k=10)
        resp = Retriever.open(index, params, engine=engine).search(
            **_q(corpus), k=10)
        np.testing.assert_array_equal(resp.ids, ref.ids)
        np.testing.assert_array_equal(resp.scores, ref.scores)


@pytest.mark.parametrize("params", [twolevel.original(gamma=0.2),
                                    twolevel.fast()],
                         ids=["rank_safe", "guided"])
def test_sequential_matches_legacy(setup, params):
    corpus, index = setup
    ref = retrieve_sequential(index, corpus.queries, corpus.q_weights_b,
                              corpus.q_weights_l, params, k=10)
    resp = Retriever.open(index, params, engine="sequential").search(
        **_q(corpus), k=10)
    np.testing.assert_array_equal(resp.ids, ref.ids)
    np.testing.assert_array_equal(resp.scores, ref.scores)
    assert resp.latencies_ms is not None and len(resp.latencies_ms) == len(
        corpus.queries)


@pytest.mark.parametrize("params", [twolevel.original(gamma=0.2),
                                    twolevel.fast()],
                         ids=["rank_safe", "guided"])
def test_sharded_matches_legacy(setup, params):
    corpus, index = setup
    sh = shard_index(index, 3)
    ref = shard_retrieve_batched(sh, corpus.queries, corpus.q_weights_b,
                                 corpus.q_weights_l, params, k=10)
    resp = Retriever.open(index, params, engine="sharded",
                          n_shards=3).search(**_q(corpus), k=10)
    np.testing.assert_array_equal(resp.ids, ref.ids)
    np.testing.assert_array_equal(resp.scores, ref.scores)


def test_sharded_accepts_prebuilt_shard_plan(setup):
    corpus, index = setup
    p = twolevel.fast()
    sh = shard_index(index, 4)
    a = Retriever.open(index, p, engine="sharded", n_shards=4).search(
        **_q(corpus), k=10)
    b = Retriever.open(sh, p, engine="sharded").search(**_q(corpus), k=10)
    np.testing.assert_array_equal(a.ids, b.ids)
    np.testing.assert_array_equal(a.scores, b.scores)


def test_dense_matches_legacy():
    import jax.numpy as jnp
    from repro.core.dense_guided import build_dense_index, retrieve_dense
    rng = np.random.default_rng(1)
    emb = rng.standard_normal((2048, 24)).astype(np.float32)
    index = build_dense_index(jnp.asarray(emb), block_size=256, d_cheap=8)
    qs = rng.standard_normal((4, 24)).astype(np.float32)
    for params in (twolevel.TwoLevelParams(0.0, 0.0, 0.0),   # rank-safe
                   twolevel.TwoLevelParams(1.0, 0.3, 0.0)):  # guided
        resp = Retriever.open(index, params, engine="dense").search(
            dense=qs, k=10)
        for i, q in enumerate(qs):
            vals, ids, _ = retrieve_dense(index, jnp.asarray(q), params,
                                          k=10)
            # the engine's batched lane vmaps the guided scan, which
            # reorders XLA's dot-product reductions: scores agree to
            # float tolerance, and ids may swap only across near-ties
            np.testing.assert_allclose(resp.scores[i], vals,
                                       rtol=1e-5, atol=1e-5)
            mism = resp.ids[i] != ids
            if mism.any():
                tied = np.zeros_like(mism)
                close = np.abs(np.diff(vals)) < 1e-5
                tied[1:] |= close
                tied[:-1] |= close
                assert mism[~tied].sum() == 0, (resp.ids[i], ids)


# -- per-call knobs -----------------------------------------------------------

def test_threshold_factor_override_matches_replaced_params(setup):
    corpus, index = setup
    base = twolevel.original(gamma=0.2)
    ref = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l,
                           base.replace(threshold_factor=1.5), k=10)
    resp = Retriever.open(index, base).search(**_q(corpus), k=10,
                                              threshold_factor=1.5)
    np.testing.assert_array_equal(resp.ids, ref.ids)
    np.testing.assert_array_equal(resp.scores, ref.scores)


def test_search_request_object_and_kwargs_agree(setup):
    corpus, index = setup
    r = Retriever.open(index, twolevel.fast())
    a = r.search(SearchRequest(**_q(corpus), k=7))
    b = r.search(**_q(corpus), k=7)
    np.testing.assert_array_equal(a.ids, b.ids)
    with pytest.raises(TypeError, match="not both"):
        r.search(SearchRequest(**_q(corpus)), k=7)
    with pytest.raises(TypeError, match="not both"):
        r.search(SearchRequest(**_q(corpus)),
                 weights_b=corpus.q_weights_b)


def test_ragged_queries_are_padded(setup):
    """Ragged per-query lists serve identically to zero-padded arrays."""
    corpus, index = setup
    r = Retriever.open(index, twolevel.fast())
    ref = r.search(**_q(corpus), k=10)
    ragged = dict(
        terms=[q for q in corpus.queries],
        weights_b=[w for w in corpus.q_weights_b],
        weights_l=[w for w in corpus.q_weights_l])
    # chop one query short (its weights tail was nonzero -> scores may
    # legitimately change), so instead extend with explicit zero weights
    ragged["terms"][0] = np.concatenate([corpus.queries[0], [0, 0]])
    ragged["weights_b"][0] = np.concatenate([corpus.q_weights_b[0],
                                             [0.0, 0.0]])
    ragged["weights_l"][0] = np.concatenate([corpus.q_weights_l[0],
                                             [0.0, 0.0]])
    resp = r.search(**ragged, k=10)
    np.testing.assert_array_equal(resp.ids, ref.ids)
    np.testing.assert_array_equal(resp.scores, ref.scores)


# -- k-bucketing --------------------------------------------------------------

def test_bucket_k_boundaries():
    assert [bucket_k(k) for k in (1, 10, 11, 100, 101, 1000, 5000)] == \
        [10, 10, 100, 100, 1000, 1000, 5000]
    assert bucket_k(7, None) == 7
    with pytest.raises(ValueError):
        bucket_k(0)


@pytest.mark.parametrize("k", [5, 10, 100])
def test_bucketed_k_rank_safe_parity_all_sparse_engines(setup, k):
    """The acceptance sweep: k in {5, 10, 100} through the bucketing path
    must be bit-identical to the legacy entry point run at exactly k, for
    every sparse engine, on a rank-safe config (k=5 executes at the k=10
    bucket and is truncated — exact top-k is prefix-closed)."""
    corpus, index = setup
    params = twolevel.original(gamma=0.2)
    legacy = {
        "batched": lambda: retrieve_batched(
            index, corpus.queries, corpus.q_weights_b, corpus.q_weights_l,
            params, k=k),
        "kernel": lambda: retrieve_batched(
            index, corpus.queries, corpus.q_weights_b, corpus.q_weights_l,
            params, use_kernel=True, k=k),
        "sequential": lambda: retrieve_sequential(
            index, corpus.queries, corpus.q_weights_b, corpus.q_weights_l,
            params, k=k),
        "sharded": lambda: shard_retrieve_batched(
            shard_index(index, 2), corpus.queries, corpus.q_weights_b,
            corpus.q_weights_l, params, k=k),
    }
    for engine, call in legacy.items():
        ref = call()
        opts = {"n_shards": 2} if engine == "sharded" else {}
        resp = Retriever.open(index, params, engine=engine, **opts).search(
            **_q(corpus), k=k)
        assert resp.k_exec == bucket_k(k)
        np.testing.assert_array_equal(resp.ids, ref.ids[:, :k],
                                      err_msg=engine)
        np.testing.assert_array_equal(resp.scores, ref.scores[:, :k],
                                      err_msg=engine)


def test_k_within_bucket_does_not_recompile(setup):
    """Changing k at call time must not recompile within a bucket: the
    jitted batched impl's cache may not grow between k=5 and k=8 (both
    execute at the 10-bucket); a new bucket adds exactly one entry."""
    from repro.core.traversal import _retrieve_batched_impl
    corpus, _ = setup
    # fresh tile_size -> unique static shapes -> cold jit-cache rows for
    # this test regardless of what other tests already compiled
    index = build_index(corpus.merged("scaled"), tile_size=64)
    r = Retriever.open(index, twolevel.fast())
    r.search(**_q(corpus), k=5)        # compiles the 10-bucket
    n0 = _retrieve_batched_impl._cache_size()
    r.search(**_q(corpus), k=8)        # same bucket: cache hit
    r.search(**_q(corpus), k=10)
    assert _retrieve_batched_impl._cache_size() == n0
    r.search(**_q(corpus), k=42)       # 100-bucket: one new entry
    assert _retrieve_batched_impl._cache_size() == n0 + 1
    r.search(**_q(corpus), k=100)      # still the 100-bucket
    assert _retrieve_batched_impl._cache_size() == n0 + 1


def test_exact_mode_disables_bucketing(setup):
    corpus, index = setup
    r = Retriever.open(index, twolevel.fast(), k_buckets=None)
    resp = r.search(**_q(corpus), k=7)
    assert resp.k == resp.k_exec == 7


def test_custom_buckets_are_sorted(setup):
    corpus, index = setup
    r = Retriever.open(index, twolevel.fast(), k_buckets=(100, 10))
    assert r.search(**_q(corpus), k=5).k_exec == 10


# -- per-request k within one batch (mixed-k) ---------------------------------

MIXED_KS = [5, 10, 100]


@pytest.mark.parametrize("engine", ["batched", "kernel", "sharded"])
def test_mixed_k_batch_matches_per_k_calls(setup, engine):
    """One batch with k in {5, 10, 100} executes once at the batch-max
    bucket and each row's prefix is bit-identical to a separate call at
    that row's own k (rank-safe: the exact top-k is prefix-closed across
    buckets); slots beyond a row's depth hold the empty sentinels."""
    corpus, index = setup
    params = twolevel.original(gamma=0.2)
    opts = {"n_shards": 2} if engine == "sharded" else {}
    r = Retriever.open(index, params, engine=engine, **opts)
    n = len(MIXED_KS)
    batch = dict(terms=corpus.queries[:n],
                 weights_b=corpus.q_weights_b[:n],
                 weights_l=corpus.q_weights_l[:n])
    resp = r.search(**batch, k=MIXED_KS)
    assert resp.k == 100 and resp.k_exec == 100
    np.testing.assert_array_equal(resp.ks, MIXED_KS)
    assert resp.ids.shape == resp.scores.shape == (n, 100)
    for i, ki in enumerate(MIXED_KS):
        single = r.search(terms=corpus.queries[i:i + 1],
                          weights_b=corpus.q_weights_b[i:i + 1],
                          weights_l=corpus.q_weights_l[i:i + 1], k=ki)
        np.testing.assert_array_equal(resp.ids[i, :ki], single.ids[0],
                                      err_msg=f"{engine} row {i}")
        np.testing.assert_array_equal(resp.scores[i, :ki], single.scores[0],
                                      err_msg=f"{engine} row {i}")
        assert (resp.ids[i, ki:] == -1).all()
        assert np.isneginf(resp.scores[i, ki:]).all()


def test_mixed_k_within_bucket_does_not_recompile(setup):
    """Sweeping the per-row k mix inside one bucket must hit the jit
    cache; raising the batch-max into a new bucket adds exactly one
    entry."""
    from repro.core.traversal import _retrieve_batched_impl
    corpus, _ = setup
    # fresh tile_size -> unique static shapes -> cold jit-cache rows
    index = build_index(corpus.merged("scaled"), tile_size=32)
    r = Retriever.open(index, twolevel.fast())
    batch = dict(terms=corpus.queries[:3],
                 weights_b=corpus.q_weights_b[:3],
                 weights_l=corpus.q_weights_l[:3])
    r.search(**batch, k=[5, 9, 10])        # compiles the 10-bucket
    n0 = _retrieve_batched_impl._cache_size()
    r.search(**batch, k=[7, 8, 10])        # same bucket: cache hit
    r.search(**batch, k=10)                # scalar k, same bucket
    assert _retrieve_batched_impl._cache_size() == n0
    r.search(**batch, k=[5, 10, 42])       # batch max 42 -> 100-bucket
    assert _retrieve_batched_impl._cache_size() == n0 + 1
    r.search(**batch, k=[100, 5, 10])      # still the 100-bucket
    assert _retrieve_batched_impl._cache_size() == n0 + 1


def test_mixed_k_validation(setup):
    corpus, index = setup
    r = Retriever.open(index, twolevel.fast())
    q3 = dict(terms=corpus.queries[:3],
              weights_b=corpus.q_weights_b[:3],
              weights_l=corpus.q_weights_l[:3])
    with pytest.raises(ValueError, match="3 queries"):
        r.search(**q3, k=[5, 10])
    with pytest.raises(ValueError, match=">= 1"):
        r.search(**q3, k=[5, 0, 10])
    with pytest.raises(ValueError, match="whole numbers"):
        r.search(**q3, k=[5.5, 10, 100])
    # exact float depths are fine (a computed k often arrives as float)
    assert r.search(**q3, k=[5.0, 10.0, 10.0]).ks.tolist() == [5, 10, 10]


# -- TwoLevelParams.k deprecation shim ----------------------------------------

def test_legacy_k_warns_and_still_works(setup):
    corpus, index = setup
    with pytest.warns(DeprecationWarning, match="query-time"):
        p_old = twolevel.fast(k=5)
    assert p_old.k == 5
    # the stash survives replace() and keeps driving legacy call sites
    assert p_old.replace(schedule="impact").k == 5
    ref = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, twolevel.fast(), k=5)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p_old)
    np.testing.assert_array_equal(res.ids, ref.ids)
    np.testing.assert_array_equal(res.scores, ref.scores)
    # policy equality ignores the deprecated stash; resolve_k honors it
    assert p_old == twolevel.fast()
    assert twolevel.resolve_k(p_old) == 5
    assert twolevel.resolve_k(p_old, 12) == 12
    assert twolevel.resolve_k(twolevel.fast()) == twolevel.DEFAULT_K


def test_legacy_k_positional_slot_preserved():
    with pytest.warns(DeprecationWarning):
        p = twolevel.TwoLevelParams(1.0, 0.3, 0.05, 7)
    assert p.k == 7 and p.threshold_factor == 1.0


def test_retriever_honors_legacy_k_default(setup):
    corpus, index = setup
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        p_old = twolevel.fast(k=5)
    r = Retriever.open(index, p_old)
    resp = r.search(**_q(corpus))
    assert resp.k == 5
    # both invocation styles resolve the depth identically
    assert r.search(SearchRequest(**_q(corpus))).k == 5
