"""The v2 serving seam: AsyncRetrievalScheduler handle lifecycle,
(k-bucket x length-class) micro-batching with per-request k, the
compile-once-per-group guarantee, query-length routing, the LRU
response cache (zero-service-time completions), priorities, the
threaded mode, run_workload accounting, and the deprecated
RetrievalServer shim.

The parity tests are the acceptance contract: a mixed-k, mixed-length
stream served through the scheduler must return bit-identical
ids/scores to per-request ``Retriever.search`` calls for rank-safe
configs on the batched, kernel, and sharded engines.
"""
import math

import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.retrieval import Retriever, SearchRequest
from repro.serve import (AsyncRetrievalScheduler, Request, RetrievalServer,
                         RoutingPolicy, SchedulerConfig, ServerConfig,
                         query_length, route, run_workload, single_route,
                         table8_policy)

RANK_SAFE = twolevel.original(gamma=0.2)
SHORT, LONG = 3, 5   # live-term counts in the small_corpus stream


@pytest.fixture(scope="module")
def setup(small_corpus):
    index = build_index(small_corpus.merged("scaled"), tile_size=256)
    return small_corpus, index


def _req(corpus, i, qlen=None, k=10, threshold_factor=None):
    q, wb, wl = (corpus.queries[i], corpus.q_weights_b[i],
                 corpus.q_weights_l[i])
    if qlen is not None:
        q, wb, wl = q[:qlen], wb[:qlen], wl[:qlen]
    return SearchRequest(terms=q, weights_b=wb, weights_l=wl, k=k,
                         threshold_factor=threshold_factor)


def _two_class_policy(engine, **opts):
    return RoutingPolicy((
        route("short", SHORT, engine, pad_terms=SHORT, **opts),
        route("long", None, engine, **opts)))


# -- handle lifecycle ---------------------------------------------------------

def test_handle_lifecycle_sync(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))
    h = s.submit(_req(corpus, 0, k=7))
    assert not h.done()
    assert math.isnan(h.latency_ms)
    assert s.pending_count() == 1
    assert h.k_bucket == 10 and h.route == "all"
    assert s.flush() == 1
    assert h.done() and s.pending_count() == 0
    resp = h.result()
    assert resp.ids.shape == resp.scores.shape == (1, 7)
    assert resp.ks.tolist() == [7] and resp.k_exec == 10
    assert h.latency_ms >= 0 and not h.cached


def test_result_on_sync_scheduler_flushes_instead_of_deadlocking(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE, SchedulerConfig())
    h = s.submit(_req(corpus, 1))
    resp = h.result(timeout=120.0)   # no worker, no explicit poll
    assert resp.ids.shape == (1, 10)


def test_submit_guards(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE)
    with pytest.raises(TypeError, match="not both"):
        s.submit(_req(corpus, 0), k=5)
    with pytest.raises(ValueError, match="dense"):
        s.submit(SearchRequest(dense=np.zeros((1, 4), np.float32)))
    with pytest.raises(ValueError, match="terms"):
        s.submit(SearchRequest())
    with pytest.raises(ValueError, match="zero-row"):
        s.submit(SearchRequest(terms=np.zeros((0, 5), np.int32),
                               weights_b=np.zeros((0, 5), np.float32),
                               weights_l=np.zeros((0, 5), np.float32)))


def test_zero_term_request_serves_as_noop_row(setup):
    """A 0-term query (everything filtered upstream) pads to an
    all-zero-weight row and returns the empty-queue sentinels — the
    historical server behavior, not a crash."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=0))
    h = s.submit(terms=np.zeros(0, np.int32),
                 weights_b=np.zeros(0, np.float32),
                 weights_l=np.zeros(0, np.float32), k=10)
    s.flush()
    resp = h.result()
    assert resp.ids.shape == (1, 10)
    assert not np.isnan(resp.scores).any()


def test_cache_entries_are_isolated_from_consumer_mutation(setup):
    """Mutating a delivered response (hit or miss) must not corrupt the
    cached entry other requests will be served from."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=8))
    h1 = s.submit(_req(corpus, 0))
    s.flush()
    expect = h1.result().ids.copy()
    expect_tiles = h1.result().stats["tiles_visited"].copy()
    h1.result().ids[:] = -7                  # consumer scribbles (miss path)
    h1.result().ks[:] = 1
    tiles = h1.result().stats["tiles_visited"]
    if tiles.flags.writeable:                # read-only is isolation too
        tiles[:] = -1.0
    h2 = s.submit(_req(corpus, 0))
    assert h2.cached
    np.testing.assert_array_equal(h2.result().ids, expect)
    np.testing.assert_array_equal(h2.result().ks, [10])
    np.testing.assert_array_equal(h2.result().stats["tiles_visited"],
                                  expect_tiles)
    h2.result().ids[:] = -8                  # consumer scribbles (hit path)
    h2.result().ks[:] = 2
    h3 = s.submit(_req(corpus, 0))
    np.testing.assert_array_equal(h3.result().ids, expect)
    np.testing.assert_array_equal(h3.result().ks, [10])


def test_oversized_request_rejected_at_submit(setup):
    """A multi-row request larger than max_batch would retrace the jit
    per distinct size; the scheduler refuses it up front."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2))
    with pytest.raises(ValueError, match="max_batch"):
        s.submit(SearchRequest(terms=corpus.queries[:3],
                               weights_b=corpus.q_weights_b[:3],
                               weights_l=corpus.q_weights_l[:3], k=10))


def test_batch_failure_fails_handles_instead_of_hanging(setup):
    """A dispatch-time error (here: a bad engine opt surfacing at lazy
    Retriever.open) must resolve the affected handles with the
    exception, not strand them forever."""
    corpus, index = setup
    policy = RoutingPolicy((route("all", None, "batched", bogus_opt=1),))
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=0),
                                routing=policy)
    h = s.submit(_req(corpus, 0))
    with pytest.raises(TypeError, match="bogus_opt"):
        s.flush()
    assert h.done()
    with pytest.raises(TypeError, match="bogus_opt"):
        h.result()
    assert s.stats()["failed"] == 1 and s.stats()["completed"] == 0


# -- the acceptance parity: mixed-k, mixed-length stream ----------------------

@pytest.mark.parametrize("engine,opts", [
    ("batched", {}), ("kernel", {}), ("sharded", {"n_shards": 2})])
def test_mixed_stream_matches_per_request_calls(setup, engine, opts):
    """Every handle of a mixed-k (5/10/100), mixed-length (3/5-term)
    stream resolves to exactly what a per-request Retriever.search on
    the serving route's engine configuration returns (rank-safe)."""
    corpus, index = setup
    policy = _two_class_policy(engine, **opts)
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, pad_terms=LONG, cache_size=0),
        routing=policy)
    stream = [(i, (SHORT, LONG)[i % 2], (5, 10, 100)[i % 3])
              for i in range(12)]
    handles = [s.submit(_req(corpus, i, qlen, k)) for i, qlen, k in stream]
    s.flush()
    refs = {}
    for h, (i, qlen, k) in zip(handles, stream):
        assert h.route == ("short" if qlen == SHORT else "long")
        resp = h.result()
        if h.route not in refs:
            rt = policy.by_name(h.route)
            refs[h.route] = Retriever.open(index, RANK_SAFE,
                                           engine=rt.engine, **rt.opts())
        ref = refs[h.route].search(
            terms=corpus.queries[i:i + 1, :qlen],
            weights_b=corpus.q_weights_b[i:i + 1, :qlen],
            weights_l=corpus.q_weights_l[i:i + 1, :qlen], k=k)
        np.testing.assert_array_equal(resp.ids, ref.ids,
                                      err_msg=f"{engine} req {i}")
        np.testing.assert_array_equal(resp.scores, ref.scores,
                                      err_msg=f"{engine} req {i}")


def test_one_compile_per_bucket_times_class(setup):
    """Batches of any fill level retrace nothing once a (k-bucket x
    length-class) group has compiled — the padded static shapes are the
    whole compile key."""
    from repro.core.traversal import _retrieve_batched_impl
    corpus, _ = setup
    # fresh tile_size -> cold jit-cache rows for this test alone
    index = build_index(corpus.merged("scaled"), tile_size=64)
    s = AsyncRetrievalScheduler(
        index, twolevel.fast(),
        SchedulerConfig(max_batch=4, pad_terms=LONG, cache_size=0),
        routing=_two_class_policy("batched"))
    # warm all four (bucket x class) groups with full batches
    for i in range(8):
        qlen = SHORT if i % 2 == 0 else LONG
        s.submit(_req(corpus, i, qlen, k=10 if i < 4 else 100))
    s.flush()
    n0 = _retrieve_batched_impl._cache_size()
    # same groups at every other fill level and k mix: zero new entries
    for i, k in enumerate((5, 8, 10, 42, 100)):
        s.submit(_req(corpus, i, SHORT if i % 2 else LONG, k=k))
        s.flush()   # fill levels 1, 1, 1, ... (padded to max_batch)
    for i in range(3):
        s.submit(_req(corpus, i, SHORT, k=9))
    s.flush()       # fill level 3
    assert _retrieve_batched_impl._cache_size() == n0


def test_multi_row_request_is_atomic(setup):
    """A [3, Nq] request with per-row k rides one batch and slices back
    per-row; stats rows match the request's rows."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=8, cache_size=0))
    ks = [5, 10, 7]
    h = s.submit(SearchRequest(terms=corpus.queries[:3],
                               weights_b=corpus.q_weights_b[:3],
                               weights_l=corpus.q_weights_l[:3], k=ks))
    s.flush()
    resp = h.result()
    ref = Retriever.open(index, RANK_SAFE).search(
        terms=corpus.queries[:3], weights_b=corpus.q_weights_b[:3],
        weights_l=corpus.q_weights_l[:3], k=ks)
    np.testing.assert_array_equal(resp.ids, ref.ids)
    np.testing.assert_array_equal(resp.scores, ref.scores)
    np.testing.assert_array_equal(resp.ks, ks)
    assert resp.stats["tiles_visited"].shape == (3,)


def test_threshold_factor_override_is_grouped_and_honored(setup):
    # pad_terms matches the query width: zero-width padding is a no-op
    # only above threshold, and factor=1.5 over-prunes past that
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, pad_terms=LONG, cache_size=0))
    h = s.submit(_req(corpus, 0, threshold_factor=1.5))
    s.flush()
    ref = Retriever.open(index, RANK_SAFE).search(
        terms=corpus.queries[:1], weights_b=corpus.q_weights_b[:1],
        weights_l=corpus.q_weights_l[:1], k=10, threshold_factor=1.5)
    np.testing.assert_array_equal(h.result().ids, ref.ids)
    np.testing.assert_array_equal(h.result().scores, ref.scores)


# -- routing ------------------------------------------------------------------

def test_routing_policy_validation():
    with pytest.raises(ValueError, match="catch-all"):
        RoutingPolicy((route("a", 4),))
    with pytest.raises(ValueError, match="catch-all"):
        RoutingPolicy((route("a"), route("b", 4), route("c")))
    with pytest.raises(ValueError, match="ascend"):
        RoutingPolicy((route("a", 8), route("b", 4), route("c")))
    with pytest.raises(ValueError, match="duplicate"):
        RoutingPolicy((route("a", 4), route("a")))
    with pytest.raises(ValueError, match="at least one"):
        RoutingPolicy(())


def test_table8_policy_classification():
    p = table8_policy(short_max_len=4)
    assert p.classify(0).name == "short"
    assert p.classify(4).name == "short"
    assert p.classify(5).name == "long"
    assert p.by_name("short").pad_terms == 4
    with pytest.raises(KeyError, match="nope"):
        p.by_name("nope")


def test_query_length_counts_live_terms_only():
    assert query_length([1.0, 0.0, 2.0], [0.0, 0.0, 1.0]) == 2
    assert query_length([0.0, 0.0], [0.0, 0.0]) == 0


def test_policy_fingerprint_tracks_routes_and_params():
    a = table8_policy().fingerprint(twolevel.fast())
    assert a == table8_policy().fingerprint(twolevel.fast())
    assert a != table8_policy().fingerprint(twolevel.gti())
    assert a != table8_policy(short_max_len=2).fingerprint(twolevel.fast())
    assert a != single_route().fingerprint(twolevel.fast())


def test_scheduler_routes_by_live_length_and_reports_stats(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE, SchedulerConfig(max_batch=4, cache_size=0),
        routing=_two_class_policy("batched"))
    s.submit(_req(corpus, 0, SHORT))
    s.submit(_req(corpus, 1, LONG))
    # zero-weight padding does not change the class: a LONG-length query
    # whose tail weights are zero classifies as short
    wb, wl = corpus.q_weights_b[2].copy(), corpus.q_weights_l[2].copy()
    wb[SHORT:] = 0.0
    wl[SHORT:] = 0.0
    h = s.submit(SearchRequest(terms=corpus.queries[2], weights_b=wb,
                               weights_l=wl, k=10))
    s.flush()
    assert h.route == "short"
    st = s.stats()
    assert st["requests_by_route"] == {"short": 2, "long": 1}
    assert st["batches"] == 2 and st["completed"] == 3
    assert set(st["batches_by_group"]) == {"k10/short", "k10/long"}


# -- response cache -----------------------------------------------------------

def test_cache_hit_completes_at_submit(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=8))
    h1 = s.submit(_req(corpus, 0))
    s.flush()
    h2 = s.submit(_req(corpus, 0))
    assert h2.done() and h2.cached          # zero-service-time path
    assert h2.result().latency_ms == 0.0
    assert h2.latency_ms >= 0
    np.testing.assert_array_equal(h2.result().ids, h1.result().ids)
    np.testing.assert_array_equal(h2.result().scores, h1.result().scores)
    st = s.stats()
    assert st["cache_hits"] == 1 and st["cache_misses"] == 1
    assert st["completed"] == 2 and st["batches"] == 1


def test_cache_respects_depth_and_evicts_lru(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=2))
    s.submit(_req(corpus, 0))
    s.flush()
    # same query, different k in the same bucket: a different cache key,
    # served fresh — and both depths then coexist as entries
    h = s.submit(_req(corpus, 0, k=7))
    assert not h.done()
    s.flush()
    assert s.submit(_req(corpus, 0, k=7)).cached
    assert s.submit(_req(corpus, 0, k=10)).cached
    # two newer fingerprints evict both query-0 depths from a 2-entry cache
    s.submit(_req(corpus, 1))
    s.submit(_req(corpus, 2))
    s.flush()
    h2 = s.submit(_req(corpus, 0, k=7))
    assert not h2.done()
    s.flush()
    assert s.stats()["cache_entries"] == 2
    s.cache_clear()
    assert s.stats()["cache_entries"] == 0


def test_cache_key_includes_threshold_factor(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=8))
    s.submit(_req(corpus, 0))
    s.flush()
    h = s.submit(_req(corpus, 0, threshold_factor=1.5))
    assert not h.done()                      # different policy knob: miss
    s.flush()
    assert s.stats()["cache_hits"] == 0


# -- priorities ---------------------------------------------------------------

def test_priority_orders_dispatch_within_group(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=0))
    hs = {p: s.submit(_req(corpus, p), priority=p) for p in (2, 0, 3, 1)}
    s.flush()
    # batches of two: priorities {0, 1} dispatch before {2, 3}
    assert hs[0].t_done == hs[1].t_done
    assert hs[2].t_done == hs[3].t_done
    assert hs[1].t_done < hs[2].t_done


# -- threaded mode ------------------------------------------------------------

def test_threaded_mode_serves_without_explicit_poll(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, max_wait_ms=1.0, cache_size=0))
    with s:
        assert s.is_running()
        h = s.submit(_req(corpus, 0))
        resp = h.result(timeout=120.0)
    assert not s.is_running()
    assert resp.ids.shape == (1, 10)
    ref = Retriever.open(index, RANK_SAFE).search(
        terms=corpus.queries[:1], weights_b=corpus.q_weights_b[:1],
        weights_l=corpus.q_weights_l[:1], k=10)
    np.testing.assert_array_equal(resp.ids, ref.ids)


def test_result_timeout_raises(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE)
    s.start()    # worker running -> result() will not self-flush
    try:
        # a request that cannot be admitted: worker waits on max_wait,
        # so an immediate tiny timeout fires first
        h = s.submit(_req(corpus, 0), now=1e12)   # deadline far future
        with pytest.raises(TimeoutError, match="not served"):
            h.result(timeout=0.01)
    finally:
        s.close()


# -- run_workload -------------------------------------------------------------

def test_run_workload_zero_service_cache_path(setup):
    """A workload served mostly from the cache keeps finite, clamped
    latency accounting (the zero-service-time path)."""
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=32))
    for i in range(4):   # warm the cache with the distinct queries
        s.submit(_req(corpus, i))
    s.flush()
    stats = run_workload(s, [_req(corpus, i % 4) for i in range(16)],
                         qps=500.0)
    assert stats["n"] == 16
    assert stats["cache_hits"] == 16
    assert np.isfinite(stats["mrt_ms"]) and stats["mrt_ms"] >= 0.0
    assert stats["p99_ms"] >= stats["p50_ms"] >= 0.0


def test_run_workload_survives_partial_route_failure(setup):
    """One broken route fails its own requests (handles resolve with the
    error, counted in stats) while the rest of the stream is still
    served and measured."""
    corpus, index = setup
    policy = RoutingPolicy((
        route("short", SHORT, "batched", bogus_opt=1),   # breaks at open
        route("long", None, "batched")))
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0),
                                routing=policy)
    reqs = [SearchRequest(terms=corpus.queries[i, :(SHORT, LONG)[i % 2]],
                          weights_b=corpus.q_weights_b[i, :(SHORT, LONG)[i % 2]],
                          weights_l=corpus.q_weights_l[i, :(SHORT, LONG)[i % 2]],
                          k=10)
            for i in range(8)]
    stats = run_workload(s, reqs, qps=5000.0)
    assert stats["failed"] == 4 and stats["completed"] == 4
    assert stats["n"] == 4                     # only served requests
    assert np.isfinite(stats["mrt_ms"])
    # a healthy handle's result() self-flush must not surface the broken
    # route's error: submit one of each, resolve the healthy one first
    h_bad = s.submit(reqs[0])                  # short -> broken route
    h_ok = s.submit(reqs[1])                   # long  -> healthy route
    resp = h_ok.result()                       # flushes both groups
    assert resp.ids.shape == (1, 10)
    with pytest.raises(TypeError, match="bogus_opt"):
        h_bad.result()


def test_run_workload_empty(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE)
    stats = run_workload(s, [], qps=100.0)
    assert stats["n"] == 0 and math.isnan(stats["mrt_ms"])
    assert stats["qps_achieved"] == 0.0


# -- the deprecated server shim -----------------------------------------------

def test_retrieval_server_warns_and_matches_retriever(setup):
    corpus, index = setup
    with pytest.warns(DeprecationWarning, match="AsyncRetrievalScheduler"):
        srv = RetrievalServer(index, twolevel.fast(),
                              ServerConfig(max_batch=4))
    for i in range(4):
        srv.submit(Request(corpus.queries[i], corpus.q_weights_b[i],
                           corpus.q_weights_l[i]), now=float(i))
    srv._flush()
    ref = Retriever.open(index, twolevel.fast()).search(
        terms=corpus.queries[:4], weights_b=corpus.q_weights_b[:4],
        weights_l=corpus.q_weights_l[:4], k=10)
    got_ids = np.stack([r.ids for r in srv.completed])
    got_scores = np.stack([r.scores for r in srv.completed])
    np.testing.assert_array_equal(got_ids, ref.ids)
    np.testing.assert_array_equal(got_scores, ref.scores)
    assert all(r.t_done > 0 for r in srv.completed)


def test_request_latency_nan_while_in_flight():
    r = Request(np.array([1], np.int32), np.ones(1, np.float32),
                np.ones(1, np.float32))
    assert math.isnan(r.latency_ms)          # t_done unset: no garbage
    r.t_enqueue = 5.0
    assert math.isnan(r.latency_ms)
    r.t_done = 5.5
    assert r.latency_ms == pytest.approx(500.0)


# -- _pad_queries fast path ---------------------------------------------------

def test_pad_queries_rectangular_passthrough():
    from repro.retrieval.retriever import _pad_queries
    t = np.arange(6, dtype=np.int32).reshape(2, 3)
    wb = np.ones((2, 3), np.float32)
    wl = np.ones((2, 3), np.float32)
    ot, ob, ol = _pad_queries(t, wb, wl)
    assert ot is t and ob is wb and ol is wl     # no copy, no loop


def test_pad_queries_device_arrays_stay_on_device():
    import jax.numpy as jnp
    from repro.retrieval.retriever import _pad_queries
    t = jnp.arange(6, dtype=jnp.int32).reshape(2, 3)
    wb = jnp.ones((2, 3), jnp.float32)
    wl = jnp.ones((2, 3), jnp.float32)
    ot, ob, ol = _pad_queries(t, wb, wl)
    assert ot is t and ob is wb and ol is wl     # no host round-trip
