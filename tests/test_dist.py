"""repro.dist unit tests: sharding policy placement, collective identities,
compression accounting, straggler recovery. Multi-device collective
correctness runs in a subprocess with 8 fake devices (slow lane) — the main
test process must keep its single CPU device."""
import json
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compression import (CompressionConfig, compress_with_feedback,
                                    compression_ratio, init_error_feedback,
                                    topk_sparsify)
from repro.dist.sharding import (activation_rules, input_shardings,
                                 opt_shardings, param_shardings)
from repro.dist.straggler import StragglerConfig, StragglerMonitor


# -- sharding policy ---------------------------------------------------------

@pytest.fixture(scope="module")
def mesh11():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_activation_rules_tp_vs_fsdp(mesh11):
    tp = activation_rules(mesh11, "tp")
    assert tp.batch == ("data",) and tp.heads == "model"
    assert tp.vocab == "model" and not tp.gather_weights
    fsdp = activation_rules(mesh11, "fsdp")
    assert fsdp.batch == ("data",) and fsdp.heads is None
    assert fsdp.gather_weights


def test_lm_param_placement(mesh11):
    from repro.configs import get_arch
    from repro.launch.steps import state_specs
    arch = get_arch("internlm2-1.8b")
    cfg = arch.config()
    st = state_specs(arch, "train_4k", cfg)
    p_sh = param_shardings("lm", cfg, mesh11, st["params"], "tp")
    specs = {k: v.spec for k, v in p_sh["layers"].items()}
    # projections shard the head/ffn dim; return projections the
    # contraction dim; norms replicate
    assert specs["wq"][-1] == "model" and specs["w_up"][-1] == "model"
    assert specs["wo"][-2] == "model" and specs["w_down"][-2] == "model"
    assert all(s is None for s in specs["attn_norm"])
    assert p_sh["embed"].spec[0] == "model"
    # optimizer moments inherit the param layout; step replicates
    o_sh = opt_shardings(p_sh)
    assert o_sh["m"]["layers"]["wq"].spec == specs["wq"]
    assert o_sh["step"].spec == jax.sharding.PartitionSpec()


def test_fsdp_shards_params_over_all_axes(mesh11):
    from repro.configs import get_arch
    from repro.launch.steps import state_specs
    arch = get_arch("internlm2-1.8b")
    cfg = arch.config()
    st = state_specs(arch, "train_4k", cfg)
    p_sh = param_shardings("lm", cfg, mesh11, st["params"], "fsdp")
    spec = p_sh["layers"]["wq"].spec
    assert ("data", "model") in tuple(spec), spec


def test_input_shardings_batch_and_candidates(mesh11):
    from repro.configs import get_arch
    from repro.configs.shapes import input_specs
    arch = get_arch("two-tower-retrieval")
    cfg = arch.config()
    spec = input_specs(arch, "retrieval_cand", cfg)
    in_sh = input_shardings("recsys", cfg, mesh11, spec, "tp")
    # 1M-candidate axis spans the whole mesh; the 1-row user replicates
    assert in_sh["cand_emb"].spec[0] == ("data", "model")
    assert all(s is None for s in in_sh["user_feats"].spec)


def test_non_divisible_dims_replicate():
    """Placement rules at a real tp_size=2 (pure functions, no mesh):
    dims that the axis size does not divide must replicate."""
    from repro.dist.sharding import _lm_param_spec, _recsys_param_spec
    P = jax.sharding.PartitionSpec
    # 13 % 2 != 0: projection replicates instead of sharding unevenly
    assert _lm_param_spec("wq", (7, 13), "model", 2) == P(None, None)
    assert _lm_param_spec("wq", (7, 16), "model", 2) == P(None, "model")
    # contraction-dim rule for the return projection
    assert _lm_param_spec("wo", (4, 16, 13), "model", 2) == \
        P(None, "model", None)
    assert _lm_param_spec("embed", (92543, 64), "model", 2) == P(None, None)
    # table rows shard only when divisible
    assert _recsys_param_spec("item_embed", (2_000_000, 128), "model", 2) \
        == P("model", None)
    assert _recsys_param_spec("item_embed", (2_000_001, 128), "model", 2) \
        == P(None, None)


# -- collectives (single device: identity) -----------------------------------

def test_collective_identities_single_device():
    from repro.dist.collectives import (hierarchical_all_reduce,
                                        reduce_scatter, ring_all_gather,
                                        ring_all_reduce)
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(12, dtype=jnp.float32).reshape(4, 3)
    for fn in (lambda v: ring_all_reduce(v, mesh, "data"),
               lambda v: reduce_scatter(v, mesh, "data"),
               lambda v: ring_all_gather(v, mesh, "data")):
        np.testing.assert_allclose(np.asarray(fn(x)), np.asarray(x))
    mesh2 = jax.make_mesh((1, 1), ("data", "model"))
    np.testing.assert_allclose(
        np.asarray(hierarchical_all_reduce(x, mesh2, "model", "data")),
        np.asarray(x))


_COLLECTIVE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.dist.collectives import (hierarchical_all_reduce,
                                        reduce_scatter, ring_all_gather,
                                        ring_all_reduce)
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((16, 5)), jnp.float32)
    out = {}
    # ring all-reduce over data: sum of the 4 contribution slices
    r = ring_all_reduce(x, mesh, "data")
    ref = np.asarray(x).reshape(4, 4, 5).sum(0)
    out["ring"] = float(np.abs(np.asarray(r) - ref).max())
    # hierarchical: intra-model then inter-data ring == sum of 8 slices
    h = hierarchical_all_reduce(x, mesh, "model", "data")
    ref8 = np.asarray(x).reshape(8, 2, 5).sum(0)
    out["hier"] = float(np.abs(np.asarray(h) - ref8).max())
    # reduce-scatter + all-gather round trip == all-reduce
    rs = reduce_scatter(x, mesh, "data")
    ag = ring_all_gather(rs, mesh, "data")
    out["rs_ag"] = float(np.abs(np.asarray(ag) - ref).max())
    # non-divisible contribution rows must be rejected, not duplicated
    try:
        reduce_scatter(x[:8], mesh, "data")  # 2 rows/device, 4-way axis
        out["rs_guard"] = "missing"
    except ValueError:
        out["rs_guard"] = "raised"
    print("RESULT:" + json.dumps(out))
""")


@pytest.mark.slow
def test_ring_collectives_multi_device_subprocess():
    res = subprocess.run([sys.executable, "-c", _COLLECTIVE_SCRIPT],
                         capture_output=True, text=True, timeout=560)
    assert res.returncode == 0, res.stderr[-3000:]
    line = [ln for ln in res.stdout.splitlines()
            if ln.startswith("RESULT:")][0]
    out = json.loads(line[len("RESULT:"):])
    assert out["ring"] < 1e-5, out
    assert out["hier"] < 1e-5, out
    assert out["rs_ag"] < 1e-5, out
    assert out["rs_guard"] == "raised", out


# -- compression -------------------------------------------------------------

def test_topk_sparsify_keeps_largest():
    g = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    out = np.asarray(topk_sparsify(g, 2))
    np.testing.assert_allclose(out, [0.0, -5.0, 0.0, 3.0, 0.0])


def test_compression_residual_bounded_every_step():
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.standard_normal((32, 16)), jnp.float32)}
    err = init_error_feedback(g)
    for _ in range(20):
        sent, err = compress_with_feedback(g, err)
        # error feedback holds only the int8 quantization residual
        assert float(jnp.abs(err["w"]).max()) < 0.05
        assert sent["w"].shape == g["w"].shape


def test_compression_bf16_cast_error_fed_back():
    """Low-precision gradients: the bf16 rounding of the transmitted value
    must enter the error feedback, or it accumulates uncorrected."""
    rng = np.random.default_rng(3)
    g32 = rng.standard_normal(512).astype(np.float32)
    g = {"w": jnp.asarray(g32, jnp.bfloat16)}
    err = init_error_feedback(g)
    total_true = np.zeros(512, np.float64)
    total_sent = np.zeros(512, np.float64)
    for _ in range(50):
        total_true += np.asarray(g["w"], np.float64)
        sent, err = compress_with_feedback(g, err)
        assert sent["w"].dtype == jnp.bfloat16
        total_sent += np.asarray(sent["w"], np.float64)
    assert np.abs(total_true - total_sent).max() < 0.1


def test_compression_is_jittable():
    g = {"w": jnp.ones(64)}
    err = init_error_feedback(g)
    sent, new_err = jax.jit(compress_with_feedback)(g, err)
    np.testing.assert_allclose(np.asarray(sent["w"] + new_err["w"]),
                               np.asarray(g["w"]), atol=1e-6)


def test_compression_ratio_scales_with_bits():
    g = {"w": jnp.ones(4096)}
    r8 = compression_ratio(g)
    r4 = compression_ratio(g, CompressionConfig(residual_bits=4))
    assert r4 > r8 > 3.5


# -- straggler ---------------------------------------------------------------

def test_straggler_recovers_after_speedup():
    mon = StragglerMonitor(4, 4, StragglerConfig(patience=2, evict_after=50))
    for step in range(6):
        out = mon.report(step, np.array([1.0, 1.0, 1.0, 4.0]))
    assert mon.degraded[3] and out["assignments"][3] == 2
    for step in range(6, 30):
        out = mon.report(step, np.array([1.0, 1.0, 1.0, 1.0]))
    assert not mon.degraded[3]
    assert out["assignments"][3] == 4            # restored
    assert out["assignments"].sum() == 16
    assert out["evict"] == []


def test_straggler_work_conserved_with_many_degraded():
    mon = StragglerMonitor(8, 4, StragglerConfig(patience=1, evict_after=99))
    d = np.ones(8)
    d[[2, 5, 6]] = 10.0
    for step in range(4):
        out = mon.report(step, d)
    assert out["assignments"].sum() == 32
    assert all(out["assignments"][i] == 2 for i in (2, 5, 6))
