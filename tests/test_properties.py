"""Property-based tests (hypothesis) for the paper's formal claims (§4.2).

Prop 1: any doc in the top-k of all three rankings R_alpha, R_beta, R_gamma
        is in 2GTI's output (engine + oracle).
Prop 2: with alpha=beta or beta=gamma, mean R_gamma-score of 2GTI's top-k
        >= that of the two-stage R2_{alpha,gamma} (oracle).
Plus structural invariants: threshold monotonicity, queue ordering.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install -e .[dev]); skipping, not failing")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import build_index, twolevel
from repro.core.oracle import daat_2gti, ranked_list, score_all_merged, two_stage
from repro.core.traversal import retrieve_batched
from repro.data import make_corpus

K = 8
GRID = [i / 20.0 for i in range(21)]


def _corpus(seed):
    return make_corpus("deepimpact_like", n_docs=512, n_terms=128,
                       n_queries=4, n_q_terms=4, n_rel=2,
                       avg_doc_terms=12, seed=seed)


def _unique_topk(merged, qt, qwb, qwl, x, k):
    """Top-k of R_x; returns None when the boundary is tied (paper assumes
    unique top-k subsets)."""
    s = score_all_merged(merged, qt, qwb, qwl, x)
    order = np.argsort(-s, kind="stable")
    if len(s) > k and abs(s[order[k - 1]] - s[order[k]]) < 1e-5:
        return None
    return set(int(d) for d in order[:k])


@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 50), alpha=st.sampled_from(GRID),
       beta=st.sampled_from(GRID), gamma=st.sampled_from([0.0, 0.05, 0.3]))
def test_prop1_triple_topk_membership(seed, alpha, beta, gamma):
    corpus = _corpus(seed)
    merged = corpus.merged("scaled")
    index = build_index(merged, tile_size=128, pad_multiple=128)
    p = twolevel.TwoLevelParams(alpha=alpha, beta=beta, gamma=gamma)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p, k=K)
    for qi in range(len(corpus.queries)):
        qt, qwb, qwl = (corpus.queries[qi], corpus.q_weights_b[qi],
                        corpus.q_weights_l[qi])
        tops = [_unique_topk(merged, qt, qwb, qwl, x, K)
                for x in (alpha, beta, gamma)]
        if any(t is None for t in tops):
            continue  # tie at the boundary: proposition precondition fails
        must_have = tops[0] & tops[1] & tops[2]
        got_engine = set(int(d) for d in res.ids[qi])
        assert must_have <= got_engine, (
            f"engine violated Prop 1: missing {must_have - got_engine}")
        ids_o, _, _ = daat_2gti(merged, qt, qwb, qwl, p, k=K)
        got_oracle = set(int(d) for d in ids_o)
        assert must_have <= got_oracle, (
            f"oracle violated Prop 1: missing {must_have - got_oracle}")


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 50), alpha=st.sampled_from(GRID),
       gamma=st.sampled_from([0.0, 0.05, 0.2]),
       tie=st.sampled_from(["alpha", "gamma"]))
def test_prop2_beats_two_stage(seed, alpha, gamma, tie):
    """alpha=beta or beta=gamma => mean R_gamma score of 2GTI >= R2."""
    corpus = _corpus(seed)
    merged = corpus.merged("scaled")
    beta = alpha if tie == "alpha" else gamma
    p = twolevel.TwoLevelParams(alpha=alpha, beta=beta, gamma=gamma)
    for qi in range(2):
        qt, qwb, qwl = (corpus.queries[qi], corpus.q_weights_b[qi],
                        corpus.q_weights_l[qi])
        ids_o, _, _ = daat_2gti(merged, qt, qwb, qwl, p, k=K)
        s = score_all_merged(merged, qt, qwb, qwl, gamma)
        ids_o = ids_o[ids_o >= 0]
        ids_2s, _ = two_stage(merged, qt, qwb, qwl, alpha, gamma, K)
        mean_2gti = float(s[ids_o].mean()) if len(ids_o) else 0.0
        mean_2s = float(s[ids_2s].mean()) if len(ids_2s) else 0.0
        assert mean_2gti >= mean_2s - 1e-4, (
            f"Prop 2 violated: {mean_2gti} < {mean_2s}")


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50),
       gamma=st.sampled_from([0.0, 0.1, 0.5, 1.0]))
def test_safe_config_equals_exhaustive(seed, gamma):
    """alpha=beta=gamma is rank-safe: engine == exhaustive top-k scores."""
    corpus = _corpus(seed)
    merged = corpus.merged("zero")
    index = build_index(merged, tile_size=128, pad_multiple=128)
    p = twolevel.original(gamma=gamma)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, p, k=K)
    for qi in range(len(corpus.queries)):
        _, vals = ranked_list(merged, corpus.queries[qi],
                              corpus.q_weights_b[qi],
                              corpus.q_weights_l[qi], gamma, K)
        np.testing.assert_allclose(res.scores[qi], vals, rtol=2e-4, atol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 50), fill=st.sampled_from(["zero", "one", "scaled"]))
def test_alignment_invariants(seed, fill):
    """Filling never alters existing BM25 weights and never drops postings."""
    corpus = _corpus(seed)
    m_zero = corpus.merged("zero")
    m_fill = corpus.merged(fill)
    assert m_fill.nnz == m_zero.nnz
    np.testing.assert_array_equal(m_fill.docids, m_zero.docids)
    np.testing.assert_allclose(m_fill.w_l, m_zero.w_l)
    existing = m_zero.w_b > 0
    np.testing.assert_allclose(m_fill.w_b[existing], m_zero.w_b[existing])
    assert np.all(m_fill.w_b[~existing] >= 0)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 50))
def test_result_sorted_and_unique(seed):
    corpus = _corpus(seed)
    merged = corpus.merged("scaled")
    index = build_index(merged, tile_size=128, pad_multiple=128)
    res = retrieve_batched(index, corpus.queries, corpus.q_weights_b,
                           corpus.q_weights_l, twolevel.fast(), k=K)
    for qi in range(len(corpus.queries)):
        sc = res.scores[qi]
        finite = sc[np.isfinite(sc)]
        assert np.all(np.diff(finite) <= 1e-6), "scores must be descending"
        ids = res.ids[qi]
        ids = ids[ids >= 0]
        assert len(set(ids.tolist())) == len(ids), "duplicate docids"
