"""Fault tolerance: deadlines, retries + hedging, breakers + degraded
mode, and generation-safe hot swap — all pinned on a **simulated
clock** (every scheduler/health entry point takes an explicit ``now``),
so none of these tests sleeps to make a fault happen.

The soak test at the bottom is the integration pin: a mixed-k Poisson
stream with injected failures and a mid-stream ``swap_index`` must lose
zero handles, keep the accounting invariant in every snapshot, and
never deliver a cross-generation response.
"""
import threading

import numpy as np
import pytest

from repro.core import build_index, twolevel
from repro.retrieval import SearchRequest
from repro.serve import (BREAKER_CLOSED, BREAKER_DEAD, BREAKER_HALF_OPEN,
                         BREAKER_OPEN, AsyncRetrievalScheduler,
                         DeadlineExceeded, Fault, FaultPlan, HealthConfig,
                         HealthMonitor, InjectedFault, ReplicaMap,
                         RetryPolicy, RoutingPolicy, SchedulerConfig,
                         SearchTimeout, delay_route, fail_batch,
                         kill_executor, poison_generation, route,
                         run_workload)

RANK_SAFE = twolevel.original(gamma=0.2)
SHORT = 3


@pytest.fixture(scope="module")
def setup(small_corpus):
    index = build_index(small_corpus.merged("scaled"), tile_size=256)
    return small_corpus, index


def _req(corpus, i, qlen=None, k=10, deadline_ms=None):
    q, wb, wl = (corpus.queries[i], corpus.q_weights_b[i],
                 corpus.q_weights_l[i])
    if qlen is not None:
        q, wb, wl = q[:qlen], wb[:qlen], wl[:qlen]
    return SearchRequest(terms=q, weights_b=wb, weights_l=wl, k=k,
                         deadline_ms=deadline_ms)


def _invariant(st) -> bool:
    return (st["submitted"] == st["completed"] + st["failed"] + st["shed"]
            + st["rejected"] + st["expired"] + st["pending"]
            + st["in_flight"])


def _drain(s, t, step=0.002, rounds=500):
    """Force-drain on the simulated clock, absorbing injected faults
    (each failing batch resolves its own handles)."""
    for _ in range(rounds):
        if not s.pending_count():
            return t
        picked = s._pick_batch(t, True)
        if picked is None:
            t += step
            continue
        try:
            s._execute(*picked, now=t)
        except InjectedFault:
            pass
        t += step
    raise AssertionError("drain did not terminate")


# -- deadlines ----------------------------------------------------------------

def test_deadline_sheds_expired_entry_at_pick(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))
    h = s.submit(_req(corpus, 0, deadline_ms=50.0), now=0.0)
    assert h.deadline_ms == 50.0
    # the budget ran out while queued: shed at pick time, never executed
    assert s._pick_batch(1.0, True) is None
    st = s.stats()
    assert st["expired"] == 1 and st["pending"] == 0
    assert st["batches"] == 0 and _invariant(st)
    with pytest.raises(DeadlineExceeded, match="expired before dispatch"):
        h.result()


def test_deadline_met_in_time_executes_normally(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))
    h = s.submit(_req(corpus, 0, deadline_ms=100.0), now=0.0)
    picked = s._pick_batch(0.02, True)
    assert picked is not None
    assert s._execute(*picked, now=0.02) == 1
    assert h.result().ids.shape == (1, 10)
    st = s.stats()
    assert st["expired"] == 0 and st["completed"] == 1 and _invariant(st)


def test_deadline_validation(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE)
    with pytest.raises(ValueError, match="deadline_ms"):
        s.submit(_req(corpus, 0, deadline_ms=0.0))
    with pytest.raises(TypeError, match="not both"):
        s.submit(_req(corpus, 0), deadline_ms=5.0)


def test_inflight_batch_carries_min_deadline_budget(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))
    s.submit(_req(corpus, 0, deadline_ms=100.0), now=0.0)
    s.submit(_req(corpus, 1, deadline_ms=40.0), now=0.0)
    key, batch = s._pick_batch(0.02, True)
    token = s._begin_batch(key, batch, None, now=0.02)
    # min remaining budget over the rows: 40ms deadline, 20ms elapsed
    assert s._inflight[token].budget_ms == pytest.approx(20.0)
    assert s._run_attempt(token, now=0.02) == 2


def test_run_workload_reports_goodput_next_to_qps(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))
    reqs = [_req(corpus, i % 4, deadline_ms=10_000.0) for i in range(6)]
    stats = run_workload(s, reqs, qps=1000.0)
    assert stats["n"] == stats["n_in_deadline"] == 6
    assert stats["goodput_qps"] > 0
    assert stats["goodput_qps"] <= stats["qps_achieved"] * 1.001


# -- retries ------------------------------------------------------------------

def test_retry_requeues_with_backoff_then_succeeds(setup):
    corpus, index = setup
    plan = FaultPlan([fail_batch(0)])
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0,
                        retry=RetryPolicy(max_attempts=3, backoff_ms=10.0,
                                          backoff_factor=2.0, jitter=0.0)),
        faults=plan)
    h = s.submit(_req(corpus, 0), now=0.0)
    picked = s._pick_batch(0.01, False)
    assert picked is not None
    # the injected failure requeues instead of raising or failing handles
    assert s._execute(*picked, now=0.01) == 0
    st = s.stats()
    assert st["retries"] == 1 and st["failed"] == 0 and _invariant(st)
    # backoff: invisible to pick before not_before (0.01 + 10ms)...
    assert s._pick_batch(0.015, False) is None
    assert s.next_deadline() == pytest.approx(0.02)
    # ...eligible again after it, and the retry succeeds (fault consumed)
    picked = s._pick_batch(0.021, False)
    assert picked is not None
    assert s._execute(*picked, now=0.021) == 1
    assert h.result().ids.shape == (1, 10)
    assert plan.fired == [("fail", None, 0, "all", 0)]


def test_retry_exhaustion_fails_handles_and_reraises(setup):
    corpus, index = setup
    plan = FaultPlan([Fault("fail", times=None)])   # every attempt fails
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0,
                        retry=RetryPolicy(max_attempts=2, backoff_ms=1.0,
                                          jitter=0.0)),
        faults=plan)
    h = s.submit(_req(corpus, 0), now=0.0)
    assert s._execute(*s._pick_batch(0.01, True), now=0.01) == 0
    with pytest.raises(InjectedFault):
        s._execute(*s._pick_batch(1.0, True), now=1.0)
    with pytest.raises(InjectedFault):
        h.result()
    st = s.stats()
    assert st["retries"] == 1 and st["failed"] == 1 and _invariant(st)


def test_non_retryable_fault_fails_fast(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0,
                        retry=RetryPolicy(max_attempts=5)),
        faults=FaultPlan([fail_batch(0, retryable=False)]))
    h = s.submit(_req(corpus, 0), now=0.0)
    with pytest.raises(InjectedFault):
        s._execute(*s._pick_batch(0.01, True), now=0.01)
    with pytest.raises(InjectedFault):
        h.result()
    st = s.stats()
    assert st["retries"] == 0 and st["failed"] == 1 and _invariant(st)


def test_retry_policy_backoff_is_deterministic():
    p = RetryPolicy(backoff_ms=100.0, backoff_factor=2.0, jitter=0.5,
                    seed=3)
    d = p.delay_ms(2, token=9)
    assert d == p.delay_ms(2, token=9)            # pure in (seed, token, a)
    assert 100.0 <= d <= 300.0                    # base 200 +- 50%
    assert p.delay_ms(2, token=10) != d
    assert p.delay_ms(3, token=9) != d
    exact = RetryPolicy(backoff_ms=10.0, backoff_factor=3.0, jitter=0.0)
    assert exact.delay_ms(1) == 10.0 and exact.delay_ms(3) == 90.0


def test_retry_policy_validation_and_retryable_predicate():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.5)
    with pytest.raises(ValueError, match="backoff"):
        RetryPolicy(backoff_factor=0.5)
    assert RetryPolicy.retryable(InjectedFault("x", retryable=True))
    assert not RetryPolicy.retryable(InjectedFault("x", retryable=False))
    assert RetryPolicy.retryable(TimeoutError())
    assert RetryPolicy.retryable(ConnectionResetError())
    assert not RetryPolicy.retryable(ValueError("deterministic"))


# -- breakers / health --------------------------------------------------------

def test_breaker_full_cycle_on_simulated_clock():
    hm = HealthMonitor(HealthConfig(failure_threshold=2, cooldown_ms=100.0))
    assert hm.state(0) == BREAKER_CLOSED and not hm.degraded()
    hm.record_failure(0, now=0.0)
    assert hm.state(0) == BREAKER_CLOSED          # below threshold
    hm.record_failure(0, now=0.01)
    assert hm.state(0) == BREAKER_OPEN and hm.degraded()
    assert not hm.allow(0, now=0.05)              # cooling down
    assert hm.allow(0, now=0.12)                  # half-open probe
    assert hm.state(0) == BREAKER_HALF_OPEN
    assert not hm.allow(0, now=0.13)              # one probe at a time
    hm.record_failure(0, now=0.14)                # probe failed: reopen
    assert hm.state(0) == BREAKER_OPEN
    assert not hm.allow(0, now=0.2)               # cooldown restarted
    assert hm.allow(0, now=0.25)                  # next probe
    hm.record_success(0, 5.0, now=0.26)           # probe won: close
    assert hm.state(0) == BREAKER_CLOSED and not hm.degraded()


def test_breaker_lost_probe_rearms_after_cooldown():
    hm = HealthMonitor(HealthConfig(failure_threshold=1, cooldown_ms=50.0))
    hm.record_failure(0, now=0.0)
    assert hm.allow(0, now=0.06)                  # probe taken...
    assert not hm.allow(0, now=0.07)              # ...and outstanding
    assert hm.allow(0, now=0.12)                  # lost probe self-heals


def test_dead_breaker_is_terminal():
    hm = HealthMonitor()
    hm.mark_dead(1)
    assert hm.state(1) == BREAKER_DEAD and hm.degraded()
    hm.record_success(1, 1.0, now=0.0)            # cannot resurrect
    assert not hm.allow(1, now=1e9)
    assert hm.snapshot()[1]["state"] == BREAKER_DEAD


def test_health_ewma_and_p99():
    hm = HealthMonitor(HealthConfig(ewma_decay=0.6))
    hm.record_success(0, 100.0, now=0.0)
    hm.record_success(0, 50.0, now=0.1)
    assert hm.snapshot()[0]["ewma_ms"] == pytest.approx(80.0)
    # exact-rank p99 over {100, 50}: the max sample, not interpolated
    assert hm.latency_p99_ms() == pytest.approx(100.0)
    assert HealthMonitor().latency_p99_ms(default=7.0) == 7.0


def test_degraded_pool_rewrites_route_to_fallback_lane(setup):
    corpus, index = setup
    policy = RoutingPolicy(
        (route("short", SHORT, pad_terms=SHORT, fallback="short_fast"),
         route("long", None)),
        fallback_routes=(route("short_fast", pad_terms=SHORT),))
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=2, cache_size=8,
                        health=HealthConfig(failure_threshold=2)),
        routing=policy)
    # trip executor 0's breaker: the pool is now degraded
    s.health.record_failure(0, now=0.0)
    s.health.record_failure(0, now=0.0)
    assert s.health.degraded()
    h = s.submit(_req(corpus, 0, qlen=SHORT), now=0.0)
    assert s._execute(*s._pick_batch(0.01, True), now=0.01) == 1
    resp = h.result()
    assert resp.degraded
    st = s.stats()
    assert st["degraded_batches"] == 1
    assert st["cache_entries"] == 0               # degraded: never cached
    # heal the breaker: same request now serves the primary lane + caches
    s.health.record_success(0, 1.0, now=0.02)
    assert not s.health.degraded()
    h2 = s.submit(_req(corpus, 0, qlen=SHORT), now=0.03)
    assert not h2.done()                          # no stale degraded hit
    s._execute(*s._pick_batch(0.04, True), now=0.04)
    assert not h2.result().degraded
    assert s.stats()["cache_entries"] == 1
    h3 = s.submit(_req(corpus, 0, qlen=SHORT), now=0.05)
    assert h3.done() and h3.cached


def test_router_fallback_validation():
    with pytest.raises(ValueError, match="unknown route"):
        RoutingPolicy((route("a", None, fallback="ghost"),))
    with pytest.raises(ValueError, match="chains"):
        RoutingPolicy((route("a", None, fallback="b"),),
                      fallback_routes=(route("b", fallback="c"),
                                       route("c")))
    with pytest.raises(ValueError, match="pad_terms"):
        RoutingPolicy((route("a", None, pad_terms=4, fallback="b"),),
                      fallback_routes=(route("b", pad_terms=8),))


# -- hedging ------------------------------------------------------------------

def test_hedge_first_result_wins_loser_cancelled_at_queue(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, hedge_ms=5.0))
    h = s.submit(_req(corpus, 0), now=0.0)
    key, batch = s._pick_batch(0.01, True)
    token = s._begin_batch(key, batch, 0, now=0.01)
    assert s.hedge_due(now=0.012) == []           # younger than hedge_ms
    assert s.hedge_due(now=0.02, exclude_executor=0) == []   # own batch
    assert s.hedge_due(now=0.02, exclude_executor=1) == [token]
    assert s.hedge_due(now=0.03) == []            # one hedge per batch
    assert s.stats()["hedges"] == 1
    # winner delivers; the loser's token is gone -> cancelled at queue
    assert s._run_attempt(token, now=0.04, executor_id=1) == 1
    assert h.result().ids.shape == (1, 10)
    assert s._run_attempt(token, now=0.05, executor_id=0) == 0
    st = s.stats()
    assert st["hedges_cancelled"] == 1 and st["completed"] == 1
    assert st["batches"] == 1 and _invariant(st)


def test_hedge_loser_finishing_after_winner_counts_wasted(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, hedge_ms=5.0))
    h = s.submit(_req(corpus, 0), now=0.0)
    key, batch = s._pick_batch(0.01, True)
    token = s._begin_batch(key, batch, 0, now=0.01)
    assert s.hedge_due(now=0.02, exclude_executor=1) == [token]
    assert s._run_attempt(token, now=0.03, executor_id=1) == 1
    # the loser executed to completion but the record is gone: its
    # delivery is discarded and counted as wasted work
    assert s._deliver(token, None, 1, 0, degraded=False,
                      executor_id=0, t_done=0.04) == 0
    st = s.stats()
    assert st["hedges_wasted"] == 1 and st["completed"] == 1
    assert h.done() and _invariant(st)


def test_hedge_failure_while_other_attempt_races(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, hedge_ms=5.0))
    h = s.submit(_req(corpus, 0), now=0.0)
    key, batch = s._pick_batch(0.01, True)
    token = s._begin_batch(key, batch, 0, now=0.01)
    assert s.hedge_due(now=0.02, exclude_executor=1) == [token]
    # one racer fails while the other is still running: absorbed
    assert s._attempt_failed(token, InjectedFault("x"), 0, now=0.03) == 0
    assert s.stats()["hedge_failures"] == 1
    assert token in s._inflight
    assert s._run_attempt(token, now=0.04, executor_id=1) == 1
    assert h.result().ids.shape == (1, 10)
    assert _invariant(s.stats())


def test_hedge_delay_derived_from_latency_p99(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, hedge_ms=0.0,
                        hedge_from_p99=True))
    s.submit(_req(corpus, 0), now=0.0)
    key, batch = s._pick_batch(0.01, True)
    token = s._begin_batch(key, batch, 0, now=0.01)
    assert s.hedge_due(now=10.0) == []            # no samples, default 0
    s.health.record_success(1, 50.0, now=0.01)    # p99 is now 50ms
    assert s.hedge_due(now=0.04) == []            # 30ms in flight < p99
    assert s.hedge_due(now=0.07) == [token]       # 60ms in flight > p99
    assert s._run_attempt(token, now=0.08) == 1


# -- hot swap / generations ---------------------------------------------------

def test_swap_index_bumps_generation_and_purges_stale_cache(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=8))
    h = s.submit(_req(corpus, 0), now=0.0)
    s._execute(*s._pick_batch(0.01, True), now=0.01)
    assert h.result().generation == 0
    assert s.stats()["cache_entries"] == 1
    gen = s.swap_index(
        build_index(corpus.merged("scaled"), tile_size=256), warm=False)
    assert gen == s.generation == 1
    st = s.stats()
    assert st["swaps"] == 1 and st["cache_gen_evictions"] == 1
    assert st["cache_entries"] == 0               # no stale hits possible
    h2 = s.submit(_req(corpus, 0), now=0.02)
    assert not h2.done()                          # the old entry is gone
    s._execute(*s._pick_batch(0.03, True), now=0.03)
    assert h2.result().generation == 1
    # the rebuilt index is identical content: results must agree
    np.testing.assert_array_equal(h.result().ids, h2.result().ids)


def test_stale_generation_response_is_delivered_but_never_cached(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=1, cache_size=8))
    h = s.submit(_req(corpus, 0), now=0.0)
    key, batch = s._pick_batch(0.01, True)
    token = s._begin_batch(key, batch, None, now=0.01)
    retr0 = s._retriever("all")                   # gen-0 master
    s.swap_index(build_index(corpus.merged("scaled"), tile_size=256),
                 warm=False)
    # the in-flight batch finishes on its pre-swap retriever: the caller
    # still gets an answer (stamped gen 0), but it must not be cached
    resp, n_real, n_pad = s._search_batch(retr0, batch, None)
    assert s._deliver(token, resp, n_real, n_pad, degraded=False,
                      executor_id=None, t_done=0.02) == 1
    assert h.result().generation == 0
    st = s.stats()
    assert st["generation"] == 1 and st["cache_entries"] == 0
    assert _invariant(st)


def test_replica_map_rebuilds_after_swap(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=2, cache_size=0))
    rm = ReplicaMap({"all": s._retriever("all").replicate()}, generation=0)
    retr, gen = s._resolve_retriever("all", rm)
    assert gen == 0 and retr is rm["all"]
    s.swap_index(build_index(corpus.merged("scaled"), tile_size=256),
                 warm=False)
    retr, gen = s._resolve_retriever("all", rm)
    assert gen == 1 and rm.generation == 1
    assert retr.generation == 1                   # rebuilt from new master


# -- cache lifecycle ----------------------------------------------------------

def test_cache_ttl_evicts_on_lookup(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=2, cache_size=8, cache_ttl_s=1.0))
    s.submit(_req(corpus, 0), now=0.0)
    s._execute(*s._pick_batch(0.01, True), now=0.0)
    h_fresh = s.submit(_req(corpus, 0), now=0.5)
    assert h_fresh.done() and h_fresh.cached      # within TTL
    h_stale = s.submit(_req(corpus, 0), now=2.0)
    assert not h_stale.done()                     # over-age: evicted
    st = s.stats()
    assert st["cache_ttl_evictions"] == 1 and st["cache_entries"] == 0
    s._execute(*s._pick_batch(2.1, True), now=2.1)
    assert h_stale.result().ids.shape == (1, 10)
    h_again = s.submit(_req(corpus, 0), now=2.5)
    assert h_again.done() and h_again.cached      # re-stored at 2.1


def test_cache_second_sight_admission(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=2, cache_size=8,
                        cache_admission="second_sight"))
    s.submit(_req(corpus, 0), now=0.0)
    s._execute(*s._pick_batch(0.01, True), now=0.01)
    st = s.stats()
    # first sighting: ghost-listed, not stored
    assert st["cache_admission_skips"] == 1 and st["cache_entries"] == 0
    h2 = s.submit(_req(corpus, 0), now=0.02)
    assert not h2.done()
    s._execute(*s._pick_batch(0.03, True), now=0.03)
    assert s.stats()["cache_entries"] == 1        # second sighting: stored
    h3 = s.submit(_req(corpus, 0), now=0.04)
    assert h3.done() and h3.cached
    with pytest.raises(ValueError, match="cache_admission"):
        AsyncRetrievalScheduler(
            index, RANK_SAFE, SchedulerConfig(cache_admission="bogus"))


# -- liveness / timeouts ------------------------------------------------------

def test_search_timeout_carries_routing_context(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))
    from repro.serve.scheduler import SearchHandle
    h = SearchHandle(s, "long", 100, 0, 0.0)      # never submitted
    with pytest.raises(SearchTimeout, match="not served") as ei:
        h.result(timeout=0.01)
    assert ei.value.route == "long" and ei.value.k_bucket == 100
    assert isinstance(ei.value, TimeoutError)


def test_scheduler_survives_and_reports_worker_death(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE)
    s._record_executor_death(None, RuntimeError("boom"))
    st = s.stats()
    assert st["executor_deaths"] == 1
    assert st["dead_executors"] == {-1: "RuntimeError('boom')"}
    h = s.submit(_req(corpus, 0), now=0.0)        # still serves
    s.flush()
    assert h.result().ids.shape == (1, 10)


def test_pool_survives_injected_executor_death(setup):
    corpus, index = setup
    plan = FaultPlan([kill_executor(0)])
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=0, executors=2),
        faults=plan)
    with s:
        handles = [s.submit(_req(corpus, i % 8)) for i in range(12)]
        for h in handles:
            assert h.result(timeout=120.0).ids.shape == (1, 10)
    st = s.stats()
    assert st["completed"] == 12 and st["executor_deaths"] == 1
    assert 0 in st["dead_executors"]
    assert st["breakers"][0]["state"] == BREAKER_DEAD
    assert ("die", 0, None, None, None) in plan.fired
    assert _invariant(st)


def test_delivery_notifies_condition_waiters(setup):
    corpus, index = setup
    s = AsyncRetrievalScheduler(index, RANK_SAFE,
                                SchedulerConfig(max_batch=4, cache_size=0))

    class SpyCond(threading.Condition):
        notifies = 0

        def notify_all(self):
            SpyCond.notifies += 1
            super().notify_all()

    s._cond = SpyCond(s._lock)                    # shares the real lock
    h = s.submit(_req(corpus, 0), now=0.0)
    before = SpyCond.notifies
    s._execute(*s._pick_batch(0.01, True), now=0.01)
    # pick frees admission space and delivery wakes result()/blocked
    # submitters — both must notify, not rely on a poll timeout
    assert SpyCond.notifies >= before + 2
    assert h.done()


# -- fault plan ---------------------------------------------------------------

def test_fault_plan_validation_and_virtual_delay():
    with pytest.raises(ValueError, match="kind"):
        Fault("nope")
    plan = FaultPlan([delay_route("all", 7.5)])
    d = plan.on_batch(executor_id=None, batch_index=0, global_index=0,
                      route="all", generation=0)
    assert d == 7.5                               # virtual: no sleep
    assert plan.fired == [("delay", None, 0, "all", 0)]


def test_fault_plan_firing_log_is_deterministic(setup):
    corpus, index = setup

    def drive(plan):
        s = AsyncRetrievalScheduler(
            index, RANK_SAFE,
            SchedulerConfig(max_batch=2, cache_size=0,
                            retry=RetryPolicy(max_attempts=2,
                                              backoff_ms=1.0, jitter=0.0)),
            faults=plan)
        for i in range(4):
            s.submit(_req(corpus, i), now=0.001 * i)
        _drain(s, 0.1, step=0.01)
        return s.stats()

    p1 = FaultPlan([fail_batch(1), delay_route(None, 3.0, times=2)])
    p2 = FaultPlan([fail_batch(1), delay_route(None, 3.0, times=2)])
    st1, st2 = drive(p1), drive(p2)
    assert p1.fired == p2.fired
    assert [f[0] for f in p1.fired] == ["delay", "fail", "delay"]
    assert st1 == st2


# -- soak ---------------------------------------------------------------------

def test_fault_soak_mixed_stream_with_midstream_swap(setup):
    """The integration pin: a simulated-clock Poisson stream of mixed-k
    requests with injected failures (retryable, poison, delays), a
    too-tight deadline, and a mid-stream index hot-swap. Zero lost
    handles, the accounting invariant in every snapshot, and no
    cross-generation response."""
    corpus, index = setup
    plan = FaultPlan([poison_generation(0, times=1),
                      fail_batch(2), fail_batch(6),
                      delay_route(None, 5.0, times=4)])
    s = AsyncRetrievalScheduler(
        index, RANK_SAFE,
        SchedulerConfig(max_batch=4, cache_size=16,
                        retry=RetryPolicy(max_attempts=3, backoff_ms=1.0,
                                          jitter=0.0)),
        faults=plan)
    rng = np.random.default_rng(42)
    arrivals = np.cumsum(rng.exponential(1.0 / 400.0, 36))
    handles, pre_swap_done = [], set()
    t = 0.0
    for i in range(36):
        t = float(arrivals[i])
        if i == 18:
            # mid-stream hot swap; everything completed so far is gen 0
            pre_swap_done = {id(h) for h in handles
                             if h.done() and h._exception is None}
            assert all(h._response.generation == 0 for h in handles
                       if id(h) in pre_swap_done)
            assert s.swap_index(
                build_index(corpus.merged("scaled"), tile_size=256),
                warm=False) == 1
        if i == 9:
            # a hopeless deadline in its own micro-batch group (unique
            # query + threshold_factor, so no cache hit and no ride-along
            # on another group's dispatch): must expire, not execute
            handles.append(s.submit(SearchRequest(
                terms=corpus.queries[9], weights_b=corpus.q_weights_b[9],
                weights_l=corpus.q_weights_l[9], k=100,
                threshold_factor=0.9, deadline_ms=0.05), now=t))
        else:
            dl = 150.0 if i % 3 == 0 else None
            handles.append(s.submit(
                _req(corpus, i % 8, qlen=SHORT if i % 2 else None,
                     k=(10, 100)[i % 2], deadline_ms=dl), now=t))
        while True:
            picked = s._pick_batch(t, False)
            if picked is None:
                break
            try:
                s._execute(*picked, now=t)
            except InjectedFault:
                pass
        assert _invariant(s.stats())
    _drain(s, t)
    st = s.stats()
    assert all(h.done() for h in handles)         # zero lost handles
    assert st["pending"] == 0 and st["in_flight"] == 0
    assert (st["completed"] + st["failed"] + st["expired"]
            == st["submitted"] == 36)
    assert st["expired"] >= 1                     # the 0.05 ms deadline
    assert st["failed"] >= 1                      # the gen-0 poison
    assert st["retries"] >= 1 and st["swaps"] == 1
    assert _invariant(st)
    # generation safety: pre-swap completions are gen 0, everything
    # delivered after the flip (including cache hits) is gen 1
    for h in handles:
        if h._exception is not None:
            continue
        expect = 0 if id(h) in pre_swap_done else 1
        assert h._response.generation == expect
