"""Per-architecture smoke tests: reduced config, one real step on CPU,
shape + finiteness checks. The full configs are exercised via the dry-run
only (ShapeDtypeStruct; no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.launch.steps import (adapt_config, init_fn, make_serve_step,
                                make_train_step, smoke_batch)
from repro.models.transformer import NO_RULES
from repro.train.optimizer import AdamWConfig, adamw_init

TRAIN_SHAPE = {"lm": "train_4k", "gnn": "molecule", "recsys": "train_batch"}


def _finite(tree):
    return all(np.all(np.isfinite(np.asarray(x, np.float32)))
               for x in jax.tree_util.tree_leaves(tree))


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    arch = get_arch(arch_id)
    shape = TRAIN_SHAPE[arch.family]
    cfg = adapt_config(arch, shape, arch.smoke())
    params = init_fn(arch, shape, cfg)(jax.random.PRNGKey(0))
    state = {"params": params, "opt": adamw_init(params)}
    batch = smoke_batch(arch, shape, cfg)
    step = jax.jit(make_train_step(arch, shape, cfg, NO_RULES,
                                   AdamWConfig(warmup_steps=1,
                                               total_steps=10)))
    state, metrics = step(state, batch["batch"] if "batch" in batch
                          else batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["grad_norm"]) > 0
    assert _finite(state["params"])
    # one more step: loss should change (params actually updated)
    state2, metrics2 = step(state, batch["batch"] if "batch" in batch
                            else batch)
    assert float(metrics2["loss"]) != float(metrics["loss"])


@pytest.mark.parametrize("arch_id,shape", [
    (a, s) for a in ARCH_IDS for s in get_arch(a).shapes
    if s not in (TRAIN_SHAPE[get_arch(a).family],)
    and get_arch(a).family != "gnn"])
def test_smoke_serve_step(arch_id, shape):
    arch = get_arch(arch_id)
    cfg = adapt_config(arch, shape, arch.smoke())
    params = init_fn(arch, shape, cfg)(jax.random.PRNGKey(1))
    batch = smoke_batch(arch, shape, cfg)
    step = jax.jit(make_serve_step(arch, shape, cfg, NO_RULES))
    out = step(params, *batch.values())
    leaves = jax.tree_util.tree_leaves(out)
    assert leaves, "serve step returned nothing"
    for leaf in leaves:
        arr = np.asarray(leaf)
        if arr.dtype.kind == "f":
            assert np.all(np.isfinite(arr)), (arch_id, shape)


@pytest.mark.parametrize("arch_id,shape", [
    ("schnet", "full_graph_sm"), ("schnet", "minibatch_lg"),
    ("schnet", "ogb_products")])
def test_smoke_gnn_graph_cells(arch_id, shape):
    arch = get_arch(arch_id)
    cfg = adapt_config(arch, shape, arch.smoke())
    params = init_fn(arch, shape, cfg)(jax.random.PRNGKey(2))
    state = {"params": params, "opt": adamw_init(params)}
    batch = smoke_batch(arch, shape, cfg)
    step = jax.jit(make_train_step(arch, shape, cfg, NO_RULES,
                                   AdamWConfig(warmup_steps=1,
                                               total_steps=10)))
    state, metrics = step(state, batch["batch"])
    assert np.isfinite(float(metrics["loss"]))


def test_all_cells_enumerate_40():
    from repro.configs import all_cells
    cells = list(all_cells())
    assert len(cells) == 40
    assert len(set(cells)) == 40


def test_int8_kv_cache_decode_close_to_fp():
    """int8 KV cache (beyond-paper decode optimization) must track the
    full-precision decode distribution closely."""
    import dataclasses
    from repro.models.transformer import (TransformerConfig, decode_step,
                                          init_params, prefill)
    cfg = TransformerConfig(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
                            d_ff=128, vocab=211, compute_dtype=jnp.float32,
                            remat=False)
    cfg_q = dataclasses.replace(cfg, kv_quant=True)
    params = init_params(cfg, jax.random.PRNGKey(3))
    toks = jax.random.randint(jax.random.PRNGKey(4), (2, 24), 0, cfg.vocab)
    lg, cache = prefill(cfg, params, toks[:, :16], max_len=24)
    lgq, cacheq = prefill(cfg_q, params, toks[:, :16], max_len=24)
    assert cacheq["k"].dtype == jnp.int8
    l1, _ = decode_step(cfg, params, toks[:, 16:17], cache, jnp.int32(16))
    l2, _ = decode_step(cfg_q, params, toks[:, 16:17], cacheq, jnp.int32(16))
    p1 = np.asarray(jax.nn.softmax(l1[:, 0]))
    p2 = np.asarray(jax.nn.softmax(l2[:, 0]))
    assert np.max(np.abs(p1 - p2)) < 0.05
    assert np.array_equal(p1.argmax(-1), p2.argmax(-1))
