"""Substrate tests: optimizer, checkpoint/resume, trainer fault tolerance,
gradient compression, straggler monitor, collectives, serving engine."""
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.compression import (compress_with_feedback, compression_ratio,
                                    init_error_feedback)
from repro.dist.straggler import StragglerConfig, StragglerMonitor
from repro.train import checkpoint
from repro.train.optimizer import (AdamWConfig, adamw_init, adamw_update,
                                   cosine_schedule, flop_regularizer)
from repro.train.trainer import SimulatedFailure, Trainer, TrainerConfig


# -- optimizer ---------------------------------------------------------------

def _quad_loss(params, batch):
    return jnp.sum((params["w"] - batch["t"]) ** 2)


def test_adamw_converges_quadratic():
    params = {"w": jnp.zeros(8)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=300,
                      weight_decay=0.0, schedule="constant")
    batch = {"t": jnp.arange(8, dtype=jnp.float32) / 8.0}
    for _ in range(300):
        g = jax.grad(_quad_loss)(params, batch)
        params, state, m = adamw_update(cfg, g, state, params)
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(batch["t"]), atol=1e-2)


def test_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(cosine_schedule(cfg, jnp.int32(10))) == pytest.approx(1.0)
    assert float(cosine_schedule(cfg, jnp.int32(100))) < 1e-6


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    state = adamw_init(params)
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, schedule="constant")
    g = {"w": jnp.full(4, 1e6)}
    _, _, metrics = adamw_update(cfg, g, state, params)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


def test_flop_regularizer_positive_and_sparser_is_smaller():
    dense = jnp.ones((4, 16))
    sparse = dense.at[:, 8:].set(0.0)
    assert float(flop_regularizer(sparse)) < float(flop_regularizer(dense))


# -- checkpoint ----------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    state = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
             "nested": {"b": jnp.int32(7)}}
    checkpoint.save(tmp_path, 5, state)
    assert checkpoint.latest_step(tmp_path) == 5
    out = checkpoint.restore(tmp_path, 5, state)
    np.testing.assert_array_equal(out["a"], state["a"])
    assert int(out["nested"]["b"]) == 7


def test_checkpoint_keep_n_and_torn_write(tmp_path):
    state = {"a": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        checkpoint.save(tmp_path, s, state, keep=2)
    steps = sorted(p.name for p in pathlib.Path(tmp_path).glob("step_*"))
    assert steps == ["step_00000003", "step_00000004"]
    # torn checkpoint (no manifest) must be ignored by latest_step
    torn = pathlib.Path(tmp_path) / "step_00000009"
    torn.mkdir()
    assert checkpoint.latest_step(tmp_path) == 4


def test_checkpoint_elastic_reshard(tmp_path):
    """Save unsharded, restore with explicit shardings (1-device mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    state = {"w": jnp.arange(8, dtype=jnp.float32)}
    checkpoint.save(tmp_path, 1, state)
    sh = {"w": NamedSharding(mesh, P("data"))}
    out = checkpoint.restore(tmp_path, 1, state, sh)
    np.testing.assert_array_equal(out["w"], state["w"])
    assert out["w"].sharding == sh["w"]


# -- trainer -------------------------------------------------------------------

def _mk_trainer(tmp_path, total=30, fail_at=None, microbatches=1,
                compression=False):
    def loss_fn(params, batch):
        pred = batch["x"] @ params["w"]
        return jnp.mean((pred - batch["y"]) ** 2)

    def init_params(key):
        return {"w": jax.random.normal(key, (4,)) * 0.1}

    def data_fn(step):
        rng = np.random.default_rng(step)
        x = rng.standard_normal((8 * microbatches, 4)).astype(np.float32)
        w_true = np.array([1.0, -2.0, 0.5, 3.0], np.float32)
        return {"x": jnp.asarray(x), "y": jnp.asarray(x @ w_true)}

    cfg = TrainerConfig(total_steps=total, ckpt_every=10,
                        out_dir=str(tmp_path), fail_at_step=fail_at,
                        microbatches=microbatches,
                        grad_compression=compression, log_every=5)
    opt = AdamWConfig(lr=0.05, warmup_steps=0, schedule="constant",
                      weight_decay=0.0)
    return Trainer(loss_fn, init_params, data_fn, cfg, opt)


def test_trainer_loss_decreases(tmp_path):
    res = _mk_trainer(tmp_path, total=60).run()
    first = np.mean(res["losses"][:5])
    last = np.mean(res["losses"][-5:])
    assert last < first * 0.5, (first, last)


def test_trainer_crash_resume_equivalence(tmp_path):
    """Crash at step 17, resume: final params == uninterrupted run."""
    t1 = _mk_trainer(tmp_path / "a", total=30, fail_at=17)
    with pytest.raises(SimulatedFailure):
        t1.run()
    t1b = _mk_trainer(tmp_path / "a", total=30)   # resumes from step 10
    res_resumed = t1b.run()
    res_clean = _mk_trainer(tmp_path / "b", total=30).run()
    np.testing.assert_allclose(
        np.asarray(res_resumed["state"]["params"]["w"]),
        np.asarray(res_clean["state"]["params"]["w"]), rtol=1e-5)


def test_trainer_microbatch_equivalence(tmp_path):
    """Grad accumulation over 4 microbatches == single big batch."""
    r1 = _mk_trainer(tmp_path / "m1", total=40, microbatches=1).run()
    r4 = _mk_trainer(tmp_path / "m4", total=40, microbatches=4).run()
    # same total batch content per step (data_fn scales with microbatches);
    # identical data for m=1 vs m=4 isn't guaranteed, so just check both
    # converge and metrics files exist
    assert np.mean(r1["losses"][-5:]) < np.mean(r1["losses"][:5])
    assert np.mean(r4["losses"][-5:]) < np.mean(r4["losses"][:5])
    assert (pathlib.Path(tmp_path / "m4") / "metrics.jsonl").exists()


def test_trainer_with_compression_converges(tmp_path):
    res = _mk_trainer(tmp_path, total=60, compression=True).run()
    assert np.mean(res["losses"][-5:]) < np.mean(res["losses"][:5]) * 0.5


# -- compression ---------------------------------------------------------------

def test_error_feedback_mean_error_vanishes():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal(256), jnp.float32)}
    err = init_error_feedback(g)
    total_true = np.zeros(256)
    total_sent = np.zeros(256)
    for _ in range(50):
        total_true += np.asarray(g["w"])
        sent, err = compress_with_feedback(g, err)
        total_sent += np.asarray(sent["w"])
    # cumulative compressed updates track cumulative true gradients
    resid = np.abs(total_true - total_sent).max()
    assert resid < 0.1, resid
    assert compression_ratio(g) > 3.5


# -- straggler -------------------------------------------------------------------

def test_straggler_detection_and_rebalance():
    mon = StragglerMonitor(n_workers=8, microbatches_per_worker=4,
                           cfg=StragglerConfig(patience=2, evict_after=50))
    rng = np.random.default_rng(0)
    for step in range(10):
        d = rng.normal(1.0, 0.02, 8)
        d[3] = 3.0  # worker 3 is slow
        out = mon.report(step, d)
    assert mon.degraded[3]
    assert out["assignments"][3] == 2            # relieved
    assert out["assignments"].sum() == 32        # work conserved
    assert out["assignments"][np.argmin(d)] >= 4  # fastest picked up slack


def test_straggler_eviction_signal():
    mon = StragglerMonitor(4, 2, StragglerConfig(patience=1, evict_after=5))
    for step in range(10):
        d = np.array([1.0, 1.0, 1.0, 9.0])
        out = mon.report(step, d)
    assert 3 in out["evict"]


# -- collectives (1-device mesh semantics) --------------------------------------

def test_hierarchical_all_reduce_single_device():
    from repro.dist.collectives import ring_all_reduce
    mesh = jax.make_mesh((1,), ("data",))
    x = jnp.arange(8, dtype=jnp.float32)
    out = ring_all_reduce(x, mesh, "data")
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


# -- serving ---------------------------------------------------------------------

def test_retrieval_server_latency_accounting(small_corpus):
    from repro.core import build_index, twolevel
    from repro.serve import Request, RetrievalServer, ServerConfig
    corpus = small_corpus
    index = build_index(corpus.merged("scaled"), tile_size=256)
    srv = RetrievalServer(index, twolevel.fast(),
                          ServerConfig(max_batch=4, max_wait_ms=1.0))
    reqs = [Request(corpus.queries[i % len(corpus.queries)],
                    corpus.q_weights_b[i % len(corpus.queries)],
                    corpus.q_weights_l[i % len(corpus.queries)])
            for i in range(12)]
    stats = srv.run_workload(reqs, qps=500.0)
    assert stats["n"] == 12
    assert stats["p99_ms"] >= stats["mrt_ms"] > 0
    assert all(r.ids is not None for r in srv.completed)
