"""Table 6 analogue (BEIR zero-shot suite): a battery of six synthetic
datasets (3 alignment regimes x 2 seeds) — methods are run with FIXED
hyperparameters (no per-dataset tuning = the zero-shot condition)."""
from __future__ import annotations

import numpy as np

from .common import METHODS, emit, run_method

SUITE = [(p, s) for p in ("splade_like", "unicoil_like", "deepimpact_like")
         for s in (0, 1)]


def run(out) -> None:
    agg = {m: {"ndcg": [], "mrt": []} for m in ("org", "gti", "2gti_fast")}
    for preset, seed in SUITE:
        for method in agg:
            fill = "zero" if method == "gti" else "scaled"
            r = run_method(preset, fill, METHODS[method](), seed=seed)
            agg[method]["ndcg"].append(r["ndcg"])
            agg[method]["mrt"].append(r["mrt_ms"])
            out(emit(f"table6/{preset}_s{seed}/{method}", r["mrt_ms"],
                     {"ndcg": r["ndcg"], "recall": r["recall"]}))
    base = np.mean(agg["org"]["mrt"])
    for method, v in agg.items():
        out(emit(f"table6/average/{method}", float(np.mean(v["mrt"])),
                 {"ndcg": float(np.mean(v["ndcg"])),
                  "speedup_vs_org": base / np.mean(v["mrt"])}))
