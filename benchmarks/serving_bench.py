"""Poisson-load serving benchmark for the async scheduler.

    PYTHONPATH=src python -m benchmarks.serving_bench [--out PATH]

Drives a mixed-k (10/100), mixed-length (3/12-term) request stream
through ``AsyncRetrievalScheduler`` under three serving policies and
writes ``BENCH_serving.json`` (repo root by default):

  - ``baseline``      one route, full-scan batched engine, no cache —
                      the PR-3 ``RetrievalServer`` regime;
  - ``routed``        Table-8 query-length routing (short queries ->
                      fine-grained chunked traversal, long -> coarser
                      chunks) — also groups micro-batches by length
                      class, so a batch's while_loop trip count tracks
                      its own class instead of the slowest mixed row;
  - ``routed_cached`` the same policy plus the LRU response cache (the
                      stream repeats queries, as real traffic does).

Each config records QPS/MRT/P99 plus the scheduler's cache-hit and
routing counters. Jit caches are warmed by a discarded scheduler with
identical routes before timing, so MRT measures serving, not
compilation. The corpus is tiny and seeded; numbers are stable enough
to diff across PRs (``make bench-smoke`` is the CI entry).
"""
from __future__ import annotations

import argparse
import json
import pathlib

from repro.core import build_index, twolevel
from repro.data import make_corpus
from repro.serve import (AsyncRetrievalScheduler, SchedulerConfig,
                         mixed_request_stream, run_workload, single_route,
                         table8_policy)

try:  # package-relative when driven by benchmarks.run
    from .common import emit
except ImportError:  # python -m benchmarks.serving_bench
    from benchmarks.common import emit

N_DOCS = 4096
N_TERMS = 1024
N_QUERIES = 32
TILE = 128
SHORT_LEN = 3          # live terms of the "short" half of the stream
N_REQUESTS = 160
QPS = 100.0            # saturating: MRT reflects serving capacity, not queue noise
MAX_WAIT_MS = 100.0    # long enough for micro-batches to actually form
MAX_BATCH = 8
K_POOL = (10, 100)     # two k-buckets in flight at once

CONFIGS = (
    ("baseline", lambda: single_route("batched"), 0),
    ("routed", table8_policy, 0),
    ("routed_cached", table8_policy, 256),
)


def _requests(corpus, n: int) -> list:
    """The shared mixed stream (``serve.mixed_request_stream``): every
    (length-class x k-bucket) group stays continuously populated."""
    return mixed_request_stream(corpus, n, short_len=SHORT_LEN,
                                k_pool=K_POOL)


def collect() -> dict:
    corpus = make_corpus("splade_like", n_docs=N_DOCS, n_terms=N_TERMS,
                         n_queries=N_QUERIES, n_q_terms=12, seed=0)
    index = build_index(corpus.merged("scaled"), tile_size=TILE)
    params = twolevel.fast().replace(schedule="impact")
    configs = {}
    for name, routing, cache in CONFIGS:
        def fresh():
            return AsyncRetrievalScheduler(
                index, params,
                SchedulerConfig(max_batch=MAX_BATCH,
                                max_wait_ms=MAX_WAIT_MS,
                                cache_size=cache),
                routing=routing())
        # warm every (k-bucket x length-class) jit entry on a throwaway
        # scheduler (the compile caches are global), then time fresh
        run_workload(fresh(), _requests(corpus, 4 * MAX_BATCH), qps=1e6)
        stats = run_workload(fresh(), _requests(corpus, N_REQUESTS),
                             qps=QPS, seed=3)
        configs[name] = {
            "n": stats["n"], "qps_offered": QPS,
            "qps_achieved": round(stats["qps_achieved"], 2),
            "mrt_ms": round(stats["mrt_ms"], 3),
            "p50_ms": round(stats["p50_ms"], 3),
            "p99_ms": round(stats["p99_ms"], 3),
            "batches": stats["batches"],
            "cache_hits": stats["cache_hits"],
            "cache_misses": stats["cache_misses"],
            "requests_by_route": stats["requests_by_route"],
            "batches_by_group": stats["batches_by_group"],
        }
    return {"meta": {"corpus": "splade_like", "n_docs": N_DOCS,
                     "n_terms": N_TERMS, "n_queries": N_QUERIES,
                     "tile_size": TILE, "n_requests": N_REQUESTS,
                     "short_len": SHORT_LEN, "k_pool": list(K_POOL),
                     "max_batch": MAX_BATCH,
                     "p99_note": f"p99_ms over {N_REQUESTS} requests is a "
                                 "true percentile (n >= 100)"},
            "configs": configs}


def run(out) -> None:
    data = collect()
    for name, row in data["configs"].items():
        out(emit(f"serving/{name}", row["mrt_ms"],
                 {k: v for k, v in row.items()
                  if k not in ("mrt_ms", "requests_by_route",
                               "batches_by_group")}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_serving.json)")
    args = ap.parse_args()
    path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_serving.json")
    data = collect()
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    base = data["configs"]["baseline"]["mrt_ms"]
    for name, row in data["configs"].items():
        hits = row["cache_hits"]
        print(f"{name:14s} MRT={row['mrt_ms']:8.2f}ms "
              f"P99={row['p99_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"cache={hits}/{hits + row['cache_misses']} "
              f"vs-baseline={row['mrt_ms'] / base:5.2f}x")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
