"""Poisson-load serving benchmark for the async scheduler.

    PYTHONPATH=src python -m benchmarks.serving_bench [--out PATH]

Drives a mixed-k (10/100), mixed-length (3/12-term) request stream
through ``AsyncRetrievalScheduler`` under three serving policies and
writes ``BENCH_serving.json`` (repo root by default):

  - ``baseline``      one route, full-scan batched engine, no cache —
                      the PR-3 ``RetrievalServer`` regime;
  - ``routed``        Table-8 query-length routing (short queries ->
                      fine-grained chunked traversal, long -> coarser
                      chunks) — also groups micro-batches by length
                      class, so a batch's while_loop trip count tracks
                      its own class instead of the slowest mixed row;
  - ``routed_cached`` the same policy plus the LRU response cache (the
                      stream repeats queries, as real traffic does).

then records the **cost-sorted dispatch lanes** (ROADMAP scheduler
intelligence (a)): a traced run over a single chunked route fits an
``obs.cost.CostModel`` from its own spans (query features -> realized
``chunks_dispatched``), and the same stream then replays unsorted vs
``sort_batches_by_cost=True`` — batches ordered by predicted chunk
count so the while_loop's max-over-batch trip count hugs the mean.
The lanes replay as a *burst* (every request queued up front) rather
than Poisson arrivals: with a deep queue, dispatch order is the only
lever, and Poisson sleep jitter (±15% MRT run-to-run at this
saturation) would otherwise swamp the few-percent sorting effect.
Per-query results are batch-composition independent (pinned by test),
so the lanes differ only in latency. The fitted model's R² and the
cost lanes' metrics-registry snapshots land in ``meta``;

then sweeps the **executor pool** (1/2/4/8 workers, bounded admission
with load-shedding and priority aging) over the same stream — the
QPS-vs-executors curve — and finally records the **degraded-mode
lane**: the same deadline-carrying stream on a 2-worker pool, healthy
vs with executor 0 persistently fault-injected (every one of its
batches fails, retried on the survivor; the breaker opens and routes
rewrite to the fallback lane). Goodput (in-deadline completions/s) is
reported next to QPS for both, which is the pair the deadline
machinery exists for. Every config records QPS/MRT/P99 plus the
scheduler's cache-hit, routing, admission (admitted/shed/rejected) and
per-executor counters, and the grid warmup time. Jit caches are warmed
before timing (a discarded scheduler for the sync configs; the pool's
own startup warmup for the sweep), so MRT measures serving, not
compilation. The corpus is tiny and seeded; numbers are stable enough
to diff across PRs (``make bench-smoke`` is the CI entry).

Executor scaling is compute-bound: the pool multiplies throughput only
up to the host's free cores (XLA's CPU backend keeps a worker busy for
a batch's whole service time). ``meta.host_cores`` records what this
run had — on a 1-core host the curve is flat by construction, which is
exactly what the curve is for: like-for-like comparison across hosts.
"""
from __future__ import annotations

import argparse
import os
import pathlib

from repro.core import build_index, twolevel
from repro.data import make_corpus
from repro.obs import (CostModel, MetricsRegistry, Tracer,
                       json_snapshot)
from repro.serve import (AsyncRetrievalScheduler, Fault, FaultPlan,
                         HealthConfig, RetryPolicy, RoutingPolicy,
                         SchedulerConfig, mixed_request_stream, route,
                         run_workload, single_route, table8_policy)

try:  # package-relative when driven by benchmarks.run
    from .common import emit, write_bench_json
except ImportError:  # python -m benchmarks.serving_bench
    from benchmarks.common import emit, write_bench_json

N_DOCS = 4096
N_TERMS = 1024
N_QUERIES = 32
TILE = 128
SHORT_LEN = 3          # live terms of the "short" half of the stream
N_REQUESTS = 160
QPS = 100.0            # saturating: MRT reflects serving capacity, not queue noise
MAX_WAIT_MS = 100.0    # long enough for micro-batches to actually form
MAX_BATCH = 8
K_POOL = (10, 100)     # two k-buckets in flight at once

CONFIGS = (
    ("baseline", lambda: single_route("batched"), 0),
    ("routed", table8_policy, 0),
    ("routed_cached", table8_policy, 256),
)
EXECUTOR_SWEEP = (1, 2, 4, 8)
COST_CHUNK_TILES = 2   # fine exit grid: chunk count varies with query
COST_QPS = 1e6         # burst replay: the whole stream queues up front
ADMISSION_LIMIT = 8 * MAX_BATCH   # bounded queue: saturation sheds,
ADMISSION_POLICY = "shed"         # so the median stays bounded and the
AGING_MS = 50.0                   # tail (P99) absorbs the overload
DEADLINE_MS = 500.0               # degraded-mode lane: goodput budget
DEGRADED_EXECUTORS = 2            # one faulted, one survivor


def _fallback_policy() -> RoutingPolicy:
    """Table-8 routing plus a cheaper fallback lane per class (coarser
    chunked traversal, same padded width), for the degraded-mode lane:
    while the faulted executor's breaker is open, the router rewrites
    both classes to their fallback and responses come back flagged."""
    return RoutingPolicy(
        (route("short", 4, "batched", pad_terms=4, traversal="chunked",
               chunk_tiles=2, fallback="short_fast"),
         route("long", None, "batched", fallback="long_fast")),
        fallback_routes=(
            route("short_fast", None, "batched", pad_terms=4,
                  traversal="chunked", chunk_tiles=8),
            route("long_fast", None, "batched", traversal="chunked",
                  chunk_tiles=16)))


def _requests(corpus, n: int) -> list:
    """The shared mixed stream (``serve.mixed_request_stream``): every
    (length-class x k-bucket) group stays continuously populated."""
    return mixed_request_stream(corpus, n, short_len=SHORT_LEN,
                                k_pool=K_POOL)


def _cost_routing():
    """One chunked route over the whole stream: short and long queries
    share a group, so dispatch *order* is the only lever — exactly what
    the cost-sorted lanes measure."""
    return single_route("batched", traversal="chunked",
                        chunk_tiles=COST_CHUNK_TILES)


def _cost_dispatch(index, params, corpus):
    """Fit a chunk-count model from a traced run, then replay the same
    stream unsorted vs cost-sorted. Returns (model, lanes, obs
    snapshots). The unsorted lane runs with no tracer and no sorting,
    so it also pays no featurization — the honest control. Both lanes
    replay the stream as a burst (``COST_QPS``): Poisson arrival jitter
    at the saturating rate is larger than the sorting effect itself."""
    tracer = Tracer(capacity=8192)
    traced = AsyncRetrievalScheduler(
        index, params,
        SchedulerConfig(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                        cache_size=0, tracer=tracer),
        routing=_cost_routing())
    # this run also warms the chunked route's jit entries, so the timed
    # lanes below never pay a trace
    run_workload(traced, _requests(corpus, N_REQUESTS), qps=COST_QPS,
                 seed=3)
    model = CostModel.fit_from_traces(tracer.export())
    lanes, snapshots = {}, {}
    for lane, sort in (("unsorted", False), ("cost_sorted", True)):
        registry = MetricsRegistry()
        sched = AsyncRetrievalScheduler(
            index, params,
            SchedulerConfig(max_batch=MAX_BATCH,
                            max_wait_ms=MAX_WAIT_MS, cache_size=0,
                            metrics=registry,
                            cost_model=model if sort else None,
                            sort_batches_by_cost=sort),
            routing=_cost_routing())
        stats = run_workload(sched, _requests(corpus, N_REQUESTS),
                             qps=COST_QPS, seed=3)
        row = _row(stats, executors=0)
        row["qps_offered"] = COST_QPS
        row["queue_wait_ms"] = stats["queue_wait_ms"]
        row["service_ms"] = stats["service_ms"]
        lanes[lane] = row
        snapshots[lane] = json_snapshot(registry)
    return model, lanes, snapshots


def collect() -> dict:
    corpus = make_corpus("splade_like", n_docs=N_DOCS, n_terms=N_TERMS,
                         n_queries=N_QUERIES, n_q_terms=12, seed=0)
    index = build_index(corpus.merged("scaled"), tile_size=TILE)
    params = twolevel.fast().replace(schedule="impact")
    configs = {}
    for name, routing, cache in CONFIGS:
        def fresh():
            return AsyncRetrievalScheduler(
                index, params,
                SchedulerConfig(max_batch=MAX_BATCH,
                                max_wait_ms=MAX_WAIT_MS,
                                cache_size=cache),
                routing=routing())
        # warm every (k-bucket x length-class) jit entry on a throwaway
        # scheduler (the compile caches are global), then time fresh
        run_workload(fresh(), _requests(corpus, 4 * MAX_BATCH), qps=1e6)
        stats = run_workload(fresh(), _requests(corpus, N_REQUESTS),
                             qps=QPS, seed=3)
        configs[name] = _row(stats, executors=0)
    cost_model, cost_lanes, cost_obs = _cost_dispatch(index, params,
                                                      corpus)
    sweep = {}
    for n_exec in EXECUTOR_SWEEP:
        sched = AsyncRetrievalScheduler(
            index, params,
            SchedulerConfig(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                            cache_size=0, executors=n_exec,
                            admission_limit=ADMISSION_LIMIT,
                            admission_policy=ADMISSION_POLICY,
                            aging_ms=AGING_MS),
            routing=table8_policy())
        # the pool warms the routing grid at start(), inside the context
        # manager but before run_workload's clock starts
        with sched:
            stats = run_workload(sched, _requests(corpus, N_REQUESTS),
                                 qps=QPS, seed=3)
        sweep[f"executors_{n_exec}"] = _row(stats, executors=n_exec)
    degraded = {}
    for lane, faulted in (("healthy", False), ("faulted", True)):
        faults = None
        if faulted:
            # every batch attempt on executor 0 fails (retryable): the
            # retry policy requeues onto the survivor, the breaker opens
            # after the threshold, and routes rewrite to the fallback
            faults = FaultPlan(
                [Fault("fail", executor=0, times=None)], wall=True)
        sched = AsyncRetrievalScheduler(
            index, params,
            SchedulerConfig(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                            cache_size=0, executors=DEGRADED_EXECUTORS,
                            retry=RetryPolicy(max_attempts=4,
                                              backoff_ms=2.0),
                            health=HealthConfig(failure_threshold=3,
                                                cooldown_ms=200.0)),
            routing=_fallback_policy(), faults=faults)
        with sched:
            stats = run_workload(
                sched, mixed_request_stream(
                    corpus, N_REQUESTS, short_len=SHORT_LEN,
                    k_pool=K_POOL, deadline_ms=DEADLINE_MS),
                qps=QPS, seed=3)
        row = _row(stats, executors=DEGRADED_EXECUTORS)
        row.update({
            "deadline_ms": DEADLINE_MS,
            "expired": stats["expired"], "failed": stats["failed"],
            "retries": stats["retries"],
            "degraded_batches": stats["degraded_batches"],
            "breakers": {str(k): v["state"]
                         for k, v in stats["breakers"].items()}})
        degraded[lane] = row
    return {"meta": {"corpus": "splade_like", "n_docs": N_DOCS,
                     "n_terms": N_TERMS, "n_queries": N_QUERIES,
                     "tile_size": TILE, "n_requests": N_REQUESTS,
                     "short_len": SHORT_LEN, "k_pool": list(K_POOL),
                     "max_batch": MAX_BATCH,
                     "admission_limit": ADMISSION_LIMIT,
                     "admission_policy": ADMISSION_POLICY,
                     "aging_ms": AGING_MS,
                     "host_cores": os.cpu_count(),
                     "scaling_note": "executor scaling is bounded by "
                                     "host_cores: XLA's CPU backend keeps "
                                     "a worker busy for a batch's whole "
                                     "service time, so on a 1-core host "
                                     "the QPS-vs-executors curve is flat",
                     "deadline_ms": DEADLINE_MS,
                     "degraded_note": "degraded_mode lanes run the same "
                                      "deadline-carrying stream on a "
                                      f"{DEGRADED_EXECUTORS}-worker pool; "
                                      "'faulted' persistently fails every "
                                      "batch on executor 0 (retried, "
                                      "breaker opens, routes fall back), "
                                      "'healthy' is the control",
                     "p99_note": f"p99_ms over {N_REQUESTS} requests is a "
                                 "true percentile (n >= 100); quantiles "
                                 "are exact-rank (obs.metrics), not "
                                 "interpolated — expect small upward "
                                 "p99 shifts vs pre-PR10 recordings",
                     "cost_model": {
                         "features": list(cost_model.features),
                         "weights": [round(float(w), 6)
                                     for w in cost_model.weights],
                         "intercept": round(float(cost_model.intercept),
                                            6),
                         "r2": round(float(cost_model.r2), 4),
                         "n_samples": cost_model.n_samples},
                     "cost_note": "cost_dispatch lanes replay the mixed "
                                  "stream through one chunked route "
                                  f"(chunk_tiles={COST_CHUNK_TILES}) as "
                                  "a burst (dispatch order is the only "
                                  "lever; Poisson jitter at QPS=100 "
                                  "exceeds the sorting effect); "
                                  "'cost_sorted' orders each picked "
                                  "group by the trace-fitted chunk "
                                  "predictor; ids/scores are "
                                  "bit-identical across lanes by "
                                  "batch-composition independence",
                     "obs": cost_obs},
            "configs": configs, "cost_dispatch": cost_lanes,
            "executor_sweep": sweep, "degraded_mode": degraded}


def _row(stats: dict, executors: int) -> dict:
    return {
        "n": stats["n"], "qps_offered": QPS,
        "qps_achieved": round(stats["qps_achieved"], 2),
        "goodput_qps": round(stats["goodput_qps"], 2),
        "n_in_deadline": stats["n_in_deadline"],
        "mrt_ms": round(stats["mrt_ms"], 3),
        "p50_ms": round(stats["p50_ms"], 3),
        "p99_ms": round(stats["p99_ms"], 3),
        "batches": stats["batches"],
        "executors": executors,
        "admitted": stats["admitted"],
        "shed": stats["shed"],
        "rejected": stats["rejected"],
        "warmup_s": round(stats["warmup_s"], 3),
        "cache_hits": stats["cache_hits"],
        "cache_misses": stats["cache_misses"],
        "requests_by_route": stats["requests_by_route"],
        "batches_by_group": stats["batches_by_group"],
        "batches_by_executor": {str(k): v for k, v in
                                stats["batches_by_executor"].items()},
    }


def run(out) -> None:
    data = collect()
    rows = {**data["configs"],
            **{f"cost/{k}": v for k, v in data["cost_dispatch"].items()},
            **{f"pool/{k}": v for k, v in data["executor_sweep"].items()},
            **{f"degraded_mode/{k}": v
               for k, v in data["degraded_mode"].items()}}
    for name, row in rows.items():
        out(emit(f"serving/{name}", row["mrt_ms"],
                 {k: v for k, v in row.items()
                  if k not in ("mrt_ms", "requests_by_route",
                               "batches_by_group", "batches_by_executor",
                               "breakers", "queue_wait_ms",
                               "service_ms")}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_serving.json)")
    args = ap.parse_args()
    path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_serving.json")
    data = collect()
    write_bench_json(path, data)
    base = data["configs"]["baseline"]["mrt_ms"]
    for name, row in data["configs"].items():
        hits = row["cache_hits"]
        print(f"{name:14s} MRT={row['mrt_ms']:8.2f}ms "
              f"P99={row['p99_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"cache={hits}/{hits + row['cache_misses']} "
              f"vs-baseline={row['mrt_ms'] / base:5.2f}x")
    cm = data["meta"]["cost_model"]
    print(f"cost model: r2={cm['r2']:.3f} n={cm['n_samples']} "
          f"weights={cm['weights']}")
    for name, row in data["cost_dispatch"].items():
        print(f"cost/{name:11s} MRT={row['mrt_ms']:8.2f}ms "
              f"P99={row['p99_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f}")
    for name, row in data["executor_sweep"].items():
        print(f"{name:14s} MRT={row['mrt_ms']:8.2f}ms "
              f"P99={row['p99_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"admitted={row['admitted']} shed={row['shed']} "
              f"warmup={row['warmup_s']:.2f}s")
    for name, row in data["degraded_mode"].items():
        print(f"degraded/{name:7s} MRT={row['mrt_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"goodput={row['goodput_qps']:6.1f} "
              f"retries={row['retries']} "
              f"degraded_batches={row['degraded_batches']} "
              f"expired={row['expired']} breakers={row['breakers']}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
