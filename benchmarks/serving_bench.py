"""Poisson-load serving benchmark for the async scheduler.

    PYTHONPATH=src python -m benchmarks.serving_bench [--out PATH]

Drives a mixed-k (10/100), mixed-length (3/12-term) request stream
through ``AsyncRetrievalScheduler`` under three serving policies and
writes ``BENCH_serving.json`` (repo root by default):

  - ``baseline``      one route, full-scan batched engine, no cache —
                      the PR-3 ``RetrievalServer`` regime;
  - ``routed``        Table-8 query-length routing (short queries ->
                      fine-grained chunked traversal, long -> coarser
                      chunks) — also groups micro-batches by length
                      class, so a batch's while_loop trip count tracks
                      its own class instead of the slowest mixed row;
  - ``routed_cached`` the same policy plus the LRU response cache (the
                      stream repeats queries, as real traffic does).

then sweeps the **executor pool** (1/2/4/8 workers, bounded admission
with load-shedding and priority aging) over the same stream — the
QPS-vs-executors curve — and finally records the **degraded-mode
lane**: the same deadline-carrying stream on a 2-worker pool, healthy
vs with executor 0 persistently fault-injected (every one of its
batches fails, retried on the survivor; the breaker opens and routes
rewrite to the fallback lane). Goodput (in-deadline completions/s) is
reported next to QPS for both, which is the pair the deadline
machinery exists for. Every config records QPS/MRT/P99 plus the
scheduler's cache-hit, routing, admission (admitted/shed/rejected) and
per-executor counters, and the grid warmup time. Jit caches are warmed
before timing (a discarded scheduler for the sync configs; the pool's
own startup warmup for the sweep), so MRT measures serving, not
compilation. The corpus is tiny and seeded; numbers are stable enough
to diff across PRs (``make bench-smoke`` is the CI entry).

Executor scaling is compute-bound: the pool multiplies throughput only
up to the host's free cores (XLA's CPU backend keeps a worker busy for
a batch's whole service time). ``meta.host_cores`` records what this
run had — on a 1-core host the curve is flat by construction, which is
exactly what the curve is for: like-for-like comparison across hosts.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib

from repro.core import build_index, twolevel
from repro.data import make_corpus
from repro.serve import (AsyncRetrievalScheduler, Fault, FaultPlan,
                         HealthConfig, RetryPolicy, RoutingPolicy,
                         SchedulerConfig, mixed_request_stream, route,
                         run_workload, single_route, table8_policy)

try:  # package-relative when driven by benchmarks.run
    from .common import emit
except ImportError:  # python -m benchmarks.serving_bench
    from benchmarks.common import emit

N_DOCS = 4096
N_TERMS = 1024
N_QUERIES = 32
TILE = 128
SHORT_LEN = 3          # live terms of the "short" half of the stream
N_REQUESTS = 160
QPS = 100.0            # saturating: MRT reflects serving capacity, not queue noise
MAX_WAIT_MS = 100.0    # long enough for micro-batches to actually form
MAX_BATCH = 8
K_POOL = (10, 100)     # two k-buckets in flight at once

CONFIGS = (
    ("baseline", lambda: single_route("batched"), 0),
    ("routed", table8_policy, 0),
    ("routed_cached", table8_policy, 256),
)
EXECUTOR_SWEEP = (1, 2, 4, 8)
ADMISSION_LIMIT = 8 * MAX_BATCH   # bounded queue: saturation sheds,
ADMISSION_POLICY = "shed"         # so the median stays bounded and the
AGING_MS = 50.0                   # tail (P99) absorbs the overload
DEADLINE_MS = 500.0               # degraded-mode lane: goodput budget
DEGRADED_EXECUTORS = 2            # one faulted, one survivor


def _fallback_policy() -> RoutingPolicy:
    """Table-8 routing plus a cheaper fallback lane per class (coarser
    chunked traversal, same padded width), for the degraded-mode lane:
    while the faulted executor's breaker is open, the router rewrites
    both classes to their fallback and responses come back flagged."""
    return RoutingPolicy(
        (route("short", 4, "batched", pad_terms=4, traversal="chunked",
               chunk_tiles=2, fallback="short_fast"),
         route("long", None, "batched", fallback="long_fast")),
        fallback_routes=(
            route("short_fast", None, "batched", pad_terms=4,
                  traversal="chunked", chunk_tiles=8),
            route("long_fast", None, "batched", traversal="chunked",
                  chunk_tiles=16)))


def _requests(corpus, n: int) -> list:
    """The shared mixed stream (``serve.mixed_request_stream``): every
    (length-class x k-bucket) group stays continuously populated."""
    return mixed_request_stream(corpus, n, short_len=SHORT_LEN,
                                k_pool=K_POOL)


def collect() -> dict:
    corpus = make_corpus("splade_like", n_docs=N_DOCS, n_terms=N_TERMS,
                         n_queries=N_QUERIES, n_q_terms=12, seed=0)
    index = build_index(corpus.merged("scaled"), tile_size=TILE)
    params = twolevel.fast().replace(schedule="impact")
    configs = {}
    for name, routing, cache in CONFIGS:
        def fresh():
            return AsyncRetrievalScheduler(
                index, params,
                SchedulerConfig(max_batch=MAX_BATCH,
                                max_wait_ms=MAX_WAIT_MS,
                                cache_size=cache),
                routing=routing())
        # warm every (k-bucket x length-class) jit entry on a throwaway
        # scheduler (the compile caches are global), then time fresh
        run_workload(fresh(), _requests(corpus, 4 * MAX_BATCH), qps=1e6)
        stats = run_workload(fresh(), _requests(corpus, N_REQUESTS),
                             qps=QPS, seed=3)
        configs[name] = _row(stats, executors=0)
    sweep = {}
    for n_exec in EXECUTOR_SWEEP:
        sched = AsyncRetrievalScheduler(
            index, params,
            SchedulerConfig(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                            cache_size=0, executors=n_exec,
                            admission_limit=ADMISSION_LIMIT,
                            admission_policy=ADMISSION_POLICY,
                            aging_ms=AGING_MS),
            routing=table8_policy())
        # the pool warms the routing grid at start(), inside the context
        # manager but before run_workload's clock starts
        with sched:
            stats = run_workload(sched, _requests(corpus, N_REQUESTS),
                                 qps=QPS, seed=3)
        sweep[f"executors_{n_exec}"] = _row(stats, executors=n_exec)
    degraded = {}
    for lane, faulted in (("healthy", False), ("faulted", True)):
        faults = None
        if faulted:
            # every batch attempt on executor 0 fails (retryable): the
            # retry policy requeues onto the survivor, the breaker opens
            # after the threshold, and routes rewrite to the fallback
            faults = FaultPlan(
                [Fault("fail", executor=0, times=None)], wall=True)
        sched = AsyncRetrievalScheduler(
            index, params,
            SchedulerConfig(max_batch=MAX_BATCH, max_wait_ms=MAX_WAIT_MS,
                            cache_size=0, executors=DEGRADED_EXECUTORS,
                            retry=RetryPolicy(max_attempts=4,
                                              backoff_ms=2.0),
                            health=HealthConfig(failure_threshold=3,
                                                cooldown_ms=200.0)),
            routing=_fallback_policy(), faults=faults)
        with sched:
            stats = run_workload(
                sched, mixed_request_stream(
                    corpus, N_REQUESTS, short_len=SHORT_LEN,
                    k_pool=K_POOL, deadline_ms=DEADLINE_MS),
                qps=QPS, seed=3)
        row = _row(stats, executors=DEGRADED_EXECUTORS)
        row.update({
            "deadline_ms": DEADLINE_MS,
            "expired": stats["expired"], "failed": stats["failed"],
            "retries": stats["retries"],
            "degraded_batches": stats["degraded_batches"],
            "breakers": {str(k): v["state"]
                         for k, v in stats["breakers"].items()}})
        degraded[lane] = row
    return {"meta": {"corpus": "splade_like", "n_docs": N_DOCS,
                     "n_terms": N_TERMS, "n_queries": N_QUERIES,
                     "tile_size": TILE, "n_requests": N_REQUESTS,
                     "short_len": SHORT_LEN, "k_pool": list(K_POOL),
                     "max_batch": MAX_BATCH,
                     "admission_limit": ADMISSION_LIMIT,
                     "admission_policy": ADMISSION_POLICY,
                     "aging_ms": AGING_MS,
                     "host_cores": os.cpu_count(),
                     "scaling_note": "executor scaling is bounded by "
                                     "host_cores: XLA's CPU backend keeps "
                                     "a worker busy for a batch's whole "
                                     "service time, so on a 1-core host "
                                     "the QPS-vs-executors curve is flat",
                     "deadline_ms": DEADLINE_MS,
                     "degraded_note": "degraded_mode lanes run the same "
                                      "deadline-carrying stream on a "
                                      f"{DEGRADED_EXECUTORS}-worker pool; "
                                      "'faulted' persistently fails every "
                                      "batch on executor 0 (retried, "
                                      "breaker opens, routes fall back), "
                                      "'healthy' is the control",
                     "p99_note": f"p99_ms over {N_REQUESTS} requests is a "
                                 "true percentile (n >= 100)"},
            "configs": configs, "executor_sweep": sweep,
            "degraded_mode": degraded}


def _row(stats: dict, executors: int) -> dict:
    return {
        "n": stats["n"], "qps_offered": QPS,
        "qps_achieved": round(stats["qps_achieved"], 2),
        "goodput_qps": round(stats["goodput_qps"], 2),
        "n_in_deadline": stats["n_in_deadline"],
        "mrt_ms": round(stats["mrt_ms"], 3),
        "p50_ms": round(stats["p50_ms"], 3),
        "p99_ms": round(stats["p99_ms"], 3),
        "batches": stats["batches"],
        "executors": executors,
        "admitted": stats["admitted"],
        "shed": stats["shed"],
        "rejected": stats["rejected"],
        "warmup_s": round(stats["warmup_s"], 3),
        "cache_hits": stats["cache_hits"],
        "cache_misses": stats["cache_misses"],
        "requests_by_route": stats["requests_by_route"],
        "batches_by_group": stats["batches_by_group"],
        "batches_by_executor": {str(k): v for k, v in
                                stats["batches_by_executor"].items()},
    }


def run(out) -> None:
    data = collect()
    rows = {**data["configs"],
            **{f"pool/{k}": v for k, v in data["executor_sweep"].items()},
            **{f"degraded_mode/{k}": v
               for k, v in data["degraded_mode"].items()}}
    for name, row in rows.items():
        out(emit(f"serving/{name}", row["mrt_ms"],
                 {k: v for k, v in row.items()
                  if k not in ("mrt_ms", "requests_by_route",
                               "batches_by_group", "batches_by_executor",
                               "breakers")}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_serving.json)")
    args = ap.parse_args()
    path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_serving.json")
    data = collect()
    path.write_text(json.dumps(data, indent=2, sort_keys=True) + "\n")
    base = data["configs"]["baseline"]["mrt_ms"]
    for name, row in data["configs"].items():
        hits = row["cache_hits"]
        print(f"{name:14s} MRT={row['mrt_ms']:8.2f}ms "
              f"P99={row['p99_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"cache={hits}/{hits + row['cache_misses']} "
              f"vs-baseline={row['mrt_ms'] / base:5.2f}x")
    for name, row in data["executor_sweep"].items():
        print(f"{name:14s} MRT={row['mrt_ms']:8.2f}ms "
              f"P99={row['p99_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"admitted={row['admitted']} shed={row['shed']} "
              f"warmup={row['warmup_s']:.2f}s")
    for name, row in data["degraded_mode"].items():
        print(f"degraded/{name:7s} MRT={row['mrt_ms']:8.2f}ms "
              f"qps={row['qps_achieved']:6.1f} "
              f"goodput={row['goodput_qps']:6.1f} "
              f"retries={row['retries']} "
              f"degraded_batches={row['degraded_batches']} "
              f"expired={row['expired']} breakers={row['breakers']}")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
