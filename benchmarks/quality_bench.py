"""Relevance-vs-latency grid: the committed quality baseline.

    PYTHONPATH=src python -m benchmarks.quality_bench [--out PATH]

Writes ``BENCH_quality.json`` (repo root by default): for each pruning
method x threshold_factor x engine lane, MRR@10 / nDCG@10 /
Recall@{10,100} next to the warmed MRT — the paper's quality/efficiency
tradeoff in one table. The corpus is the seeded graded-qrels corpus of
``repro.eval.synthetic`` (contested by construction: one prunable
relevant doc per query, dense signal inside the noise tail), so the
numbers are deterministic and diffable across PRs.

What the committed baseline demonstrates:

- ``tf=3.0`` (over-estimated thresholds) degrades guided ``gti`` MRR@10
  visibly below the rank-safe lane at k=10 — the paper's small-k
  misalignment failure;
- ``cascade`` MRR@10 sits strictly above the sparse-only lane under
  every (method, tf), and above the dense-only lane: reranking ~100
  sparse candidates with the exact dense score beats either modality
  alone;
- the hybrid lanes pay for it in MRT (a second stage is not free) —
  which is exactly the tradeoff a deployment sweep needs to see.
"""
from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np

from repro.core import twolevel
from repro.eval import build_hybrid, evaluate_retriever, make_graded_corpus
from repro.eval.harness import evaluate_ranking
from repro.retrieval import Retriever
from repro.retrieval.hybrid import dense_topk, embed_queries

try:  # package-relative when driven by benchmarks.run
    from .common import emit, write_bench_json
except ImportError:  # python -m benchmarks.quality_bench
    from benchmarks.common import emit, write_bench_json

N_DOCS = 4096
N_TERMS = 1024
N_QUERIES = 32
DIM = 32
TILE = 128
K = 10          # headline retrieval depth (rankings evaluated to 100)
DEPTH = 100     # hybrid candidate depth k'

METHODS = (
    ("rank_safe", lambda: twolevel.linear_combination(gamma=0.05)),
    ("gti", twolevel.gti),
    ("2gti_fast", twolevel.fast),
)
THRESHOLD_FACTORS = (1.0, 3.0)
ENGINES = (("sparse", "batched", {}),
           ("cascade", "cascade", {"depth": DEPTH}),
           ("rrf", "rrf", {"depth": DEPTH}))
# first-stage candidate depths k' swept for the cascade frontier
# (full mode only; lanes land under "cascade_frontier/d<k'>")
CASCADE_DEPTHS = (20, 50, 100, 200)


def collect(smoke: bool = False) -> dict:
    n_queries = 8 if smoke else N_QUERIES
    graded = make_graded_corpus(n_docs=N_DOCS, n_terms=N_TERMS,
                                n_queries=n_queries, dim=DIM, seed=0)
    hybrid = build_hybrid(graded, tile_size=TILE)
    queries = graded.queries()
    lanes = {}
    for mname, preset in METHODS:
        params = preset()
        for tf in THRESHOLD_FACTORS:
            for ename, engine, opts in ENGINES:
                r = Retriever.open(hybrid, params, engine=engine, **opts)
                row = evaluate_retriever(r, queries, graded.qrels, k=DEPTH,
                                         threshold_factor=tf,
                                         repeats=1 if smoke else 3)
                # the headline small-k view: the same engine asked for
                # k=10 only (bucketed execution at 10 — what a serving
                # deployment returning ten results actually runs)
                resp = r.search(k=K, threshold_factor=tf, **queries)
                row["mrr@10_at_k10"] = evaluate_ranking(
                    resp.ids, graded.qrels)["mrr@10"]
                lanes[f"{mname}/tf{tf}/{ename}"] = row
    # dense-only reference lane: exact top-k over the whole embedding
    # table through the same query bridge (no traversal, no pruning)
    q_rot = embed_queries(hybrid, queries["terms"], queries["weights_l"])
    _, dense_ids = dense_topk(hybrid, q_rot, k=DEPTH)
    lanes["dense_only"] = dict(
        evaluate_ranking(np.asarray(dense_ids), graded.qrels),
        engine="dense_topk", k=DEPTH, n_queries=n_queries)
    if not smoke:
        # cascade first-stage depth frontier: sweep the candidate depth
        # k' the sparse stage hands to the exact dense rerank (fixed
        # method/tf) — how shallow the first stage can go before quality
        # falls off, against the MRT each depth pays
        params = twolevel.fast()
        for depth in CASCADE_DEPTHS:
            r = Retriever.open(hybrid, params, engine="cascade",
                               depth=depth)
            row = evaluate_retriever(r, queries, graded.qrels, k=DEPTH,
                                     threshold_factor=1.0, repeats=3)
            row["first_stage_depth"] = depth
            lanes[f"cascade_frontier/d{depth}"] = row
    return {"meta": {"corpus": "splade_like+graded", "n_docs": N_DOCS,
                     "n_terms": N_TERMS, "n_queries": n_queries,
                     "dim": DIM, "tile_size": TILE, "k_headline": K,
                     "depth": DEPTH, "seed": 0,
                     "threshold_factors": list(THRESHOLD_FACTORS),
                     "mrt_note": "mrt_ms is warmed per-query mean over "
                                 "the batched path; hybrid lanes include "
                                 "their second stage"},
            "lanes": lanes}


def run(out) -> None:
    data = collect(smoke=True)
    for name, row in data["lanes"].items():
        out(emit(f"quality_bench/{name}", row.get("mrt_ms", float("nan")),
                 {m: row[m] for m in ("mrr@10", "ndcg@10", "recall@10",
                                      "recall@100") if m in row}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_quality.json)")
    args = ap.parse_args()
    path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_quality.json")
    data = collect()
    write_bench_json(path, data)
    for name, row in sorted(data["lanes"].items()):
        print(f"{name}: mrr@10={row['mrr@10']:.3f} "
              f"ndcg@10={row['ndcg@10']:.3f} "
              f"r@100={row['recall@100']:.3f} "
              f"mrt={row.get('mrt_ms', float('nan')):.2f}ms")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
