"""Roofline analysis from the dry-run artifacts (single-pod mesh).

Per (arch x shape): three terms in seconds —
    compute    = HLO_FLOPs_per_device / 197 TFLOP/s (bf16 peak)
    memory     = HLO_bytes_per_device / 819 GB/s (HBM)
    collective = sum(factor_k * bytes_k per device) / 50 GB/s (ICI link)
      factors: all-reduce 2x (ring moves ~2x payload), others 1x.
HLO flops/bytes use the loop-extrapolated values (XLA counts while bodies
once; the dry-run compiles unrolled depth-1/2 probes to recover per-layer
cost). MODEL_FLOPS is the analytic useful-work count (6*N*D train,
2*N*tokens inference; MoE uses active params); the ratio flags
remat/redundancy waste. Dominant term = the bottleneck the perf loop works
on.
"""
from __future__ import annotations

import json
import pathlib

from repro.configs import get_arch
from repro.configs.shapes import (GNN_SHAPE_DEFS, LM_SHAPE_DEFS,
                                  RECSYS_SHAPE_DEFS)
from repro.launch.steps import adapt_config

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s
LINK_BW = 50e9            # B/s per ICI link
CHIPS = 256               # single-pod roofline
AR_FACTOR = 2.0
ART = pathlib.Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def model_flops(arch_id: str, shape: str) -> float:
    """Analytic useful FLOPs per step, GLOBAL (whole mesh)."""
    arch = get_arch(arch_id)
    cfg = adapt_config(arch, shape)
    if arch.family == "lm":
        d = LM_SHAPE_DEFS[shape]
        n = cfg.active_param_count()
        L, h, dh = cfg.n_layers, cfg.n_heads, cfg.head_dim
        if d["kind"] == "train":
            attn = 2.0 * d["batch"] * d["seq"] ** 2 * h * dh * L  # causal/2
            return 6.0 * n * d["batch"] * d["seq"] + 3.0 * attn
        if d["kind"] == "prefill":
            attn = 2.0 * d["batch"] * d["seq"] ** 2 * h * dh * L
            return 2.0 * n * d["batch"] * d["seq"] + attn
        # decode: one token attends over the full cache
        attn = 4.0 * d["batch"] * d["seq"] * h * dh * L
        return 2.0 * n * d["batch"] + attn
    if arch.family == "gnn":
        dd = GNN_SHAPE_DEFS[shape]
        h, r, i = cfg.d_hidden, cfg.n_rbf, cfg.n_interactions
        if shape == "molecule":
            nodes = dd["batch"] * dd["atoms"]
            edges = dd["batch"] * dd["edges"]
            mult = 6.0  # train (fwd+bwd)
        else:
            nodes, edges, mult = dd["nodes"], dd["edges"], 6.0
        per = i * (edges * r * h + 3 * nodes * h * h) + nodes * h * h
        embed = nodes * (cfg.d_feat or 1) * h
        return mult * (per + embed) / 2.0 * 2.0  # MACs -> flops already 2x
    # recsys
    dd = RECSYS_SHAPE_DEFS[shape]
    from repro.models import recsys as R
    if isinstance(cfg, R.Bert4RecConfig):
        tc = cfg.tf_config()
        # matmul-active params only: embeddings are gathers here (sampled
        # softmax), so exclude the table from the 6ND convention
        n = tc.param_count() - tc.padded_vocab * tc.d_model \
            - tc.max_position * tc.d_model
        attn = 4.0 * cfg.seq_len ** 2 * cfg.n_heads \
            * (cfg.embed_dim // cfg.n_heads) * cfg.n_blocks
        per_seq = 2.0 * n * cfg.seq_len + attn
        if dd["kind"] == "train":
            return 3.0 * dd["batch"] * (per_seq
                                        + 2.0 * cfg.seq_len * 512
                                        * cfg.embed_dim)
        if dd["kind"] == "serve":
            return dd["batch"] * (per_seq + 2.0 * dd["shortlist"]
                                  * cfg.embed_dim)
        return per_seq + 2.0 * dd["n_cand"] * cfg.embed_dim
    if isinstance(cfg, R.DLRMConfig):
        mlp = sum(2 * i * o for i, o in zip(cfg.bot_mlp, cfg.bot_mlp[1:]))
        n_int = cfg.n_sparse + 1
        d_int = n_int * (n_int - 1) // 2 + cfg.embed_dim
        dims = (d_int,) + cfg.top_mlp_hidden
        mlp += sum(2 * i * o for i, o in zip(dims, dims[1:]))
        inter = 2 * n_int * n_int * cfg.embed_dim
        per_ex = mlp + inter
        b = dd.get("n_cand", dd["batch"]) if dd["kind"] == "retrieval" \
            else dd["batch"]
        return (3.0 if dd["kind"] == "train" else 1.0) * per_ex * b
    if isinstance(cfg, R.DINConfig):
        d = cfg.embed_dim
        att = (8 * d * cfg.attn_mlp[0]
               + 2 * cfg.attn_mlp[0] * cfg.attn_mlp[1]) * cfg.seq_len
        dims = (2 * d,) + cfg.mlp + (1,)
        mlp = sum(2 * i * o for i, o in zip(dims, dims[1:]))
        b = dd.get("n_cand", dd["batch"]) if dd["kind"] == "retrieval" \
            else dd["batch"]
        return (3.0 if dd["kind"] == "train" else 1.0) * (att + mlp) * b
    if isinstance(cfg, R.TwoTowerConfig):
        dims = (cfg.feat_dim,) + cfg.tower_mlp
        tower = sum(2 * i * o for i, o in zip(dims, dims[1:]))
        if dd["kind"] == "train":
            b = dd["batch"]
            return 3.0 * (2 * tower * b
                          + 2 * b * (cfg.n_negatives + 1) * cfg.tower_mlp[-1])
        if dd["kind"] == "serve":
            return (tower * dd["batch"] + tower * dd["shortlist"]
                    + 2 * dd["batch"] * dd["shortlist"] * cfg.tower_mlp[-1])
        return tower + 2.0 * dd["n_cand"] * cfg.tower_mlp[-1]
    raise TypeError(type(cfg))


def analyze(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    ex = rec.get("extrapolated") or {}
    flops = ex.get("flops", rec["flops"])
    nbytes = ex.get("bytes_accessed", rec["bytes_accessed"])
    coll = ex.get("collectives", rec["collectives"])
    coll_bytes = sum((AR_FACTOR if k == "all-reduce" else 1.0)
                     * v["bytes"] for k, v in coll.items())
    t_c = flops / PEAK_FLOPS
    t_m = nbytes / HBM_BW
    t_n = coll_bytes / LINK_BW
    dominant = max(("compute", t_c), ("memory", t_m),
                   ("collective", t_n), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"]) / rec["devices"]
    mem = rec.get("memory", {})
    resident = (mem.get("argument_size_in_bytes", 0)
                + mem.get("temp_size_in_bytes", 0)
                + mem.get("output_size_in_bytes", 0))
    return {"arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
            "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
            "dominant": dominant,
            "hlo_flops_dev": flops, "model_flops_dev": mf,
            "useful_ratio": mf / flops if flops > 0 else float("nan"),
            "roofline_frac": max(t_c, t_m, t_n) and
            t_c / max(t_c, t_m, t_n),
            "hbm_gb": resident / 1e9,
            "fits_16g": resident <= 16e9}


def load_all(mesh: str = "pod16x16", variant: str | None = "tp"
             ) -> list[dict]:
    """variant 'tp' = baselines only; a name = that variant's artifacts;
    None = everything (variant recorded per row)."""
    rows = []
    for p in sorted(ART.glob(f"{mesh}__*.json")):
        rec = json.loads(p.read_text())
        v = rec.get("variant", "tp")
        if variant is not None and v != variant:
            continue
        r = analyze(rec)
        if r:
            r["variant"] = v
            rows.append(r)
    return rows


def run(out) -> None:
    rows = load_all(variant=None)
    for r in rows:
        suffix = "" if r["variant"] == "tp" else f"/{r['variant']}"
        name = f"roofline/{r['arch']}/{r['shape']}{suffix}"
        total = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out(f"{name},{total*1e6:.1f},"
            f"compute_s={r['compute_s']:.4g};memory_s={r['memory_s']:.4g};"
            f"collective_s={r['collective_s']:.4g};dom={r['dominant']};"
            f"useful={r['useful_ratio']:.3f};hbm_gb={r['hbm_gb']:.2f};"
            f"fits={r['fits_16g']}")


def markdown_table(mesh: str = "pod16x16") -> str:
    rows = load_all(mesh)
    lines = ["| arch | shape | compute s | memory s | collective s | "
             "dominant | useful ratio | HBM GB | fits 16G |",
             "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.2e} "
            f"| {r['memory_s']:.2e} | {r['collective_s']:.2e} "
            f"| {r['dominant']} | {r['useful_ratio']:.2f} "
            f"| {r['hbm_gb']:.2f} | {'Y' if r['fits_16g'] else 'N'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown_table())
