"""Table 4 analogue: validating 2GTI's competitiveness properties vs the
two-stage baseline R2_{alpha,gamma} and the rank-safe linear combination."""
from __future__ import annotations

import numpy as np

from repro.core import twolevel
from repro.core.metrics import evaluate_run
from repro.core.oracle import two_stage

from .common import METHODS, corpus, emit, run_method

GAMMA = 0.05


def run(out) -> None:
    c = corpus("splade_like")
    # two-stage R2: stage 1 = REAL BM25 (zero-filled weights), stage 2 =
    # gamma-combined rerank on the aligned index — the paper's baseline.
    m_zero = c.merged("zero")
    from repro.core.oracle import ranked_list, score_all_merged
    m_scaled = c.merged("scaled")
    ids = []
    for q in range(len(c.queries)):
        first, _ = ranked_list(m_zero, c.queries[q], c.q_weights_b[q],
                               c.q_weights_l[q], 1.0, 10)
        s2 = score_all_merged(m_scaled, c.queries[q], c.q_weights_b[q],
                              c.q_weights_l[q], GAMMA)
        order = np.argsort(-s2[first], kind="stable")
        ids.append(first[order])
    ids = np.stack(ids)
    m = evaluate_run(ids, c.qrels, 10)
    out(emit("table4/two_stage_R2", float("nan"),
             {"mrr": m["mrr"], "recall": m["recall"]}))
    rows = [
        ("gti_s", twolevel.gti(gamma=GAMMA)),
        ("2gti_beta_gamma", twolevel.TwoLevelParams(1.0, GAMMA, GAMMA)),
        ("2gti_accurate", twolevel.accurate(gamma=GAMMA)),
        ("2gti_fast", twolevel.fast(gamma=GAMMA)),
        ("linear_comb", twolevel.linear_combination(gamma=GAMMA)),
    ]
    for name, p in rows:
        r = run_method("splade_like", "scaled", p)
        out(emit(f"table4/{name}", r["mrt_ms"],
                 {"mrr": r["mrr"], "recall": r["recall"],
                  "p99_ms": r["p99_ms"]}))
