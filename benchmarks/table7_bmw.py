"""Table 7 / Appendix B analogue: block-max (BMW-style) bounds vs list-level
MaxScore bounds under 2GTI, across k — plus the beyond-paper impact-ordered
schedule, the TPU-native traversal refinement. For each bound mode the
``*_chunked`` row runs the chunked batched engine (the impact order folded
into early-exit chunks) and reports ``chunks_dispatched`` next to the
tiles-visited count."""
from __future__ import annotations

from repro.core import twolevel

from .common import emit, run_method


def run(out) -> None:
    for k in (10, 20, 100):
        for bound in ("list", "tile"):
            for sched in ("docid", "impact"):
                p = twolevel.fast().replace(bound_mode=bound,
                                            schedule=sched)
                r = run_method("unicoil_like", "scaled", p, k=k)
                out(emit(f"table7/{bound}_{sched}/k{k}", r["mrt_ms"],
                         {"mrr": r["mrr"], "recall": r["recall"],
                          "tiles": r["tiles_visited"],
                          "frozen": r["docs_frozen"]}))
            pc = twolevel.fast().replace(bound_mode=bound)
            rc = run_method("unicoil_like", "scaled", pc, k=k,
                            timed=False, traversal="chunked")
            out(emit(f"table7/{bound}_chunked/k{k}", float("nan"),
                     {"mrr": rc["mrr"], "recall": rc["recall"],
                      "tiles": rc["tiles_visited"],
                      "frozen": rc["docs_frozen"],
                      "chunks_dispatched": rc["chunks_dispatched"],
                      "n_chunks": rc["n_chunks"]}))
