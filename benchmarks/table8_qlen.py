"""Table 8 analogue (Appendix B): performance by query-length class.

The paper finds VBMW-2GTI preferable for short queries and
MaxScore-2GTI for long ones, suggesting query routing. Our analogue
compares list-level (MaxScore) vs tile-level (BMW-style) bounds across
corpora with 3 / 6 / 9 / 12-term queries.
"""
from __future__ import annotations

from repro.core import build_index, twolevel
from repro.core.metrics import evaluate_run, mean_and_p99
from repro.data import make_corpus
from repro.retrieval import Retriever

from .common import emit


def run(out) -> None:
    for n_terms in (3, 6, 9, 12):
        corpus = make_corpus("unicoil_like", n_docs=16384, n_terms=4096,
                             n_queries=16, n_q_terms=n_terms, seed=5)
        index = build_index(corpus.merged("scaled"), tile_size=512)
        for bound in ("list", "tile"):
            p = twolevel.fast().replace(bound_mode=bound,
                                        schedule="impact")
            r = Retriever.open(index, p, engine="sequential")
            res = r.search(terms=corpus.queries,
                           weights_b=corpus.q_weights_b,
                           weights_l=corpus.q_weights_l, k=10)
            m = evaluate_run(res.ids, corpus.qrels, 10)
            mrt, p99 = mean_and_p99(res.latencies_ms)
            out(emit(f"table8/qlen{n_terms}/{bound}", mrt,
                     {"mrr": m["mrr"], "recall": m["recall"],
                      "p99_ms": p99,
                      "tiles": float(res.stats["tiles_visited"].mean()),
                      "frozen": float(res.stats["docs_frozen"].mean())}))
