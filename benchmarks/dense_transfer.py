"""Beyond-paper: 2GTI transferred to dense retrieval (two-tower
retrieval_cand). Beta sweep reproduces the paper's Fig.-3 conclusion in the
dense regime: small beta retains recall while pruning full-dim work."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from repro.core.dense_guided import build_dense_index, exhaustive_dense
from repro.core.twolevel import TwoLevelParams
from repro.retrieval import Retriever

from .common import emit


def run(out) -> None:
    rng = np.random.default_rng(0)
    n, d = 100_000, 128
    centers = rng.standard_normal((16, d)) * 2.0
    assign = rng.integers(0, 16, n)
    emb = centers[assign] + rng.standard_normal((n, d))
    emb /= np.linalg.norm(emb, axis=1, keepdims=True)
    order = np.argsort(assign, kind="stable")
    index = build_dense_index(jnp.asarray(emb[order], jnp.float32),
                              block_size=2048, d_cheap=32)
    qs = rng.standard_normal((12, d)).astype(np.float32)
    qs /= np.linalg.norm(qs, axis=1, keepdims=True)

    for beta in (0.0, 0.2, 0.4, 0.6, 1.0):
        p = TwoLevelParams(alpha=1.0, beta=beta, gamma=0.0)
        r = Retriever.open(index, p, engine="dense")
        t0 = time.time()
        resp = r.search(dense=qs, k=10)
        ms = (time.time() - t0) / len(qs) * 1e3
        rec = 0.0
        for i, q in enumerate(qs):
            _, eids = exhaustive_dense(index, jnp.asarray(q), 10)
            rec += len(set(resp.ids[i].tolist())
                       & set(eids.tolist())) / 10
        frac = float(np.mean(resp.stats["candidates_fully_scored"]
                             / resp.stats["n_candidates"]))
        out(emit(f"dense_transfer/beta{beta}", ms,
                 {"recall10": rec / len(qs), "fully_scored_frac": frac}))
