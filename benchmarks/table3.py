"""Table 3 analogue: design options on the misaligned (SPLADE-like) corpus,
k=10 — (a) threshold over-estimation on the unguided method, (b) weight
alignment (zero/one/scaled filling) for GTI and 2GTI-Accurate."""
from __future__ import annotations

from repro.core import twolevel

from .common import METHODS, emit, run_method


def run(out) -> None:
    # threshold over-estimation on org (rank-unsafe speedup)
    for f in (1.0, 1.1, 1.3, 1.5):
        p = twolevel.original().replace(threshold_factor=f)
        r = run_method("splade_like", "scaled", p)
        out(emit(f"table3/overestimate/F{f}", r["mrt_ms"],
                 {"mrr": r["mrr"], "recall": r["recall"],
                  "survived": r["docs_survived"]}))
    # alignment fillings
    for method in ("gti", "2gti_acc"):
        for fill in ("zero", "one", "scaled"):
            r = run_method("splade_like", fill, METHODS[method]())
            out(emit(f"table3/{method}/{fill}", r["mrt_ms"],
                     {"mrr": r["mrr"], "recall": r["recall"],
                      "p99_ms": r["p99_ms"],
                      "survived": r["docs_survived"]}))
