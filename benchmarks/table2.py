"""Table 2 analogue: method comparison across three model-alignment regimes
(SPLADE-like / uniCOIL-like / DeepImpact-like) at k=10 and k=1000.

Per the paper's defaults: GT/GTI run on the zero-filled index, 2GTI on the
scaled-filled index, org is guidance-free (fill irrelevant for ranking —
uses scaled to share the cache). BM25-rank row = R_1.0 exhaustive."""
from __future__ import annotations

import numpy as np

from repro.core.oracle import ranked_list
from repro.core.metrics import evaluate_run

from .common import METHODS, corpus, emit, run_method

PRESETS = ("splade_like", "unicoil_like", "deepimpact_like")
ROWS = [("org", "scaled"), ("gt", "zero"), ("gti", "zero"),
        ("gti/s", "scaled"), ("2gti_acc", "scaled"), ("2gti_fast", "scaled")]


def bm25_row(preset: str, k: int) -> dict:
    c = corpus(preset)
    merged = c.merged("zero")
    ids = np.stack([ranked_list(merged, c.queries[q], c.q_weights_b[q],
                                c.q_weights_l[q], 1.0, k)[0]
                    for q in range(len(c.queries))])
    return evaluate_run(ids, c.qrels, k)


def run(out) -> None:
    for preset in PRESETS:
        for k in (10, 1000):
            m = bm25_row(preset, k)
            out(emit(f"table2/{preset}/bm25_rank/k{k}", float("nan"),
                     {"mrr": m["mrr"], "recall": m["recall"]}))
            for row, fill in ROWS:
                method = row.split("/")[0]
                r = run_method(preset, fill, METHODS[method](), k=k)
                out(emit(f"table2/{preset}/{row}/k{k}", r["mrt_ms"],
                         {"mrr": r["mrr"], "recall": r["recall"],
                          "p99_ms": r["p99_ms"],
                          "tiles": r["tiles_visited"],
                          "survived": r["docs_survived"]}))
