"""Million-document compressed-index benchmark: size, build, traversal.

    PYTHONPATH=src python -m benchmarks.million_doc [--out PATH] [--full]

Streams a synthetic corpus chunk-by-chunk through
``repro.data.StreamingIndexBuilder`` (peak memory = one chunk) and
records into ``BENCH_index.json``:

- ``size``: compressed bytes/doc vs the analytic fp32 BII bytes/doc for
  the same postings (``CompressedImpactIndex.fp32_nbytes``) and their
  ratio — the headline "<25% of fp32" number;
- ``build``: docs/s for a cold build and for a resumed build (first half
  of the chunks already on disk — measures the idempotent-skip replay);
- ``mrt``: chunked-traversal mean response time on the compressed index
  (decode-on-gather jnp path; the in-kernel decode is pinned for parity
  at small scale in tests — its tri-matmul cumsum scratch does not pay
  at benchmark pad_len on CPU).

Default is a seconds-scale smoke config; ``--full`` (or env
``REPRO_BENCH_FULL=1``) runs the 2^20-doc corpus the acceptance ratio is
pinned on.
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import tempfile
import time

import numpy as np

from repro.core import twolevel
from repro.core.traversal import retrieve_batched
from repro.data import StreamingIndexBuilder, synthetic_chunk_stream

try:  # package-relative when driven by benchmarks.run
    from .common import emit, write_bench_json
except ImportError:  # python -m benchmarks.million_doc
    from benchmarks.common import emit, write_bench_json

# Corpus shape tuned so per-(term, tile) runs are dense enough for
# narrow gap widths (steep Zipf head): the regime where delta+int8
# clearly beats fp32 storage, as on real learned-sparse corpora.
ZIPF_A = 1.2
AVG_DOC_TERMS = 64
N_TERMS = 256
K = 10
N_QUERIES = 8
N_Q_TERMS = 8
CHUNK_TILES = 8

SMOKE = dict(n_chunks=4, chunk_docs=16384, tile_size=2048)
FULL = dict(n_chunks=16, chunk_docs=65536, tile_size=8192)   # 2^20 docs


def _stream(cfg, seed: int = 0, start_chunk: int = 0):
    return synthetic_chunk_stream(
        cfg["n_chunks"], cfg["chunk_docs"], N_TERMS,
        avg_doc_terms=AVG_DOC_TERMS, seed=seed, start_chunk=start_chunk,
        zipf_a=ZIPF_A)


def _build(out_dir, cfg):
    b = StreamingIndexBuilder(out_dir, n_terms=N_TERMS,
                              tile_size=cfg["tile_size"],
                              chunk_docs=cfg["chunk_docs"])
    for ch in _stream(cfg):
        b.add_chunk(ch)
    return b


def _queries(rng):
    # mid-band terms (informative, non-empty), impact-style weights
    band = np.arange(4, N_TERMS // 2)
    q = np.stack([rng.choice(band, size=N_Q_TERMS, replace=False)
                  for _ in range(N_QUERIES)]).astype(np.int32)
    qw_l = (1.0 + rng.gamma(2.0, 0.5, size=q.shape)).astype(np.float32)
    qw_b = np.ones_like(qw_l)
    return q, qw_b, qw_l


def collect(full: bool) -> dict:
    cfg = FULL if full else SMOKE
    n_docs = cfg["n_chunks"] * cfg["chunk_docs"]

    with tempfile.TemporaryDirectory() as d:
        # cold build: every chunk generated + encoded + spilled
        t0 = time.perf_counter()
        builder = _build(pathlib.Path(d) / "cold", cfg)
        build_s = time.perf_counter() - t0
        index = builder.finalize()

        # resumed build: first half already on disk; the replay skips
        # them (manifest hit, no generation for skipped ids) and encodes
        # the rest — the kill-and-resume wall-clock a restart pays
        half = pathlib.Path(d) / "resume"
        b = StreamingIndexBuilder(half, n_terms=N_TERMS,
                                  tile_size=cfg["tile_size"],
                                  chunk_docs=cfg["chunk_docs"])
        for ch in _stream(cfg):
            if ch.chunk_id >= cfg["n_chunks"] // 2:
                break
            b.add_chunk(ch)
        t0 = time.perf_counter()
        b2 = StreamingIndexBuilder(half, n_terms=N_TERMS,
                                   tile_size=cfg["tile_size"],
                                   chunk_docs=cfg["chunk_docs"])
        done = set(b2.completed_chunks)
        start = min(set(range(cfg["n_chunks"])) - done, default=0)
        for ch in _stream(cfg, start_chunk=start):
            b2.add_chunk(ch)
        resume_s = time.perf_counter() - t0

    nb = index.nbytes()
    fp32 = index.fp32_nbytes()

    # chunked-traversal MRT (decode-on-gather), compile excluded
    q, qw_b, qw_l = _queries(np.random.default_rng(42))
    params = twolevel.fast(chunk_tiles=CHUNK_TILES)
    run = lambda: retrieve_batched(index, q, qw_b, qw_l, params, k=K,
                                   traversal="chunked",
                                   chunk_tiles=CHUNK_TILES)
    run()                               # compile + first dispatch
    t0 = time.perf_counter()
    reps = 3
    for _ in range(reps):
        resp = run()
    mrt_ms = (time.perf_counter() - t0) / (reps * N_QUERIES) * 1e3

    return {
        "meta": {"mode": "full" if full else "smoke", "n_docs": n_docs,
                 "n_terms": N_TERMS, "avg_doc_terms": AVG_DOC_TERMS,
                 "zipf_a": ZIPF_A, "tile_size": cfg["tile_size"],
                 "chunk_docs": cfg["chunk_docs"],
                 "n_chunks": cfg["n_chunks"], "k": K,
                 "n_queries": N_QUERIES, "chunk_tiles": CHUNK_TILES,
                 "nnz": index.nnz, "pad_len": index.pad_len},
        "size": {"bytes_per_doc": round(nb["total"] / n_docs, 2),
                 "fp32_bytes_per_doc": round(fp32 / n_docs, 2),
                 "ratio": round(nb["total"] / fp32, 4),
                 "components": {k: v for k, v in nb.items()
                                if k != "total"}},
        "build": {"build_s": round(build_s, 2),
                  "docs_per_s": round(n_docs / build_s),
                  "resume_s": round(resume_s, 2),
                  "docs_per_s_resume": round(n_docs / resume_s)},
        "mrt": {"chunked_mrt_ms": round(mrt_ms, 3),
                "tiles_visited": float(resp.stats["tiles_visited"].mean()),
                "chunks_dispatched": float(
                    resp.stats["chunks_dispatched"].mean()),
                "n_chunks": float(resp.stats["n_chunks"].mean())},
    }


def _is_full(args_full: bool) -> bool:
    return args_full or os.environ.get("REPRO_BENCH_FULL") == "1"


def run(out) -> None:
    data = collect(_is_full(False))
    out(emit("million_doc/size", data["size"]["bytes_per_doc"],
             {"ratio": data["size"]["ratio"],
              "fp32_bytes_per_doc": data["size"]["fp32_bytes_per_doc"]}))
    out(emit("million_doc/build", data["build"]["docs_per_s"],
             {"docs_per_s_resume": data["build"]["docs_per_s_resume"]}))
    out(emit("million_doc/mrt", data["mrt"]["chunked_mrt_ms"],
             {"tiles_visited": data["mrt"]["tiles_visited"]}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_index.json)")
    ap.add_argument("--full", action="store_true",
                    help="run the 2^20-doc corpus (also REPRO_BENCH_FULL=1)")
    args = ap.parse_args()
    path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent / "BENCH_index.json")
    data = collect(_is_full(args.full))
    write_bench_json(path, data)
    s, b, m = data["size"], data["build"], data["mrt"]
    print(f"{data['meta']['n_docs']} docs: {s['bytes_per_doc']}B/doc vs "
          f"fp32 {s['fp32_bytes_per_doc']}B/doc (ratio {s['ratio']:.3f}); "
          f"build {b['docs_per_s']}/s cold, {b['docs_per_s_resume']}/s "
          f"resumed; chunked MRT {m['chunked_mrt_ms']:.1f}ms")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
