"""Figure 3 analogue: controlling BM25's influence on pruning — alpha sweep
(beta=1), beta sweep (alpha=1), and threshold under-estimation on GTI
(alpha=beta=1, F<1); latency/relevance tradeoff curves."""
from __future__ import annotations

from repro.core import twolevel

from .common import emit, run_method


def run(out) -> None:
    for a in (1.0, 0.7, 0.4, 0.0):
        p = twolevel.TwoLevelParams(alpha=a, beta=1.0, gamma=0.05)
        r = run_method("splade_like", "scaled", p)
        out(emit(f"figure3/alpha_sweep/a{a}", r["mrt_ms"],
                 {"mrr": r["mrr"], "recall": r["recall"]}))
    for b in (1.0, 0.6, 0.3, 0.0):
        p = twolevel.TwoLevelParams(alpha=1.0, beta=b, gamma=0.05)
        r = run_method("splade_like", "scaled", p)
        out(emit(f"figure3/beta_sweep/b{b}", r["mrt_ms"],
                 {"mrr": r["mrr"], "recall": r["recall"]}))
    for f in (1.0, 0.9, 0.8, 0.7):
        p = twolevel.gti().replace(threshold_factor=f)
        r = run_method("splade_like", "scaled", p)
        out(emit(f"figure3/underestimate/F{f}", r["mrt_ms"],
                 {"mrr": r["mrr"], "recall": r["recall"]}))
