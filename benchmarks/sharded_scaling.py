"""Sharded-retrieval scaling: shard counts {1,2,4,8}, throughput + skip
tables + single-device parity.

Standalone entry fakes 8 host devices *before* jax initializes so every
shard count runs the real ``shard_map`` + collective-merge path:

    PYTHONPATH=src python -m benchmarks.sharded_scaling [--smoke]

``--smoke`` is the CI lane (``make bench-smoke``): tiny corpus, 1-device
mesh, one rep. Via ``benchmarks.run`` the module uses however many devices
already exist and falls back to the vmap emulation path (bit-identical
math, no cross-device traffic) for larger shard counts.

Rows: ``sharded/<method>/s<shards>_e<exchange>[_chunked]`` with per-query
latency, throughput, mean tiles visited per shard, and the max |score
delta| vs the single-device ``batched`` engine (0 for rank-safe configs
by construction; the parity *tests* pin bit-identity). ``_chunked`` rows
run the per-shard early-exit chunk loop and add ``chunks_dispatched``
next to the tiles-visited counts. Both sides run through the
``repro.retrieval.Retriever`` facade.
"""
from __future__ import annotations

import argparse
import os
import sys
import time

if __name__ == "__main__" and "--smoke" not in sys.argv:
    _prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in _prev:
        os.environ["XLA_FLAGS"] = (
            f"{_prev} --xla_force_host_platform_device_count=8".strip())

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import build_index, twolevel  # noqa: E402
from repro.core.shard_plan import shard_index  # noqa: E402
from repro.data import make_corpus  # noqa: E402
from repro.retrieval import Retriever  # noqa: E402
from repro.serve.sharded import make_shard_mesh  # noqa: E402

try:  # package-relative when driven by benchmarks.run
    from .common import emit
except ImportError:  # python -m benchmarks.sharded_scaling
    from benchmarks.common import emit


def run(out, smoke: bool = False) -> None:
    n_docs = 4096 if smoke else 32768
    corpus = make_corpus("splade_like", n_docs=n_docs, n_terms=4096,
                         n_queries=32, seed=0)
    index = build_index(corpus.merged("scaled"), tile_size=512)
    q = (corpus.queries, corpus.q_weights_b, corpus.q_weights_l)
    b = len(corpus.queries)
    n_dev = len(jax.devices())
    shard_counts = (1,) if smoke else (1, 2, 4, 8)
    exchanges = (0,) if smoke else (0, 2)
    reps = 1 if smoke else 3
    methods = [("fast_docid", twolevel.fast())]
    if not smoke:
        methods.append(("fast_impact",
                        twolevel.fast().replace(schedule="impact")))
    queries = dict(terms=q[0], weights_b=q[1], weights_l=q[2])
    for name, params in methods:
        # per-traversal single-device references: chunked rows compare
        # against the chunked batched engine (same descending-bound visit
        # order), full rows against the schedule the method names
        refs = {
            trav: Retriever.open(index, params, traversal=trav
                                 ).search(**queries, k=10)
            for trav in ("full", "chunked")}
        for ns in shard_counts:
            sharded = shard_index(index, ns)
            mesh = make_shard_mesh(ns) if ns <= n_dev else None
            for exch in exchanges:
                for trav in ("full", "chunked"):
                    r = Retriever.open(sharded, params, engine="sharded",
                                       mesh=mesh, exchange_every=exch,
                                       traversal=trav)
                    res = r.search(**queries, k=10)  # compile untimed
                    t0 = time.perf_counter()
                    for _ in range(reps):
                        res = r.search(**queries, k=10)
                    dt = (time.perf_counter() - t0) / reps
                    ref = refs[trav]
                    per_shard = res.stats["shard_tiles_visited"].mean(0)
                    delta = float(np.abs(res.scores - ref.scores).max())
                    derived = {
                        "qps": b / dt,
                        "path": "mesh" if mesh is not None else "emu",
                        "tiles_per_shard": "|".join(
                            f"{v:.1f}" for v in per_shard),
                        "tiles_total": float(
                            res.stats["tiles_visited"].mean()),
                        "score_delta_vs_1dev": delta,
                        "ids_equal": bool(np.array_equal(res.ids, ref.ids))}
                    suffix = ""
                    if trav == "chunked":
                        suffix = "_chunked"
                        derived["chunks_dispatched"] = float(
                            res.stats["chunks_dispatched"].mean())
                        derived["n_chunks"] = float(
                            res.stats["n_chunks"].mean())
                    out(emit(f"sharded/{name}/s{ns}_e{exch}{suffix}",
                             dt * 1e3 / b, derived))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny corpus, 1-device mesh, single rep (CI lane)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    run(print, smoke=args.smoke)


if __name__ == "__main__":
    main()
