"""Figure 1 analogue: recall@k and MRR@10 as retrieval depth k varies —
the paper's headline phenomenon (GTI degrades as k shrinks; 2GTI tracks
the original MaxScore). Each method also runs through the chunked batched
engine (descending-bound chunk loop with early exit): the ``*_chunked``
rows report ``chunks_dispatched`` next to ``tiles_visited`` — the
dispatched-work fraction the chunk loop actually executed."""
from __future__ import annotations

from .common import METHODS, emit, run_method

KS = (10, 20, 50, 100, 1000)


def run(out) -> None:
    for method, fill in (("org", "scaled"), ("gti", "zero"),
                         ("2gti_acc", "scaled")):
        for k in KS:
            for traversal in ("full", "chunked"):
                r = run_method("splade_like", fill, METHODS[method](), k=k,
                               timed=False, traversal=traversal)
                derived = {"recall_at_k": r["recall"], "mrr10": r["mrr"],
                           "tiles_visited": r["tiles_visited"]}
                suffix = ""
                if traversal == "chunked":
                    suffix = "_chunked"
                    derived["chunks_dispatched"] = r["chunks_dispatched"]
                    derived["n_chunks"] = r["n_chunks"]
                out(emit(f"figure1/{method}{suffix}/k{k}", float("nan"),
                         derived))
