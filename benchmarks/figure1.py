"""Figure 1 analogue: recall@k and MRR@10 as retrieval depth k varies —
the paper's headline phenomenon (GTI degrades as k shrinks; 2GTI tracks
the original MaxScore)."""
from __future__ import annotations

from .common import METHODS, emit, run_method

KS = (10, 20, 50, 100, 1000)


def run(out) -> None:
    for method, fill in (("org", "scaled"), ("gti", "zero"),
                         ("2gti_acc", "scaled")):
        for k in KS:
            r = run_method("splade_like", fill, METHODS[method](), k=k,
                           timed=False)
            out(emit(f"figure1/{method}/k{k}", float("nan"),
                     {"recall_at_k": r["recall"], "mrr10": r["mrr"]}))
