"""Seconds-scale retrieval perf smoke: the recorded baseline later PRs
diff against.

    PYTHONPATH=src python -m benchmarks.retrieval_smoke [--out PATH]

Writes ``BENCH_retrieval.json`` (repo root by default) with, per method:
``mrt_ms`` (sequential-engine mean response time — the paper's latency
regime), ``tiles_visited`` (full scan), and the chunked batched engine's
``chunks_dispatched`` / ``n_chunks`` / ``tiles_visited`` — the
dispatched-work reduction the early-exit chunk loop delivers. The corpus
is tiny and seeded, so numbers are stable enough to diff across PRs
(``make bench-smoke`` is the CI entry).
"""
from __future__ import annotations

import argparse
import pathlib

from repro.core import build_index, twolevel
from repro.data import make_corpus
from repro.obs import Histogram
from repro.retrieval import Retriever

try:  # package-relative when driven by benchmarks.run
    from .common import emit, write_bench_json
except ImportError:  # python -m benchmarks.retrieval_smoke
    from benchmarks.common import emit, write_bench_json

N_DOCS = 4096
N_TERMS = 1024
N_QUERIES = 128  # >= 100 so p99_ms is a real percentile, not the max
TILE = 128
K = 10
CHUNK_TILES = 4

METHODS = (
    ("org", twolevel.original),
    ("gti", twolevel.gti),
    ("2gti_fast", twolevel.fast),
)


def collect() -> dict:
    corpus = make_corpus("splade_like", n_docs=N_DOCS, n_terms=N_TERMS,
                         n_queries=N_QUERIES, seed=0)
    index = build_index(corpus.merged("scaled"), tile_size=TILE)
    queries = dict(terms=corpus.queries, weights_b=corpus.q_weights_b,
                   weights_l=corpus.q_weights_l)
    methods = {}
    for name, preset in METHODS:
        params = preset(chunk_tiles=CHUNK_TILES)
        seq = Retriever.open(index, params, engine="sequential",
                             k_buckets=None)
        resp = seq.search(**queries, k=K)
        # latency accounting through the obs histogram: mean is exact,
        # p99 is exact-rank (max-clamped bucket edge) — a latency some
        # query actually took, not numpy's interpolated percentile
        hist = Histogram(name=f"latency_ms/{name}")
        hist.record_many(resp.latencies_ms)
        row = {"mrt_ms": round(hist.mean, 3),
               "p99_ms": round(hist.quantile(0.99), 3),
               "tiles_visited": float(resp.stats["tiles_visited"].mean()),
               "n_tiles": float(resp.stats["n_tiles"].mean())}
        ck = Retriever.open(index, params, engine="batched",
                            traversal="chunked", k_buckets=None)
        cresp = ck.search(**queries, k=K)
        row["chunked_tiles_visited"] = float(
            cresp.stats["tiles_visited"].mean())
        row["chunks_dispatched"] = float(
            cresp.stats["chunks_dispatched"].mean())
        row["n_chunks"] = float(cresp.stats["n_chunks"].mean())
        methods[name] = row
    return {"meta": {"corpus": "splade_like", "n_docs": N_DOCS,
                     "n_terms": N_TERMS, "n_queries": N_QUERIES,
                     "tile_size": TILE, "k": K,
                     "chunk_tiles": CHUNK_TILES,
                     # PR10: p99 moved from numpy's interpolated
                     # percentile to obs.metrics exact-rank quantiles
                     # (bucketed, max-clamped); expect small upward p99
                     # shifts vs pre-PR10 recordings
                     "quantiles": "exact_rank_bucketed"},
            "methods": methods}


def run(out) -> None:
    data = collect()
    for name, row in data["methods"].items():
        out(emit(f"retrieval_smoke/{name}", row["mrt_ms"],
                 {k: v for k, v in row.items() if k != "mrt_ms"}))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=None,
                    help="output path (default: <repo>/BENCH_retrieval.json)")
    args = ap.parse_args()
    path = pathlib.Path(args.out) if args.out else (
        pathlib.Path(__file__).resolve().parent.parent
        / "BENCH_retrieval.json")
    data = collect()
    write_bench_json(path, data)
    for name, row in data["methods"].items():
        frac = row["chunks_dispatched"] / max(row["n_chunks"], 1.0)
        print(f"{name}: mrt={row['mrt_ms']:.2f}ms "
              f"tiles={row['tiles_visited']:.1f}/{row['n_tiles']:.0f} "
              f"chunks={row['chunks_dispatched']:.1f}/{row['n_chunks']:.0f} "
              f"({frac:.0%} dispatched)")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
