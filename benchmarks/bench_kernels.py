"""Kernel microbenchmarks: Pallas (interpret mode on CPU) vs jnp oracle.

Interpret-mode timings measure Python emulation, not TPU performance — the
derived column carries the correctness deltas and shapes; wall numbers are
for regression tracking only."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.embedding_bag import embedding_bag
from repro.kernels.flash_attention import flash_attention
from repro.kernels.guided_score import guided_score_tile

from .common import emit


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / reps * 1e3


def run(out) -> None:
    rng = np.random.default_rng(0)
    # guided_score
    nq, p, s = 16, 256, 1024
    offs = np.sort(rng.choice(s, (nq, p), replace=True), axis=1)
    offs = jnp.asarray(offs, jnp.int32)
    wb = jnp.asarray(rng.random((nq, p)), jnp.float32)
    wl = jnp.asarray(rng.random((nq, p)), jnp.float32)
    ess = jnp.asarray(rng.random(nq) < 0.5, jnp.float32)
    pb = jnp.asarray(np.cumsum(rng.random(nq)), jnp.float32)
    args = (offs, wb, wl, ess, pb, jnp.float32(2.0),
            jnp.float32(1.0), jnp.float32(0.3), jnp.float32(0.05))
    t_k = _time(lambda *a: guided_score_tile(*a, tile_size=s, block_s=512),
                *args)
    t_r = _time(lambda *a: ref.guided_score_tile_ref(*a, tile_size=s), *args)
    err = float(jnp.max(jnp.abs(
        guided_score_tile(*args, tile_size=s, block_s=512)
        - ref.guided_score_tile_ref(*args, tile_size=s))))
    out(emit("kernels/guided_score/nq16_p256_s1024", t_k,
             {"ref_ms": t_r, "max_err": err}))
    # flash attention
    q = jnp.asarray(rng.standard_normal((4, 256, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 256, 64)), jnp.float32)
    t_k = _time(lambda q, k, v: flash_attention(q, k, v, causal=True), q, k, v)
    t_r = _time(lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True),
                q, k, v)
    err = float(jnp.max(jnp.abs(flash_attention(q, k, v, causal=True)
                                - ref.flash_attention_ref(q, k, v,
                                                          causal=True))))
    out(emit("kernels/flash_attention/h4_s256_d64", t_k,
             {"ref_ms": t_r, "max_err": err}))
    # embedding bag
    tab = jnp.asarray(rng.standard_normal((4096, 64)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 4096, (64, 8)), jnp.int32)
    w = jnp.asarray(rng.random((64, 8)), jnp.float32)
    t_k = _time(lambda t, i, w: embedding_bag(t, i, w, block_b=8), tab, idx, w)
    t_r = _time(ref.embedding_bag_ref, tab, idx, w)
    err = float(jnp.max(jnp.abs(embedding_bag(tab, idx, w, block_b=8)
                                - ref.embedding_bag_ref(tab, idx, w))))
    out(emit("kernels/embedding_bag/v4096_b64_l8", t_k,
             {"ref_ms": t_r, "max_err": err}))
