"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only tableX ...]

Prints ``name,us_per_call,derived`` CSV rows.
"""
from __future__ import annotations

import argparse
import sys
import time

MODULES = ("figure1", "table2", "table3", "table4", "figure3",
           "table6_suite", "table7_bmw", "table8_qlen", "dense_transfer",
           "bench_kernels", "sharded_scaling", "retrieval_smoke",
           "serving_bench", "quality_bench", "roofline", "million_doc")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    mods = args.only or MODULES
    print("name,us_per_call,derived")

    def out(line: str) -> None:
        print(line, flush=True)

    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(out)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,nan,error={type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
        print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
