"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only tableX ...]

Prints ``name,us_per_call,derived`` CSV rows. After the selected
modules run, every recorded ``BENCH_*.json`` next to this file is
scanned for NaN/inf values — a non-finite number in a committed
benchmark means a lane silently failed, so the harness exits non-zero
and names the offending paths instead of shipping it.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import time

from benchmarks.common import validate_bench_files

MODULES = ("figure1", "table2", "table3", "table4", "figure3",
           "table6_suite", "table7_bmw", "table8_qlen", "dense_transfer",
           "bench_kernels", "sharded_scaling", "retrieval_smoke",
           "serving_bench", "quality_bench", "roofline", "million_doc")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args()
    mods = args.only or MODULES
    print("name,us_per_call,derived")

    def out(line: str) -> None:
        print(line, flush=True)

    for name in mods:
        mod = __import__(f"benchmarks.{name}", fromlist=["run"])
        t0 = time.time()
        try:
            mod.run(out)
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,nan,error={type(e).__name__}: {e}",
                  file=sys.stderr)
            raise
        print(f"# {name} done in {time.time()-t0:.0f}s", file=sys.stderr)

    bad = validate_bench_files(pathlib.Path(__file__).resolve().parent.parent)
    if bad:
        for fname, paths in bad.items():
            print(f"{fname}/ERROR,nan,non_finite={';'.join(paths[:10])}",
                  file=sys.stderr)
        raise SystemExit(
            f"non-finite values in recorded benchmarks: {sorted(bad)}")


if __name__ == "__main__":
    main()
