"""Shared benchmark machinery: corpus/index caches, method runner, CSV."""
from __future__ import annotations

import functools

import numpy as np

from repro.core import build_index, twolevel
from repro.core.metrics import evaluate_run, mean_and_p99
from repro.core.traversal import retrieve_batched, retrieve_sequential
from repro.data import make_corpus

# benchmark-scale corpus (kept moderate: single CPU core)
N_DOCS = 32768
N_TERMS = 4096
N_QUERIES = 32
TILE = 512


@functools.lru_cache(maxsize=8)
def corpus(preset: str, seed: int = 0, n_docs: int = N_DOCS):
    return make_corpus(preset, n_docs=n_docs, n_terms=N_TERMS,
                       n_queries=N_QUERIES, seed=seed)


@functools.lru_cache(maxsize=16)
def index_for(preset: str, fill: str, seed: int = 0, tile: int = TILE,
              n_docs: int = N_DOCS):
    c = corpus(preset, seed, n_docs)
    return build_index(c.merged(fill), tile_size=tile)


def run_method(preset: str, fill: str, params, timed: bool = True,
               seed: int = 0, mrr_cutoff: int = 10):
    """Run one method config; returns metrics dict."""
    c = corpus(preset, seed)
    idx = index_for(preset, fill, seed)
    if timed:
        res = retrieve_sequential(idx, c.queries, c.q_weights_b,
                                  c.q_weights_l, params)
        mrt, p99 = mean_and_p99(res.latencies_ms)
    else:
        res = retrieve_batched(idx, c.queries, c.q_weights_b,
                               c.q_weights_l, params)
        mrt = p99 = float("nan")
    m = evaluate_run(res.ids, c.qrels, params.k, mrr_cutoff)
    st = res.stats
    return {"mrr": m["mrr"], "recall": m["recall"], "ndcg": m["ndcg"],
            "mrt_ms": mrt, "p99_ms": p99,
            "tiles_visited": float(np.mean(st["tiles_visited"])),
            "n_tiles": float(np.mean(st["n_tiles"])),
            "docs_survived": float(np.mean(st["docs_survived"])),
            "docs_present": float(np.mean(st["docs_present"])),
            "docs_frozen": float(np.mean(st["docs_frozen"]))}


METHODS = {
    "org": lambda k: twolevel.original(k=k),
    "gt": lambda k: twolevel.gt(k=k),
    "gti": lambda k: twolevel.gti(k=k),
    "2gti_acc": lambda k: twolevel.accurate(k=k),
    "2gti_fast": lambda k: twolevel.fast(k=k),
    "2gti_fast_impact": lambda k: twolevel.fast(k=k).replace(
        schedule="impact"),
    "linear": lambda k: twolevel.linear_combination(k=k),
}


def emit(name: str, mrt_ms: float, derived: dict) -> str:
    """CSV row: name,us_per_call,derived (k=v;...)."""
    dv = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                  for k, v in derived.items())
    us = mrt_ms * 1e3 if mrt_ms == mrt_ms else float("nan")
    return f"{name},{us:.1f},{dv}"
