"""Shared benchmark machinery: corpus/index/retriever caches, method
runner, CSV. All methods run through the ``repro.retrieval.Retriever``
facade — ``timed=True`` uses the ``sequential`` engine (per-query host
latencies, the paper's regime), ``timed=False`` the ``batched`` engine.
Retrievers are opened in exact-k mode (``k_buckets=None``): a benchmark
sweeping k must measure the depth it names, not the bucket above it.
"""
from __future__ import annotations

import functools

import numpy as np

from repro.core import build_index, twolevel
from repro.core.metrics import evaluate_run, mean_and_p99
from repro.data import make_corpus
from repro.retrieval import Retriever

# benchmark-scale corpus (kept moderate: single CPU core)
N_DOCS = 32768
N_TERMS = 4096
N_QUERIES = 32
TILE = 512


@functools.lru_cache(maxsize=8)
def corpus(preset: str, seed: int = 0, n_docs: int = N_DOCS):
    return make_corpus(preset, n_docs=n_docs, n_terms=N_TERMS,
                       n_queries=N_QUERIES, seed=seed)


@functools.lru_cache(maxsize=16)
def index_for(preset: str, fill: str, seed: int = 0, tile: int = TILE,
              n_docs: int = N_DOCS):
    c = corpus(preset, seed, n_docs)
    return build_index(c.merged(fill), tile_size=tile)


@functools.lru_cache(maxsize=64)
def retriever_for(preset: str, fill: str, params, engine: str,
                  seed: int = 0, traversal: str = "full") -> Retriever:
    """One facade per (index, params, engine, traversal); params hash by
    policy fields, so threshold/schedule variants get distinct entries."""
    opts = {} if engine == "sequential" else {"traversal": traversal}
    return Retriever.open(index_for(preset, fill, seed), params,
                          engine=engine, k_buckets=None, **opts)


def run_method(preset: str, fill: str, params, k: int = 10,
               timed: bool = True, seed: int = 0,
               mrr_cutoff: int = 10, traversal: str = "full"):
    """Run one method config at retrieval depth ``k``; returns metrics.

    ``traversal="chunked"`` routes the batched engine through the
    early-exit chunk loop (descending-bound visit order); the returned
    dict then carries real ``chunks_dispatched`` / ``n_chunks`` counts
    (nan for the full scan and the sequential engine).
    """
    if timed and traversal != "full":
        raise ValueError(
            "timed runs use the sequential engine (host loop with physical "
            "skips), which has no chunked traversal; pass timed=False for "
            "chunked stats")
    c = corpus(preset, seed)
    r = retriever_for(preset, fill, params,
                      "sequential" if timed else "batched", seed, traversal)
    resp = r.search(terms=c.queries, weights_b=c.q_weights_b,
                    weights_l=c.q_weights_l, k=k)
    if timed:
        mrt, p99 = mean_and_p99(resp.latencies_ms)
    else:
        mrt = p99 = float("nan")
    m = evaluate_run(resp.ids, c.qrels, k, mrr_cutoff)
    st = resp.stats
    nan = float("nan")
    return {"mrr": m["mrr"], "recall": m["recall"], "ndcg": m["ndcg"],
            "mrt_ms": mrt, "p99_ms": p99,
            "tiles_visited": float(np.mean(st["tiles_visited"])),
            "n_tiles": float(np.mean(st["n_tiles"])),
            "docs_survived": float(np.mean(st["docs_survived"])),
            "docs_present": float(np.mean(st["docs_present"])),
            "docs_frozen": float(np.mean(st["docs_frozen"])),
            "chunks_dispatched": (float(np.mean(st["chunks_dispatched"]))
                                  if "chunks_dispatched" in st else nan),
            "n_chunks": (float(np.mean(st["n_chunks"]))
                         if "n_chunks" in st else nan)}


METHODS = {
    "org": twolevel.original,
    "gt": twolevel.gt,
    "gti": twolevel.gti,
    "2gti_acc": twolevel.accurate,
    "2gti_fast": twolevel.fast,
    "2gti_fast_impact": lambda: twolevel.fast().replace(schedule="impact"),
    "linear": twolevel.linear_combination,
}


def emit(name: str, mrt_ms: float, derived: dict) -> str:
    """CSV row: name,us_per_call,derived (k=v;...)."""
    dv = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                  for k, v in derived.items())
    us = mrt_ms * 1e3 if mrt_ms == mrt_ms else float("nan")
    return f"{name},{us:.1f},{dv}"


def check_finite(obj, path: str = "$") -> list[str]:
    """Paths of every NaN/inf number in a JSON-able tree. A recorded
    BENCH_*.json with a non-finite value means a lane silently failed —
    the run harness fails loudly instead of committing it."""
    bad = []
    if isinstance(obj, dict):
        for k, v in obj.items():
            bad += check_finite(v, f"{path}.{k}")
    elif isinstance(obj, (list, tuple)):
        for i, v in enumerate(obj):
            bad += check_finite(v, f"{path}[{i}]")
    elif isinstance(obj, bool):
        pass
    elif isinstance(obj, (int, float)):
        if not np.isfinite(obj):
            bad.append(path)
    return bad


def write_bench_json(path, data: dict) -> None:
    """The single BENCH_*.json writer: refuses non-finite values, then
    writes deterministic (sorted, indented) JSON."""
    import json
    import pathlib
    bad = check_finite(data)
    if bad:
        raise ValueError(
            f"refusing to write {path}: non-finite values at "
            f"{', '.join(bad[:10])}" + (" ..." if len(bad) > 10 else ""))
    pathlib.Path(path).write_text(
        json.dumps(data, indent=2, sort_keys=True) + "\n")


def validate_bench_files(root) -> dict:
    """Scan every BENCH_*.json under ``root`` for non-finite values;
    returns {filename: [bad paths]} for offenders (empty = clean)."""
    import json
    import pathlib
    bad = {}
    for p in sorted(pathlib.Path(root).glob("BENCH_*.json")):
        paths = check_finite(json.loads(p.read_text()))
        if paths:
            bad[p.name] = paths
    return bad
