#!/usr/bin/env python
"""Fit the serving cost model (chunk-count predictor) from traces.

    PYTHONPATH=src python scripts/fit_cost_model.py --traces spans.json
    PYTHONPATH=src python scripts/fit_cost_model.py --synthetic

Two sources:

- ``--traces PATH``: a JSON list of span dicts — a saved
  ``Tracer.export()``, or ``curl http://host:port/traces`` from a
  ``--metrics-port`` serving process. Every span carrying both
  ``cost_features`` and ``chunks_dispatched`` attributes is a sample.
  This path is jax-free: fitting is pure numpy.
- ``--synthetic``: build a seeded corpus in-process, serve a traced
  mixed-length workload through one chunked route, and fit from those
  spans (needs jax; what CI and a cold start use).

Writes ``cost_model.json`` (``--out``) — loadable by
``repro.obs.CostModel.load`` and ``repro-serve --cost-model`` — and
prints the fit's R² over its training samples.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent
                       / "src"))


def synthetic_spans(n_docs: int = 4096, n_terms: int = 1024,
                    n_requests: int = 160, chunk_tiles: int = 2) -> list:
    """Spans from a traced serving run over a seeded corpus: the same
    single-chunked-route regime ``benchmarks/serving_bench.py``'s
    cost_dispatch lanes use."""
    from repro.core import build_index, twolevel
    from repro.data import make_corpus
    from repro.obs import Tracer
    from repro.serve import (AsyncRetrievalScheduler, SchedulerConfig,
                             mixed_request_stream, run_workload,
                             single_route)
    corpus = make_corpus("splade_like", n_docs=n_docs, n_terms=n_terms,
                         n_queries=32, n_q_terms=12, seed=0)
    index = build_index(corpus.merged("scaled"), tile_size=128)
    params = twolevel.fast().replace(schedule="impact")
    tracer = Tracer(capacity=8192)
    sched = AsyncRetrievalScheduler(
        index, params,
        SchedulerConfig(max_batch=8, max_wait_ms=100.0, cache_size=0,
                        tracer=tracer),
        routing=single_route("batched", traversal="chunked",
                             chunk_tiles=chunk_tiles))
    run_workload(sched, mixed_request_stream(corpus, n_requests,
                                             k_pool=(10, 100)),
                 qps=100.0, seed=3)
    return tracer.export()


def main() -> None:
    ap = argparse.ArgumentParser()
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--traces", metavar="PATH",
                     help="JSON span list (Tracer.export() / GET /traces)")
    src.add_argument("--synthetic", action="store_true",
                     help="fit from a traced in-process workload on a "
                          "seeded corpus")
    ap.add_argument("--out", default="cost_model.json",
                    help="model output path (default: ./cost_model.json)")
    ap.add_argument("--l2", type=float, default=1e-3,
                    help="ridge strength")
    args = ap.parse_args()

    from repro.obs import CostModel
    if args.traces:
        spans = json.loads(pathlib.Path(args.traces).read_text())
        if not isinstance(spans, list):
            raise SystemExit(f"{args.traces}: expected a JSON list of "
                             f"span dicts, got {type(spans).__name__}")
    else:
        spans = synthetic_spans()
    model = CostModel.fit_from_traces(spans, l2=args.l2)
    model.save(args.out)
    print(f"fit {model.n_samples} samples: r2={model.r2:.4f}")
    for name, w in zip(model.features, model.weights):
        print(f"  {name:10s} {float(w):.6f}")
    print(f"intercept    {model.intercept:.6f}")
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
