"""Synthetic graded-qrels corpora with a planted dense modality.

``repro.data.make_corpus`` already plants relevance into the *sparse*
impact weights (and, with ``n_rel_partial``, a grade-1 tier); this
module adds the second modality the hybrid engines need: per-document
embeddings plus a ``q_proj`` term-projection such that

- a query's embedding (learned-weight-weighted sum of its terms'
  projection rows, L2-normalized — exactly what
  ``repro.retrieval.hybrid.embed_queries`` computes at query time) has
  planted cosine affinity to its relevant docs, scaled by grade;
- the BM25-strong distractors get a *weaker but nonzero* affinity, so
  the dense ranking is good-but-imperfect — neither modality alone is
  trivially right, which is what makes cascade/RRF measurable instead
  of degenerate;
- every other document is isotropic noise.

Everything is seed-pinned: two calls with the same arguments produce
bit-identical corpora, embeddings, and qrels (the determinism contract
``BENCH_quality.json`` relies on).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.index import BlockedImpactIndex, build_index
from ..data import make_corpus
from ..data.corpus import SyntheticCorpus
from ..retrieval.hybrid import HybridIndex, build_hybrid_index


@dataclasses.dataclass
class GradedCorpus:
    """A synthetic corpus plus its graded judgments and dense modality."""
    corpus: SyntheticCorpus
    qrels: list[dict[int, float]]    # per query: docid -> gain
    doc_emb: np.ndarray              # [n_docs, D] original-docid order
    q_proj: np.ndarray               # [n_terms, D] query-term projection

    @property
    def binary_qrels(self) -> list[set[int]]:
        """Any positive gain counts as relevant (MRR / recall view)."""
        return [set(g) for g in self.qrels]

    def queries(self) -> dict:
        """The sparse query batch as ``Retriever.search`` kwargs."""
        c = self.corpus
        return dict(terms=c.queries, weights_b=c.q_weights_b,
                    weights_l=c.q_weights_l)


def _embed_queries_np(q_proj: np.ndarray, terms: np.ndarray,
                      weights_l: np.ndarray) -> np.ndarray:
    """Host-side twin of ``hybrid._embed_impl`` (pre-rotation): the
    planting below must target the exact vectors the engines will
    compute at query time."""
    e = (q_proj[terms] * weights_l[..., None]).sum(axis=-2)
    n = np.linalg.norm(e, axis=-1, keepdims=True)
    return e / np.maximum(n, 1e-9)


def make_graded_corpus(preset: str = "splade_like", *, n_docs: int = 4096,
                       n_terms: int = 1024, n_queries: int = 32,
                       n_q_terms: int = 6, n_rel: int = 1,
                       n_rel_partial: int = 3, avg_doc_terms: int = 48,
                       dim: int = 32, seed: int = 0,
                       rel_boost_scale: float = 1.0,
                       rel_affinity: float = 1.0,
                       distract_affinity: float = 0.25,
                       noise: float = 1.0) -> GradedCorpus:
    """Generate a corpus with graded sparse relevance *and* a consistent
    planted dense modality.

    ``rel_affinity`` scales the grade-proportional pull of relevant docs
    toward their query's embedding; ``distract_affinity`` the (weaker)
    pull of the planted BM25-strong distractors — set it to 0 for a
    clean-separation corpus where dense alone is near-perfect.

    The defaults are deliberately *contested*: ``n_rel=1`` keeps MRR@10
    unsaturated (one prunable target per query instead of four chances),
    and ``rel_affinity=1.0`` puts relevant docs' dense cosine (~0.7)
    within reach of the corpus-wide noise tail, so dense-alone over the
    full corpus is good-but-imperfect while an exact rerank of a ~100-doc
    sparse candidate set (whose noise tail is far smaller) is near-exact —
    the cascade's advantage is structural, not planted."""
    corpus = make_corpus(preset, n_docs=n_docs, n_terms=n_terms,
                         n_queries=n_queries, n_q_terms=n_q_terms,
                         n_rel=n_rel, avg_doc_terms=avg_doc_terms,
                         seed=seed, n_rel_partial=n_rel_partial,
                         rel_boost_scale=rel_boost_scale)
    # independent stream: embedding draws must not perturb (or depend on
    # draw-order details of) the sparse corpus generator
    rng = np.random.default_rng(seed + 104729)
    q_proj = (rng.standard_normal((n_terms, dim)) / np.sqrt(dim)
              ).astype(np.float32)
    q_emb = _embed_queries_np(q_proj, corpus.queries, corpus.q_weights_l)
    doc_emb = (rng.standard_normal((n_docs, dim)) * noise / np.sqrt(dim)
               ).astype(np.float32)
    gmax = max((max(g.values()) for g in corpus.qrels_graded if g),
               default=1.0)
    for qi, gains in enumerate(corpus.qrels_graded):
        for d, g in gains.items():
            doc_emb[d] += rel_affinity * (g / gmax) * q_emb[qi]
        for d in corpus.q_distractors[qi]:
            doc_emb[d] += distract_affinity * q_emb[qi]
    doc_emb /= np.maximum(
        np.linalg.norm(doc_emb, axis=1, keepdims=True), 1e-9)
    return GradedCorpus(corpus=corpus, qrels=corpus.qrels_graded,
                        doc_emb=doc_emb, q_proj=q_proj)


def build_hybrid(graded: GradedCorpus, tile_size: int = 128,
                 fill: str = "scaled", block_size: int = 512,
                 d_cheap: int | None = None,
                 sparse_index: BlockedImpactIndex | None = None
                 ) -> HybridIndex:
    """BII + dense index + query bridge for one graded corpus — the
    index every quality-bench engine lane opens on. Pass a prebuilt
    ``sparse_index`` to reuse an existing BII (it must come from the
    same corpus)."""
    if sparse_index is None:
        sparse_index = build_index(graded.corpus.merged(fill),
                                   tile_size=tile_size)
    return build_hybrid_index(sparse_index, graded.doc_emb, graded.q_proj,
                              block_size=block_size, d_cheap=d_cheap)
