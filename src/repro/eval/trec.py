"""TREC-format qrels / run-file interchange.

The synthetic harness carries judgments as in-memory dicts, but real
collections (MS MARCO, BEIR) ship them as TREC text files; this module
is the bridge so the same ``evaluate_ranking`` driver scores either.
Formats (whitespace-separated, one judgment/result per line):

    qrels:  qid  iteration  docid  grade
    run:    qid  Q0         docid  rank  score  tag

Ids are kept as strings (TREC ids are opaque tokens like ``MARCO_1234``)
and mapped to dense integer indices on load, so the numeric metric
kernels in ``core.metrics`` apply unchanged. Grades <= 0 lines are kept
in the qrels mapping as explicit non-relevant judgments (standard TREC
practice) but contribute zero gain.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np

from .harness import evaluate_ranking


@dataclasses.dataclass
class TrecQrels:
    """Graded judgments keyed by string qid/docid, plus the dense-int
    view the metric kernels consume."""
    gains: dict[str, dict[str, float]]       # qid -> docid -> grade
    doc_index: dict[str, int]                # docid -> dense int

    @property
    def qids(self) -> list[str]:
        return sorted(self.gains)

    def graded(self, qids: list[str]) -> list[dict[int, float]]:
        """Positive-gain judgments in dense-int space, one dict per qid
        (missing qids -> empty: unjudged queries score zero)."""
        return [{self.doc_index[d]: g
                 for d, g in self.gains.get(q, {}).items() if g > 0}
                for q in qids]


def load_qrels(path) -> TrecQrels:
    gains: dict[str, dict[str, float]] = {}
    doc_index: dict[str, int] = {}
    for ln, line in enumerate(Path(path).read_text().splitlines(), 1):
        parts = line.split()
        if not parts:
            continue
        if len(parts) != 4:
            raise ValueError(f"{path}:{ln}: expected 'qid iter docid "
                             f"grade', got {line!r}")
        qid, _, docid, grade = parts
        gains.setdefault(qid, {})[docid] = float(grade)
        doc_index.setdefault(docid, len(doc_index))
    return TrecQrels(gains=gains, doc_index=doc_index)


def load_run(path, qrels: TrecQrels,
             depth: int = 1000) -> tuple[list[str], np.ndarray]:
    """Read a TREC run into a ranked [Q, depth] dense-int id matrix.

    Rows follow the run's qid order of first appearance; within a row,
    results are ordered by the file's rank column. Docids never seen in
    the qrels map to fresh indices (they are unjudged, not errors);
    rows shorter than ``depth`` pad with the -1 sentinel."""
    per_q: dict[str, list[tuple[int, str]]] = {}
    order: list[str] = []
    for ln, line in enumerate(Path(path).read_text().splitlines(), 1):
        parts = line.split()
        if not parts:
            continue
        if len(parts) != 6:
            raise ValueError(f"{path}:{ln}: expected 'qid Q0 docid rank "
                             f"score tag', got {line!r}")
        qid, _, docid, rank, _, _ = parts
        if qid not in per_q:
            per_q[qid] = []
            order.append(qid)
        per_q[qid].append((int(rank), docid))
    ids = np.full((len(order), depth), -1, np.int32)
    for row, qid in enumerate(order):
        ranked = sorted(per_q[qid])[:depth]
        for col, (_, docid) in enumerate(ranked):
            ids[row, col] = qrels.doc_index.setdefault(
                docid, len(qrels.doc_index))
    return order, ids


def write_run(path, qids: list[str], ids: np.ndarray, scores: np.ndarray,
              tag: str = "repro") -> None:
    """Emit a ranked batch as a TREC run file (integer docids are
    written verbatim as the docid tokens; -1 sentinels are dropped)."""
    lines = []
    for qid, row_ids, row_scores in zip(qids, np.asarray(ids),
                                        np.asarray(scores)):
        rank = 0
        for d, s in zip(row_ids, row_scores):
            if int(d) < 0:
                continue
            rank += 1
            lines.append(f"{qid} Q0 {int(d)} {rank} {float(s):.6f} {tag}")
    Path(path).write_text("\n".join(lines) + ("\n" if lines else ""))


def evaluate_trec(run_path, qrels_path) -> dict[str, float]:
    """Score a TREC run file against a TREC qrels file with the same
    metric grid the synthetic harness reports."""
    qrels = load_qrels(qrels_path)
    qids, ids = load_run(run_path, qrels)
    return evaluate_ranking(ids, qrels.graded(qids))
