"""Relevance-evaluation driver: engine -> metrics-next-to-latency.

The paper's whole argument is a *joint* claim — guided traversal buys
mean response time without giving up rank quality (until it does, at
small k under misalignment). That claim is only checkable when MRR/nDCG/
recall and MRT come out of the same run over the same judged queries;
this module is that seam. ``benchmarks/quality_bench.py`` drives it to
produce the committed ``BENCH_quality.json`` grid, and the regression
tests call it directly.

Metric cut-offs follow the paper's tables: MRR@10, nDCG@10,
Recall@{10, 100} (so rankings must reach depth >= 100 for the full set;
shallower rankings simply score what they have).
"""
from __future__ import annotations

import time

import numpy as np

from ..core.metrics import (mean_and_p99, mrr_at_k, ndcg_at_k,
                            recall_at_k)

# (metric name, cutoff) grid of the reported quality columns
QUALITY_METRICS = (("mrr", 10), ("ndcg", 10), ("recall", 10),
                   ("recall", 100))


def evaluate_ranking(ids: np.ndarray, qrels: list[dict[int, float]],
                     ) -> dict[str, float]:
    """Mean quality metrics of one ranked-id batch against graded qrels.

    ``ids`` [B, depth] original docids (-1 sentinels ignored by the
    metric guards); ``qrels`` per-query docid -> gain. Binary metrics
    (MRR, recall) treat any positive gain as relevant; nDCG uses the
    gains. Returns ``{"mrr@10": ..., "ndcg@10": ..., "recall@10": ...,
    "recall@100": ...}``."""
    ids = np.asarray(ids)
    if ids.shape[0] != len(qrels):
        raise ValueError(f"{ids.shape[0]} ranked rows vs {len(qrels)} "
                         f"judged queries")
    acc: dict[str, list[float]] = {f"{m}@{c}": [] for m, c in QUALITY_METRICS}
    for row, gains in zip(ids, qrels):
        rel = {d for d, g in gains.items() if g > 0}
        acc["mrr@10"].append(mrr_at_k(row, rel, 10))
        acc["ndcg@10"].append(ndcg_at_k(row, gains, 10))
        acc["recall@10"].append(recall_at_k(row, rel, 10))
        acc["recall@100"].append(recall_at_k(row, rel, 100))
    return {name: float(np.mean(vals)) for name, vals in acc.items()}


def evaluate_retriever(retriever, queries: dict,
                       qrels: list[dict[int, float]], *, k: int = 100,
                       threshold_factor: float | None = None,
                       warmup: bool = True, repeats: int = 1) -> dict:
    """Run one engine over a judged query batch: quality + timing.

    ``queries`` is the kwargs dict ``Retriever.search`` takes (``terms``
    / ``weights_b`` / ``weights_l`` and optionally ``dense``). A warmup
    call absorbs compilation so ``mrt_ms`` (mean per-query response
    time, the paper's MRT) reflects steady-state execution; ``repeats``
    timed calls feed the p99."""
    if warmup:
        retriever.search(k=k, threshold_factor=threshold_factor, **queries)
    lats = []
    resp = None
    for _ in range(max(1, int(repeats))):
        t0 = time.perf_counter()
        resp = retriever.search(k=k, threshold_factor=threshold_factor,
                                **queries)
        lats.append((time.perf_counter() - t0) * 1e3)
    n_q = resp.ids.shape[0]
    per_query = np.asarray(lats) / max(n_q, 1)
    mrt, p99 = mean_and_p99(per_query)
    out = evaluate_ranking(resp.ids, qrels)
    out.update(engine=retriever.engine_name, k=int(k),
               mrt_ms=mrt, p99_ms=p99, n_queries=int(n_q))
    return out
