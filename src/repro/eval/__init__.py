"""Qrels-based relevance harness: did the fast thing return the right
documents?

``benchmarks/`` answer "how fast"; this package answers "how good" —
and reports both from the same run, because the paper's claim (guided
traversal keeps quality until small-k misalignment breaks it; hybrid
second stages recover it) is inherently a quality-vs-latency joint
statement:

  - :mod:`synthetic` — graded-qrels corpora with a planted dense
    modality consistent with the sparse relevance structure
    (``make_graded_corpus`` / ``build_hybrid``);
  - :mod:`harness` — the evaluation driver: ``evaluate_ranking``
    (MRR@10 / nDCG@10 / Recall@{10,100} from any ranked-id batch) and
    ``evaluate_retriever`` (one engine -> quality metrics + warmed MRT);
  - :mod:`trec` — TREC qrels/run file interchange, so the same driver
    scores real collections (``evaluate_trec``).
"""
from .harness import (QUALITY_METRICS, evaluate_ranking,  # noqa: F401
                      evaluate_retriever)
from .synthetic import (GradedCorpus, build_hybrid,  # noqa: F401
                        make_graded_corpus)
from .trec import (TrecQrels, evaluate_trec, load_qrels,  # noqa: F401
                   load_run, write_run)
