from .engine import (RetrievalServer, Request,  # noqa: F401
                     ServerConfig)
from .executor import ExecutorPool  # noqa: F401
from .router import (Route, RoutingPolicy, query_length, route,  # noqa: F401
                     single_route, table8_policy, warmup_grid)
from .scheduler import (ADMISSION_POLICIES,  # noqa: F401
                        AsyncRetrievalScheduler, SchedulerConfig,
                        SchedulerSaturated, SearchHandle,
                        aggregate_latencies, mixed_request_stream,
                        run_workload, truncate_terms)
from .sharded import (ShardedRetrievalServer, make_shard_mesh,  # noqa: F401
                      shard_retrieve_batched)
