from .engine import (RetrievalServer, Request,  # noqa: F401
                     ServerConfig)
from .sharded import (ShardedRetrievalServer, make_shard_mesh,  # noqa: F401
                      shard_retrieve_batched)
