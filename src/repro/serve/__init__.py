from .engine import (RetrievalServer, Request,  # noqa: F401
                     ServerConfig)
from .executor import ExecutorPool, ReplicaMap  # noqa: F401
from .faults import (Fault, FaultPlan, InjectedDeath,  # noqa: F401
                     InjectedFault, delay_route, fail_batch,
                     kill_executor, poison_generation)
from .health import (BREAKER_CLOSED, BREAKER_DEAD,  # noqa: F401
                     BREAKER_HALF_OPEN, BREAKER_OPEN, HealthConfig,
                     HealthMonitor, RetryPolicy)
from .router import (Route, RoutingPolicy, policy_summary,  # noqa: F401
                     query_length, route, single_route, table8_policy,
                     warmup_grid)
from .scheduler import (ADMISSION_POLICIES,  # noqa: F401
                        CACHE_ADMISSIONS, AsyncRetrievalScheduler,
                        DeadlineExceeded, SchedulerConfig,
                        SchedulerSaturated, SearchHandle, SearchTimeout,
                        aggregate_latencies, mixed_request_stream,
                        run_workload, truncate_terms)
from .sharded import (ShardedRetrievalServer, make_shard_mesh,  # noqa: F401
                      shard_retrieve_batched)
