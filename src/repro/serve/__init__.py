from .engine import (RetrievalServer, Request,  # noqa: F401
                     ServerConfig)
