"""Executor pool: N concurrent batch executors over one scheduler.

The layer between :meth:`AsyncRetrievalScheduler._pick_batch` and batch
execution. Each executor is a worker thread holding its **own
Retriever replica per route** (``Retriever.replicate()``: a fresh
engine dispatch surface sharing the open index device arrays — no
index rebuild, no re-partition), pulling picked micro-batches
concurrently from the scheduler's (k-bucket x length-class) group
queues. The scheduler stays the single source of truth: admission,
grouping, deadlines, the response cache, and every counter live behind
its lock; executors only race on *pick* (serialized by that same lock)
and then run ``Retriever.search`` outside it.

Why replicas at all, when jax jit caches are process-global? The
compiled computations are shared — one warmup pass compiles the whole
routing grid for every executor at once — but the *Python* dispatch
path (engine objects, per-call state) is not designed for concurrent
reuse; a replica per worker makes each batch's host-side path private
by construction instead of by audit.

Lifecycle: ``start()`` warms the full (route x k-bucket) grid via
:meth:`AsyncRetrievalScheduler.warmup`, pre-builds every slot's replica
map, then spawns the workers. ``close(drain=True)`` flips the stop
flag and lets the executors themselves drain the group queues before
exiting — close-time backlog still runs on all N replicas
concurrently, and every outstanding ``SearchHandle`` resolves before
``close`` returns.

Fault tolerance hooks (``serve.health`` / ``serve.faults``): before
picking, a worker consults its circuit breaker
(``scheduler.health.allow``) — an open breaker idles the slot until
its half-open probe is due — and the fault plan's ``on_pick`` (a
scripted ``die`` fault unwinds the thread here, *outside* batch
execution). A worker that dies this way is reported to the scheduler
(``executor_deaths`` / ``dead_executors`` in ``stats()``) and its
breaker goes terminally dead; the remaining workers keep serving.
When the queue is idle, a worker hedges straggler batches running on
*other* slots (``scheduler.hedge_due``) — first result wins. Replica
maps are generation-tagged (:class:`ReplicaMap`): after an index
hot-swap, the next resolve clears and rebuilds them from the new
masters, so the flip needs no pool restart.

Determinism: N executors produce bit-identical responses to the
single-worker path. A picked batch is an ordered list of whole
requests executed in one ``search`` call; which *replica* runs it
cannot change its result (same compiled computation, same index
buffers), and the response cache stores per-request slices keyed on
content, not on arrival interleaving.
"""
from __future__ import annotations

import threading
import time


class ReplicaMap(dict):
    """One slot's {route_name: Retriever replica} map, tagged with the
    index generation it was replicated from. The scheduler's
    ``_resolve_retriever`` clears + rebuilds a map whose generation
    trails the installed index — the lazy half of the hot-swap gate."""

    def __init__(self, *args, generation: int = 0, **kwargs):
        super().__init__(*args, **kwargs)
        self.generation = generation


class ExecutorPool:
    """N worker threads executing a scheduler's picked micro-batches.

    Built (and owned) by :meth:`AsyncRetrievalScheduler.start` when
    ``SchedulerConfig.executors > 0``; usable standalone in tests via
    ``ExecutorPool(scheduler, n).start()``.
    """

    def __init__(self, scheduler, n_executors: int, *,
                 warmup: bool = True):
        if n_executors < 1:
            raise ValueError(
                f"an ExecutorPool needs >= 1 executors, got {n_executors}")
        self.scheduler = scheduler
        self.n_executors = n_executors
        self._do_warmup = warmup
        self._threads: list[threading.Thread] = []
        # slot -> ReplicaMap; built at start() so the first picked batch
        # never pays replication, extended lazily by _execute if a route
        # first appears after start, rebuilt after an index hot-swap
        self.replicas: dict[int, ReplicaMap] = {}
        self._stop = False
        self._drain = True

    def is_running(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    def start(self) -> "ExecutorPool":
        """Warm the routing grid, build per-slot replicas, spawn workers
        (idempotent while running)."""
        if self.is_running():
            return self
        sched = self.scheduler
        if self._do_warmup:
            sched.warmup()
        for slot in range(self.n_executors):
            self.replicas[slot] = ReplicaMap(
                {r.name: sched._retriever(r.name).replicate()
                 for r in sched.routing.all_routes},
                generation=sched.generation)
        self._stop = False
        self._drain = True
        self._threads = [
            threading.Thread(target=self._run, args=(slot,),
                             name=f"retrieval-executor-{slot}", daemon=True)
            for slot in range(self.n_executors)]
        for t in self._threads:
            t.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Stop the workers. ``drain=True`` (default) has them empty the
        group queues first — deadlines are waived, every pending request
        executes, all handles resolve — before the threads exit."""
        sched = self.scheduler
        with sched._cond:
            self._stop = True
            self._drain = drain
            sched._cond.notify_all()
        for t in self._threads:
            t.join()
        self._threads = []

    def swap_index(self, index, params=None, *, warm: bool = True) -> int:
        """Install a rebuilt index as a new generation without stopping
        the pool — delegates to
        :meth:`AsyncRetrievalScheduler.swap_index` (warm the new grid,
        flip masters between batches); each slot's :class:`ReplicaMap`
        rebuilds itself on its next resolve."""
        return self.scheduler.swap_index(index, params, warm=warm)

    def _run(self, slot: int) -> None:
        """One executor's loop (see :meth:`_serve`): any escape that is
        not a normal return is a thread death *outside* batch execution
        — no handle is stranded by it, but the operator must see it."""
        try:
            self._serve(slot)
        except BaseException as exc:  # noqa: BLE001 — liveness accounting
            self.scheduler._record_executor_death(slot, exc)

    def _serve(self, slot: int) -> None:
        """Pick a due batch (under the scheduler lock), execute it on
        this slot's replicas (outside it), repeat; when idle, hedge a
        straggler batch from another slot or park on the condition
        until the next deadline. A slot whose breaker is open idles
        until its half-open probe is due (drain waives the gate so
        ``close`` can never hang on a broken breaker)."""
        sched = self.scheduler
        retrievers = self.replicas.setdefault(slot, ReplicaMap())
        while True:
            force = False
            with sched._cond:
                if self._stop:
                    if not self._drain or not sched._groups:
                        return
                    force = True   # drain: waive deadlines, take the rest
            if sched.faults is not None:
                # the scripted-death hook: outside _execute's failure
                # delivery, so a raise here unwinds the worker itself
                sched.faults.on_pick(executor_id=slot)
            now = time.perf_counter()
            if not force and not sched.health.allow(slot, now):
                with sched._cond:
                    sched._cond.wait(timeout=0.01)
                continue
            picked = sched._pick_batch(now, force)
            if picked is None:
                # idle: volunteer as the hedge executor for straggler
                # batches whose primary is another slot
                hedged = 0
                for token in sched.hedge_due(now=now,
                                             exclude_executor=slot):
                    hedged += 1
                    try:
                        sched._run_attempt(token, retrievers=retrievers,
                                           executor_id=slot)
                    except Exception:
                        # failed attempts resolve their own handles
                        pass
                if hedged:
                    continue
                with sched._cond:
                    if self._stop:
                        if not self._drain or not sched._groups:
                            return
                        continue   # another slot is mid-pick; retry
                    deadlines = [max(e.deadline, e.not_before)
                                 for g in sched._groups.values() for e in g]
                    wait = 0.05
                    if deadlines:
                        wait = min(wait, min(deadlines) -
                                   time.perf_counter())
                    sched._cond.wait(timeout=max(wait, 1e-3))
                continue
            t_exec = time.perf_counter()
            try:
                sched._execute(*picked, retrievers=retrievers,
                               executor_id=slot)
            except Exception:
                # the batch's handles were already failed by _execute;
                # this executor must keep serving everyone else
                pass
            finally:
                # wall time this slot spent executing (success or not) —
                # the per-executor utilization signal next to the
                # scheduler's delivery-side batch_service_ms
                sched.metrics.histogram("executor_service_ms").record(
                    (time.perf_counter() - t_exec) * 1e3)
