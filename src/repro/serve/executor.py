"""Executor pool: N concurrent batch executors over one scheduler.

The layer between :meth:`AsyncRetrievalScheduler._pick_batch` and batch
execution. Each executor is a worker thread holding its **own
Retriever replica per route** (``Retriever.replicate()``: a fresh
engine dispatch surface sharing the open index device arrays — no
index rebuild, no re-partition), pulling picked micro-batches
concurrently from the scheduler's (k-bucket x length-class) group
queues. The scheduler stays the single source of truth: admission,
grouping, deadlines, the response cache, and every counter live behind
its lock; executors only race on *pick* (serialized by that same lock)
and then run ``Retriever.search`` outside it.

Why replicas at all, when jax jit caches are process-global? The
compiled computations are shared — one warmup pass compiles the whole
routing grid for every executor at once — but the *Python* dispatch
path (engine objects, per-call state) is not designed for concurrent
reuse; a replica per worker makes each batch's host-side path private
by construction instead of by audit.

Lifecycle: ``start()`` warms the full (route x k-bucket) grid via
:meth:`AsyncRetrievalScheduler.warmup`, pre-builds every slot's replica
map, then spawns the workers. ``close(drain=True)`` flips the stop
flag and lets the executors themselves drain the group queues before
exiting — close-time backlog still runs on all N replicas
concurrently, and every outstanding ``SearchHandle`` resolves before
``close`` returns.

Determinism: N executors produce bit-identical responses to the
single-worker path. A picked batch is an ordered list of whole
requests executed in one ``search`` call; which *replica* runs it
cannot change its result (same compiled computation, same index
buffers), and the response cache stores per-request slices keyed on
content, not on arrival interleaving.
"""
from __future__ import annotations

import threading
import time


class ExecutorPool:
    """N worker threads executing a scheduler's picked micro-batches.

    Built (and owned) by :meth:`AsyncRetrievalScheduler.start` when
    ``SchedulerConfig.executors > 0``; usable standalone in tests via
    ``ExecutorPool(scheduler, n).start()``.
    """

    def __init__(self, scheduler, n_executors: int, *,
                 warmup: bool = True):
        if n_executors < 1:
            raise ValueError(
                f"an ExecutorPool needs >= 1 executors, got {n_executors}")
        self.scheduler = scheduler
        self.n_executors = n_executors
        self._do_warmup = warmup
        self._threads: list[threading.Thread] = []
        # slot -> {route_name: Retriever replica}; built at start() so
        # the first picked batch never pays replication, extended lazily
        # by _execute if a route first appears after start
        self.replicas: dict[int, dict] = {}
        self._stop = False
        self._drain = True

    def is_running(self) -> bool:
        return any(t.is_alive() for t in self._threads)

    def start(self) -> "ExecutorPool":
        """Warm the routing grid, build per-slot replicas, spawn workers
        (idempotent while running)."""
        if self.is_running():
            return self
        sched = self.scheduler
        if self._do_warmup:
            sched.warmup()
        for slot in range(self.n_executors):
            self.replicas[slot] = {
                r.name: sched._retriever(r.name).replicate()
                for r in sched.routing.routes}
        self._stop = False
        self._drain = True
        self._threads = [
            threading.Thread(target=self._run, args=(slot,),
                             name=f"retrieval-executor-{slot}", daemon=True)
            for slot in range(self.n_executors)]
        for t in self._threads:
            t.start()
        return self

    def close(self, drain: bool = True) -> None:
        """Stop the workers. ``drain=True`` (default) has them empty the
        group queues first — deadlines are waived, every pending request
        executes, all handles resolve — before the threads exit."""
        sched = self.scheduler
        with sched._cond:
            self._stop = True
            self._drain = drain
            sched._cond.notify_all()
        for t in self._threads:
            t.join()
        self._threads = []

    def _run(self, slot: int) -> None:
        """One executor's loop: pick a due batch (under the scheduler
        lock), execute it on this slot's replicas (outside it), repeat;
        park on the condition until the next deadline when idle."""
        sched = self.scheduler
        retrievers = self.replicas.setdefault(slot, {})
        while True:
            force = False
            with sched._cond:
                if self._stop:
                    if not self._drain or not sched._groups:
                        return
                    force = True   # drain: waive deadlines, take the rest
            picked = sched._pick_batch(time.perf_counter(), force)
            if picked is None:
                with sched._cond:
                    if self._stop:
                        if not self._drain or not sched._groups:
                            return
                        continue   # another slot is mid-pick; retry
                    deadlines = [e.deadline
                                 for g in sched._groups.values() for e in g]
                    wait = 0.05
                    if deadlines:
                        wait = min(wait, min(deadlines) -
                                   time.perf_counter())
                    sched._cond.wait(timeout=max(wait, 1e-3))
                continue
            try:
                sched._execute(*picked, retrievers=retrievers,
                               executor_id=slot)
            except Exception:
                # the batch's handles were already failed by _execute;
                # this executor must keep serving everyone else
                pass
