"""Query-length routing for the serving scheduler (paper Table 8).

The paper's Appendix-B finding: the best traversal variant depends on
query length — short queries skip more and prefer a finer skip grid
(VBMW-flavored / small chunks), long queries amortize better over larger
blocks (MaxScore-flavored / bigger chunks, or the fused kernel). Our
chunked executor exposes exactly that dial (``chunk_tiles``), so routing
is declarative: a :class:`RoutingPolicy` is an ordered tuple of
:class:`Route` length classes, each naming an engine configuration from
the ``repro.retrieval`` registry.

    policy = RoutingPolicy((
        route("short", max_query_len=4, engine="batched",
              traversal="chunked", chunk_tiles=2),
        route("long", engine="batched", traversal="chunked",
              chunk_tiles=16),
    ))
    policy.classify(3).name   # "short"

``classify`` walks the routes in order and picks the first whose
``max_query_len`` (inclusive) admits the query; the final route must be
the catch-all (``max_query_len=None``). Query length is the number of
*live* terms — terms with a nonzero query weight — so zero-weight
padding never changes a request's class.

The scheduler opens one ``Retriever`` per route (lazily) and keys its
micro-batches and response cache on the route name, so a policy is also
a compile-budget statement: at most one jit entry per
(k-bucket x length-class).
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

from .health import RetryPolicy


@dataclasses.dataclass(frozen=True)
class Route:
    """One length class -> one engine configuration.

    ``pad_terms`` overrides the scheduler's static query width for this
    class: a short class executing at a narrow width skips the masked
    compute the global width would spend on its padding terms — on the
    batched engines the planner/gather cost scales with the padded
    width, so this is where length routing pays most (queries longer
    than the width keep their highest-impact terms, as always).

    ``retry`` overrides the scheduler-wide :class:`RetryPolicy` for
    failed batch executions of this class; ``fallback`` names a
    *fallback lane* (a route from ``RoutingPolicy.fallback_routes``)
    the scheduler rewrites to while the pool is degraded — the cheaper
    engine serves, and the responses come back ``degraded=True``.

    ``engine_opts`` is a sorted (key, value) tuple so the Route stays
    hashable; build routes with :func:`route` to pass them as kwargs.
    """
    name: str
    max_query_len: int | None = None   # inclusive; None = catch-all
    engine: str = "batched"
    engine_opts: tuple = ()
    pad_terms: int | None = None       # None -> SchedulerConfig.pad_terms
    retry: RetryPolicy | None = None   # None -> SchedulerConfig.retry
    fallback: str | None = None        # degraded-mode lane (route name)

    def opts(self) -> dict:
        return dict(self.engine_opts)

    def admits(self, query_len: int) -> bool:
        return self.max_query_len is None or query_len <= self.max_query_len


def route(name: str, max_query_len: int | None = None,
          engine: str = "batched", pad_terms: int | None = None,
          retry: RetryPolicy | None = None, fallback: str | None = None,
          **engine_opts) -> Route:
    """Declarative Route builder: kwargs become engine constructor opts
    (``traversal=``, ``chunk_tiles=``, ``n_shards=``, ...)."""
    return Route(name, max_query_len, engine,
                 tuple(sorted(engine_opts.items())), pad_terms,
                 retry, fallback)


@dataclasses.dataclass(frozen=True)
class RoutingPolicy:
    """Ordered length classes; the last route must be the catch-all.

    ``fallback_routes`` are extra lanes that ``classify`` never picks —
    they only serve as ``Route.fallback`` targets while the pool is
    degraded. Keeping them out of ``routes`` means they don't have to
    satisfy the catch-all/ascending-bounds ordering, but they are still
    opened, warmed, and replicated like any primary route.
    """
    routes: tuple[Route, ...]
    fallback_routes: tuple[Route, ...] = ()

    def __post_init__(self):
        if not self.routes:
            raise ValueError("RoutingPolicy needs at least one route")
        names = [r.name for r in self.all_routes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate route names: {names}")
        if self.routes[-1].max_query_len is not None:
            raise ValueError(
                "the last route must be the catch-all "
                "(max_query_len=None); got "
                f"max_query_len={self.routes[-1].max_query_len}")
        bounds = [r.max_query_len for r in self.routes[:-1]]
        if any(b is None for b in bounds):
            raise ValueError("only the last route may be the catch-all")
        if any(b2 <= b1 for b1, b2 in zip(bounds, bounds[1:])):
            raise ValueError(
                f"route max_query_len bounds must strictly ascend: {bounds}")
        by_name = {r.name: r for r in self.all_routes}
        for r in self.all_routes:
            if r.fallback is None:
                continue
            target = by_name.get(r.fallback)
            if target is None:
                raise ValueError(
                    f"route {r.name!r} falls back to unknown route "
                    f"{r.fallback!r}; routes: {sorted(by_name)}")
            if target.fallback is not None:
                raise ValueError(
                    f"fallback chains are not allowed: {r.name!r} -> "
                    f"{target.name!r} -> {target.fallback!r}")
            if target.pad_terms != r.pad_terms:
                # the fallback executes the *same padded batches*, so a
                # width mismatch would silently re-pad (and recompile)
                raise ValueError(
                    f"fallback route {target.name!r} must share "
                    f"pad_terms with {r.name!r} "
                    f"({target.pad_terms} != {r.pad_terms})")

    @property
    def all_routes(self) -> tuple[Route, ...]:
        """Primary + fallback lanes — what warmup/replication iterate."""
        return self.routes + self.fallback_routes

    def classify(self, query_len: int) -> Route:
        """First route admitting ``query_len`` (the catch-all always does)."""
        for r in self.routes:
            if r.admits(query_len):
                return r
        raise AssertionError("unreachable: catch-all route admits all")

    def by_name(self, name: str) -> Route:
        for r in self.all_routes:
            if r.name == name:
                return r
        raise KeyError(f"no route named {name!r}; routes: "
                       f"{[r.name for r in self.all_routes]}")

    def fingerprint(self, params) -> str:
        """Stable policy hash: routes + pruning policy. Part of every
        response-cache key, so two schedulers sharing a cache (or one
        scheduler after a policy swap) can never alias entries."""
        blob = repr((self.routes, self.fallback_routes, params)).encode()
        return hashlib.sha1(blob).hexdigest()[:16]


def warmup_grid(policy: RoutingPolicy, k_buckets,
                default_pad_terms: int) -> tuple:
    """The serving compile grid: one ``(route, width, k_bucket)`` cell
    per (length-class x k-bucket) pair, with the static query width that
    class executes at. Executor warmup runs one zero-weight no-op batch
    per cell so the first real request of any group never pays a trace;
    the compile-discipline tests pin the jitted traversal's
    ``_cache_size()`` growth to ``len(warmup_grid(...))``."""
    buckets = tuple(k_buckets) if k_buckets else ()
    return tuple(
        (r, r.pad_terms if r.pad_terms is not None else default_pad_terms, b)
        for r in policy.all_routes for b in buckets)


def query_length(weights_b, weights_l) -> int:
    """Live-term count of one query: terms whose combined weight is
    nonzero (zero-weight padding scores as a no-op everywhere)."""
    wb = np.asarray(weights_b)
    wl = np.asarray(weights_l)
    return int(((wb != 0) | (wl != 0)).sum())


def policy_summary(policy: RoutingPolicy) -> dict:
    """A JSON-able description of a routing policy — what the metrics
    endpoint and bench meta embed so a recorded run says which lanes it
    ran. Non-JSON engine opt values (retry policies, callables) render
    as ``repr``."""
    def _jsonable(v):
        return v if isinstance(v, (str, int, float, bool,
                                   type(None))) else repr(v)

    def _route(r: Route) -> dict:
        return {"max_query_len": r.max_query_len, "engine": r.engine,
                "opts": {k: _jsonable(v) for k, v in r.opts().items()},
                "pad_terms": r.pad_terms, "fallback": r.fallback}

    return {"routes": {r.name: _route(r) for r in policy.routes},
            "fallback_routes": {r.name: _route(r)
                                for r in policy.fallback_routes}}


def single_route(engine: str = "batched", **engine_opts) -> RoutingPolicy:
    """The no-routing policy: one catch-all class (what the deprecated
    ``RetrievalServer`` shim uses)."""
    return RoutingPolicy((route("all", None, engine, **engine_opts),))


def table8_policy(short_max_len: int = 4,
                  short_chunk_tiles: int = 2,
                  long_engine: str = "batched",
                  long_traversal: str = "full",
                  **common_opts) -> RoutingPolicy:
    """The Table-8 routing suggestion on our knobs: short queries run at
    a narrow static width (``pad_terms=short_max_len``) through the
    chunked executor's fine exit grid — short queries skip the most, so
    they get the finest-grained early exit *and* none of the masked
    compute a wide padded shape would spend on them. Long queries keep
    the full width on the plain batched scan by default; pass
    ``long_engine="kernel"`` (and ``long_traversal="chunked"`` /
    ``"chunked_fused"``) for the fused scorer on TPU."""
    # "full" is every engine's default traversal — omitting it keeps the
    # long route valid for engines without a traversal knob (sequential)
    long_opts = ({} if long_traversal == "full"
                 else {"traversal": long_traversal})
    return RoutingPolicy((
        route("short", short_max_len, "batched",
              pad_terms=short_max_len, traversal="chunked",
              chunk_tiles=short_chunk_tiles, **common_opts),
        route("long", None, long_engine, **long_opts, **common_opts),
    ))
