"""Executor health: retry policy, EWMA latency, per-executor breakers.

Fault *handling* for the serving pool (fault *injection* lives in
``serve.faults``). Three pieces:

  - :class:`RetryPolicy` — declarative per-route (or scheduler-default)
    retry behavior for failed batch executions: bounded attempts,
    exponential backoff with **deterministic seeded jitter** (the same
    (seed, request, attempt) always backs off by the same amount, so
    retry schedules are reproducible in tests and across replays), and
    a retryability predicate (transient faults requeue, poison faults
    fail fast).
  - :class:`HealthMonitor` — per-executor EWMA service latency (the
    ``dist.straggler`` shape: weight ``ewma_decay`` on history) plus
    consecutive-failure counts, and a ring of recent latencies for the
    hedge-delay percentile.
  - the **circuit breaker** per executor: ``closed`` (in rotation) ->
    ``open`` after ``failure_threshold`` consecutive failures (the
    executor stops picking batches) -> ``half_open`` after
    ``cooldown_ms`` (one probe batch is allowed through; a lost probe
    self-heals after another cooldown) -> ``closed`` on probe success /
    back to ``open`` on probe failure. ``dead`` is terminal: an
    executor whose *thread* died (reported by the pool) never re-enters
    rotation.

Every method takes an explicit ``now`` (``time.perf_counter`` scale) so
breaker transitions are drivable on a simulated clock — none of the
fault-injection tests sleep.
"""
from __future__ import annotations

import dataclasses
import math
import threading
from collections import deque

import numpy as np

from ..obs.metrics import exact_quantile

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"
BREAKER_DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Retry behavior for failed batch executions.

    ``max_attempts`` counts the first execution: ``max_attempts=3``
    means up to two requeues. Backoff for the retry after attempt ``a``
    is ``backoff_ms * backoff_factor**(a-1)``, jittered by a
    deterministic ``+- jitter`` fraction drawn from
    ``default_rng((seed, token, a))`` — no shared RNG state, so the
    schedule is a pure function of (policy, request, attempt).
    """
    max_attempts: int = 3
    backoff_ms: float = 10.0
    backoff_factor: float = 2.0
    jitter: float = 0.5          # +- fraction of the base backoff
    seed: int = 0

    def __post_init__(self):
        if self.max_attempts < 1:
            raise ValueError(f"max_attempts must be >= 1, "
                             f"got {self.max_attempts}")
        if self.backoff_ms < 0 or self.backoff_factor < 1:
            raise ValueError("backoff_ms must be >= 0 and "
                             "backoff_factor >= 1")
        if not 0 <= self.jitter < 1:
            raise ValueError(f"jitter must be in [0, 1), got {self.jitter}")

    def delay_ms(self, attempt: int, token: int = 0) -> float:
        """Backoff before the retry that follows failed ``attempt``
        (1-based). Deterministic in (seed, token, attempt)."""
        base = self.backoff_ms * self.backoff_factor ** max(attempt - 1, 0)
        if self.jitter <= 0 or base <= 0:
            return base
        u = np.random.default_rng(
            (self.seed, int(token), int(attempt))).random()
        return base * (1.0 + self.jitter * (2.0 * u - 1.0))

    @staticmethod
    def retryable(exc: BaseException) -> bool:
        """Transient faults requeue; anything marked non-retryable (or
        plainly deterministic, like a ValueError from bad input) fails
        the handles immediately. The escape hatch is the exception's own
        ``retryable`` attribute (``serve.faults.InjectedFault`` sets
        it); otherwise timeouts and connection-flavored OS errors count
        as transient."""
        flag = getattr(exc, "retryable", None)
        if flag is not None:
            return bool(flag)
        return isinstance(exc, (TimeoutError, ConnectionError))


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    failure_threshold: int = 3   # consecutive failures -> breaker opens
    cooldown_ms: float = 250.0   # open -> half-open probe delay
    ewma_decay: float = 0.6      # weight on history (straggler shape)
    window: int = 256            # recent latencies kept for percentiles


class _ExecutorHealth:
    __slots__ = ("state", "ewma_ms", "n_reports", "consecutive_failures",
                 "failures", "successes", "opened_at", "probe_at")

    def __init__(self):
        self.state = BREAKER_CLOSED
        self.ewma_ms = 0.0
        self.n_reports = 0
        self.consecutive_failures = 0
        self.failures = 0
        self.successes = 0
        self.opened_at = -math.inf
        self.probe_at = -math.inf


class HealthMonitor:
    """Per-executor EWMA latency + consecutive failures + breaker state.

    Executors register lazily (the first ``record_*``/``allow`` call for
    an id creates its entry), so the monitor needs no fixed pool size.
    Thread-safe; every transition is driven by an explicit ``now``.
    """

    def __init__(self, cfg: HealthConfig | None = None):
        self.cfg = cfg if cfg is not None else HealthConfig()
        self._execs: dict[int, _ExecutorHealth] = {}
        self._latencies: deque = deque(maxlen=self.cfg.window)
        self._lock = threading.Lock()

    def _get(self, executor_id: int) -> _ExecutorHealth:
        h = self._execs.get(executor_id)
        if h is None:
            h = self._execs[executor_id] = _ExecutorHealth()
        return h

    # -- reporting -----------------------------------------------------------

    def record_success(self, executor_id: int, latency_ms: float,
                       now: float) -> None:
        with self._lock:
            h = self._get(executor_id)
            if h.state == BREAKER_DEAD:
                return
            a = self.cfg.ewma_decay
            h.ewma_ms = (latency_ms if h.n_reports == 0
                         else a * h.ewma_ms + (1.0 - a) * latency_ms)
            h.n_reports += 1
            h.successes += 1
            h.consecutive_failures = 0
            if h.state in (BREAKER_OPEN, BREAKER_HALF_OPEN):
                h.state = BREAKER_CLOSED   # probe succeeded: close
            self._latencies.append(float(latency_ms))

    def record_failure(self, executor_id: int, now: float) -> None:
        with self._lock:
            h = self._get(executor_id)
            if h.state == BREAKER_DEAD:
                return
            h.failures += 1
            h.consecutive_failures += 1
            if h.state == BREAKER_HALF_OPEN:
                # failed probe: back to open, restart the cooldown
                h.state = BREAKER_OPEN
                h.opened_at = now
            elif (h.state == BREAKER_CLOSED
                  and h.consecutive_failures >= self.cfg.failure_threshold):
                h.state = BREAKER_OPEN
                h.opened_at = now

    def mark_dead(self, executor_id: int) -> None:
        """Terminal: the executor's thread died. Never re-enters
        rotation (``allow`` is permanently False; the pool is degraded
        until replaced)."""
        with self._lock:
            self._get(executor_id).state = BREAKER_DEAD

    # -- gating --------------------------------------------------------------

    def allow(self, executor_id: int, now: float) -> bool:
        """May this executor pick a batch at ``now``? Closed: yes.
        Open: no, until ``cooldown_ms`` passes — then one half-open
        probe is let through. A probe that never reports back (e.g. the
        queue was empty) self-heals: another probe is allowed one
        cooldown later."""
        with self._lock:
            h = self._get(executor_id)
            if h.state == BREAKER_CLOSED:
                return True
            if h.state == BREAKER_DEAD:
                return False
            cool = self.cfg.cooldown_ms / 1e3
            if h.state == BREAKER_OPEN:
                if now - h.opened_at >= cool:
                    h.state = BREAKER_HALF_OPEN
                    h.probe_at = now
                    return True
                return False
            # half-open: one probe outstanding; re-arm if it got lost
            if now - h.probe_at >= cool:
                h.probe_at = now
                return True
            return False

    def degraded(self) -> bool:
        """True while any executor's breaker is not closed — the signal
        the scheduler uses to rewrite routes to their fallback lane."""
        with self._lock:
            return any(h.state != BREAKER_CLOSED
                       for h in self._execs.values())

    def state(self, executor_id: int) -> str:
        with self._lock:
            h = self._execs.get(executor_id)
            return h.state if h is not None else BREAKER_CLOSED

    # -- hedge delay ---------------------------------------------------------

    def latency_p99_ms(self, default: float = 0.0) -> float:
        """P99 over the recent-latency window (across executors), or
        ``default`` with no samples — the hedge-delay source. Exact-rank
        (a latency an attempt actually took), not interpolated."""
        with self._lock:
            if not self._latencies:
                return default
            return exact_quantile(self._latencies, 0.99)

    # -- introspection -------------------------------------------------------

    def snapshot(self) -> dict:
        """Detached per-executor view: breaker state, EWMA latency,
        failure counters — what ``scheduler.stats()`` surfaces."""
        with self._lock:
            return {
                eid: {"state": h.state, "ewma_ms": round(h.ewma_ms, 3),
                      "consecutive_failures": h.consecutive_failures,
                      "failures": h.failures, "successes": h.successes}
                for eid, h in sorted(self._execs.items())}
