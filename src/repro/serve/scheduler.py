"""Async serving scheduler: futures, mixed-k micro-batches, routing, cache.

The v2 serving seam. ``submit(SearchRequest) -> SearchHandle`` admits a
request into a priority queue and returns immediately; a handle is a
future (``.done()`` / ``.result(timeout)``) that resolves to a
:class:`repro.retrieval.SearchResponse`. Requests are grouped into
micro-batches by **(k-bucket x query-length class)** — the two
per-request decisions the paper makes matter (Section 4's depth/quality
tradeoff; Table 8's length-dependent engine preference) — and each
group dispatches under the usual serving deadlines (``max_batch`` rows
or the oldest request's ``max_wait_ms``). One ``Retriever.search`` call
serves the whole batch with **per-request k**: the engine executes once
at the group's bucket and every row is truncated back to its own depth.

Compile discipline: every dispatched batch is padded to a static
``[max_batch, width]`` shape (``pad_batch=True``), where ``width`` is
the route's ``pad_terms`` (or the scheduler default) — so the jitted
traversal compiles **at most once per (k-bucket x length-class)** and
the fill level of a batch never retraces. Padding rows are zero-weight
queries: they score as no-ops, never extend the chunked while_loop past
the real rows, and are sliced off before results surface. A short
route's narrow width is where length routing pays on the batched
engines: the planner/gather cost scales with the padded query width.

Query-length routing (``serve.router``): a declarative
:class:`RoutingPolicy` maps live-term counts to engine configurations
(Table 8: short queries -> finer ``chunk_tiles``; long -> coarser
chunks or the fused kernel). One ``Retriever`` is opened per route,
lazily.

Response cache: an LRU keyed on ``(query fingerprint, policy hash,
k-bucket, per-row depths)``. A hit completes the handle at submit time
— the zero-service-time path — and hit/miss counters surface in
``stats()``. Keying on the exact depths lets the same query coexist at
several k within one bucket, and means a hit is always the exact
request replayed (within a bucket, different depths are different
truncations of the same execution for rank-safe configs, but guided
configs are only reproducible at the exact request — the cache never
approximates). Entries and delivered responses never share arrays.

Fault tolerance (``serve.health`` / ``serve.faults``): requests may
carry a ``deadline_ms`` — expired entries are shed at pick time
(:class:`DeadlineExceeded`) instead of burning batch slots; failed
batch executions requeue under a per-route :class:`RetryPolicy`
(deterministic seeded backoff) when the fault is retryable; idle
executors hedge straggler batches (first result wins, the loser is
cancelled at the queue); per-executor circuit breakers take failing
executors out of rotation and, while the pool is degraded, routes with
a ``fallback`` lane execute there with responses flagged
``degraded=True``. ``swap_index`` installs a rebuilt index as a new
*generation* behind a two-phase gate (warm, then flip between
batches); cache keys carry the generation, so a rebuild can never
serve stale hits.

Observability (``repro.obs``): the scheduler always owns a
:class:`~repro.obs.metrics.MetricsRegistry` (queue-wait and batch
service-time histograms feed the ``queue_wait_ms`` percentiles in
``stats()``), and — when ``SchedulerConfig.tracer`` carries a real
:class:`~repro.obs.spans.Tracer` — records one trace per request
(admission -> queue -> execute spans, with the batch token, executor
id and the traversal's ``chunks_dispatched`` attached), emitted
retroactively at delivery so in-flight requests hold timestamps, not
span objects. With the default no-op tracer the whole path is a single
attribute check. ``sort_batches_by_cost`` orders each picked group by
a trace-fitted chunk-count prediction
(:class:`~repro.obs.cost.CostModel`) within an aged-priority level, so
micro-batches cluster similar-cost requests and the chunked
while_loop's max-over-batch trip count hugs the mean; per-query
results are independent of batch composition, so cost-sorted dispatch
is bit-identical to unsorted (pinned by test).

Two drive modes:

  - synchronous: ``poll()`` dispatches every *due* micro-batch inline
    and ``flush()`` drains everything — deterministic, what the
    benchmarks, the deprecated ``RetrievalServer`` shim, and most tests
    use;
  - threaded: ``start()`` (or ``with scheduler:``) runs a background
    worker that wakes on submissions and deadlines; ``result()`` then
    blocks like any future. ``close()`` stops the worker and drains.
"""
from __future__ import annotations

import dataclasses
import hashlib
import itertools
import math
import threading
import time
from collections import OrderedDict

import numpy as np

from ..core.twolevel import TwoLevelParams, resolve_k
from ..obs.cost import CostModel, QueryFeaturizer
from ..obs.metrics import Histogram, MetricsRegistry, exact_quantile
from ..obs.spans import NULL_TRACER
from ..retrieval import (K_BUCKETS, Retriever, SearchRequest,
                         SearchResponse, bucket_k, resolve_ks)
from .health import HealthConfig, HealthMonitor, RetryPolicy
from .router import (RoutingPolicy, query_length, single_route,
                     warmup_grid)


ADMISSION_POLICIES = ("block", "reject", "shed")
CACHE_ADMISSIONS = ("always", "second_sight")


class SchedulerSaturated(RuntimeError):
    """The bounded admission queue is full. Raised by ``submit`` under
    ``admission_policy="reject"`` (and for a submission that loses the
    priority comparison under ``"shed"``); delivered through
    ``SearchHandle.result()`` for a queued request that was load-shed to
    admit a more important one."""


class DeadlineExceeded(RuntimeError):
    """The request's ``deadline_ms`` budget ran out while it was still
    queued: the scheduler sheds it at pick time instead of spending a
    batch slot on an answer nobody is waiting for. Delivered through
    ``SearchHandle.result()``; counted as ``expired`` in ``stats()``."""


class SearchTimeout(TimeoutError):
    """``SearchHandle.result(timeout=...)`` gave up waiting. Unlike
    :class:`DeadlineExceeded` the request itself is still live — only
    this caller stopped waiting. Carries the handle's routing context
    so timeout logs can say *which* lane stalled."""

    def __init__(self, msg: str, route: str | None = None,
                 k_bucket: int | None = None):
        super().__init__(msg)
        self.route = route
        self.k_bucket = k_bucket


@dataclasses.dataclass
class SchedulerConfig:
    max_batch: int = 32        # rows per micro-batch (and the padded shape)
    max_wait_ms: float = 2.0   # oldest-request dispatch deadline
    pad_terms: int = 16        # static query width (overlong rows truncate)
    # pad every batch to [max_batch, pad_terms] so a (k-bucket x class)
    # group compiles exactly once regardless of fill level
    pad_batch: bool = True
    cache_size: int = 256      # LRU response-cache entries; 0 disables
    # -- executor pool / backpressure (serve.executor) ----------------------
    # worker threads started by start(): 0 keeps the single dispatch
    # worker; N >= 1 runs an ExecutorPool of N workers, each with its own
    # Retriever replica per route, pulling micro-batches concurrently
    executors: int = 0
    # bounded admission: max queued rows (pending, not yet picked);
    # 0 = unbounded. Saturation then degrades tail latency (or sheds)
    # instead of growing MRT without bound for everyone.
    admission_limit: int = 0
    # what submit() does when the queue is full:
    #   "block"  — wait for space (inline-drains in sync mode);
    #   "reject" — raise SchedulerSaturated immediately;
    #   "shed"   — drop the least-important queued request (by aged
    #              priority; its handle fails with SchedulerSaturated)
    #              if the new one outranks it, else refuse the new one.
    admission_policy: str = "block"
    # priority aging: a queued request gains one priority level per
    # aging_ms waited, so strict priority cannot starve low-priority
    # traffic under a saturating high-priority stream. 0 = strict.
    aging_ms: float = 0.0
    # -- fault tolerance (serve.health / serve.faults) -----------------------
    # scheduler-wide retry policy for failed batch executions (a Route
    # may override with its own); None = fail handles on first error
    retry: RetryPolicy | None = None
    # hedge straggler batches: an idle executor re-dispatches a batch
    # that has been in flight longer than hedge_ms on itself; first
    # result wins, the loser is cancelled at the queue (or discarded).
    # 0 disables unless hedge_from_p99 derives the delay from the
    # health monitor's recent-latency p99 (hedge_ms is then the
    # cold-start default before any latency samples exist).
    hedge_ms: float = 0.0
    hedge_from_p99: bool = False
    # per-executor breaker/EWMA configuration; None = defaults
    health: HealthConfig | None = None
    # -- cache lifecycle -----------------------------------------------------
    # entries older than ttl_s are evicted on lookup; 0 = no TTL
    cache_ttl_s: float = 0.0
    # "always" caches every response; "second_sight" only admits a key
    # seen before (one-hit wonders never displace a repeating query)
    cache_admission: str = "always"
    # -- observability (repro.obs) -------------------------------------------
    # tracer for per-request spans (admission -> queue -> execute);
    # None = the shared no-op tracer, whose entire cost on the serving
    # path is one attribute check per delivery
    tracer: object | None = None
    # metrics registry (queue-wait / service-time histograms, stats()
    # percentiles); None = a private registry per scheduler
    metrics: MetricsRegistry | None = None
    # trace-fitted chunk-count predictor (obs.cost.CostModel). With
    # sort_batches_by_cost, each picked group orders by predicted cost
    # *within* an aged-priority level, clustering similar-cost requests
    # per micro-batch so the chunked while_loop's max-over-batch trip
    # count hugs the mean. Per-query results are batch-composition
    # independent, so dispatch order never changes ids/scores.
    cost_model: CostModel | None = None
    sort_batches_by_cost: bool = False


def truncate_terms(terms, qw_b, qw_l, pad_terms: int,
                   gamma: float) -> np.ndarray:
    """Indices of the ``pad_terms`` terms to keep for one over-long
    query: drop the *lowest-impact* terms — ranked by the gamma-combined
    query weight the engine scores with — not the trailing ones, and
    preserve the original term order among the kept."""
    if len(terms) <= pad_terms:
        return np.arange(len(terms))
    impact = (gamma * np.asarray(qw_b, np.float32)
              + (1.0 - gamma) * np.asarray(qw_l, np.float32))
    keep = np.argsort(-impact, kind="stable")[:pad_terms]
    return np.sort(keep)


class SearchHandle:
    """Future-style result of one :meth:`AsyncRetrievalScheduler.submit`.

    ``done()`` is non-blocking; ``result(timeout=None)`` blocks until
    the response exists (with a worker thread running this is a plain
    future wait; without one it flushes the scheduler so a bare
    submit->result round trip can never deadlock). ``cached`` marks the
    zero-service-time path; ``latency_ms`` is submit->completion and
    NaN while the request is still in flight.
    """

    __slots__ = ("route", "k_bucket", "priority", "cached", "t_submit",
                 "t_done", "deadline_ms", "_event", "_response",
                 "_exception", "_scheduler")

    def __init__(self, scheduler, route: str, k_bucket: int,
                 priority: int, t_submit: float,
                 deadline_ms: float | None = None):
        self.route = route
        self.k_bucket = k_bucket
        self.priority = priority
        self.cached = False
        self.t_submit = t_submit
        self.t_done = math.nan
        self.deadline_ms = deadline_ms
        self._event = threading.Event()
        self._response: SearchResponse | None = None
        self._exception: BaseException | None = None
        self._scheduler = scheduler

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: float | None = None) -> SearchResponse:
        if not self._event.is_set() and not self._scheduler.is_running():
            # drain to completion: an *unrelated* batch failing mid-flush
            # already resolved its own handles with the error, but ours
            # may still be queued behind it — keep flushing (each failed
            # batch is popped, so this terminates) instead of letting the
            # foreign exception escape or a timeout=None wait deadlock
            while not self._event.is_set():
                try:
                    self._scheduler.flush()
                    break
                except Exception:
                    continue
        if not self._event.wait(timeout):
            raise SearchTimeout(
                f"request not served within {timeout}s (route "
                f"{self.route!r}, k-bucket {self.k_bucket})",
                route=self.route, k_bucket=self.k_bucket)
        if self._exception is not None:
            raise self._exception
        return self._response

    @property
    def latency_ms(self) -> float:
        """Submit -> completion in ms; NaN while in flight."""
        if not self._event.is_set():
            return math.nan
        return (self.t_done - self.t_submit) * 1e3

    def _complete(self, response: SearchResponse, t_done: float,
                  cached: bool = False) -> None:
        self._response = response
        self.t_done = t_done
        self.cached = cached
        self._event.set()

    def _fail(self, exc: BaseException, t_done: float) -> None:
        """Deliver a batch-execution failure: ``result()`` re-raises.
        The request is gone either way, but the caller finds out instead
        of blocking forever on a handle nothing will ever complete."""
        self._exception = exc
        self.t_done = t_done
        self._event.set()


@dataclasses.dataclass
class _Pending:
    """One admitted request, normalized to static-width rows."""
    seq: int
    priority: int
    deadline: float            # absolute perf_counter dispatch deadline
    handle: SearchHandle
    terms: np.ndarray          # [r, pad_terms] int32
    qw_b: np.ndarray           # [r, pad_terms] f32
    qw_l: np.ndarray           # [r, pad_terms] f32
    ks: np.ndarray             # [r] int32 per-row depth
    cache_key: tuple | None    # generation-free base key; gen appended
    #                            at store/lookup time
    expires: float = math.inf  # absolute deadline_ms expiry; shed after
    not_before: float = -math.inf  # retry backoff: ineligible until then
    attempts: int = 1          # execution attempts including the next one
    cost: float = 0.0          # predicted chunk count (cost-sorted pick)
    features: tuple | None = None  # heaviest row's cost features (tracing)

    @property
    def rows(self) -> int:
        return self.terms.shape[0]


@dataclasses.dataclass
class _Inflight:
    """One picked batch between pick and delivery — the unit retries,
    hedges, and first-result-wins races are resolved on. ``outstanding``
    counts live attempts (primary + hedges); the first ``_deliver`` pops
    the record, so a losing attempt finds it gone and is discarded."""
    token: int
    key: tuple                 # (bucket, route_name, threshold_factor)
    batch: list                # the _Pending entries
    t_start: float
    budget_ms: float           # min remaining deadline budget over rows
    executor_id: int | None    # primary executor (hedges run elsewhere)
    attempts: int = 1
    outstanding: int = 1
    hedged: bool = False


class AsyncRetrievalScheduler:
    """The v2 serving loop: priority admission, (k-bucket x length-class)
    micro-batching, per-request k, query-length routing, response cache.

    One instance owns one index + pruning policy and a lazily-opened
    ``Retriever`` per route. See the module docstring for semantics.
    """

    def __init__(self, index, params: TwoLevelParams | None = None,
                 cfg: SchedulerConfig | None = None, *,
                 routing: RoutingPolicy | None = None,
                 k_buckets=K_BUCKETS, faults=None):
        self.index = index
        self.params = params if params is not None else TwoLevelParams()
        self.cfg = cfg if cfg is not None else SchedulerConfig()
        self.routing = routing if routing is not None else single_route()
        self.k_buckets = k_buckets
        if self.cfg.admission_policy not in ADMISSION_POLICIES:
            raise ValueError(
                f"admission_policy must be one of {ADMISSION_POLICIES}, "
                f"got {self.cfg.admission_policy!r}")
        if self.cfg.cache_admission not in CACHE_ADMISSIONS:
            raise ValueError(
                f"cache_admission must be one of {CACHE_ADMISSIONS}, "
                f"got {self.cfg.cache_admission!r}")
        if self.cfg.executors < 0:
            raise ValueError(f"executors must be >= 0, "
                             f"got {self.cfg.executors}")
        if self.cfg.sort_batches_by_cost and self.cfg.cost_model is None:
            raise ValueError("sort_batches_by_cost=True requires a "
                             "cost_model (fit one with "
                             "scripts/fit_cost_model.py or "
                             "obs.cost.CostModel.fit_from_traces)")
        self.tracer = (self.cfg.tracer if self.cfg.tracer is not None
                       else NULL_TRACER)
        self.metrics = (self.cfg.metrics if self.cfg.metrics is not None
                        else MetricsRegistry())
        self._hist_queue = self.metrics.histogram("queue_wait_ms")
        self._hist_service = self.metrics.histogram("batch_service_ms")
        # lazily-built query featurizer (needs only index stats arrays);
        # invalidated by swap_index so features track the live index
        self._featurizer: QueryFeaturizer | None = None
        self._policy_fp = self.routing.fingerprint(self.params)
        self._retrievers: dict[str, Retriever] = {}
        # (bucket, route_name, threshold_factor) -> list of _Pending
        # (ordered by aged priority at pick time, not at admission)
        self._groups: dict[tuple, list] = {}
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._open_lock = threading.Lock()   # lazy Retriever.open guard
        self._thread: threading.Thread | None = None
        self._pool = None                    # ExecutorPool when executors>0
        self._stop = False
        self._cache: OrderedDict = OrderedDict()
        # second-sight admission ghost list: base keys seen once (LRU)
        self._cache_seen: OrderedDict = OrderedDict()
        # fault tolerance: per-executor health/breakers, the no-op-able
        # fault hook, picked-batch records (retry/hedge bookkeeping),
        # and the index generation the hot-swap gate bumps
        self.health = HealthMonitor(self.cfg.health)
        self.faults = faults
        self._generation = 0
        self._inflight: dict[int, _Inflight] = {}
        self._inflight_seq = itertools.count()
        self._fault_global = 0
        self._fault_per_exec: dict = {}
        self._dead_executors: dict = {}
        self._counts = {"submitted": 0, "completed": 0, "failed": 0,
                        "rejected": 0, "shed": 0, "expired": 0,
                        "in_flight": 0,
                        "batches": 0, "cache_hits": 0, "cache_misses": 0,
                        "rows_executed": 0, "rows_padding": 0,
                        "retries": 0, "hedges": 0, "hedges_wasted": 0,
                        "hedges_cancelled": 0, "hedge_failures": 0,
                        "degraded_batches": 0, "executor_deaths": 0,
                        "swaps": 0, "cache_ttl_evictions": 0,
                        "cache_admission_skips": 0,
                        "cache_gen_evictions": 0}
        self._route_requests: dict[str, int] = {}
        self._group_batches: dict[str, int] = {}
        self._executor_batches: dict[int, int] = {}
        self._executor_rows: dict[int, int] = {}
        self._warmup_s = 0.0

    # -- admission -----------------------------------------------------------

    def submit(self, request: SearchRequest | None = None, *,
               terms=None, weights_b=None, weights_l=None, k=None,
               threshold_factor: float | None = None,
               deadline_ms: float | None = None,
               priority: int = 0, now: float | None = None) -> SearchHandle:
        """Admit one request; returns its future immediately.

        ``priority`` orders dispatch within a micro-batch group (lower =
        sooner; FIFO within a priority). ``now`` overrides the admission
        timestamp (perf_counter scale) for simulated workloads. A
        response-cache hit completes the handle before returning.
        ``deadline_ms`` bounds queueing: a request still undispatched
        when its budget runs out is shed at pick time and its handle
        fails with :class:`DeadlineExceeded`.
        """
        if request is None:
            request = SearchRequest(terms=terms, weights_b=weights_b,
                                    weights_l=weights_l, k=k,
                                    threshold_factor=threshold_factor,
                                    deadline_ms=deadline_ms)
        elif any(v is not None for v in (terms, weights_b, weights_l, k,
                                         threshold_factor, deadline_ms)):
            raise TypeError("pass either a SearchRequest or field kwargs, "
                            "not both")
        if request.deadline_ms is not None and request.deadline_ms <= 0:
            raise ValueError(
                f"deadline_ms must be > 0, got {request.deadline_ms}")
        if request.dense is not None:
            raise ValueError("the scheduler serves sparse engines; use a "
                             "Retriever(engine='dense') directly for dense "
                             "requests")
        now = time.perf_counter() if now is None else now
        rows, qlen = self._normalize_rows(request)
        if not rows:
            raise ValueError("request carries a zero-row query batch")
        if len(rows) > self.cfg.max_batch:
            # an oversized atomic request would dispatch at its own row
            # count, re-tracing the jitted traversal per distinct size —
            # split it client-side instead of breaking compile discipline
            raise ValueError(
                f"request has {len(rows)} rows > max_batch="
                f"{self.cfg.max_batch}; split it into <= max_batch-row "
                f"requests (each request rides one micro-batch)")
        route = self.routing.classify(qlen)
        width = (route.pad_terms if route.pad_terms is not None
                 else self.cfg.pad_terms)
        q_terms, qw_b, qw_l = self._pad_rows(rows, width)
        ks = resolve_ks(request.k, q_terms.shape[0])
        if ks is None:
            ks = np.full(q_terms.shape[0],
                         resolve_k(self.params, request.k), np.int32)
        bucket = bucket_k(int(ks.max()), self.k_buckets)
        tf = (None if request.threshold_factor is None
              else float(request.threshold_factor))
        handle = SearchHandle(self, route.name, bucket, priority, now,
                              deadline_ms=request.deadline_ms)
        key = None
        if self.cfg.cache_size > 0:
            # per-row depths are part of the key, so the same query at
            # different k within one bucket keeps separate entries
            # instead of thrashing a single slot; the index generation
            # is appended at lookup/store time, so a hot-swap atomically
            # orphans every pre-swap entry
            key = (self._fingerprint(q_terms, qw_b, qw_l, tf),
                   self._policy_fp, bucket, ks.tobytes())
        n_rows = q_terms.shape[0]
        if 0 < self.cfg.admission_limit < n_rows:
            raise ValueError(
                f"request has {n_rows} rows > admission_limit="
                f"{self.cfg.admission_limit}; it could never be admitted")
        with self._cond:
            self._counts["submitted"] += 1
            self._route_requests[route.name] = (
                self._route_requests.get(route.name, 0) + 1)
            if key is not None:
                hit = self._cache_lookup_locked(key, now)
                if hit is not None:
                    self._counts["cache_hits"] += 1
                    self._counts["completed"] += 1
                    handle._complete(self._detach(hit, latency_ms=0.0),
                                     t_done=now, cached=True)
                    if self.tracer.enabled:
                        self.tracer.emit(
                            "request", now, now, trace_id=next(self._seq),
                            route=route.name, k_bucket=bucket,
                            priority=priority, rows=q_terms.shape[0],
                            cached=True, outcome="cached")
                    return handle
                self._counts["cache_misses"] += 1
        expires = (math.inf if request.deadline_ms is None
                   else now + request.deadline_ms / 1e3)
        cost_pred, feats = 0.0, None
        if self.cfg.sort_batches_by_cost or self.tracer.enabled:
            F = self._featurize(q_terms, qw_b, qw_l)
            # a multi-row request rides one batch slot; its heaviest row
            # (by upper-bound mass) is the one that paces the while_loop
            heavy = F[int(np.argmax(F[:, 1]))]
            feats = tuple(float(x) for x in heavy)
            if self.cfg.cost_model is not None:
                cost_pred = float(self.cfg.cost_model.predict(F).max())
        entry = _Pending(
            seq=next(self._seq), priority=priority,
            deadline=min(now + self.cfg.max_wait_ms / 1e3, expires),
            handle=handle, terms=q_terms, qw_b=qw_b, qw_l=qw_l, ks=ks,
            cache_key=key, expires=expires, cost=cost_pred,
            features=feats)
        self._admit(entry, (bucket, route.name, tf), now)
        return handle

    def _featurize(self, terms, qw_b, qw_l) -> np.ndarray:
        f = self._featurizer
        if f is None:
            f = QueryFeaturizer(self.index, self.params)
            self._featurizer = f
        return f(terms, qw_b, qw_l)

    def _cache_lookup_locked(self, base_key: tuple, now: float):
        """Current-generation cache hit for ``base_key``, honoring TTL
        (an over-age entry is evicted and counts as a miss)."""
        full = base_key + (self._generation,)
        slot = self._cache.get(full)
        if slot is None:
            return None
        resp, stored_at = slot
        if 0 < self.cfg.cache_ttl_s < (now - stored_at):
            del self._cache[full]
            self._counts["cache_ttl_evictions"] += 1
            return None
        self._cache.move_to_end(full)
        return resp

    # -- backpressure --------------------------------------------------------

    def _aged_priority(self, priority: float, t_submit: float,
                       now: float) -> float:
        """Effective priority after aging: one level gained per
        ``aging_ms`` waited (lower = more important). With aging off this
        is the static priority — strict, starvation-prone ordering."""
        if self.cfg.aging_ms <= 0:
            return float(priority)
        return priority - (now - t_submit) * 1e3 / self.cfg.aging_ms

    def _pending_rows_locked(self) -> int:
        return sum(e.rows for g in self._groups.values() for e in g)

    def _admit(self, entry: _Pending, group_key: tuple, now: float) -> None:
        """Enqueue under the bounded admission queue. "block" waits for
        space (inline-draining when no worker runs, so a sync caller can
        never deadlock itself); "reject" raises ``SchedulerSaturated``;
        "shed" drops the least-important queued request — by *aged*
        priority, newest first within a class — when the incoming one
        outranks it, else refuses the incoming request."""
        limit = self.cfg.admission_limit
        while True:
            with self._cond:
                if limit <= 0 or (self._pending_rows_locked() + entry.rows
                                  <= limit):
                    self._groups.setdefault(group_key, []).append(entry)
                    self._cond.notify_all()
                    return
                if self.cfg.admission_policy == "reject":
                    self._counts["rejected"] += 1
                    raise SchedulerSaturated(
                        f"admission queue full ({limit} rows); request "
                        f"rejected (priority {entry.priority})")
                if self.cfg.admission_policy == "shed":
                    self._shed_for_locked(entry, group_key, now)
                    return
                # "block": wait for the queue to drain. Completion,
                # shed, expiry, and pick all notify the condition, so
                # this wakes the moment space exists — the timeout is
                # only a backstop against a lost wakeup, not a poll
                # interval that quantizes admission latency.
                if self.is_running():
                    self._cond.wait(timeout=1.0)
                    continue
            # sync mode, no worker to drain the queue: dispatch inline
            # (outside the lock) and retry admission
            self.poll(now=None, force=True)

    def _shed_for_locked(self, entry: _Pending, group_key: tuple,
                         now: float) -> None:
        """Make room for ``entry`` by dropping least-important queued
        requests, or refuse ``entry`` when it is itself the least
        important. Victim handles fail with ``SchedulerSaturated``."""
        limit = self.cfg.admission_limit
        incoming = self._aged_priority(entry.priority,
                                       entry.handle.t_submit, now)
        while self._pending_rows_locked() + entry.rows > limit:
            victim_key, victim = None, None
            worst = (incoming, -1)
            for gk, group in self._groups.items():
                for e in group:
                    aged = self._aged_priority(e.priority,
                                               e.handle.t_submit, now)
                    if (aged, e.seq) > worst:
                        worst = (aged, e.seq)
                        victim_key, victim = gk, e
            if victim is None:
                # the incoming request is the least important in sight
                self._counts["rejected"] += 1
                raise SchedulerSaturated(
                    f"admission queue full ({limit} rows) of equal-or-"
                    f"higher-priority requests; request shed at admission "
                    f"(priority {entry.priority})")
            self._groups[victim_key].remove(victim)
            if not self._groups[victim_key]:
                del self._groups[victim_key]
            self._counts["shed"] += 1
            victim.handle._fail(SchedulerSaturated(
                f"request load-shed (aged priority {worst[0]:.2f}) to "
                f"admit a higher-priority request"), t_done=now)
        self._groups.setdefault(group_key, []).append(entry)
        self._cond.notify_all()

    def _normalize_rows(self, request: SearchRequest):
        """Split a request into per-query (terms, qw_b, qw_l) rows — a
        single flat query becomes one row — and report its live-term
        count (max over rows), which picks the route *before* any
        padding or truncation happens."""
        terms, qw_b, qw_l = request.terms, request.weights_b, request.weights_l
        if terms is None:
            raise ValueError("scheduler requests need sparse terms/weights")
        nd = getattr(terms, "ndim", None)
        flat = (nd == 1 if nd is not None
                # plain sequence: flat iff empty or scalar first element
                else len(terms) == 0 or np.ndim(terms[0]) == 0)
        if flat:
            # one query — including the 0-term edge, which pads to an
            # all-zero-weight no-op row (the historical server behavior)
            terms, qw_b, qw_l = [terms], [qw_b], [qw_l]
        rows = [(np.asarray(terms[i]),
                 np.asarray(qw_b[i], np.float32),
                 np.asarray(qw_l[i], np.float32))
                for i in range(len(terms))]
        qlen = max((query_length(wb, wl) for _, wb, wl in rows), default=0)
        return rows, qlen

    def _pad_rows(self, rows, width: int):
        """Static [r, width] row block: over-long rows keep their
        highest-impact terms (``truncate_terms``), short rows pad with
        zero-weight no-ops. ``width`` is the route's ``pad_terms`` (or
        the scheduler default), so a short length class executes at a
        narrow compiled shape."""
        r = len(rows)
        out_t = np.zeros((r, width), np.int32)
        out_b = np.zeros((r, width), np.float32)
        out_l = np.zeros((r, width), np.float32)
        for i, (t, wb, wl) in enumerate(rows):
            keep = truncate_terms(t, wb, wl, width, self.params.gamma)
            n = len(keep)
            out_t[i, :n] = t[keep]
            out_b[i, :n] = wb[keep]
            out_l[i, :n] = wl[keep]
        return out_t, out_b, out_l

    @staticmethod
    def _fingerprint(terms, qw_b, qw_l, tf) -> bytes:
        h = hashlib.sha1()
        for a in (terms, qw_b, qw_l):
            h.update(np.ascontiguousarray(a).tobytes())
        h.update(repr(tf).encode())
        return h.digest()

    def _retriever(self, route_name: str) -> Retriever:
        retr = self._retrievers.get(route_name)
        if retr is None:
            # double-checked under the route lock: a worker poll and a
            # main-thread flush racing here must not open (and for the
            # sharded engine, partition) the same route twice
            with self._open_lock:
                retr = self._retrievers.get(route_name)
                if retr is None:
                    route = self.routing.by_name(route_name)
                    retr = Retriever.open(self.index, self.params,
                                          engine=route.engine,
                                          k_buckets=self.k_buckets,
                                          generation=self._generation,
                                          **route.opts())
                    self._retrievers[route_name] = retr
        return retr

    def _resolve_retriever(self, route_name: str,
                           retrievers: dict | None) -> tuple:
        """(retriever, generation) for one attempt. With a replica map
        (executor pool), a map left behind by a hot-swap is cleared and
        rebuilt from the new masters before use — the generation check
        is what makes the flip safe without stopping the pool."""
        if retrievers is None:
            retr = self._retriever(route_name)
            return retr, retr.generation
        with self._lock:
            gen = self._generation
        if getattr(retrievers, "generation", gen) != gen:
            retrievers.clear()
            retrievers.generation = gen
        retr = retrievers.get(route_name)
        if retr is None:
            retr = self._retriever(route_name).replicate()
            retrievers[route_name] = retr
        return retr, retr.generation

    # -- dispatch ------------------------------------------------------------

    def pending_count(self) -> int:
        with self._lock:
            return sum(len(g) for g in self._groups.values())

    def next_deadline(self) -> float | None:
        """Earliest actionable time among pending requests (absolute
        perf_counter time), or None when the queue is idle. An entry in
        retry backoff is not actionable before ``not_before``, so the
        sync driver never busy-spins on a backing-off queue."""
        with self._lock:
            deadlines = [max(e.deadline, e.not_before)
                         for g in self._groups.values() for e in g]
        return min(deadlines) if deadlines else None

    def poll(self, now: float | None = None, force: bool = False) -> int:
        """Dispatch every *due* micro-batch inline; returns the number of
        requests completed. A group is due when it can fill ``max_batch``
        rows or its oldest deadline has passed (``force`` dispatches
        everything — that is ``flush``)."""
        completed = 0
        while True:
            picked = self._pick_batch(
                time.perf_counter() if now is None else now, force)
            if picked is None:
                return completed
            completed += self._execute(*picked)

    def flush(self) -> int:
        """Drain: dispatch every pending request regardless of deadlines."""
        return self.poll(force=True)

    def _expire_locked(self, now: float) -> int:
        """Shed every queued entry whose deadline budget ran out: the
        handle fails with :class:`DeadlineExceeded` and the entry never
        occupies a batch slot. Called under the lock at pick time."""
        expired = []
        for gk in list(self._groups):
            keep = [e for e in self._groups[gk] if e.expires > now]
            if len(keep) != len(self._groups[gk]):
                expired.extend(e for e in self._groups[gk]
                               if e.expires <= now)
                if keep:
                    self._groups[gk] = keep
                else:
                    del self._groups[gk]
        if expired:
            self._counts["expired"] += len(expired)
            for e in expired:
                h = e.handle
                h._fail(DeadlineExceeded(
                    f"deadline of {h.deadline_ms}ms expired before "
                    f"dispatch (route {h.route!r}, k-bucket "
                    f"{h.k_bucket})"), t_done=now)
                if self.tracer.enabled:
                    self.tracer.emit(
                        "request", h.t_submit, now, trace_id=e.seq,
                        route=h.route, k_bucket=h.k_bucket,
                        priority=e.priority, rows=e.rows,
                        outcome="expired",
                        deadline_ms=h.deadline_ms)
            # expired rows free admission-queue space
            self._cond.notify_all()
        return len(expired)

    def _pick_batch(self, now: float, force: bool):
        """Pop one due micro-batch (whole requests, up to ``max_batch``
        rows) under the lock; execution happens outside it. Entries in
        retry backoff (``not_before`` in the future) are invisible
        unless ``force`` drains them early; already-expired entries are
        shed first and never picked."""
        with self._lock:
            self._expire_locked(now)
            due_key = None
            due_deadline = math.inf
            for key, group in self._groups.items():
                eligible = (group if force
                            else [e for e in group if e.not_before <= now])
                if not eligible:
                    continue
                rows = sum(e.rows for e in eligible)
                oldest = min(e.deadline for e in eligible)
                if force or rows >= self.cfg.max_batch or oldest <= now:
                    if oldest < due_deadline:
                        due_key, due_deadline = key, oldest
            if due_key is None:
                return None
            group = self._groups[due_key]
            # aged priority decides dispatch order *at pick time* (a
            # static heap order could not model aging); FIFO within a
            # level via seq. With sort_batches_by_cost, predicted chunk
            # count breaks ties within a priority level, so consecutive
            # micro-batches carry similar-cost rows and the while_loop's
            # max-over-batch trip count stays near the batch mean.
            if self.cfg.sort_batches_by_cost:
                group.sort(key=lambda e: (
                    self._aged_priority(e.priority, e.handle.t_submit,
                                        now),
                    e.cost, e.seq))
            else:
                group.sort(key=lambda e: (
                    self._aged_priority(e.priority, e.handle.t_submit,
                                        now),
                    e.seq))
            batch, rows = [], 0
            i = 0
            while i < len(group):
                e = group[i]
                if not force and e.not_before > now:
                    i += 1
                    continue
                if batch and rows + e.rows > self.cfg.max_batch:
                    break
                group.pop(i)
                batch.append(e)
                rows += e.rows
            if not group:
                del self._groups[due_key]
            self._counts["in_flight"] += len(batch)
            # picked rows free admission-queue space: wake blocked submitters
            self._cond.notify_all()
            return due_key, batch

    def _execute(self, key: tuple, batch: list, *,
                 retrievers: dict | None = None,
                 executor_id: int | None = None,
                 now: float | None = None) -> int:
        """Run one picked batch. ``retrievers`` lets an executor slot
        substitute its own replica map for the shared one; the pool tags
        ``executor_id`` so per-executor batch/row counters (and the
        health monitor) aggregate per slot. ``now`` pins the clock for
        simulated-time tests (begin and completion share it)."""
        token = self._begin_batch(key, batch, executor_id, now)
        return self._run_attempt(token, retrievers=retrievers,
                                 executor_id=executor_id, now=now)

    def _begin_batch(self, key: tuple, batch: list,
                     executor_id: int | None,
                     now: float | None = None) -> int:
        """Register a picked batch as in flight: the token is what
        retries, hedges, and first-result-wins delivery key on. The
        record carries the min remaining deadline budget over its rows
        (inf with no deadlines) — what an executor could use to skip
        doomed work or size hedging."""
        now = time.perf_counter() if now is None else now
        budget = min((e.expires - now) * 1e3 for e in batch)
        with self._lock:
            token = next(self._inflight_seq)
            self._inflight[token] = _Inflight(
                token=token, key=key, batch=batch, t_start=now,
                budget_ms=budget, executor_id=executor_id,
                attempts=max(e.attempts for e in batch))
        return token

    def _run_attempt(self, token: int, *, retrievers: dict | None = None,
                     executor_id: int | None = None,
                     now: float | None = None) -> int:
        """One execution attempt of an in-flight batch (the primary
        pick, a retry, or a hedge). An attempt whose token is already
        gone was cancelled at the queue — the race winner delivered
        before this attempt started executing."""
        t_start = time.perf_counter() if now is None else now
        with self._lock:
            rec = self._inflight.get(token)
            if rec is None:
                self._counts["hedges_cancelled"] += 1
                return 0
            key, batch = rec.key, rec.batch
        bucket, route_name, tf = key
        # degraded mode: while any breaker is not closed, a route with a
        # fallback lane executes there (same padded width by policy
        # validation) and the responses are flagged degraded
        exec_route, degraded = route_name, False
        if self.health.degraded():
            fb = self.routing.by_name(route_name).fallback
            if fb is not None:
                exec_route, degraded = fb, True
        delay_ms = 0.0
        try:
            retr, gen = self._resolve_retriever(exec_route, retrievers)
            if self.faults is not None:
                b_idx, g_idx = self._next_indices(executor_id)
                delay_ms = self.faults.on_batch(
                    executor_id=executor_id, batch_index=b_idx,
                    global_index=g_idx, route=exec_route, generation=gen)
            resp, n_real, n_pad = self._search_batch(retr, batch, tf)
        except Exception as exc:
            return self._attempt_failed(token, exc, executor_id, now)
        t_done = time.perf_counter() if now is None else now
        n = self._deliver(token, resp, n_real, n_pad, degraded=degraded,
                          executor_id=executor_id, t_done=t_done)
        if executor_id is not None and n:
            # virtual fault delays count toward the EWMA/percentiles so
            # simulated-clock tests exercise real health dynamics
            self.health.record_success(
                executor_id, (t_done - t_start) * 1e3 + delay_ms, t_done)
        return n

    def _search_batch(self, retr: Retriever, batch: list, tf):
        """Concatenate + pad one batch to the static shape and run it."""
        terms = np.concatenate([e.terms for e in batch])
        qw_b = np.concatenate([e.qw_b for e in batch])
        qw_l = np.concatenate([e.qw_l for e in batch])
        ks = np.concatenate([e.ks for e in batch])
        n_real = terms.shape[0]
        n_pad = 0
        if self.cfg.pad_batch and n_real < self.cfg.max_batch:
            # zero-weight no-op rows: static [max_batch, pad_terms] shape
            # -> one compile per (k-bucket x length-class), any fill level
            n_pad = self.cfg.max_batch - n_real
            terms = np.concatenate(
                [terms, np.zeros((n_pad, terms.shape[1]), np.int32)])
            qw_b = np.concatenate(
                [qw_b, np.zeros((n_pad, qw_b.shape[1]), np.float32)])
            qw_l = np.concatenate(
                [qw_l, np.zeros((n_pad, qw_l.shape[1]), np.float32)])
            ks = np.concatenate([ks, np.ones(n_pad, np.int32)])
        resp = retr.search(terms=terms, weights_b=qw_b, weights_l=qw_l,
                           k=ks, threshold_factor=tf)
        return resp, n_real, n_pad

    def _deliver(self, token: int, resp: SearchResponse, n_real: int,
                 n_pad: int, *, degraded: bool,
                 executor_id: int | None, t_done: float) -> int:
        """First result wins: pop the in-flight record and complete the
        handles. A losing (hedged) attempt finds the record gone and its
        result is discarded. Completion notifies the condition — blocked
        submitters and deadline waiters wake immediately."""
        row0 = 0
        with self._cond:
            rec = self._inflight.pop(token, None)
            if rec is None:
                self._counts["hedges_wasted"] += 1
                return 0
            batch = rec.batch
            bucket, route_name, tf = rec.key
            self._counts["batches"] += 1
            self._counts["rows_executed"] += n_real
            self._counts["rows_padding"] += n_pad
            self._counts["in_flight"] -= len(batch)
            if degraded:
                self._counts["degraded_batches"] += 1
            gname = f"k{bucket}/{route_name}"
            self._group_batches[gname] = self._group_batches.get(gname, 0) + 1
            if executor_id is not None:
                self._executor_batches[executor_id] = (
                    self._executor_batches.get(executor_id, 0) + 1)
                self._executor_rows[executor_id] = (
                    self._executor_rows.get(executor_id, 0) + n_real)
            service_ms = max((t_done - rec.t_start) * 1e3, 0.0)
            self._hist_service.record(service_ms)
            tracing = self.tracer.enabled
            if tracing:
                self.tracer.emit(
                    "batch", rec.t_start, t_done,
                    trace_id=f"batch-{rec.token}", batch=rec.token,
                    route=route_name, k_bucket=bucket, rows=n_real,
                    padding=n_pad, attempts=rec.attempts,
                    degraded=degraded,
                    executor=-1 if executor_id is None else executor_id)
            for e in batch:
                rows = slice(row0, row0 + e.rows)
                row0 += e.rows
                k_e = int(e.ks.max())
                # materialized copies, not views: a view would pin the
                # whole padded batch alive for the cache's lifetime, and
                # a consumer mutating its response would corrupt the
                # shared cache entry
                sliced = SearchResponse(
                    ids=resp.ids[rows, :k_e].copy(),
                    scores=resp.scores[rows, :k_e].copy(),
                    engine=resp.engine, k=k_e, k_exec=resp.k_exec,
                    stats=self._slice_stats(resp.stats, rows,
                                            n_real + n_pad),
                    latency_ms=resp.latency_ms, ks=e.ks,
                    generation=resp.generation, degraded=degraded)
                # never cache a degraded (fallback-lane) response, nor
                # one a concurrent hot-swap already obsoleted — a stale
                # or approximate entry must not outlive the fault
                if (e.cache_key is not None and not degraded
                        and resp.generation == self._generation
                        and self._cache_admit_locked(e.cache_key)):
                    full = e.cache_key + (resp.generation,)
                    self._cache[full] = (self._detach(sliced), t_done)
                    self._cache.move_to_end(full)
                    while len(self._cache) > self.cfg.cache_size:
                        self._cache.popitem(last=False)
                self._counts["completed"] += 1
                e.handle._complete(sliced, t_done=t_done)
                self._hist_queue.record(
                    max((rec.t_start - e.handle.t_submit) * 1e3, 0.0))
                if tracing:
                    self._trace_request(rec, e, sliced, t_done,
                                        degraded, executor_id)
            self._cond.notify_all()
        return len(batch)

    def _trace_request(self, rec: _Inflight, e: _Pending,
                       sliced: SearchResponse, t_done: float,
                       degraded: bool, executor_id: int | None) -> None:
        """Emit one request's trace at delivery: a root ``request`` span
        with ``queue`` and ``execute`` children. Spans are emitted
        retroactively from the timestamps the scheduler already carries
        (handle.t_submit, the in-flight record's t_start, t_done), so
        tracing never adds state to the hot path. The execute span gets
        the traversal's per-query counters (``chunks_dispatched`` et
        al.) plus the cost-model features/prediction when present."""
        from ..obs import trace_exec  # imports jax via core.traversal
        t_sub = e.handle.t_submit
        root = self.tracer.emit(
            "request", t_sub, t_done, trace_id=e.seq,
            route=e.handle.route, k_bucket=e.handle.k_bucket,
            priority=e.priority, rows=e.rows, attempts=rec.attempts,
            degraded=degraded, outcome="completed")
        self.tracer.emit(
            "queue", t_sub, rec.t_start, trace_id=e.seq, parent=root,
            queue_wait_ms=float(max((rec.t_start - t_sub) * 1e3, 0.0)))
        attrs = trace_exec.request_attributes(sliced.stats)
        if e.features is not None:
            attrs["cost_features"] = list(e.features)
            if e.cost:
                attrs["cost_pred"] = e.cost
        self.tracer.emit(
            "execute", rec.t_start, t_done, trace_id=e.seq, parent=root,
            batch=rec.token, budget_ms=rec.budget_ms,
            executor=-1 if executor_id is None else executor_id,
            **attrs)

    def _cache_admit_locked(self, base_key: tuple) -> bool:
        """Admission filter: "always" stores every response;
        "second_sight" only stores keys seen before (the first sighting
        goes on an LRU ghost list), keeping one-hit wonders from
        displacing repeating queries."""
        if self.cfg.cache_admission == "always":
            return True
        seen = base_key in self._cache_seen
        self._cache_seen[base_key] = True
        self._cache_seen.move_to_end(base_key)
        while len(self._cache_seen) > max(8 * self.cfg.cache_size, 1024):
            self._cache_seen.popitem(last=False)
        if not seen:
            self._counts["cache_admission_skips"] += 1
        return seen

    def _attempt_failed(self, token: int, exc: BaseException,
                        executor_id: int | None,
                        now: float | None = None) -> int:
        """Resolve one failed attempt: absorb it while other attempts
        of the batch are still racing, requeue the rows with backoff
        when the route's retry policy covers the fault, else fail every
        handle and re-raise (sync callers see the error; workers survive
        it)."""
        t_done = time.perf_counter() if now is None else now
        if executor_id is not None:
            self.health.record_failure(executor_id, t_done)
        with self._cond:
            rec = self._inflight.get(token)
            if rec is None:
                # the race winner already delivered; this loss is moot
                self._counts["hedge_failures"] += 1
                return 0
            rec.outstanding -= 1
            if rec.outstanding > 0:
                # a hedge of this batch is still running — let it win
                self._counts["hedge_failures"] += 1
                return 0
            del self._inflight[token]
            batch = rec.batch
            bucket, route_name, tf = rec.key
            policy = self.routing.by_name(route_name).retry
            if policy is None:
                policy = self.cfg.retry
            if (policy is not None and policy.retryable(exc)
                    and rec.attempts < policy.max_attempts):
                # requeue with deterministic seeded backoff; the entries
                # become pick-eligible again at not_before
                delay = policy.delay_ms(
                    rec.attempts, token=min(e.seq for e in batch))
                for e in batch:
                    e.attempts = rec.attempts + 1
                    e.not_before = t_done + delay / 1e3
                self._groups.setdefault(rec.key, []).extend(batch)
                self._counts["retries"] += 1
                self._counts["in_flight"] -= len(batch)
                self._cond.notify_all()
                return 0
            self._counts["failed"] += len(batch)
            self._counts["in_flight"] -= len(batch)
            for e in batch:
                e.handle._fail(exc, t_done)
            self._cond.notify_all()
        raise exc

    # -- hedging -------------------------------------------------------------

    def hedge_due(self, now: float | None = None,
                  exclude_executor: int | None = None) -> list:
        """Mark straggler batches for hedged re-execution and return
        their tokens. A batch qualifies once it has been in flight
        longer than the hedge delay (``cfg.hedge_ms``, or the health
        monitor's recent p99 under ``hedge_from_p99``) and has no hedge
        yet. The caller runs ``_run_attempt(token, ...)`` for each
        token on a *different* executor (``exclude_executor`` filters
        out batches whose primary is the would-be hedger)."""
        delay = self.cfg.hedge_ms
        if self.cfg.hedge_from_p99:
            delay = self.health.latency_p99_ms(default=self.cfg.hedge_ms)
        if delay <= 0:
            return []
        now = time.perf_counter() if now is None else now
        tokens = []
        with self._lock:
            for token, rec in self._inflight.items():
                if rec.hedged:
                    continue
                if (exclude_executor is not None
                        and rec.executor_id == exclude_executor):
                    continue
                if (now - rec.t_start) * 1e3 < delay:
                    continue
                rec.hedged = True
                rec.outstanding += 1
                self._counts["hedges"] += 1
                tokens.append(token)
        return tokens

    # -- hot swap ------------------------------------------------------------

    def swap_index(self, index, params: TwoLevelParams | None = None, *,
                   warm: bool = True) -> int:
        """Install a rebuilt index as a new generation behind a
        two-phase gate. Phase 1 (no lock held, pool keeps serving):
        open fresh retrievers for every route at the next generation
        and warm them over the routing grid, so the flip never pays a
        trace. Phase 2 (under the scheduler lock, between batches):
        swap the masters, bump the generation, and purge every cache
        entry of an older generation. Batches already in flight finish
        on their old replica — their responses carry the old generation
        stamp and are never cached. Executor replica maps rebuild
        lazily on their next resolve. Returns the new generation."""
        with self._open_lock:
            params = self.params if params is None else params
            next_gen = self._generation + 1
            fresh = {}
            for route in self.routing.all_routes:
                fresh[route.name] = Retriever.open(
                    index, params, engine=route.engine,
                    k_buckets=self.k_buckets, generation=next_gen,
                    **route.opts())
            if warm:
                buckets = (self.k_buckets if self.k_buckets
                           else (resolve_k(params, None),))
                for route, width, bucket in warmup_grid(
                        self.routing, buckets, self.cfg.pad_terms):
                    b = self.cfg.max_batch
                    zero_w = np.zeros((b, width), np.float32)
                    fresh[route.name].search(
                        terms=np.zeros((b, width), np.int32),
                        weights_b=zero_w, weights_l=zero_w,
                        k=np.full(b, bucket, np.int32))
            with self._cond:
                self.index = index
                self.params = params
                self._policy_fp = self.routing.fingerprint(params)
                self._retrievers = fresh
                self._generation = next_gen
                # cost features are index-derived; refit lazily on the
                # new generation's stats arrays
                self._featurizer = None
                stale = [k for k in self._cache if k[-1] != next_gen]
                for k in stale:
                    del self._cache[k]
                self._counts["cache_gen_evictions"] += len(stale)
                self._counts["swaps"] += 1
                self._cond.notify_all()
        return next_gen

    @property
    def generation(self) -> int:
        with self._lock:
            return self._generation

    # -- executor liveness ---------------------------------------------------

    def _record_executor_death(self, executor_id: int | None,
                               exc: BaseException) -> None:
        """A worker thread died outside batch execution (batch failures
        resolve their own handles; this path has no handle to fail).
        The scheduler survives: the death is counted and surfaced in
        ``stats()``, the executor's breaker goes terminally dead, and
        waiters are notified so nothing blocks on the lost thread."""
        with self._cond:
            self._counts["executor_deaths"] += 1
            self._dead_executors[-1 if executor_id is None
                                 else executor_id] = repr(exc)
            self._cond.notify_all()
        if executor_id is not None:
            self.health.mark_dead(executor_id)

    def _next_indices(self, executor_id) -> tuple:
        """(per-executor, global) batch-attempt ordinals for the fault
        plan's positional matching."""
        with self._lock:
            g = self._fault_global
            self._fault_global += 1
            b = self._fault_per_exec.get(executor_id, 0)
            self._fault_per_exec[executor_id] = b + 1
        return b, g

    @staticmethod
    def _detach(resp: SearchResponse, **overrides) -> SearchResponse:
        """A response whose arrays (ids, scores, ks, per-query stats)
        are private copies. The cache entry and every delivered response
        must never alias: a consumer mutating its response would
        otherwise rewrite what later hits are served."""
        return dataclasses.replace(
            resp, ids=resp.ids.copy(), scores=resp.scores.copy(),
            ks=resp.ks.copy(),
            stats={n: v.copy() if isinstance(v, np.ndarray) else v
                   for n, v in resp.stats.items()},
            **overrides)

    @staticmethod
    def _slice_stats(stats: dict, rows: slice, batch_rows: int) -> dict:
        """Per-query counter arrays slice to the request's rows; scalar
        counters pass through unchanged."""
        out = {}
        for name, v in stats.items():
            arr = np.asarray(v)
            out[name] = (arr[rows].copy()
                         if arr.ndim >= 1 and arr.shape[0] == batch_rows
                         else v)
        return out

    # -- warmup --------------------------------------------------------------

    def warmup(self, buckets=None) -> float:
        """Pre-compile the full serving grid — one zero-weight no-op
        batch per ``warmup_grid`` cell (route x k-bucket), at the
        route's static ``[max_batch, width]`` shape — so the first real
        request of *any* group never pays a trace. jit caches are
        process-global, so one pass warms every executor replica at
        once. Returns the wall-seconds spent (cumulative; also surfaced
        as ``warmup_s`` in ``stats()``)."""
        t0 = time.perf_counter()
        if buckets is None:
            buckets = (self.k_buckets if self.k_buckets
                       else (resolve_k(self.params, None),))
        for route, width, bucket in warmup_grid(
                self.routing, buckets, self.cfg.pad_terms):
            retr = self._retriever(route.name)
            b = self.cfg.max_batch
            zero_w = np.zeros((b, width), np.float32)
            retr.search(terms=np.zeros((b, width), np.int32),
                        weights_b=zero_w, weights_l=zero_w,
                        k=np.full(b, bucket, np.int32))
        self._warmup_s += time.perf_counter() - t0
        return self._warmup_s

    # -- stats / cache -------------------------------------------------------

    def stats(self) -> dict:
        """Serving counters: submissions, batches, cache hits/misses,
        per-route request counts, per-(bucket x class) and per-executor
        batch counts. The whole snapshot is read under the scheduler
        lock and returned as a detached dict (nested dicts copied), so
        a reader racing N executor threads sees one consistent moment:
        ``submitted == completed + failed + shed + rejected + expired +
        pending + in_flight`` holds in every snapshot."""
        with self._lock:
            counts = dict(self._counts)
            snap = {**counts,
                    "admitted": counts["submitted"] - counts["rejected"],
                    "warmup_s": self._warmup_s,
                    "cache_entries": len(self._cache),
                    "pending": sum(len(g) for g in self._groups.values()),
                    "pending_rows": self._pending_rows_locked(),
                    "generation": self._generation,
                    "dead_executors": dict(self._dead_executors),
                    "requests_by_route": dict(self._route_requests),
                    "batches_by_group": dict(self._group_batches),
                    "batches_by_executor": dict(self._executor_batches),
                    "rows_by_executor": dict(self._executor_rows)}
        # the health monitor has its own (leaf) lock; read outside ours
        snap["breakers"] = self.health.snapshot()
        # histograms carry their own (leaf) locks too: pick-to-submit
        # queue wait and batch service time as exact-rank-at-bucket
        # summaries ({"n": 0} before any delivery — never NaN)
        snap["queue_wait_ms"] = self._hist_queue.summary()
        snap["service_ms"] = self._hist_service.summary()
        return snap

    def cache_clear(self) -> None:
        with self._lock:
            self._cache.clear()

    # -- threaded mode -------------------------------------------------------

    def is_running(self) -> bool:
        if self._pool is not None and self._pool.is_running():
            return True
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "AsyncRetrievalScheduler":
        """Run the background dispatch machinery (idempotent): the
        single worker thread, or — with ``cfg.executors > 0`` — an
        :class:`~repro.serve.executor.ExecutorPool` of N workers, each
        holding its own Retriever replica per route, warmed over the
        routing grid before any of them serves a request."""
        if self.is_running():
            return self
        self._stop = False
        if self.cfg.executors > 0:
            from .executor import ExecutorPool  # avoid an import cycle
            self._pool = ExecutorPool(self, self.cfg.executors)
            self._pool.start()
            return self
        self._thread = threading.Thread(
            target=self._worker, name="retrieval-scheduler", daemon=True)
        self._thread.start()
        return self

    def close(self, flush: bool = True) -> None:
        """Stop the worker(s); by default drain whatever is still
        queued — with a pool, the executors themselves drain the group
        queues before exiting, so close-time work still runs on every
        replica concurrently."""
        if self._pool is not None:
            self._pool.close(drain=flush)
            self._pool = None
        if self._thread is not None:
            with self._cond:
                self._stop = True
                self._cond.notify_all()
            self._thread.join()
            self._thread = None
        if flush:
            self.flush()

    def __enter__(self) -> "AsyncRetrievalScheduler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    def _worker(self) -> None:
        try:
            self._worker_loop()
        except BaseException as exc:  # noqa: BLE001 — liveness accounting
            # death outside batch execution (batch failures are handled
            # inside poll): record it so stats tell the operator why
            # the queue stopped draining, instead of silent stranding
            self._record_executor_death(None, exc)

    def _worker_loop(self) -> None:
        while True:
            with self._cond:
                if self._stop:
                    return
                # an entry in retry backoff wakes the worker at
                # not_before, not at its (possibly past) deadline
                deadlines = [min(max(e.deadline, e.not_before) for e in g)
                             for g in self._groups.values() if g]
                full = any(sum(e.rows for e in g) >= self.cfg.max_batch
                           for g in self._groups.values())
                if not deadlines:
                    self._cond.wait(timeout=0.1)
                    continue
                wait = min(deadlines) - time.perf_counter()
                if not full and wait > 0:
                    self._cond.wait(timeout=min(wait, 0.05))
                    continue
            try:
                self.poll()
            except Exception:
                # the failing batch's handles were already failed by
                # _execute; the worker must keep serving everyone else
                pass


def aggregate_latencies(latencies_ms, wall_s: float,
                        histogram: Histogram | None = None) -> dict:
    """MRT/P50/P99/QPS over a served workload's per-request latencies —
    the single copy of the serving latency accounting (the scheduler's
    ``run_workload``, the deprecated server shim, and the serving bench
    all use it). NaN entries (in-flight requests) are dropped and
    zero-service cache completions clamp at 0, so neither poisons the
    aggregates. Quantiles are **exact-rank** (``obs.metrics``), not
    numpy's interpolated percentiles: the reported p99 is a latency
    some request actually experienced. Passing ``histogram`` also folds
    the samples into a registry histogram (the bench's mergeable
    export)."""
    lat = np.asarray(latencies_ms, np.float64)
    lat = np.clip(lat[np.isfinite(lat)], 0.0, None)
    if histogram is not None:
        histogram.record_many(lat)
    if lat.size == 0:
        return {"n": 0, "mrt_ms": math.nan, "p50_ms": math.nan,
                "p99_ms": math.nan, "qps_achieved": 0.0}
    return {"n": int(lat.size), "mrt_ms": float(lat.mean()),
            "p50_ms": exact_quantile(lat, 0.50),
            "p99_ms": exact_quantile(lat, 0.99),
            "qps_achieved": lat.size / wall_s}


def mixed_request_stream(corpus, n: int, *, short_len: int = 3,
                         k_pool=(10, 100),
                         query_pool: int | None = None,
                         deadline_ms: float | None = None) -> list:
    """Deterministic real-traffic-shaped demo stream over a synthetic
    corpus: alternate short (``short_len``-term) and full-length rows,
    cycle ``k`` through ``k_pool`` (mixed k-buckets in flight), and
    cycle a ``query_pool``-sized query subset so queries repeat — the
    access pattern the response cache exists for. The single copy the
    serving example and ``benchmarks/serving_bench.py`` both drive, so
    their numbers describe the same workload."""
    qn = min(query_pool or len(corpus.queries), len(corpus.queries))
    reqs = []
    for i in range(n):
        qi = i % qn
        qlen = short_len if i % 2 == 0 else corpus.queries.shape[1]
        reqs.append(SearchRequest(
            terms=corpus.queries[qi, :qlen],
            weights_b=corpus.q_weights_b[qi, :qlen],
            weights_l=corpus.q_weights_l[qi, :qlen],
            k=k_pool[(i // 2) % len(k_pool)],
            deadline_ms=deadline_ms))
    return reqs


def run_workload(scheduler: AsyncRetrievalScheduler,
                 requests: list, qps: float, seed: int = 0,
                 priorities=None) -> dict:
    """Open-loop Poisson driver: submit ``requests`` (SearchRequests) at
    exponential inter-arrival times — single-host serving, the regime
    the paper's MRT/P99 tables use. With no worker running it polls the
    scheduler inline (deterministic sync mode); with ``start()`` active
    (single worker or executor pool) it only submits and then blocks on
    the handles, so dispatch concurrency is whatever the scheduler
    runs. Latency is admission -> completion per handle, so it includes
    batching delay; cache hits complete with zero service time and are
    clamped at 0 (never negative, never NaN, never dropped). Requests
    refused at admission (``SchedulerSaturated``) and load-shed victims
    are excluded from the latency aggregates but appear in the returned
    ``stats()`` counters. Returns latency aggregates plus
    ``scheduler.stats()``, and reports **goodput** next to QPS:
    ``n_in_deadline`` / ``goodput_qps`` count only completions that met
    their own ``deadline_ms`` (every completion, for deadline-free
    requests) — the number that matters when expired work still burns
    batch slots.
    """
    if not requests:
        return {"n": 0, "mrt_ms": math.nan, "p50_ms": math.nan,
                "p99_ms": math.nan, "qps_achieved": 0.0,
                "n_in_deadline": 0, "goodput_qps": 0.0,
                **scheduler.stats()}
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / qps, len(requests)))
    threaded = scheduler.is_running()
    t0 = time.perf_counter()
    handles = []
    i, n = 0, len(requests)
    while i < n or (not threaded and scheduler.pending_count()):
        now = time.perf_counter() - t0
        while i < n and arrivals[i] <= now:
            pr = 0 if priorities is None else int(priorities[i])
            try:
                handles.append(scheduler.submit(requests[i], priority=pr,
                                                now=t0 + arrivals[i]))
            except SchedulerSaturated:
                pass  # rejected at admission; counted in stats()
            i += 1
        if threaded:
            # the worker(s) dispatch; just pace the arrivals
            if i < n:
                time.sleep(max(0.0,
                               t0 + arrivals[i] - time.perf_counter()))
            continue
        # a failing batch resolves its own handles (and is popped from
        # its group, so draining terminates); one bad route must not
        # abort the measurement for every other request
        try:
            progressed = (scheduler.flush() if i >= n
                          else scheduler.poll())
        except Exception:
            continue
        if i < n and not progressed:
            nxt = t0 + arrivals[i]
            dl = scheduler.next_deadline()
            if dl is not None:
                nxt = min(nxt, dl)
            time.sleep(max(0.0, nxt - time.perf_counter()))
    if threaded:
        for h in handles:
            try:
                h.result(timeout=120.0)
            except Exception:
                pass  # failures/sheds surface via stats and are filtered
    wall = time.perf_counter() - t0
    served = [h.latency_ms for h in handles if h._exception is None]
    n_good = sum(
        1 for h in handles
        if h._exception is None and math.isfinite(h.latency_ms)
        and (h.deadline_ms is None or h.latency_ms <= h.deadline_ms))
    return {**aggregate_latencies(served, wall),
            "n_in_deadline": n_good, "goodput_qps": n_good / wall,
            **scheduler.stats()}
