"""Mesh-sharded retrieval: per-shard tile scans + collective top-k merge.

The index is partitioned into contiguous tile ranges (``core.shard_plan``)
laid out on a one-axis device mesh. Every shard runs the *same* executor
step as the single-device engine (``core.traversal._tile_step``, planner
from ``core.plan``) over its own tiles under ``shard_map``, carrying
shard-local top-k queues; the final queues are ring-all-gathered
(``dist.collectives.ring_gather_stack``) and merged with one stable top-k
per queue. Stacking the gathered queues in shard order before the merge
preserves the single-device stable-tie discipline: with the ``docid``
schedule the concatenation enumerates candidates in exactly the global
tile order, so for rank-safe configurations (alpha = beta = gamma) the
merged Q_Rk is bit-identical to ``retrieve_batched`` — ids, scores and
tie-breaks. Guided (rank-unsafe) configurations prune against thresholds
whose trajectory depends on traversal order, so a shard's looser local
theta can keep boundary docs the sequential traversal froze; heads agree,
tails may differ within the usual guided tolerance.

Threshold exchange (``exchange_every``): every E tiles the shards
all-gather their Global queues and set a shared floor theta — the k-th
best Global score across the union, i.e. the *exact* global theta at that
point — so subsequent tile skips prune against the global queue rather
than the local one. Thresholds only tighten, so the floor is always safe.

Two execution paths share every formula:

  - ``mesh`` path: ``shard_map`` over a mesh axis, ring-collective merge —
    the multi-device deployment (and the 8-fake-device slow-lane test);
  - emulation path (``mesh=None``): ``vmap`` over the stacked shard axis
    with the identical merge math — runs any shard count on one device
    and is bit-identical to the mesh path, which is what the fast-lane
    parity tests pin down.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from ..core.plan import chunk_schedule, plan_query, tile_schedule
from ..core.shard_plan import ShardedImpactIndex
from ..core.traversal import (STAT_KEYS, RetrievalResult, _chunk_scan,
                              _chunk_while, _init_carry, _tile_step)
from ..core.twolevel import TwoLevelParams, resolve_k
from ..dist.collectives import ring_gather_stack
from .engine import RetrievalServer, ServerConfig


def make_shard_mesh(n_shards: int, axis_name: str = "shard"):
    """One-axis mesh over the first ``n_shards`` local devices."""
    devs = jax.devices()
    if len(devs) < n_shards:
        raise ValueError(
            f"need {n_shards} devices for a {n_shards}-shard mesh, have "
            f"{len(devs)} (set XLA_FLAGS=--xla_force_host_platform_device_"
            f"count={n_shards} before jax initializes, or pass mesh=None "
            f"for the single-device emulation path)")
    return jax.sharding.Mesh(np.array(devs[:n_shards]), (axis_name,))


def _merge_stacked(vals, ids, k: int):
    """Merge shard-stacked queues [n, B, k] -> [B, k], shard-order stable."""
    n, b, kk = vals.shape
    v = jnp.moveaxis(vals, 0, 1).reshape(b, n * kk)
    i = jnp.moveaxis(ids, 0, 1).reshape(b, n * kk)
    top, idx = jax.lax.top_k(v, k)
    return top, jnp.take_along_axis(i, idx, axis=1)


def _global_theta(gv, k: int):
    """k-th best Global score across the union of shard queues: [n,B,k]->[B]."""
    n, b, kk = gv.shape
    v = jnp.moveaxis(gv, 0, 1).reshape(b, n * kk)
    return jax.lax.top_k(v, k)[0][:, -1]


def _fold_schedule(tiles, tiles_per_shard: int, exchange_every: int):
    """Reshape a tile order [..., T] into exchange rounds [..., C, E].

    E is the exchange period (the whole schedule when exchange is off).
    The tail round is padded with the sentinel tile ``tiles_per_shard``:
    it is >= every shard's ``n_real``, so ``_tile_step`` force-skips it
    (``tile_valid`` False) and it touches no queue, stat, or gather —
    every round gets the same static length and the round loop can be a
    single ``lax.scan`` instead of unrolled segments.
    """
    t = tiles.shape[-1]
    period = exchange_every if 0 < exchange_every < t else t
    n_rounds = -(-t // period)
    pad = n_rounds * period - t
    if pad:
        tiles = jnp.concatenate(
            [tiles, jnp.full(tiles.shape[:-1] + (pad,), tiles_per_shard,
                             jnp.int32)], axis=-1)
    return tiles.reshape(tiles.shape[:-1] + (n_rounds, period))


def _plan_shard(tm_b, tm_l, sigma_b, sigma_l, q_terms, qw_b, qw_l, alpha,
                *, tiles_per_shard, schedule):
    """Batched planner for one shard: plans [B, ...], tile order [B, T]."""
    def one(qt, qwb, qwl):
        plan = plan_query(qt, qwb, qwl, sigma_b, sigma_l, alpha)
        tiles = tile_schedule(plan, tm_b, tm_l, alpha,
                              tiles_per_shard, schedule)
        return plan, tiles
    return jax.vmap(one)(q_terms, qw_b, qw_l)


def _plan_shard_chunked(tm_b, tm_l, sigma_b, sigma_l, q_terms, qw_b, qw_l,
                        alpha, n_real, *, tiles_per_shard, chunk_tiles):
    """Chunked planner for one shard: plans [B, ...] plus the descending
    chunk order [B, n_chunks, C] / bounds [B, n_chunks]. Shape-padding
    tiles (id >= ``n_real``) get -inf bounds so they sort last and never
    keep the chunk loop alive; the sentinel ``tiles_per_shard`` pads the
    ragged tail chunk."""
    def one(qt, qwb, qwl):
        plan = plan_query(qt, qwb, qwl, sigma_b, sigma_l, alpha)
        sched = chunk_schedule(plan, tm_b, tm_l, alpha, tiles_per_shard,
                               chunk_tiles, n_real)
        return plan, sched
    return jax.vmap(one)(q_terms, qw_b, qw_l)


def _fold_chunk_rounds(chunks, chunk_ub, tiles_per_shard: int,
                       exchange_every: int, chunk_tiles: int):
    """Fold a chunk order [..., n_chunks, C] into exchange rounds
    [..., n_rounds, per_round, C] (+ bounds [..., n_rounds, per_round]).

    The exchange period is counted in tiles (as for the full scan) and
    rounded up to whole chunks; the tail round is padded with all-sentinel
    chunks (bound -inf) so the round loop stays a single ``lax.scan``.
    """
    n_chunks = chunks.shape[-2]
    if 0 < exchange_every:
        per_round = min(max(1, -(-exchange_every // chunk_tiles)), n_chunks)
    else:
        per_round = n_chunks
    n_rounds = -(-n_chunks // per_round)
    pad = n_rounds * per_round - n_chunks
    if pad:
        chunks = jnp.concatenate(
            [chunks, jnp.full(chunks.shape[:-2] + (pad, chunks.shape[-1]),
                              tiles_per_shard, jnp.int32)], axis=-2)
        chunk_ub = jnp.concatenate(
            [chunk_ub, jnp.full(chunk_ub.shape[:-1] + (pad,), -jnp.inf,
                                jnp.float32)], axis=-1)
    chunks = chunks.reshape(
        chunks.shape[:-2] + (n_rounds, per_round, chunks.shape[-1]))
    chunk_ub = chunk_ub.reshape(chunk_ub.shape[:-1] + (n_rounds, per_round))
    return chunks, chunk_ub


def _chunk_round(idx_arrays, n_real, plans, chunks_round, ub_round,
                 carries, disp, th_floor,
                 alpha, beta, gamma, factor, *, statics):
    """Advance all queries of one shard over one round of chunks with a
    real early exit — the shared ``core.traversal._chunk_while`` loop
    over per-query ``_chunk_scan`` steps, with the exchanged global
    theta as the threshold floor."""
    def step_one(plan, tiles_i, carry, floor):
        return _chunk_scan(idx_arrays, plan, carry, tiles_i,
                           alpha, beta, gamma, factor, n_real,
                           th_floor=floor, **statics)

    def advance(i, carries):
        tiles_i = jax.lax.dynamic_index_in_dim(chunks_round, i, 1, False)
        return jax.vmap(step_one)(plans, tiles_i, carries, th_floor)

    return _chunk_while(advance, ub_round, carries, disp, th_floor, factor)


def _scan_chunk(idx_arrays, n_real, plans, tiles_chunk, carries, th_floor,
                alpha, beta, gamma, factor, *, statics):
    """Advance all queries of one shard over a chunk of its tile order.

    ``n_real`` is the shard's real tile count: shape-padding tiles (local
    index >= n_real) are force-skipped so they touch no queue or stat."""
    def one(plan, tiles_q, carry, floor):
        def step(c, tile):
            return _tile_step(idx_arrays, plan, c, tile,
                              alpha, beta, gamma, factor,
                              th_floor=floor, tile_valid=tile < n_real,
                              **statics), None
        c, _ = jax.lax.scan(step, carry, tiles_q)
        return c
    return jax.vmap(one)(plans, tiles_chunk, carries, th_floor)


def _broadcast_carry(k: int, n: int, b: int):
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (n, b) + x.shape), _init_carry(k))


def _rebase(ids, base):
    return jnp.where(ids >= 0, ids + base, ids)


@partial(jax.jit, static_argnames=(
    "k", "kq", "pad_len", "tile_size", "bound_mode", "use_kernel",
    "gather_kind", "schedule", "tiles_per_shard", "n_shards",
    "exchange_every", "traversal", "chunk_tiles"))
def _sharded_impl_emulated(gather, tm_b, tm_l, doc_base,
                           n_real, sigma_b, sigma_l, q_terms, qw_b, qw_l,
                           alpha, beta, gamma, factor,
                           *, k, kq, pad_len, tile_size, bound_mode,
                           use_kernel, gather_kind, schedule, tiles_per_shard,
                           n_shards, exchange_every, traversal="full",
                           chunk_tiles=8):
    statics = dict(k=k, kq=kq, pad_len=pad_len, tile_size=tile_size,
                   bound_mode=bound_mode, use_kernel=use_kernel,
                   gather_kind=gather_kind)
    b = q_terms.shape[0]
    carries = _broadcast_carry(k, n_shards, b)
    no_floor = jnp.full((b,), -jnp.inf, jnp.float32)

    if traversal == "chunked":
        planner = partial(_plan_shard_chunked, tiles_per_shard=tiles_per_shard,
                          chunk_tiles=chunk_tiles)
        plans, sched = jax.vmap(
            lambda mb, ml, nr: planner(mb, ml, sigma_b, sigma_l,
                                       q_terms, qw_b, qw_l, alpha, nr)
        )(tm_b, tm_l, n_real)
        # [n_shards, B, R, per, C] -> rounds-first [R, n_shards, B, per, C]
        chunks, chunk_ub = _fold_chunk_rounds(
            sched.chunks, sched.chunk_ub, tiles_per_shard,
            exchange_every, chunk_tiles)
        chunks = jnp.moveaxis(chunks, 2, 0)
        chunk_ub = jnp.moveaxis(chunk_ub, 2, 0)
        disp = jnp.zeros((n_shards, b), jnp.float32)
        round_fn = partial(_chunk_round, statics=statics)

        def run_round(carries, disp, chunks_round, ub_round, floor):
            return jax.vmap(round_fn, in_axes=(0, 0, 0, 0, 0, 0, 0, None,
                                               None, None, None, None))(
                (gather, tm_b, tm_l),
                n_real, plans, chunks_round, ub_round, carries, disp,
                floor, alpha, beta, gamma, factor)

        carries, disp = run_round(carries, disp, chunks[0], chunk_ub[0],
                                  no_floor)
        if chunks.shape[0] > 1:
            def round_step(state, xs):
                carries, disp = state
                floor = _global_theta(carries[0], k)
                return run_round(carries, disp, xs[0], xs[1], floor), None
            (carries, disp), _ = jax.lax.scan(
                round_step, (carries, disp), (chunks[1:], chunk_ub[1:]))
    else:
        disp = None
        planner = partial(_plan_shard, tiles_per_shard=tiles_per_shard,
                          schedule=schedule)
        plans, tiles = jax.vmap(
            lambda mb, ml: planner(mb, ml, sigma_b, sigma_l,
                                   q_terms, qw_b, qw_l, alpha))(tm_b, tm_l)
        scan = partial(_scan_chunk, statics=statics)

        def run_round(carries, tiles_round, floor):
            return jax.vmap(scan, in_axes=(0, 0, 0, 0, 0, None,
                                           None, None, None, None))(
                (gather, tm_b, tm_l),
                n_real, plans, tiles_round, carries, floor,
                alpha, beta, gamma, factor)

        # [n_shards, B, C, E] -> rounds-first [C, n_shards, B, E]
        rounds = jnp.moveaxis(
            _fold_schedule(tiles, tiles_per_shard, exchange_every), 2, 0)
        # round 0 has no exchanged floor; every later round derives the
        # exact global theta from the carries at round *start* — the
        # between-rounds exchange of the old unrolled loop, now inside one
        # lax.scan (two compiled segments total, independent of the round
        # count)
        carries = run_round(carries, rounds[0], no_floor)
        if rounds.shape[0] > 1:
            def round_step(carries, tiles_round):
                floor = _global_theta(carries[0], k)
                return run_round(carries, tiles_round, floor), None
            carries, _ = jax.lax.scan(round_step, carries, rounds[1:])
    gv, gi, lv, li, rv, ri, st = carries
    gi, li, ri = (jax.vmap(_rebase)(i, doc_base) for i in (gi, li, ri))
    gv, gi = _merge_stacked(gv, gi, k)
    lv, li = _merge_stacked(lv, li, k)
    rv, ri = _merge_stacked(rv, ri, k)
    return gv, gi, lv, li, rv, ri, st, disp


@partial(jax.jit, static_argnames=(
    "k", "kq", "pad_len", "tile_size", "bound_mode", "use_kernel",
    "gather_kind", "schedule", "tiles_per_shard", "n_shards",
    "exchange_every", "mesh", "axis_name", "traversal", "chunk_tiles"))
def _sharded_impl_mesh(gather, tm_b, tm_l, doc_base,
                       n_real, sigma_b, sigma_l, q_terms, qw_b, qw_l,
                       alpha, beta, gamma, factor,
                       *, k, kq, pad_len, tile_size, bound_mode, use_kernel,
                       gather_kind, schedule, tiles_per_shard, n_shards,
                       exchange_every, mesh, axis_name, traversal="full",
                       chunk_tiles=8):
    statics = dict(k=k, kq=kq, pad_len=pad_len, tile_size=tile_size,
                   bound_mode=bound_mode, use_kernel=use_kernel,
                   gather_kind=gather_kind)
    scan = partial(_scan_chunk, statics=statics)
    chunked = traversal == "chunked"

    def local_fn(gather, tm_b, tm_l, doc_base, n_real,
                 sigma_b, sigma_l, q_terms, qw_b, qw_l,
                 alpha, beta, gamma, factor):
        # sharded operands arrive with a local leading dim of 1
        idx_arrays = (tuple(a[0] for a in gather), tm_b[0], tm_l[0])
        b = q_terms.shape[0]
        carries = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (b,) + x.shape), _init_carry(k))
        no_floor = jnp.full((b,), -jnp.inf, jnp.float32)
        if chunked:
            plans, sched = _plan_shard_chunked(
                tm_b[0], tm_l[0], sigma_b, sigma_l, q_terms, qw_b, qw_l,
                alpha, n_real[0], tiles_per_shard=tiles_per_shard,
                chunk_tiles=chunk_tiles)
            # [B, R, per, C] -> rounds-first [R, B, per, C]; round 0 runs
            # floor-less, later rounds all-gather the exact global theta
            # at round start and early-exit within the round's chunk loop
            chunks, chunk_ub = _fold_chunk_rounds(
                sched.chunks, sched.chunk_ub, tiles_per_shard,
                exchange_every, chunk_tiles)
            chunks = jnp.moveaxis(chunks, 1, 0)
            chunk_ub = jnp.moveaxis(chunk_ub, 1, 0)
            disp = jnp.zeros((b,), jnp.float32)
            round_fn = partial(_chunk_round, statics=statics)
            carries, disp = round_fn(idx_arrays, n_real[0], plans,
                                     chunks[0], chunk_ub[0], carries, disp,
                                     no_floor, alpha, beta, gamma, factor)
            if chunks.shape[0] > 1:
                def round_step(state, xs):
                    carries, disp = state
                    gv_all = ring_gather_stack(carries[0], axis_name,
                                               n_shards)
                    floor = _global_theta(gv_all, k)
                    carries, disp = round_fn(
                        idx_arrays, n_real[0], plans, xs[0], xs[1],
                        carries, disp, floor, alpha, beta, gamma, factor)
                    return (carries, disp), None
                (carries, disp), _ = jax.lax.scan(
                    round_step, (carries, disp), (chunks[1:], chunk_ub[1:]))
            disp_out = disp[None]
        else:
            plans, tiles = _plan_shard(tm_b[0], tm_l[0], sigma_b, sigma_l,
                                       q_terms, qw_b, qw_l, alpha,
                                       tiles_per_shard=tiles_per_shard,
                                       schedule=schedule)
            # [B, C, E] -> rounds-first [C, B, E]; round 0 runs floor-less,
            # later rounds all-gather the exact global theta at round start
            # (same collective count as the old unrolled between-rounds
            # loop)
            rounds = jnp.moveaxis(
                _fold_schedule(tiles, tiles_per_shard, exchange_every), 1, 0)
            carries = scan(idx_arrays, n_real[0], plans, rounds[0],
                           carries, no_floor, alpha, beta, gamma, factor)
            if rounds.shape[0] > 1:
                def round_step(carries, tiles_round):
                    gv_all = ring_gather_stack(carries[0], axis_name,
                                               n_shards)
                    floor = _global_theta(gv_all, k)
                    carries = scan(idx_arrays, n_real[0], plans, tiles_round,
                                   carries, floor, alpha, beta, gamma,
                                   factor)
                    return carries, None
                carries, _ = jax.lax.scan(round_step, carries, rounds[1:])
            disp_out = jnp.zeros((1, b), jnp.float32)
        gv, gi, lv, li, rv, ri, st = carries
        gi, li, ri = (_rebase(i, doc_base[0]) for i in (gi, li, ri))
        merged = []
        for vals, ids in ((gv, gi), (lv, li), (rv, ri)):
            av = ring_gather_stack(vals, axis_name, n_shards)
            ai = ring_gather_stack(ids, axis_name, n_shards)
            merged.append(_merge_stacked(av, ai, k))
        (gv, gi), (lv, li), (rv, ri) = merged
        return gv, gi, lv, li, rv, ri, st[None], disp_out

    sh = P(axis_name)
    sh3 = P(axis_name, None, None)
    rep1, rep2 = P(None), P(None, None)
    scal = P()
    # per-leaf shard specs: every gather leaf is stacked on the shard axis
    gspec = tuple(P(axis_name, *([None] * (a.ndim - 1))) for a in gather)
    f = shard_map(
        local_fn, mesh=mesh,
        in_specs=(gspec, sh3, sh3, sh, sh,
                  rep1, rep1, rep2, rep2, rep2,
                  scal, scal, scal, scal),
        out_specs=(rep2, rep2, rep2, rep2, rep2, rep2, sh3, P(axis_name, None)),
        check_rep=False)
    out = f(gather, tm_b, tm_l, doc_base, n_real,
            sigma_b, sigma_l, q_terms, qw_b, qw_l,
            alpha, beta, gamma, factor)
    gv, gi, lv, li, rv, ri, st, disp = out
    return gv, gi, lv, li, rv, ri, st, (disp if chunked else None)


def shard_retrieve_batched(sharded: ShardedImpactIndex, q_terms, qw_b, qw_l,
                           params: TwoLevelParams, mesh=None,
                           axis_name: str = "shard",
                           use_kernel: bool = False,
                           exchange_every: int = 0,
                           k: int | None = None,
                           traversal: str = "full",
                           chunk_tiles: int | None = None
                           ) -> RetrievalResult:
    """Sharded batched retrieval over a stacked shard index.

    ``mesh=None`` runs the vmap emulation path (any shard count on one
    device, bit-identical to the mesh path); a one-axis mesh whose
    ``axis_name`` size equals ``sharded.n_shards`` runs the collective
    ``shard_map`` path. ``exchange_every=E`` all-gathers the exact global
    theta_Gl every E tiles so shards skip against the global queue; the
    round loop is one ``lax.scan`` over sentinel-padded rounds, so fine
    periods compile at production tile counts. ``k`` is the per-call
    retrieval depth (legacy ``params.k`` fallback).

    ``traversal="chunked"``: each shard scans its tiles in descending
    local-bound chunks of ``chunk_tiles`` (default ``params.chunk_tiles``)
    under a ``lax.while_loop`` that stops at the first bound-failing chunk
    — bit-identical to the ``impact``-schedule full scan per shard
    (shape-padding tiles sort last with -inf bounds and never keep the
    loop alive). With ``exchange_every=E`` the exchange period is rounded
    up to whole chunks and the early exit applies within each round.
    Stats gain ``chunks_dispatched`` / ``n_chunks`` (summed over shards).
    """
    if mesh is not None and mesh.shape[axis_name] != sharded.n_shards:
        raise ValueError(
            f"mesh axis {axis_name!r} has size {mesh.shape[axis_name]} but "
            f"the index has {sharded.n_shards} shards")
    if traversal not in ("full", "chunked"):
        raise ValueError(f"sharded traversal must be 'full' or 'chunked', "
                         f"got {traversal!r}")
    q_terms = jnp.asarray(q_terms, dtype=jnp.int32)
    qw_b = jnp.asarray(qw_b, dtype=jnp.float32)
    qw_l = jnp.asarray(qw_l, dtype=jnp.float32)
    k = resolve_k(params, k)
    kq = min(k, sharded.tile_size)
    ct = int(chunk_tiles if chunk_tiles is not None else params.chunk_tiles)
    kw = dict(k=k, kq=kq, pad_len=sharded.pad_len,
              tile_size=sharded.tile_size, bound_mode=params.bound_mode,
              use_kernel=use_kernel, gather_kind=sharded.gather_kind,
              schedule=params.schedule,
              tiles_per_shard=sharded.tiles_per_shard,
              n_shards=sharded.n_shards, exchange_every=exchange_every,
              traversal=traversal, chunk_tiles=ct)
    args = (sharded.gather,
            sharded.tile_max_b, sharded.tile_max_l, sharded.doc_base,
            sharded.n_real_tiles,
            sharded.sigma_b, sharded.sigma_l, q_terms, qw_b, qw_l,
            jnp.float32(params.alpha), jnp.float32(params.beta),
            jnp.float32(params.gamma), jnp.float32(params.threshold_factor))
    if mesh is None:
        out = _sharded_impl_emulated(*args, **kw)
    else:
        out = _sharded_impl_mesh(*args, **kw, mesh=mesh, axis_name=axis_name)
    gv, gi, lv, li, rv, ri, st, disp = jax.tree_util.tree_map(np.asarray, out)
    agg = st.sum(0)                                    # [B, 5]
    stats = dict(zip(STAT_KEYS, agg.T))
    b = q_terms.shape[0]
    # padding tiles are force-skipped, so the real tile count is the
    # denominator — skip rates stay comparable with retrieve_batched
    stats["n_tiles"] = np.full(b, sharded.n_tiles, np.float32)
    stats["shard_tiles_visited"] = st[:, :, 4].T       # [B, n_shards]
    if disp is not None:
        stats["chunks_dispatched"] = disp.sum(0)       # [B]
        n_chunks = -(-sharded.tiles_per_shard // ct) * sharded.n_shards
        stats["n_chunks"] = np.full(b, n_chunks, np.float32)
        stats["shard_chunks_dispatched"] = disp.T      # [B, n_shards]
    return RetrievalResult(ids=sharded.to_orig(ri), scores=rv,
                           global_ids=sharded.to_orig(gi),
                           local_ids=sharded.to_orig(li), stats=stats)


class ShardedRetrievalServer(RetrievalServer):
    """Deprecated (with :class:`RetrievalServer`): the same shim over
    ``AsyncRetrievalScheduler``, pinned to the mesh-sharded engine. New
    code opens a scheduler with a routing policy whose routes use
    ``engine="sharded"`` (``route(..., engine="sharded", n_shards=N)``).

    Accepts the same queue/batching config; the index is partitioned once
    at construction (inside the ``"sharded"`` registry engine).
    ``mesh=None`` serves through the emulation path."""

    def __init__(self, index, params: TwoLevelParams,
                 cfg: ServerConfig | None = None, *,
                 n_shards: int | None = None, mesh=None,
                 axis_name: str = "shard", use_kernel: bool = False,
                 exchange_every: int = 0, k: int | None = None,
                 traversal: str = "full", chunk_tiles: int | None = None):
        super().__init__(index, params, cfg, engine="sharded", k=k,
                         n_shards=n_shards, mesh=mesh, axis_name=axis_name,
                         use_kernel=use_kernel,
                         exchange_every=exchange_every,
                         traversal=traversal, chunk_tiles=chunk_tiles)
        self.sharded = self.retriever.engine.sharded
        self.mesh = mesh
