"""Deterministic fault injection for the serving stack.

None of the fault-tolerance machinery (deadlines, retries, breakers,
hot swap) is testable without *controllable* failures, so the scheduler
and executor pool thread every batch through a declarative
:class:`FaultPlan` hook — a no-op by default (``faults=None``), a
scripted failure schedule under test:

    plan = FaultPlan([
        fail_batch(0, executor=0),            # executor 0's first batch
        delay_route("long", 40.0, times=2),   # +40 ms on two long batches
        poison_generation(2),                 # every gen-2 batch fails
        kill_executor(1),                     # thread death, not a batch
    ])
    sched = AsyncRetrievalScheduler(index, params, cfg, faults=plan)

Two hook points:

  - ``on_batch(...)`` — called by the scheduler right before a batch
    attempt runs ``Retriever.search``. ``fail``/``poison`` faults raise
    :class:`InjectedFault` (the retry policy sees ``retryable``);
    ``delay`` faults return a *virtual* delay in ms — added to the
    latency the health monitor records — and only actually sleep when
    the plan was built with ``wall=True`` (benchmarks want real
    slowdown; tests never sleep).
  - ``on_pick(executor_id)`` — called by a pool worker at the top of
    its loop, *outside* the batch-execution protection. ``die`` faults
    raise :class:`InjectedDeath` there, unwinding the worker thread —
    the scheduler must survive and report it.

Matching is positional and deterministic: ``batch=N`` matches the Nth
batch *attempt* (0-based) — per-executor when ``executor`` is set,
global otherwise — so a retry of a failed batch is a *different*
ordinal and a ``times=1`` fault lets it through. ``plan.fired`` records
every injection for test assertions.
"""
from __future__ import annotations

import dataclasses
import threading
import time


class InjectedFault(RuntimeError):
    """A scripted batch-execution failure. ``retryable`` is what
    :meth:`~repro.serve.health.RetryPolicy.retryable` reads."""

    def __init__(self, msg: str, retryable: bool = True):
        super().__init__(msg)
        self.retryable = retryable


class InjectedDeath(RuntimeError):
    """A scripted executor-thread death (raised outside batch
    execution, so no handle catches it — the pool must)."""


_KINDS = ("fail", "delay", "die", "poison")


@dataclasses.dataclass(frozen=True)
class Fault:
    """One declarative fault. ``None`` filters match anything; ``times``
    bounds how often it fires (``None`` = unlimited)."""
    kind: str
    executor: int | None = None    # pool slot filter
    route: str | None = None       # executed route-name filter
    batch: int | None = None       # Nth attempt (per-executor if executor
    #                                is set, else global), 0-based
    generation: int | None = None  # index-generation filter
    times: int | None = 1
    delay_ms: float = 0.0          # for kind="delay"
    retryable: bool = True         # for kind="fail"

    def __post_init__(self):
        if self.kind not in _KINDS:
            raise ValueError(f"fault kind must be one of {_KINDS}, "
                             f"got {self.kind!r}")


def fail_batch(batch: int | None = None, *, executor: int | None = None,
               route: str | None = None, retryable: bool = True,
               times: int | None = 1) -> Fault:
    """Fail batch attempt N (on executor E / route R) with an
    :class:`InjectedFault`."""
    return Fault("fail", executor=executor, route=route, batch=batch,
                 retryable=retryable, times=times)


def delay_route(route: str | None, delay_ms: float, *,
                executor: int | None = None,
                times: int | None = None) -> Fault:
    """Slow batches of ``route`` down by ``delay_ms`` (virtual unless
    the plan has ``wall=True``)."""
    return Fault("delay", executor=executor, route=route,
                 delay_ms=delay_ms, times=times)


def poison_generation(generation: int, *,
                      times: int | None = None) -> Fault:
    """Every batch served by index generation G fails, non-retryably —
    the 'bad rebuild' scenario the hot-swap gate must survive."""
    return Fault("poison", generation=generation, retryable=False,
                 times=times)


def kill_executor(executor: int, *, times: int | None = 1) -> Fault:
    """Unwind executor E's worker thread at its next pick."""
    return Fault("die", executor=executor, times=times)


class FaultPlan:
    """A seeded, declarative failure schedule (see module docstring).

    ``wall=True`` makes ``delay`` faults actually sleep (benchmarks);
    the default returns virtual delays only, so fault tests never touch
    the wall clock. ``fired`` is the injection log:
    ``(kind, executor_id, batch_index, route, generation)`` tuples in
    injection order — a pure function of the batch schedule, pinned by
    the determinism test.
    """

    def __init__(self, faults=(), *, seed: int = 0, wall: bool = False):
        self.faults = tuple(faults)
        self.seed = seed
        self.wall = wall
        self.fired: list[tuple] = []
        self._remaining = [f.times for f in self.faults]
        self._lock = threading.Lock()

    @staticmethod
    def _matches(f: Fault, *, executor_id, batch_index, global_index,
                 route, generation) -> bool:
        if f.executor is not None and f.executor != executor_id:
            return False
        if f.route is not None and f.route != route:
            return False
        if f.generation is not None and f.generation != generation:
            return False
        if f.batch is not None:
            ordinal = batch_index if f.executor is not None else global_index
            if f.batch != ordinal:
                return False
        return True

    def _take(self, i: int) -> bool:
        """Consume one firing of fault ``i`` (False when exhausted)."""
        left = self._remaining[i]
        if left is None:
            return True
        if left <= 0:
            return False
        self._remaining[i] = left - 1
        return True

    def on_batch(self, *, executor_id, batch_index, global_index,
                 route, generation) -> float:
        """The batch-attempt hook: may raise ``InjectedFault``; returns
        the (virtual) extra delay in ms."""
        delay = 0.0
        raise_fault = None
        with self._lock:
            for i, f in enumerate(self.faults):
                if f.kind == "die":
                    continue
                if not self._matches(f, executor_id=executor_id,
                                     batch_index=batch_index,
                                     global_index=global_index,
                                     route=route, generation=generation):
                    continue
                if not self._take(i):
                    continue
                self.fired.append((f.kind, executor_id, batch_index,
                                   route, generation))
                if f.kind == "delay":
                    delay += f.delay_ms
                elif f.kind == "fail":
                    raise_fault = InjectedFault(
                        f"injected failure (executor {executor_id}, "
                        f"batch {batch_index}, route {route!r})",
                        retryable=f.retryable)
                    break
                elif f.kind == "poison":
                    raise_fault = InjectedFault(
                        f"injected poison (index generation {generation})",
                        retryable=f.retryable)
                    break
        if self.wall and delay > 0:
            time.sleep(delay / 1e3)
        if raise_fault is not None:
            raise raise_fault
        return delay

    def on_pick(self, *, executor_id) -> None:
        """The worker-loop hook: ``die`` faults raise InjectedDeath."""
        with self._lock:
            for i, f in enumerate(self.faults):
                if f.kind != "die" or f.executor != executor_id:
                    continue
                if not self._take(i):
                    continue
                self.fired.append(("die", executor_id, None, None, None))
                raise InjectedDeath(
                    f"injected death of executor {executor_id}")
