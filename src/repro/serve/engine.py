"""DEPRECATED synchronous serving facade over the v2 scheduler.

``RetrievalServer`` (and ``ShardedRetrievalServer`` in ``serve.sharded``)
predate :class:`repro.serve.scheduler.AsyncRetrievalScheduler`; they are
kept as thin shims so existing call sites keep returning the exact same
ids/scores, but new code should submit ``SearchRequest`` objects to the
scheduler directly (futures, mixed-k micro-batching, query-length
routing, response cache). The shim pins the legacy behavior: one engine
for every request, no routing, no cache, and the historical
``Request``/``run_workload`` latency accounting.
"""
from __future__ import annotations

import dataclasses
import math
import time
import warnings

import numpy as np

from ..core.index import BlockedImpactIndex
from ..core.twolevel import TwoLevelParams, resolve_k
from ..retrieval import SearchRequest
from .router import single_route
from .scheduler import (AsyncRetrievalScheduler, SchedulerConfig,
                        aggregate_latencies, truncate_terms)


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 32
    max_wait_ms: float = 2.0
    pad_terms: int = 16


@dataclasses.dataclass
class Request:
    terms: np.ndarray
    qw_b: np.ndarray
    qw_l: np.ndarray
    t_enqueue: float = 0.0
    t_done: float = 0.0
    ids: np.ndarray | None = None
    scores: np.ndarray | None = None

    @property
    def latency_ms(self) -> float:
        """Enqueue -> results in ms; NaN while the request is in flight
        (``t_done`` unset) instead of a garbage negative number."""
        if not self.t_done:
            return math.nan
        return (self.t_done - self.t_enqueue) * 1e3


class RetrievalServer:
    """Deprecated: a synchronous queue over one engine. Use
    ``AsyncRetrievalScheduler`` (see the module docstring)."""

    def __init__(self, index: BlockedImpactIndex, params: TwoLevelParams,
                 cfg: ServerConfig | None = None, *,
                 engine: str = "batched", k: int | None = None,
                 **engine_opts):
        warnings.warn(
            "RetrievalServer is deprecated: use repro.serve."
            "AsyncRetrievalScheduler (submit(SearchRequest) -> "
            "SearchHandle) for mixed-k micro-batching, query-length "
            "routing and response caching.",
            DeprecationWarning, stacklevel=2)
        self.index = index
        self.params = params
        # None -> fresh per-instance config (a shared default instance would
        # leak max_batch/pad_terms mutations across servers)
        self.cfg = cfg if cfg is not None else ServerConfig()
        self.scheduler = AsyncRetrievalScheduler(
            index, params, self._sched_cfg(),
            routing=single_route(engine, **engine_opts))
        # legacy attribute: the one retriever every batch goes through
        self.retriever = self.scheduler._retriever("all")
        self.k = resolve_k(params, k)
        self.pending: list[Request] = []
        self.completed: list[Request] = []

    def _sched_cfg(self) -> SchedulerConfig:
        """Scheduler view of the (mutable) legacy config. The pinned
        behaviors: no cache, and no batch padding — the shim serves the
        exact row count the old server did."""
        return SchedulerConfig(max_batch=self.cfg.max_batch,
                               max_wait_ms=self.cfg.max_wait_ms,
                               pad_terms=self.cfg.pad_terms,
                               pad_batch=False, cache_size=0)

    def submit(self, req: Request, now: float) -> None:
        req.t_enqueue = now
        self.pending.append(req)

    def _truncate(self, r: Request) -> np.ndarray:
        """Indices of the ``pad_terms`` terms to keep (see
        ``scheduler.truncate_terms``)."""
        return truncate_terms(r.terms, r.qw_b, r.qw_l, self.cfg.pad_terms,
                              self.params.gamma)

    def _flush(self) -> None:
        batch, self.pending = (self.pending[:self.cfg.max_batch],
                               self.pending[self.cfg.max_batch:])
        # legacy config objects are mutated in place by callers; re-sync
        self.scheduler.cfg = self._sched_cfg()
        handles = [
            self.scheduler.submit(
                SearchRequest(terms=r.terms, weights_b=r.qw_b,
                              weights_l=r.qw_l, k=self.k),
                now=r.t_enqueue)
            for r in batch]
        self.scheduler.flush()
        for r, h in zip(batch, handles):
            resp = h.result()
            r.ids, r.scores, r.t_done = resp.ids[0], resp.scores[0], h.t_done
        self.completed.extend(batch)

    def run_workload(self, requests: list[Request], qps: float,
                     seed: int = 0) -> dict:
        """Poisson arrivals at ``qps``; synchronous single-host execution."""
        if not requests:  # nothing to serve: no lat array to reduce
            return {"n": 0, "mrt_ms": float("nan"), "p50_ms": float("nan"),
                    "p99_ms": float("nan"), "qps_achieved": 0.0}
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, len(requests)))
        t0 = time.perf_counter()
        i = 0
        while i < len(requests) or self.pending:
            now = time.perf_counter() - t0
            while i < len(requests) and arrivals[i] <= now:
                self.submit(requests[i], t0 + arrivals[i])
                i += 1
            oldest_wait = (time.perf_counter() - self.pending[0].t_enqueue
                           if self.pending else 0.0)
            if (len(self.pending) >= self.cfg.max_batch
                    or (self.pending
                        and oldest_wait * 1e3 >= self.cfg.max_wait_ms)
                    or (i >= len(requests) and self.pending)):
                self._flush()
            elif not self.pending and i < len(requests):
                time.sleep(max(0.0, arrivals[i] - now))
        return aggregate_latencies([r.latency_ms for r in self.completed],
                                   time.perf_counter() - t0)
