"""Batched retrieval serving engine with latency accounting.

Requests accumulate into batches (max size / max wait); each batch goes
through the unified ``repro.retrieval.Retriever`` facade once — the server
is engine-agnostic: ``engine="batched"`` (default), ``"kernel"``, or
``"sharded"`` (see ``ShardedRetrievalServer``) all serve through the same
queue/batch machinery. Per-request latency = enqueue -> results, so the
MRT/P99 numbers include batching delay — the metric regime of the paper's
tables, extended to a served setting. A synchronous simulator
(``run_workload``) drives it with a Poisson arrival process for benchmarks
on this single-core container.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core.index import BlockedImpactIndex
from ..core.twolevel import TwoLevelParams, resolve_k
from ..retrieval import Retriever


@dataclasses.dataclass
class ServerConfig:
    max_batch: int = 32
    max_wait_ms: float = 2.0
    pad_terms: int = 16


@dataclasses.dataclass
class Request:
    terms: np.ndarray
    qw_b: np.ndarray
    qw_l: np.ndarray
    t_enqueue: float = 0.0
    t_done: float = 0.0
    ids: np.ndarray | None = None
    scores: np.ndarray | None = None

    @property
    def latency_ms(self) -> float:
        return (self.t_done - self.t_enqueue) * 1e3


class RetrievalServer:
    def __init__(self, index: BlockedImpactIndex, params: TwoLevelParams,
                 cfg: ServerConfig | None = None, *,
                 engine: str = "batched", k: int | None = None,
                 **engine_opts):
        self.index = index
        self.params = params
        # None -> fresh per-instance config (a shared default instance would
        # leak max_batch/pad_terms mutations across servers)
        self.cfg = cfg if cfg is not None else ServerConfig()
        self.retriever = Retriever.open(index, params, engine=engine,
                                        **engine_opts)
        self.k = resolve_k(params, k)
        self.pending: list[Request] = []
        self.completed: list[Request] = []

    def submit(self, req: Request, now: float) -> None:
        req.t_enqueue = now
        self.pending.append(req)

    def _truncate(self, r: Request) -> np.ndarray:
        """Indices of the ``pad_terms`` terms to keep. Over-long queries
        drop their *lowest-impact* terms — ranked by the gamma-combined
        query weight the engine scores with — not the trailing ones."""
        if len(r.terms) <= self.cfg.pad_terms:
            return np.arange(len(r.terms))
        g = self.params.gamma
        impact = g * np.asarray(r.qw_b) + (1.0 - g) * np.asarray(r.qw_l)
        keep = np.argsort(-impact, kind="stable")[:self.cfg.pad_terms]
        return np.sort(keep)  # preserve original term order

    def _flush(self) -> None:
        batch, self.pending = (self.pending[:self.cfg.max_batch],
                               self.pending[self.cfg.max_batch:])
        n, p = len(batch), self.cfg.pad_terms
        terms = np.zeros((n, p), np.int32)
        qw_b = np.zeros((n, p), np.float32)
        qw_l = np.zeros((n, p), np.float32)
        for i, r in enumerate(batch):
            keep = self._truncate(r)
            k = len(keep)
            terms[i, :k] = np.asarray(r.terms)[keep]
            qw_b[i, :k] = np.asarray(r.qw_b)[keep]
            qw_l[i, :k] = np.asarray(r.qw_l)[keep]
        res = self.retriever.search(terms=terms, weights_b=qw_b,
                                    weights_l=qw_l, k=self.k)
        done = time.perf_counter()
        for i, r in enumerate(batch):
            r.ids, r.scores, r.t_done = res.ids[i], res.scores[i], done
        self.completed.extend(batch)

    def run_workload(self, requests: list[Request], qps: float,
                     seed: int = 0) -> dict:
        """Poisson arrivals at ``qps``; synchronous single-host execution."""
        if not requests:  # nothing to serve: no lat array to reduce
            return {"n": 0, "mrt_ms": float("nan"), "p50_ms": float("nan"),
                    "p99_ms": float("nan"), "qps_achieved": 0.0}
        rng = np.random.default_rng(seed)
        arrivals = np.cumsum(rng.exponential(1.0 / qps, len(requests)))
        t0 = time.perf_counter()
        i = 0
        while i < len(requests) or self.pending:
            now = time.perf_counter() - t0
            while i < len(requests) and arrivals[i] <= now:
                self.submit(requests[i], t0 + arrivals[i])
                i += 1
            oldest_wait = (time.perf_counter() - self.pending[0].t_enqueue
                           if self.pending else 0.0)
            if (len(self.pending) >= self.cfg.max_batch
                    or (self.pending
                        and oldest_wait * 1e3 >= self.cfg.max_wait_ms)
                    or (i >= len(requests) and self.pending)):
                self._flush()
            elif not self.pending and i < len(requests):
                time.sleep(max(0.0, arrivals[i] - now))
        lat = np.array([r.latency_ms for r in self.completed])
        return {"n": len(lat), "mrt_ms": float(lat.mean()),
                "p50_ms": float(np.percentile(lat, 50)),
                "p99_ms": float(np.percentile(lat, 99)),
                "qps_achieved": len(lat) / (time.perf_counter() - t0)}
