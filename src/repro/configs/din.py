"""din [arXiv:1706.06978]: embed_dim=18 seq_len=100 attn_mlp=80-40
mlp=200-80 interaction=target-attn."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import DINConfig


def _full():
    return DINConfig(embed_dim=18, seq_len=100, n_items=1_000_000,
                     attn_mlp=(80, 40), mlp=(200, 80))


def _smoke():
    return DINConfig(embed_dim=8, seq_len=20, n_items=500,
                     attn_mlp=(16, 8), mlp=(16, 8))


ARCH = ArchSpec(arch_id="din", family="recsys", source="arXiv:1706.06978",
                make_config=_full, make_smoke=_smoke, shapes=RECSYS_SHAPES)
