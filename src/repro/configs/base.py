"""ArchSpec: a selectable architecture (--arch <id>) + its shape cells."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                       # "lm" | "gnn" | "recsys"
    source: str                       # public-literature citation
    make_config: Callable[[], Any]    # full published config
    make_smoke: Callable[[], Any]     # reduced same-family config
    shapes: tuple[str, ...]           # assigned shape-cell names
    notes: str = ""

    def config(self) -> Any:
        return self.make_config()

    def smoke(self) -> Any:
        return self.make_smoke()


# Assigned shape-cell names per family (the 40-cell grid).
LM_SHAPES = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
GNN_SHAPES = ("full_graph_sm", "minibatch_lg", "ogb_products", "molecule")
RECSYS_SHAPES = ("train_batch", "serve_p99", "serve_bulk", "retrieval_cand")
