"""two-tower-retrieval [RecSys'19 (YouTube); unverified]: embed_dim=256
tower_mlp=1024-512-256 interaction=dot, sampled-softmax retrieval."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import TwoTowerConfig


def _full():
    return TwoTowerConfig(embed_dim=256, tower_mlp=(1024, 512, 256),
                          n_user_feats=500_000, n_items=2_000_000,
                          user_bag=16, feat_dim=256, n_negatives=1024)


def _smoke():
    return TwoTowerConfig(embed_dim=32, tower_mlp=(64, 32),
                          n_user_feats=1000, n_items=2000, user_bag=8,
                          feat_dim=32, n_negatives=16)


ARCH = ArchSpec(arch_id="two-tower-retrieval", family="recsys",
                source="Yi et al., RecSys'19 (YouTube)",
                make_config=_full, make_smoke=_smoke, shapes=RECSYS_SHAPES,
                notes="retrieval_cand uses core.dense_guided (2GTI transfer)")
