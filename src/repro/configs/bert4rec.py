"""bert4rec [arXiv:1904.06690]: embed_dim=64 n_blocks=2 n_heads=2
seq_len=200 interaction=bidir-seq. Item catalog set to 1M so the
retrieval_cand cell is meaningful."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import Bert4RecConfig


def _full():
    return Bert4RecConfig(n_items=1_000_000, embed_dim=64, n_blocks=2,
                          n_heads=2, seq_len=200,
                          compute_dtype=jnp.bfloat16)


def _smoke():
    return Bert4RecConfig(n_items=300, embed_dim=16, n_blocks=2, n_heads=2,
                          seq_len=20)


ARCH = ArchSpec(arch_id="bert4rec", family="recsys",
                source="arXiv:1904.06690",
                make_config=_full, make_smoke=_smoke, shapes=RECSYS_SHAPES)
