"""phi4-mini-3.8b [arXiv:2412.08905; hf]: 32L d=3072 24H (GQA kv=8)
d_ff=8192 vocab=200064 — RoPE SwiGLU GQA."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def _full():
    return TransformerConfig(
        n_layers=32, d_model=3072, n_heads=24, n_kv_heads=8, d_ff=8192,
        vocab=200064, rope_theta=10000.0, tie_embeddings=True,
        compute_dtype=jnp.bfloat16)


def _smoke():
    return TransformerConfig(
        n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256,
        vocab=512, compute_dtype=jnp.float32, remat=False)


ARCH = ArchSpec(arch_id="phi4-mini-3.8b", family="lm",
                source="arXiv:2412.08905 (hf-verified)",
                make_config=_full, make_smoke=_smoke, shapes=LM_SHAPES)
