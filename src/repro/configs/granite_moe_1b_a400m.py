"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base]: 24L
d=1024 16H (GQA kv=8) vocab=49155, MoE 32 experts top-8, expert d_ff=512."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import MoEConfig, TransformerConfig


def _full():
    return TransformerConfig(
        n_layers=24, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=0,
        vocab=49155, moe=MoEConfig(n_experts=32, top_k=8, d_ff_expert=512),
        tie_embeddings=True, compute_dtype=jnp.bfloat16,
        attn_chunk=1024)


def _smoke():
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=0, vocab=384,
        moe=MoEConfig(n_experts=4, top_k=2, d_ff_expert=32),
        compute_dtype=jnp.float32, remat=False)


ARCH = ArchSpec(arch_id="granite-moe-1b-a400m", family="lm",
                source="hf:ibm-granite/granite-3.0-1b-a400m-base",
                make_config=_full, make_smoke=_smoke, shapes=LM_SHAPES)
