"""schnet [arXiv:1706.08566]: n_interactions=3 d_hidden=64 rbf=300 cutoff=10.

One trunk, two input modes: molecule (atom types + positions) and graph
(linear feature embed; per-shape d_feat/classes applied by the step factory
via dataclasses.replace — full_graph_sm 1433/7, minibatch_lg 602/41,
ogb_products 100/47).
"""
from repro.configs.base import ArchSpec, GNN_SHAPES
from repro.models.schnet import SchNetConfig


def _full():
    return SchNetConfig(n_interactions=3, d_hidden=64, n_rbf=300,
                        cutoff=10.0, n_atom_types=100, n_out=1)


def _smoke():
    return SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=24,
                        cutoff=5.0, n_atom_types=16, n_out=1)


ARCH = ArchSpec(arch_id="schnet", family="gnn", source="arXiv:1706.08566",
                make_config=_full, make_smoke=_smoke, shapes=GNN_SHAPES)
