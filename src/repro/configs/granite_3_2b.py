"""granite-3-2b [hf:ibm-granite/granite-3.0-2b-base]: 40L d=2048 32H
(GQA kv=8) d_ff=8192 vocab=49155."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def _full():
    return TransformerConfig(
        n_layers=40, d_model=2048, n_heads=32, n_kv_heads=8, d_ff=8192,
        vocab=49155, tie_embeddings=True, compute_dtype=jnp.bfloat16,
        attn_chunk=1024)


def _smoke():
    return TransformerConfig(
        n_layers=3, d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab=384, compute_dtype=jnp.float32, remat=False)


ARCH = ArchSpec(arch_id="granite-3-2b", family="lm",
                source="hf:ibm-granite/granite-3.0-2b-base",
                make_config=_full, make_smoke=_smoke, shapes=LM_SHAPES)
