"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B]: 48L d=2048 32H (GQA kv=4)
vocab=151936, MoE 128 experts top-8, expert d_ff=768."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import MoEConfig, TransformerConfig


def _full():
    return TransformerConfig(
        n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_ff=0,
        vocab=151936, moe=MoEConfig(n_experts=128, top_k=8, d_ff_expert=768),
        tie_embeddings=True, compute_dtype=jnp.bfloat16,
        attn_chunk=1024)


def _smoke():
    return TransformerConfig(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=0, vocab=384,
        moe=MoEConfig(n_experts=8, top_k=2, d_ff_expert=32),
        compute_dtype=jnp.float32, remat=False)


ARCH = ArchSpec(arch_id="qwen3-moe-30b-a3b", family="lm",
                source="hf:Qwen/Qwen3-30B-A3B",
                make_config=_full, make_smoke=_smoke, shapes=LM_SHAPES)
