"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

Weak-type-correct, shardable, no device allocation — consumed by
``launch/dryrun.py`` (AOT lower+compile) and by the roofline bench.
Each spec dict carries: kind ("train"/"prefill"/"decode"/"serve"/"retrieval"),
inputs (pytree of ShapeDtypeStruct), and static metadata for the step
factory. ``[audio]/[vlm]``-style frontends do not occur in this assignment;
GNN large-graph cells take precomputed sampled-subgraph arrays from the
neighbor sampler.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

SDS = jax.ShapeDtypeStruct
I32 = jnp.int32
F32 = jnp.float32


def _pad_to(x: int, m: int) -> int:
    return -(-x // m) * m


# --------------------------------------------------------------------------
# LM family
# --------------------------------------------------------------------------

LM_SHAPE_DEFS = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def lm_input_specs(cfg, shape: str) -> dict:
    d = LM_SHAPE_DEFS[shape]
    b, s = d["batch"], d["seq"]
    kind = d["kind"]
    if kind == "train":
        return {"kind": kind,
                "inputs": {"batch": {
                    "tokens": SDS((b, s), I32),
                    "targets": SDS((b, s), I32)}}}
    if kind == "prefill":
        return {"kind": kind, "max_len": s,
                "inputs": {"tokens": SDS((b, s), I32)}}
    # decode: one new token against a seq-length KV cache
    hkv, dh, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    kv_dtype = jnp.int8 if getattr(cfg, "kv_quant", False) \
        else cfg.compute_dtype
    cache = {"k": SDS((L, b, s, hkv, dh), kv_dtype),
             "v": SDS((L, b, s, hkv, dh), kv_dtype)}
    if getattr(cfg, "kv_quant", False):
        cache["k_scale"] = SDS((L, b, s, hkv), F32)
        cache["v_scale"] = SDS((L, b, s, hkv), F32)
    return {"kind": "decode",
            "inputs": {"token": SDS((b, 1), I32), "cache": cache,
                       "cache_len": SDS((), I32)}}


# --------------------------------------------------------------------------
# GNN family (SchNet)
# --------------------------------------------------------------------------

GNN_SHAPE_DEFS = {
    # (nodes, edges, d_feat, n_classes, replicate)
    "full_graph_sm": dict(kind="gnn_full", nodes=2708, edges=10556,
                          d_feat=1433, classes=7, pad=1),  # replicated
    # Reddit-scale sampled training: 1024 seeds x fanout 15 -> x10
    "minibatch_lg": dict(kind="gnn_sampled", nodes=169984, edges=168960,
                         d_feat=602, classes=41, pad=512),
    "ogb_products": dict(kind="gnn_full", nodes=2449029, edges=61859140,
                         d_feat=100, classes=47, pad=512),
    "molecule": dict(kind="gnn_mol", batch=128, atoms=30, edges=64),
}


def gnn_input_specs(cfg, shape: str) -> dict:
    d = GNN_SHAPE_DEFS[shape]
    if d["kind"] == "gnn_mol":
        b, n, e = d["batch"], d["atoms"], d["edges"]
        return {"kind": "gnn_mol",
                "inputs": {"batch": {
                    "z": SDS((b, n), I32), "pos": SDS((b, n, 3), F32),
                    "edge_src": SDS((b, e), I32),
                    "edge_dst": SDS((b, e), I32),
                    "energy": SDS((b,), F32)}}}
    nn, ee = _pad_to(d["nodes"], d["pad"]), _pad_to(d["edges"], d["pad"])
    return {"kind": d["kind"], "classes": d["classes"], "d_feat": d["d_feat"],
            "inputs": {"batch": {
                "x": SDS((nn, d["d_feat"]), F32),
                "edge_src": SDS((ee,), I32), "edge_dst": SDS((ee,), I32),
                "edge_dist": SDS((ee,), F32),
                "labels": SDS((nn,), I32),
                "train_mask": SDS((nn,), F32)}}}


# --------------------------------------------------------------------------
# RecSys family
# --------------------------------------------------------------------------

RECSYS_SHAPE_DEFS = {
    "train_batch": dict(kind="train", batch=65536),
    "serve_p99": dict(kind="serve", batch=512, shortlist=8192),
    "serve_bulk": dict(kind="serve", batch=262144, shortlist=8192),
    # 1M candidates padded to a 512 multiple so the candidate axis
    # shards evenly over 256/512 devices (pad scores are masked).
    "retrieval_cand": dict(kind="retrieval", batch=1, n_cand=1_000_448),
}


def recsys_input_specs(cfg, shape: str) -> dict:
    from repro.models.recsys import (Bert4RecConfig, DINConfig, DLRMConfig,
                                     TwoTowerConfig)
    d = RECSYS_SHAPE_DEFS[shape]
    b = d["batch"]
    if isinstance(cfg, DLRMConfig):
        feats = {"dense": SDS((b, cfg.n_dense), F32),
                 "sparse": SDS((b, cfg.n_sparse, cfg.multi_hot), I32)}
        if d["kind"] == "train":
            return {"kind": "train",
                    "inputs": {"batch": {**feats, "label": SDS((b,), I32)}}}
        if d["kind"] == "serve":
            return {"kind": "serve", "inputs": {"batch": feats}}
        # retrieval: user context + 1M candidate ids for the varying field
        user = {"dense": SDS((1, cfg.n_dense), F32),
                "sparse": SDS((1, cfg.n_sparse - 1, cfg.multi_hot), I32)}
        return {"kind": "retrieval",
                "inputs": {"user": user,
                           "cand_ids": SDS((d["n_cand"],), I32)}}
    if isinstance(cfg, DINConfig):
        if d["kind"] == "train":
            return {"kind": "train", "inputs": {"batch": {
                "hist": SDS((b, cfg.seq_len), I32),
                "target": SDS((b,), I32), "label": SDS((b,), I32)}}}
        if d["kind"] == "serve":
            return {"kind": "serve", "inputs": {"batch": {
                "hist": SDS((b, cfg.seq_len), I32),
                "target": SDS((b,), I32)}}}
        return {"kind": "retrieval",
                "inputs": {"hist": SDS((1, cfg.seq_len), I32),
                           "cand_ids": SDS((d["n_cand"],), I32)}}
    if isinstance(cfg, TwoTowerConfig):
        if d["kind"] == "train":
            return {"kind": "train", "inputs": {"batch": {
                "user_feats": SDS((b, cfg.user_bag), I32),
                "pos_item": SDS((b,), I32),
                "neg_items": SDS((cfg.n_negatives,), I32),
                "neg_logq": SDS((cfg.n_negatives,), F32)}}}
        if d["kind"] == "serve":
            return {"kind": "serve", "inputs": {
                "user_feats": SDS((b, cfg.user_bag), I32),
                "shortlist": SDS((d["shortlist"],), I32)}}
        # retrieval: 1 user vs 1M precomputed candidate tower outputs
        return {"kind": "retrieval",
                "inputs": {"user_feats": SDS((1, cfg.user_bag), I32),
                           "cand_emb": SDS((d["n_cand"],
                                            cfg.tower_mlp[-1]), F32)}}
    if isinstance(cfg, Bert4RecConfig):
        if d["kind"] == "train":
            return {"kind": "train", "inputs": {"batch": {
                "items": SDS((b, cfg.seq_len), I32),
                "targets": SDS((b, cfg.seq_len), I32),
                "mask": SDS((b, cfg.seq_len), I32),
                "neg_items": SDS((512,), I32)}}}
        if d["kind"] == "serve":
            return {"kind": "serve", "inputs": {
                "items": SDS((b, cfg.seq_len), I32),
                "cand_ids": SDS((d["shortlist"],), I32)}}
        return {"kind": "retrieval",
                "inputs": {"items": SDS((1, cfg.seq_len), I32),
                           "cand_ids": SDS((d["n_cand"],), I32)}}
    raise TypeError(f"unknown recsys config {type(cfg)}")


def input_specs(arch, shape: str, cfg=None) -> dict:
    """Dispatch by family. ``arch``: ArchSpec; returns spec dict."""
    cfg = cfg if cfg is not None else arch.config()
    if arch.family == "lm":
        return lm_input_specs(cfg, shape)
    if arch.family == "gnn":
        return gnn_input_specs(cfg, shape)
    if arch.family == "recsys":
        return recsys_input_specs(cfg, shape)
    raise ValueError(arch.family)
