"""internlm2-1.8b [arXiv:2403.17297; hf]: 24L d=2048 16H (GQA kv=8)
d_ff=8192 vocab=92544."""
import jax.numpy as jnp

from repro.configs.base import ArchSpec, LM_SHAPES
from repro.models.transformer import TransformerConfig


def _full():
    return TransformerConfig(
        n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_ff=8192,
        vocab=92544, tie_embeddings=True, compute_dtype=jnp.bfloat16,
        attn_chunk=1024)


def _smoke():
    return TransformerConfig(
        n_layers=2, d_model=96, n_heads=4, n_kv_heads=2, d_ff=192,
        vocab=384, compute_dtype=jnp.float32, remat=False)


ARCH = ArchSpec(arch_id="internlm2-1.8b", family="lm",
                source="arXiv:2403.17297",
                make_config=_full, make_smoke=_smoke, shapes=LM_SHAPES)
