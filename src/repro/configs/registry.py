"""--arch <id> registry for all 10 assigned architectures."""
from __future__ import annotations

import importlib

_MODULES = {
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "granite-3-2b": "granite_3_2b",
    "internlm2-1.8b": "internlm2_1_8b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "schnet": "schnet",
    "dlrm-rm2": "dlrm_rm2",
    "din": "din",
    "two-tower-retrieval": "two_tower_retrieval",
    "bert4rec": "bert4rec",
}

ARCH_IDS = tuple(_MODULES)


def get_arch(arch_id: str):
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; options: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch_id]}")
    return mod.ARCH


def all_cells():
    """Every (arch_id, shape) pair — the 40-cell dry-run grid."""
    for aid in ARCH_IDS:
        arch = get_arch(aid)
        for shape in arch.shapes:
            yield aid, shape
