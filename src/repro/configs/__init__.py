from .base import ArchSpec  # noqa: F401
from .registry import ARCH_IDS, all_cells, get_arch  # noqa: F401
from .shapes import input_specs  # noqa: F401
