"""dlrm-rm2 [arXiv:1906.00091]: n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1 interaction=dot."""
from repro.configs.base import ArchSpec, RECSYS_SHAPES
from repro.models.recsys import DLRMConfig


def _full():
    return DLRMConfig(n_dense=13, n_sparse=26, embed_dim=64,
                      vocab_per_field=1_000_000,
                      bot_mlp=(13, 512, 256, 64),
                      top_mlp_hidden=(512, 512, 256, 1), multi_hot=1)


def _smoke():
    return DLRMConfig(n_dense=13, n_sparse=6, embed_dim=16,
                      vocab_per_field=1000, bot_mlp=(13, 32, 16),
                      top_mlp_hidden=(32, 1), multi_hot=1)


ARCH = ArchSpec(arch_id="dlrm-rm2", family="recsys",
                source="arXiv:1906.00091",
                make_config=_full, make_smoke=_smoke, shapes=RECSYS_SHAPES)
