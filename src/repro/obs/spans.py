"""Low-overhead request tracing: spans, a bounded ring, a no-op path.

A :class:`Span` is one named interval with attributes; a trace is the
set of spans sharing a ``trace_id`` (the scheduler uses the request's
admission sequence number, so every request is its own trace). The
serving pipeline records one trace per request across
admission -> queue -> batch-form -> execute -> deliver, with the
traversal telemetry (``chunks_dispatched``, ``tiles_visited``, ...)
attached to the execute span by ``obs.trace_exec`` — a single exported
trace answers *why* a query was slow: it waited in the queue, it rode a
batch with an expensive batchmate, or its own traversal dispatched many
chunks.

Clock discipline matches ``serve/health.py``: the tracer holds a
``now`` callable (``time.perf_counter`` by default) and every
``start`` / ``finish`` / ``emit`` accepts an explicit ``now=`` /
timestamp override, so span lifecycles are fully drivable on a
simulated clock — no tracing test sleeps.

Storage is a bounded ring (``collections.deque(maxlen=capacity)``):
finished spans append FIFO and the oldest spans fall off
deterministically once the ring is full. Spans are only *in* the ring
once finished; an abandoned started span costs nothing.

The disabled path is :data:`NULL_TRACER`, a module-level
:class:`NullTracer` singleton: ``enabled`` is False, ``start`` /
``emit`` return the shared immutable no-op span, and nothing
allocates. Callers guard attribute assembly with
``if tracer.enabled:`` so a disabled pipeline pays a single attribute
load per request — the overhead-guard test pins this.
"""
from __future__ import annotations

import itertools
import math
import threading
import time
from collections import deque
from contextlib import contextmanager


class Span:
    """One named interval. ``t_end`` is NaN until finished."""

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t_start",
                 "t_end", "attrs")

    def __init__(self, name: str, trace_id, span_id: int,
                 parent_id: int | None, t_start: float, attrs: dict):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t_start = t_start
        self.t_end = math.nan
        self.attrs = attrs

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    @property
    def duration_ms(self) -> float:
        return (self.t_end - self.t_start) * 1e3

    def to_dict(self) -> dict:
        return {"name": self.name, "trace_id": self.trace_id,
                "span_id": self.span_id, "parent_id": self.parent_id,
                "t_start": self.t_start, "t_end": self.t_end,
                "duration_ms": self.duration_ms, "attrs": dict(self.attrs)}

    def __repr__(self) -> str:
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"{self.duration_ms:.3f}ms, {self.attrs})")


class _NullSpan:
    """The shared no-op span: every mutation is a no-op returning self,
    so disabled-mode call sites keep their shape without branching."""

    __slots__ = ()
    name = ""
    trace_id = None
    span_id = -1
    parent_id = None
    t_start = math.nan
    t_end = math.nan
    duration_ms = math.nan
    attrs: dict = {}

    def set(self, **attrs) -> "_NullSpan":
        return self

    def to_dict(self) -> dict:
        return {}


NULL_SPAN = _NullSpan()


class Tracer:
    """Span recorder over a bounded ring buffer.

    ``capacity`` bounds retained *finished* spans (oldest evicted
    first); ``now`` is the clock every unstamped start/finish reads.
    Thread-safe: the scheduler and N executor threads finish spans
    concurrently.
    """

    enabled = True

    def __init__(self, capacity: int = 4096, now=time.perf_counter):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = capacity
        self._now = now
        self._ring: deque[Span] = deque(maxlen=capacity)
        self._span_ids = itertools.count()
        self._trace_ids = itertools.count()
        self._lock = threading.Lock()

    def now(self) -> float:
        return self._now()

    # -- recording -----------------------------------------------------------

    def start(self, name: str, *, trace_id=None, parent: Span | None = None,
              now: float | None = None, **attrs) -> Span:
        """A live span (not yet in the ring). ``trace_id`` defaults to
        the parent's, else a fresh auto id."""
        if trace_id is None:
            trace_id = (parent.trace_id if parent is not None
                        else next(self._trace_ids))
        return Span(name, trace_id, next(self._span_ids),
                    None if parent is None else parent.span_id,
                    self._now() if now is None else now, attrs)

    def finish(self, span: Span, now: float | None = None) -> Span:
        """Stamp ``t_end`` and commit the span to the ring."""
        if span is NULL_SPAN:
            return span
        span.t_end = self._now() if now is None else now
        with self._lock:
            self._ring.append(span)
        return span

    def emit(self, name: str, t_start: float, t_end: float, *,
             trace_id=None, parent: Span | None = None, **attrs) -> Span:
        """Record an already-elapsed interval in one call — the
        retroactive path the scheduler uses at delivery time, so a
        request in flight holds timestamps, not span objects."""
        span = self.start(name, trace_id=trace_id, parent=parent,
                          now=t_start, **attrs)
        return self.finish(span, now=t_end)

    @contextmanager
    def span(self, name: str, *, trace_id=None, parent: Span | None = None,
             **attrs):
        s = self.start(name, trace_id=trace_id, parent=parent, **attrs)
        try:
            yield s
        finally:
            self.finish(s)

    # -- reading -------------------------------------------------------------

    def export(self, trace_id=None) -> list[dict]:
        """Finished spans as dicts, ring (finish) order; optionally one
        trace only. This is the interchange format ``obs.cost`` fits
        from and ``scripts/fit_cost_model.py`` reads back."""
        with self._lock:
            spans = list(self._ring)
        return [s.to_dict() for s in spans
                if trace_id is None or s.trace_id == trace_id]

    def trace(self, trace_id) -> list[dict]:
        return self.export(trace_id)

    def slowest(self, name: str = "request"):
        """Trace id of the longest finished span named ``name`` (None if
        absent) — 'show me the worst request' in one call."""
        with self._lock:
            spans = [s for s in self._ring if s.name == name]
        if not spans:
            return None
        return max(spans, key=lambda s: s.duration_ms).trace_id

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)


class NullTracer:
    """The zero-cost disabled tracer: same surface as :class:`Tracer`,
    no state, no allocation. ``enabled`` is False so hot paths skip
    attribute assembly entirely."""

    enabled = False
    capacity = 0

    def now(self) -> float:
        return 0.0

    def start(self, name: str, **kwargs) -> _NullSpan:
        return NULL_SPAN

    def finish(self, span, now: float | None = None) -> _NullSpan:
        return NULL_SPAN

    def emit(self, name: str, t_start: float, t_end: float,
             **kwargs) -> _NullSpan:
        return NULL_SPAN

    @contextmanager
    def span(self, name: str, **kwargs):
        yield NULL_SPAN

    def export(self, trace_id=None) -> list:
        return []

    def trace(self, trace_id) -> list:
        return []

    def slowest(self, name: str = "request"):
        return None

    def clear(self) -> None:
        pass

    def __len__(self) -> int:
        return 0


NULL_TRACER = NullTracer()
