"""Counters, gauges, and log-bucketed latency histograms.

The single copy of every quantile computation in the repo. Two
conventions, both deliberate:

- **exact-rank (nearest-rank) quantiles** — ``exact_quantile(x, q)`` is
  ``sorted(x)[ceil(q * n) - 1]``: the q-quantile is an *observed*
  sample, never an interpolation between two samples. numpy's default
  linear interpolation reports a p99 *below* the worst observed latency
  for small n (``np.percentile([1, 3], 99) == 2.98``); nearest-rank
  reports 3.0 — the number an SLO is actually written against.
- **log-bucketed mergeable histograms** — :class:`Histogram` stores
  counts in geometrically-spaced buckets (``bucket_growth`` relative
  width per bucket, default 2%), so its state is O(occupied buckets),
  merging two histograms is count addition, and a quantile query walks
  the cumulative counts at the same exact-rank convention. The merge
  invariant the tests pin: ``merge(h1, h2)`` answers every quantile
  exactly as a single histogram fed the pooled samples would.

A histogram quantile is the *upper edge* of the rank's bucket, clamped
into ``[min, max]`` of the observed samples — so it is within one
bucket width (<= growth - 1, i.e. 2%) above the exact-rank sample
quantile, never below the observed minimum, and the top ranks are
*exact* (the clamp pins them to the true maximum). Mean is exact
(``sum / n``), not bucketed.

:class:`MetricsRegistry` is the named collection the scheduler /
executors / retrievers record into and ``obs.export`` serializes
(Prometheus text + JSON). Everything here is stdlib + numpy — importing
``repro.obs`` never touches jax.
"""
from __future__ import annotations

import math
import threading

import numpy as np


def exact_quantile(samples, q: float) -> float:
    """Nearest-rank quantile: ``sorted(x)[ceil(q * n) - 1]``.

    Non-finite entries (NaN in-flight markers, inf) are dropped; an
    empty or all-non-finite sample yields NaN. ``q`` is clamped to
    (0, 1]: every query answers an observed sample, so q=0 degrades to
    the minimum (rank 1) rather than an extrapolation.
    """
    x = np.asarray(samples, dtype=np.float64).ravel()
    x = x[np.isfinite(x)]
    if x.size == 0:
        return math.nan
    rank = min(max(int(math.ceil(q * x.size)), 1), int(x.size))
    return float(np.sort(x)[rank - 1])


class Counter:
    """Monotonic counter. ``inc`` is locked: serving increments race
    across executor threads and a torn read-modify-write would drift
    the snapshot-consistency invariants."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value

    def merge(self, other: "Counter") -> "Counter":
        self.inc(other.value)
        return self


class Gauge:
    """Last-write-wins instantaneous value (queue depth, generation)."""

    __slots__ = ("name", "_value", "_lock")

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Histogram:
    """Log-bucketed latency histogram with mergeable state.

    Bucket ``i`` covers ``(growth**i, growth**(i+1)]`` for positive
    values; non-positive values (zero-service cache hits clamp at 0)
    share one underflow bucket. State is ``{bucket_index: count}`` plus
    exact n / sum / min / max — merging is plain count addition, so
    per-thread or per-process histograms aggregate without losing
    quantile fidelity beyond the bucket width.
    """

    __slots__ = ("name", "growth", "_log_growth", "_buckets", "_nonpos",
                 "_n", "_sum", "_min", "_max", "_lock")

    def __init__(self, name: str = "", growth: float = 1.02):
        if growth <= 1.0:
            raise ValueError(f"bucket growth must be > 1, got {growth}")
        self.name = name
        self.growth = float(growth)
        self._log_growth = math.log(self.growth)
        self._buckets: dict[int, int] = {}
        self._nonpos = 0
        self._n = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    # -- recording -----------------------------------------------------------

    def record(self, v: float) -> None:
        v = float(v)
        if not math.isfinite(v):
            return
        with self._lock:
            self._n += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if v <= 0.0:
                self._nonpos += 1
            else:
                i = math.floor(math.log(v) / self._log_growth)
                self._buckets[i] = self._buckets.get(i, 0) + 1

    def record_many(self, values) -> None:
        x = np.asarray(values, dtype=np.float64).ravel()
        x = x[np.isfinite(x)]
        if x.size == 0:
            return
        pos = x[x > 0.0]
        if pos.size:
            idx = np.floor(np.log(pos) / self._log_growth).astype(np.int64)
            uniq, counts = np.unique(idx, return_counts=True)
        else:
            uniq, counts = (), ()
        with self._lock:
            self._n += int(x.size)
            self._sum += float(x.sum())
            self._min = min(self._min, float(x.min()))
            self._max = max(self._max, float(x.max()))
            self._nonpos += int(x.size - pos.size)
            for i, c in zip(uniq, counts):
                i = int(i)
                self._buckets[i] = self._buckets.get(i, 0) + int(c)

    # -- merging -------------------------------------------------------------

    def merge(self, other: "Histogram") -> "Histogram":
        """Fold ``other``'s state in (count addition). Both histograms
        must share the bucket geometry, or the indices would alias."""
        if abs(other.growth - self.growth) > 1e-12:
            raise ValueError(
                f"cannot merge histograms with different bucket growth "
                f"({self.growth} vs {other.growth})")
        with other._lock:
            o_buckets = dict(other._buckets)
            o = (other._nonpos, other._n, other._sum, other._min,
                 other._max)
        with self._lock:
            for i, c in o_buckets.items():
                self._buckets[i] = self._buckets.get(i, 0) + c
            self._nonpos += o[0]
            self._n += o[1]
            self._sum += o[2]
            self._min = min(self._min, o[3])
            self._max = max(self._max, o[4])
        return self

    # -- queries -------------------------------------------------------------

    @property
    def n(self) -> int:
        with self._lock:
            return self._n

    @property
    def mean(self) -> float:
        with self._lock:
            return self._sum / self._n if self._n else math.nan

    def quantile(self, q: float) -> float:
        """Exact-rank quantile at bucket resolution: the rank's bucket
        upper edge, clamped into [min, max] of the observed samples."""
        with self._lock:
            if self._n == 0:
                return math.nan
            rank = min(max(int(math.ceil(q * self._n)), 1), self._n)
            if rank <= self._nonpos:
                # all underflow samples are <= 0; min is the exact
                # representative when they are one repeated value (the
                # zero-service cache-hit case)
                return self._min
            seen = self._nonpos
            for i in sorted(self._buckets):
                seen += self._buckets[i]
                if rank <= seen:
                    edge = self.growth ** (i + 1)
                    return float(min(max(edge, self._min), self._max))
            return self._max  # unreachable: counts sum to n

    def summary(self, quantiles=(0.5, 0.9, 0.99)) -> dict:
        """JSON-able view. An empty histogram reports only ``n`` — no
        NaN fields, so summaries embed directly in the hardened bench
        JSON (``benchmarks.common.write_bench_json`` rejects NaN)."""
        if self.n == 0:
            return {"n": 0}
        with self._lock:
            out = {"n": self._n, "mean": self._sum / self._n,
                   "min": self._min, "max": self._max}
        for q in quantiles:
            out[f"p{int(q * 100)}"] = self.quantile(q)
        return out

    def state(self) -> dict:
        """Full serializable state (bucket counts included) — what a
        trace/metrics export ships so another process can merge it."""
        with self._lock:
            return {"growth": self.growth, "n": self._n, "sum": self._sum,
                    "min": self._min if self._n else None,
                    "max": self._max if self._n else None,
                    "nonpos": self._nonpos,
                    "buckets": {str(i): c
                                for i, c in sorted(self._buckets.items())}}

    @classmethod
    def from_state(cls, state: dict, name: str = "") -> "Histogram":
        h = cls(name, growth=state["growth"])
        h._n = int(state["n"])
        h._sum = float(state["sum"])
        h._min = math.inf if state["min"] is None else float(state["min"])
        h._max = -math.inf if state["max"] is None else float(state["max"])
        h._nonpos = int(state.get("nonpos", 0))
        h._buckets = {int(i): int(c)
                      for i, c in state.get("buckets", {}).items()}
        return h


class MetricsRegistry:
    """Named counters / gauges / histograms, created on first use.

    One registry per scheduler (or one shared across a process — names
    are the namespace). A name is permanently one metric kind; asking
    for it as another kind is a programming error and raises.
    """

    def __init__(self):
        self._metrics: dict[str, object] = {}
        self._lock = threading.Lock()

    def _get(self, name: str, kind, factory):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = factory()
            elif not isinstance(m, kind):
                raise TypeError(
                    f"metric {name!r} is a {type(m).__name__}, not a "
                    f"{kind.__name__}")
            return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter, lambda: Counter(name))

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge, lambda: Gauge(name))

    def histogram(self, name: str, growth: float = 1.02) -> Histogram:
        return self._get(name, Histogram,
                         lambda: Histogram(name, growth=growth))

    def snapshot(self) -> dict:
        """Detached JSON-able view: {kind: {name: value-or-summary}}."""
        with self._lock:
            items = list(self._metrics.items())
        out = {"counters": {}, "gauges": {}, "histograms": {}}
        for name, m in sorted(items):
            if isinstance(m, Counter):
                out["counters"][name] = m.value
            elif isinstance(m, Gauge):
                out["gauges"][name] = m.value
            else:
                out["histograms"][name] = m.summary()
        return out

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Fold another registry in: counters add, histograms merge,
        gauges take the other's (newer) value."""
        with other._lock:
            items = list(other._metrics.items())
        for name, m in items:
            if isinstance(m, Counter):
                self.counter(name).merge(m)
            elif isinstance(m, Gauge):
                self.gauge(name).set(m.value)
            else:
                self.histogram(name, growth=m.growth).merge(m)
        return self
