"""Metrics/trace exposition: Prometheus text, JSON snapshots, HTTP.

Three consumers, one serialization seam:

- ``launch/serve.py --metrics-port N`` runs :class:`MetricsServer` — a
  stdlib ``http.server`` on a daemon thread serving ``/metrics``
  (Prometheus text exposition), ``/metrics.json`` (the registry
  snapshot plus whatever extra stats callable the owner wires in) and
  ``/traces`` (the tracer's ring as JSON);
- the benchmarks embed :func:`json_snapshot` into their ``BENCH_*.json``
  meta, so recorded runs carry the same histograms an operator would
  scrape;
- tests read both formats back.

Histograms export as Prometheus *summaries* (quantile-labelled gauges
plus ``_sum`` / ``_count``): the registry's quantiles are exact-rank at
bucket resolution, which is what a summary models — re-aggregating
them server-side would be wrong, and that is Prometheus's summary
contract, not ours.
"""
from __future__ import annotations

import json
import re
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

from .metrics import MetricsRegistry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str, prefix: str = "repro_") -> str:
    """Prometheus metric name: prefixed, invalid chars to '_'."""
    return prefix + _NAME_RE.sub("_", name)


def prometheus_text(registry: MetricsRegistry,
                    quantiles=(0.5, 0.9, 0.99)) -> str:
    """The registry in Prometheus text exposition format (v0.0.4)."""
    snap = registry.snapshot()
    lines = []
    for name, value in snap["counters"].items():
        pn = _prom_name(name)
        lines += [f"# TYPE {pn} counter", f"{pn} {value}"]
    for name, value in snap["gauges"].items():
        pn = _prom_name(name)
        lines += [f"# TYPE {pn} gauge", f"{pn} {value}"]
    for name, summ in snap["histograms"].items():
        pn = _prom_name(name)
        lines.append(f"# TYPE {pn} summary")
        for q in quantiles:
            key = f"p{int(q * 100)}"
            if key in summ:
                lines.append(f'{pn}{{quantile="{q}"}} {summ[key]}')
        lines.append(f"{pn}_count {summ['n']}")
        if "mean" in summ:
            lines.append(f"{pn}_sum {summ['mean'] * summ['n']}")
    return "\n".join(lines) + "\n"


def _coerce(obj):
    """``json.dumps`` fallback for numpy scalars (span attrs may carry
    them when callers drive the scheduler with numpy-computed clocks)."""
    if hasattr(obj, "item"):
        return obj.item()
    raise TypeError(f"not JSON serializable: {type(obj).__name__}")


def json_snapshot(registry: MetricsRegistry, tracer=None,
                  extra: dict | None = None) -> dict:
    """One JSON-able observability snapshot: the metrics registry,
    optionally the tracer's span count + slowest request, plus caller
    extras (scheduler stats, bench config) merged under ``extra``."""
    out = {"metrics": registry.snapshot()}
    if tracer is not None and tracer.enabled:
        out["traces"] = {"spans": len(tracer),
                         "slowest_request": tracer.slowest("request")}
    if extra:
        out["extra"] = extra
    return out


class MetricsServer:
    """``http.server`` exposition on a daemon thread.

    ``port=0`` binds an ephemeral port (read it back from ``.port`` —
    what the tests use). ``extra`` is a zero-arg callable returning a
    JSON-able dict merged into ``/metrics.json`` (the scheduler passes
    its ``stats``), evaluated per request so snapshots are live.
    """

    def __init__(self, registry: MetricsRegistry, tracer=None, *,
                 host: str = "127.0.0.1", port: int = 0, extra=None):
        self.registry = registry
        self.tracer = tracer
        self._extra = extra
        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — http.server API
                try:
                    if self.path == "/metrics":
                        body = prometheus_text(server.registry)
                        ctype = "text/plain; version=0.0.4"
                    elif self.path == "/metrics.json":
                        extra = (server._extra() if callable(server._extra)
                                 else server._extra)
                        body = json.dumps(
                            json_snapshot(server.registry, server.tracer,
                                          extra=extra), default=_coerce)
                        ctype = "application/json"
                    elif self.path == "/traces":
                        spans = (server.tracer.export()
                                 if server.tracer is not None else [])
                        body = json.dumps(spans, default=_coerce)
                        ctype = "application/json"
                    else:
                        self.send_error(404)
                        return
                except Exception as exc:  # noqa: BLE001 — surface, don't die
                    self.send_error(500, repr(exc))
                    return
                payload = body.encode()
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(payload)))
                self.end_headers()
                self.wfile.write(payload)

            def log_message(self, *args):  # quiet: no per-scrape stderr
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics-server",
            daemon=True)
        self._thread.start()

    @property
    def port(self) -> int:
        return self._httpd.server_address[1]

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self) -> "MetricsServer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
