"""``repro.obs`` — tracing, metrics, export, and the serving cost model.

The observability subsystem: :mod:`~repro.obs.spans` (per-request
tracing with a bounded ring and a zero-cost disabled path),
:mod:`~repro.obs.metrics` (counters / gauges / mergeable log-bucketed
histograms with exact-rank quantiles), :mod:`~repro.obs.export`
(Prometheus text + JSON snapshots + the ``--metrics-port`` HTTP
server), and :mod:`~repro.obs.cost` (the trace-fitted chunk-count
predictor behind ``SchedulerConfig.sort_batches_by_cost``).

This package root stays jax-free on import: ``obs.trace_exec`` (which
adapts ``core.traversal`` stats into span attributes) is imported
explicitly by its consumers, so tools like ``scripts/fit_cost_model.py``
can load a model without initializing a backend.
"""
from .cost import FEATURES, CostModel, QueryFeaturizer  # noqa: F401
from .export import (MetricsServer, json_snapshot,  # noqa: F401
                     prometheus_text)
from .metrics import (Counter, Gauge, Histogram,  # noqa: F401
                      MetricsRegistry, exact_quantile)
from .spans import (NULL_SPAN, NULL_TRACER, NullTracer,  # noqa: F401
                    Span, Tracer)
