"""Trace-fitted chunk-count predictor for batch-sorted dispatch.

The chunked traversal's cost is its ``lax.while_loop`` trip count —
``chunks_dispatched`` — and under vmap the *batch* pays the max over
its rows. Query length only coarsely predicts that count; what actually
drives it is how much bound mass the query carries (ROADMAP item (a)).
This module closes the loop the tracer opens: execute spans carry
``(cost_features, chunks_dispatched)`` pairs, a ridge regression fits
them offline (``scripts/fit_cost_model.py``), and the scheduler sorts
each picked group by the prediction (``SchedulerConfig.
sort_batches_by_cost``) so micro-batches cluster similar-cost requests
and the max-over-batch trip count hugs the mean.

**Features** (:data:`FEATURES`, per query row, computed host-side from
the same planner inputs ``core.plan.plan_query`` sorts by — the
alpha-combined query-weighted list maxima ``combine(alpha, qwb *
sigma_b[qt], qwl * sigma_l[qt])``):

- ``n_terms``    — live (nonzero-weight) term count;
- ``ub_sum``     — total per-term upper-bound mass;
- ``ub_max``     — the single largest term bound;
- ``ub_tail``    — ``ub_sum - ub_max``: the non-essential prefix mass
  (MaxScore's non-essential side at the deepest threshold) — what keeps
  chunk bounds above theta long after the top term alone would fail;
- ``ess_ref``    — essential-set size at a fixed reference threshold
  (the corpus's largest list maximum, frozen at featurizer build): how
  many terms the ascending prefix-sum partition marks essential.

**Monotonicity by construction**: every feature is nondecreasing under
adding a term or increasing a weight (for ``ess_ref``: the sum of the
i smallest bounds is nondecreasing in every bound, and a new element
only shifts the count up), and :meth:`CostModel.fit` constrains the
ridge weights nonnegative (projected coordinate descent) — so a
heavier query can never predict fewer chunks, which the test suite
pins. Prediction is pure numpy; fitting needs nothing but numpy
either, so this module never imports jax.
"""
from __future__ import annotations

import dataclasses
import json
import math
import pathlib

import numpy as np

FEATURES = ("n_terms", "ub_sum", "ub_max", "ub_tail", "ess_ref")


class QueryFeaturizer:
    """Host-side feature extraction over one index's list maxima.

    Pulls ``sigma_b`` / ``sigma_l`` to numpy once (a ``HybridIndex``
    exposes them through its sparse half) and evaluates
    :data:`FEATURES` for padded ``[r, width]`` query rows — a few numpy
    reductions per request, cheap enough for the submit path.
    """

    def __init__(self, index, params):
        base = getattr(index, "sparse", index)
        self.sigma_b = np.asarray(base.sigma_b, np.float32)
        self.sigma_l = np.asarray(base.sigma_l, np.float32)
        self.alpha = float(params.alpha)
        # fixed reference threshold for ess_ref: the corpus's largest
        # alpha-combined list maximum — frozen here so the feature is a
        # pure (monotone) function of the query
        combined = (self.alpha * self.sigma_b
                    + (1.0 - self.alpha) * self.sigma_l)
        self.theta_ref = float(combined.max(initial=0.0))

    def __call__(self, terms, qw_b, qw_l) -> np.ndarray:
        """Features for padded query rows: [r, len(FEATURES)] f64.
        Zero-weight padding terms contribute nothing (live mask)."""
        t = np.atleast_2d(np.asarray(terms))
        wb = np.atleast_2d(np.asarray(qw_b, np.float64))
        wl = np.atleast_2d(np.asarray(qw_l, np.float64))
        live = (wb != 0) | (wl != 0)
        ub = (self.alpha * wb * self.sigma_b[t]
              + (1.0 - self.alpha) * wl * self.sigma_l[t])
        ub = np.where(live, np.maximum(ub, 0.0), 0.0)
        n_terms = live.sum(axis=1)
        ub_sum = ub.sum(axis=1)
        ub_max = ub.max(axis=1, initial=0.0)
        # essential count at theta_ref: terms whose ascending inclusive
        # prefix sum exceeds the reference threshold
        cum = np.cumsum(np.sort(ub, axis=1), axis=1)
        ess = (cum > self.theta_ref).sum(axis=1)
        return np.stack([n_terms, ub_sum, ub_max, ub_sum - ub_max, ess],
                        axis=1).astype(np.float64)


@dataclasses.dataclass
class CostModel:
    """Nonnegative ridge regression ``chunks ~ intercept + X @ w``.

    ``weights`` are guaranteed >= 0 by :meth:`fit`, so prediction is
    monotone in every (monotone) feature. ``predict`` clamps at 0 —
    a chunk count can't be negative; callers comparing batches only
    need the ordering anyway.
    """

    weights: np.ndarray
    intercept: float
    features: tuple = FEATURES
    r2: float = math.nan
    n_samples: int = 0

    def predict(self, X) -> np.ndarray:
        X = np.atleast_2d(np.asarray(X, np.float64))
        if X.shape[1] != len(self.weights):
            raise ValueError(
                f"feature width {X.shape[1]} != model width "
                f"{len(self.weights)} (features {self.features})")
        return np.maximum(self.intercept + X @ self.weights, 0.0)

    @classmethod
    def fit(cls, X, y, l2: float = 1e-3, n_iter: int = 300,
            features: tuple = FEATURES) -> "CostModel":
        """Projected coordinate descent for the nonnegative ridge
        problem ``min ||y - b - Xw||^2 + l2 ||w||^2, w >= 0``. Columns
        are max-scaled internally for conditioning (a positive scale,
        so projecting to ``w >= 0`` is unchanged) and the scale is
        folded back into the returned weights."""
        X = np.atleast_2d(np.asarray(X, np.float64))
        y = np.asarray(y, np.float64).ravel()
        if X.shape[0] != y.size:
            raise ValueError(f"{X.shape[0]} feature rows vs {y.size} targets")
        if y.size == 0:
            raise ValueError("cannot fit a cost model on zero samples")
        scale = np.abs(X).max(axis=0)
        scale[scale == 0] = 1.0
        Xs = X / scale
        w = np.zeros(Xs.shape[1])
        b = float(y.mean())
        col_sq = (Xs * Xs).sum(axis=0)
        r = y - b - Xs @ w
        for _ in range(n_iter):
            for j in range(Xs.shape[1]):
                if col_sq[j] == 0:
                    continue
                rho = Xs[:, j] @ r + col_sq[j] * w[j]
                new = max(rho / (col_sq[j] + l2), 0.0)
                if new != w[j]:
                    r += Xs[:, j] * (w[j] - new)
                    w[j] = new
            new_b = b + r.mean()
            r -= new_b - b
            b = new_b
        ss_res = float(r @ r)
        ss_tot = float(((y - y.mean()) ** 2).sum())
        r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else (
            1.0 if ss_res < 1e-12 else 0.0)
        return cls(weights=w / scale, intercept=b, features=tuple(features),
                   r2=r2, n_samples=int(y.size))

    @classmethod
    def fit_from_traces(cls, spans: list, l2: float = 1e-3) -> "CostModel":
        """Fit from a tracer export (``Tracer.export()`` dicts): every
        span carrying both ``cost_features`` and a realized
        ``chunks_dispatched`` attribute is a sample — the pairs the
        scheduler's execute spans record when tracing is enabled."""
        X, y = [], []
        for s in spans:
            attrs = s.get("attrs", s)
            f, c = attrs.get("cost_features"), attrs.get("chunks_dispatched")
            if f is None or c is None:
                continue
            X.append(np.asarray(f, np.float64))
            y.append(float(c))
        if not y:
            raise ValueError(
                "no (cost_features, chunks_dispatched) samples in the "
                "trace export — run with tracing enabled on a chunked-"
                "traversal route first")
        return cls.fit(np.stack(X), np.asarray(y), l2=l2)

    # -- persistence ---------------------------------------------------------

    def to_dict(self) -> dict:
        return {"features": list(self.features),
                "weights": [float(w) for w in self.weights],
                "intercept": float(self.intercept),
                "r2": float(self.r2), "n_samples": self.n_samples}

    def save(self, path) -> None:
        pathlib.Path(path).write_text(
            json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n")

    @classmethod
    def load(cls, path) -> "CostModel":
        d = json.loads(pathlib.Path(path).read_text())
        return cls(weights=np.asarray(d["weights"], np.float64),
                   intercept=float(d["intercept"]),
                   features=tuple(d["features"]),
                   r2=float(d.get("r2", math.nan)),
                   n_samples=int(d.get("n_samples", 0)))
