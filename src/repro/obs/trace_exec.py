"""Per-query traversal telemetry -> span attributes.

The traversal executors already count the work the paper's pruning
scheme saves (``tiles_visited``, ``chunks_dispatched``, ``n_chunks``,
the doc-level skip counters) into per-query stat arrays; the scheduler
slices them per request at delivery. This module is the small adapter
that turns one request's sliced stats dict into flat scalar span
attributes, so a single exported trace shows *why* the query was slow
— its own dispatched-chunk count, not just its latency.

Imports ``core.traversal`` (which imports jax), so it is deliberately
not re-exported from ``repro.obs``'s package root: importing the
lightweight obs surface (metrics/spans/cost/export) never initializes
jax; the scheduler imports this module explicitly.
"""
from __future__ import annotations

import numpy as np

from ..core.traversal import TRACE_STAT_KEYS


def request_attributes(stats: dict, reduce=np.max) -> dict:
    """Flatten a (per-request) stats dict to scalar attributes: each
    known traversal counter reduced over the request's rows (max by
    default — the row that kept the batch's while_loop alive). Keys an
    engine doesn't produce (``chunks_dispatched`` on a full scan) are
    simply absent."""
    out = {}
    for key in TRACE_STAT_KEYS:
        v = stats.get(key)
        if v is None:
            continue
        arr = np.asarray(v, np.float64)
        if arr.size == 0 or not np.isfinite(arr).all():
            continue
        out[key] = float(reduce(arr) if arr.ndim else arr)
    return out


def row_attributes(stats: dict, row: int) -> dict:
    """Scalar traversal attributes for one row of a stats dict."""
    out = {}
    for key in TRACE_STAT_KEYS:
        v = stats.get(key)
        if v is None:
            continue
        arr = np.asarray(v, np.float64)
        if arr.ndim >= 1 and row < arr.shape[0]:
            out[key] = float(arr[row])
        elif arr.ndim == 0:
            out[key] = float(arr)
    return out
