"""Sparse weight models (term-major CSR) and exhaustive scoring.

A ``SparseModel`` is one weighting model over a corpus: BM25 or a learned
impact model (SPLADE / uniCOIL / DeepImpact style). Postings are term-major
CSR, docids sorted ascending within each term — the layout every other core
module (alignment, index build, oracle) consumes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SparseModel:
    """One weighting model in term-major CSR form (host-side, numpy)."""

    n_docs: int
    n_terms: int
    indptr: np.ndarray   # [n_terms + 1] int64
    docids: np.ndarray   # [nnz] int32, sorted ascending within each term
    weights: np.ndarray  # [nnz] float32

    @property
    def nnz(self) -> int:
        return int(self.docids.shape[0])

    def postings(self, term: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[term], self.indptr[term + 1]
        return self.docids[s:e], self.weights[s:e]

    def validate(self) -> None:
        assert self.indptr.shape == (self.n_terms + 1,)
        assert self.indptr[0] == 0 and self.indptr[-1] == self.nnz
        assert np.all(np.diff(self.indptr) >= 0)
        for t in range(min(self.n_terms, 64)):  # spot-check sortedness
            d, _ = self.postings(t)
            assert np.all(np.diff(d) > 0), f"term {t} postings unsorted/dup"

    def max_weights(self) -> np.ndarray:
        """Per-term maximum contribution sigma[t] (0 for empty lists)."""
        out = np.zeros(self.n_terms, dtype=np.float32)
        np.maximum.at(out, np.repeat(np.arange(self.n_terms),
                                     np.diff(self.indptr)), self.weights)
        return out


def from_coo(n_docs: int, n_terms: int, terms: np.ndarray, docs: np.ndarray,
             weights: np.ndarray) -> SparseModel:
    """Build a SparseModel from unsorted COO triples, deduping (term,doc)."""
    key = terms.astype(np.int64) * n_docs + docs.astype(np.int64)
    order = np.argsort(key, kind="stable")
    key, terms, docs, weights = key[order], terms[order], docs[order], weights[order]
    keep = np.concatenate([[True], np.diff(key) != 0])
    terms, docs, weights = terms[keep], docs[keep], weights[keep]
    counts = np.bincount(terms, minlength=n_terms)
    indptr = np.zeros(n_terms + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return SparseModel(n_docs, n_terms, indptr,
                       docs.astype(np.int32), weights.astype(np.float32))


def score_all(model: SparseModel, q_terms: np.ndarray,
              q_weights: np.ndarray | None = None) -> np.ndarray:
    """Exhaustively score every document: S[d] = sum_t qw_t * w(t, d)."""
    scores = np.zeros(model.n_docs, dtype=np.float64)
    if q_weights is None:
        q_weights = np.ones(len(q_terms), dtype=np.float32)
    for t, qw in zip(q_terms, q_weights):
        d, w = model.postings(int(t))
        scores[d] += float(qw) * w.astype(np.float64)
    return scores.astype(np.float32)


def exhaustive_topk(scores: np.ndarray, k: int) -> tuple[np.ndarray, np.ndarray]:
    """Top-k (ids, scores), score-desc with docid-asc tiebreak (stable)."""
    k = min(k, len(scores))
    # argsort on (-score, docid): lexsort keys are last-key-primary.
    order = np.lexsort((np.arange(len(scores)), -scores))[:k]
    return order.astype(np.int32), scores[order]
