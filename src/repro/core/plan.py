"""Query planner for the 2GTI tile-scan engine — the planner/executor contract.

One planner, three executors. Every traversal mode used to carry its own
copy of the sort/bound/skip logic (the batched vmap engine, the sequential
host loop, and the Pallas-kernel wrapper each re-derived term order, tile
upper bounds and the essential partition). This module is now the single
copy; executors only gather, scatter-accumulate, and merge queues.

Planner responsibilities (this module):
  - **term ordering** — ``plan_query`` presorts query terms ascending by
    alpha-combined list maxima and packages the weighted list maxima
    (``sig_b``/``sig_l``) alongside, as a :class:`QueryPlan`;
  - **tile scheduling** — ``tile_upper_bounds`` gives the per-tile
    alpha-combined global upper bound (the tile-skip test operand) and
    ``tile_schedule`` turns it into a visit order (``docid`` or ``impact``);
  - **per-tile term bounds** — ``term_bounds`` yields ``(m_alpha, m_beta,
    ub_gl)`` under either ``bound_mode`` (``list`` = MaxScore list maxima,
    ``tile`` = block-max tightening);
  - **threshold partitioning** — ``essential_terms`` marks the essential
    suffix given theta_Gl, ``freeze_bounds`` gives the inclusive beta-bound
    prefix sums driving the local freeze test.

Executor responsibilities (``core.traversal`` / ``kernels.guided_score``):
  posting gather, dense scatter, the freeze-loop accumulate, per-tile
  candidate top-k and queue merges. Executors receive ``essential`` and
  ``prefix_beta`` ready-made — neither scorer path sees theta_Gl, whose
  only remaining consumer is the planner-side tile-skip test.

Everything here is pure jnp, shape-static, and vmap / shard_map
compatible: the same functions drive the batched engine, the sequential
host loop (which pulls results back with ``np.asarray``) and the
mesh-sharded executor in ``serve.sharded``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


def combine(coef, b, l):
    """The paper's two-weight interpolation: coef * B + (1 - coef) * L."""
    return coef * b + (1.0 - coef) * l


class QueryPlan(NamedTuple):
    """Per-query traversal plan: terms presorted ascending by
    alpha-combined list maxima (the MaxScore partition order)."""
    qt: jax.Array      # [Nq] int32 term ids, sorted order
    qwb: jax.Array     # [Nq] f32 BM25-side query weights, sorted order
    qwl: jax.Array     # [Nq] f32 learned-side query weights, sorted order
    sig_b: jax.Array   # [Nq] f32 query-weighted list maxima (BM25 side)
    sig_l: jax.Array   # [Nq] f32 query-weighted list maxima (learned side)


def plan_query(qt, qwb, qwl, sigma_b, sigma_l, alpha) -> QueryPlan:
    """Sort query terms ascending by alpha-combined list maxima."""
    sig_b = qwb * sigma_b[qt]
    sig_l = qwl * sigma_l[qt]
    order = jnp.argsort(combine(alpha, sig_b, sig_l))
    return QueryPlan(qt[order], qwb[order], qwl[order],
                     sig_b[order], sig_l[order])


def tile_upper_bounds(plan: QueryPlan, tile_max_b, tile_max_l, alpha):
    """Per-tile alpha-combined global upper bounds: [n_tiles]."""
    tm_b = plan.qwb[:, None] * tile_max_b[plan.qt, :]
    tm_l = plan.qwl[:, None] * tile_max_l[plan.qt, :]
    return combine(alpha, tm_b, tm_l).sum(0)


def tile_schedule(plan: QueryPlan, tile_max_b, tile_max_l, alpha,
                  n_tiles: int, schedule: str):
    """Tile visit order. ``docid`` mirrors DAAT; ``impact`` visits tiles in
    descending global upper bound so thresholds tighten fastest."""
    if schedule == "impact":
        ub = tile_upper_bounds(plan, tile_max_b, tile_max_l, alpha)
        return jnp.argsort(-ub).astype(jnp.int32)
    return jnp.arange(n_tiles, dtype=jnp.int32)


class ChunkSchedule(NamedTuple):
    """Descending-bound tile order folded into static fixed-size chunks —
    the Block-Max-Pruning visit structure (process blocks in descending
    bound order, stop when the next bound clears the threshold) mapped
    onto a shape-static ``lax.while_loop`` carrier."""
    chunks: jax.Array    # [n_chunks, chunk_tiles] int32 tile ids; the
    #                      sentinel ``n_tiles`` pads the tail chunk and is
    #                      force-skipped by the executor (tile_valid False)
    chunk_ub: jax.Array  # [n_chunks] f32 max tile upper bound per chunk
    #                      (-inf for all-padding chunks): the early-exit
    #                      test operand. Descending by construction.


def chunk_schedule(plan: QueryPlan, tile_max_b, tile_max_l, alpha,
                   n_tiles: int, chunk_tiles: int,
                   n_real: int | jax.Array | None = None) -> ChunkSchedule:
    """Chunked visit order: sort tiles by descending global upper bound and
    pad into static ``[n_chunks, chunk_tiles]`` groups.

    Because tiles are sorted descending, the per-chunk max bound is the
    bound of the chunk's first tile, and the sequence ``chunk_ub`` is
    itself descending — so the first chunk whose bound fails the theta_Gl
    test proves every later tile fails it too, and the executor may stop.

    ``n_real`` (sharded path): tiles with id >= n_real are shape padding;
    their bound is forced to -inf so they sort last and never keep the
    chunk loop alive. The sentinel id ``n_tiles`` pads the ragged tail.
    """
    ub = tile_upper_bounds(plan, tile_max_b, tile_max_l, alpha)
    if n_real is not None:
        ub = jnp.where(jnp.arange(n_tiles) < n_real, ub, -jnp.inf)
    # Same expression as the ``impact`` tile_schedule: identical tie-break
    # order, which is what makes the chunked scan bit-identical to it.
    order = jnp.argsort(-ub).astype(jnp.int32)
    ub_sorted = ub[order]
    n_chunks = -(-n_tiles // chunk_tiles)
    pad = n_chunks * chunk_tiles - n_tiles
    if pad:
        order = jnp.concatenate(
            [order, jnp.full((pad,), n_tiles, jnp.int32)])
        ub_sorted = jnp.concatenate(
            [ub_sorted, jnp.full((pad,), -jnp.inf, jnp.float32)])
    chunks = order.reshape(n_chunks, chunk_tiles)
    return ChunkSchedule(chunks, ub_sorted.reshape(n_chunks, chunk_tiles).max(1))


def term_bounds(plan: QueryPlan, tile_max_b, tile_max_l, tile,
                alpha, beta, bound_mode: str):
    """Bounds for one tile visit: per-term maxima under both combinations
    plus the tile's global upper bound (the skip-test operand).

    ``bound_mode='list'`` partitions with list-level maxima (paper
    MaxScore); ``'tile'`` with the tile-level block maxima.
    """
    tm_b = plan.qwb * tile_max_b[plan.qt, tile]
    tm_l = plan.qwl * tile_max_l[plan.qt, tile]
    ub_gl = combine(alpha, tm_b, tm_l).sum()
    if bound_mode == "tile":
        m_alpha = combine(alpha, tm_b, tm_l)
        m_beta = combine(beta, tm_b, tm_l)
    else:
        m_alpha = combine(alpha, plan.sig_b, plan.sig_l)
        m_beta = combine(beta, plan.sig_b, plan.sig_l)
    return m_alpha, m_beta, ub_gl


def essential_terms(m_alpha, th_gl):
    """Global-level term partition: the suffix whose inclusive prefix bound
    exceeds theta_Gl is essential (bool, sorted term order)."""
    return jnp.cumsum(m_alpha) > th_gl


def freeze_bounds(m_beta):
    """Inclusive prefix sums of the beta-combined bounds: the remaining
    upper bound used by the local freeze test before each term."""
    return jnp.cumsum(m_beta)
