"""Blocked Impact Index (BII): the TPU-native layout of a merged index.

The docid space is partitioned into tiles of ``tile_size`` documents. For
each (term, tile) we store a CSR pointer into the term's posting run for that
tile, plus tile-granular maxima of both weights (the block-max analogue).
All query-time gathers are static-shaped: a term's postings inside one tile
are fetched as a ``pad_len``-wide padded slice.

Arrays live as jnp devices arrays; the build is numpy host-side.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .align import MergedPostings

INVALID_DOC = np.int32(2**31 - 1)


@dataclasses.dataclass
class BlockedImpactIndex:
    n_docs: int
    n_terms: int
    tile_size: int
    n_tiles: int
    pad_len: int          # max postings of one term inside one tile (padded)
    # flat postings (term-major, docid-sorted within term)
    docids: jax.Array     # [nnz] int32
    w_b: jax.Array        # [nnz] f32
    w_l: jax.Array        # [nnz] f32
    # per-(term, tile) structure
    tile_ptr: jax.Array   # [n_terms, n_tiles + 1] int32 (offsets into flat arrays)
    tile_max_b: jax.Array # [n_terms, n_tiles] f32
    tile_max_l: jax.Array # [n_terms, n_tiles] f32
    # list-level maxima
    sigma_b: jax.Array    # [n_terms] f32
    sigma_l: jax.Array    # [n_terms] f32
    # docid remapping (identity unless the index was built with doc_order):
    # orig_of_new[new_id] = original docid, or None for identity.
    orig_of_new: np.ndarray | None = None

    # Static tag dispatched on by the traversal executors (see
    # ``dispatch_gather``). The compressed index reports "q8".
    gather_kind = "fp32"

    @property
    def nnz(self) -> int:
        return int(self.docids.shape[0])

    def gather_arrays(self) -> tuple[jax.Array, ...]:
        """Posting-side arrays consumed by ``dispatch_gather`` — the
        per-kind payload the executors thread through jit as a pytree."""
        return (self.docids, self.w_b, self.w_l, self.tile_ptr)

    def to_orig(self, ids: np.ndarray) -> np.ndarray:
        """Map internal docids back to original ids (-1 passes through)."""
        ids = np.asarray(ids)
        if self.orig_of_new is None:
            return ids
        safe = np.clip(ids, 0, self.n_docs - 1)
        return np.where(ids < 0, ids, self.orig_of_new[safe]).astype(ids.dtype)


def impact_doc_order(merged: MergedPostings) -> np.ndarray:
    """Docid reordering by descending total learned mass.

    Clusters high-impact documents into few tiles so tile maxima become
    discriminative — the tile-granular analogue of the docid-reassignment
    (BP reordering) used with block-max indexes in PISA. Returns ``order``
    such that new docid ``i`` is original doc ``order[i]``.
    """
    mass = np.zeros(merged.n_docs, dtype=np.float64)
    np.add.at(mass, merged.docids, merged.w_l.astype(np.float64))
    return np.argsort(-mass, kind="stable").astype(np.int32)


def blocked_layout(merged: MergedPostings, tile_size: int = 2048,
                   pad_multiple: int = 8, pad_cap: int | None = None,
                   doc_order: np.ndarray | None = None) -> dict:
    """Host-side tile layout shared by the fp32 and compressed builders.

    Returns a dict of numpy arrays: the (optionally reordered) term-major
    flat postings, ``tile_ptr``/``cnt``, exact per-(term, tile) and
    per-term maxima, and ``pad_len``. ``build_index`` wraps this into
    device arrays; ``repro.index.compress_index`` encodes the same
    layout instead of materializing fp32 postings on device.
    """
    n_docs, n_terms = merged.n_docs, merged.n_terms
    n_tiles = -(-n_docs // tile_size)
    indptr = merged.indptr
    docids = merged.docids
    w_b_arr, w_l_arr = merged.w_b, merged.w_l
    orig_of_new = None
    if doc_order is not None:
        orig_of_new = np.asarray(doc_order, dtype=np.int32)
        new_of_orig = np.empty(n_docs, dtype=np.int32)
        new_of_orig[orig_of_new] = np.arange(n_docs, dtype=np.int32)
        docids = new_of_orig[docids]
        # re-sort each term's postings by the new docid
        term_of = np.repeat(np.arange(n_terms, dtype=np.int64),
                            np.diff(indptr))
        order = np.lexsort((docids, term_of))
        docids = docids[order]
        w_b_arr = w_b_arr[order]
        w_l_arr = w_l_arr[order]

    # tile_ptr[t, tau] = global offset of first posting of term t with
    # docid >= tau * tile_size. searchsorted per term, vectorized over tiles.
    tile_ptr = np.zeros((n_terms, n_tiles + 1), dtype=np.int32)
    bounds = np.arange(n_tiles + 1, dtype=np.int64) * tile_size
    tile_of = (docids.astype(np.int64) // tile_size)
    term_of = np.repeat(np.arange(n_terms, dtype=np.int64), np.diff(indptr))
    # counts[t, tau] = postings of term t in tile tau
    flat = term_of * n_tiles + tile_of
    cnt = np.bincount(flat, minlength=n_terms * n_tiles).reshape(n_terms, n_tiles)
    tile_ptr[:, 1:] = np.cumsum(cnt, axis=1, dtype=np.int64).astype(np.int32)
    tile_ptr += indptr[:n_terms, None].astype(np.int32)
    del bounds

    # per-(term, tile) maxima via max-scatter
    tm_b = np.zeros((n_terms, n_tiles), dtype=np.float32)
    tm_l = np.zeros((n_terms, n_tiles), dtype=np.float32)
    np.maximum.at(tm_b.reshape(-1), flat, w_b_arr)
    np.maximum.at(tm_l.reshape(-1), flat, w_l_arr)

    run_max = int(cnt.max()) if cnt.size else 0
    pad_len = max(pad_multiple, -(-run_max // pad_multiple) * pad_multiple)
    if pad_cap is not None:
        pad_len = min(pad_len, pad_cap)
        if run_max > pad_len:
            raise ValueError(f"pad_cap {pad_cap} < max run {run_max}")

    sigma_b = np.zeros(n_terms, dtype=np.float32)
    sigma_l = np.zeros(n_terms, dtype=np.float32)
    np.maximum.at(sigma_b, term_of, w_b_arr)
    np.maximum.at(sigma_l, term_of, w_l_arr)

    return dict(
        n_docs=n_docs, n_terms=n_terms, tile_size=tile_size, n_tiles=n_tiles,
        pad_len=pad_len, docids=docids.astype(np.int32), w_b=w_b_arr,
        w_l=w_l_arr, tile_ptr=tile_ptr, cnt=cnt, tile_max_b=tm_b,
        tile_max_l=tm_l, sigma_b=sigma_b, sigma_l=sigma_l,
        orig_of_new=orig_of_new)


def build_index(merged: MergedPostings, tile_size: int = 2048,
                pad_multiple: int = 8, pad_cap: int | None = None,
                doc_order: np.ndarray | None = None) -> BlockedImpactIndex:
    """Build the BII from merged postings (host-side numpy).

    ``doc_order`` (optional): permutation; new docid i <- original
    doc_order[i]. Results are mapped back via ``index.to_orig``.
    """
    lay = blocked_layout(merged, tile_size, pad_multiple, pad_cap, doc_order)
    return BlockedImpactIndex(
        n_docs=lay["n_docs"], n_terms=lay["n_terms"], tile_size=tile_size,
        n_tiles=lay["n_tiles"], pad_len=lay["pad_len"],
        docids=jnp.asarray(lay["docids"], dtype=jnp.int32),
        w_b=jnp.asarray(lay["w_b"]), w_l=jnp.asarray(lay["w_l"]),
        tile_ptr=jnp.asarray(lay["tile_ptr"]),
        tile_max_b=jnp.asarray(lay["tile_max_b"]),
        tile_max_l=jnp.asarray(lay["tile_max_l"]),
        sigma_b=jnp.asarray(lay["sigma_b"]),
        sigma_l=jnp.asarray(lay["sigma_l"]),
        orig_of_new=lay["orig_of_new"])


@partial(jax.jit, static_argnames=("pad_len", "tile_size"))
def gather_tile(docids: jax.Array, w_b: jax.Array, w_l: jax.Array,
                tile_ptr: jax.Array, q_terms: jax.Array, tile: jax.Array,
                qw_b: jax.Array | None = None, qw_l: jax.Array | None = None,
                *, pad_len: int, tile_size: int):
    """Fetch padded posting runs of query terms inside one tile.

    Returns (offs [Nq, P] int32 local doc offsets, -1 where padded;
             wb, wl [Nq, P] f32 zero-padded). ``qw_b``/``qw_l`` (optional,
    [Nq]) scale each term's posting weights by the query weight — the
    executors' query-weighted gather; omitted = raw index weights. This
    is the single gather implementation shared by every traversal mode.
    """
    start = tile_ptr[q_terms, tile]            # [Nq]
    cnt = tile_ptr[q_terms, tile + 1] - start  # [Nq]
    idx = start[:, None] + jnp.arange(pad_len, dtype=jnp.int32)[None, :]
    mask = jnp.arange(pad_len, dtype=jnp.int32)[None, :] < cnt[:, None]
    idx = jnp.where(mask, idx, 0)
    d = jnp.take(docids, idx, mode="clip")
    offs = jnp.where(mask, d - tile * tile_size, -1).astype(jnp.int32)
    wb = jnp.where(mask, jnp.take(w_b, idx, mode="clip"), 0.0)
    wl = jnp.where(mask, jnp.take(w_l, idx, mode="clip"), 0.0)
    if qw_b is not None:
        wb = wb * qw_b[:, None]
    if qw_l is not None:
        wl = wl * qw_l[:, None]
    return offs, wb, wl


def dispatch_gather(kind: str, gt: tuple, q_terms: jax.Array,
                    tile: jax.Array, qw_b: jax.Array | None = None,
                    qw_l: jax.Array | None = None, *, pad_len: int,
                    tile_size: int):
    """Kind-polymorphic tile gather.

    ``kind`` is the index's static ``gather_kind`` ("fp32" | "q8") and
    ``gt`` its ``gather_arrays()`` tuple. Both index types decode to the
    same (offs, wb, wl) padded-run contract, so every executor above
    this call is codec-agnostic. Called inside jit with ``kind`` static.
    """
    if kind == "fp32":
        docids, w_b, w_l, tile_ptr = gt
        return gather_tile(docids, w_b, w_l, tile_ptr, q_terms, tile,
                           qw_b, qw_l, pad_len=pad_len, tile_size=tile_size)
    if kind == "q8":
        from ..index.compressed import gather_tile_q
        return gather_tile_q(gt, q_terms, tile, qw_b, qw_l,
                             pad_len=pad_len, tile_size=tile_size)
    raise ValueError(f"unknown gather kind: {kind!r}")
