"""2GTI parameterization (paper Section 4.1) and method presets.

Three hybrid scores per document, accumulated incrementally:

    Global(d)    = alpha * S_B(d) + (1-alpha) * S_L(d)   -- drives global pruning
    Local(d)     = beta  * S_B(d) + (1-beta)  * S_L(d)   -- drives local pruning
    RankScore(d) = gamma * S_B(d) + (1-gamma) * S_L(d)   -- final ranking

with three independent top-k queues / dynamic thresholds. Special cases:
GTI  = 2GTI(alpha=beta=1);  GT = GTI with gamma=0;
plain MaxScore on learned weights = 2GTI(alpha=beta=gamma=0).
``threshold_factor`` multiplies theta_Gl/theta_Lo at pruning time only
(>1 = rank-unsafe over-estimation, <1 = under-estimation; Table 3 / Fig. 3).
``bound_mode``: 'list' uses list-level maxima for term partitioning and local
bounds (paper MaxScore); 'tile' uses tile-level (block-max) maxima — the
Appendix-B/BMW-style tightening, our TPU-native default for the optimized
configuration.

Retrieval depth ``k`` is a *query-time* quantity, not a pruning policy:
it lives in the request path (``repro.retrieval.SearchRequest.k`` or the
``k=`` argument of the retrieve entry points). ``TwoLevelParams`` still
accepts ``k=`` as a deprecation shim — the value is stashed outside the
dataclass fields (it does not participate in equality/hash) and is used
as a fallback by ``resolve_k`` when a call site passes no depth.
"""
from __future__ import annotations

import dataclasses
import warnings

BOUND_MODES = ("list", "tile")
SCHEDULES = ("docid", "impact")

# Fallback retrieval depth when neither the call site nor a legacy
# TwoLevelParams(k=...) stash provides one.
DEFAULT_K = 10


def _warn_k_deprecated() -> None:
    warnings.warn(
        "TwoLevelParams.k is deprecated: retrieval depth is a query-time "
        "argument now. Pass k per call (Retriever.search(..., k=...) / "
        "SearchRequest.k / retrieve_*(..., k=...)).",
        DeprecationWarning, stacklevel=3)


@dataclasses.dataclass(frozen=True, init=False)
class TwoLevelParams:
    alpha: float = 1.0
    beta: float = 0.3
    gamma: float = 0.05
    threshold_factor: float = 1.0
    bound_mode: str = "list"
    # Tile visitation order. 'docid' mirrors DAAT (paper-faithful);
    # 'impact' visits tiles in descending global upper bound — thresholds
    # tighten fastest and traversal can stop at the first bound-failing
    # tile (beyond-paper, score-at-a-time flavored; still bound-safe).
    schedule: str = "docid"
    # Tiles per dispatch chunk for the ``traversal="chunked"`` executors:
    # the descending-bound tile order is folded into static groups of this
    # size and the chunk loop exits at the first bound-failing chunk
    # (Block-Max-Pruning structure). Only read by chunked traversal.
    chunk_tiles: int = 8

    # ``k`` keeps its historical positional slot so pre-deprecation call
    # sites (including positional ones) stay bit-compatible.
    def __init__(self, alpha: float = 1.0, beta: float = 0.3,
                 gamma: float = 0.05, k: int | None = None,
                 threshold_factor: float = 1.0, bound_mode: str = "list",
                 schedule: str = "docid", chunk_tiles: int = 8):
        object.__setattr__(self, "alpha", alpha)
        object.__setattr__(self, "beta", beta)
        object.__setattr__(self, "gamma", gamma)
        object.__setattr__(self, "threshold_factor", threshold_factor)
        object.__setattr__(self, "bound_mode", bound_mode)
        object.__setattr__(self, "schedule", schedule)
        object.__setattr__(self, "chunk_tiles", chunk_tiles)
        if k is not None:
            _warn_k_deprecated()
            k = int(k)
        object.__setattr__(self, "_legacy_k", k)
        self.__post_init__()

    def __post_init__(self):
        if self.bound_mode not in BOUND_MODES:
            raise ValueError(f"bound_mode must be in {BOUND_MODES}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be in {SCHEDULES}")
        if self.chunk_tiles < 1:
            raise ValueError(f"chunk_tiles={self.chunk_tiles} must be >= 1")
        for name in ("alpha", "beta", "gamma"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} outside [0, 1]")

    @property
    def k(self) -> int:
        """Deprecated fallback depth: the legacy stash, else DEFAULT_K."""
        lk = getattr(self, "_legacy_k", None)
        return lk if lk is not None else DEFAULT_K

    def replace(self, **kw) -> "TwoLevelParams":
        if "k" in kw:
            k = kw.pop("k")
            if k is not None:
                _warn_k_deprecated()
                k = int(k)
        else:
            k = getattr(self, "_legacy_k", None)
        new = dataclasses.replace(self, **kw)
        object.__setattr__(new, "_legacy_k", k)
        return new


def resolve_k(params: TwoLevelParams | None, k: int | None = None) -> int:
    """Retrieval depth for one call: explicit ``k`` > legacy params stash
    > DEFAULT_K. The single place the deprecation shim is consulted."""
    if k is not None:
        return int(k)
    lk = getattr(params, "_legacy_k", None) if params is not None else None
    return int(lk) if lk is not None else DEFAULT_K


def original(k: int | None = None, gamma: float = 0.0, **kw) -> TwoLevelParams:
    """Plain MaxScore on the gamma-combined score (alpha=beta=gamma)."""
    return TwoLevelParams(alpha=gamma, beta=gamma, gamma=gamma, k=k, **kw)


def gt(k: int | None = None, **kw) -> TwoLevelParams:
    """GT: BM25-guided pruning, learned-only final ranking."""
    return TwoLevelParams(alpha=1.0, beta=1.0, gamma=0.0, k=k, **kw)


def gti(k: int | None = None, gamma: float = 0.05, **kw) -> TwoLevelParams:
    """GTI: BM25-guided pruning, interpolated final ranking."""
    return TwoLevelParams(alpha=1.0, beta=1.0, gamma=gamma, k=k, **kw)


def accurate(k: int | None = None, gamma: float = 0.05, **kw) -> TwoLevelParams:
    """2GTI-Accurate: beta=0 (learned-only local pruning)."""
    return TwoLevelParams(alpha=1.0, beta=0.0, gamma=gamma, k=k, **kw)


def fast(k: int | None = None, beta: float = 0.3, gamma: float = 0.05,
         **kw) -> TwoLevelParams:
    """2GTI-Fast: small-but-nonzero beta."""
    return TwoLevelParams(alpha=1.0, beta=beta, gamma=gamma, k=k, **kw)


def linear_combination(k: int | None = None, gamma: float = 0.05,
                       **kw) -> TwoLevelParams:
    """Rank-safe MaxScore over the linear combination (alpha=beta=gamma=g)."""
    return TwoLevelParams(alpha=gamma, beta=gamma, gamma=gamma, k=k, **kw)
