"""2GTI parameterization (paper Section 4.1) and method presets.

Three hybrid scores per document, accumulated incrementally:

    Global(d)    = alpha * S_B(d) + (1-alpha) * S_L(d)   -- drives global pruning
    Local(d)     = beta  * S_B(d) + (1-beta)  * S_L(d)   -- drives local pruning
    RankScore(d) = gamma * S_B(d) + (1-gamma) * S_L(d)   -- final ranking

with three independent top-k queues / dynamic thresholds. Special cases:
GTI  = 2GTI(alpha=beta=1);  GT = GTI with gamma=0;
plain MaxScore on learned weights = 2GTI(alpha=beta=gamma=0).
``threshold_factor`` multiplies theta_Gl/theta_Lo at pruning time only
(>1 = rank-unsafe over-estimation, <1 = under-estimation; Table 3 / Fig. 3).
``bound_mode``: 'list' uses list-level maxima for term partitioning and local
bounds (paper MaxScore); 'tile' uses tile-level (block-max) maxima — the
Appendix-B/BMW-style tightening, our TPU-native default for the optimized
configuration.
"""
from __future__ import annotations

import dataclasses

BOUND_MODES = ("list", "tile")
SCHEDULES = ("docid", "impact")


@dataclasses.dataclass(frozen=True)
class TwoLevelParams:
    alpha: float = 1.0
    beta: float = 0.3
    gamma: float = 0.05
    k: int = 10
    threshold_factor: float = 1.0
    bound_mode: str = "list"
    # Tile visitation order. 'docid' mirrors DAAT (paper-faithful);
    # 'impact' visits tiles in descending global upper bound — thresholds
    # tighten fastest and traversal can stop at the first bound-failing
    # tile (beyond-paper, score-at-a-time flavored; still bound-safe).
    schedule: str = "docid"

    def __post_init__(self):
        if self.bound_mode not in BOUND_MODES:
            raise ValueError(f"bound_mode must be in {BOUND_MODES}")
        if self.schedule not in SCHEDULES:
            raise ValueError(f"schedule must be in {SCHEDULES}")
        for name in ("alpha", "beta", "gamma"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name}={v} outside [0, 1]")

    def replace(self, **kw) -> "TwoLevelParams":
        return dataclasses.replace(self, **kw)


def original(k: int = 10, gamma: float = 0.0, **kw) -> TwoLevelParams:
    """Plain MaxScore on the gamma-combined score (alpha=beta=gamma)."""
    return TwoLevelParams(alpha=gamma, beta=gamma, gamma=gamma, k=k, **kw)


def gt(k: int = 10, **kw) -> TwoLevelParams:
    """GT: BM25-guided pruning, learned-only final ranking."""
    return TwoLevelParams(alpha=1.0, beta=1.0, gamma=0.0, k=k, **kw)


def gti(k: int = 10, gamma: float = 0.05, **kw) -> TwoLevelParams:
    """GTI: BM25-guided pruning, interpolated final ranking."""
    return TwoLevelParams(alpha=1.0, beta=1.0, gamma=gamma, k=k, **kw)


def accurate(k: int = 10, gamma: float = 0.05, **kw) -> TwoLevelParams:
    """2GTI-Accurate: beta=0 (learned-only local pruning)."""
    return TwoLevelParams(alpha=1.0, beta=0.0, gamma=gamma, k=k, **kw)


def fast(k: int = 10, beta: float = 0.3, gamma: float = 0.05, **kw) -> TwoLevelParams:
    """2GTI-Fast: small-but-nonzero beta."""
    return TwoLevelParams(alpha=1.0, beta=beta, gamma=gamma, k=k, **kw)


def linear_combination(k: int = 10, gamma: float = 0.05, **kw) -> TwoLevelParams:
    """Rank-safe MaxScore over the linear combination (alpha=beta=gamma=g)."""
    return TwoLevelParams(alpha=gamma, beta=gamma, gamma=gamma, k=k, **kw)
