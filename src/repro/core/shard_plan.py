"""Tile-range sharding of the BlockedImpactIndex for mesh-parallel retrieval.

The docid space is partitioned into ``n_shards`` *contiguous tile ranges*
(tiles are already independent scan units, so a range of them is a fully
self-contained mini-index). For each shard the host build re-packs the
term-major posting runs that fall inside its range, rebases docids to the
shard-local space (docid - shard_start_tile * tile_size) and rebases
``tile_ptr`` into the shard's flat arrays. All shards are padded to one
static shape — ``tiles_per_shard`` tiles, ``max_nnz`` postings — and
stacked on a leading shard axis, so the stack maps directly onto a mesh
axis via ``shard_map`` (or a ``vmap`` emulation on one device).

List-level maxima (``sigma_b``/``sigma_l``) stay *global* and replicated:
every shard must sort query terms in the same order or the MaxScore
partition — and therefore results — would diverge between shard counts.

Padded tiles (when ``n_shards`` does not divide ``n_tiles``) carry zero
postings and zero block maxima; they survive nothing and contribute only
NEG_INF candidates, which lose stable-tie merges against real entries.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .index import BlockedImpactIndex


@dataclasses.dataclass
class ShardedImpactIndex:
    """Stacked per-shard view of a BlockedImpactIndex (leading dim = shard)."""
    n_shards: int
    n_docs: int
    n_terms: int
    tile_size: int
    n_tiles: int            # real (unpadded) global tile count
    tiles_per_shard: int    # padded: n_shards * tiles_per_shard >= n_tiles
    pad_len: int
    doc_base: jax.Array     # [n_shards] int32 first internal docid per shard
    n_real_tiles: jax.Array  # [n_shards] int32 real tiles (rest is padding)
    nnz_per_shard: np.ndarray
    docids: jax.Array       # [n_shards, max_nnz] int32 shard-local docids
    w_b: jax.Array          # [n_shards, max_nnz] f32
    w_l: jax.Array          # [n_shards, max_nnz] f32
    tile_ptr: jax.Array     # [n_shards, n_terms, tiles_per_shard + 1] int32
    tile_max_b: jax.Array   # [n_shards, n_terms, tiles_per_shard] f32
    tile_max_l: jax.Array   # [n_shards, n_terms, tiles_per_shard] f32
    sigma_b: jax.Array      # [n_terms] f32 — global, replicated
    sigma_l: jax.Array      # [n_terms] f32 — global, replicated
    orig_of_new: np.ndarray | None = None

    def to_orig(self, ids: np.ndarray) -> np.ndarray:
        """Map internal docids back to original ids (-1 passes through)."""
        ids = np.asarray(ids)
        if self.orig_of_new is None:
            return ids
        safe = np.clip(ids, 0, self.n_docs - 1)
        return np.where(ids < 0, ids, self.orig_of_new[safe]).astype(ids.dtype)


def shard_index(index: BlockedImpactIndex, n_shards: int) -> ShardedImpactIndex:
    """Partition ``index`` into ``n_shards`` contiguous tile ranges.

    Host-side numpy re-pack; shards are padded to a common static shape so
    the result stacks on a leading shard axis.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    n_terms, n_tiles = index.n_terms, index.n_tiles
    tile_size = index.tile_size
    tps = -(-n_tiles // n_shards)  # ceil: padded tiles per shard

    h_ptr = np.asarray(index.tile_ptr)
    h_docids = np.asarray(index.docids)
    h_wb = np.asarray(index.w_b)
    h_wl = np.asarray(index.w_l)
    h_tmb = np.asarray(index.tile_max_b)
    h_tml = np.asarray(index.tile_max_l)

    per_shard = []
    nnz = np.zeros(n_shards, dtype=np.int64)
    for s in range(n_shards):
        t0 = min(s * tps, n_tiles)
        t1 = min((s + 1) * tps, n_tiles)
        starts = h_ptr[:, t0].astype(np.int64)
        ends = h_ptr[:, t1].astype(np.int64)
        lens = ends - starts
        total = int(lens.sum())
        out_starts = np.zeros(n_terms + 1, dtype=np.int64)
        np.cumsum(lens, out=out_starts[1:])
        # gather each term's run for this tile range into one flat slab
        flat = (np.arange(total, dtype=np.int64)
                - np.repeat(out_starts[:-1], lens) + np.repeat(starts, lens))
        local_doc = h_docids[flat].astype(np.int64) - t0 * tile_size
        # rebase tile_ptr into the slab; pad tiles repeat the last offset
        lp = np.empty((n_terms, tps + 1), dtype=np.int32)
        real = t1 - t0
        lp[:, :real + 1] = (h_ptr[:, t0:t1 + 1].astype(np.int64)
                            - starts[:, None] + out_starts[:-1, None]
                            ).astype(np.int32)
        lp[:, real + 1:] = lp[:, real:real + 1]
        tmb = np.zeros((n_terms, tps), dtype=np.float32)
        tml = np.zeros((n_terms, tps), dtype=np.float32)
        tmb[:, :real] = h_tmb[:, t0:t1]
        tml[:, :real] = h_tml[:, t0:t1]
        nnz[s] = total
        per_shard.append((local_doc.astype(np.int32), h_wb[flat], h_wl[flat],
                          lp, tmb, tml, t0 * tile_size))

    max_nnz = max(1, int(nnz.max()))

    def pad_flat(a, fill):
        out = np.full(max_nnz, fill, dtype=a.dtype)
        out[:len(a)] = a
        return out

    docids = np.stack([pad_flat(p[0], 0) for p in per_shard])
    w_b = np.stack([pad_flat(p[1], 0.0) for p in per_shard])
    w_l = np.stack([pad_flat(p[2], 0.0) for p in per_shard])
    tile_ptr = np.stack([p[3] for p in per_shard])
    tile_max_b = np.stack([p[4] for p in per_shard])
    tile_max_l = np.stack([p[5] for p in per_shard])
    doc_base = np.array([p[6] for p in per_shard], dtype=np.int32)
    n_real = np.clip(n_tiles - tps * np.arange(n_shards), 0, tps
                     ).astype(np.int32)

    return ShardedImpactIndex(
        n_shards=n_shards, n_docs=index.n_docs, n_terms=n_terms,
        tile_size=tile_size, n_tiles=n_tiles, tiles_per_shard=tps,
        pad_len=index.pad_len,
        doc_base=jnp.asarray(doc_base), n_real_tiles=jnp.asarray(n_real),
        nnz_per_shard=nnz,
        docids=jnp.asarray(docids), w_b=jnp.asarray(w_b),
        w_l=jnp.asarray(w_l), tile_ptr=jnp.asarray(tile_ptr),
        tile_max_b=jnp.asarray(tile_max_b), tile_max_l=jnp.asarray(tile_max_l),
        sigma_b=index.sigma_b, sigma_l=index.sigma_l,
        orig_of_new=index.orig_of_new)
