"""Tile-range sharding of the BlockedImpactIndex for mesh-parallel retrieval.

The docid space is partitioned into ``n_shards`` *contiguous tile ranges*
(tiles are already independent scan units, so a range of them is a fully
self-contained mini-index). For each shard the host build re-packs the
term-major posting runs that fall inside its range, rebases docids to the
shard-local space (docid - shard_start_tile * tile_size) and rebases
``tile_ptr`` into the shard's flat arrays. All shards are padded to one
static shape — ``tiles_per_shard`` tiles, ``max_nnz`` postings — and
stacked on a leading shard axis, so the stack maps directly onto a mesh
axis via ``shard_map`` (or a ``vmap`` emulation on one device).

Both index kinds shard: the fp32 ``BlockedImpactIndex`` and the
``repro.index.CompressedImpactIndex``. The posting payload is carried as
the index's ``gather_arrays()`` tuple with every leaf stacked on the
shard axis (``gather_kind`` tags the layout). Compressed runs need no
value rebase — delta gaps and the per-run first offset are tile-local,
so sharding only re-bases the two CSR pointer grids (``tile_ptr`` at
posting granularity, ``pack_ptr`` at word granularity; runs are
word-aligned, so word spans concatenate without re-packing) and slices
the per-(term, tile) metadata columns.

List-level maxima (``sigma_b``/``sigma_l``) stay *global* and replicated:
every shard must sort query terms in the same order or the MaxScore
partition — and therefore results — would diverge between shard counts.

Padded tiles (when ``n_shards`` does not divide ``n_tiles``) carry zero
postings and zero block maxima; they survive nothing and contribute only
NEG_INF candidates, which lose stable-tie merges against real entries.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .index import BlockedImpactIndex


@dataclasses.dataclass
class ShardedImpactIndex:
    """Stacked per-shard view of a blocked index (leading dim = shard)."""
    n_shards: int
    n_docs: int
    n_terms: int
    tile_size: int
    n_tiles: int            # real (unpadded) global tile count
    tiles_per_shard: int    # padded: n_shards * tiles_per_shard >= n_tiles
    pad_len: int
    doc_base: jax.Array     # [n_shards] int32 first internal docid per shard
    n_real_tiles: jax.Array  # [n_shards] int32 real tiles (rest is padding)
    nnz_per_shard: np.ndarray
    # posting payload: the source index's gather_arrays() tuple, every
    # leaf stacked on a leading shard axis and padded to a common shape
    gather: tuple
    gather_kind: str        # "fp32" | "q8" (static; threaded through jit)
    tile_max_b: jax.Array   # [n_shards, n_terms, tiles_per_shard] f32
    tile_max_l: jax.Array   # [n_shards, n_terms, tiles_per_shard] f32
    sigma_b: jax.Array      # [n_terms] f32 — global, replicated
    sigma_l: jax.Array      # [n_terms] f32 — global, replicated
    orig_of_new: np.ndarray | None = None

    def _fp32_leaf(self, i: int) -> jax.Array:
        if self.gather_kind != "fp32":
            raise AttributeError(
                "flat fp32 posting views are only defined for "
                f"gather_kind='fp32' (this index is {self.gather_kind!r})")
        return self.gather[i]

    # fp32 back-compat views (pre-gather-tuple field names)
    @property
    def docids(self) -> jax.Array:
        return self._fp32_leaf(0)

    @property
    def w_b(self) -> jax.Array:
        return self._fp32_leaf(1)

    @property
    def w_l(self) -> jax.Array:
        return self._fp32_leaf(2)

    @property
    def tile_ptr(self) -> jax.Array:
        return self.gather[3]  # same slot in both layouts

    def to_orig(self, ids: np.ndarray) -> np.ndarray:
        """Map internal docids back to original ids (-1 passes through)."""
        ids = np.asarray(ids)
        if self.orig_of_new is None:
            return ids
        safe = np.clip(ids, 0, self.n_docs - 1)
        return np.where(ids < 0, ids, self.orig_of_new[safe]).astype(ids.dtype)


def _csr_shard_gather(h_ptr: np.ndarray, t0: int, t1: int):
    """Span bookkeeping for one shard of a [n_terms, n_tiles+1] CSR grid.

    Returns (flat gather index into the flat payload, rebased local CSR
    of shape [n_terms, t1-t0+1], per-term span lengths)."""
    starts = h_ptr[:, t0].astype(np.int64)
    ends = h_ptr[:, t1].astype(np.int64)
    lens = ends - starts
    total = int(lens.sum())
    out_starts = np.zeros(len(lens) + 1, dtype=np.int64)
    np.cumsum(lens, out=out_starts[1:])
    flat = (np.arange(total, dtype=np.int64)
            - np.repeat(out_starts[:-1], lens) + np.repeat(starts, lens))
    local = (h_ptr[:, t0:t1 + 1].astype(np.int64)
             - starts[:, None] + out_starts[:-1, None]).astype(np.int32)
    return flat, local, out_starts


def _pad_cols(a: np.ndarray, tps: int) -> np.ndarray:
    """Zero-pad a sliced [n_terms, real] metadata grid to tps columns."""
    if a.shape[1] == tps:
        return a
    out = np.zeros((a.shape[0], tps), dtype=a.dtype)
    out[:, :a.shape[1]] = a
    return out


def _pad_ptr(lp: np.ndarray, tps: int) -> np.ndarray:
    """Pad a rebased local CSR to tps+1 cols, repeating the last offset."""
    n_terms, cols = lp.shape
    out = np.empty((n_terms, tps + 1), dtype=np.int32)
    out[:, :cols] = lp
    out[:, cols:] = lp[:, -1:]
    return out


def _pad_flat(a: np.ndarray, n: int, fill=0) -> np.ndarray:
    out = np.full(n, fill, dtype=a.dtype)
    out[:len(a)] = a
    return out


def shard_index(index, n_shards: int) -> ShardedImpactIndex:
    """Partition ``index`` (fp32 or compressed) into ``n_shards``
    contiguous tile ranges.

    Host-side numpy re-pack; shards are padded to a common static shape so
    the result stacks on a leading shard axis.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    kind = index.gather_kind
    n_terms, n_tiles = index.n_terms, index.n_tiles
    tile_size = index.tile_size
    tps = -(-n_tiles // n_shards)  # ceil: padded tiles per shard

    h_ptr = np.asarray(index.tile_ptr)
    h_tmb = np.asarray(index.tile_max_b)
    h_tml = np.asarray(index.tile_max_l)
    if kind == "fp32":
        h_docids = np.asarray(index.docids)
        h_wb = np.asarray(index.w_b)
        h_wl = np.asarray(index.w_l)
    elif kind == "q8":
        h_packed = np.asarray(index.packed)
        h_qb = np.asarray(index.qb)
        h_ql = np.asarray(index.ql)
        h_pptr = np.asarray(index.pack_ptr)
        h_grids = {n: np.asarray(getattr(index, n)) for n in
                   ("width", "first", "scale_b", "zero_b",
                    "scale_l", "zero_l")}
    else:
        raise ValueError(f"unknown gather kind: {kind!r}")

    shard_gather = []   # per-shard gather tuples (numpy)
    tmb_l, tml_l, base_l = [], [], []
    nnz = np.zeros(n_shards, dtype=np.int64)
    for s in range(n_shards):
        t0 = min(s * tps, n_tiles)
        t1 = min((s + 1) * tps, n_tiles)
        flat, lp_real, _ = _csr_shard_gather(h_ptr, t0, t1)
        lp = _pad_ptr(lp_real, tps)
        nnz[s] = len(flat)
        if kind == "fp32":
            local_doc = (h_docids[flat].astype(np.int64)
                         - t0 * tile_size).astype(np.int32)
            shard_gather.append((local_doc, h_wb[flat], h_wl[flat], lp))
        else:
            wflat, lpw_real, _ = _csr_shard_gather(h_pptr, t0, t1)
            lpw = _pad_ptr(lpw_real, tps)
            shard_gather.append((
                h_packed[wflat], h_qb[flat], h_ql[flat], lp, lpw,
                *(_pad_cols(g[:, t0:t1], tps) for g in
                  (h_grids["width"], h_grids["first"], h_grids["scale_b"],
                   h_grids["zero_b"], h_grids["scale_l"],
                   h_grids["zero_l"]))))
        tmb = np.zeros((n_terms, tps), dtype=np.float32)
        tml = np.zeros((n_terms, tps), dtype=np.float32)
        tmb[:, :t1 - t0] = h_tmb[:, t0:t1]
        tml[:, :t1 - t0] = h_tml[:, t0:t1]
        tmb_l.append(tmb)
        tml_l.append(tml)
        base_l.append(t0 * tile_size)

    # pad every shard's flat leaves (postings, and words for q8) to the
    # max length, then stack each gather slot on the shard axis
    n_leaves = len(shard_gather[0])
    flat_slots = (0, 1, 2) if kind == "fp32" else (0, 1, 2)
    gather = []
    for i in range(n_leaves):
        leaves = [sg[i] for sg in shard_gather]
        if i in flat_slots:
            m = max(1, max(len(a) for a in leaves))
            leaves = [_pad_flat(a, m) for a in leaves]
        gather.append(jnp.asarray(np.stack(leaves)))

    n_real = np.clip(n_tiles - tps * np.arange(n_shards), 0, tps
                     ).astype(np.int32)

    return ShardedImpactIndex(
        n_shards=n_shards, n_docs=index.n_docs, n_terms=n_terms,
        tile_size=tile_size, n_tiles=n_tiles, tiles_per_shard=tps,
        pad_len=index.pad_len,
        doc_base=jnp.asarray(np.array(base_l, dtype=np.int32)),
        n_real_tiles=jnp.asarray(n_real), nnz_per_shard=nnz,
        gather=tuple(gather), gather_kind=kind,
        tile_max_b=jnp.asarray(np.stack(tmb_l)),
        tile_max_l=jnp.asarray(np.stack(tml_l)),
        sigma_b=index.sigma_b, sigma_l=index.sigma_l,
        orig_of_new=index.orig_of_new)
