"""Index alignment: merge BM25 and learned postings (paper Section 4.3).

The merged index carries, per posting, both a BM25 weight ``w_b`` and a
learned weight ``w_l``. Where a (term, doc) pair exists in only one model the
other weight is *filled*:

- learned weight missing  -> always 0 (no smoothing proposed in the paper),
- BM25 weight missing     -> ``zero`` | ``one`` | ``scaled`` filling.

``scaled`` filling (the paper's default for 2GTI) replaces the missing BM25
weight with ``mean(w_B over P_B) / mean(w_L over P_L) * w_L(t, d)``.
``one`` filling uses the BM25 weight the pair would have had with tf = 1,
which needs corpus stats (doc lens + idf).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .bm25 import Bm25Stats, one_fill_weight
from .sparse import SparseModel

FILL_METHODS = ("zero", "one", "scaled")


@dataclasses.dataclass
class MergedPostings:
    """Union of learned + BM25 postings, term-major CSR, dual weights."""

    n_docs: int
    n_terms: int
    indptr: np.ndarray  # [n_terms + 1] int64
    docids: np.ndarray  # [nnz] int32 sorted within term
    w_b: np.ndarray     # [nnz] float32 (aligned BM25 weight)
    w_l: np.ndarray     # [nnz] float32 (learned weight, 0 if BM25-only)

    @property
    def nnz(self) -> int:
        return int(self.docids.shape[0])

    def postings(self, term: int):
        s, e = self.indptr[term], self.indptr[term + 1]
        return self.docids[s:e], self.w_b[s:e], self.w_l[s:e]


def scaled_fill_ratio(bm25: SparseModel, learned: SparseModel) -> float:
    """mean nonzero BM25 weight / mean nonzero learned weight."""
    mb = float(bm25.weights[bm25.weights > 0].mean()) if bm25.nnz else 0.0
    ml = float(learned.weights[learned.weights > 0].mean()) if learned.nnz else 1.0
    return mb / max(ml, 1e-12)


def merge_models(learned: SparseModel, bm25: SparseModel, fill: str = "scaled",
                 bm25_stats: Bm25Stats | None = None) -> MergedPostings:
    """Merge per-term posting lists of both models with BM25-side filling."""
    if fill not in FILL_METHODS:
        raise ValueError(f"fill must be one of {FILL_METHODS}, got {fill!r}")
    assert learned.n_docs == bm25.n_docs and learned.n_terms == bm25.n_terms
    n_docs, n_terms = learned.n_docs, learned.n_terms
    ratio = scaled_fill_ratio(bm25, learned) if fill == "scaled" else 0.0

    # Vectorized union via global (term, doc) keys from both models.
    rep_l = np.repeat(np.arange(n_terms, dtype=np.int64), np.diff(learned.indptr))
    rep_b = np.repeat(np.arange(n_terms, dtype=np.int64), np.diff(bm25.indptr))
    key_l = rep_l * n_docs + learned.docids
    key_b = rep_b * n_docs + bm25.docids
    keys = np.concatenate([key_l, key_b])
    order = np.argsort(keys, kind="stable")
    keys_s = keys[order]
    uniq_mask = np.concatenate([[True], np.diff(keys_s) != 0])
    uniq_keys = keys_s[uniq_mask]

    # Scatter weights of each side onto the union.
    pos_l = np.searchsorted(uniq_keys, key_l)
    pos_b = np.searchsorted(uniq_keys, key_b)
    w_l = np.zeros(len(uniq_keys), dtype=np.float32)
    w_b = np.zeros(len(uniq_keys), dtype=np.float32)
    w_l[pos_l] = learned.weights
    w_b[pos_b] = bm25.weights
    in_b = np.zeros(len(uniq_keys), dtype=bool)
    in_b[pos_b] = True

    docids = (uniq_keys % n_docs).astype(np.int32)
    terms = (uniq_keys // n_docs).astype(np.int64)
    missing = (~in_b) & (w_l > 0)
    if fill == "one":
        if bm25_stats is None:
            raise ValueError("one-filling needs bm25_stats (doc lens + idf)")
        fill_w = one_fill_weight(bm25_stats.doc_lens[docids[missing]],
                                 bm25_stats.idf[terms[missing]],
                                 bm25_stats.avg_len)
        w_b[missing] = fill_w
    elif fill == "scaled":
        w_b[missing] = ratio * w_l[missing]
    # zero fill: leave 0.

    counts = np.bincount(terms, minlength=n_terms)
    indptr = np.zeros(n_terms + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    return MergedPostings(n_docs, n_terms, indptr, docids, w_b, w_l)


def misalignment_fraction(learned: SparseModel, bm25: SparseModel) -> float:
    """Fraction of learned postings absent from the BM25 index.

    The paper reports 98.6% for SPLADE++ and 1.4% for uniCOIL vs BM25-T5-B.
    """
    rep_l = np.repeat(np.arange(learned.n_terms, dtype=np.int64),
                      np.diff(learned.indptr))
    rep_b = np.repeat(np.arange(bm25.n_terms, dtype=np.int64),
                      np.diff(bm25.indptr))
    key_l = rep_l * learned.n_docs + learned.docids
    key_b = rep_b * bm25.n_docs + bm25.docids
    present = np.isin(key_l, key_b)
    return float(1.0 - present.mean()) if len(key_l) else 0.0
