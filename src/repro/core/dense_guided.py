"""2GTI transferred to dense retrieval (two-tower ``retrieval_cand`` path).

The paper's structure — a cheap model guides two levels of pruning with
independent dynamic thresholds, while an expensive model ranks — maps onto
blocked dense candidate scoring:

- cheap model  = dot product over the first ``d_cheap`` dimensions
  (principal subspace; plays BM25's role),
- expensive model = full-dimension dot product (plays the learned model),
- Global level = per-block upper bound of the alpha-combined score from
  coordinate-wise block maxima/minima (block-max analogue) vs theta_Gl,
- Local level  = per-candidate cheap score + residual-dim bound (beta
  combination) vs theta_Lo; frozen candidates keep their partial
  (gamma-combined) rank score, which still competes in Q_Rk,
- blocks are visited in descending bound order (impact scheduling).

alpha = beta = gamma recovers exact blocked top-k (rank-safe).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .twolevel import TwoLevelParams, resolve_k

NEG = jnp.float32(-jnp.inf)


@dataclasses.dataclass
class DenseGuidedIndex:
    emb: jax.Array          # [N, D] rotated candidate embeddings
    block_size: int
    d_cheap: int
    n_blocks: int
    bmax: jax.Array         # [n_blocks, D] coordinate-wise block max
    bmin: jax.Array         # [n_blocks, D] coordinate-wise block min
    rotation: jax.Array     # [D, D] PCA basis (queries must be rotated too)

    def rotate_query(self, q: jax.Array) -> jax.Array:
        return q @ self.rotation


def build_dense_index(emb: jax.Array, block_size: int = 4096,
                      d_cheap: int = 32) -> DenseGuidedIndex:
    """PCA-rotate so the leading ``d_cheap`` dims carry the most energy —
    the dense analogue of the paper's index *alignment*: the cheap model
    must correlate with the expensive one for its guidance to be safe.
    Dot products are rotation-invariant, so exact scores are unchanged."""
    n, d = emb.shape
    cov = (emb.T @ emb) / n
    _, vecs = jnp.linalg.eigh(cov)           # ascending eigenvalues
    rot = vecs[:, ::-1]                       # descending: PCA basis
    emb = emb @ rot
    pad = (-n) % block_size
    if pad:
        emb = jnp.concatenate(
            [emb, jnp.zeros((pad, d), emb.dtype)], axis=0)
    nb = emb.shape[0] // block_size
    blocks = emb.reshape(nb, block_size, d)
    return DenseGuidedIndex(emb=emb, block_size=block_size, d_cheap=d_cheap,
                            n_blocks=nb, bmax=blocks.max(1),
                            bmin=blocks.min(1), rotation=rot)


def _bound(q, bmax, bmin):
    """Upper bound of q . x over a block, coordinate-wise."""
    return jnp.sum(jnp.maximum(q * bmax, q * bmin), axis=-1)


def _retrieve_one(emb, bmax, bmin, q, alpha, beta, gamma,
                  *, k, block_size, d_cheap, n_blocks):
    """One query's guided block scan (unjitted body — shared by the
    single-query entry and the vmapped batched lane)."""
    d = emb.shape[1]
    qc = q.at[d_cheap:].set(0.0)
    qr = q.at[:d_cheap].set(0.0)
    ub_cheap = _bound(qc, bmax, bmin)          # [nb] cheap-score bound
    ub_rest = _bound(qr, bmax, bmin)           # [nb] residual bound
    ub_full = ub_cheap + ub_rest
    ub_alpha = alpha * ub_cheap + (1 - alpha) * ub_full
    order = jnp.argsort(-ub_alpha).astype(jnp.int32)

    def step(carry, bi):
        (gv, gi, lv, li, rv, ri, scored) = carry
        th_gl, th_lo = gv[-1], lv[-1]
        skip = ub_alpha[bi] <= th_gl
        rows = jax.lax.dynamic_slice_in_dim(emb, bi * block_size, block_size)
        s_cheap = rows @ qc                    # [B] cheap scores
        # local level: freeze candidates whose beta-combined bound fails
        local_bound = (beta * s_cheap
                       + (1 - beta) * (s_cheap + ub_rest[bi]))
        alive = local_bound > th_lo
        s_rest = jnp.where(alive, rows @ qr, 0.0)
        s_full = s_cheap + s_rest
        g = alpha * s_cheap + (1 - alpha) * s_full
        l = beta * s_cheap + (1 - beta) * s_full
        r = gamma * s_cheap + (1 - gamma) * s_full   # partial if frozen
        ids = bi * block_size + jnp.arange(block_size, dtype=jnp.int32)

        def merge(qv, qi, vals, mask):
            vals = jnp.where(mask & ~skip, vals, NEG)
            nv = jnp.concatenate([qv, vals])
            ni = jnp.concatenate([qi, ids])
            tv, idx = jax.lax.top_k(nv, k)
            return tv, ni[idx]

        gv, gi = merge(gv, gi, g, alive)
        lv, li = merge(lv, li, l, alive)
        rv, ri = merge(rv, ri, r, jnp.ones_like(alive))
        scored = scored + jnp.where(skip, 0.0, alive.sum().astype(jnp.float32))
        return (gv, gi, lv, li, rv, ri, scored), None

    init = (jnp.full(k, NEG), jnp.full(k, -1, jnp.int32),
            jnp.full(k, NEG), jnp.full(k, -1, jnp.int32),
            jnp.full(k, NEG), jnp.full(k, -1, jnp.int32),
            jnp.float32(0.0))
    (gv, gi, lv, li, rv, ri, scored), _ = jax.lax.scan(step, init, order)
    return rv, ri, scored


@partial(jax.jit, static_argnames=("k", "block_size", "d_cheap", "n_blocks"))
def _retrieve(emb, bmax, bmin, q, alpha, beta, gamma,
              *, k, block_size, d_cheap, n_blocks):
    return _retrieve_one(emb, bmax, bmin, q, alpha, beta, gamma, k=k,
                         block_size=block_size, d_cheap=d_cheap,
                         n_blocks=n_blocks)


@partial(jax.jit, static_argnames=("k", "block_size", "d_cheap", "n_blocks"))
def _retrieve_dense_batched_impl(emb, bmax, bmin, q, alpha, beta, gamma,
                                 *, k, block_size, d_cheap, n_blocks):
    """[B, D] queries through the guided block scan in one jitted call
    (vmap over the per-query scan — each row keeps its own block order
    and thresholds, so results match the per-query path)."""
    return jax.vmap(
        lambda qi: _retrieve_one(emb, bmax, bmin, qi, alpha, beta, gamma,
                                 k=k, block_size=block_size,
                                 d_cheap=d_cheap, n_blocks=n_blocks))(q)


def retrieve_dense_batched(index: DenseGuidedIndex, q: jax.Array,
                           params: TwoLevelParams, k: int | None = None):
    """Batched guided dense retrieval: one jitted ``[B, D]`` call instead
    of a host-side per-query loop (the serving-load lane the ``dense``
    registry engine uses). Returns ``(scores [B, k], ids [B, k], stats)``
    with a per-query ``candidates_fully_scored`` array. Compiles once per
    (B, k) shape pair; rank-safe configs reduce to the batched exact
    ``[B, D] @ [N, D]^T`` top-k the blocks implement."""
    q = jnp.asarray(q, index.emb.dtype)
    if q.ndim != 2:
        raise ValueError(f"retrieve_dense_batched takes [B, D] queries, "
                         f"got shape {tuple(q.shape)}")
    rv, ri, scored = _retrieve_dense_batched_impl(
        index.emb, index.bmax, index.bmin, q @ index.rotation,
        jnp.float32(params.alpha), jnp.float32(params.beta),
        jnp.float32(params.gamma), k=resolve_k(params, k),
        block_size=index.block_size,
        d_cheap=index.d_cheap, n_blocks=index.n_blocks)
    stats = {"candidates_fully_scored": np.asarray(scored, np.float32),
             "n_candidates": float(index.emb.shape[0])}
    return np.asarray(rv), np.asarray(ri), stats


def retrieve_dense(index: DenseGuidedIndex, q: jax.Array,
                   params: TwoLevelParams, k: int | None = None):
    """Top-k candidates for one query. Returns (scores, ids, stats).
    ``k`` is the per-call retrieval depth (legacy ``params.k`` fallback)."""
    q = index.rotate_query(q.astype(index.emb.dtype))
    rv, ri, scored = _retrieve(
        index.emb, index.bmax, index.bmin, q,
        jnp.float32(params.alpha), jnp.float32(params.beta),
        jnp.float32(params.gamma), k=resolve_k(params, k),
        block_size=index.block_size,
        d_cheap=index.d_cheap, n_blocks=index.n_blocks)
    stats = {"candidates_fully_scored": float(scored),
             "n_candidates": index.emb.shape[0]}
    return np.asarray(rv), np.asarray(ri), stats


def exhaustive_dense(index: DenseGuidedIndex, q: jax.Array, k: int):
    s = index.emb @ index.rotate_query(q.astype(index.emb.dtype))
    vals, ids = jax.lax.top_k(s, k)
    return np.asarray(vals), np.asarray(ids)
