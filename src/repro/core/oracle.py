"""Sequential numpy DAAT 2GTI oracle — the paper's exact control flow.

Implements document-at-a-time MaxScore with two-level guided pruning and
per-document threshold updates (Section 4.1 verbatim): term partitioning via
the alpha-combined prefix, pivot selection from essential cursors, descending
local refinement against theta_Lo with the beta-combined bound, and the
three-queue discipline (locally-pruned docs still enter Q_Rk with partial
RankScore). Used to cross-validate the tile-scan engine; also provides the
exhaustive ranked lists R_x and the two-stage baseline R2_{alpha,gamma}.
"""
from __future__ import annotations

import heapq

import numpy as np

from .align import MergedPostings
from .twolevel import TwoLevelParams, resolve_k


class _TopK:
    """Min-heap top-k queue with (score, -docid) ordering (docid tiebreak)."""

    def __init__(self, k: int):
        self.k = k
        self.heap: list[tuple[float, int]] = []

    @property
    def threshold(self) -> float:
        return self.heap[0][0] if len(self.heap) >= self.k else -np.inf

    def push(self, score: float, docid: int) -> None:
        item = (score, -docid)
        if len(self.heap) < self.k:
            heapq.heappush(self.heap, item)
        elif item > self.heap[0]:
            heapq.heapreplace(self.heap, item)

    def sorted_desc(self) -> tuple[np.ndarray, np.ndarray]:
        items = sorted(self.heap, reverse=True)
        ids = np.array([-d for _, d in items], dtype=np.int32)
        vals = np.array([s for s, _ in items], dtype=np.float32)
        pad = self.k - len(items)
        if pad:
            ids = np.concatenate([ids, np.full(pad, -1, np.int32)])
            vals = np.concatenate([vals, np.full(pad, -np.inf, np.float32)])
        return ids, vals


def score_all_merged(merged: MergedPostings, q_terms, qw_b, qw_l, x: float
                     ) -> np.ndarray:
    """Exhaustive x-combined scores over all docs: R_x ranking source."""
    s = np.zeros(merged.n_docs, dtype=np.float64)
    for t, wb_q, wl_q in zip(q_terms, qw_b, qw_l):
        d, wb, wl = merged.postings(int(t))
        s[d] += x * wb_q * wb + (1.0 - x) * wl_q * wl
    return s.astype(np.float32)


def ranked_list(merged: MergedPostings, q_terms, qw_b, qw_l, x: float,
                k: int) -> tuple[np.ndarray, np.ndarray]:
    """Top-k of R_x with docid-asc tiebreak."""
    s = score_all_merged(merged, q_terms, qw_b, qw_l, x)
    order = np.lexsort((np.arange(len(s)), -s))[:k]
    return order.astype(np.int32), s[order]


def two_stage(merged: MergedPostings, q_terms, qw_b, qw_l, alpha: float,
              gamma: float, k: int) -> tuple[np.ndarray, np.ndarray]:
    """R2_{alpha,gamma}: fetch top-k of R_alpha, rerank by R_gamma scores."""
    ids, _ = ranked_list(merged, q_terms, qw_b, qw_l, alpha, k)
    s = score_all_merged(merged, q_terms, qw_b, qw_l, gamma)
    sub = s[ids]
    order = np.lexsort((ids, -sub))
    return ids[order], sub[order]


def daat_2gti(merged: MergedPostings, q_terms, qw_b, qw_l,
              params: TwoLevelParams, k: int | None = None):
    """Paper-faithful sequential 2GTI. Returns (ids, scores, stats).
    ``k`` is the per-call retrieval depth (legacy ``params.k`` fallback)."""
    a, b, g = params.alpha, params.beta, params.gamma
    F = params.threshold_factor
    k = resolve_k(params, k)
    nq = len(q_terms)
    lists = []
    sig_b = np.zeros(nq, np.float64)
    sig_l = np.zeros(nq, np.float64)
    for i, (t, wbq, wlq) in enumerate(zip(q_terms, qw_b, qw_l)):
        d, wb, wl = merged.postings(int(t))
        wb = wb.astype(np.float64) * float(wbq)
        wl = wl.astype(np.float64) * float(wlq)
        lists.append((d.astype(np.int64), wb, wl))
        if len(d):
            sig_b[i] = wb.max()
            sig_l[i] = wl.max()
    order = np.argsort(a * sig_b + (1 - a) * sig_l, kind="stable")
    lists = [lists[i] for i in order]
    sig_b, sig_l = sig_b[order], sig_l[order]
    m_alpha = a * sig_b + (1 - a) * sig_l
    prefix_alpha = np.cumsum(m_alpha)
    m_beta = b * sig_b + (1 - b) * sig_l
    prefix_beta = np.cumsum(m_beta)

    q_gl, q_lo, q_rk = _TopK(k), _TopK(k), _TopK(k)
    cursors = [0] * nq
    docs_evaluated = 0
    docs_frozen = 0
    while True:
        th_gl = q_gl.threshold * F
        th_lo = q_lo.threshold * F
        essential = prefix_alpha > th_gl  # suffix in sorted order
        if not essential.any():
            break  # every doc bounded below theta_Gl: traversal terminates
        # pivot doc: min current docid among essential cursors
        d = None
        for i in range(nq):
            if essential[i] and cursors[i] < len(lists[i][0]):
                cd = lists[i][0][cursors[i]]
                d = cd if d is None else min(d, cd)
        if d is None:
            break
        # advance non-essential cursors to >= d (skip pointers)
        for i in range(nq):
            if not essential[i]:
                di = lists[i][0]
                cursors[i] = int(np.searchsorted(di, d, side="left"))
        # local refinement, descending term order
        sb = sl = 0.0
        alive = True
        for i in range(nq - 1, -1, -1):
            if not essential[i]:
                if b * sb + (1 - b) * sl + prefix_beta[i] <= th_lo:
                    alive = False
                    break
            di, wbi, wli = lists[i]
            c = cursors[i]
            if c < len(di) and di[c] == d:
                sb += wbi[c]
                sl += wli[c]
        docs_evaluated += 1
        q_rk.push(g * sb + (1 - g) * sl, int(d))  # partial or full
        if alive:
            q_gl.push(a * sb + (1 - a) * sl, int(d))
            q_lo.push(b * sb + (1 - b) * sl, int(d))
        else:
            docs_frozen += 1
        # advance every cursor sitting at d
        for i in range(nq):
            di = lists[i][0]
            c = cursors[i]
            if c < len(di) and di[c] == d:
                cursors[i] = c + 1
    ids, vals = q_rk.sorted_desc()
    stats = {"docs_evaluated": docs_evaluated, "docs_frozen": docs_frozen}
    return ids, vals, stats
