"""Relevance metrics used in the paper: MRR@k, recall@k, nDCG@10."""
from __future__ import annotations

import numpy as np


def mrr_at_k(ranked_ids: np.ndarray, relevant: set[int], k: int = 10) -> float:
    for rank, d in enumerate(ranked_ids[:k], start=1):
        if int(d) in relevant:
            return 1.0 / rank
    return 0.0


def recall_at_k(ranked_ids: np.ndarray, relevant: set[int], k: int) -> float:
    if not relevant:
        return 0.0
    hits = sum(1 for d in ranked_ids[:k] if int(d) in relevant)
    return hits / len(relevant)


def ndcg_at_k(ranked_ids: np.ndarray, gains: dict[int, float], k: int = 10
              ) -> float:
    """nDCG@k with graded gains (binary dict -> standard nDCG)."""
    dcg = 0.0
    for rank, d in enumerate(ranked_ids[:k], start=1):
        g = gains.get(int(d), 0.0)
        if g:
            dcg += (2.0 ** g - 1.0) / np.log2(rank + 1)
    ideal = sorted(gains.values(), reverse=True)[:k]
    idcg = sum((2.0 ** g - 1.0) / np.log2(r + 1)
               for r, g in enumerate(ideal, start=1))
    return dcg / idcg if idcg > 0 else 0.0


def mean_and_p99(latencies_ms: np.ndarray) -> tuple[float, float]:
    """MRT and tail latency as reported in the paper's tables."""
    lat = np.asarray(latencies_ms, dtype=np.float64)
    return float(lat.mean()), float(np.percentile(lat, 99))


def evaluate_run(ids: np.ndarray, qrels: list[set[int]], k: int,
                 mrr_cutoff: int = 10) -> dict:
    """Aggregate MRR@cutoff / recall@k / nDCG@10 over a query batch."""
    mrr, rec, ndcg = [], [], []
    for row, rel in zip(ids, qrels):
        mrr.append(mrr_at_k(row, rel, mrr_cutoff))
        rec.append(recall_at_k(row, rel, k))
        ndcg.append(ndcg_at_k(row, {d: 1.0 for d in rel}, 10))
    return {"mrr": float(np.mean(mrr)), "recall": float(np.mean(rec)),
            "ndcg": float(np.mean(ndcg))}
