"""Relevance metrics used in the paper: MRR@k, recall@k, nDCG@k.

Contract details the eval harness (``repro.eval``) and its property
tests pin down:

- a document counts **once**: duplicate ids in a ranked list never
  inflate recall or DCG (first occurrence wins — the TREC convention);
- sentinel / invalid ids (< 0, the engines' empty-queue marker) are
  never relevant and never consume a "seen" slot;
- ``k`` larger than the ranked list degrades gracefully;
- ``mean_and_p99`` ignores non-finite latencies (in-flight NaN markers)
  and returns (nan, nan) for an empty or all-NaN sample instead of
  raising. Its p99 is the **exact-rank** quantile (``repro.obs``), not
  numpy's interpolated percentile — the reported tail is a latency some
  query actually took.
"""
from __future__ import annotations

import numpy as np

from ..obs.metrics import exact_quantile


def mrr_at_k(ranked_ids: np.ndarray, relevant: set[int], k: int = 10) -> float:
    for rank, d in enumerate(ranked_ids[:k], start=1):
        if int(d) >= 0 and int(d) in relevant:
            return 1.0 / rank
    return 0.0


def recall_at_k(ranked_ids: np.ndarray, relevant: set[int], k: int) -> float:
    if not relevant:
        return 0.0
    hits = {int(d) for d in ranked_ids[:k]
            if int(d) >= 0 and int(d) in relevant}
    return len(hits) / len(relevant)


def ndcg_at_k(ranked_ids: np.ndarray, gains: dict[int, float], k: int = 10
              ) -> float:
    """nDCG@k with graded gains (binary dict -> standard nDCG)."""
    dcg = 0.0
    seen: set[int] = set()
    for rank, d in enumerate(ranked_ids[:k], start=1):
        d = int(d)
        if d < 0 or d in seen:
            continue   # sentinels never score; dups never earn gain twice
        seen.add(d)
        g = gains.get(d, 0.0)
        if g:
            dcg += (2.0 ** g - 1.0) / np.log2(rank + 1)
    ideal = sorted(gains.values(), reverse=True)[:k]
    idcg = sum((2.0 ** g - 1.0) / np.log2(r + 1)
               for r, g in enumerate(ideal, start=1))
    return dcg / idcg if idcg > 0 else 0.0


def mean_and_p99(latencies_ms: np.ndarray) -> tuple[float, float]:
    """MRT and tail latency as reported in the paper's tables.

    Non-finite entries (NaN in-flight markers, inf) are dropped; an
    empty or fully non-finite sample yields (nan, nan) rather than a
    numpy error, so callers can aggregate partial workloads safely."""
    lat = np.asarray(latencies_ms, dtype=np.float64).ravel()
    lat = lat[np.isfinite(lat)]
    if lat.size == 0:
        return (float("nan"), float("nan"))
    return float(lat.mean()), exact_quantile(lat, 0.99)


def evaluate_run(ids: np.ndarray, qrels: list[set[int]], k: int,
                 mrr_cutoff: int = 10) -> dict:
    """Aggregate MRR@cutoff / recall@k / nDCG@10 over a query batch."""
    mrr, rec, ndcg = [], [], []
    for row, rel in zip(ids, qrels):
        mrr.append(mrr_at_k(row, rel, mrr_cutoff))
        rec.append(recall_at_k(row, rel, k))
        ndcg.append(ndcg_at_k(row, {d: 1.0 for d in rel}, 10))
    return {"mrr": float(np.mean(mrr)), "recall": float(np.mean(rec)),
            "ndcg": float(np.mean(ndcg))}
