"""BM25 weighting (Robertson/Sparck-Jones) over term-frequency corpora.

The paper builds a BM25 index over DocT5Query-expanded documents, tokenized
to match the learned model. Here BM25 is computed from (tf, doclen, df)
statistics; ``one_fill_weight`` implements the paper's one-filling alignment
(Section 4.3): the BM25 weight a (term, doc) pair *would* have had with tf=1.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .sparse import SparseModel, from_coo

K1 = 0.9
B = 0.4


@dataclasses.dataclass
class Bm25Stats:
    """Corpus statistics needed to (re)compute BM25 weights."""

    n_docs: int
    n_terms: int
    doc_lens: np.ndarray  # [n_docs] float32
    idf: np.ndarray       # [n_terms] float32

    @property
    def avg_len(self) -> float:
        return float(self.doc_lens.mean())


def idf_from_df(n_docs: int, df: np.ndarray) -> np.ndarray:
    """Lucene-style non-negative idf."""
    return np.log(1.0 + (n_docs - df + 0.5) / (df + 0.5)).astype(np.float32)


def bm25_weight(tf: np.ndarray, doc_len: np.ndarray, idf: np.ndarray,
                avg_len: float, k1: float = K1, b: float = B) -> np.ndarray:
    """w_B(t, d) = idf(t) * tf*(k1+1) / (tf + k1*(1 - b + b*len/avglen))."""
    denom = tf + k1 * (1.0 - b + b * doc_len / avg_len)
    return (idf * tf * (k1 + 1.0) / denom).astype(np.float32)


def one_fill_weight(doc_len: np.ndarray, idf: np.ndarray, avg_len: float,
                    k1: float = K1, b: float = B) -> np.ndarray:
    """BM25 weight with tf = 1 — the one-filling value for missing pairs."""
    return bm25_weight(np.ones_like(doc_len), doc_len, idf, avg_len, k1, b)


def build_bm25(n_docs: int, n_terms: int, terms: np.ndarray, docs: np.ndarray,
               tfs: np.ndarray, doc_lens: np.ndarray,
               k1: float = K1, b: float = B) -> tuple[SparseModel, Bm25Stats]:
    """BM25 SparseModel + stats from COO (term, doc, tf) triples."""
    df = np.bincount(terms, minlength=n_terms).astype(np.float32)
    idf = idf_from_df(n_docs, df)
    avg_len = float(doc_lens.mean())
    w = bm25_weight(tfs.astype(np.float32), doc_lens[docs].astype(np.float32),
                    idf[terms], avg_len, k1, b)
    model = from_coo(n_docs, n_terms, terms, docs, w)
    stats = Bm25Stats(n_docs, n_terms, doc_lens.astype(np.float32), idf)
    return model, stats
