# The paper's primary contribution: two-level guided traversal (2GTI) for
# learned sparse retrieval, as a TPU-native tile-scan engine.
from .align import MergedPostings, merge_models, misalignment_fraction  # noqa: F401
from .bm25 import Bm25Stats, build_bm25  # noqa: F401
from .index import BlockedImpactIndex, build_index  # noqa: F401
from .metrics import evaluate_run, mean_and_p99  # noqa: F401
from .plan import QueryPlan, plan_query  # noqa: F401
from .shard_plan import ShardedImpactIndex, shard_index  # noqa: F401
from .sparse import SparseModel, from_coo  # noqa: F401
from .traversal import (RetrievalResult, retrieve_batched,  # noqa: F401
                        retrieve_sequential)
from .twolevel import TwoLevelParams  # noqa: F401
from . import oracle, plan, twolevel  # noqa: F401
