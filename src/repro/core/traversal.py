"""Two-level guided tile-scan traversal — the paper's algorithm, TPU-native.

The docid space is scanned tile-by-tile in docid order (``lax.scan``),
carrying three top-k queues whose thresholds tighten monotonically — the
DAAT threshold dynamic at tile granularity. Per tile:

  1. *Tile skip* (global level): sum of alpha-combined per-(term,tile) maxima
     <= theta_Gl  =>  no doc in the tile can qualify; skip.
  2. *Term partitioning* (global level): terms presorted ascending by
     alpha-combined list maxima; the prefix whose bound sum stays <= theta_Gl
     is non-essential. Docs with no essential-term posting are pruned and
     enter no queue.
  3. *Local level*: surviving docs accumulate weights term-by-term in
     descending order. Before each non-essential term, docs whose
     beta-partial + beta-combined remaining bound <= theta_Lo freeze: they
     stop accumulating but keep their partial gamma-combined RankScore,
     which still enters Q_Rk (paper queue discipline).
  4. Tile-local top-k of Global/Local/Rank merge into the carried queues.

Planner/executor split (see ``core.plan`` for the full contract): term
sorting, tile scheduling, bound computation and the theta_Gl partition all
live in the planner; this module holds the *executors* — ``score_tile``
(pure jnp) and ``_score_tile_kernel`` (fused Pallas ``guided_score``) share
one contract ``(offs, wb, wl, essential, prefix_beta, th_lo, ...)`` and are
interchangeable per ``use_kernel``. ``_tile_step`` is the executor step
driven by every traversal mode:

  - ``retrieve_batched`` (``traversal="full"``): vmap over queries x
    lax.scan over tiles (TPU path; skipped tiles are masked compute).
  - ``retrieve_batched`` (``traversal="chunked"``/``"chunked_fused"``):
    descending-bound tile chunks under a ``lax.while_loop`` that stops at
    the first bound-failing chunk — *real* work elision under jit
    (Block-Max-Pruning structure; see ``_retrieve_chunked_impl``).
  - ``retrieve_sequential``: host loop with *physical* tile skipping, timing
    each query — the paper's single-threaded latency regime.
  - ``serve.sharded.shard_retrieve_batched``: per-shard tile scans under
    ``shard_map`` with a collective top-k merge (same step, same planner).
"""
from __future__ import annotations

import dataclasses
import time
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .index import BlockedImpactIndex, dispatch_gather, gather_tile
from .plan import (QueryPlan, chunk_schedule, combine, essential_terms,
                   freeze_bounds, plan_query, term_bounds, tile_schedule,
                   tile_upper_bounds)
from .twolevel import TwoLevelParams, resolve_k

NEG_INF = jnp.float32(-jnp.inf)

# Kept under the historical name: kernel tests exercise the executor's
# combination directly.
_combine = combine

STAT_KEYS = ("docs_present", "docs_survived", "docs_frozen",
             "postings_touched", "tiles_visited")

# The per-query counters worth attaching to a request's execute span:
# the executor stats plus the chunked traversal's dispatch counts
# (absent from engines that don't produce them). Consumed by
# ``repro.obs.trace_exec`` — keep in sync with retrieve_batched's stats
# assembly below.
TRACE_STAT_KEYS = STAT_KEYS + ("n_tiles", "chunks_dispatched", "n_chunks")


@dataclasses.dataclass
class RetrievalResult:
    ids: np.ndarray        # [B, k] int32 (Q_Rk docids, score-desc)
    scores: np.ndarray     # [B, k] float32 (RankScore)
    global_ids: np.ndarray
    local_ids: np.ndarray
    stats: dict            # per-query counters
    latencies_ms: np.ndarray | None = None  # sequential mode only


def _merge_queue(q_vals, q_ids, c_vals, c_ids, k: int):
    """Merge tile candidates into a sorted top-k queue (stable ties)."""
    vals = jnp.concatenate([q_vals, c_vals])
    ids = jnp.concatenate([q_ids, c_ids])
    top_vals, idx = jax.lax.top_k(vals, k)
    return top_vals, ids[idx]


def _tile_topk(scores, mask, kq: int):
    vals, idx = jax.lax.top_k(jnp.where(mask, scores, NEG_INF), kq)
    return vals, idx.astype(jnp.int32)


def score_tile(offs, wb, wl, essential, prefix_beta, th_lo,
               alpha, beta, gamma, *, tile_size: int, kq: int):
    """Score one tile for one query. See module docstring for the levels.

    offs:        [Nq, P] int32 local doc offsets (-1 = padding)
    wb, wl:      [Nq, P] f32 query-weighted posting weights (0 = padding)
    essential:   [Nq] bool essential-term mask (planner, sorted order)
    prefix_beta: [Nq] f32 inclusive beta-bound prefix sums (planner)
    Returns three (vals, local_idx) candidate sets + stat counters.
    """
    nq = offs.shape[0]
    S = tile_size
    valid = offs >= 0
    offs_safe = jnp.where(valid, offs, S).astype(jnp.int32)

    # Dense per-term rows: one scatter for all terms at once.
    seg = (jnp.arange(nq, dtype=jnp.int32)[:, None] * (S + 1) + offs_safe).ravel()
    dense_b = jax.ops.segment_sum(wb.ravel(), seg, num_segments=nq * (S + 1)
                                  ).reshape(nq, S + 1)[:, :S]
    dense_l = jax.ops.segment_sum(wl.ravel(), seg, num_segments=nq * (S + 1)
                                  ).reshape(nq, S + 1)[:, :S]
    cnt = jax.ops.segment_sum(valid.ravel().astype(jnp.float32), seg,
                              num_segments=nq * (S + 1)).reshape(nq, S + 1)[:, :S]

    present = cnt.sum(0) > 0                               # [S]
    ess_cnt = jnp.einsum("t,ts->s", essential.astype(jnp.float32), cnt)
    survive = ess_cnt > 0                                  # [S]

    # Local level: descending accumulate with freeze checks.
    def body(j, state):
        i = nq - 1 - j
        sb, sl, alive = state
        l_part = combine(beta, sb, sl)
        ok = essential[i] | (l_part + prefix_beta[i] > th_lo)
        alive = alive & ok
        gate = (survive & alive).astype(sb.dtype)
        sb = sb + gate * dense_b[i]
        sl = sl + gate * dense_l[i]
        return sb, sl, alive

    sb0 = jnp.zeros(S, dtype=jnp.float32)
    alive0 = jnp.ones(S, dtype=bool)
    sb, sl, alive = jax.lax.fori_loop(0, nq, body, (sb0, sb0, alive0))

    g = combine(alpha, sb, sl)
    l = combine(beta, sb, sl)
    r = combine(gamma, sb, sl)
    eval_mask = survive & alive
    rank_mask = survive

    g_c = _tile_topk(g, eval_mask, kq)
    l_c = _tile_topk(l, eval_mask, kq)
    r_c = _tile_topk(r, rank_mask, kq)
    stats = jnp.stack([present.sum().astype(jnp.float32),
                       survive.sum().astype(jnp.float32),
                       (survive & ~alive).sum().astype(jnp.float32),
                       valid.sum().astype(jnp.float32)])
    return g_c, l_c, r_c, stats


def _score_tile_kernel(offs, wb, wl, essential, prefix_beta, th_lo,
                       alpha, beta, gamma, *, tile_size: int, kq: int):
    """Pallas guided_score kernel path (interpret mode on CPU): same
    contract as ``score_tile``; the fused kernel returns G/L/R + masks."""
    from ..kernels.guided_score import guided_score_tile
    out = guided_score_tile(offs, wb, wl, essential.astype(jnp.float32),
                            prefix_beta, th_lo, alpha, beta, gamma,
                            tile_size=tile_size,
                            block_s=min(512, tile_size))
    g, l, r, eval_m, rank_m = out
    eval_mask = eval_m > 0
    rank_mask = rank_m > 0

    # The kernel reports only the post-partition masks; presence is
    # re-derived from the gathered offsets exactly as score_tile counts it
    # (one scatter over doc slots), so both paths report identical stats.
    valid = offs >= 0
    S = tile_size
    offs_safe = jnp.where(valid, offs, S).astype(jnp.int32)
    cnt = jax.ops.segment_sum(valid.ravel().astype(jnp.float32),
                              offs_safe.ravel(), num_segments=S + 1)[:S]
    present = cnt > 0
    stats = jnp.stack([present.sum().astype(jnp.float32),
                       rank_m.sum(),
                       (rank_mask & ~eval_mask).sum().astype(jnp.float32),
                       valid.sum().astype(jnp.float32)])
    return (_tile_topk(g, eval_mask, kq), _tile_topk(l, eval_mask, kq),
            _tile_topk(r, rank_mask, kq), stats)


def _gather_tile(docids, w_b, w_l, tile_ptr, qt, qwb, qwl, tile,
                 *, pad_len: int, tile_size: int):
    """Query-weighted padded tile gather — delegates to the single gather
    implementation in ``core.index.gather_tile``."""
    return gather_tile(docids, w_b, w_l, tile_ptr, qt, tile, qwb, qwl,
                       pad_len=pad_len, tile_size=tile_size)


def _score_tile_kernel_q(gt, plan: QueryPlan, tile, essential, prefix_beta,
                         th_lo, alpha, beta, gamma,
                         *, tile_size: int, pad_len: int, kq: int):
    """Decode-in-kernel Pallas path for the compressed index: raw packed
    rows go straight into ``guided_score_tile_q``, which delta-decodes the
    offsets and dequantizes the impacts in VMEM before the shared scatter/
    freeze passes. Same candidate contract as ``score_tile``; stats come
    from the kernel's extra per-slot posting-count row (no host-side
    decode, so decompression stays inside the memory-bound gather)."""
    from ..index.compressed import gather_tile_q_raw
    from ..kernels.guided_score import guided_score_tile_q
    words, qb_row, ql_row, meta_i, meta_f = gather_tile_q_raw(
        gt, plan.qt, tile, pad_len=pad_len)
    out = guided_score_tile_q(
        words, qb_row, ql_row, meta_i, meta_f, plan.qwb, plan.qwl,
        essential.astype(jnp.float32), prefix_beta, th_lo,
        alpha, beta, gamma, tile_size=tile_size, pad_len=pad_len,
        block_s=min(512, tile_size))
    g, l, r, eval_m, rank_m, slot_cnt = out
    eval_mask = eval_m > 0
    rank_mask = rank_m > 0
    stats = jnp.stack([(slot_cnt > 0).sum().astype(jnp.float32),
                       rank_m.sum(),
                       (rank_mask & ~eval_mask).sum().astype(jnp.float32),
                       slot_cnt.sum()])
    return (_tile_topk(g, eval_mask, kq), _tile_topk(l, eval_mask, kq),
            _tile_topk(r, rank_mask, kq), stats)


def _tile_step(idx_arrays, plan: QueryPlan, carry, tile,
               alpha, beta, gamma, factor,
               *, k, kq, pad_len, tile_size, bound_mode, use_kernel=False,
               gather_kind="fp32", th_floor=None, tile_valid=None):
    """One tile visit: plan bounds -> skip test -> score -> queue merge.

    ``idx_arrays`` is ``(gather_tuple, tile_max_b, tile_max_l)`` — the
    index's ``gather_arrays()`` payload plus the exact fp32 tile maxima;
    ``gather_kind`` (static) selects the decoder, so the same step serves
    the fp32 and compressed indexes. Planning reads only the exact maxima,
    which both index types carry — bounds and skip decisions are
    codec-independent by construction.

    ``th_floor`` (optional scalar) is an externally supplied lower bound on
    theta_Gl — the sharded path injects the exchanged global threshold here
    so a shard prunes against the global queue, not just its local one.
    Thresholds only tighten, so any floor <= the true global theta is safe.

    ``tile_valid`` (optional bool) force-skips the visit when False — the
    sharded path marks its shape-padding tiles invalid so they never enter
    queues or stats and skip rates stay comparable across engines.
    """
    gt, tile_max_b, tile_max_l = idx_arrays
    (gv, gi, lv, li, rv, ri, st) = carry
    th_gl = gv[-1]
    if th_floor is not None:
        th_gl = jnp.maximum(th_gl, th_floor)
    th_gl = th_gl * factor
    th_lo = lv[-1] * factor

    m_alpha, m_beta, ub_gl = term_bounds(plan, tile_max_b, tile_max_l, tile,
                                         alpha, beta, bound_mode)
    skip = ub_gl <= th_gl
    if tile_valid is not None:
        skip = skip | ~tile_valid
    essential = essential_terms(m_alpha, th_gl)
    prefix_beta = freeze_bounds(m_beta)

    if use_kernel and gather_kind == "q8":
        # compressed + kernel: decode happens inside the pallas_call
        g_c, l_c, r_c, stats = _score_tile_kernel_q(
            gt, plan, tile, essential, prefix_beta, th_lo,
            alpha, beta, gamma, tile_size=tile_size, pad_len=pad_len, kq=kq)
    else:
        offs, wb, wl = dispatch_gather(gather_kind, gt, plan.qt, tile,
                                       plan.qwb, plan.qwl,
                                       pad_len=pad_len, tile_size=tile_size)
        scorer = _score_tile_kernel if use_kernel else score_tile
        g_c, l_c, r_c, stats = scorer(
            offs, wb, wl, essential, prefix_beta, th_lo, alpha, beta, gamma,
            tile_size=tile_size, kq=kq)

    base = tile * tile_size

    def masked(c):
        vals, idx = c
        vals = jnp.where(skip, NEG_INF, vals)
        return vals, base + idx

    gv, gi = _merge_queue(gv, gi, *masked(g_c), k)
    lv, li = _merge_queue(lv, li, *masked(l_c), k)
    rv, ri = _merge_queue(rv, ri, *masked(r_c), k)
    visited = jnp.where(skip, 0.0, 1.0)
    st = st + jnp.concatenate([jnp.where(skip, 0.0, stats), visited[None]])
    return (gv, gi, lv, li, rv, ri, st)


def _init_carry(k):
    vals = jnp.full(k, NEG_INF, dtype=jnp.float32)
    ids = jnp.full(k, -1, dtype=jnp.int32)
    return (vals, ids, vals, ids, vals, ids, jnp.zeros(5, dtype=jnp.float32))


TRAVERSALS = ("full", "chunked", "chunked_fused")


def _chunk_scan(idx_arrays, plan, carry, tiles_chunk, alpha, beta, gamma,
                factor, n_valid, *, th_floor=None, **statics):
    """Advance one query's carry over one chunk of its tile order.

    Exact per-tile semantics: every tile re-reads the carry's thresholds,
    so the operation sequence is identical to the full scan's — the chunk
    grouping only decides how much of the schedule is dispatched at all.
    ``n_valid`` force-skips sentinel/padding tiles (id >= n_valid)."""
    def step(c, tile):
        return _tile_step(idx_arrays, plan, c, tile, alpha, beta, gamma,
                          factor, th_floor=th_floor,
                          tile_valid=tile < n_valid, **statics), None
    return jax.lax.scan(step, carry, tiles_chunk)[0]


def _chunk_while(advance, chunk_ub, carries, disp, th_floor, factor):
    """Early-exit loop over a chunk sequence — the single copy of the
    Block-Max-Pruning termination rule, shared by the batched executor
    and the sharded per-shard rounds (``serve.sharded._chunk_round``).

    Dispatches chunk ``i`` (``advance(i, carries)``) while any query's
    next chunk bound beats its (floored) theta_Gl; per-chunk bounds are
    descending and thresholds only tighten, so the first failing chunk
    proves every later tile fails its per-tile skip test too. ``disp``
    accumulates the per-query count of chunks that were live when
    dispatched. All operands are batched over queries ([B] leading dim);
    ``th_floor`` is -inf when no exchanged global theta applies."""
    n_c = chunk_ub.shape[1]

    def th_of(carries):
        return jnp.maximum(carries[0][:, -1], th_floor) * factor

    def cond(state):
        i, carries, _ = state
        ub_i = jax.lax.dynamic_index_in_dim(chunk_ub, i, 1, False)
        return (i < n_c) & jnp.any(ub_i > th_of(carries))

    def body(state):
        i, carries, disp = state
        ub_i = jax.lax.dynamic_index_in_dim(chunk_ub, i, 1, False)
        active = ub_i > th_of(carries)
        carries = advance(i, carries)
        return i + 1, carries, disp + active.astype(jnp.float32)

    _, carries, disp = jax.lax.while_loop(
        cond, body, (jnp.int32(0), carries, disp))
    return carries, disp


def _chunk_step_fused(idx_arrays, plan, carry, tiles_chunk,
                      alpha, beta, gamma, factor, n_valid,
                      *, k, kq, pad_len, tile_size, bound_mode,
                      gather_kind="fp32", th_floor=None):
    """Advance one query's carry over one chunk via the multi-tile Pallas
    ``guided_score_chunk`` kernel (one pallas_call per chunk; the ``_q``
    decode-in-kernel variant when the index is compressed).

    The skip predicate, essential partition and freeze bounds for every
    tile in the chunk derive from the *chunk-start* thresholds (the carry
    cannot be updated mid-kernel). Within a chunk that only loosens the
    pruning, so rank-safe configs stay bound-exact; guided configs follow
    a slightly different (still bound-safe) threshold trajectory — the
    usual guided tolerance, pinned in test_traversal."""
    from ..kernels.guided_score import guided_score_chunk, guided_score_chunk_q
    gt, tile_max_b, tile_max_l = idx_arrays
    gv, gi, lv, li, rv, ri, st = carry
    th_gl = gv[-1]
    if th_floor is not None:
        th_gl = jnp.maximum(th_gl, th_floor)
    th_gl = th_gl * factor
    th_lo = lv[-1] * factor

    m_alpha, m_beta, ub_gl = jax.vmap(
        lambda t: term_bounds(plan, tile_max_b, tile_max_l, t,
                              alpha, beta, bound_mode))(tiles_chunk)
    skip = (ub_gl <= th_gl) | (tiles_chunk >= n_valid)        # [C]
    essential = jax.vmap(essential_terms, in_axes=(0, None))(m_alpha, th_gl)
    prefix_beta = jax.vmap(freeze_bounds)(m_beta)

    if gather_kind == "q8":
        from ..index.compressed import gather_tile_q_raw
        words, qbr, qlr, meta_i, meta_f = jax.vmap(
            lambda t: gather_tile_q_raw(gt, plan.qt, t, pad_len=pad_len)
        )(tiles_chunk)
        out = guided_score_chunk_q(
            words, qbr, qlr, meta_i, meta_f, plan.qwb, plan.qwl,
            essential.astype(jnp.float32), prefix_beta, skip, th_lo,
            alpha, beta, gamma, tile_size=tile_size, pad_len=pad_len,
            block_s=min(512, tile_size))
        # posting presence/counts come from the kernel's 6th output row
        slot_cnt = out[:, 5]                                  # [C, S]
        present = (slot_cnt > 0).sum(1).astype(jnp.float32)
        postings = slot_cnt.sum(1)
    else:
        docids, w_b, w_l, tile_ptr = gt
        offs, wb, wl = jax.vmap(
            lambda t: _gather_tile(docids, w_b, w_l, tile_ptr,
                                   plan.qt, plan.qwb, plan.qwl, t,
                                   pad_len=pad_len, tile_size=tile_size)
        )(tiles_chunk)                                        # [C, Nq, P]
        out = guided_score_chunk(offs, wb, wl, essential.astype(jnp.float32),
                                 prefix_beta, skip, th_lo, alpha, beta, gamma,
                                 tile_size=tile_size,
                                 block_s=min(512, tile_size))
        # Stats exactly as _score_tile_kernel derives them, chunk-vectorized:
        # presence re-counted from the gathered offsets (one scatter/tile).
        S = tile_size
        valid = offs >= 0
        offs_safe = jnp.where(valid, offs, S).astype(jnp.int32)

        def present_one(v, o):
            cnt = jax.ops.segment_sum(v.ravel().astype(jnp.float32),
                                      o.ravel(), num_segments=S + 1)[:S]
            return (cnt > 0).sum().astype(jnp.float32)
        present = jax.vmap(present_one)(valid, offs_safe)
        postings = valid.sum((1, 2)).astype(jnp.float32)

    g, l, r = out[:, 0], out[:, 1], out[:, 2]
    eval_mask = out[:, 3] > 0
    rank_mask = out[:, 4] > 0
    tile_stats = jnp.stack(
        [present, out[:, 4].sum(1),
         (rank_mask & ~eval_mask).sum(1).astype(jnp.float32),
         postings], axis=1)                                   # [C, 4]

    def merge_step(c, xs):
        gv, gi, lv, li, rv, ri, st = c
        tile, g_t, l_t, r_t, ev_t, rk_t, sk_t, st_t = xs
        base = tile * tile_size

        def masked(cand):
            vals, idx = cand
            return jnp.where(sk_t, NEG_INF, vals), base + idx
        gv, gi = _merge_queue(gv, gi, *masked(_tile_topk(g_t, ev_t, kq)), k)
        lv, li = _merge_queue(lv, li, *masked(_tile_topk(l_t, ev_t, kq)), k)
        rv, ri = _merge_queue(rv, ri, *masked(_tile_topk(r_t, rk_t, kq)), k)
        visited = jnp.where(sk_t, 0.0, 1.0)
        st = st + jnp.concatenate([jnp.where(sk_t, 0.0, st_t),
                                   visited[None]])
        return (gv, gi, lv, li, rv, ri, st), None
    carry, _ = jax.lax.scan(
        merge_step, carry,
        (tiles_chunk, g, l, r, eval_mask, rank_mask, skip, tile_stats))
    return carry


@partial(jax.jit, static_argnames=("k", "kq", "pad_len", "tile_size",
                                   "n_tiles", "bound_mode", "chunk_tiles",
                                   "use_kernel", "fused", "gather_kind"))
def _retrieve_chunked_impl(gt, tile_max_b, tile_max_l,
                           sigma_b, sigma_l, q_terms, qw_b, qw_l,
                           alpha, beta, gamma, factor,
                           *, k, kq, pad_len, tile_size, n_tiles, bound_mode,
                           chunk_tiles, use_kernel=False, fused=False,
                           gather_kind="fp32"):
    """Chunked traversal: real skipping under jit.

    Tiles are presorted by descending global upper bound and folded into
    static ``[n_chunks, chunk_tiles]`` groups (``core.plan.chunk_schedule``);
    a ``lax.while_loop`` dispatches one chunk per iteration and terminates
    at the first chunk whose max bound fails the theta_Gl test. Bounds
    descend and thresholds only tighten, so every undispatched tile would
    have been skipped by the full impact-ordered scan anyway — results and
    stats are bit-identical to it while a fraction of the chunks execute.
    Under vmap-over-queries the loop runs until every query's bound fails
    (per-query ``chunks_dispatched`` still counts each query's own work).
    """
    idx_arrays = (gt, tile_max_b, tile_max_l)

    def plan_one(qt, qwb, qwl):
        plan = plan_query(qt, qwb, qwl, sigma_b, sigma_l, alpha)
        sched = chunk_schedule(plan, tile_max_b, tile_max_l, alpha,
                               n_tiles, chunk_tiles)
        return plan, sched
    plans, sched = jax.vmap(plan_one)(q_terms, qw_b, qw_l)
    chunks, chunk_ub = sched          # [B, n_chunks, C], [B, n_chunks]
    b = q_terms.shape[0]
    carries = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (b,) + x.shape), _init_carry(k))
    statics = dict(k=k, kq=kq, pad_len=pad_len, tile_size=tile_size,
                   bound_mode=bound_mode, gather_kind=gather_kind)

    if fused:
        def step_one(plan, tiles_i, carry):
            return _chunk_step_fused(idx_arrays, plan, carry, tiles_i,
                                     alpha, beta, gamma, factor, n_tiles,
                                     **statics)
    else:
        def step_one(plan, tiles_i, carry):
            return _chunk_scan(idx_arrays, plan, carry, tiles_i,
                               alpha, beta, gamma, factor, n_tiles,
                               use_kernel=use_kernel, **statics)

    def advance(i, carries):
        tiles_i = jax.lax.dynamic_index_in_dim(chunks, i, 1, False)
        return jax.vmap(step_one)(plans, tiles_i, carries)

    return _chunk_while(advance, chunk_ub, carries,
                        jnp.zeros(b, jnp.float32),
                        jnp.full(b, -jnp.inf, jnp.float32), factor)


@partial(jax.jit, static_argnames=("k", "kq", "pad_len", "tile_size",
                                   "n_tiles", "bound_mode", "schedule",
                                   "use_kernel", "gather_kind"))
def _retrieve_batched_impl(gt, tile_max_b, tile_max_l,
                           sigma_b, sigma_l, q_terms, qw_b, qw_l,
                           alpha, beta, gamma, factor,
                           *, k, kq, pad_len, tile_size, n_tiles, bound_mode,
                           schedule, use_kernel=False, gather_kind="fp32"):
    idx_arrays = (gt, tile_max_b, tile_max_l)

    def one_query(qt, qwb, qwl):
        plan = plan_query(qt, qwb, qwl, sigma_b, sigma_l, alpha)
        tiles = tile_schedule(plan, tile_max_b, tile_max_l, alpha,
                              n_tiles, schedule)

        def step(carry, tile):
            carry = _tile_step(idx_arrays, plan, carry, tile,
                               alpha, beta, gamma, factor,
                               k=k, kq=kq, pad_len=pad_len,
                               tile_size=tile_size, bound_mode=bound_mode,
                               use_kernel=use_kernel,
                               gather_kind=gather_kind)
            return carry, None

        carry, _ = jax.lax.scan(step, _init_carry(k), tiles)
        return carry

    return jax.vmap(one_query)(q_terms, qw_b, qw_l)


def retrieve_batched(index: BlockedImpactIndex, q_terms, qw_b, qw_l,
                     params: TwoLevelParams,
                     use_kernel: bool = False,
                     k: int | None = None,
                     traversal: str = "full",
                     chunk_tiles: int | None = None) -> RetrievalResult:
    """Batched retrieval: q_terms [B, Nq] int32 (pad with qw = 0).

    ``index`` may be a ``BlockedImpactIndex`` or a
    ``repro.index.CompressedImpactIndex`` — both expose the same planner
    metadata and a ``gather_arrays()``/``gather_kind`` pair; the executors
    decode compressed postings inside the gather (or inside the Pallas
    kernel when ``use_kernel=True``).

    ``k`` is the retrieval depth for this call (falls back to the
    deprecated ``params.k`` stash, then DEFAULT_K). ``use_kernel=True``
    routes tile scoring through the fused Pallas guided_score kernel
    (native on TPU; interpreter elsewhere).

    ``traversal``:
      - ``"full"`` — lax.scan over all tiles in ``params.schedule`` order;
        skipped tiles are masked compute (the historical engine).
      - ``"chunked"`` — descending-bound tile chunks under a
        ``lax.while_loop`` that stops at the first bound-failing chunk:
        bit-identical (ids, scores, stats) to the full scan with the
        ``impact`` schedule while dispatching only the live chunk prefix.
        Stats gain ``chunks_dispatched`` / ``n_chunks``.
      - ``"chunked_fused"`` — same chunk loop, but each chunk is scored by
        one multi-tile ``guided_score_chunk`` pallas_call whose skip/
        essential/freeze inputs come from the chunk-start thresholds:
        rank-safe configs stay exact; guided configs track the exact
        chunked path within the usual guided tolerance.
    ``chunk_tiles`` overrides ``params.chunk_tiles`` for this call.
    """
    if traversal not in TRAVERSALS:
        raise ValueError(f"traversal must be in {TRAVERSALS}, "
                         f"got {traversal!r}")
    q_terms = jnp.asarray(q_terms, dtype=jnp.int32)
    qw_b = jnp.asarray(qw_b, dtype=jnp.float32)
    qw_l = jnp.asarray(qw_l, dtype=jnp.float32)
    k = resolve_k(params, k)
    kq = min(k, index.tile_size)
    arrays = (index.gather_arrays(),
              index.tile_max_b, index.tile_max_l,
              index.sigma_b, index.sigma_l, q_terms, qw_b, qw_l,
              jnp.float32(params.alpha), jnp.float32(params.beta),
              jnp.float32(params.gamma), jnp.float32(params.threshold_factor))
    statics = dict(k=k, kq=kq, pad_len=index.pad_len,
                   tile_size=index.tile_size, bound_mode=params.bound_mode,
                   gather_kind=index.gather_kind)
    disp = None
    if traversal == "full":
        out = _retrieve_batched_impl(*arrays, n_tiles=index.n_tiles,
                                     schedule=params.schedule,
                                     use_kernel=use_kernel, **statics)
    else:
        ct = int(chunk_tiles if chunk_tiles is not None
                 else params.chunk_tiles)
        out, disp = _retrieve_chunked_impl(
            *arrays, n_tiles=index.n_tiles, chunk_tiles=ct,
            use_kernel=use_kernel, fused=traversal == "chunked_fused",
            **statics)
    gv, gi, lv, li, rv, ri, st = jax.tree_util.tree_map(np.asarray, out)
    stats = dict(zip(STAT_KEYS, st.T))
    b = q_terms.shape[0]
    stats["n_tiles"] = np.full(b, index.n_tiles, np.float32)
    if disp is not None:
        stats["chunks_dispatched"] = np.asarray(disp)
        stats["n_chunks"] = np.full(b, -(-index.n_tiles // ct), np.float32)
    return RetrievalResult(ids=index.to_orig(ri), scores=rv,
                           global_ids=index.to_orig(gi),
                           local_ids=index.to_orig(li), stats=stats)


# ---------------------------------------------------------------------------
# Sequential mode: host tile loop with physical skipping (latency benchmarks).
# ---------------------------------------------------------------------------

@jax.jit
def _plan_with_bounds(qt, qwb, qwl, sigma_b, sigma_l,
                      tile_max_b, tile_max_l, alpha):
    """Planner entry for the host loop: plan + per-tile upper bounds."""
    plan = plan_query(qt, qwb, qwl, sigma_b, sigma_l, alpha)
    ub = tile_upper_bounds(plan, tile_max_b, tile_max_l, alpha)
    return plan, ub


@partial(jax.jit, static_argnames=("k", "kq", "pad_len", "tile_size",
                                   "bound_mode", "gather_kind"))
def _tile_step_jit(gt, tile_max_b, tile_max_l,
                   plan, carry, tile, alpha, beta, gamma, factor,
                   *, k, kq, pad_len, tile_size, bound_mode,
                   gather_kind="fp32"):
    idx_arrays = (gt, tile_max_b, tile_max_l)
    return _tile_step(idx_arrays, plan, carry, tile,
                      alpha, beta, gamma, factor, k=k, kq=kq, pad_len=pad_len,
                      tile_size=tile_size, bound_mode=bound_mode,
                      gather_kind=gather_kind)


def retrieve_sequential(index: BlockedImpactIndex, q_terms, qw_b, qw_l,
                        params: TwoLevelParams,
                        warmup: bool = True,
                        k: int | None = None) -> RetrievalResult:
    """Host-driven per-query traversal with physical tile skipping + timing.

    Mirrors the paper's single-threaded CPU latency regime: skipped tiles
    cost nothing (the gather/score call is never issued). Planning runs
    through the same ``core.plan`` functions as the batched engine; only
    the skip *decision* is evaluated on host so it can elide work.
    ``k`` is the per-call retrieval depth (legacy ``params.k`` fallback).
    """
    B = len(q_terms)
    k = resolve_k(params, k)
    kq = min(k, index.tile_size)
    alpha, beta, gamma = params.alpha, params.beta, params.gamma
    factor = params.threshold_factor
    args = (jnp.float32(alpha), jnp.float32(beta), jnp.float32(gamma),
            jnp.float32(factor))
    statics = dict(k=k, kq=kq, pad_len=index.pad_len,
                   tile_size=index.tile_size, bound_mode=params.bound_mode,
                   gather_kind=index.gather_kind)
    gt = index.gather_arrays()
    ids = np.full((B, k), -1, np.int32)
    scores = np.full((B, k), -np.inf, np.float32)
    g_ids = np.full((B, k), -1, np.int32)
    l_ids = np.full((B, k), -1, np.int32)
    lat = np.zeros(B, np.float64)
    stat_rows = np.zeros((B, 6), np.float32)

    def run_query(qi, record):
        qt = jnp.asarray(np.asarray(q_terms[qi], dtype=np.int32))
        qwb = jnp.asarray(np.asarray(qw_b[qi], dtype=np.float32))
        qwl = jnp.asarray(np.asarray(qw_l[qi], dtype=np.float32))
        plan, ub_dev = _plan_with_bounds(qt, qwb, qwl,
                                         index.sigma_b, index.sigma_l,
                                         index.tile_max_b, index.tile_max_l,
                                         jnp.float32(alpha))
        ub = np.asarray(ub_dev)
        impact = params.schedule == "impact"
        tile_order = (np.argsort(-ub, kind="stable") if impact
                      else np.arange(index.n_tiles))
        t0 = time.perf_counter()
        carry = _init_carry(k)
        th_gl = -np.inf
        visited = 0
        for tau in tile_order:
            if ub[tau] <= th_gl * factor:  # th_gl=-inf never skips
                if impact:
                    break  # ub descending: every later tile fails too
                continue
            carry = _tile_step_jit(
                gt, index.tile_max_b, index.tile_max_l,
                plan, carry, jnp.int32(tau), *args, **statics)
            th_gl = float(carry[0][-1])
            visited += 1
        carry = jax.tree_util.tree_map(np.asarray, carry)
        dt = (time.perf_counter() - t0) * 1e3
        if record:
            gv, gi, lv, li, rv, ri, st = carry
            ids[qi], scores[qi] = ri, rv
            g_ids[qi], l_ids[qi] = gi, li
            lat[qi] = dt
            stat_rows[qi] = np.concatenate([st, [index.n_tiles]])

    if warmup and B > 0:
        run_query(0, record=False)  # compile outside the timed region
    for qi in range(B):
        run_query(qi, record=True)

    stats = dict(zip(STAT_KEYS + ("n_tiles",), stat_rows.T))
    return RetrievalResult(ids=index.to_orig(ids), scores=scores,
                           global_ids=index.to_orig(g_ids),
                           local_ids=index.to_orig(l_ids), stats=stats,
                           latencies_ms=lat)
