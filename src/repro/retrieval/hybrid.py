"""Hybrid sparse+dense retrieval substrate: index pair, query embedding,
jitted dense rerank, and reciprocal-rank fusion.

The paper's relevance/efficiency argument only becomes measurable when a
second ranking signal exists: both related systems (BM25→dense-rerank
cascades; sparse+dense RRF fusion) dominate either modality alone on
judged corpora. This module supplies the shared substrate the
``cascade`` and ``rrf`` registry engines are built on:

- :class:`HybridIndex` — a :class:`~repro.core.index.BlockedImpactIndex`
  paired with a :class:`~repro.core.dense_guided.DenseGuidedIndex` over
  per-document embeddings (**original-docid order**: row ``d`` of the
  embedding matrix is document ``d``, so the sparse engines' already
  orig-mapped result ids index the embedding table directly) plus a
  ``q_proj`` [n_terms, D] term-projection matrix;
- :func:`embed_queries` — the sparse→dense query bridge: a query's
  embedding is the learned-weight-weighted sum of its terms' projection
  rows, L2-normalized and rotated into the dense index's PCA basis.
  Deriving the embedding from the *sparse* request keeps the hybrid
  engines servable through every sparse path (Retriever, scheduler
  routing, response cache) with no request-format change; callers with
  real query embeddings pass them via ``SearchRequest.dense`` instead;
- :func:`rerank_candidates` — cascade stage two: gather the candidates'
  embedding rows and take the exact-dense top-k (jitted, static
  ``(depth, k)`` so the k'-bucketed cascade compiles once per bucket
  pair);
- :func:`dense_topk` — batched exact dense ranking (the RRF dense leg);
- :func:`rrf_fuse` — reciprocal-rank fusion ``sum 1/(rrf_k + rank)``
  with deterministic (score-desc, docid-asc) tie-breaks.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.dense_guided import DenseGuidedIndex, build_dense_index
from ..core.index import BlockedImpactIndex

NEG = jnp.float32(-jnp.inf)


@dataclasses.dataclass
class HybridIndex:
    """One corpus, two rankers: the sparse BII plus a dense index whose
    embedding rows are **original-docid indexed** (row ``d`` embeds doc
    ``d`` — required because sparse engine results arrive orig-mapped).

    ``q_proj`` [n_terms, D] turns a sparse query into a dense one
    (:func:`embed_queries`); real deployments would plug a query encoder
    here, the synthetic harness plants a projection that is consistent
    with the generated document embeddings.
    """
    sparse: BlockedImpactIndex
    dense: DenseGuidedIndex
    q_proj: jax.Array          # [n_terms, D]

    @property
    def n_docs(self) -> int:
        return self.sparse.n_docs

    @property
    def dim(self) -> int:
        return int(self.q_proj.shape[1])


def build_hybrid_index(sparse: BlockedImpactIndex, doc_emb, q_proj,
                       block_size: int = 512,
                       d_cheap: int | None = None) -> HybridIndex:
    """Pair a built BII with document embeddings (original-docid order)
    and a query projection. The dense side goes through
    ``core.dense_guided.build_dense_index`` — PCA rotation preserves dot
    products and row order, so orig docids keep indexing rows."""
    doc_emb = jnp.asarray(doc_emb, jnp.float32)
    q_proj = jnp.asarray(q_proj, jnp.float32)
    if doc_emb.ndim != 2 or doc_emb.shape[0] != sparse.n_docs:
        raise ValueError(
            f"doc_emb must be [n_docs={sparse.n_docs}, D] in original "
            f"docid order, got shape {tuple(doc_emb.shape)}")
    if q_proj.shape != (sparse.n_terms, doc_emb.shape[1]):
        raise ValueError(
            f"q_proj must be [n_terms={sparse.n_terms}, "
            f"D={doc_emb.shape[1]}], got {tuple(q_proj.shape)}")
    if d_cheap is None:
        d_cheap = min(16, int(doc_emb.shape[1]))
    dense = build_dense_index(doc_emb, block_size=min(block_size,
                                                      sparse.n_docs),
                              d_cheap=d_cheap)
    return HybridIndex(sparse=sparse, dense=dense, q_proj=q_proj)


@jax.jit
def _embed_impl(q_proj, rotation, terms, wl):
    # zero-weight padding terms contribute nothing; the row norm guard
    # keeps an all-padding (no-op) query at the zero vector
    e = (q_proj[terms] * wl[..., None]).sum(axis=-2)          # [B, D]
    n = jnp.linalg.norm(e, axis=-1, keepdims=True)
    return (e / jnp.maximum(n, 1e-9)) @ rotation              # rotated


def embed_queries(hybrid: HybridIndex, terms, weights_l,
                  dense=None) -> jax.Array:
    """[B, D] query embeddings in the dense index's rotated basis.

    ``dense`` (optional, [B, D]): caller-provided raw query embeddings
    (e.g. a real query encoder) — rotated here; otherwise the sparse
    query is bridged through ``q_proj`` weighted by the learned query
    weights (the side the rank score is dominated by)."""
    if dense is not None:
        q = jnp.asarray(dense, jnp.float32)
        if q.ndim != 2 or q.shape[1] != hybrid.dim:
            raise ValueError(f"dense query embeddings must be [B, "
                             f"{hybrid.dim}], got {tuple(q.shape)}")
        return q @ hybrid.dense.rotation
    return _embed_impl(hybrid.q_proj, hybrid.dense.rotation,
                       jnp.asarray(terms, jnp.int32),
                       jnp.asarray(weights_l, jnp.float32))


@partial(jax.jit, static_argnames=("k",))
def _rerank_impl(emb, q_rot, cand_ids, *, k):
    safe = jnp.maximum(cand_ids, 0)
    ce = emb[safe]                                      # [B, depth, D]
    s = jnp.einsum("bkd,bd->bk", ce, q_rot)
    s = jnp.where(cand_ids >= 0, s, NEG)
    vals, idx = jax.lax.top_k(s, k)                     # stable ties:
    ids = jnp.take_along_axis(cand_ids, idx, axis=1)    # first-stage order
    ids = jnp.where(jnp.isneginf(vals), -1, ids)
    return vals, ids


def rerank_candidates(hybrid: HybridIndex, q_rot, cand_ids,
                      k: int) -> tuple[np.ndarray, np.ndarray]:
    """Exact-dense rerank of first-stage candidates: gather the
    candidates' embedding rows, score against the rotated queries, keep
    the top ``k``. Jitted with static ``(depth, k)`` — with both depths
    bucketed, one compile per bucket pair. Sentinel candidates (-1)
    never resurface; short rows pad with (-1, -inf)."""
    cand_ids = jnp.asarray(cand_ids, jnp.int32)
    k = min(int(k), int(cand_ids.shape[1]))
    vals, ids = _rerank_impl(hybrid.dense.emb, q_rot, cand_ids, k=k)
    return np.asarray(vals, np.float32), np.asarray(ids, np.int32)


@partial(jax.jit, static_argnames=("k", "n_docs"))
def _dense_topk_impl(emb, q_rot, *, k, n_docs):
    s = q_rot @ emb.T                                   # [B, N_padded]
    live = jnp.arange(emb.shape[0]) < n_docs            # mask pad rows
    s = jnp.where(live[None, :], s, NEG)
    vals, ids = jax.lax.top_k(s, k)
    return vals, ids.astype(jnp.int32)


def dense_topk(hybrid: HybridIndex, q_rot,
               k: int) -> tuple[np.ndarray, np.ndarray]:
    """Batched exact dense top-k over the whole corpus (the RRF dense
    leg / the dense-only evaluation lane)."""
    k = min(int(k), hybrid.n_docs)
    vals, ids = _dense_topk_impl(hybrid.dense.emb, q_rot, k=k,
                                 n_docs=hybrid.n_docs)
    return np.asarray(vals, np.float32), np.asarray(ids, np.int32)


def rrf_fuse(ids_a: np.ndarray, ids_b: np.ndarray, k: int,
             rrf_k: float = 60.0) -> tuple[np.ndarray, np.ndarray]:
    """Reciprocal-rank fusion of two ranked id lists (per row):
    ``score(d) = sum over lists 1 / (rrf_k + rank_d)`` with 1-based
    ranks; docs absent from a list contribute nothing. Ties break
    deterministically by (fused score desc, docid asc). Sentinel ids
    (< 0) are skipped; rows with fewer than ``k`` fused docs pad with
    (-1, -inf)."""
    ids_a, ids_b = np.asarray(ids_a), np.asarray(ids_b)
    if ids_a.shape[0] != ids_b.shape[0]:
        raise ValueError(f"row mismatch: {ids_a.shape[0]} vs "
                         f"{ids_b.shape[0]} queries")
    b = ids_a.shape[0]
    out_ids = np.full((b, k), -1, np.int32)
    out_scores = np.full((b, k), -np.inf, np.float32)
    for row in range(b):
        fused: dict[int, float] = {}
        for ranked in (ids_a[row], ids_b[row]):
            for rank, d in enumerate(ranked, start=1):
                d = int(d)
                if d < 0:
                    continue
                fused[d] = fused.get(d, 0.0) + 1.0 / (rrf_k + rank)
        if not fused:
            continue
        docs = np.fromiter(fused.keys(), np.int64, len(fused))
        vals = np.fromiter(fused.values(), np.float64, len(fused))
        order = np.lexsort((docs, -vals))[:k]
        out_ids[row, :len(order)] = docs[order]
        out_scores[row, :len(order)] = vals[order]
    return out_ids, out_scores
