"""Request/response contract of the unified search API.

One wire format for every engine: a :class:`SearchRequest` carries the
query batch plus the *query-time* knobs — retrieval depth ``k`` and an
optional ``threshold_factor`` override — while :class:`TwoLevelParams`
keeps only the pruning *policy* (alpha/beta/gamma, bounds, schedule).
A :class:`SearchResponse` is uniform across engines: original-space doc
ids, RankScores, the engine's stat counters, and wall-clock latency.

k-bucketing: per-request ``k`` is executed at the smallest bucket
>= k (``K_BUCKETS``) and the response is truncated back, so sweeping k
at query time does not recompile the jitted traversal — one compile per
bucket, not per distinct k. For rank-safe configurations the truncated
prefix is bit-identical to running at exactly ``k`` (the exact top-k is
prefix-closed under the stable tie discipline); guided configurations
prune against the k-th threshold, so exact-k semantics require ``k`` to
sit on a bucket (or an exact-mode retriever with ``k_buckets=None``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Default execution depths. Chosen to cover the paper's sweep (Table 2 /
# Figure 1 use k in {10, ..., 1000}); anything above the largest bucket
# executes at its exact value.
K_BUCKETS = (10, 100, 1000)


def bucket_k(k: int, buckets=K_BUCKETS) -> int:
    """Smallest bucket >= k; k itself beyond the largest bucket.
    ``buckets=None`` disables bucketing (exact-k execution)."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if buckets:
        for b in buckets:
            if k <= b:
                return b
    return k


@dataclasses.dataclass
class SearchRequest:
    """One retrieval call: a query batch plus query-time knobs.

    Sparse engines read ``terms``/``weights_b``/``weights_l`` ([B, Nq]
    arrays or ragged per-query lists — the Retriever pads ragged input
    with zero-weight terms, which score as no-ops). The dense engine
    reads ``dense`` ([B, D] float embeddings) instead.
    """
    terms: object = None       # [B, Nq] int32 term ids (or ragged lists)
    weights_b: object = None   # [B, Nq] f32 BM25-side query weights
    weights_l: object = None   # [B, Nq] f32 learned-side query weights
    dense: object = None       # [B, D] f32 query embeddings (dense engine)
    # None -> resolved by the Retriever (DEFAULT_K, honoring a legacy
    # TwoLevelParams(k=...) stash) so both invocation styles agree
    k: int | None = None
    # Per-call pruning aggressiveness override (Table 3 / Fig. 3 sweeps);
    # flows into the jitted engines as a traced scalar — no recompile.
    threshold_factor: float | None = None

    def batch_size(self) -> int:
        src = self.dense if self.terms is None else self.terms
        return len(src)


@dataclasses.dataclass
class SearchResponse:
    """Uniform engine output: ids/scores truncated to the requested k."""
    ids: np.ndarray            # [B, k] original-space docids (-1 = empty)
    scores: np.ndarray         # [B, k] f32 RankScore, descending
    engine: str                # registry name that served the call
    k: int                     # requested depth
    k_exec: int                # executed depth (the bucket)
    stats: dict                # engine counters (per-query arrays/floats)
    latency_ms: float          # wall-clock of the engine call
    # per-query host-loop timings (sequential engine only)
    latencies_ms: np.ndarray | None = None
