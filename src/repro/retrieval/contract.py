"""Request/response contract of the unified search API.

One wire format for every engine: a :class:`SearchRequest` carries the
query batch plus the *query-time* knobs — retrieval depth ``k`` and an
optional ``threshold_factor`` override — while :class:`TwoLevelParams`
keeps only the pruning *policy* (alpha/beta/gamma, bounds, schedule).
A :class:`SearchResponse` is uniform across engines: original-space doc
ids, RankScores, the engine's stat counters, and wall-clock latency.

k-bucketing: per-request ``k`` is executed at the smallest bucket
>= k (``K_BUCKETS``) and the response is truncated back, so sweeping k
at query time does not recompile the jitted traversal — one compile per
bucket, not per distinct k. For rank-safe configurations the truncated
prefix is bit-identical to running at exactly ``k`` (the exact top-k is
prefix-closed under the stable tie discipline); guided configurations
prune against the k-th threshold, so exact-k semantics require ``k`` to
sit on a bucket (or an exact-mode retriever with ``k_buckets=None``).

Mixed-k batches: ``SearchRequest.k`` may also be a per-query sequence
([B] ints). The batch executes once at the bucket of the *largest*
requested depth and every row is truncated back to its own k (slots
beyond a row's depth hold the empty-queue sentinels: id -1, score
-inf). ``SearchResponse.ks`` always carries the per-row depths, so
downstream consumers never have to re-derive which columns are live.
"""
from __future__ import annotations

import dataclasses

import numpy as np

# Default execution depths. Chosen to cover the paper's sweep (Table 2 /
# Figure 1 use k in {10, ..., 1000}); anything above the largest bucket
# executes at its exact value.
K_BUCKETS = (10, 100, 1000)


def bucket_k(k: int, buckets=K_BUCKETS) -> int:
    """Smallest bucket >= k; k itself beyond the largest bucket.
    ``buckets=None`` disables bucketing (exact-k execution)."""
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    if buckets:
        for b in buckets:
            if k <= b:
                return b
    return k


def resolve_ks(k, batch_size: int) -> np.ndarray | None:
    """Normalize a per-query ``k`` to an int32 [batch_size] array.

    Returns None for the scalar (uniform-depth) invocation styles —
    ``None`` and plain ints keep the historical scalar path. Sequences
    and 0-d arrays of the right length become the per-row depth vector.
    """
    if k is None or isinstance(k, (int, np.integer)):
        return None
    ks = np.asarray(k)
    if ks.ndim == 0:  # np.int64(7) etc. — still a scalar request
        return None
    if not np.issubdtype(ks.dtype, np.integer):
        # fail loudly instead of silently truncating 5.9 -> 5 results
        if ks.size and (np.mod(ks, 1) != 0).any():
            raise ValueError(
                f"per-request k entries must be whole numbers, got {ks}")
    ks = ks.astype(np.int64).ravel()
    if ks.size != batch_size:
        raise ValueError(f"per-request k has {ks.size} entries for a "
                         f"batch of {batch_size} queries")
    if ks.size == 0 or (ks < 1).any():
        raise ValueError(f"per-request k entries must be >= 1, got {ks}")
    return ks.astype(np.int32)


@dataclasses.dataclass
class SearchRequest:
    """One retrieval call: a query batch plus query-time knobs.

    Sparse engines read ``terms``/``weights_b``/``weights_l`` ([B, Nq]
    arrays or ragged per-query lists — the Retriever pads ragged input
    with zero-weight terms, which score as no-ops). The dense engine
    reads ``dense`` ([B, D] float embeddings) instead.
    """
    terms: object = None       # [B, Nq] int32 term ids (or ragged lists)
    weights_b: object = None   # [B, Nq] f32 BM25-side query weights
    weights_l: object = None   # [B, Nq] f32 learned-side query weights
    dense: object = None       # [B, D] f32 query embeddings (dense engine)
    # None -> resolved by the Retriever (DEFAULT_K, honoring a legacy
    # TwoLevelParams(k=...) stash) so both invocation styles agree.
    # May be a per-query [B] sequence: the batch executes at the bucket
    # of the largest entry and each row is truncated to its own depth.
    k: int | object | None = None
    # Per-call pruning aggressiveness override (Table 3 / Fig. 3 sweeps);
    # flows into the jitted engines as a traced scalar — no recompile.
    threshold_factor: float | None = None
    # Serving deadline, measured from scheduler submit. The scheduler
    # sheds entries whose budget ran out before pick (the handle fails
    # with DeadlineExceeded); engines themselves never read it.
    deadline_ms: float | None = None

    def batch_size(self) -> int:
        src = self.dense if self.terms is None else self.terms
        return len(src)


@dataclasses.dataclass
class SearchResponse:
    """Uniform engine output: ids/scores truncated to the requested k.

    ``k`` is the (maximum) requested depth — the column count of
    ``ids``/``scores``; ``ks`` the per-row depths (all equal to ``k``
    for scalar requests). Rows with ``ks[i] < k`` carry the empty-queue
    sentinels (-1 / -inf) beyond their own depth.
    """
    ids: np.ndarray            # [B, k] original-space docids (-1 = empty)
    scores: np.ndarray         # [B, k] f32 RankScore, descending
    engine: str                # registry name that served the call
    k: int                     # requested depth (max over rows)
    k_exec: int                # executed depth (the bucket)
    stats: dict                # engine counters (per-query arrays/floats)
    latency_ms: float          # wall-clock of the engine call
    # per-query host-loop timings (sequential engine only)
    latencies_ms: np.ndarray | None = None
    # per-row requested depths [B] int32 (always set by the Retriever)
    ks: np.ndarray | None = None
    # index generation that served the call (hot-swap bookkeeping; a
    # response may never mix rows from two generations)
    generation: int = 0
    # True when a degraded pool served this via a fallback route
    degraded: bool = False
