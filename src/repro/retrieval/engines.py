"""Engine protocol + string-keyed registry of retrieval backends.

Every engine adapts one existing traversal entry point to the uniform
``search(terms, weights_b, weights_l, dense, *, k, params)`` contract and
returns a ``core.traversal.RetrievalResult``. All sparse engines are
driven by the same ``core.plan`` planner — registering an engine selects
an *executor/placement*, never a different pruning algorithm:

    "batched"     vmap x lax.scan tile scan (jnp scorer)      1 device
    "kernel"      same scan, fused Pallas guided_score scorer 1 device
    "sequential"  host tile loop, physical skips + timings    1 device
    "sharded"     shard_map tile ranges + collective merge    mesh
    "dense"       blocked dense two-level pruning             1 device
    "cascade"     sparse traversal at depth k' -> dense rerank to k
    "rrf"         reciprocal-rank fusion of sparse + dense rankings

The hybrid engines (``cascade`` / ``rrf``) open on a
:class:`~repro.retrieval.hybrid.HybridIndex` (sparse BII + dense doc
embeddings + query projection); every *sparse* engine also accepts a
HybridIndex and transparently serves its ``.sparse`` side, so one
scheduler index can back a routing policy that mixes sparse and hybrid
routes.

Third-party backends register with ``@register_engine("name")`` — the
class must accept ``(index, params, **opts)`` and implement ``search``.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from ..core.dense_guided import DenseGuidedIndex, retrieve_dense_batched
from ..core.index import BlockedImpactIndex
from ..core.traversal import (RetrievalResult, retrieve_batched,
                              retrieve_sequential)
from ..core.twolevel import TwoLevelParams
from .contract import K_BUCKETS, bucket_k
from .hybrid import (HybridIndex, dense_topk, embed_queries,
                     rerank_candidates, rrf_fuse)

_REGISTRY: dict[str, type] = {}


def register_engine(name: str):
    """Class decorator: register an Engine implementation under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def engine_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_engine(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; registered engines: "
                       f"{', '.join(engine_names())}") from None


@runtime_checkable
class Engine(Protocol):
    """What the Retriever facade drives. ``search`` executes one batch at
    depth ``k`` under pruning policy ``params`` and returns the raw
    engine result (internal ids already mapped to original docid space).

    ``replicate`` returns a fresh instance with the same configuration
    **sharing the open index arrays** (no rebuild, no re-partition) —
    what the serving executor pool clones per worker. Engines hold no
    per-call mutable state, so a replica is just a second dispatch
    surface over the same device buffers."""
    name: str

    def search(self, terms, weights_b, weights_l, dense, *, k: int,
               params: TwoLevelParams) -> RetrievalResult:
        ...

    def replicate(self, params: TwoLevelParams) -> "Engine":
        ...


def _require_bii(index, engine: str) -> BlockedImpactIndex:
    from ..index.compressed import CompressedImpactIndex
    if isinstance(index, HybridIndex):
        index = index.sparse   # sparse engines serve the sparse side
    if not isinstance(index, (BlockedImpactIndex, CompressedImpactIndex)):
        raise TypeError(f"engine {engine!r} needs a BlockedImpactIndex or "
                        f"CompressedImpactIndex, got {type(index).__name__}")
    return index


def _require_hybrid(index, engine: str) -> HybridIndex:
    if not isinstance(index, HybridIndex):
        raise TypeError(
            f"engine {engine!r} needs a HybridIndex (sparse BII + dense "
            f"doc embeddings; see repro.retrieval.build_hybrid_index), "
            f"got {type(index).__name__}")
    return index


@register_engine("batched")
class BatchedEngine:
    """vmap-over-queries lax.scan tile scan; pure-jnp tile scorer.

    ``traversal="chunked"`` replaces the all-tiles scan with the
    descending-bound chunk loop (``lax.while_loop`` with early exit):
    bit-identical to the ``impact``-schedule full scan while dispatching
    only the live chunk prefix; stats gain ``chunks_dispatched``.
    ``chunk_tiles`` overrides ``params.chunk_tiles``.
    """

    use_kernel = False
    traversals = ("full", "chunked")

    # NOTE: engines deliberately hold no pruning params — the policy for
    # each call arrives via search(params=...) (possibly with a per-call
    # threshold_factor override), so storing the open-time copy would
    # only invite stale reads.
    def __init__(self, index, params: TwoLevelParams,
                 traversal: str = "full", chunk_tiles: int | None = None):
        self.index = _require_bii(index, self.name)
        if traversal not in self.traversals:
            raise ValueError(
                f"engine {self.name!r} supports traversal in "
                f"{self.traversals}, got {traversal!r}")
        self.traversal = traversal
        self.chunk_tiles = chunk_tiles

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        return retrieve_batched(self.index, terms, weights_b, weights_l,
                                params, use_kernel=self.use_kernel, k=k,
                                traversal=self.traversal,
                                chunk_tiles=self.chunk_tiles)

    def replicate(self, params):
        return type(self)(self.index, params, traversal=self.traversal,
                          chunk_tiles=self.chunk_tiles)


@register_engine("kernel")
class KernelEngine(BatchedEngine):
    """Batched scan routed through the fused Pallas guided_score kernel
    (native on TPU, interpreter elsewhere). ``traversal="chunked"`` keeps
    the per-tile kernel inside the chunk loop (bit-identical early exit);
    ``"chunked_fused"`` scores each chunk with one multi-tile
    ``guided_score_chunk`` pallas_call (chunk-start thresholds: rank-safe
    exact, guided within the usual tolerance)."""

    use_kernel = True
    traversals = ("full", "chunked", "chunked_fused")


@register_engine("sequential")
class SequentialEngine:
    """Host-driven per-query loop with physical tile skips; the paper's
    single-threaded latency regime. Responses carry per-query timings."""

    def __init__(self, index, params: TwoLevelParams, warmup: bool = True):
        self.index = _require_bii(index, self.name)
        self.warmup = warmup

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        return retrieve_sequential(self.index, terms, weights_b, weights_l,
                                   params, warmup=self.warmup, k=k)

    def replicate(self, params):
        return type(self)(self.index, params, warmup=self.warmup)


@register_engine("sharded")
class ShardedEngine:
    """Mesh-sharded tile ranges with a collective top-k merge.

    Accepts a ``BlockedImpactIndex`` (partitioned here via ``n_shards``)
    or a prebuilt ``core.shard_plan.ShardedImpactIndex``. ``mesh=None``
    serves through the single-device vmap emulation path.
    """

    def __init__(self, index, params: TwoLevelParams, *,
                 n_shards: int | None = None, mesh=None,
                 axis_name: str = "shard", use_kernel: bool = False,
                 exchange_every: int = 0, traversal: str = "full",
                 chunk_tiles: int | None = None):
        # deferred: serve.sharded imports serve.engine, which uses the
        # Retriever facade — a module-level import here would be circular
        from ..core.shard_plan import ShardedImpactIndex, shard_index
        if traversal not in ("full", "chunked"):
            raise ValueError(f"engine {self.name!r} supports traversal in "
                             f"('full', 'chunked'), got {traversal!r}")
        if mesh is not None and n_shards is None:
            n_shards = mesh.shape[axis_name]
        if isinstance(index, ShardedImpactIndex):
            self.sharded = index
        else:
            self.sharded = shard_index(_require_bii(index, self.name),
                                       n_shards or 1)
        self.mesh = mesh
        self.axis_name = axis_name
        self.use_kernel = use_kernel
        self.exchange_every = exchange_every
        self.traversal = traversal
        self.chunk_tiles = chunk_tiles

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        from ..serve.sharded import shard_retrieve_batched
        return shard_retrieve_batched(
            self.sharded, terms, weights_b, weights_l, params,
            mesh=self.mesh, axis_name=self.axis_name,
            use_kernel=self.use_kernel,
            exchange_every=self.exchange_every, k=k,
            traversal=self.traversal, chunk_tiles=self.chunk_tiles)

    def replicate(self, params):
        # hand over the prebuilt ShardedImpactIndex: a replica must never
        # re-partition the tile ranges (stacked shard arrays are the
        # expensive part of open)
        return type(self)(self.sharded, params, mesh=self.mesh,
                          axis_name=self.axis_name,
                          use_kernel=self.use_kernel,
                          exchange_every=self.exchange_every,
                          traversal=self.traversal,
                          chunk_tiles=self.chunk_tiles)


@register_engine("dense")
class DenseEngine:
    """2GTI transferred to blocked dense retrieval (two-tower candidates).

    Queries arrive as ``SearchRequest.dense`` [B, D] embeddings and the
    whole batch runs through one jitted guided block scan
    (``core.dense_guided.retrieve_dense_batched`` — a vmap over the
    per-query scan, so each row keeps its own block order/thresholds and
    results match the per-query path). ``threshold_factor`` overrides
    are ignored — the dense skip test has no factor knob."""

    def __init__(self, index, params: TwoLevelParams):
        if isinstance(index, HybridIndex):
            index = index.dense   # dense-only lane of a hybrid index
        if not isinstance(index, DenseGuidedIndex):
            raise TypeError(f"engine 'dense' needs a DenseGuidedIndex "
                            f"(core.dense_guided.build_dense_index), got "
                            f"{type(index).__name__}")
        self.index = index

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        if dense is None:
            raise ValueError("engine 'dense' reads SearchRequest.dense "
                             "([B, D] query embeddings); got None")
        scores, ids, stats = retrieve_dense_batched(self.index, dense,
                                                    params, k=k)
        ids = ids.astype(np.int32)
        scores = scores.astype(np.float32)
        return RetrievalResult(ids=ids, scores=scores, global_ids=ids,
                               local_ids=ids, stats=stats)

    def replicate(self, params):
        return type(self)(self.index, params)


_HYBRID_FIRST_STAGES = ("batched", "kernel", "sequential", "sharded")


class _HybridBase:
    """Shared open-time plumbing of the two hybrid engines: a HybridIndex,
    a sparse first stage from the registry, and a candidate depth k'.

    ``depth`` (k') is bucketed at call time together with the requested
    k, so the jitted stages compile once per (k'-bucket, k-bucket) pair
    — a per-call k sweep never retraces either stage. Extra ``**opts``
    go to the first-stage constructor (``traversal="chunked"``,
    ``n_shards=...``, ...)."""

    def __init__(self, index, params: TwoLevelParams, *,
                 depth: int = 100, first_stage: str = "batched", **opts):
        self.hybrid = _require_hybrid(index, self.name)
        if first_stage not in _HYBRID_FIRST_STAGES:
            raise ValueError(
                f"engine {self.name!r} first_stage must be in "
                f"{_HYBRID_FIRST_STAGES}, got {first_stage!r}")
        if depth < 1:
            raise ValueError(f"depth={depth} must be >= 1")
        self.depth = int(depth)
        self.first = get_engine(first_stage)(self.hybrid.sparse, params,
                                             **opts)
        # remembered for replicate(): the executor pool re-opens the same
        # configuration over the shared HybridIndex
        self._first_stage = first_stage
        self._first_opts = dict(opts)

    def replicate(self, params):
        return type(self)(self.hybrid, params, depth=self.depth,
                          first_stage=self._first_stage,
                          **self._replicate_opts())

    def _replicate_opts(self) -> dict:
        return dict(self._first_opts)

    def _depth_for(self, k: int) -> int:
        """Candidate depth of one call: at least the configured k' and
        the requested k, bucketed (and corpus-capped) so the static
        stage shapes stay on the compile grid."""
        return min(bucket_k(max(self.depth, k), K_BUCKETS),
                   self.hybrid.n_docs)


@register_engine("cascade")
class CascadeEngine(_HybridBase):
    """Sparse guided traversal at depth k', exact-dense rerank to k.

    Stage one is any sparse registry engine on the shared planner (the
    pruning policy — including per-call ``threshold_factor`` overrides —
    applies there); stage two gathers the k' candidates' embedding rows
    through the hybrid index and takes the exact dense top-k (jitted,
    ``hybrid.rerank_candidates``). Query embeddings come from
    ``SearchRequest.dense`` when provided, else from the sparse query
    via the index's ``q_proj`` bridge — so the engine serves plain
    sparse requests end-to-end (scheduler routing included). Scores in
    the response are *dense* scores, not RankScores."""

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        k1 = self._depth_for(k)
        res = self.first.search(terms, weights_b, weights_l, None,
                                k=k1, params=params)
        q_rot = embed_queries(self.hybrid, terms, weights_l, dense=dense)
        scores, ids = rerank_candidates(self.hybrid, q_rot,
                                        np.asarray(res.ids), k=k)
        stats = dict(res.stats)
        stats["cascade_depth"] = float(k1)
        return RetrievalResult(ids=ids, scores=scores, global_ids=ids,
                               local_ids=ids, stats=stats,
                               latencies_ms=res.latencies_ms)


@register_engine("rrf")
class RRFEngine(_HybridBase):
    """Reciprocal-rank fusion of the sparse and dense rankings.

    Both legs rank to depth k' (sparse: first-stage traversal under the
    pruning policy; dense: batched exact top-k' over the embedding
    table), then fuse with ``score(d) = sum 1/(rrf_k + rank_d)`` and
    keep the top k. Response scores are RRF scores — comparable within
    a response, not across engines."""

    def __init__(self, index, params: TwoLevelParams, *,
                 depth: int = 100, rrf_k: float = 60.0,
                 first_stage: str = "batched", **opts):
        super().__init__(index, params, depth=depth,
                         first_stage=first_stage, **opts)
        if rrf_k <= 0:
            raise ValueError(f"rrf_k={rrf_k} must be > 0")
        self.rrf_k = float(rrf_k)

    def _replicate_opts(self) -> dict:
        return {**self._first_opts, "rrf_k": self.rrf_k}

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        k1 = self._depth_for(k)
        res = self.first.search(terms, weights_b, weights_l, None,
                                k=k1, params=params)
        q_rot = embed_queries(self.hybrid, terms, weights_l, dense=dense)
        _, dense_ids = dense_topk(self.hybrid, q_rot, k=k1)
        ids, scores = rrf_fuse(np.asarray(res.ids), dense_ids, k=k,
                               rrf_k=self.rrf_k)
        stats = dict(res.stats)
        stats["fusion_depth"] = float(k1)
        stats["rrf_k"] = self.rrf_k
        return RetrievalResult(ids=ids, scores=scores, global_ids=ids,
                               local_ids=ids, stats=stats,
                               latencies_ms=res.latencies_ms)
