"""Engine protocol + string-keyed registry of retrieval backends.

Every engine adapts one existing traversal entry point to the uniform
``search(terms, weights_b, weights_l, dense, *, k, params)`` contract and
returns a ``core.traversal.RetrievalResult``. All sparse engines are
driven by the same ``core.plan`` planner — registering an engine selects
an *executor/placement*, never a different pruning algorithm:

    "batched"     vmap x lax.scan tile scan (jnp scorer)      1 device
    "kernel"      same scan, fused Pallas guided_score scorer 1 device
    "sequential"  host tile loop, physical skips + timings    1 device
    "sharded"     shard_map tile ranges + collective merge    mesh
    "dense"       blocked dense two-level pruning             1 device

Third-party backends register with ``@register_engine("name")`` — the
class must accept ``(index, params, **opts)`` and implement ``search``.
"""
from __future__ import annotations

from typing import Protocol, runtime_checkable

import numpy as np

from ..core.dense_guided import DenseGuidedIndex, retrieve_dense
from ..core.index import BlockedImpactIndex
from ..core.traversal import (RetrievalResult, retrieve_batched,
                              retrieve_sequential)
from ..core.twolevel import TwoLevelParams

_REGISTRY: dict[str, type] = {}


def register_engine(name: str):
    """Class decorator: register an Engine implementation under ``name``."""
    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls
    return deco


def engine_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def get_engine(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(f"unknown engine {name!r}; registered engines: "
                       f"{', '.join(engine_names())}") from None


@runtime_checkable
class Engine(Protocol):
    """What the Retriever facade drives. ``search`` executes one batch at
    depth ``k`` under pruning policy ``params`` and returns the raw
    engine result (internal ids already mapped to original docid space)."""
    name: str

    def search(self, terms, weights_b, weights_l, dense, *, k: int,
               params: TwoLevelParams) -> RetrievalResult:
        ...


def _require_bii(index, engine: str) -> BlockedImpactIndex:
    if not isinstance(index, BlockedImpactIndex):
        raise TypeError(f"engine {engine!r} needs a BlockedImpactIndex, "
                        f"got {type(index).__name__}")
    return index


@register_engine("batched")
class BatchedEngine:
    """vmap-over-queries lax.scan tile scan; pure-jnp tile scorer.

    ``traversal="chunked"`` replaces the all-tiles scan with the
    descending-bound chunk loop (``lax.while_loop`` with early exit):
    bit-identical to the ``impact``-schedule full scan while dispatching
    only the live chunk prefix; stats gain ``chunks_dispatched``.
    ``chunk_tiles`` overrides ``params.chunk_tiles``.
    """

    use_kernel = False
    traversals = ("full", "chunked")

    # NOTE: engines deliberately hold no pruning params — the policy for
    # each call arrives via search(params=...) (possibly with a per-call
    # threshold_factor override), so storing the open-time copy would
    # only invite stale reads.
    def __init__(self, index, params: TwoLevelParams,
                 traversal: str = "full", chunk_tiles: int | None = None):
        self.index = _require_bii(index, self.name)
        if traversal not in self.traversals:
            raise ValueError(
                f"engine {self.name!r} supports traversal in "
                f"{self.traversals}, got {traversal!r}")
        self.traversal = traversal
        self.chunk_tiles = chunk_tiles

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        return retrieve_batched(self.index, terms, weights_b, weights_l,
                                params, use_kernel=self.use_kernel, k=k,
                                traversal=self.traversal,
                                chunk_tiles=self.chunk_tiles)


@register_engine("kernel")
class KernelEngine(BatchedEngine):
    """Batched scan routed through the fused Pallas guided_score kernel
    (native on TPU, interpreter elsewhere). ``traversal="chunked"`` keeps
    the per-tile kernel inside the chunk loop (bit-identical early exit);
    ``"chunked_fused"`` scores each chunk with one multi-tile
    ``guided_score_chunk`` pallas_call (chunk-start thresholds: rank-safe
    exact, guided within the usual tolerance)."""

    use_kernel = True
    traversals = ("full", "chunked", "chunked_fused")


@register_engine("sequential")
class SequentialEngine:
    """Host-driven per-query loop with physical tile skips; the paper's
    single-threaded latency regime. Responses carry per-query timings."""

    def __init__(self, index, params: TwoLevelParams, warmup: bool = True):
        self.index = _require_bii(index, self.name)
        self.warmup = warmup

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        return retrieve_sequential(self.index, terms, weights_b, weights_l,
                                   params, warmup=self.warmup, k=k)


@register_engine("sharded")
class ShardedEngine:
    """Mesh-sharded tile ranges with a collective top-k merge.

    Accepts a ``BlockedImpactIndex`` (partitioned here via ``n_shards``)
    or a prebuilt ``core.shard_plan.ShardedImpactIndex``. ``mesh=None``
    serves through the single-device vmap emulation path.
    """

    def __init__(self, index, params: TwoLevelParams, *,
                 n_shards: int | None = None, mesh=None,
                 axis_name: str = "shard", use_kernel: bool = False,
                 exchange_every: int = 0, traversal: str = "full",
                 chunk_tiles: int | None = None):
        # deferred: serve.sharded imports serve.engine, which uses the
        # Retriever facade — a module-level import here would be circular
        from ..core.shard_plan import ShardedImpactIndex, shard_index
        if traversal not in ("full", "chunked"):
            raise ValueError(f"engine {self.name!r} supports traversal in "
                             f"('full', 'chunked'), got {traversal!r}")
        if mesh is not None and n_shards is None:
            n_shards = mesh.shape[axis_name]
        if isinstance(index, ShardedImpactIndex):
            self.sharded = index
        else:
            self.sharded = shard_index(_require_bii(index, self.name),
                                       n_shards or 1)
        self.mesh = mesh
        self.axis_name = axis_name
        self.use_kernel = use_kernel
        self.exchange_every = exchange_every
        self.traversal = traversal
        self.chunk_tiles = chunk_tiles

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        from ..serve.sharded import shard_retrieve_batched
        return shard_retrieve_batched(
            self.sharded, terms, weights_b, weights_l, params,
            mesh=self.mesh, axis_name=self.axis_name,
            use_kernel=self.use_kernel,
            exchange_every=self.exchange_every, k=k,
            traversal=self.traversal, chunk_tiles=self.chunk_tiles)


@register_engine("dense")
class DenseEngine:
    """2GTI transferred to blocked dense retrieval (two-tower candidates).

    Queries arrive as ``SearchRequest.dense`` [B, D] embeddings; the
    per-query guided block scan runs host-side. ``threshold_factor``
    overrides are ignored — the dense skip test has no factor knob."""

    def __init__(self, index, params: TwoLevelParams):
        if not isinstance(index, DenseGuidedIndex):
            raise TypeError(f"engine 'dense' needs a DenseGuidedIndex "
                            f"(core.dense_guided.build_dense_index), got "
                            f"{type(index).__name__}")
        self.index = index

    def search(self, terms, weights_b, weights_l, dense, *, k, params):
        if dense is None:
            raise ValueError("engine 'dense' reads SearchRequest.dense "
                             "([B, D] query embeddings); got None")
        import jax.numpy as jnp
        ids, scores, scored = [], [], []
        for q in dense:
            vals, di, st = retrieve_dense(self.index, jnp.asarray(q),
                                          params, k=k)
            ids.append(di)
            scores.append(vals)
            scored.append(st["candidates_fully_scored"])
        stats = {"candidates_fully_scored": np.asarray(scored, np.float32),
                 "n_candidates": float(self.index.emb.shape[0])}
        ids = np.stack(ids).astype(np.int32)
        scores = np.stack(scores).astype(np.float32)
        return RetrievalResult(ids=ids, scores=scores, global_ids=ids,
                               local_ids=ids, stats=stats)
