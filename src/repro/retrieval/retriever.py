"""The ``Retriever`` facade: one search entry point over every engine.

    index = build_index(corpus.merged("scaled"), tile_size=512)
    r = Retriever.open(index, twolevel.fast(), engine="batched")
    resp = r.search(terms=q_terms, weights_b=qw_b, weights_l=qw_l, k=10)
    resp.ids, resp.scores, resp.stats, resp.latency_ms

The facade owns the query-time mechanics every entry point used to
re-implement (or hardcode):

  - **engine selection** — string-keyed registry (``engines.py``); the
    pruning policy (TwoLevelParams) and index are fixed at ``open`` time,
    depth and threshold overrides are per call;
  - **padding** — ragged per-query term lists are padded to one static
    [B, Nq] shape with zero-weight no-op terms;
  - **k-bucketing** — per-request ``k`` executes at the smallest bucket
    >= k and is truncated back, so a k-sweep costs one compile per
    bucket, not one per distinct k (``k_buckets=None`` = exact mode);
  - **threshold_factor override** — flows into the jitted engines as a
    traced scalar (never a static), so sweeping it never recompiles.
"""
from __future__ import annotations

import time

import numpy as np

from ..core.twolevel import TwoLevelParams, resolve_k
from .contract import (K_BUCKETS, SearchRequest, SearchResponse, bucket_k,
                       resolve_ks)
from .engines import get_engine


def _cast2d(a, dtype):
    """``a`` unchanged when it is already a [., .] array of ``dtype`` —
    np *and* jnp arrays both satisfy this without leaving their device —
    else the cheapest dtype cast the array type provides."""
    return a if a.dtype == dtype else a.astype(dtype)


def _pad_queries(terms, weights_b, weights_l):
    """Rectangularize a query batch. [B, Nq] arrays pass through; ragged
    per-query sequences are padded with zero-weight terms (score no-ops,
    the same convention the serving batcher has always used)."""
    if all(getattr(a, "ndim", None) == 2
           for a in (terms, weights_b, weights_l)):
        # already-rectangular np/jnp batch: no per-row copy loop, and jnp
        # arrays stay on device (no host round-trip through np.asarray)
        return (_cast2d(terms, np.int32), _cast2d(weights_b, np.float32),
                _cast2d(weights_l, np.float32))
    try:
        arr = np.asarray(terms)
    except ValueError:  # ragged: numpy refuses inhomogeneous shapes
        arr = None
    if arr is not None and arr.dtype != object and arr.ndim == 2:
        return (arr.astype(np.int32),
                np.asarray(weights_b, dtype=np.float32),
                np.asarray(weights_l, dtype=np.float32))
    if (arr is not None and arr.dtype != object and arr.ndim == 1
            and arr.size and np.ndim(terms[0]) == 0):
        raise ValueError("terms must be a [B, Nq] batch or a list of "
                         "per-query term arrays, got a single flat query")
    lens = [len(t) for t in terms]
    b, n = len(terms), max(lens, default=1)
    t_pad = np.zeros((b, max(n, 1)), np.int32)
    wb_pad = np.zeros((b, max(n, 1)), np.float32)
    wl_pad = np.zeros((b, max(n, 1)), np.float32)
    for i, (t, wb, wl) in enumerate(zip(terms, weights_b, weights_l)):
        t_pad[i, :len(t)] = np.asarray(t)
        wb_pad[i, :len(t)] = np.asarray(wb)
        wl_pad[i, :len(t)] = np.asarray(wl)
    return t_pad, wb_pad, wl_pad


class Retriever:
    """Facade over a registered engine; the seam all serving/benchmark
    layers call through (and later scaling work plugs into)."""

    def __init__(self, engine, params: TwoLevelParams,
                 k_buckets=K_BUCKETS, generation: int = 0,
                 metrics=None):
        self.engine = engine
        self.params = params
        # sorted: bucket_k picks the first bucket >= k in iteration order
        self.k_buckets = tuple(sorted(k_buckets)) if k_buckets else None
        # index generation tag: bumped by the serving hot-swap gate and
        # stamped on every response so stale replicas are detectable
        self.generation = generation
        # optional obs.MetricsRegistry: each search records its wall
        # latency into a per-engine histogram (search_ms/<engine>)
        self.metrics = metrics
        self._hist_search = (
            None if metrics is None
            else metrics.histogram(f"search_ms/{self.engine_name}"))

    @classmethod
    def open(cls, index, params: TwoLevelParams | None = None,
             engine: str = "batched", *, k_buckets=K_BUCKETS,
             generation: int = 0, metrics=None,
             **engine_opts) -> "Retriever":
        """Build a retriever: ``index`` + pruning ``params`` + an engine
        name from the registry. ``index`` may be a fp32
        ``BlockedImpactIndex``, a ``repro.index.CompressedImpactIndex``
        (decode-on-gather; every sparse engine serves it transparently),
        or a ``HybridIndex`` wrapping either. ``engine_opts`` go to the
        engine constructor (e.g. ``n_shards=4, exchange_every=8`` for
        ``"sharded"``, ``warmup=False`` for ``"sequential"``);
        ``metrics`` an optional ``repro.obs.MetricsRegistry`` that
        collects per-engine search latency histograms."""
        params = params if params is not None else TwoLevelParams()
        eng = get_engine(engine)(index, params, **engine_opts)
        return cls(eng, params, k_buckets=k_buckets, generation=generation,
                   metrics=metrics)

    @property
    def engine_name(self) -> str:
        return self.engine.name

    def replicate(self) -> "Retriever":
        """A cheap serving replica: a fresh engine instance with the same
        configuration **sharing the open index arrays** (no index
        rebuild; the sharded engine hands over its partitioned tile
        ranges). The executor pool clones one per worker so concurrent
        batches never share a dispatch surface; jit caches are
        process-global, so a warmed grid stays warm for every replica."""
        replicate = getattr(self.engine, "replicate", None)
        if replicate is None:
            raise TypeError(
                f"engine {self.engine_name!r} does not support replica "
                f"cloning (no .replicate); executor pools need it")
        return Retriever(replicate(self.params), self.params,
                         k_buckets=self.k_buckets,
                         generation=self.generation,
                         metrics=self.metrics)

    def search(self, request: SearchRequest | None = None, *,
               terms=None, weights_b=None, weights_l=None, dense=None,
               k=None,
               threshold_factor: float | None = None) -> SearchResponse:
        """Execute one request (a SearchRequest, or its fields as kwargs).

        ``k`` falls back to the request default (DEFAULT_K, honoring a
        legacy ``TwoLevelParams(k=...)`` stash). ids/scores come back
        truncated to the requested ``k`` even when the engine executed at
        a larger bucket.

        ``k`` may also be a per-query [B] sequence (mixed-k batch): the
        engine runs *once* at the bucket of the largest entry and each
        row is truncated back to its own depth — slots beyond a row's k
        hold the empty-queue sentinels (id -1, score -inf), and
        ``SearchResponse.ks`` records the per-row depths."""
        if request is None:
            request = SearchRequest(
                terms=terms, weights_b=weights_b, weights_l=weights_l,
                dense=dense, k=k, threshold_factor=threshold_factor)
        elif any(v is not None for v in (terms, weights_b, weights_l,
                                         dense, k, threshold_factor)):
            raise TypeError("pass either a SearchRequest or field kwargs, "
                            "not both")
        ks = resolve_ks(request.k, request.batch_size())
        if ks is None:
            k_req = resolve_k(self.params, request.k)
        else:
            k_req = int(ks.max())
        k_exec = bucket_k(k_req, self.k_buckets)
        params = self.params
        if request.threshold_factor is not None:
            params = params.replace(
                threshold_factor=float(request.threshold_factor))

        if request.terms is not None:
            q_terms, qw_b, qw_l = _pad_queries(
                request.terms, request.weights_b, request.weights_l)
        else:
            q_terms = qw_b = qw_l = None

        t0 = time.perf_counter()
        res = self.engine.search(q_terms, qw_b, qw_l, request.dense,
                                 k=k_exec, params=params)
        latency_ms = (time.perf_counter() - t0) * 1e3
        if self._hist_search is not None:
            self._hist_search.record(latency_ms)
        ids = np.asarray(res.ids)[:, :k_req]
        scores = np.asarray(res.scores)[:, :k_req]
        if ks is None:
            ks = np.full(ids.shape[0], k_req, np.int32)
        elif (ks < k_req).any():
            # mixed-k batch: mask each row beyond its own requested depth
            # with the engines' empty-queue sentinels
            dead = np.arange(k_req)[None, :] >= ks[:, None]
            ids = np.where(dead, np.int32(-1), ids)
            scores = np.where(dead, np.float32(-np.inf), scores)
        return SearchResponse(
            ids=ids, scores=scores,
            engine=self.engine_name, k=k_req, k_exec=k_exec,
            stats=res.stats, latency_ms=latency_ms,
            latencies_ms=res.latencies_ms, ks=ks,
            generation=self.generation)
