"""Unified public search API over the 2GTI traversal engines.

The paper's central tradeoff — pruning aggressiveness vs. relevance — is
swept over retrieval depth *k* and engine variant; this package is the
one seam those sweeps (and every serving/scaling layer) go through:

  - :mod:`contract` — ``SearchRequest`` / ``SearchResponse`` with
    per-call ``k`` and ``threshold_factor``; ``K_BUCKETS`` static-shape
    depth buckets;
  - :mod:`engines` — the ``Engine`` protocol and string-keyed registry
    (``batched`` / ``kernel`` / ``sequential`` / ``sharded`` / ``dense``
    plus the hybrid ``cascade`` / ``rrf``), all sparse engines driven by
    the single ``core.plan`` planner;
  - :mod:`hybrid` — the sparse+dense substrate (``HybridIndex``,
    ``build_hybrid_index``, query embedding bridge, jitted dense rerank,
    ``rrf_fuse``) the hybrid engines run on;
  - :mod:`retriever` — the ``Retriever`` facade
    (``Retriever.open(index, params, engine=...)`` → ``.search(...)``)
    handling padding, k-bucketing, and engine dispatch.

Legacy entry points (``core.traversal.retrieve_batched`` / ``_sequential``,
``serve.sharded.shard_retrieve_batched``, ``core.dense_guided.
retrieve_dense``) remain as thin bit-identical wrappers the engines call.
"""
from .contract import (K_BUCKETS, SearchRequest, SearchResponse,  # noqa: F401
                       bucket_k, resolve_ks)
from .engines import (Engine, engine_names, get_engine,  # noqa: F401
                      register_engine)
from .hybrid import (HybridIndex, build_hybrid_index,  # noqa: F401
                     dense_topk, embed_queries, rerank_candidates,
                     rrf_fuse)
from .retriever import Retriever  # noqa: F401
