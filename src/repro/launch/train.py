"""Training launcher: --arch <id> [--shape train_4k] [--smoke].

On this CPU container the default is the reduced (smoke) configuration —
the full configs are exercised via dryrun.py. On real hardware, drop
--smoke and set --dp/--tp to the cluster shape.
"""
import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.data.stream import (GraphStore, lm_batch, molecule_batch,
                               recsys_batch)
from repro.launch.steps import adapt_config, init_fn, loss_fn
from repro.models.transformer import NO_RULES
from repro.train.optimizer import AdamWConfig
from repro.train.trainer import Trainer, TrainerConfig


def data_provider(arch, shape, cfg, batch_size):
    fam = arch.family
    if fam == "lm":
        return lambda step: lm_batch(step, batch=batch_size, seq=64,
                                     vocab=cfg.vocab)
    if fam == "gnn":
        if shape == "molecule":
            return lambda step: molecule_batch(
                step, batch=batch_size, atoms=8, edges=16,
                n_types=cfg.n_atom_types)
        store = GraphStore(2048, 8192, cfg.d_feat, cfg.n_out)
        return lambda step: {k: jax.numpy.asarray(v) for k, v in
                             store.sample(step, 64).items()}
    from repro.models import recsys as R
    if isinstance(cfg, R.DLRMConfig):
        return lambda step: recsys_batch(step, kind="dlrm", cfg=cfg,
                                         batch=batch_size)
    if isinstance(cfg, R.DINConfig):
        return lambda step: recsys_batch(step, kind="din", cfg=cfg,
                                         batch=batch_size)
    # two-tower / bert4rec: reuse smoke batches keyed by step
    from repro.launch.steps import smoke_batch
    def fn(step):
        b = smoke_batch(arch, shape, cfg, seed=step)
        return b["batch"] if "batch" in b else b
    return fn


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    arch = get_arch(args.arch)
    shape = args.shape or {"lm": "train_4k", "gnn": "molecule",
                           "recsys": "train_batch"}[arch.family]
    cfg = adapt_config(arch, shape, arch.smoke() if args.smoke else None)
    out = args.out or f"runs/{args.arch}"
    lfn = loss_fn(arch, shape, cfg, NO_RULES)
    trainer = Trainer(
        lfn, init_fn(arch, shape, cfg),
        data_provider(arch, shape, cfg, args.batch),
        TrainerConfig(total_steps=args.steps, ckpt_every=max(args.steps // 2,
                                                             10),
                      out_dir=out, log_every=5),
        AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=args.steps))
    res = trainer.run()
    print(f"{args.arch}/{shape}: loss {res['losses'][0]:.4f} -> "
          f"{np.mean(res['losses'][-5:]):.4f} over {args.steps} steps")


if __name__ == "__main__":
    main()
