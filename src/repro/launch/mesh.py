"""Production mesh builders. Functions, not module constants — importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi_pod adds a 2-pod leading axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(dp: int, tp: int, pods: int = 1):
    """Elastic mesh builder for arbitrary DP/TP splits (--dp/--tp)."""
    if pods > 1:
        return jax.make_mesh((pods, dp, tp), ("pod", "data", "model"))
    return jax.make_mesh((dp, tp), ("data", "model"))


def dp_axes(mesh) -> tuple:
    """Mesh axes that carry data parallelism (pod axis folds into DP)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def model_axis(mesh):
    return "model" if "model" in mesh.axis_names else None
