"""Serving launcher: batched 2GTI retrieval over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --preset splade_like
"""
import argparse

from repro.core import build_index, twolevel
from repro.data import make_corpus
from repro.serve import Request, RetrievalServer, ServerConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="splade_like")
    ap.add_argument("--docs", type=int, default=16384)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    corpus = make_corpus(args.preset, n_docs=args.docs, n_terms=4096,
                         n_queries=64)
    index = build_index(corpus.merged("scaled"), tile_size=1024)
    params = twolevel.fast(k=args.k, beta=args.beta).replace(
        schedule="impact")
    srv = RetrievalServer(index, params, ServerConfig(max_batch=16))
    reqs = [Request(corpus.queries[i % 64], corpus.q_weights_b[i % 64],
                    corpus.q_weights_l[i % 64])
            for i in range(args.requests)]
    stats = srv.run_workload(reqs, qps=args.qps)
    print(stats)


if __name__ == "__main__":
    main()
