"""Serving launcher: the async scheduler over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --preset splade_like
    PYTHONPATH=src python -m repro.launch.serve --routing table8 --cache 256
    PYTHONPATH=src python -m repro.launch.serve --shards 4 --host-devices 4
    repro-serve --engine kernel --k 100        # installed console script

Requests go through ``repro.serve.AsyncRetrievalScheduler``: mixed-k
micro-batches (``--k-mix`` draws per-request depths), query-length
routing (``--routing table8``; ``--engine``/``--shards`` configure the
single-route policy otherwise), and an LRU response cache (``--cache N``
entries; the workload repeats queries, so hits show up immediately in
the printed stats). ``--shards N`` uses a one-axis mesh when N devices
exist (``--host-devices`` fakes them on CPU), else the single-device
vmap emulation path (bit-identical results).

Observability: ``--metrics-port N`` serves the live registry over HTTP
(``/metrics`` Prometheus text, ``/metrics.json``, ``/traces``; port 0
binds an ephemeral port and prints it); ``--trace`` turns on
per-request span recording and prints the slowest request's trace
after the run; ``--cost-model PATH`` loads a fitted
``obs.cost.CostModel`` (see ``scripts/fit_cost_model.py``) and enables
cost-sorted batch dispatch.

Heavy imports live inside ``main`` so ``cli`` (the ``repro-serve`` entry
point) can fix up ``XLA_FLAGS`` before jax initializes.
"""
import argparse
import os
import sys


def _preparse_host_devices(argv=None) -> None:
    """--host-devices must reach XLA before the backend initializes, i.e.
    before any repro import triggers a jnp array build. Appends to any
    pre-existing XLA_FLAGS; malformed values fall through to argparse; a
    conflicting pre-existing device count wins, with a warning."""
    argv = sys.argv if argv is None else argv
    n = None
    for i, tok in enumerate(argv):
        if tok == "--host-devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif tok.startswith("--host-devices="):
            n = tok.split("=", 1)[1]
    if n is None or not n.isdigit():
        return
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in prev:
        if f"xla_force_host_platform_device_count={n}" not in prev:
            print(f"# warning: XLA_FLAGS already pins a device count; "
                  f"--host-devices {n} is ignored ({prev})", file=sys.stderr)
        return
    os.environ["XLA_FLAGS"] = (
        f"{prev} --xla_force_host_platform_device_count={n}".strip())


def main() -> None:
    import jax
    import numpy as np

    from repro.core import build_index, twolevel
    from repro.data import make_corpus
    from repro.retrieval import SearchRequest, engine_names
    from repro.serve import (AsyncRetrievalScheduler, RetryPolicy,
                             SchedulerConfig, make_shard_mesh,
                             run_workload, single_route, table8_policy)

    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="splade_like")
    ap.add_argument("--docs", type=int, default=16384)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=10,
                    help="retrieval depth per request")
    ap.add_argument("--k-mix", type=int, nargs="*", default=None,
                    help="draw per-request depths from this set "
                         "(mixed-k micro-batching), e.g. --k-mix 10 100")
    ap.add_argument("--engine", default="batched",
                    choices=sorted(set(engine_names()) - {"dense"}),
                    help="retrieval engine for the single-route policy")
    ap.add_argument("--routing", default="none",
                    choices=("none", "table8"),
                    help="query-length routing policy (Table 8)")
    ap.add_argument("--cache", type=int, default=0,
                    help="LRU response-cache entries (0 = off)")
    ap.add_argument("--max-batch", type=int, default=16)
    ap.add_argument("--executors", type=int, default=0,
                    help="executor-pool worker threads, each with its "
                         "own Retriever replica (0 = sync inline "
                         "dispatch, the deterministic default)")
    ap.add_argument("--admission-limit", type=int, default=0,
                    help="bounded admission queue: max pending rows "
                         "(0 = unbounded)")
    ap.add_argument("--admission-policy", default="block",
                    choices=("block", "reject", "shed"),
                    help="what submit() does when the admission queue "
                         "is full")
    ap.add_argument("--aging-ms", type=float, default=0.0,
                    help="priority aging: a queued request gains one "
                         "priority level per this many ms waited "
                         "(0 = strict priority)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: still-queued requests "
                         "are shed when the budget runs out, and the "
                         "workload reports goodput next to QPS")
    ap.add_argument("--retries", type=int, default=0,
                    help="max execution attempts per batch (0/1 = fail "
                         "on first error); failed batches requeue with "
                         "deterministic exponential backoff")
    ap.add_argument("--hedge", type=float, default=0.0,
                    help="hedge straggler batches after this many ms "
                         "in flight (0 = off; needs --executors >= 2); "
                         "first result wins")
    ap.add_argument("--swap-demo", action="store_true",
                    help="hot-swap demo: rebuild the index mid-stream "
                         "and swap it in behind the two-phase gate, "
                         "then report the generation + cache evictions")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the index over N tile-range shards "
                         "(implies --engine sharded)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices (must be set at launch)")
    ap.add_argument("--exchange-every", type=int, default=0,
                    help="all-gather global theta_Gl every E tiles")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus), /metrics.json "
                         "and /traces on this port while the workload "
                         "runs (0 = ephemeral, printed at startup)")
    ap.add_argument("--trace", action="store_true",
                    help="record per-request spans; the slowest "
                         "request's trace prints after the run")
    ap.add_argument("--cost-model", default=None, metavar="PATH",
                    help="load a fitted obs.cost.CostModel (JSON from "
                         "scripts/fit_cost_model.py) and sort batches "
                         "by predicted chunk count")
    args = ap.parse_args()
    corpus = make_corpus(args.preset, n_docs=args.docs, n_terms=4096,
                         n_queries=64)
    index = build_index(corpus.merged("scaled"), tile_size=1024)
    params = twolevel.fast(beta=args.beta).replace(schedule="impact")

    if args.shards > 1 or args.engine == "sharded":
        if args.routing != "none":
            ap.error("--shards/--engine sharded cannot combine with "
                     "--routing (the sharded engine is a single route); "
                     "drop one of the flags")
        mesh = (make_shard_mesh(args.shards)
                if 1 < args.shards <= len(jax.devices()) else None)
        routing = single_route("sharded", n_shards=args.shards, mesh=mesh,
                               exchange_every=args.exchange_every)
        path = "mesh" if mesh is not None else "emulated"
        print(f"# sharded serving: {args.shards} shards ({path})")
    elif args.routing == "table8":
        # --engine still matters under routing: it serves the long class
        routing = table8_policy(long_engine=args.engine)
        print(f"# routing: table8 (short -> fine chunks, "
              f"long -> {args.engine})")
    else:
        routing = single_route(args.engine)
        print(f"# serving engine: {args.engine}")

    retry = (RetryPolicy(max_attempts=args.retries)
             if args.retries > 1 else None)
    from repro.obs import CostModel, MetricsRegistry, Tracer
    tracer = Tracer() if args.trace else None
    registry = MetricsRegistry()
    cost_model = (CostModel.load(args.cost_model)
                  if args.cost_model else None)
    if cost_model is not None:
        print(f"# cost model: {args.cost_model} "
              f"(r2={cost_model.r2:.3f}, n={cost_model.n_samples}) — "
              f"cost-sorted dispatch on")
    sched = AsyncRetrievalScheduler(
        index, params,
        SchedulerConfig(max_batch=args.max_batch, cache_size=args.cache,
                        executors=args.executors,
                        admission_limit=args.admission_limit,
                        admission_policy=args.admission_policy,
                        aging_ms=args.aging_ms, retry=retry,
                        hedge_ms=args.hedge,
                        tracer=tracer, metrics=registry,
                        cost_model=cost_model,
                        sort_batches_by_cost=cost_model is not None),
        routing=routing)
    server = None
    if args.metrics_port is not None:
        from repro.obs import MetricsServer
        server = MetricsServer(registry, tracer,
                               port=args.metrics_port,
                               extra=sched.stats)
        print(f"# metrics: http://127.0.0.1:{server.port}/metrics "
              f"(.json, /traces)")
    rng = np.random.default_rng(0)
    k_pool = args.k_mix if args.k_mix else [args.k]
    reqs = [SearchRequest(terms=corpus.queries[i % 64],
                          weights_b=corpus.q_weights_b[i % 64],
                          weights_l=corpus.q_weights_l[i % 64],
                          k=int(rng.choice(k_pool)),
                          deadline_ms=args.deadline_ms)
            for i in range(args.requests)]
    if args.swap_demo:
        # serve half the stream, hot-swap a rebuilt index, serve the rest
        mid = len(reqs) // 2
        if args.executors > 0:
            sched.start()
        stats = run_workload(sched, reqs[:mid], qps=args.qps)
        gen = sched.swap_index(
            build_index(corpus.merged("scaled"), tile_size=1024))
        print(f"# hot-swap: installed generation {gen} "
              f"(cache evictions: "
              f"{sched.stats()['cache_gen_evictions']})")
        stats = run_workload(sched, reqs[mid:], qps=args.qps)
        if args.executors > 0:
            sched.close()
    elif args.executors > 0:
        print(f"# executor pool: {args.executors} workers "
              f"(warming routing grid...)")
        with sched:
            stats = run_workload(sched, reqs, qps=args.qps)
    else:
        stats = run_workload(sched, reqs, qps=args.qps)
    print(stats)
    if tracer is not None:
        slow = tracer.slowest("request")
        if slow is not None:
            print(f"# slowest request (trace {slow}):")
            for span in tracer.trace(slow):
                print(f"#   {span['name']}: "
                      f"{(span['t_end'] - span['t_start']) * 1e3:.2f}ms "
                      f"{span['attrs']}")
    if server is not None:
        server.close()


def cli() -> None:
    """`repro-serve` console entry: env fix-up, then the real main."""
    _preparse_host_devices()
    main()


if __name__ == "__main__":
    cli()
