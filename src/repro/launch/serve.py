"""Serving launcher: batched 2GTI retrieval over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --preset splade_like
    PYTHONPATH=src python -m repro.launch.serve --shards 4 --host-devices 4

``--shards N`` serves through the mesh-sharded engine: a one-axis mesh
when N devices exist (``--host-devices`` fakes them on CPU), else the
single-device vmap emulation path (bit-identical results).
"""
import argparse
import os
import sys


def _preparse_host_devices() -> None:
    """--host-devices must reach XLA before the backend initializes, i.e.
    before any repro import triggers a jnp array build. Appends to any
    pre-existing XLA_FLAGS; malformed values fall through to argparse; a
    conflicting pre-existing device count wins, with a warning."""
    n = None
    for i, tok in enumerate(sys.argv):
        if tok == "--host-devices" and i + 1 < len(sys.argv):
            n = sys.argv[i + 1]
        elif tok.startswith("--host-devices="):
            n = tok.split("=", 1)[1]
    if n is None or not n.isdigit():
        return
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in prev:
        if f"xla_force_host_platform_device_count={n}" not in prev:
            print(f"# warning: XLA_FLAGS already pins a device count; "
                  f"--host-devices {n} is ignored ({prev})", file=sys.stderr)
        return
    os.environ["XLA_FLAGS"] = (
        f"{prev} --xla_force_host_platform_device_count={n}".strip())


if __name__ == "__main__":  # importers must not get argv-driven env edits
    _preparse_host_devices()

import jax  # noqa: E402

from repro.core import build_index, twolevel  # noqa: E402
from repro.data import make_corpus  # noqa: E402
from repro.serve import (Request, RetrievalServer, ServerConfig,  # noqa: E402
                         ShardedRetrievalServer, make_shard_mesh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="splade_like")
    ap.add_argument("--docs", type=int, default=16384)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the index over N tile-range shards")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices (must be set at launch)")
    ap.add_argument("--exchange-every", type=int, default=0,
                    help="all-gather global theta_Gl every E tiles")
    args = ap.parse_args()
    corpus = make_corpus(args.preset, n_docs=args.docs, n_terms=4096,
                         n_queries=64)
    index = build_index(corpus.merged("scaled"), tile_size=1024)
    params = twolevel.fast(k=args.k, beta=args.beta).replace(
        schedule="impact")
    if args.shards > 1:
        mesh = (make_shard_mesh(args.shards)
                if len(jax.devices()) >= args.shards else None)
        srv = ShardedRetrievalServer(
            index, params, ServerConfig(max_batch=16),
            n_shards=args.shards, mesh=mesh,
            exchange_every=args.exchange_every)
        path = "mesh" if mesh is not None else "emulated"
        print(f"# sharded serving: {args.shards} shards ({path})")
    else:
        srv = RetrievalServer(index, params, ServerConfig(max_batch=16))
    reqs = [Request(corpus.queries[i % 64], corpus.q_weights_b[i % 64],
                    corpus.q_weights_l[i % 64])
            for i in range(args.requests)]
    stats = srv.run_workload(reqs, qps=args.qps)
    print(stats)


if __name__ == "__main__":
    main()
