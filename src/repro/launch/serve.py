"""Serving launcher: batched 2GTI retrieval over a synthetic corpus.

    PYTHONPATH=src python -m repro.launch.serve --preset splade_like
    PYTHONPATH=src python -m repro.launch.serve --shards 4 --host-devices 4
    repro-serve --engine kernel --k 100        # installed console script

``--engine`` picks any name from the ``repro.retrieval`` registry
(``--shards N > 1`` implies ``sharded``): the server always goes through
the ``Retriever`` facade. ``--shards N`` uses a one-axis mesh when N
devices exist (``--host-devices`` fakes them on CPU), else the
single-device vmap emulation path (bit-identical results).

Heavy imports live inside ``main`` so ``cli`` (the ``repro-serve`` entry
point) can fix up ``XLA_FLAGS`` before jax initializes.
"""
import argparse
import os
import sys


def _preparse_host_devices(argv=None) -> None:
    """--host-devices must reach XLA before the backend initializes, i.e.
    before any repro import triggers a jnp array build. Appends to any
    pre-existing XLA_FLAGS; malformed values fall through to argparse; a
    conflicting pre-existing device count wins, with a warning."""
    argv = sys.argv if argv is None else argv
    n = None
    for i, tok in enumerate(argv):
        if tok == "--host-devices" and i + 1 < len(argv):
            n = argv[i + 1]
        elif tok.startswith("--host-devices="):
            n = tok.split("=", 1)[1]
    if n is None or not n.isdigit():
        return
    prev = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" in prev:
        if f"xla_force_host_platform_device_count={n}" not in prev:
            print(f"# warning: XLA_FLAGS already pins a device count; "
                  f"--host-devices {n} is ignored ({prev})", file=sys.stderr)
        return
    os.environ["XLA_FLAGS"] = (
        f"{prev} --xla_force_host_platform_device_count={n}".strip())


def main() -> None:
    import jax

    from repro.core import build_index, twolevel
    from repro.data import make_corpus
    from repro.retrieval import engine_names
    from repro.serve import (Request, RetrievalServer, ServerConfig,
                             ShardedRetrievalServer, make_shard_mesh)

    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="splade_like")
    ap.add_argument("--docs", type=int, default=16384)
    ap.add_argument("--qps", type=float, default=200.0)
    ap.add_argument("--requests", type=int, default=128)
    ap.add_argument("--beta", type=float, default=0.3)
    ap.add_argument("--k", type=int, default=10,
                    help="retrieval depth per request")
    ap.add_argument("--engine", default="batched",
                    choices=sorted(set(engine_names()) - {"dense"}),
                    help="retrieval engine (registry name)")
    ap.add_argument("--shards", type=int, default=1,
                    help="partition the index over N tile-range shards "
                         "(implies --engine sharded)")
    ap.add_argument("--host-devices", type=int, default=None,
                    help="fake N host devices (must be set at launch)")
    ap.add_argument("--exchange-every", type=int, default=0,
                    help="all-gather global theta_Gl every E tiles")
    args = ap.parse_args()
    corpus = make_corpus(args.preset, n_docs=args.docs, n_terms=4096,
                         n_queries=64)
    index = build_index(corpus.merged("scaled"), tile_size=1024)
    params = twolevel.fast(beta=args.beta).replace(schedule="impact")
    if args.shards > 1 or args.engine == "sharded":
        mesh = (make_shard_mesh(args.shards)
                if 1 < args.shards <= len(jax.devices()) else None)
        srv = ShardedRetrievalServer(
            index, params, ServerConfig(max_batch=16),
            n_shards=args.shards, mesh=mesh,
            exchange_every=args.exchange_every, k=args.k)
        path = "mesh" if mesh is not None else "emulated"
        print(f"# sharded serving: {args.shards} shards ({path})")
    else:
        srv = RetrievalServer(index, params, ServerConfig(max_batch=16),
                              engine=args.engine, k=args.k)
        print(f"# serving engine: {args.engine}")
    reqs = [Request(corpus.queries[i % 64], corpus.q_weights_b[i % 64],
                    corpus.q_weights_l[i % 64])
            for i in range(args.requests)]
    stats = srv.run_workload(reqs, qps=args.qps)
    print(stats)


def cli() -> None:
    """`repro-serve` console entry: env fix-up, then the real main."""
    _preparse_host_devices()
    main()


if __name__ == "__main__":
    cli()
