"""Step factory: (arch x shape) -> the exact callable the dry-run lowers,
the trainer executes, and the smoke tests run at reduced scale.

Train steps: state {"params", "opt"} x batch -> (state, metrics), AdamW.
Serve steps: family-specific (prefill/decode/scoring/retrieval).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ArchSpec
from ..configs.shapes import (GNN_SHAPE_DEFS, LM_SHAPE_DEFS,
                              RECSYS_SHAPE_DEFS, input_specs)
from ..models import recsys as R
from ..models import schnet as S
from ..models import transformer as T
from ..train.optimizer import AdamWConfig, adamw_init, adamw_update
from ..sparse_ops import embedding_bag

TOPK_SERVE = 100


def _topk(scores, k=TOPK_SERVE):
    return jax.lax.top_k(scores, min(k, scores.shape[-1]))


def adapt_config(arch: ArchSpec, shape: str, cfg=None):
    """Per-shape config adjustments (SchNet graph-mode d_feat/classes)."""
    import dataclasses
    cfg = cfg if cfg is not None else arch.config()
    if arch.family == "gnn" and shape != "molecule":
        d = GNN_SHAPE_DEFS[shape]
        return dataclasses.replace(cfg, d_feat=d["d_feat"],
                                    n_out=d["classes"])
    return cfg


def init_fn(arch: ArchSpec, shape: str, cfg):
    fam = arch.family
    if fam == "lm":
        return lambda key: T.init_params(cfg, key)
    if fam == "gnn":
        return lambda key: S.init_params(cfg, key)
    if isinstance(cfg, R.DLRMConfig):
        return lambda key: R.init_dlrm(cfg, key)
    if isinstance(cfg, R.DINConfig):
        return lambda key: R.init_din(cfg, key)
    if isinstance(cfg, R.TwoTowerConfig):
        return lambda key: R.init_two_tower(cfg, key)
    if isinstance(cfg, R.Bert4RecConfig):
        return lambda key: R.init_bert4rec(cfg, key)
    raise TypeError(type(cfg))


def loss_fn(arch: ArchSpec, shape: str, cfg, rules: T.Rules):
    fam = arch.family
    if fam == "lm":
        return lambda p, b: T.lm_loss(cfg, p, b, rules)
    if fam == "gnn":
        if shape == "molecule":
            return lambda p, b: S.molecule_loss(cfg, p, b)
        return lambda p, b: S.node_loss(cfg, p, b)
    if isinstance(cfg, R.DLRMConfig):
        return lambda p, b: R.dlrm_loss(cfg, p, b, rules)
    if isinstance(cfg, R.DINConfig):
        return lambda p, b: R.din_loss(cfg, p, b, rules)
    if isinstance(cfg, R.TwoTowerConfig):
        return lambda p, b: R.two_tower_loss(cfg, p, b, rules)
    if isinstance(cfg, R.Bert4RecConfig):
        return lambda p, b: R.bert4rec_loss(cfg, p, b, rules)
    raise TypeError(type(cfg))


def make_train_step(arch: ArchSpec, shape: str, cfg, rules: T.Rules,
                    opt_cfg: AdamWConfig | None = None,
                    grad_shardings=None):
    """``grad_shardings``: optional pytree of NamedSharding — constrains
    gradients to the optimizer-state layout right after autodiff, which
    turns GSPMD's full-gradient all-reduce into a reduce-scatter (ZeRO)."""
    opt_cfg = opt_cfg or AdamWConfig()
    lfn = loss_fn(arch, shape, cfg, rules)

    def step(state, batch):
        loss, grads = jax.value_and_grad(lfn)(state["params"], batch)
        if grad_shardings is not None:
            grads = jax.tree_util.tree_map(
                jax.lax.with_sharding_constraint, grads, grad_shardings)
        params, opt, metrics = adamw_update(opt_cfg, grads, state["opt"],
                                            state["params"])
        metrics["loss"] = loss
        return {"params": params, "opt": opt}, metrics

    return step


# --------------------------------------------------------------------------
# serve steps
# --------------------------------------------------------------------------

def _dlrm_score_candidates(cfg, params, user, cand_ids, rules):
    """One user context x N candidate items (26th sparse field varies)."""
    n = cand_ids.shape[0]
    cd = cfg.compute_dtype
    bot = R._mlp(params["bot"], user["dense"].astype(cd), final_act=True)
    user_embs = [embedding_bag(params["tables"][f].astype(cd),
                               user["sparse"][:, f, :],
                               jnp.ones((1, cfg.multi_hot), cd))
                 for f in range(cfg.n_sparse - 1)]
    cand = jnp.take(params["tables"][cfg.n_sparse - 1], cand_ids,
                    axis=0).astype(cd)                        # [N, D]
    fixed = jnp.concatenate([bot] + user_embs, axis=0)        # [26, D]
    feats = jnp.concatenate(
        [jnp.broadcast_to(fixed[None], (n,) + fixed.shape), cand[:, None]],
        axis=1)                                               # [N, 27, D]
    inter = jnp.einsum("bnd,bmd->bnm", feats, feats)
    iu, ju = jnp.triu_indices(feats.shape[1], k=1)
    flat = inter[:, iu, ju]
    top_in = jnp.concatenate(
        [jnp.broadcast_to(bot, (n, bot.shape[1])), flat], axis=-1)
    return R._mlp(params["top"], top_in)[:, 0]


def make_serve_step(arch: ArchSpec, shape: str, cfg, rules: T.Rules,
                    mesh=None, sharded_topk: bool = False):
    fam = arch.family
    spec = None
    if fam == "lm":
        kind = LM_SHAPE_DEFS[shape]["kind"]
        if kind == "prefill":
            max_len = LM_SHAPE_DEFS[shape]["seq"]

            def step(params, tokens):
                return T.prefill(cfg, params, tokens, max_len, rules)
            return step
        if kind == "decode":
            def step(params, token, cache, cache_len):
                return T.decode_step(cfg, params, token, cache, cache_len,
                                     rules)
            return step
        raise ValueError(f"no serve step for LM shape {shape}")
    if fam == "gnn":
        raise ValueError("GNN cells are train-step cells")
    del spec
    kind = RECSYS_SHAPE_DEFS[shape]["kind"]
    if isinstance(cfg, R.DLRMConfig):
        if kind == "serve":
            return lambda params, batch: R.dlrm_forward(cfg, params, batch,
                                                        rules)
        def dlrm_retr(params, user, cand_ids):
            s = _dlrm_score_candidates(cfg, params, user, cand_ids, rules)
            vals, idx = _topk(s)
            return vals, cand_ids[idx]
        return dlrm_retr
    if isinstance(cfg, R.DINConfig):
        if kind == "serve":
            return lambda params, batch: R.din_forward(cfg, params, batch,
                                                       rules)
        def din_retr(params, hist, cand_ids):
            n = cand_ids.shape[0]
            batch = {"hist": jnp.broadcast_to(hist, (n, hist.shape[1])),
                     "target": cand_ids}
            s = R.din_forward(cfg, params, batch, rules)
            vals, idx = _topk(s)
            return vals, cand_ids[idx]
        return din_retr
    if isinstance(cfg, R.TwoTowerConfig):
        if kind == "serve":
            def tt_serve(params, user_feats, shortlist):
                u = R.user_encode(cfg, params, user_feats, rules)
                v = R.item_encode(cfg, params, shortlist, rules)
                return u @ v.T
            return tt_serve
        if sharded_topk and mesh is not None:
            from jax.experimental.shard_map import shard_map
            from jax.sharding import PartitionSpec as P
            axes = tuple(mesh.axis_names)
            import numpy as _np
            n_shards = int(_np.prod([mesh.shape[a] for a in axes]))

            def tt_retr_sharded(params, user_feats, cand_emb):
                u = R.user_encode(cfg, params, user_feats, rules)[0]
                local_n = cand_emb.shape[0] // n_shards
                kk = min(TOPK_SERVE, local_n)

                def local(ce, uu):
                    s = ce @ uu
                    v, i = jax.lax.top_k(s, kk)
                    flat = jax.lax.axis_index(axes[0])
                    for a in axes[1:]:
                        flat = flat * mesh.shape[a] + jax.lax.axis_index(a)
                    return v, i + flat * local_n

                v, i = shard_map(local, mesh=mesh,
                                 in_specs=(P(axes, None), P()),
                                 out_specs=(P(axes), P(axes)))(cand_emb, u)
                tv, ti = jax.lax.top_k(v, TOPK_SERVE)
                return tv, i[ti]
            return tt_retr_sharded

        def tt_retr(params, user_feats, cand_emb):
            s = R.two_tower_score_candidates(cfg, params, user_feats,
                                             cand_emb, rules)
            return _topk(s)
        return tt_retr
    if isinstance(cfg, R.Bert4RecConfig):
        if kind == "serve":
            return lambda params, items, cand_ids: R.bert4rec_score_catalog(
                cfg, params, items, cand_ids, rules)
        def b4r_retr(params, items, cand_ids):
            s = R.bert4rec_score_catalog(cfg, params, items, cand_ids,
                                         rules)[0]
            vals, idx = _topk(s)
            return vals, cand_ids[idx]
        return b4r_retr
    raise TypeError(type(cfg))


def state_specs(arch: ArchSpec, shape: str, cfg):
    """ShapeDtypeStructs of the train state (no allocation)."""
    init = init_fn(arch, shape, cfg)
    params = jax.eval_shape(lambda: init(jax.random.PRNGKey(0)))
    opt = jax.eval_shape(lambda: adamw_init(
        jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype), params)))
    return {"params": params, "opt": opt}


# --------------------------------------------------------------------------
# smoke batches (small real data for reduced configs)
# --------------------------------------------------------------------------

def smoke_batch(arch: ArchSpec, shape: str, cfg, seed: int = 0):
    rng = np.random.default_rng(seed)
    fam = arch.family
    if fam == "lm":
        kind = LM_SHAPE_DEFS[shape]["kind"]
        b, s = 2, 32
        toks = rng.integers(1, cfg.vocab, (b, s + 1))
        if kind == "train":
            return {"batch": {"tokens": jnp.asarray(toks[:, :-1]),
                              "targets": jnp.asarray(toks[:, 1:])}}
        if kind == "prefill":
            return {"tokens": jnp.asarray(toks[:, :-1])}
        hkv, dh, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
        cache = {"k": jnp.zeros((L, b, s, hkv, dh), cfg.compute_dtype),
                 "v": jnp.zeros((L, b, s, hkv, dh), cfg.compute_dtype)}
        return {"token": jnp.asarray(toks[:, :1]), "cache": cache,
                "cache_len": jnp.int32(s - 1)}
    if fam == "gnn":
        if shape == "molecule":
            b, n, e = 4, 8, 16
            return {"batch": {
                "z": jnp.asarray(rng.integers(1, cfg.n_atom_types, (b, n))),
                "pos": jnp.asarray(rng.standard_normal((b, n, 3)),
                                   jnp.float32),
                "edge_src": jnp.asarray(rng.integers(0, n, (b, e))),
                "edge_dst": jnp.asarray(rng.integers(0, n, (b, e))),
                "energy": jnp.asarray(rng.standard_normal(b), jnp.float32)}}
        nn, ee = 64, 256
        return {"batch": {
            "x": jnp.asarray(rng.standard_normal((nn, cfg.d_feat)),
                             jnp.float32),
            "edge_src": jnp.asarray(rng.integers(0, nn, ee)),
            "edge_dst": jnp.asarray(rng.integers(0, nn, ee)),
            "edge_dist": jnp.asarray(rng.random(ee) * cfg.cutoff,
                                     jnp.float32),
            "labels": jnp.asarray(rng.integers(0, cfg.n_out, nn)),
            "train_mask": jnp.ones(nn, jnp.float32)}}
    # recsys
    kind = RECSYS_SHAPE_DEFS[shape]["kind"]
    b = 8
    if isinstance(cfg, R.DLRMConfig):
        feats = {"dense": jnp.asarray(rng.standard_normal((b, cfg.n_dense)),
                                      jnp.float32),
                 "sparse": jnp.asarray(rng.integers(
                     0, cfg.vocab_per_field,
                     (b, cfg.n_sparse, cfg.multi_hot)))}
        if kind == "train":
            return {"batch": {**feats,
                              "label": jnp.asarray(rng.integers(0, 2, b))}}
        if kind == "serve":
            return {"batch": feats}
        return {"user": {"dense": feats["dense"][:1],
                         "sparse": feats["sparse"][:1, :cfg.n_sparse - 1]},
                "cand_ids": jnp.asarray(
                    rng.integers(0, cfg.vocab_per_field, 64))}
    if isinstance(cfg, R.DINConfig):
        base = {"hist": jnp.asarray(rng.integers(0, cfg.n_items,
                                                 (b, cfg.seq_len))),
                "target": jnp.asarray(rng.integers(0, cfg.n_items, b))}
        if kind == "train":
            return {"batch": {**base,
                              "label": jnp.asarray(rng.integers(0, 2, b))}}
        if kind == "serve":
            return {"batch": base}
        return {"hist": base["hist"][:1],
                "cand_ids": jnp.asarray(rng.integers(0, cfg.n_items, 64))}
    if isinstance(cfg, R.TwoTowerConfig):
        uf = jnp.asarray(rng.integers(1, cfg.n_user_feats,
                                      (b, cfg.user_bag)))
        if kind == "train":
            return {"batch": {
                "user_feats": uf,
                "pos_item": jnp.asarray(rng.integers(0, cfg.n_items, b)),
                "neg_items": jnp.asarray(
                    rng.integers(0, cfg.n_items, cfg.n_negatives)),
                "neg_logq": jnp.zeros(cfg.n_negatives, jnp.float32)}}
        if kind == "serve":
            return {"user_feats": uf,
                    "shortlist": jnp.asarray(rng.integers(0, cfg.n_items,
                                                          32))}
        return {"user_feats": uf[:1],
                "cand_emb": jnp.asarray(
                    rng.standard_normal((128, cfg.tower_mlp[-1])),
                    jnp.float32)}
    if isinstance(cfg, R.Bert4RecConfig):
        items = jnp.asarray(rng.integers(0, cfg.n_items, (b, cfg.seq_len)))
        if kind == "train":
            return {"batch": {
                "items": items,
                "targets": jnp.asarray(rng.integers(0, cfg.n_items,
                                                    (b, cfg.seq_len))),
                "mask": jnp.asarray(rng.integers(0, 2, (b, cfg.seq_len))),
                "neg_items": jnp.asarray(rng.integers(0, cfg.n_items, 64))}}
        cand = jnp.asarray(rng.integers(0, cfg.n_items, 32))
        if kind == "serve":
            return {"items": items, "cand_ids": cand}
        return {"items": items[:1], "cand_ids": cand}
    raise TypeError(type(cfg))
