import os
if "--xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " --xla_force_host_platform_device_count=512"
                               ).strip()
# ^ MUST precede every other import: jax locks the device count at first
# init. Merged into any pre-set XLA_FLAGS so a caller that already forces
# a device count (the 8-device subprocess test) keeps its own, while
# unrelated flags don't lose the 512-device emulation. Do NOT replicate
# this in conftest/pyproject — tests see 1 device.

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import all_cells, get_arch  # noqa: E402
from repro.configs.shapes import input_specs  # noqa: E402
from repro.dist.sharding import (activation_rules, input_shardings,  # noqa: E402
                                 opt_shardings, param_shardings)
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.steps import (adapt_config, make_serve_step,  # noqa: E402
                                make_train_step, state_specs)

ART_DIR = pathlib.Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
               "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
               "pred": 1, "c64": 8, "c128": 16}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def cost_stats(compiled) -> dict:
    """Normalize ``compiled.cost_analysis()`` across jax versions — older
    jaxlibs return ``[dict]`` (one per computation), newer return a dict."""
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def collective_bytes(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in partitioned HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        for kind in COLLECTIVES:
            # match op invocations: "%x = TYPE all-reduce(" or fusion roots
            if f" {kind}(" not in ls and f" {kind}-start(" not in ls:
                continue
            lhs = ls.split("=", 1)
            if len(lhs) != 2:
                continue
            m = _SHAPE_RE.findall(lhs[1].split(kind)[0])
            nbytes = 0
            for dt, dims in m:
                if dt not in DTYPE_BYTES:
                    continue
                n = 1
                for d in dims.split(","):
                    if d:
                        n *= int(d)
                nbytes += n * DTYPE_BYTES[dt]
            out[kind]["count"] += 1
            out[kind]["bytes"] += nbytes
            break
    return out


def with_depth(arch, cfg, depth: int | None):
    """Reduced-depth config variant for loop-cost extrapolation (XLA's
    cost_analysis counts while-loop bodies once, ignoring trip count)."""
    import dataclasses
    if depth is None:
        return cfg, None
    # depth probes unroll the layer scan so HLO flops count every layer
    if arch.family == "lm":
        return (dataclasses.replace(cfg, n_layers=depth, unroll=True),
                cfg.n_layers)
    if arch.family == "gnn":
        return (dataclasses.replace(cfg, n_interactions=depth, unroll=True),
                cfg.n_interactions)
    if hasattr(cfg, "n_blocks"):  # bert4rec
        return (dataclasses.replace(cfg, n_blocks=depth, unroll=True),
                cfg.n_blocks)
    return cfg, None  # no scanned depth: costs are already exact


def lower_cell(arch_id: str, shape: str, mesh, depth: int | None = None,
               variant: str = "tp") -> tuple:
    """Build the step fn + (in_shardings, args) for one cell.

    variant "opt" = beyond-paper optimized config per cell kind:
      - LM train: FSDP/ZeRO-3 sharding (no TP activation all-reduces,
        bf16 weight gathers, two-axis param/opt sharding),
      - LM prefill: attention chunk 512 (halves transient score buffers),
      - recsys retrieval: shard_map per-shard top-k (collective = k per
        shard instead of the full candidate score vector).
    """
    import dataclasses
    arch = get_arch(arch_id)
    cfg, _ = with_depth(arch, adapt_config(arch, shape), depth)
    spec0 = input_specs(arch, shape, cfg)
    kind = spec0["kind"]
    eff = variant
    if variant == "opt":
        eff = "fsdp" if (arch.family == "lm" and kind == "train") else "tp"
        if arch.family == "lm" and kind == "prefill":
            cfg = dataclasses.replace(cfg, attn_chunk=512)
        if arch.family == "lm" and kind == "decode":
            cfg = dataclasses.replace(cfg, kv_quant=True)  # int8 KV
    spec = input_specs(arch, shape, cfg)
    rules = activation_rules(mesh, eff)
    in_sh = input_shardings(arch.family, cfg, mesh, spec, eff)
    if spec["kind"] in ("train", "gnn_mol", "gnn_full", "gnn_sampled"):
        st = state_specs(arch, shape, cfg)
        p_sh = param_shardings(arch.family, cfg, mesh, st["params"], eff)
        step = make_train_step(arch, shape, cfg, rules,
                               grad_shardings=p_sh)
        state_sh = {"params": p_sh, "opt": opt_shardings(p_sh)}
        args = (st, spec["inputs"]["batch"])
        shardings = (state_sh, in_sh["batch"])
        donate = (0,)
    else:
        step = make_serve_step(arch, shape, cfg, rules, mesh=mesh,
                               sharded_topk=(variant == "opt"))
        st = state_specs(arch, shape, cfg)["params"]
        p_sh = param_shardings(arch.family, cfg, mesh, st, eff)
        args = (st,) + tuple(spec["inputs"].values())
        shardings = (p_sh,) + tuple(in_sh[k] for k in spec["inputs"])
        donate = (2,) if spec["kind"] == "decode" else ()
    jitted = jax.jit(step, in_shardings=shardings, donate_argnums=donate)
    return jitted, args


def run_cell(arch_id: str, shape: str, mesh, mesh_name: str,
             force: bool = False, variant: str = "tp") -> dict:
    ART_DIR.mkdir(parents=True, exist_ok=True)
    suffix = "" if variant == "tp" else f"__{variant}"
    out_path = ART_DIR / f"{mesh_name}__{arch_id}__{shape}{suffix}.json"
    if out_path.exists() and not force:
        return json.loads(out_path.read_text())
    rec = {"arch": arch_id, "shape": shape, "mesh": mesh_name,
           "variant": variant, "devices": mesh.devices.size, "ok": False}
    t0 = time.time()
    try:
        with mesh:
            jitted, args = lower_cell(arch_id, shape, mesh,
                                      variant=variant)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = cost_stats(compiled)
            coll = collective_bytes(compiled.as_text())
            # Loop-aware cost extrapolation: compile depth-1 and depth-2
            # variants; per-layer cost = f(2) - f(1); total = f(1)+(L-1)*per.
            arch = get_arch(arch_id)
            _, full_depth = with_depth(arch, adapt_config(arch, shape), 1)
            extrap = None
            if full_depth is not None and full_depth > 1:
                probes = []
                for dd in (1, 2):
                    j2, a2 = lower_cell(arch_id, shape, mesh, depth=dd,
                                        variant=variant)
                    c2 = j2.lower(*a2).compile()
                    cost2 = cost_stats(c2)
                    probes.append({
                        "flops": float(cost2.get("flops", 0.0)),
                        "bytes": float(cost2.get("bytes accessed", 0.0)),
                        "coll": collective_bytes(c2.as_text())})
                L = full_depth

                def lin(a, b):
                    # robust per-layer estimate: f(2)-f(1) unless XLA's
                    # CSE/fusion makes the delta degenerate, then f(2)/2.
                    per = b - a
                    if per <= 0.25 * b:
                        per = b / 2.0
                    return max(a - per, 0.0) + L * per

                extrap = {
                    "depth": L,
                    "flops": lin(probes[0]["flops"], probes[1]["flops"]),
                    "bytes_accessed": lin(probes[0]["bytes"],
                                          probes[1]["bytes"]),
                    "collectives": {
                        k: {"bytes": lin(probes[0]["coll"][k]["bytes"],
                                         probes[1]["coll"][k]["bytes"])}
                        for k in probes[0]["coll"]}}
        rec.update(
            ok=True, lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
            memory={k: int(getattr(mem, k)) for k in
                    ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes")
                    if hasattr(mem, k)},
            flops=float(cost.get("flops", -1.0)),
            bytes_accessed=float(cost.get("bytes accessed", -1.0)),
            collectives=coll, extrapolated=extrap)
    except Exception as e:  # noqa: BLE001
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    out_path.write_text(json.dumps(rec, indent=1))
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[{mesh_name}] {arch_id} x {shape}: {status} "
          f"({time.time() - t0:.0f}s)", flush=True)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="tp")
    args = ap.parse_args()
    cells = [(a, s) for a, s in all_cells()
             if (args.arch in (None, a)) and (args.shape in (None, s))]
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("pod16x16", make_production_mesh(multi_pod=False)))
    if args.mesh in ("multi", "both"):
        meshes.append(("multipod2x16x16",
                       make_production_mesh(multi_pod=True)))
    n_ok = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch_id, shape in cells:
            rec = run_cell(arch_id, shape, mesh, mesh_name, args.force,
                           variant=args.variant)
            n_ok += rec["ok"]
            n_fail += not rec["ok"]
    print(f"\ndry-run: {n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
