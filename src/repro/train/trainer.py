"""Fault-tolerant trainer: microbatch accumulation, atomic checkpoints,
auto-resume, deterministic data order, optional gradient compression.

The data pipeline is keyed by step number (``data_fn(step) -> batch``), so
a restart replays exactly the batches that were never applied — combined
with atomic checkpoints this gives effectively-once batch semantics.
``fail_at_step`` injects a crash (tests exercise the resume path with it).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import Callable

import jax
import jax.numpy as jnp

from ..dist.compression import (compress_with_feedback, compression_ratio,
                                init_error_feedback)
from . import checkpoint
from .optimizer import AdamWConfig, adamw_init, adamw_update, global_norm


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    microbatches: int = 1
    ckpt_every: int = 50
    ckpt_keep: int = 3
    out_dir: str = "runs/default"
    log_every: int = 10
    grad_compression: bool = False
    fail_at_step: int | None = None    # fault injection (tests)


class SimulatedFailure(RuntimeError):
    pass


class Trainer:
    def __init__(self, loss_fn: Callable, init_params: Callable,
                 data_fn: Callable, cfg: TrainerConfig,
                 opt_cfg: AdamWConfig | None = None, shardings=None):
        self.loss_fn = loss_fn
        self.data_fn = data_fn
        self.cfg = cfg
        self.opt_cfg = opt_cfg or AdamWConfig(total_steps=cfg.total_steps)
        self.out = pathlib.Path(cfg.out_dir)
        self.out.mkdir(parents=True, exist_ok=True)
        self.shardings = shardings
        self._init_params = init_params
        self._step_fn = jax.jit(self._make_step())

    # -- step ---------------------------------------------------------------

    def _make_step(self):
        opt_cfg = self.opt_cfg
        m = self.cfg.microbatches
        use_comp = self.cfg.grad_compression

        def step(state, batch):
            def micro(carry, mb):
                gacc, lacc = carry
                loss, g = jax.value_and_grad(self.loss_fn)(state["params"],
                                                           mb)
                gacc = jax.tree_util.tree_map(jnp.add, gacc, g)
                return (gacc, lacc + loss), None

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state["params"])
            if m > 1:
                mbs = jax.tree_util.tree_map(
                    lambda x: x.reshape((m, x.shape[0] // m) + x.shape[1:]),
                    batch)
                (gsum, lsum), _ = jax.lax.scan(micro, (zeros, 0.0), mbs)
                grads = jax.tree_util.tree_map(lambda g: g / m, gsum)
                loss = lsum / m
            else:
                loss, grads = jax.value_and_grad(self.loss_fn)(
                    state["params"], batch)
            if use_comp:
                grads, err = compress_with_feedback(grads, state["err"])
            params, opt, metrics = adamw_update(opt_cfg, grads, state["opt"],
                                                state["params"])
            new_state = {"params": params, "opt": opt}
            if use_comp:
                new_state["err"] = err
                metrics["err_norm"] = global_norm(err)
            metrics["loss"] = loss
            return new_state, metrics

        return step

    # -- lifecycle ----------------------------------------------------------

    def init_state(self, seed: int = 0):
        params = self._init_params(jax.random.PRNGKey(seed))
        state = {"params": params, "opt": adamw_init(params)}
        if self.cfg.grad_compression:
            state["err"] = init_error_feedback(params)
        return state

    def run(self, seed: int = 0) -> dict:
        ckpt_dir = self.out / "ckpt"
        start = checkpoint.latest_step(ckpt_dir)
        if start is not None:
            state = self.init_state(seed)
            state = checkpoint.restore(ckpt_dir, start, state,
                                       self.shardings)
            start_step = start
        else:
            state = self.init_state(seed)
            start_step = 0
        log_path = self.out / "metrics.jsonl"
        # shape-only constant (grads are param-shaped by construction)
        comp_ratio = (round(compression_ratio(state["params"]), 2)
                      if self.cfg.grad_compression else None)
        losses = []
        with log_path.open("a") as log:
            for step in range(start_step, self.cfg.total_steps):
                if self.cfg.fail_at_step is not None \
                        and step == self.cfg.fail_at_step:
                    raise SimulatedFailure(f"injected failure at {step}")
                t0 = time.perf_counter()
                batch = self.data_fn(step)
                state, metrics = self._step_fn(state, batch)
                loss = float(metrics["loss"])
                losses.append(loss)
                if step % self.cfg.log_every == 0 \
                        or step == self.cfg.total_steps - 1:
                    rec = {"step": step, "loss": loss,
                           "grad_norm": float(metrics["grad_norm"]),
                           "lr": float(metrics["lr"]),
                           "sec": time.perf_counter() - t0}
                    if "err_norm" in metrics:
                        rec["err_norm"] = float(metrics["err_norm"])
                        rec["compression_ratio"] = comp_ratio
                    log.write(json.dumps(rec) + "\n")
                    log.flush()
                next_step = step + 1
                if next_step % self.cfg.ckpt_every == 0 \
                        or next_step == self.cfg.total_steps:
                    checkpoint.save(ckpt_dir, next_step, state,
                                    self.cfg.ckpt_keep)
        return {"state": state, "losses": losses,
                "final_step": self.cfg.total_steps}
