"""AdamW with global-norm clipping, cosine schedule, FLOP regularization.

No optax offline — implemented directly on pytrees. Moments are fp32
regardless of parameter dtype (mixed-precision master statistics).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    schedule: str = "cosine"  # "cosine" | "constant"


def cosine_schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        return cfg.lr * warm
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    return cfg.lr * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def adamw_init(params) -> dict:
    zeros = lambda p: jax.tree_util.tree_map(
        lambda x: jnp.zeros(x.shape, jnp.float32), p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = cosine_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        update = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        new_p = (p.astype(jnp.float32)
                 - lr * (update + cfg.weight_decay * p.astype(jnp.float32)))
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p)
           for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_state = {"m": treedef.unflatten([o[1] for o in out]),
                 "v": treedef.unflatten([o[2] for o in out]),
                 "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def flop_regularizer(rep: jax.Array) -> jax.Array:
    """SPLADE FLOP regularization: sum_j (mean_i |rep_ij|)^2."""
    return jnp.sum(jnp.square(jnp.mean(jnp.abs(rep), axis=0)))
