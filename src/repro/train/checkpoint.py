"""Fault-tolerant checkpointing: atomic, keep-N, auto-resume, elastic.

Layout: ``<dir>/step_<n>/arrays.npz + manifest.json``. The npz is written
into a ``.tmp`` directory first and atomically renamed — a crash mid-write
can never produce a checkpoint that ``latest_step`` would pick up.
Restore takes ``shardings`` (pytree of NamedSharding) so a checkpoint saved
on one mesh restores onto any other mesh (elastic re-shard): arrays are
saved as full host arrays and re-placed with ``jax.device_put``.
"""
from __future__ import annotations

import json
import os
import pathlib
import shutil

import jax
import numpy as np


def _leaves_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


def save(ckpt_dir: str | os.PathLike, step: int, state, keep: int = 3) -> str:
    base = pathlib.Path(ckpt_dir)
    base.mkdir(parents=True, exist_ok=True)
    final = base / f"step_{step:08d}"
    tmp = base / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir()
    named = _leaves_with_paths(state)
    arrays = {f"leaf_{i}": np.asarray(jax.device_get(leaf))
              for i, (_, leaf) in enumerate(named)}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {"step": step, "n_leaves": len(named),
                "paths": [p for p, _ in named],
                "shapes": [list(np.shape(a)) for a in arrays.values()],
                "dtypes": [str(np.asarray(a).dtype) for a in arrays.values()],
                "complete": True}
    (tmp / "manifest.json").write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)                      # atomic publish
    _gc(base, keep)
    return str(final)


def _gc(base: pathlib.Path, keep: int) -> None:
    steps = sorted(p for p in base.glob("step_*") if p.is_dir())
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)
    for p in base.glob(".tmp_step_*"):
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str | os.PathLike) -> int | None:
    base = pathlib.Path(ckpt_dir)
    if not base.exists():
        return None
    best = None
    for p in sorted(base.glob("step_*")):
        man = p / "manifest.json"
        try:
            if json.loads(man.read_text()).get("complete"):
                best = int(p.name.split("_")[1])
        except (OSError, ValueError, json.JSONDecodeError):
            continue  # torn checkpoint: skip
    return best


def restore(ckpt_dir: str | os.PathLike, step: int, like, shardings=None):
    """Restore into the structure of ``like`` (pytree of arrays or SDS).

    ``shardings``: optional pytree of NamedSharding — re-shard onto any
    mesh, regardless of the mesh the checkpoint was saved from.
    """
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    man = json.loads((path / "manifest.json").read_text())
    with np.load(path / "arrays.npz") as z:
        arrays = [z[f"leaf_{i}"] for i in range(man["n_leaves"])]
    flat_like, treedef = jax.tree_util.tree_flatten(like)
    if len(flat_like) != len(arrays):
        raise ValueError(f"checkpoint has {len(arrays)} leaves, "
                         f"expected {len(flat_like)}")
    if shardings is not None:
        flat_sh = treedef.flatten_up_to(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, flat_sh)]
    else:
        arrays = [jax.numpy.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays)
