"""Straggler detection and work rebalancing for synchronous data parallelism.

The monitor keeps a per-worker EWMA of reported step times. A worker whose
EWMA exceeds ``slow_factor`` x the fleet median for ``patience`` consecutive
reports is *degraded*: its microbatch assignment is halved and the freed
microbatches move to the fastest healthy workers (total work is conserved,
so the global batch — and therefore the training trajectory — is
unchanged; only the per-worker split moves). A worker that stays degraded
for ``evict_after`` consecutive reports is signalled for eviction, the
hand-off point to the elastic trainer restart path (checkpoint + resume
with one fewer worker).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class StragglerConfig:
    patience: int = 3           # consecutive slow reports before degraded
    evict_after: int = 100      # consecutive degraded reports before evict
    slow_factor: float = 1.5    # EWMA threshold vs fleet median
    ewma_decay: float = 0.6     # weight on history (0 = last report only)


class StragglerMonitor:
    def __init__(self, n_workers: int, microbatches_per_worker: int,
                 cfg: StragglerConfig = StragglerConfig()):
        self.n_workers = n_workers
        self.mpw = microbatches_per_worker
        self.cfg = cfg
        self.ewma = np.zeros(n_workers, np.float64)
        self.slow_streak = np.zeros(n_workers, np.int64)
        self.degraded_streak = np.zeros(n_workers, np.int64)
        self.degraded = np.zeros(n_workers, bool)
        self.n_reports = 0

    def report(self, step: int, durations) -> dict:
        """Ingest one step's per-worker durations; returns the new
        assignment plan: {"assignments", "evict", "ewma", "degraded"}."""
        d = np.asarray(durations, np.float64)
        if self.n_reports == 0:
            self.ewma = d.copy()
        else:
            a = self.cfg.ewma_decay
            self.ewma = a * self.ewma + (1.0 - a) * d
        self.n_reports += 1

        median = float(np.median(self.ewma))
        slow = self.ewma > self.cfg.slow_factor * max(median, 1e-12)
        self.slow_streak = np.where(slow, self.slow_streak + 1, 0)
        self.degraded = self.slow_streak >= self.cfg.patience
        self.degraded_streak = np.where(self.degraded,
                                        self.degraded_streak + 1, 0)
        evict = np.nonzero(self.degraded_streak >= self.cfg.evict_after)[0]

        assignments = np.full(self.n_workers, self.mpw, np.int64)
        assignments[self.degraded] = max(self.mpw // 2, 1)
        freed = self.mpw * self.n_workers - int(assignments.sum())
        if freed > 0:
            healthy = np.nonzero(~self.degraded)[0]
            if len(healthy):
                # fastest healthy workers absorb the slack, round-robin
                order = healthy[np.argsort(self.ewma[healthy],
                                           kind="stable")]
                for i in range(freed):
                    assignments[order[i % len(order)]] += 1
            else:  # everyone degraded: keep the original split
                assignments[:] = self.mpw
        return {"assignments": assignments, "evict": evict.tolist(),
                "ewma": self.ewma.copy(), "degraded": self.degraded.copy()}
