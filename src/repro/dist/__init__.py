# Distributed substrate: sharding rules, host-level collectives, gradient
# compression, straggler handling. Everything here is mesh-shape agnostic and
# runs unchanged on the 1-device CPU test mesh, the 8-device subprocess mesh,
# and the 512-device production dry-run meshes.
from .collectives import (hierarchical_all_reduce, reduce_scatter,  # noqa: F401
                          ring_all_gather, ring_all_reduce, ring_gather_stack)
from .compression import (CompressionConfig, compress_with_feedback,  # noqa: F401
                          compression_ratio, init_error_feedback, topk_sparsify)
from .sharding import (activation_rules, input_shardings,  # noqa: F401
                       opt_shardings, param_shardings)
from .straggler import StragglerConfig, StragglerMonitor  # noqa: F401
