"""Gradient compression with error feedback.

Scheme (per leaf): the error-corrected gradient ``g + err`` is split into
(1) its top-k largest-magnitude coordinates, transmitted exactly in fp32
(value + index), and (2) the remainder, transmitted as per-tensor-scaled
int8. The new error-feedback state is exactly the int8 quantization
residual, so it is bounded by ``scale / 2`` at *every* step — unlike pure
top-k sparsification, whose residual for small coordinates grows with the
send interval, the cumulative transmitted update here tracks the cumulative
true gradient to within one quantization step. That bound is what
``test_error_feedback_mean_error_vanishes`` pins down, and it is why the
compressed trainer converges at an unchanged rate.

All of ``compress_with_feedback`` is jit-compatible (static shapes, lax
top_k) — the trainer calls it inside its jitted step.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    topk_fraction: float = 1.0 / 64.0   # exact-fp32 heavy hitters per leaf
    residual_bits: int = 8              # quantized tail precision
    index_bits: int = 32                # accounting: bits per top-k index


DEFAULT = CompressionConfig()


def _leaf_k(n: int, cfg: CompressionConfig) -> int:
    return max(1, int(n * cfg.topk_fraction))


def init_error_feedback(params):
    """Zero fp32 error accumulators shaped like the gradient pytree."""
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def topk_sparsify(g: jax.Array, k: int) -> jax.Array:
    """Dense tensor with everything but the k largest-|.| entries zeroed."""
    flat = g.reshape(-1).astype(jnp.float32)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    mask = jnp.zeros_like(flat).at[idx].set(1.0)
    return (flat * mask).reshape(g.shape).astype(g.dtype)


def _compress_leaf(g, err, cfg: CompressionConfig):
    flat = g.reshape(-1).astype(jnp.float32) + err.reshape(-1)
    k = _leaf_k(flat.size, cfg)
    exact = topk_sparsify(flat, k)
    rest = flat - exact
    qmax = float(2 ** (cfg.residual_bits - 1) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(rest)) / qmax, 1e-12)
    quant = jnp.round(rest / scale) * scale
    sent = (exact + quant).astype(g.dtype)   # what is actually transmitted
    # feed back vs the *cast* value so low-precision rounding (bf16 grads)
    # is corrected too, keeping the residual bound at scale/2 + cast ulp
    new_err = flat - sent.astype(jnp.float32)
    return sent.reshape(g.shape), new_err.reshape(g.shape)


def compress_with_feedback(grads, err, cfg: CompressionConfig = DEFAULT):
    """Returns (transmitted_grads, new_error_feedback), same pytrees."""
    pairs = jax.tree_util.tree_map(
        lambda g, e: _compress_leaf(g, e, cfg), grads, err)
    sent = jax.tree_util.tree_map(
        lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree_util.tree_map(
        lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return sent, new_err


def compression_ratio(grads, cfg: CompressionConfig = DEFAULT) -> float:
    """Dense fp32 bits / transmitted bits for one gradient pytree.

    Transmitted per leaf: k fp32 values + k indices + (n - k) int8 residual
    entries + one fp32 scale.
    """
    dense_bits = 0
    sent_bits = 0
    for leaf in jax.tree_util.tree_leaves(grads):
        n = math.prod(leaf.shape) if leaf.shape else 1
        k = _leaf_k(n, cfg)
        dense_bits += n * 32
        sent_bits += (k * (32 + cfg.index_bits)
                      + (n - k) * cfg.residual_bits + 32)
    return dense_bits / max(sent_bits, 1)
