"""Host-level collectives built on ``shard_map`` + ``lax.ppermute``.

Semantics: the input's leading dim is the *device contribution* axis — it is
sharded over the named mesh axis, each device's slice is its local value,
and the reduction returns the elementwise sum of all slices, replicated.
On a 1-device mesh every collective is the identity (sum of one slice),
which is what the single-device tests pin down; on an n-device mesh
``ring_all_reduce(stack(x_i)) == sum_i x_i`` exactly matches ``lax.psum``
of per-device values (the subprocess test checks this against psum).

The ring is the classic 2(n-1)-step algorithm — an (n-1)-step chunked
reduce-scatter followed by an (n-1)-step all-gather — so each device moves
2(n-1)/n of the payload regardless of n, instead of the (n-1)x payload a
naive gather-everything would move. ``hierarchical_all_reduce`` composes two
rings, intra-group then inter-group, matching the pod/ICI topology of the
production meshes (ring within a pod, ring across pods on the slower DCN
axis moves 1/n_inner of the bytes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


def _ring_sum(x, axis_name: str, n: int):
    """In-shard_map ring all-reduce of each device's ``x`` over one axis."""
    if n == 1:
        return x
    shape, size = x.shape, x.size
    flat = x.reshape(-1)
    pad = (-size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)
    idx = jax.lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]

    def rs_step(s, ch):
        # step s: send partial chunk (idx - s), receive (idx - s - 1), add
        blk = jnp.take(ch, (idx - s) % n, axis=0)
        blk = jax.lax.ppermute(blk, axis_name, fwd)
        return ch.at[(idx - s - 1) % n].add(blk)

    chunks = jax.lax.fori_loop(0, n - 1, rs_step, chunks)
    # device idx now owns the fully-reduced chunk (idx + 1) % n

    def ag_step(s, ch):
        blk = jnp.take(ch, (idx + 1 - s) % n, axis=0)
        blk = jax.lax.ppermute(blk, axis_name, fwd)
        return ch.at[(idx - s) % n].set(blk)

    chunks = jax.lax.fori_loop(0, n - 1, ag_step, chunks)
    return chunks.reshape(-1)[:size].reshape(shape)


def _shard_spec(ndim: int, axes) -> P:
    return P(axes, *([None] * (ndim - 1)))


def ring_all_reduce(x, mesh, axis_name: str):
    """Sum the per-device slices of ``x`` along dim 0, replicated.

    ``x.shape[0]`` must divide by ``mesh.shape[axis_name]``; the result has
    leading dim ``x.shape[0] // n`` (one contribution per device). On a
    1-device mesh this is the identity.
    """
    n = mesh.shape[axis_name]
    f = shard_map(partial(_ring_sum, axis_name=axis_name, n=n), mesh=mesh,
                  in_specs=_shard_spec(x.ndim, axis_name),
                  out_specs=P(*([None] * x.ndim)), check_rep=False)
    return f(x)


def hierarchical_all_reduce(x, mesh, inner_axis: str, outer_axis: str):
    """Two-phase all-reduce: ring within ``inner_axis`` groups, then ring
    across ``outer_axis`` — the intra-pod / inter-pod split. Contributions
    are the ``x`` slices along dim 0 (one per device, inner-major)."""
    n_in, n_out = mesh.shape[inner_axis], mesh.shape[outer_axis]

    def f(local):
        y = _ring_sum(local, inner_axis, n_in)
        return _ring_sum(y, outer_axis, n_out)

    return shard_map(f, mesh=mesh,
                     in_specs=_shard_spec(x.ndim, (outer_axis, inner_axis)),
                     out_specs=P(*([None] * x.ndim)), check_rep=False)(x)


def reduce_scatter(x, mesh, axis_name: str):
    """Ring reduce-scatter: device i ends with chunk i of the summed
    contributions. Returns the globally-sharded sum (shape of one
    contribution, leading dim sharded over ``axis_name``). The
    contribution row count ``x.shape[0] // n`` must itself divide by
    ``n`` so the scattered chunks partition it exactly."""
    n = mesh.shape[axis_name]
    rows = x.shape[0] // n
    if n > 1 and rows % n:
        raise ValueError(
            f"reduce_scatter needs contribution rows ({rows}) divisible "
            f"by mesh axis {axis_name!r} ({n}) to scatter without overlap")

    def f(local):
        y = _ring_sum(local, axis_name, n)  # full sum of one contribution
        i = jax.lax.axis_index(axis_name)
        chunk = local.shape[0] // n
        return jax.lax.dynamic_slice_in_dim(y, i * chunk, chunk, axis=0)

    return shard_map(f, mesh=mesh,
                     in_specs=_shard_spec(x.ndim, axis_name),
                     out_specs=_shard_spec(x.ndim, axis_name),
                     check_rep=False)(x)


def ring_gather_stack(local, axis_name: str, n: int):
    """In-shard_map building block: ring all-gather every device's ``local``
    into a new leading axis ordered by device index ([*] -> [n, *], entry j
    = device j's contribution). This is the primitive behind both
    ``ring_all_gather`` and the sharded-retrieval top-k queue merge
    (``serve.sharded``), which needs the stacked form to keep the
    shard-order stable-tie semantics of the single-device queue."""
    if n == 1:
        return local[None]
    idx = jax.lax.axis_index(axis_name)
    fwd = [(i, (i + 1) % n) for i in range(n)]
    out = jnp.zeros((n,) + local.shape, local.dtype).at[idx].set(local)

    def step(s, carry):
        blk, acc = carry
        blk = jax.lax.ppermute(blk, axis_name, fwd)
        return blk, acc.at[(idx - s - 1) % n].set(blk)

    _, out = jax.lax.fori_loop(0, n - 1, step, (local, out))
    return out


def ring_all_gather(x, mesh, axis_name: str):
    """All-gather the per-device slices: every device ends with the full
    concatenation (result replicated, same global shape as ``x``)."""
    n = mesh.shape[axis_name]

    def f(local):
        out = ring_gather_stack(local, axis_name, n)
        return out.reshape((n * local.shape[0],) + local.shape[1:])

    return shard_map(f, mesh=mesh,
                     in_specs=_shard_spec(x.ndim, axis_name),
                     out_specs=P(*([None] * x.ndim)), check_rep=False)(x)
