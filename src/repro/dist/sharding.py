"""Sharding policy: (family, config, mesh, variant) -> NamedSharding pytrees.

Two variants are understood everywhere:

- ``"tp"``   — tensor parallelism on the ``model`` axis for weights and
  activations, data parallelism on the ``data`` (and ``pod``) axes for the
  batch. The paper-era default for every dry-run cell.
- ``"fsdp"`` — ZeRO-3 style: parameters and optimizer state sharded over
  *all* mesh axes, activations sharded on batch only, weights all-gathered
  in compute dtype per layer (``Rules.gather_weights``).

Every rule is divisibility-guarded: a dimension is only sharded when the
axis size divides it, so the same policy lowers on the 8-device subprocess
mesh (4x2) and the 512-device production meshes (16x16, 2x16x16) without
per-mesh special cases. Anything unrecognized replicates — GSPMD then
propagates a layout, which is always correct, merely not always optimal.
"""
from __future__ import annotations

import math

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

# single source of truth for the mesh-axis policy (which axes carry DP,
# what the model axis is called) — shared with the launch layer
from ..launch.mesh import dp_axes as _dp_axes, model_axis as _model_axis


def _axes_size(mesh, axes) -> int:
    return int(math.prod(mesh.shape[a] for a in axes)) if axes else 1


def _rep(ndim: int) -> P:
    return P(*([None] * ndim))


def _shard_dim(shape, dim, axes) -> P:
    spec = [None] * len(shape)
    spec[dim] = axes
    return P(*spec)


def _largest_divisible_dim(shape, size: int, *, reverse: bool = True):
    """Dim index with the largest extent divisible by ``size`` (ties go to
    the trailing dim when ``reverse``), or None."""
    best = None
    dims = range(len(shape) - 1, -1, -1) if reverse else range(len(shape))
    for d in dims:
        if shape[d] % size == 0 and shape[d] > size:
            if best is None or shape[d] > shape[best]:
                best = d
    return best


# --------------------------------------------------------------------------
# activations
# --------------------------------------------------------------------------

def activation_rules(mesh, variant: str = "tp"):
    """Logical-axis rules (``models.transformer.Rules``) for one mesh.

    tp:   batch -> DP axes, heads/vocab -> model axis.
    fsdp: batch -> DP axes only; weights are gathered per layer in compute
          dtype (no TP activation all-reduces).
    """
    from ..models.transformer import Rules
    dp = _dp_axes(mesh)
    batch = dp if dp else None
    dp_size = _axes_size(mesh, dp)
    if variant == "fsdp":
        return Rules(batch=batch, heads=None, kv_seq=None, vocab=None,
                     dp_size=dp_size, gather_weights=True)
    tp = _model_axis(mesh)
    return Rules(batch=batch, heads=tp, kv_seq=None, vocab=tp,
                 dp_size=dp_size, gather_weights=False)


# --------------------------------------------------------------------------
# parameters / optimizer state
# --------------------------------------------------------------------------

# Leaf-name driven TP placements for the transformer stack. Projections
# shard their head/ffn (output) dim; the return projections shard the
# contraction dim, so each matmul pair needs a single all-reduce
# (Megatron-style column/row split). MoE expert stacks shard the expert
# dim (EP). Stacked-layer leaves carry a leading L dim that stays
# replicated.
_LM_TP_OUT = ("wq", "wk", "wv", "w_gate", "w_up", "router")
_LM_TP_IN = ("wo", "w_down")


def _lm_param_spec(name: str, shape, tp: str, tp_size: int) -> P:
    nd = len(shape)
    if nd <= 1:
        return _rep(nd)
    if name in ("embed", "pos_embed"):
        # [V, D]: shard the vocab/position rows (Rules.vocab == model axis)
        return (_shard_dim(shape, 0, tp) if shape[0] % tp_size == 0
                else _rep(nd))
    if name == "lm_head":
        return (_shard_dim(shape, 1, tp) if shape[1] % tp_size == 0
                else _rep(nd))
    if name in ("w_gate", "w_up", "w_down") and nd == 4:
        # MoE stacks [L, E, D, F]: expert-parallel on the model axis
        return (_shard_dim(shape, 1, tp) if shape[1] % tp_size == 0
                else _rep(nd))
    if name in _LM_TP_OUT:
        return (_shard_dim(shape, nd - 1, tp)
                if shape[-1] % tp_size == 0 else _rep(nd))
    if name in _LM_TP_IN:
        return (_shard_dim(shape, nd - 2, tp)
                if shape[-2] % tp_size == 0 else _rep(nd))
    return _rep(nd)


# Embedding tables dominate recsys parameter bytes; their row dim is
# sharded on the model axis (model-parallel embeddings). MLP weights
# shard their output dim when it divides.
_RECSYS_TABLE_ROWS = 8192  # row count above which dim 0 is table-like


def _recsys_param_spec(name: str, shape, tp: str, tp_size: int) -> P:
    nd = len(shape)
    if nd <= 1:
        return _rep(nd)
    if shape[0] >= _RECSYS_TABLE_ROWS and shape[0] % tp_size == 0:
        return _shard_dim(shape, 0, tp)
    if name == "w" and shape[-1] % tp_size == 0 and shape[-1] > tp_size:
        return _shard_dim(shape, nd - 1, tp)
    return _lm_param_spec(name, shape, tp, tp_size)  # bert4rec reuses the LM


def param_shardings(family: str, cfg, mesh, params, variant: str = "tp"):
    """NamedSharding pytree matching ``params`` (arrays or SDS leaves).

    tp: family-aware TP placement (see above); gnn replicates — SchNet is
    tiny and rides on pure DP. fsdp: every leaf shards its largest
    divisible dim across all mesh axes (two-axis ZeRO-3 partitioning).
    """
    all_axes = tuple(mesh.axis_names)
    all_size = _axes_size(mesh, all_axes)
    tp = _model_axis(mesh)
    tp_size = mesh.shape[tp] if tp else 1

    def leaf_spec(path, leaf) -> P:
        shape = leaf.shape
        name = ""
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        if variant == "fsdp":
            d = _largest_divisible_dim(shape, all_size)
            return _shard_dim(shape, d, all_axes) if d is not None \
                else _rep(len(shape))
        if tp is None or family == "gnn":
            return _rep(len(shape))
        if family == "lm":
            return _lm_param_spec(name, shape, tp, tp_size)
        return _recsys_param_spec(name, shape, tp, tp_size)

    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: NamedSharding(mesh, leaf_spec(path, leaf)), params)


def opt_shardings(p_sh):
    """AdamW state shardings from param shardings: moments inherit the
    param layout (fp32 copies live where the master param lives); the step
    counter replicates."""
    mesh = jax.tree_util.tree_leaves(p_sh)[0].mesh
    return {"m": p_sh, "v": p_sh, "step": NamedSharding(mesh, P())}


# --------------------------------------------------------------------------
# inputs
# --------------------------------------------------------------------------

# Inputs whose leading dim is a candidate/catalog axis: sharded over the
# whole mesh (the retrieval cells score 1M candidates across all devices).
_CANDIDATE_KEYS = ("cand_ids", "cand_emb", "shortlist", "neg_items",
                   "neg_logq")


def input_shardings(family: str, cfg, mesh, spec: dict,
                    variant: str = "tp") -> dict:
    """Per-input NamedSharding pytrees for one ``input_specs`` dict.

    Batch-like leading dims shard over the DP axes; candidate axes shard
    over every mesh axis; KV caches shard their batch dim (dim 1 of
    [L, B, S, Hkv, Dh]); scalars and non-divisible dims replicate.
    """
    dp = _dp_axes(mesh)
    dp_size = _axes_size(mesh, dp)
    all_axes = tuple(mesh.axis_names)
    all_size = _axes_size(mesh, all_axes)

    def batch_leaf(leaf) -> NamedSharding:
        shape = leaf.shape
        if len(shape) and dp and shape[0] % dp_size == 0 and shape[0] > 1:
            return NamedSharding(mesh, _shard_dim(shape, 0, dp))
        return NamedSharding(mesh, _rep(len(shape)))

    def cand_leaf(leaf) -> NamedSharding:
        shape = leaf.shape
        if len(shape) and shape[0] % all_size == 0 and shape[0] > all_size:
            return NamedSharding(mesh, _shard_dim(shape, 0, all_axes))
        return batch_leaf(leaf)

    def cache_leaf(leaf) -> NamedSharding:
        shape = leaf.shape  # [L, B, S, Hkv, Dh] or [L, B, S, Hkv]
        if len(shape) >= 2 and dp and shape[1] % dp_size == 0:
            return NamedSharding(mesh, _shard_dim(shape, 1, dp))
        return NamedSharding(mesh, _rep(len(shape)))

    def dispatch(path, leaf) -> NamedSharding:
        name = ""
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        if name in _CANDIDATE_KEYS:
            return cand_leaf(leaf)
        return batch_leaf(leaf)

    out = {}
    for key, sub in spec["inputs"].items():
        if key == "cache":
            out[key] = jax.tree_util.tree_map(cache_leaf, sub)
        elif key in _CANDIDATE_KEYS:
            out[key] = jax.tree_util.tree_map(cand_leaf, sub)
        else:
            out[key] = jax.tree_util.tree_map_with_path(dispatch, sub)
    return out
