from .ops import (embedding_bag, segment_softmax,  # noqa: F401
                  scatter_mean, degree)
