"""Sparse/ragged primitives JAX lacks natively — built, not stubbed.

EmbeddingBag = gather + weighted segment-sum (torch ``nn.EmbeddingBag``
equivalent); message passing = scatter over an edge index via
``jax.ops.segment_sum`` — these ARE the system's GNN/recsys substrate.
The Pallas kernel in ``repro/kernels/embedding_bag.py`` is the fused
serving-path variant of the same contract.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def embedding_bag(table: jax.Array, indices: jax.Array,
                  weights: jax.Array | None = None,
                  mode: str = "sum") -> jax.Array:
    """table [V, D]; indices [B, L] (pad via weight 0) -> [B, D]."""
    rows = jnp.take(table, indices, axis=0)               # [B, L, D]
    if weights is None:
        weights = jnp.ones(indices.shape, dtype=table.dtype)
    out = (rows * weights[..., None].astype(rows.dtype)).sum(axis=1)
    if mode == "mean":
        denom = jnp.maximum(weights.sum(axis=1, keepdims=True), 1e-9)
        out = out / denom.astype(out.dtype)
    return out


def segment_softmax(scores: jax.Array, segment_ids: jax.Array,
                    num_segments: int) -> jax.Array:
    """Softmax over variable-size segments (edge-softmax for GAT-style)."""
    seg_max = jax.ops.segment_max(scores, segment_ids, num_segments)
    ex = jnp.exp(scores - seg_max[segment_ids])
    seg_sum = jax.ops.segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(seg_sum[segment_ids], 1e-30)


def scatter_mean(values: jax.Array, segment_ids: jax.Array,
                 num_segments: int) -> jax.Array:
    s = jax.ops.segment_sum(values, segment_ids, num_segments)
    c = jax.ops.segment_sum(jnp.ones_like(segment_ids, dtype=values.dtype),
                            segment_ids, num_segments)
    return s / jnp.maximum(c, 1.0)[..., None] if values.ndim > 1 \
        else s / jnp.maximum(c, 1.0)


def degree(edge_dst: jax.Array, num_nodes: int) -> jax.Array:
    return jax.ops.segment_sum(jnp.ones_like(edge_dst, dtype=jnp.float32),
                               edge_dst, num_nodes)
