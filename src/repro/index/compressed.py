"""CompressedImpactIndex: the BII layout with compressed posting storage.

Same tile geometry and planner metadata as ``core.index.BlockedImpactIndex``
— identical ``tile_ptr``, *exact* fp32 per-(term, tile) maxima and list
maxima, same padded-gather contract — but the flat posting arrays are
stored compressed:

  docids   ->  per-run first offset (uint16) + delta-1 gaps bit-packed at a
               per-run width from {1, 2, 4, 8, 16} into uint32 words
               (``pack_ptr`` is the word-granular CSR mirror of
               ``tile_ptr``; every run is word-aligned so shards and
               streamed chunks concatenate without re-packing),
  impacts  ->  uint8 codes with per-run fp16 scale/zero-point, rounded so
               dequantized values never exceed the exact fp32 tile max
               (see ``codec.quantize_runs`` — bounds stay valid, so chunk
               scheduling and theta pruning are byte-identical in *plan*
               to the fp32 index).

Per posting: 4 B docid + 8 B impacts (fp32 BII) vs ~width/8 + 2 B here,
plus per-run metadata amortized over the run — the bytes-per-doc ratio is
recorded by ``benchmarks/million_doc.py``.

Decode happens *inside the gather* (``gather_tile_q``), which feeds the
same ``(offs, wb, wl)`` executor contract as the fp32 gather; the Pallas
kernels get a raw-row variant (``gather_tile_q_raw``) and decode in-VMEM
(``kernels.guided_score.guided_score_tile_q``).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..core.align import MergedPostings
from ..core.index import blocked_layout
from . import codec


@dataclasses.dataclass
class CompressedImpactIndex:
    n_docs: int
    n_terms: int
    tile_size: int
    n_tiles: int
    pad_len: int
    nnz: int
    # compressed flat postings (term-major, docid-sorted within term)
    packed: jax.Array     # [n_words] uint32 bit-packed delta-1 gaps
    qb: jax.Array         # [nnz] uint8 quantized BM25 impacts
    ql: jax.Array         # [nnz] uint8 quantized learned impacts
    # per-(term, tile) structure
    tile_ptr: jax.Array   # [n_terms, n_tiles + 1] int32 posting offsets
    pack_ptr: jax.Array   # [n_terms, n_tiles + 1] int32 word offsets
    width: jax.Array      # [n_terms, n_tiles] uint8 gap bit width
    first: jax.Array      # [n_terms, n_tiles] uint16 first local offset
    scale_b: jax.Array    # [n_terms, n_tiles] f16
    zero_b: jax.Array     # [n_terms, n_tiles] f16
    scale_l: jax.Array    # [n_terms, n_tiles] f16
    zero_l: jax.Array     # [n_terms, n_tiles] f16
    # exact fp32 bounds — unchanged from the uncompressed index
    tile_max_b: jax.Array
    tile_max_l: jax.Array
    sigma_b: jax.Array
    sigma_l: jax.Array
    orig_of_new: np.ndarray | None = None

    gather_kind = "q8"

    def gather_arrays(self) -> tuple[jax.Array, ...]:
        """Posting-side payload for ``core.index.dispatch_gather``."""
        return (self.packed, self.qb, self.ql, self.tile_ptr, self.pack_ptr,
                self.width, self.first, self.scale_b, self.zero_b,
                self.scale_l, self.zero_l)

    def to_orig(self, ids: np.ndarray) -> np.ndarray:
        """Map internal docids back to original ids (-1 passes through)."""
        ids = np.asarray(ids)
        if self.orig_of_new is None:
            return ids
        safe = np.clip(ids, 0, self.n_docs - 1)
        return np.where(ids < 0, ids, self.orig_of_new[safe]).astype(ids.dtype)

    def nbytes(self) -> dict:
        """Actual on-device bytes per component (+ ``total``)."""
        comp = {}
        for name in ("packed", "qb", "ql", "tile_ptr", "pack_ptr", "width",
                     "first", "scale_b", "zero_b", "scale_l", "zero_l",
                     "tile_max_b", "tile_max_l", "sigma_b", "sigma_l"):
            a = getattr(self, name)
            comp[name] = int(a.size) * a.dtype.itemsize
        comp["total"] = sum(comp.values())
        return comp

    def fp32_nbytes(self) -> int:
        """Bytes of the fp32 ``BlockedImpactIndex`` holding the same
        postings/geometry (docids+w_b+w_l flat arrays, tile_ptr, tile
        maxima, sigmas) — the baseline for the compression ratio."""
        return (self.nnz * 12
                + self.n_terms * (self.n_tiles + 1) * 4
                + self.n_terms * self.n_tiles * 8
                + self.n_terms * 8)

    def save(self, path) -> None:
        """Persist to one ``.npz`` (host copy of every array)."""
        meta = np.array([self.n_docs, self.n_terms, self.tile_size,
                         self.n_tiles, self.pad_len, self.nnz], np.int64)
        arrays = {name: np.asarray(getattr(self, name)) for name in
                  ("packed", "qb", "ql", "tile_ptr", "pack_ptr", "width",
                   "first", "scale_b", "zero_b", "scale_l", "zero_l",
                   "tile_max_b", "tile_max_l", "sigma_b", "sigma_l")}
        if self.orig_of_new is not None:
            arrays["orig_of_new"] = self.orig_of_new
        np.savez(path, meta=meta, **arrays)

    @classmethod
    def load(cls, path) -> "CompressedImpactIndex":
        with np.load(path) as z:
            meta = z["meta"]
            kw = {name: jnp.asarray(z[name]) for name in
                  ("packed", "qb", "ql", "tile_ptr", "pack_ptr", "width",
                   "first", "scale_b", "zero_b", "scale_l", "zero_l",
                   "tile_max_b", "tile_max_l", "sigma_b", "sigma_l")}
            orig = z["orig_of_new"] if "orig_of_new" in z.files else None
        return cls(n_docs=int(meta[0]), n_terms=int(meta[1]),
                   tile_size=int(meta[2]), n_tiles=int(meta[3]),
                   pad_len=int(meta[4]), nnz=int(meta[5]),
                   orig_of_new=orig, **kw)


def encode_runs(loc: np.ndarray, w_b: np.ndarray, w_l: np.ndarray,
                run_of: np.ndarray, cnt_flat: np.ndarray) -> dict:
    """Encode term-major postings grouped into (term, tile) runs.

    loc:      [nnz] tile-local offsets, strictly increasing within a run
    run_of:   [nnz] run id per posting (non-decreasing)
    cnt_flat: [n_runs] postings per run

    Returns numpy arrays: ``packed`` (uint32, runs word-aligned in run-id
    order), ``qb``/``ql`` (uint8, posting order), and per-run ``width``
    (uint8), ``first`` (uint16), ``words`` (int64), scale/zero fp16 pairs.
    Runs are fully self-contained, so concatenating the outputs of
    per-chunk encodes (in global run order) is bit-identical to one
    encode of the whole corpus — the property the streaming builder's
    chunked-vs-oneshot test pins.
    """
    loc = np.asarray(loc, dtype=np.int64)
    run_of = np.asarray(run_of, dtype=np.int64)
    cnt_flat = np.asarray(cnt_flat, dtype=np.int64)
    n_runs = len(cnt_flat)
    nnz = len(loc)
    run_start = np.zeros(n_runs + 1, dtype=np.int64)
    np.cumsum(cnt_flat, out=run_start[1:])
    if int(run_start[-1]) != nnz:
        raise ValueError("cnt_flat does not sum to len(loc)")

    pos = np.arange(nnz, dtype=np.int64) - run_start[run_of]
    is_first = pos == 0
    prev = np.empty(nnz, dtype=np.int64)
    prev[1:] = loc[:-1]
    prev[:1] = 0
    gaps = np.where(is_first, 0, loc - prev - 1)
    if nnz and int(gaps.min()) < 0:
        raise ValueError("run offsets must be strictly increasing")

    enc_mask = ~is_first
    maxv = np.zeros(n_runs, dtype=np.int64)
    np.maximum.at(maxv, run_of[enc_mask], gaps[enc_mask])
    width = codec.choose_width(maxv)
    words = codec.words_for(np.maximum(cnt_flat - 1, 0), width)
    word_start = np.zeros(n_runs + 1, dtype=np.int64)
    np.cumsum(words, out=word_start[1:])
    packed = codec.pack_runs(gaps[enc_mask], run_of[enc_mask],
                             (pos - 1)[enc_mask], width, word_start[:-1])
    total_words = int(word_start[-1])
    if len(packed) < total_words:  # trailing empty runs
        packed = np.concatenate(
            [packed, np.zeros(total_words - len(packed), np.uint32)])

    first = np.zeros(n_runs, dtype=np.int64)
    first[run_of[is_first]] = loc[is_first]
    if n_runs and int(first.max(initial=0)) > 0xFFFF:
        raise ValueError("tile-local offset exceeds uint16; "
                         "tile_size must be <= 65536")

    qb, scale_b, zero_b = codec.quantize_runs(w_b, run_of, n_runs)
    ql, scale_l, zero_l = codec.quantize_runs(w_l, run_of, n_runs)
    return dict(packed=packed, qb=qb, ql=ql, width=width,
                first=first.astype(np.uint16), words=words,
                scale_b=scale_b, zero_b=zero_b,
                scale_l=scale_l, zero_l=zero_l)


def from_encoded_grids(n_docs: int, n_terms: int, tile_size: int,
                       cnt: np.ndarray, words: np.ndarray,
                       packed: np.ndarray, qb: np.ndarray, ql: np.ndarray,
                       width: np.ndarray, first: np.ndarray,
                       scale_b: np.ndarray, zero_b: np.ndarray,
                       scale_l: np.ndarray, zero_l: np.ndarray,
                       tile_max_b: np.ndarray, tile_max_l: np.ndarray,
                       *, pad_multiple: int = 8, pad_cap: int | None = None,
                       orig_of_new: np.ndarray | None = None
                       ) -> CompressedImpactIndex:
    """Assemble the device index from [n_terms, n_tiles] metadata grids
    plus the flat encoded arrays (global term-major run order). Shared by
    the one-shot compressor and the streaming builder's finalize."""
    n_tiles = cnt.shape[1]
    tile_ptr_f = np.zeros(n_terms * n_tiles + 1, dtype=np.int64)
    np.cumsum(cnt.reshape(-1), out=tile_ptr_f[1:])
    tile_ptr = np.empty((n_terms, n_tiles + 1), dtype=np.int32)
    tile_ptr[:, :-1] = tile_ptr_f[:-1].reshape(n_terms, n_tiles)
    tile_ptr[:, -1] = tile_ptr_f[1:].reshape(n_terms, n_tiles)[:, -1]

    pack_ptr_f = np.zeros(n_terms * n_tiles + 1, dtype=np.int64)
    np.cumsum(words.reshape(-1), out=pack_ptr_f[1:])
    pack_ptr = np.empty((n_terms, n_tiles + 1), dtype=np.int32)
    pack_ptr[:, :-1] = pack_ptr_f[:-1].reshape(n_terms, n_tiles)
    pack_ptr[:, -1] = pack_ptr_f[1:].reshape(n_terms, n_tiles)[:, -1]

    run_max = int(cnt.max()) if cnt.size else 0
    pad_len = max(pad_multiple, -(-run_max // pad_multiple) * pad_multiple)
    if pad_cap is not None:
        pad_len = min(pad_len, pad_cap)
        if run_max > pad_len:
            raise ValueError(f"pad_cap {pad_cap} < max run {run_max}")

    return CompressedImpactIndex(
        n_docs=n_docs, n_terms=n_terms, tile_size=tile_size,
        n_tiles=n_tiles, pad_len=pad_len, nnz=int(tile_ptr_f[-1]),
        packed=jnp.asarray(packed, dtype=jnp.uint32),
        qb=jnp.asarray(qb, dtype=jnp.uint8),
        ql=jnp.asarray(ql, dtype=jnp.uint8),
        tile_ptr=jnp.asarray(tile_ptr), pack_ptr=jnp.asarray(pack_ptr),
        width=jnp.asarray(width.reshape(n_terms, n_tiles)),
        first=jnp.asarray(first.reshape(n_terms, n_tiles)),
        scale_b=jnp.asarray(scale_b.reshape(n_terms, n_tiles)),
        zero_b=jnp.asarray(zero_b.reshape(n_terms, n_tiles)),
        scale_l=jnp.asarray(scale_l.reshape(n_terms, n_tiles)),
        zero_l=jnp.asarray(zero_l.reshape(n_terms, n_tiles)),
        tile_max_b=jnp.asarray(tile_max_b), tile_max_l=jnp.asarray(tile_max_l),
        sigma_b=jnp.asarray(tile_max_b.max(axis=1)),
        sigma_l=jnp.asarray(tile_max_l.max(axis=1)),
        orig_of_new=orig_of_new)


def compress_index(merged: MergedPostings, tile_size: int = 2048,
                   pad_multiple: int = 8, pad_cap: int | None = None,
                   doc_order: np.ndarray | None = None
                   ) -> CompressedImpactIndex:
    """One-shot compressed build — same signature as ``core.build_index``
    and the same tile layout (via ``core.index.blocked_layout``), with the
    flat postings encoded instead of stored fp32."""
    lay = blocked_layout(merged, tile_size, pad_multiple, pad_cap, doc_order)
    n_terms, n_tiles = lay["n_terms"], lay["n_tiles"]
    docids = lay["docids"].astype(np.int64)
    tile_of = docids // tile_size
    term_of = np.repeat(np.arange(n_terms, dtype=np.int64),
                        lay["cnt"].sum(axis=1, dtype=np.int64))
    run_of = term_of * n_tiles + tile_of
    loc = docids - tile_of * tile_size
    enc = encode_runs(loc, lay["w_b"], lay["w_l"], run_of,
                      lay["cnt"].reshape(-1))
    g = lambda a: np.asarray(a).reshape(n_terms, n_tiles)
    return from_encoded_grids(
        lay["n_docs"], n_terms, tile_size, lay["cnt"], g(enc["words"]),
        enc["packed"], enc["qb"], enc["ql"], g(enc["width"]), g(enc["first"]),
        g(enc["scale_b"]), g(enc["zero_b"]), g(enc["scale_l"]),
        g(enc["zero_l"]), lay["tile_max_b"], lay["tile_max_l"],
        pad_multiple=pad_multiple, pad_cap=pad_cap,
        orig_of_new=lay["orig_of_new"])


# ---------------------------------------------------------------------------
# Query-time decode (jnp reference path + raw rows for the Pallas kernels)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("pad_len", "tile_size"))
def gather_tile_q(gt: tuple, q_terms: jax.Array, tile: jax.Array,
                  qw_b: jax.Array | None = None,
                  qw_l: jax.Array | None = None,
                  *, pad_len: int, tile_size: int):
    """Decode-on-gather: the q8 counterpart of ``core.index.gather_tile``.

    Returns the identical (offs [Nq, P] int32 / wb, wl [Nq, P] f32)
    contract: gap j decodes as one word load + shift + mask (widths divide
    32, so no value spans words), offsets come from one cumsum over
    ``first`` and the gaps, impacts dequantize as ``(zero + scale * q)``
    — each <= the exact fp32 tile max by construction — then scale by the
    query weight exactly like the fp32 gather.
    """
    (packed, qb, ql, tile_ptr, pack_ptr, width, first,
     scale_b, zero_b, scale_l, zero_l) = gt
    start = tile_ptr[q_terms, tile]                     # [Nq]
    cnt = tile_ptr[q_terms, tile + 1] - start           # [Nq]
    pw = pack_ptr[q_terms, tile]                        # [Nq]
    w = width[q_terms, tile].astype(jnp.int32)          # [Nq]
    f0 = first[q_terms, tile].astype(jnp.int32)         # [Nq]

    j = jnp.arange(pad_len, dtype=jnp.int32)[None, :]   # [1, P]
    bitpos = jnp.maximum(j - 1, 0) * w[:, None]         # value idx = j - 1
    word = jnp.take(packed, pw[:, None] + (bitpos >> 5), mode="clip")
    mask = (jnp.uint32(1) << w.astype(jnp.uint32)) - jnp.uint32(1)
    val = (word >> (bitpos & 31).astype(jnp.uint32)) & mask[:, None]
    contrib = jnp.where(j == 0, f0[:, None], val.astype(jnp.int32) + 1)
    valid = j < cnt[:, None]
    offs = jnp.where(valid, jnp.cumsum(contrib, axis=1), -1).astype(jnp.int32)

    idx = jnp.where(valid, start[:, None] + j, 0)

    def deq(codes, scale, zero):
        z = zero[q_terms, tile].astype(jnp.float32)[:, None]
        s = scale[q_terms, tile].astype(jnp.float32)[:, None]
        v = z + s * jnp.take(codes, idx, mode="clip").astype(jnp.float32)
        return jnp.where(valid, v, 0.0)

    wb = deq(qb, scale_b, zero_b)
    wl = deq(ql, scale_l, zero_l)
    if qw_b is not None:
        wb = wb * qw_b[:, None]
    if qw_l is not None:
        wl = wl * qw_l[:, None]
    return offs, wb, wl


def raw_words_len(pad_len: int) -> int:
    """Packed words needed to cover a run of ``pad_len`` postings: at most
    ``pad_len - 1`` gaps at 16 bits = ceil((pad_len - 1) / 2) words."""
    return max(1, (pad_len + 1) // 2)


@partial(jax.jit, static_argnames=("pad_len",))
def gather_tile_q_raw(gt: tuple, q_terms: jax.Array, tile: jax.Array,
                      *, pad_len: int):
    """Fetch *undecoded* per-term rows for the in-kernel Pallas decode.

    Returns:
      words   [Nq, Wp] int32 — packed gap words (bitcast from uint32)
      qb_row  [Nq, P]  f32   — raw uint8 impact codes (garbage past cnt;
      ql_row  [Nq, P]  f32     the kernel gates on j < cnt)
      meta_i  [3, Nq]  int32 — rows: cnt, first, width
      meta_f  [4, Nq]  f32   — rows: zero_b, scale_b, zero_l, scale_l
    """
    (packed, qb, ql, tile_ptr, pack_ptr, width, first,
     scale_b, zero_b, scale_l, zero_l) = gt
    start = tile_ptr[q_terms, tile]
    cnt = tile_ptr[q_terms, tile + 1] - start
    pw = pack_ptr[q_terms, tile]
    wp = raw_words_len(pad_len)
    widx = pw[:, None] + jnp.arange(wp, dtype=jnp.int32)[None, :]
    words = jax.lax.bitcast_convert_type(
        jnp.take(packed, widx, mode="clip"), jnp.int32)
    j = jnp.arange(pad_len, dtype=jnp.int32)[None, :]
    idx = start[:, None] + j
    qb_row = jnp.take(qb, idx, mode="clip").astype(jnp.float32)
    ql_row = jnp.take(ql, idx, mode="clip").astype(jnp.float32)
    meta_i = jnp.stack([cnt, first[q_terms, tile].astype(jnp.int32),
                        width[q_terms, tile].astype(jnp.int32)])
    meta_f = jnp.stack([zero_b[q_terms, tile].astype(jnp.float32),
                        scale_b[q_terms, tile].astype(jnp.float32),
                        zero_l[q_terms, tile].astype(jnp.float32),
                        scale_l[q_terms, tile].astype(jnp.float32)])
    return words, qb_row, ql_row, meta_i, meta_f
