"""Compression primitives for the blocked impact index.

Two codecs, both operating on per-(term, tile) posting runs — the unit
the padded gather fetches, so decode never crosses a run boundary:

- **delta + bit-pack** for tile-local doc offsets. Within a run offsets
  are strictly increasing, so gaps are positive; we store ``gap - 1`` at
  a per-run fixed width drawn from {1, 2, 4, 8, 16} bits. Every width
  divides 32, so a packed value never spans a uint32 word boundary —
  the decode is one word load, one shift, one mask, with no two-word
  stitching (the property the in-kernel Pallas decoder relies on). The
  run's *first* offset is stored separately in the run metadata
  (uint16), so a single far-into-the-tile posting never widens the run.
- **int8 linear quantization** for the two impact channels, with per-run
  fp16 scale/zero-point. Both are rounded *toward -inf* so that
  ``fl(zero + scale * q) <= max(run)`` holds in exact float32 arithmetic
  for every q <= 255 (scale*q has <= 19 mantissa bits, hence exact; the
  final add rounds monotonically below the representable run max). The
  exact fp32 tile maxima therefore remain true upper bounds for the
  dequantized impacts — chunk scheduling and theta pruning are unchanged
  from the uncompressed index.

Encoders are host-side numpy (vectorized over all runs at once, no
per-run Python loop); the numpy decoders here are the reference the
round-trip tests pin, while the query-path jnp decoder lives in
``repro.index.compressed.gather_tile_q``.
"""
from __future__ import annotations

import numpy as np

# Allowed per-run bit widths. Each divides 32, so packed values are
# always contained in a single uint32 word.
WIDTHS = (1, 2, 4, 8, 16)

# max encodable value (gap - 1) -> width: _WIDTH_OF[bit_length(maxval)]
_WIDTH_OF = np.array([1, 1, 2, 4, 4, 8, 8, 8, 8, 16, 16, 16, 16, 16, 16, 16,
                      16], dtype=np.uint8)

VALS_PER_WORD = {w: 32 // w for w in WIDTHS}


def choose_width(max_val) -> np.ndarray:
    """Smallest allowed width holding ``max_val`` (vectorized, uint8).

    ``max_val`` is the largest encoded value of a run (``max gap - 1``);
    values above 2**16 - 1 are rejected — a tile never spans more than
    65536 docids in this index (``tile_size`` cap in the builder).
    """
    mv = np.asarray(max_val)
    if mv.size and int(mv.max(initial=0)) > 0xFFFF:
        raise ValueError(f"encoded value {int(mv.max())} exceeds 16 bits; "
                         f"tile_size must be <= 65536")
    # bit_length via log2 on max(val, 1): bl(v) = floor(log2(v)) + 1
    bl = np.zeros(mv.shape, dtype=np.int64)
    pos = mv > 0
    bl[pos] = np.floor(np.log2(mv[pos].astype(np.float64))).astype(np.int64) + 1
    return _WIDTH_OF[bl]


def words_for(count, width) -> np.ndarray:
    """uint32 words needed for ``count`` values at ``width`` bits each."""
    count = np.asarray(count, dtype=np.int64)
    width = np.asarray(width, dtype=np.int64)
    return -(-(count * width) // 32)


def pack_runs(values: np.ndarray, run_of: np.ndarray, val_idx: np.ndarray,
              width_of_run: np.ndarray, word_start: np.ndarray) -> np.ndarray:
    """Bit-pack per-run values into one flat uint32 array.

    values:        [n] encoded values (< 2**width of their run)
    run_of:        [n] run index of each value
    val_idx:       [n] position of the value within its run (0-based)
    width_of_run:  [n_runs] per-run width (from ``choose_width``)
    word_start:    [n_runs] first word of each run (``words_for`` cumsum)

    Every run starts on a fresh word (word-aligned), which is what lets
    runs be sliced/concatenated — by the sharder and the streaming
    builder — without re-packing. Returns the packed word array sized
    ``word_start[-1] + words_for(last run)``; one ``bitwise_or.at``
    scatter, no Python loop.
    """
    w = width_of_run[run_of].astype(np.int64)
    bitpos = val_idx.astype(np.int64) * w
    word_idx = word_start[run_of].astype(np.int64) + (bitpos >> 5)
    shift = (bitpos & 31).astype(np.uint32)
    n_words = int(word_idx.max()) + 1 if len(word_idx) else 0
    packed = np.zeros(n_words, dtype=np.uint32)
    np.bitwise_or.at(packed, word_idx,
                     np.left_shift(values.astype(np.uint32), shift))
    return packed


def unpack_run(packed: np.ndarray, word_start: int, width: int,
               count: int) -> np.ndarray:
    """Reference numpy decoder for one run: ``count`` values at ``width``
    bits starting at word ``word_start``. Mirrors the jnp/Pallas decode
    arithmetic exactly (word load, shift, mask)."""
    j = np.arange(count, dtype=np.int64)
    bitpos = j * width
    word = packed[word_start + (bitpos >> 5)]
    mask = np.uint32((1 << width) - 1)
    return ((word >> (bitpos & 31).astype(np.uint32)) & mask).astype(np.int64)


def delta_encode(offsets: np.ndarray) -> tuple[int, np.ndarray]:
    """One run's strictly-increasing tile-local offsets -> (first, gaps-1)."""
    offsets = np.asarray(offsets, dtype=np.int64)
    if len(offsets) == 0:
        return 0, np.zeros(0, dtype=np.int64)
    d = np.diff(offsets)
    if len(d) and d.min() <= 0:
        raise ValueError("run offsets must be strictly increasing")
    return int(offsets[0]), d - 1


def delta_decode(first: int, vals: np.ndarray) -> np.ndarray:
    """Inverse of ``delta_encode``: offs[0]=first, offs[j]=offs[j-1]+v+1."""
    vals = np.asarray(vals, dtype=np.int64)
    out = np.empty(len(vals) + 1, dtype=np.int64)
    out[0] = first
    np.cumsum(vals + 1, out=out[1:])
    out[1:] += first
    return out


def fp16_down(x: np.ndarray) -> np.ndarray:
    """Largest float16 <= x, for x >= 0 (elementwise).

    numpy's float16 cast rounds to nearest; when that rounds *up* we step
    the uint16 bit pattern down one ulp (positive float16 ordering equals
    uint16 ordering, so this also collapses +inf overflow to 65504).
    """
    x = np.asarray(x, dtype=np.float32)
    h = x.astype(np.float16)
    stepped = (h.view(np.uint16) - np.uint16(1)).view(np.float16)
    return np.where(h.astype(np.float32) > x, stepped, h)


def quantize_runs(w: np.ndarray, run_of: np.ndarray, n_runs: int
                  ) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """int8-quantize impact values grouped by run.

    Returns (q uint8 [n], scale fp16 [n_runs], zero fp16 [n_runs]) with
    the bound-safety guarantee ``fl(zero + scale * q) <= max(run)`` in
    float32 for all q <= 255:

    - ``zero``  = fp16 round-down of the run min  (zero <= min),
    - ``scale`` = fp16 round-down of (max - zero) / 255, so
      ``scale * 255 <= max - zero`` exactly; ``scale * q`` has <= 19
      mantissa bits (11-bit fp16 significand x 8-bit q) hence is exact in
      fp32, and the final add rounds monotonically to <= the
      representable run max.

    Empty runs get scale = zero = 0.
    """
    w = np.asarray(w, dtype=np.float32)
    run_of = np.asarray(run_of, dtype=np.int64)
    mx = np.full(n_runs, -np.inf, dtype=np.float32)
    mn = np.full(n_runs, np.inf, dtype=np.float32)
    np.maximum.at(mx, run_of, w)
    np.minimum.at(mn, run_of, w)
    empty = ~np.isfinite(mx)
    mx[empty] = 0.0
    mn[empty] = 0.0
    zero = fp16_down(mn)
    span = (mx - zero.astype(np.float32)) / 255.0
    scale = fp16_down(np.maximum(span, 0.0))
    s32 = scale.astype(np.float32)
    z32 = zero.astype(np.float32)
    denom = np.where(s32[run_of] > 0, s32[run_of], 1.0)
    q = np.rint((w - z32[run_of]) / denom)
    q = np.clip(np.where(s32[run_of] > 0, q, 0.0), 0, 255).astype(np.uint8)
    return q, scale.astype(np.float16), zero.astype(np.float16)


def dequantize(q: np.ndarray, scale, zero) -> np.ndarray:
    """Reference dequant: the exact float32 expression the gather uses."""
    return (np.asarray(zero, np.float32)
            + np.asarray(scale, np.float32) * np.asarray(q, np.float32))
