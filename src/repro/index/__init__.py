"""repro.index — compressed blocked-impact index storage.

``CompressedImpactIndex`` keeps the BII tile geometry and exact fp32
bounds while storing postings as delta+bit-packed doc offsets and
int8-quantized impacts (per-(term, tile) fp16 scale/zero). It plugs into
every traversal executor through the polymorphic gather contract in
``core.index.dispatch_gather`` and is built either in one shot
(``compress_index``) or corpus-chunk-at-a-time with checkpointed resume
(``repro.data.StreamingIndexBuilder``).
"""
from .compressed import (CompressedImpactIndex, compress_index,
                         encode_runs, from_encoded_grids, gather_tile_q,
                         gather_tile_q_raw)
from . import codec

__all__ = ["CompressedImpactIndex", "compress_index", "encode_runs",
           "from_encoded_grids", "gather_tile_q", "gather_tile_q_raw",
           "codec"]
