from .transformer import TransformerConfig, MoEConfig, Rules  # noqa: F401
from .schnet import SchNetConfig  # noqa: F401
from .recsys import (DLRMConfig, DINConfig, TwoTowerConfig,  # noqa: F401
                     Bert4RecConfig)
