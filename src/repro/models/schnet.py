"""SchNet (Schütt et al., arXiv:1706.08566): continuous-filter convolutions.

Kernel regime: triplet-free edge gather — RBF-expanded distances feed a
filter MLP; messages are ``x[src] * W(rbf(d_ij))`` aggregated by
``segment_sum`` (the JAX-native message-passing scatter).

Two input modes share the interaction trunk:
- ``molecule``: batched small graphs (z [B, N] atom types, edges + distances
  per graph), energy readout (sum-pooled atomwise MLP).
- ``graph``: one large graph (node features [N, F] embedded linearly, flat
  edge index + synthetic distances), per-node class logits — used for the
  citation/products/reddit assigned shapes, where SchNet's geometric prior
  is re-based on edge "lengths" supplied by the data pipeline.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SchNetConfig:
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    n_atom_types: int = 100     # molecule mode vocabulary
    d_feat: int = 0             # >0: graph mode with linear feature embed
    n_out: int = 1              # 1 = energy; >1 = node classes
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    unroll: bool = False  # unroll interactions (dry-run cost probes)

    def param_count(self) -> int:
        d, r = self.d_hidden, self.n_rbf
        embed = (self.d_feat * d + d) if self.d_feat else self.n_atom_types * d
        per_inter = (r * d + d) + (d * d + d) + (d * d) + (d * d + d)
        out = d * d + d + d * self.n_out + self.n_out
        return embed + self.n_interactions * per_inter + out


def init_params(cfg: SchNetConfig, key: jax.Array) -> dict:
    ks = iter(jax.random.split(key, 4 + 4 * cfg.n_interactions))
    pt = cfg.param_dtype
    d, r = cfg.d_hidden, cfg.n_rbf

    def dense(k, i, o):
        return {"w": (jax.random.normal(k, (i, o)) / jnp.sqrt(i)).astype(pt),
                "b": jnp.zeros((o,), pt)}

    if cfg.d_feat:
        embed = dense(next(ks), cfg.d_feat, d)
    else:
        embed = {"w": (jax.random.normal(next(ks), (cfg.n_atom_types, d))
                       * 0.1).astype(pt)}
    inters = []
    for _ in range(cfg.n_interactions):
        inters.append({
            "filter1": dense(next(ks), r, d),
            "in2f": {"w": (jax.random.normal(next(ks), (d, d))
                           / jnp.sqrt(d)).astype(pt)},
            "f2out": dense(next(ks), d, d),
            "post": dense(next(ks), d, d),
        })
    return {"embed": embed,
            "inters": jax.tree_util.tree_map(lambda *x: jnp.stack(x),
                                             *inters),
            "out1": dense(next(ks), d, d),
            "out2": dense(next(ks), d, cfg.n_out)}


def _apply(layer, x):
    return x @ layer["w"].astype(x.dtype) + layer["b"].astype(x.dtype)


def shifted_softplus(x):
    return jax.nn.softplus(x) - jnp.log(2.0)


def rbf_expand(dist: jax.Array, n_rbf: int, cutoff: float) -> jax.Array:
    """Gaussian radial basis on [0, cutoff]: [E] -> [E, n_rbf]."""
    centers = jnp.linspace(0.0, cutoff, n_rbf, dtype=dist.dtype)
    gamma = n_rbf / cutoff
    return jnp.exp(-gamma * jnp.square(dist[:, None] - centers[None, :]))


def _interaction(cfg: SchNetConfig, lp: dict, x, src, dst, rbf, n_nodes):
    """cfconv + atomwise post layer. x: [N, D]."""
    w = shifted_softplus(_apply(lp["filter1"], rbf))       # [E, D]
    xs = (x @ lp["in2f"]["w"].astype(x.dtype))[src]        # gather source
    msg = xs * w
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    h = shifted_softplus(_apply(lp["f2out"], agg))
    h = _apply(lp["post"], h)
    return x + h


def encode(cfg: SchNetConfig, params: dict, nodes, src, dst, dist):
    """Shared trunk. nodes: int [N] (molecule) or float [N, F] (graph)."""
    cd = cfg.compute_dtype
    if cfg.d_feat:
        x = _apply(params["embed"], nodes.astype(cd))
    else:
        x = jnp.take(params["embed"]["w"], nodes, axis=0).astype(cd)
    rbf = rbf_expand(dist.astype(cd), cfg.n_rbf, cfg.cutoff)
    n_nodes = x.shape[0]

    def body(x, lp):
        return _interaction(cfg, lp, x, src, dst, rbf, n_nodes), None

    x, _ = jax.lax.scan(body, x, params["inters"],
                        unroll=cfg.n_interactions if cfg.unroll else 1)
    h = shifted_softplus(_apply(params["out1"], x))
    return _apply(params["out2"], h)                       # [N, n_out]


# --------------------------------------------------------------------------
# molecule mode (batched small graphs)
# --------------------------------------------------------------------------

def molecule_energy(cfg: SchNetConfig, params: dict, batch: dict):
    """batch: z [B,N] int (0 = pad), pos [B,N,3], edge_src/dst [B,E] (pad -1).

    Distances are computed from positions; padded edges masked out.
    Returns per-molecule energies [B].
    """
    b, n = batch["z"].shape
    e = batch["edge_src"].shape[1]

    def one(z, pos, es, ed):
        emask = es >= 0
        es_s = jnp.where(emask, es, 0)
        ed_s = jnp.where(emask, ed, 0)
        d = jnp.linalg.norm(pos[es_s] - pos[ed_s] + 1e-9, axis=-1)
        d = jnp.where(emask, d, cfg.cutoff)  # pad edges -> zero RBF weight
        out = encode(cfg, params, z, es_s, ed_s, d)[:, 0]
        return jnp.where(z > 0, out, 0.0).sum()

    return jax.vmap(one)(batch["z"], batch["pos"], batch["edge_src"],
                         batch["edge_dst"])


def molecule_loss(cfg: SchNetConfig, params: dict, batch: dict):
    pred = molecule_energy(cfg, params, batch)
    return jnp.mean(jnp.square(pred - batch["energy"]))


# --------------------------------------------------------------------------
# graph mode (node classification; full-batch or sampled subgraph)
# --------------------------------------------------------------------------

def node_logits(cfg: SchNetConfig, params: dict, batch: dict):
    """batch: x [N,F], edge_src/dst [E], edge_dist [E] -> logits [N, C]."""
    return encode(cfg, params, batch["x"], batch["edge_src"],
                  batch["edge_dst"], batch["edge_dist"])


def node_loss(cfg: SchNetConfig, params: dict, batch: dict):
    logits = node_logits(cfg, params, batch)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    mask = batch.get("train_mask",
                     jnp.ones_like(batch["labels"], jnp.float32))
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
