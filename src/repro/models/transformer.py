"""Decoder-style transformer LM: RoPE / GQA / SwiGLU / RMSNorm, optional MoE
(sort-based static-capacity dispatch, EP-shardable), optional bidirectional
mode + learned positions (BERT4Rec reuses this), optional SPLADE-style sparse
head (the learned sparse encoder role for the retrieval core).

Layers are scanned with stacked parameters — HLO stays O(1) in depth, which
keeps 48-layer x 512-device dry-run compiles tractable. Sharding is injected
via ``Rules`` (logical-axis -> mesh-axes) through with_sharding_constraint;
`None` rules mean single-device execution (tests, smoke configs).

Mixed precision: parameters are stored in ``param_dtype`` (fp32 by default),
compute runs in ``compute_dtype`` (bf16 by default) — the roofline counts
bf16 FLOPs.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff_expert: int
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class TransformerConfig:
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int | None = None
    moe: MoEConfig | None = None
    causal: bool = True
    rope: bool = True
    max_position: int = 0      # >0: learned positional embeddings
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = True
    sparse_head: bool = False  # SPLADE-style log1p-relu-maxpool head
    compute_dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: bool = True
    remat_policy: str = "full"  # full|dots (full = recompute layer in bwd)
    unroll: bool = False       # unroll the layer scan (dry-run cost probes)
    attn_chunk: int = 0        # >0: flash-style q-chunked attention
    kv_quant: bool = False     # int8 KV cache (per-position scales)

    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding rows padded to a 128 multiple: TP-shardable (divisible
        by the model axis) and MXU-aligned. Logical ``vocab`` is preserved
        for losses/sampling; the pad rows train toward -inf harmlessly."""
        return -(-self.vocab // 128) * 128

    def param_count(self) -> int:
        d, dh = self.d_model, self.head_dim
        attn = d * dh * (2 * self.n_heads + 2 * self.n_kv_heads)
        if self.moe is not None:
            ffn = (self.moe.n_experts * 3 * d * self.moe.d_ff_expert
                   + d * self.moe.n_experts)
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        embed = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        pos = self.max_position * d
        return self.n_layers * per_layer + embed + pos + d

    def active_param_count(self) -> int:
        """Per-token active parameters (MoE: top_k experts only)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        attn = d * self.head_dim * (2 * self.n_heads + 2 * self.n_kv_heads)
        ffn = self.moe.top_k * 3 * d * self.moe.d_ff_expert
        per_layer = attn + ffn + 2 * d + d * self.moe.n_experts
        embed = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return self.n_layers * per_layer + embed + self.max_position * d + d


@dataclasses.dataclass(frozen=True)
class Rules:
    """Logical-axis -> mesh-axis names (None = replicated)."""
    batch: Any = None       # activation batch dim
    heads: Any = None       # attention heads / ffn inner / experts
    kv_seq: Any = None      # KV cache sequence (SP for long decode)
    vocab: Any = None
    dp_size: int = 1        # data-shard count = MoE dispatch group count
    gather_weights: bool = False  # FSDP: all-gather weights in compute dtype

    def c(self, x, spec):
        if all(s is None for s in spec):
            return x
        return jax.lax.with_sharding_constraint(x, P(*spec))

    def w(self, weight, dtype):
        """Cast a parameter for compute; under FSDP, constrain the *cast*
        tensor to replicated so the per-layer all-gather moves bf16, not
        the fp32 master shard (halves gather traffic)."""
        weight = weight.astype(dtype)
        if self.gather_weights:
            weight = jax.lax.with_sharding_constraint(
                weight, P(*([None] * weight.ndim)))
        return weight


NO_RULES = Rules()


def quantize_kv(x):
    """Per-(batch, pos, head) int8 quantization: [..., Dh] -> (q, scale)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def init_params(cfg: TransformerConfig, key: jax.Array) -> dict:
    d, dh = cfg.d_model, cfg.head_dim
    h, hkv, L = cfg.n_heads, cfg.n_kv_heads, cfg.n_layers
    k = iter(jax.random.split(key, 16))
    pt = cfg.param_dtype
    s = lambda *shape: 1.0 / jnp.sqrt(jnp.prod(jnp.array(shape[:-1])) + 1.0)

    def norm_init(*shape):
        return jnp.ones(shape, dtype=pt)

    def dense(key, *shape):
        scale = (2.0 / (shape[-2] + shape[-1])) ** 0.5 if len(shape) >= 2 else 0.02
        return (jax.random.normal(key, shape) * scale).astype(pt)

    params = {
        "embed": dense(next(k), cfg.padded_vocab, d),
        "final_norm": norm_init(d),
        "layers": {
            "attn_norm": norm_init(L, d),
            "ffn_norm": norm_init(L, d),
            "wq": dense(next(k), L, d, h * dh),
            "wk": dense(next(k), L, d, hkv * dh),
            "wv": dense(next(k), L, d, hkv * dh),
            "wo": dense(next(k), L, h * dh, d),
        },
    }
    if cfg.moe is not None:
        e, f = cfg.moe.n_experts, cfg.moe.d_ff_expert
        params["layers"]["router"] = dense(next(k), L, d, e)
        params["layers"]["w_gate"] = dense(next(k), L, e, d, f)
        params["layers"]["w_up"] = dense(next(k), L, e, d, f)
        params["layers"]["w_down"] = dense(next(k), L, e, f, d)
    else:
        params["layers"]["w_gate"] = dense(next(k), L, d, cfg.d_ff)
        params["layers"]["w_up"] = dense(next(k), L, d, cfg.d_ff)
        params["layers"]["w_down"] = dense(next(k), L, cfg.d_ff, d)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense(next(k), d, cfg.padded_vocab)
    if cfg.max_position:
        params["pos_embed"] = dense(next(k), cfg.max_position, d)
    del s
    return params


# --------------------------------------------------------------------------
# building blocks
# --------------------------------------------------------------------------

def rms_norm(x, w, eps):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * w.astype(x.dtype)


def rope(x, positions, theta):
    """x: [B, S, H, Dh]; positions: [B, S]."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :].astype(x.dtype)
    sin = jnp.sin(angles)[:, :, None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)


def _attention(q, k, v, causal, q_offset, chunk: int = 0,
               unroll: bool = False):
    """q: [B, Sq, H, Dh]; k, v: [B, Skv, Hkv, Dh] (GQA via reshape).

    ``chunk`` > 0 scans over q blocks (flash-style): scores for one block
    only are ever materialized — O(Sq/chunk) passes, O(B*chunk*Skv) memory
    instead of O(B*Sq*Skv). The Pallas kernel is the real-TPU analogue.
    ``unroll`` unrolls the chunk scan (dry-run cost probes: XLA counts
    while bodies once — unrolling keeps FLOP/byte accounting exact).
    """
    b, sq, h, dh = q.shape
    if chunk and sq > chunk and sq % chunk == 0:
        nc = sq // chunk
        qc = jnp.moveaxis(q.reshape(b, nc, chunk, h, dh), 1, 0)

        def body(carry, args):
            qi, i = args
            off = q_offset + i * chunk
            return carry, _attention(qi, k, v, causal, off)

        _, out = jax.lax.scan(body, None,
                              (qc, jnp.arange(nc, dtype=jnp.int32)),
                              unroll=nc if unroll else 1)
        return jnp.moveaxis(out, 0, 1).reshape(b, sq, h, dh)
    skv, hkv = k.shape[1], k.shape[2]
    group = h // hkv
    q = q.reshape(b, sq, hkv, group, dh)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(dh).astype(s.dtype)
    if causal:
        q_pos = q_offset[:, None] + jnp.arange(sq)[None, :]   # [B, Sq]
        k_pos = jnp.arange(skv)
        mask = q_pos[:, None, None, :, None] >= k_pos[None, None, None, None, :]
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p.astype(v.dtype), v)
    return o.reshape(b, sq, h, dh)


def _moe_ffn(x, router, w_gate, w_up, w_down, moe: MoEConfig, rules: Rules):
    """Token-choice top-k MoE, GShard-style group-wise capacity dispatch.

    Tokens are split into ``rules.dp_size`` groups (= data shards); each
    group routes its local tokens into per-group expert buffers
    ``[G, E, C_local, D]`` sharded (G -> data, E -> model). The expert
    einsums are then fully local per (g, e) pair; the only communication is
    the buf resharding — the intended EP all-to-all — instead of the
    whole-buffer all-reduces a global scatter would induce under GSPMD.
    Group-wise capacity (tokens dropped per group) matches GShard
    semantics; with dp_size=1 it reduces to single-group routing.
    """
    t, d = x.shape
    e, k = moe.n_experts, moe.top_k
    g = max(1, rules.dp_size)
    if t % g != 0:  # tiny decode batches: fall back to fewer groups
        g = 1
        while t % (g * 2) == 0 and g * 2 <= rules.dp_size:
            g *= 2
    tl = t // g
    cap = int(tl * k * moe.capacity_factor / e + 1)
    xg = rules.c(x.reshape(g, tl, d), (rules.batch, None, None))

    def dispatch(xt):
        """One group: [Tl, D] -> buffers + combine metadata."""
        logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype),
                            preferred_element_type=jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_p, top_e = jax.lax.top_k(probs, k)            # [Tl, K]
        top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
        flat_e = top_e.reshape(-1)                        # [Tl*K]
        order = jnp.argsort(flat_e)                       # stable
        sorted_e = flat_e[order]
        pos_all = jnp.arange(tl * k, dtype=jnp.int32)
        start = jnp.searchsorted(sorted_e, jnp.arange(e), side="left")
        pos_in_e = pos_all - start[sorted_e]
        keep = pos_in_e < cap
        pos_safe = jnp.where(keep, pos_in_e, 0)
        tok = order // k
        buf = jnp.zeros((e, cap, d), dtype=xt.dtype)
        buf = buf.at[sorted_e, pos_safe].add(
            jnp.where(keep[:, None], xt[tok], 0.0))
        w = top_p.reshape(-1)[order].astype(xt.dtype)
        return buf, (sorted_e, pos_safe, keep, tok, w, probs, top_e)

    buf, info = jax.vmap(dispatch)(xg)                    # [G, E, C, D]
    buf = rules.c(buf, (rules.batch, rules.heads, None, None))
    hg = jnp.einsum("gecd,edf->gecf", buf, rules.w(w_gate, x.dtype),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    hu = jnp.einsum("gecd,edf->gecf", buf, rules.w(w_up, x.dtype),
                    preferred_element_type=jnp.float32).astype(x.dtype)
    hidden = jax.nn.silu(hg) * hu
    out_e = jnp.einsum("gecf,efd->gecd", hidden, rules.w(w_down, x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    out_e = rules.c(out_e, (rules.batch, rules.heads, None, None))

    def combine(out_g, inf):
        sorted_e, pos_safe, keep, tok, w, _, _ = inf
        gathered = out_g[sorted_e, pos_safe]
        gathered = jnp.where(keep[:, None], gathered, 0.0)
        return jnp.zeros((tl, d), out_g.dtype).at[tok].add(
            gathered * w[:, None])

    y = jax.vmap(combine)(out_e, info).reshape(t, d)
    y = rules.c(y.reshape(g, tl, d), (rules.batch, None, None)).reshape(t, d)
    # load-balancing auxiliary loss (Switch): E * sum(frac_tok * frac_prob)
    probs, top_e = info[5], info[6]
    frac_t = jnp.mean(jax.nn.one_hot(top_e[..., 0], e, dtype=jnp.float32),
                      (0, 1))
    frac_p = jnp.mean(probs, (0, 1))
    aux = e * jnp.sum(frac_t * frac_p)
    return y, aux


def _dense_ffn(x, w_gate, w_up, w_down, rules: Rules):
    hg = jnp.einsum("td,df->tf", x, rules.w(w_gate, x.dtype))
    hu = jnp.einsum("td,df->tf", x, rules.w(w_up, x.dtype))
    h = jax.nn.silu(hg) * hu
    h = rules.c(h, (rules.batch, rules.heads))
    return jnp.einsum("tf,fd->td", h, rules.w(w_down, x.dtype))


# --------------------------------------------------------------------------
# forward passes
# --------------------------------------------------------------------------

def _layer(cfg: TransformerConfig, rules: Rules, x, lp, positions, cache=None,
           layer_cache=None):
    """One block. x: [B, S, D]. Returns (x, aux, new_kv or None)."""
    b, s, d = x.shape
    h, hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    cd = cfg.compute_dtype
    xn = rms_norm(x, lp["attn_norm"], cfg.norm_eps)
    q = jnp.einsum("bsd,de->bse", xn, rules.w(lp["wq"], cd)).reshape(b, s, h, dh)
    k = jnp.einsum("bsd,de->bse", xn, rules.w(lp["wk"], cd)).reshape(b, s, hkv, dh)
    v = jnp.einsum("bsd,de->bse", xn, rules.w(lp["wv"], cd)).reshape(b, s, hkv, dh)
    if cfg.rope:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    # NOTE: no per-head constraint — head counts need not divide the mesh
    # (phi4: 24 heads on model=16); the flat H*Dh projections carry the
    # sharding and GSPMD propagates through the reshape.
    new_kv = None
    if layer_cache is not None and cfg.kv_quant:
        # int8 KV cache: quantize the fresh K/V slice, store int8+scale,
        # dequantize the full cache for attention. HBM traffic for the
        # cache read drops ~2x (1B + per-row scale vs bf16).
        ck, cv, cks, cvs, cache_len = layer_cache
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        ck = jax.lax.dynamic_update_slice_in_dim(ck, kq, cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, vq, cache_len, axis=1)
        cks = jax.lax.dynamic_update_slice_in_dim(cks, ks, cache_len, axis=1)
        cvs = jax.lax.dynamic_update_slice_in_dim(cvs, vs, cache_len, axis=1)
        k = dequantize_kv(ck, cks, cd)
        v = dequantize_kv(cv, cvs, cd)
        new_kv = (ck, cv, cks, cvs)
        q_offset = jnp.full((b,), cache_len, jnp.int32)
    elif layer_cache is not None:
        ck, cv, cache_len = layer_cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k, cache_len, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v, cache_len, axis=1)
        k, v = ck, cv
        new_kv = (ck, cv)
        q_offset = jnp.full((b,), cache_len, jnp.int32)
    else:
        q_offset = jnp.zeros((b,), jnp.int32)
    o = _attention(q, k, v, cfg.causal, q_offset, cfg.attn_chunk,
                   unroll=cfg.unroll)
    o = jnp.einsum("bse,ed->bsd", o.reshape(b, s, h * dh),
                   rules.w(lp["wo"], cd))
    x = x + o
    xn = rms_norm(x, lp["ffn_norm"], cfg.norm_eps)
    flat = xn.reshape(b * s, d)
    if cfg.moe is not None:
        y, aux = _moe_ffn(flat, lp["router"], lp["w_gate"], lp["w_up"],
                          lp["w_down"], cfg.moe, rules)
    else:
        y = _dense_ffn(flat, lp["w_gate"], lp["w_up"], lp["w_down"], rules)
        aux = jnp.float32(0.0)
    x = x + y.reshape(b, s, d)
    x = rules.c(x, (rules.batch, None, None))
    return x, aux, new_kv


def forward(cfg: TransformerConfig, params: dict, tokens: jax.Array,
            rules: Rules = NO_RULES, cache: dict | None = None,
            cache_len=None):
    """tokens: [B, S]. Returns (hidden [B,S,D], aux_loss, new_cache|None)."""
    cd = cfg.compute_dtype
    b, s = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0).astype(cd)
    if cache is not None:
        positions = cache_len + jnp.arange(s)[None, :].astype(jnp.int32)
        positions = jnp.broadcast_to(positions, (b, s))
    else:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    if cfg.max_position:
        x = x + jnp.take(params["pos_embed"], positions, axis=0).astype(cd)
    x = rules.c(x, (rules.batch, None, None))

    lp_stack = params["layers"]

    def scan_body(carry, inputs):
        x, aux = carry
        if cache is None:
            lp = inputs
            x, a, _ = _layer(cfg, rules, x, lp, positions)
            return (x, aux + a), None
        if cfg.kv_quant:
            lp, (ck, cv, cks, cvs) = inputs
            x, a, new_kv = _layer(cfg, rules, x, lp, positions,
                                  layer_cache=(ck, cv, cks, cvs, cache_len))
        else:
            lp, (ck, cv) = inputs
            x, a, new_kv = _layer(cfg, rules, x, lp, positions,
                                  layer_cache=(ck, cv, cache_len))
        return (x, aux + a), new_kv

    body = scan_body
    if cfg.remat and cache is None:
        if cfg.remat_policy == "dots":
            body = jax.checkpoint(scan_body,
                                  policy=jax.checkpoint_policies.dots_saveable)
        else:  # "full": save only layer inputs, recompute the layer in bwd
            body = jax.checkpoint(scan_body)
    unroll = cfg.n_layers if cfg.unroll else 1
    if cache is None:
        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), lp_stack,
                                   unroll=unroll)
        new_cache = None
    else:
        if cfg.kv_quant:
            xs = (lp_stack, (cache["k"], cache["v"], cache["k_scale"],
                             cache["v_scale"]))
        else:
            xs = (lp_stack, (cache["k"], cache["v"]))
        (x, aux), kvs = jax.lax.scan(body, (x, jnp.float32(0.0)), xs,
                                     unroll=unroll)
        new_cache = {"k": kvs[0], "v": kvs[1]}
        if cfg.kv_quant:
            new_cache["k_scale"] = kvs[2]
            new_cache["v_scale"] = kvs[3]
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, new_cache


def logits_fn(cfg: TransformerConfig, params: dict, hidden: jax.Array,
              rules: Rules = NO_RULES) -> jax.Array:
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    out = jnp.einsum("bsd,dv->bsv", hidden, head.astype(hidden.dtype),
                     preferred_element_type=jnp.float32)
    return rules.c(out, (rules.batch, None, rules.vocab))


def splade_encode(cfg: TransformerConfig, params: dict, tokens: jax.Array,
                  mask: jax.Array, rules: Rules = NO_RULES) -> jax.Array:
    """SPLADE-style learned sparse representation: [B, vocab].

    max-pool over sequence of log(1 + relu(logits)), masked.
    """
    hidden, _, _ = forward(cfg, params, tokens, rules)
    logits = logits_fn(cfg, params, hidden, rules)
    acts = jnp.log1p(jax.nn.relu(logits))
    acts = jnp.where(mask[..., None] > 0, acts, -jnp.inf)
    rep = jnp.max(acts, axis=1)[:, :cfg.vocab]  # drop pad rows
    return jnp.maximum(rep, 0.0)


# --------------------------------------------------------------------------
# steps
# --------------------------------------------------------------------------

def lm_loss(cfg: TransformerConfig, params: dict, batch: dict,
            rules: Rules = NO_RULES):
    hidden, aux, _ = forward(cfg, params, batch["tokens"], rules)
    logits = logits_fn(cfg, params, hidden, rules)
    tgt = batch["targets"]
    # logsumexp - gather: one logits-sized temp instead of a full log_softmax
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
    nll = lse - picked
    mask = batch.get("mask", jnp.ones_like(tgt, dtype=jnp.float32))
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss + 0.01 * aux


def prefill(cfg: TransformerConfig, params: dict, tokens: jax.Array,
            max_len: int, rules: Rules = NO_RULES):
    """Run prompt, build a KV cache of size max_len. Returns (logits, cache)."""
    b, s = tokens.shape
    hkv, dh, L = cfg.n_kv_heads, cfg.head_dim, cfg.n_layers
    kv_dtype = jnp.int8 if cfg.kv_quant else cfg.compute_dtype
    cache = {
        "k": jnp.zeros((L, b, max_len, hkv, dh), kv_dtype),
        "v": jnp.zeros((L, b, max_len, hkv, dh), kv_dtype),
    }
    if cfg.kv_quant:
        cache["k_scale"] = jnp.zeros((L, b, max_len, hkv), jnp.float32)
        cache["v_scale"] = jnp.zeros((L, b, max_len, hkv), jnp.float32)
    cache = jax.tree_util.tree_map(
        lambda c: rules.c(c, (None, rules.batch, rules.kv_seq, None,
                              None)[:c.ndim]),
        cache)
    hidden, _, cache = forward(cfg, params, tokens, rules, cache=cache,
                               cache_len=jnp.int32(0))
    logits = logits_fn(cfg, params, hidden[:, -1:, :], rules)
    return logits, cache


def decode_step(cfg: TransformerConfig, params: dict, token: jax.Array,
                cache: dict, cache_len, rules: Rules = NO_RULES):
    """One decode step. token: [B, 1]. Returns (logits [B,1,V], new cache)."""
    hidden, _, cache = forward(cfg, params, token, rules, cache=cache,
                               cache_len=cache_len)
    logits = logits_fn(cfg, params, hidden, rules)
    return logits, cache
