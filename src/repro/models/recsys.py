"""RecSys architectures: DLRM, DIN, two-tower retrieval, BERT4Rec.

Embedding tables are the hot substrate: built on the manual EmbeddingBag
(``repro/sparse_ops``), row-shardable over the full mesh (model-parallel
embeddings, the DLRM pattern). The two-tower serve path ``retrieval_cand``
transfers the paper's technique to dense retrieval via
``repro/core/dense_guided``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from ..sparse_ops import embedding_bag
from .transformer import (Rules, NO_RULES, TransformerConfig, forward,
                          init_params as init_tf_params)


def _mlp_init(key, dims, pt):
    layers = []
    for k, (i, o) in zip(jax.random.split(key, len(dims) - 1),
                         zip(dims[:-1], dims[1:])):
        layers.append({"w": (jax.random.normal(k, (i, o))
                             * (2.0 / (i + o)) ** 0.5).astype(pt),
                       "b": jnp.zeros((o,), pt)})
    return layers


def _mlp(layers, x, final_act=False):
    for i, l in enumerate(layers):
        x = x @ l["w"].astype(x.dtype) + l["b"].astype(x.dtype)
        if final_act or i < len(layers) - 1:
            x = jax.nn.relu(x)
    return x


def _mlp_params(dims):
    return sum(i * o + o for i, o in zip(dims[:-1], dims[1:]))


# ---------------------------------------------------------------------------
# DLRM (arXiv:1906.00091), RM-2 scale
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab_per_field: int = 1_000_000
    bot_mlp: tuple = (13, 512, 256, 64)
    top_mlp_hidden: tuple = (512, 512, 256, 1)
    multi_hot: int = 1          # lookups per field (EmbeddingBag when > 1)
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        n_inter = self.n_sparse + 1
        d_inter = n_inter * (n_inter - 1) // 2 + self.embed_dim
        return (self.n_sparse * self.vocab_per_field * self.embed_dim
                + _mlp_params(self.bot_mlp)
                + _mlp_params((d_inter,) + self.top_mlp_hidden))


def init_dlrm(cfg: DLRMConfig, key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    pt = cfg.param_dtype
    tables = (jax.random.normal(
        k1, (cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim)) * 0.01
    ).astype(pt)
    n_inter = cfg.n_sparse + 1
    d_inter = n_inter * (n_inter - 1) // 2 + cfg.embed_dim
    return {"tables": tables,
            "bot": _mlp_init(k2, list(cfg.bot_mlp), pt),
            "top": _mlp_init(k3, [d_inter] + list(cfg.top_mlp_hidden), pt)}


def dlrm_forward(cfg: DLRMConfig, params: dict, batch: dict,
                 rules: Rules = NO_RULES):
    """batch: dense [B, 13] f32, sparse [B, 26, multi_hot] int32 -> [B]."""
    cd = cfg.compute_dtype
    dense = batch["dense"].astype(cd)
    bot = _mlp(params["bot"], dense, final_act=True)       # [B, D]
    sparse = batch["sparse"]
    b = sparse.shape[0]

    def field(f):
        idx = sparse[:, f, :]
        w = jnp.ones(idx.shape, cd)
        return embedding_bag(params["tables"][f].astype(cd), idx, w)

    embs = jnp.stack([field(f) for f in range(cfg.n_sparse)], 1)  # [B,26,D]
    feats = jnp.concatenate([bot[:, None, :], embs], axis=1)      # [B,27,D]
    feats = rules.c(feats, (rules.batch, None, None))
    inter = jnp.einsum("bnd,bmd->bnm", feats, feats)
    n = feats.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    flat = inter[:, iu, ju]                                       # [B, 351]
    top_in = jnp.concatenate([bot, flat], axis=-1)
    return _mlp(params["top"], top_in)[:, 0]


def dlrm_loss(cfg: DLRMConfig, params: dict, batch: dict,
              rules: Rules = NO_RULES):
    logit = dlrm_forward(cfg, params, batch, rules)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# DIN (arXiv:1706.06978)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DINConfig:
    embed_dim: int = 18
    seq_len: int = 100
    n_items: int = 200_000
    attn_mlp: tuple = (80, 40)
    mlp: tuple = (200, 80)
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        d = self.embed_dim
        return (self.n_items * d
                + _mlp_params((4 * d,) + self.attn_mlp + (1,))
                + _mlp_params((2 * d,) + self.mlp + (1,)))


def init_din(cfg: DINConfig, key: jax.Array) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    pt = cfg.param_dtype
    return {
        "items": (jax.random.normal(k1, (cfg.n_items, cfg.embed_dim))
                  * 0.01).astype(pt),
        "attn": _mlp_init(k2, [4 * cfg.embed_dim, *cfg.attn_mlp, 1], pt),
        "mlp": _mlp_init(k3, [2 * cfg.embed_dim, *cfg.mlp, 1], pt),
    }


def din_forward(cfg: DINConfig, params: dict, batch: dict,
                rules: Rules = NO_RULES):
    """batch: hist [B, L] int (0 pad), target [B] int -> logits [B]."""
    cd = cfg.compute_dtype
    hist = jnp.take(params["items"], batch["hist"], axis=0).astype(cd)
    tgt = jnp.take(params["items"], batch["target"], axis=0).astype(cd)
    tgt_b = jnp.broadcast_to(tgt[:, None, :], hist.shape)
    att_in = jnp.concatenate(
        [hist, tgt_b, hist * tgt_b, hist - tgt_b], axis=-1)
    scores = _mlp(params["attn"], att_in)[..., 0]          # [B, L]
    mask = batch["hist"] > 0
    scores = jnp.where(mask, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1)
    user = jnp.einsum("bl,bld->bd", w, hist)
    x = jnp.concatenate([user, tgt], axis=-1)
    x = rules.c(x, (rules.batch, None))
    return _mlp(params["mlp"], x)[:, 0]


def din_loss(cfg: DINConfig, params: dict, batch: dict,
             rules: Rules = NO_RULES):
    logit = din_forward(cfg, params, batch, rules)
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logit, 0) - logit * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# ---------------------------------------------------------------------------
# Two-tower retrieval (YouTube RecSys'19 style)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TwoTowerConfig:
    embed_dim: int = 256
    tower_mlp: tuple = (1024, 512, 256)
    n_user_feats: int = 500_000
    n_items: int = 2_000_000
    user_bag: int = 16          # multi-hot user history bag size
    feat_dim: int = 128         # embedding dim feeding the towers
    n_negatives: int = 1024     # sampled softmax negatives
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32

    def param_count(self) -> int:
        return (self.n_user_feats * self.feat_dim
                + self.n_items * self.feat_dim
                + _mlp_params((self.feat_dim,) + self.tower_mlp) * 2)


def init_two_tower(cfg: TwoTowerConfig, key: jax.Array) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pt = cfg.param_dtype
    return {
        "user_embed": (jax.random.normal(k1, (cfg.n_user_feats, cfg.feat_dim))
                       * 0.02).astype(pt),
        "item_embed": (jax.random.normal(k2, (cfg.n_items, cfg.feat_dim))
                       * 0.02).astype(pt),
        "user_tower": _mlp_init(k3, [cfg.feat_dim, *cfg.tower_mlp], pt),
        "item_tower": _mlp_init(k4, [cfg.feat_dim, *cfg.tower_mlp], pt),
    }


def user_encode(cfg: TwoTowerConfig, params: dict, user_feats, rules=NO_RULES):
    cd = cfg.compute_dtype
    bag = embedding_bag(params["user_embed"].astype(cd), user_feats,
                        jnp.asarray(user_feats > 0, cd), mode="mean")
    u = _mlp(params["user_tower"], bag)
    return u / jnp.maximum(jnp.linalg.norm(u, axis=-1, keepdims=True), 1e-6)


def item_encode(cfg: TwoTowerConfig, params: dict, item_ids, rules=NO_RULES):
    cd = cfg.compute_dtype
    e = jnp.take(params["item_embed"], item_ids, axis=0).astype(cd)
    v = _mlp(params["item_tower"], e)
    return v / jnp.maximum(jnp.linalg.norm(v, axis=-1, keepdims=True), 1e-6)


def two_tower_loss(cfg: TwoTowerConfig, params: dict, batch: dict,
                   rules: Rules = NO_RULES):
    """Sampled softmax with shared negatives + logQ correction.

    batch: user_feats [B, bag], pos_item [B], neg_items [N], neg_logq [N].
    """
    u = user_encode(cfg, params, batch["user_feats"], rules)   # [B, D]
    pos = item_encode(cfg, params, batch["pos_item"], rules)   # [B, D]
    neg = item_encode(cfg, params, batch["neg_items"], rules)  # [N, D]
    u = rules.c(u, (rules.batch, None))
    temp = 20.0
    s_pos = (u * pos).sum(-1) * temp                            # [B]
    s_neg = u @ neg.T * temp - batch["neg_logq"][None, :]       # [B, N]
    logits = jnp.concatenate([s_pos[:, None], s_neg], axis=1)
    return -jnp.mean(jax.nn.log_softmax(logits, axis=-1)[:, 0])


def two_tower_score_candidates(cfg: TwoTowerConfig, params: dict,
                               user_feats, cand_emb, rules: Rules = NO_RULES):
    """Bulk-score 1 query against precomputed candidate tower outputs.

    cand_emb: [N_cand, D] (item tower outputs). Returns scores [N_cand].
    """
    u = user_encode(cfg, params, user_feats, rules)             # [1, D]
    return (cand_emb.astype(u.dtype) @ u[0]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# BERT4Rec (arXiv:1904.06690) — reuses the transformer, bidirectional
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Bert4RecConfig:
    n_items: int = 50_000
    embed_dim: int = 64
    n_blocks: int = 2
    n_heads: int = 2
    seq_len: int = 200
    compute_dtype: Any = jnp.float32
    param_dtype: Any = jnp.float32
    unroll: bool = False

    def tf_config(self) -> TransformerConfig:
        return TransformerConfig(
            n_layers=self.n_blocks, d_model=self.embed_dim,
            n_heads=self.n_heads, n_kv_heads=self.n_heads,
            d_ff=4 * self.embed_dim, vocab=self.n_items + 2,  # +pad +mask
            causal=False, rope=False, max_position=self.seq_len,
            tie_embeddings=True, compute_dtype=self.compute_dtype,
            param_dtype=self.param_dtype, remat=False, unroll=self.unroll)

    def param_count(self) -> int:
        return self.tf_config().param_count()


def init_bert4rec(cfg: Bert4RecConfig, key: jax.Array) -> dict:
    return init_tf_params(cfg.tf_config(), key)


def bert4rec_loss(cfg: Bert4RecConfig, params: dict, batch: dict,
                  rules: Rules = NO_RULES):
    """Masked-item prediction with *sampled* softmax: items/targets/mask
    [B, S] plus shared negatives ``neg_items`` [N]. A full softmax over a
    1M-item catalog would materialize [B, S, V] logits (hundreds of GB per
    device at the assigned batch) — sampled softmax is how production
    BERT4Rec-style models train at catalog scale."""
    tf_cfg = cfg.tf_config()
    hidden, _, _ = forward(tf_cfg, params, batch["items"], rules)
    emb = params["embed"].astype(hidden.dtype)
    pos_e = jnp.take(emb, batch["targets"], axis=0)          # [B, S, D]
    pos = jnp.einsum("bsd,bsd->bs", hidden, pos_e)
    neg_e = jnp.take(emb, batch["neg_items"], axis=0)        # [N, D]
    neg = jnp.einsum("bsd,nd->bsn", hidden, neg_e,
                     preferred_element_type=jnp.float32)
    lse = jnp.logaddexp(pos.astype(jnp.float32),
                        jax.nn.logsumexp(neg, axis=-1))
    nll = lse - pos
    mask = batch["mask"].astype(jnp.float32)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def bert4rec_score_catalog(cfg: Bert4RecConfig, params: dict, items,
                           cand_ids, rules: Rules = NO_RULES):
    """Next-item scores of candidate ids for each sequence: [B, N_cand]."""
    tf_cfg = cfg.tf_config()
    hidden, _, _ = forward(tf_cfg, params, items, rules)
    state = hidden[:, -1, :]                                 # [B, D]
    cand = jnp.take(params["embed"], cand_ids, axis=0).astype(state.dtype)
    return jnp.einsum("bd,nd->bn", state, cand,
                      preferred_element_type=jnp.float32)
