"""Deterministic, resume-safe data streams: every batch is a pure function
of (seed, step) — a restart replays exactly the unapplied batches."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def lm_batch(step: int, *, batch: int, seq: int, vocab: int, seed: int = 0,
             zipf_a: float = 1.2) -> dict:
    """Zipf-distributed synthetic token stream (LM pretraining proxy)."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step]))
    ranks = rng.zipf(zipf_a, size=(batch, seq + 1))
    toks = np.minimum(ranks, vocab - 1).astype(np.int32)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:])}


def pair_batch(step: int, *, batch: int, seq: int, vocab: int,
               n_rel_terms: int = 4, seed: int = 0) -> dict:
    """(query, positive doc) pairs for sparse-encoder distillation: docs
    share salient terms with their query; teacher score = overlap count."""
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 1]))
    salient = rng.integers(1, vocab, size=(batch, n_rel_terms))
    q = np.concatenate([salient, rng.integers(1, vocab,
                                              (batch, seq - n_rel_terms))], 1)
    d_pos = np.concatenate([salient, rng.integers(1, vocab,
                                                  (batch, seq - n_rel_terms))],
                           1)
    d_neg = rng.integers(1, vocab, size=(batch, seq))
    return {"query": jnp.asarray(q.astype(np.int32)),
            "doc_pos": jnp.asarray(d_pos.astype(np.int32)),
            "doc_neg": jnp.asarray(d_neg.astype(np.int32))}


def recsys_batch(step: int, *, kind: str, cfg, batch: int, seed: int = 0
                 ) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 2]))
    if kind == "dlrm":
        return {"dense": jnp.asarray(
                    rng.standard_normal((batch, cfg.n_dense)), jnp.float32),
                "sparse": jnp.asarray(rng.integers(
                    0, cfg.vocab_per_field,
                    (batch, cfg.n_sparse, cfg.multi_hot))),
                "label": jnp.asarray(rng.integers(0, 2, batch))}
    if kind == "din":
        return {"hist": jnp.asarray(
                    rng.integers(0, cfg.n_items, (batch, cfg.seq_len))),
                "target": jnp.asarray(rng.integers(0, cfg.n_items, batch)),
                "label": jnp.asarray(rng.integers(0, 2, batch))}
    raise ValueError(kind)


class GraphStore:
    """CSR adjacency + real fanout neighbor sampler (minibatch_lg cell)."""

    def __init__(self, n_nodes: int, n_edges: int, d_feat: int,
                 n_classes: int, seed: int = 0):
        rng = np.random.default_rng(seed)
        # power-law-ish degree distribution
        src = rng.integers(0, n_nodes, n_edges).astype(np.int32)
        dst = np.minimum((rng.pareto(1.5, n_edges) * n_nodes / 8),
                         n_nodes - 1).astype(np.int32)
        order = np.argsort(dst, kind="stable")
        self.src, self.dst = src[order], dst[order]
        self.indptr = np.zeros(n_nodes + 1, np.int64)
        np.cumsum(np.bincount(self.dst, minlength=n_nodes),
                  out=self.indptr[1:])
        self.n_nodes, self.d_feat, self.n_classes = n_nodes, d_feat, n_classes
        self.feat_seed = seed

    def features(self, nodes: np.ndarray) -> np.ndarray:
        """Deterministic per-node features (hash-seeded)."""
        rng = np.random.default_rng(self.feat_seed)
        base = rng.standard_normal((256, self.d_feat)).astype(np.float32)
        return base[nodes % 256] + (nodes % 7)[:, None] * 0.01

    def labels(self, nodes: np.ndarray) -> np.ndarray:
        return (nodes % self.n_classes).astype(np.int32)

    def sample(self, step: int, batch_nodes: int, fanouts=(15, 10),
               seed: int = 0) -> dict:
        """k-hop uniform neighbor sampling -> padded subgraph arrays."""
        rng = np.random.default_rng(np.random.SeedSequence([seed, step, 3]))
        seeds = rng.choice(self.n_nodes, batch_nodes, replace=False)
        nodes = [seeds]
        edges_src, edges_dst = [], []
        frontier = seeds
        for fan in fanouts:
            nbr_src = []
            nbr_dst = []
            for v in frontier:
                s, e = self.indptr[v], self.indptr[v + 1]
                if e > s:
                    pick = self.src[rng.integers(s, e, size=fan)]
                else:
                    pick = np.full(fan, v, np.int32)
                nbr_src.append(pick)
                nbr_dst.append(np.full(fan, v, np.int32))
            frontier = np.concatenate(nbr_src)
            edges_src.append(frontier)
            edges_dst.append(np.concatenate(nbr_dst))
            nodes.append(frontier)
        all_nodes, inv = np.unique(np.concatenate(nodes),
                                   return_inverse=False), None
        del inv
        remap = {v: i for i, v in enumerate(all_nodes)}
        es = np.array([remap[v] for v in np.concatenate(edges_src)],
                      np.int32)
        ed = np.array([remap[v] for v in np.concatenate(edges_dst)],
                      np.int32)
        deg = np.maximum(self.indptr[all_nodes + 1] - self.indptr[all_nodes],
                         1)
        dist_nodes = 1.0 + 9.0 / np.sqrt(deg)
        edge_dist = ((dist_nodes[es] + dist_nodes[ed]) / 2).astype(np.float32)
        mask = np.zeros(len(all_nodes), np.float32)
        mask[[remap[v] for v in seeds]] = 1.0
        return {"x": self.features(all_nodes),
                "edge_src": es, "edge_dst": ed, "edge_dist": edge_dist,
                "labels": self.labels(all_nodes), "train_mask": mask}


def molecule_batch(step: int, *, batch: int, atoms: int, edges: int,
                   n_types: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(np.random.SeedSequence([seed, step, 4]))
    z = rng.integers(1, n_types, (batch, atoms)).astype(np.int32)
    pos = rng.standard_normal((batch, atoms, 3)).astype(np.float32) * 2
    es = rng.integers(0, atoms, (batch, edges)).astype(np.int32)
    ed = rng.integers(0, atoms, (batch, edges)).astype(np.int32)
    # synthetic energy: pairwise potential proxy so the model can learn
    d = np.linalg.norm(pos[np.arange(batch)[:, None], es]
                       - pos[np.arange(batch)[:, None], ed], axis=-1)
    energy = (np.exp(-d) - 0.1 * d).sum(1).astype(np.float32)
    return {"z": jnp.asarray(z), "pos": jnp.asarray(pos),
            "edge_src": jnp.asarray(es), "edge_dst": jnp.asarray(ed),
            "energy": jnp.asarray(energy)}
