"""Synthetic retrieval corpora with controllable BM25<->learned alignment.

No MS MARCO offline, so the evaluation reproduces the paper's *phenomena* on
generated data whose knobs mirror the real-model regimes:

- ``expansion_rate``: fraction of learned postings absent from the BM25 index
  (paper: SPLADE++ 98.6%, uniCOIL 1.4%, DeepImpact ~0 after T5 expansion).
- ``weight_noise``: decorrelation between BM25 and learned weights on shared
  postings (learned models re-weight, not just expand).
- planted relevance: each query has ``n_rel`` relevant docs whose *learned*
  weights on query terms are boosted; in misaligned regimes a share of that
  boost lands on expansion-only postings — exactly the mass BM25-guided
  pruning cannot see, which is what degrades GTI at small k.
- graded relevance (``n_rel_partial > 0``): a second tier of *partially*
  relevant docs is planted with roughly half the learned boost — grade 1
  next to the fully-relevant grade 2 — so graded metrics (nDCG) are
  non-degenerate. ``qrels`` stays the binary top-grade set (backward
  compatible); ``qrels_graded`` carries docid -> gain per query. With the
  default ``n_rel_partial=0`` the generator's rng draw sequence is
  unchanged, so seeded corpora are bit-identical to pre-graded builds.

Three presets mirror the paper's models: ``splade_like``, ``unicoil_like``,
``deepimpact_like``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ..core.align import merge_models
from ..core.bm25 import Bm25Stats, build_bm25
from ..core.sparse import SparseModel, from_coo


@dataclasses.dataclass
class CorpusChunk:
    """One contiguous docid range of a corpus, as term-major COO postings.

    The unit consumed by ``repro.data.StreamingIndexBuilder``: postings
    are sorted by (term, docid) with docids *chunk-local* (global docid =
    ``doc_start + docids[i]``). Chunks tile the docid space contiguously,
    so a chunk's tiles are a contiguous global tile range.
    """
    chunk_id: int
    doc_start: int
    n_docs: int
    terms: np.ndarray    # [nnz] int64 term ids, non-decreasing
    docids: np.ndarray   # [nnz] int32 chunk-local, sorted within term
    w_b: np.ndarray      # [nnz] f32
    w_l: np.ndarray      # [nnz] f32


@dataclasses.dataclass
class SyntheticCorpus:
    n_docs: int
    n_terms: int
    bm25: SparseModel
    bm25_stats: Bm25Stats
    learned: SparseModel
    queries: np.ndarray        # [Q, Nq] int32 term ids (padded with 0)
    q_weights_l: np.ndarray    # [Q, Nq] f32 learned query weights (0 = pad)
    q_weights_b: np.ndarray    # [Q, Nq] f32 BM25 query weights (0 = pad)
    qrels: list[set[int]]      # fully-relevant docids per query (binary)
    # graded judgments: docid -> gain (2.0 = relevant, 1.0 = partial);
    # equals {d: 2.0 for d in qrels[qi]} when n_rel_partial == 0
    qrels_graded: list[dict[int, float]] | None = None
    # the BM25-strong / learned-just-below distractors planted per query
    # (the docs inaccurate guidance promotes; the eval harness gives them
    # confusable dense signal so no single modality is trivially perfect)
    q_distractors: list[set[int]] | None = None

    def merged(self, fill: str = "scaled"):
        return merge_models(self.learned, self.bm25, fill,
                            bm25_stats=self.bm25_stats)

    def iter_chunks(self, chunk_docs: int, fill: str = "scaled"):
        """Yield the corpus as ``CorpusChunk``s of ``chunk_docs`` docs.

        Slices the *same* merged postings the one-shot builders consume,
        so streaming a seeded corpus chunk-by-chunk reproduces the
        one-shot index bit-for-bit (the property the streaming-builder
        tests pin). The last chunk may be short.
        """
        if chunk_docs < 1:
            raise ValueError(f"chunk_docs must be >= 1, got {chunk_docs}")
        merged = self.merged(fill)
        term_of = np.repeat(np.arange(self.n_terms, dtype=np.int64),
                            np.diff(merged.indptr))
        for cid, d0 in enumerate(range(0, self.n_docs, chunk_docs)):
            d1 = min(d0 + chunk_docs, self.n_docs)
            m = (merged.docids >= d0) & (merged.docids < d1)
            yield CorpusChunk(
                chunk_id=cid, doc_start=d0, n_docs=d1 - d0,
                terms=term_of[m],
                docids=(merged.docids[m] - d0).astype(np.int32),
                w_b=merged.w_b[m], w_l=merged.w_l[m])


PRESETS = {
    # expansion_rate, weight_noise, rel_mass_on_expansion
    "splade_like": (0.92, 0.55, 0.75),
    "unicoil_like": (0.05, 0.25, 0.10),
    "deepimpact_like": (0.15, 0.35, 0.25),
}


def make_corpus(preset: str = "splade_like", n_docs: int = 8192,
                n_terms: int = 2048, n_queries: int = 64, n_q_terms: int = 6,
                n_rel: int = 4, avg_doc_terms: int = 48,
                seed: int = 0, n_rel_partial: int = 0,
                rel_boost_scale: float = 1.0) -> SyntheticCorpus:
    """``rel_boost_scale`` multiplies the planted relevant/partial learned
    boosts (distractors are untouched): < 1 pushes the relevant band down
    into the distractor band, making the ranking genuinely contested —
    the regime the relevance harness needs. A pure multiply on already-
    drawn values, so the default (1.0) is bit-identical to older builds
    and any scale leaves the rng draw sequence unchanged."""
    if preset not in PRESETS:
        raise ValueError(f"unknown preset {preset!r}; options {list(PRESETS)}")
    expansion_rate, weight_noise, rel_on_exp = PRESETS[preset]
    rng = np.random.default_rng(seed)

    # --- base lexical corpus: Zipf term frequencies ------------------------
    n_base = n_docs * avg_doc_terms
    zipf_p = 1.0 / np.arange(1, n_terms + 1) ** 1.1
    zipf_p /= zipf_p.sum()
    terms = rng.choice(n_terms, size=n_base, p=zipf_p).astype(np.int64)
    docs = rng.integers(0, n_docs, size=n_base).astype(np.int64)
    # dedupe (term, doc); tf ~ 1 + geometric
    key = terms * n_docs + docs
    key = np.unique(key)
    terms = (key // n_docs).astype(np.int64)
    docs = (key % n_docs).astype(np.int64)
    tfs = 1 + rng.geometric(0.55, size=len(key))
    doc_lens = np.bincount(docs, weights=tfs, minlength=n_docs).astype(np.float32)
    doc_lens = np.maximum(doc_lens, 1.0)
    bm25, stats = build_bm25(n_docs, n_terms, terms, docs, tfs, doc_lens)

    # --- learned model: reweight shared postings + expansion postings ------
    base_l = bm25.weights * np.exp(
        rng.normal(0.0, weight_noise, size=bm25.nnz)).astype(np.float32)
    n_exp = int(expansion_rate / max(1e-9, 1 - expansion_rate) * bm25.nnz)
    exp_terms = rng.choice(n_terms, size=n_exp, p=zipf_p).astype(np.int64)
    exp_docs = rng.integers(0, n_docs, size=n_exp).astype(np.int64)
    exp_w = rng.gamma(1.5, 0.6, size=n_exp).astype(np.float32)
    rep_t = np.repeat(np.arange(n_terms, dtype=np.int64), np.diff(bm25.indptr))
    all_terms = np.concatenate([rep_t, exp_terms])
    all_docs = np.concatenate([bm25.docids.astype(np.int64), exp_docs])
    all_w = np.concatenate([base_l, exp_w])

    # --- queries, planted relevance, hard distractors ----------------------
    # Query terms from the mid-frequency band (informative but non-empty).
    band = np.arange(n_terms // 64, n_terms // 2)
    queries = np.zeros((n_queries, n_q_terms), dtype=np.int32)
    qrels: list[set[int]] = []
    qrels_graded: list[dict[int, float]] = []
    q_distractors: list[set[int]] = []
    boost_t, boost_d, boost_w = [], [], []   # learned-side boosts
    add_t, add_d, add_tf = [], [], []        # BM25-side tf boosts
    n_distract = 24
    for qi in range(n_queries):
        qt = rng.choice(band, size=n_q_terms, replace=False).astype(np.int32)
        queries[qi] = qt
        pool = rng.choice(n_docs, size=n_rel + n_rel_partial + n_distract,
                          replace=False)
        rel = pool[:n_rel]
        partial = pool[n_rel:n_rel + n_rel_partial]
        distract = pool[n_rel + n_rel_partial:]
        qrels.append(set(int(d) for d in rel))
        qrels_graded.append({**{int(d): 2.0 for d in rel},
                             **{int(d): 1.0 for d in partial}})
        q_distractors.append(set(int(d) for d in distract))
        for d in rel:
            # Relevant docs: strong learned weights on all query terms, but
            # only (1 - rel_on_exp) of the terms are BM25-visible, weakly.
            # At least one term stays visible (real docs contain their topic
            # words; expansion shifts mass, it doesn't erase the lexical core).
            visible = rng.random(n_q_terms) > rel_on_exp
            visible[rng.integers(0, n_q_terms)] = True
            for t, vis in zip(qt, visible):
                boost_t.append(int(t))
                boost_d.append(int(d))
                boost_w.append(rel_boost_scale
                               * float(rng.gamma(4.0, 1.0) + 4.0))
                if vis:
                    add_t.append(int(t))
                    add_d.append(int(d))
                    add_tf.append(int(rng.integers(1, 4)))
        for d in partial:
            # Partial tier (grade 1): roughly half the relevant boost on
            # the same visibility pattern. Lands between the relevant band
            # and the distractor band so graded metrics have real ordering
            # to measure. Draws happen only when n_rel_partial > 0, so the
            # default rng sequence is untouched.
            visible = rng.random(n_q_terms) > rel_on_exp
            visible[rng.integers(0, n_q_terms)] = True
            for t, vis in zip(qt, visible):
                boost_t.append(int(t))
                boost_d.append(int(d))
                boost_w.append(rel_boost_scale
                               * float(rng.gamma(3.0, 0.9) + 2.0))
                if vis:
                    add_t.append(int(t))
                    add_d.append(int(d))
                    add_tf.append(int(rng.integers(1, 3)))
        for d in distract:
            # Hard distractors: strong BM25 (high tf on most query terms),
            # learned scores just below the relevant band. These fill the
            # BM25-driven queues, so inaccurate guidance prunes the docs
            # that matter — the paper's small-k failure mode.
            for t in qt:
                if rng.random() < 0.7:
                    add_t.append(int(t))
                    add_d.append(int(d))
                    add_tf.append(int(rng.integers(2, 7)))
                boost_t.append(int(t))
                boost_d.append(int(d))
                boost_w.append(float(rng.gamma(3.0, 0.8) + 1.5))
    # planted postings FIRST: from_coo keeps the first duplicate, so boosts
    # override pre-existing base/expansion postings for the same (t, d).
    all_terms = np.concatenate([np.array(boost_t, np.int64), all_terms])
    all_docs = np.concatenate([np.array(boost_d, np.int64), all_docs])
    all_w = np.concatenate([np.array(boost_w, np.float32), all_w])
    learned = from_coo(n_docs, n_terms, all_terms, all_docs, all_w)

    if add_t:
        terms2 = np.concatenate([np.array(add_t, np.int64), terms])
        docs2 = np.concatenate([np.array(add_d, np.int64), docs])
        tfs2 = np.concatenate([np.array(add_tf, np.int64), tfs])
        doc_lens2 = np.bincount(docs2, weights=tfs2,
                                minlength=n_docs).astype(np.float32)
        doc_lens2 = np.maximum(doc_lens2, 1.0)
        bm25, stats = build_bm25(n_docs, n_terms, terms2, docs2, tfs2,
                                 doc_lens2)

    # Query weights: learned side weighted (impact-style), BM25 side 1.
    qw_l = (1.0 + rng.gamma(2.0, 0.5, size=queries.shape)).astype(np.float32)
    qw_b = np.ones_like(qw_l)
    return SyntheticCorpus(n_docs=n_docs, n_terms=n_terms, bm25=bm25,
                           bm25_stats=stats, learned=learned, queries=queries,
                           q_weights_l=qw_l, q_weights_b=qw_b, qrels=qrels,
                           qrels_graded=qrels_graded,
                           q_distractors=q_distractors)


def synthetic_chunk_stream(n_chunks: int, chunk_docs: int, n_terms: int,
                           avg_doc_terms: int = 32, seed: int = 0,
                           start_chunk: int = 0, zipf_a: float = 1.1):
    """Million-scale corpus as a resumable chunk stream.

    Each chunk is a pure function of ``(seed, chunk_id)`` — generated
    with ``default_rng([seed, chunk_id])`` — so a restarted build replays
    exactly the chunks it has not applied, with no upstream state to
    re-wind (the property the kill-and-resume benchmark leans on). The
    corpus never materializes whole: peak memory is one chunk. Postings
    mirror ``make_corpus``'s lexical core (Zipf terms, geometric tf,
    log-normal learned re-weighting) without the query/relevance
    machinery the retrieval benchmarks don't need at this scale.
    ``zipf_a`` sets the term-frequency skew (steeper -> denser head
    posting runs -> narrower gap widths).
    """
    zipf_p = 1.0 / np.arange(1, n_terms + 1) ** zipf_a
    zipf_p /= zipf_p.sum()
    for cid in range(start_chunk, n_chunks):
        rng = np.random.default_rng(np.random.SeedSequence([seed, cid]))
        n_base = chunk_docs * avg_doc_terms
        terms = rng.choice(n_terms, size=n_base, p=zipf_p).astype(np.int64)
        docs = rng.integers(0, chunk_docs, size=n_base).astype(np.int64)
        key = np.unique(terms * chunk_docs + docs)
        terms = (key // chunk_docs).astype(np.int64)
        docs = (key % chunk_docs).astype(np.int32)
        tf = (1 + rng.geometric(0.55, size=len(key))).astype(np.float32)
        w_b = (tf / (tf + 1.2)).astype(np.float32)
        w_l = (w_b * np.exp(rng.normal(0.0, 0.4, size=len(key)))
               ).astype(np.float32)
        yield CorpusChunk(chunk_id=cid, doc_start=cid * chunk_docs,
                          n_docs=chunk_docs, terms=terms, docids=docs,
                          w_b=w_b, w_l=w_l)
