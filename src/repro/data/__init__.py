from .corpus import (CorpusChunk, SyntheticCorpus, make_corpus,  # noqa: F401
                     synthetic_chunk_stream)
from .builder import StreamingIndexBuilder  # noqa: F401
