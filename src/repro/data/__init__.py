from .corpus import SyntheticCorpus, make_corpus  # noqa: F401
