"""StreamingIndexBuilder: corpus-chunk-at-a-time compressed index build
with checkpointed resume.

Chunks (``repro.data.CorpusChunk`` — contiguous docid ranges) are encoded
independently with the *same* per-run codec as the one-shot compressor
(``repro.index.encode_runs``): runs are word-aligned and fully
self-contained, so per-chunk outputs concatenate into the global index
bit-for-bit identical to ``compress_index`` over the whole corpus.

Durability model: each completed chunk is spilled to its own ``.npz``
(written to a temp name, then ``os.replace``d), and only *then* recorded
in ``manifest.json`` (also atomically replaced). A crash between the two
leaves an orphan spill that is simply re-written on resume; a crash
mid-spill leaves a temp file the manifest never references. ``add_chunk``
is idempotent — re-adding a recorded chunk is a no-op — so resume is
"reopen the builder, replay the stream, skip what's done".

``finalize`` re-orders the per-chunk flat arrays into global term-major
run order with one vectorized run-level gather (no per-run Python loop)
and assembles the device index via ``repro.index.from_encoded_grids``.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

import numpy as np

from .corpus import CorpusChunk

_GRID_KEYS = ("cnt", "words", "width", "first", "scale_b", "zero_b",
              "scale_l", "zero_l", "tile_max_b", "tile_max_l")
_FLAT_KEYS = ("packed", "qb", "ql")


class StreamingIndexBuilder:
    """Build a ``CompressedImpactIndex`` from corpus chunks with
    checkpoint/resume.

    ``chunk_docs`` must be a multiple of ``tile_size`` so every chunk
    owns whole tiles (the last chunk may be short). Opening an existing
    ``out_dir`` resumes: previously recorded chunks are kept and
    ``add_chunk`` skips them.
    """

    def __init__(self, out_dir, *, n_terms: int, tile_size: int = 2048,
                 chunk_docs: int):
        if chunk_docs % tile_size != 0:
            raise ValueError(
                f"chunk_docs ({chunk_docs}) must be a multiple of "
                f"tile_size ({tile_size}) so chunks own whole tiles")
        self.out_dir = Path(out_dir)
        self.out_dir.mkdir(parents=True, exist_ok=True)
        self._manifest_path = self.out_dir / "manifest.json"
        if self._manifest_path.exists():
            with open(self._manifest_path) as f:
                m = json.load(f)
            for key, want in (("n_terms", n_terms),
                              ("tile_size", tile_size),
                              ("chunk_docs", chunk_docs)):
                if m[key] != want:
                    raise ValueError(
                        f"resume geometry mismatch in {self._manifest_path}:"
                        f" {key}={m[key]} on disk, {want} requested")
            self.manifest = m
        else:
            self.manifest = {"version": 1, "n_terms": n_terms,
                             "tile_size": tile_size, "chunk_docs": chunk_docs,
                             "chunks": {}}
            self._write_manifest()
        self.n_terms = n_terms
        self.tile_size = tile_size
        self.chunk_docs = chunk_docs

    # -- durability -----------------------------------------------------

    def _write_manifest(self) -> None:
        tmp = self._manifest_path.with_suffix(".json.tmp")
        with open(tmp, "w") as f:
            json.dump(self.manifest, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self._manifest_path)

    def _chunk_path(self, chunk_id: int) -> Path:
        return self.out_dir / f"chunk_{chunk_id:05d}.npz"

    @property
    def completed_chunks(self) -> list[int]:
        return sorted(int(c) for c in self.manifest["chunks"])

    def has_chunk(self, chunk_id: int) -> bool:
        return str(chunk_id) in self.manifest["chunks"]

    # -- build ----------------------------------------------------------

    def add_chunk(self, chunk: CorpusChunk) -> bool:
        """Encode + spill one chunk; record it in the manifest.

        Returns False (and does nothing) if the chunk is already
        recorded — the resume path.
        """
        from ..index.compressed import encode_runs

        if self.has_chunk(chunk.chunk_id):
            return False
        if chunk.doc_start != chunk.chunk_id * self.chunk_docs:
            raise ValueError(
                f"chunk {chunk.chunk_id} starts at doc {chunk.doc_start}, "
                f"expected {chunk.chunk_id * self.chunk_docs}")
        if chunk.n_docs > self.chunk_docs:
            raise ValueError(f"chunk {chunk.chunk_id} has {chunk.n_docs} "
                             f"docs > chunk_docs={self.chunk_docs}")
        t_c = -(-chunk.n_docs // self.tile_size)  # tiles in this chunk
        docids = np.asarray(chunk.docids, dtype=np.int64)
        terms = np.asarray(chunk.terms, dtype=np.int64)
        tile_of = docids // self.tile_size
        run_of = terms * t_c + tile_of
        cnt = np.bincount(run_of, minlength=self.n_terms * t_c
                          ).reshape(self.n_terms, t_c)
        loc = docids - tile_of * self.tile_size
        enc = encode_runs(loc, chunk.w_b, chunk.w_l, run_of, cnt.reshape(-1))

        tm_b = np.zeros((self.n_terms, t_c), dtype=np.float32)
        tm_l = np.zeros((self.n_terms, t_c), dtype=np.float32)
        np.maximum.at(tm_b.reshape(-1), run_of, chunk.w_b)
        np.maximum.at(tm_l.reshape(-1), run_of, chunk.w_l)

        g = lambda a: np.asarray(a).reshape(self.n_terms, t_c)
        path = self._chunk_path(chunk.chunk_id)
        tmp = path.with_name("tmp_" + path.name)  # savez wants a .npz name
        np.savez(tmp, packed=enc["packed"], qb=enc["qb"], ql=enc["ql"],
                 cnt=cnt, words=g(enc["words"]), width=g(enc["width"]),
                 first=g(enc["first"]), scale_b=g(enc["scale_b"]),
                 zero_b=g(enc["zero_b"]), scale_l=g(enc["scale_l"]),
                 zero_l=g(enc["zero_l"]), tile_max_b=tm_b, tile_max_l=tm_l)
        os.replace(tmp, path)
        self.manifest["chunks"][str(chunk.chunk_id)] = {
            "n_docs": int(chunk.n_docs), "file": path.name,
            "nnz": int(len(docids))}
        self._write_manifest()
        return True

    def finalize(self, *, pad_multiple: int = 8, pad_cap: int | None = None,
                 orig_of_new: np.ndarray | None = None):
        """Concatenate all spilled chunks into the global device index.

        Per-chunk flat arrays are ordered (term, local tile); the global
        index needs (term, global tile) = (term, chunk, local tile). The
        re-order is one gather over per-(term, chunk) spans — contiguous
        in the source because each chunk is term-major — built with the
        repeat/arange flat-index trick (same idiom as ``shard_index``).
        """
        from ..index.compressed import from_encoded_grids

        ids = self.completed_chunks
        if not ids:
            raise ValueError("no chunks to finalize")
        if ids != list(range(len(ids))):
            raise ValueError(f"chunk ids must be contiguous from 0, got {ids}")
        chunks = []
        n_docs = 0
        for cid in ids:
            rec = self.manifest["chunks"][str(cid)]
            if cid != ids[-1] and rec["n_docs"] != self.chunk_docs:
                raise ValueError(
                    f"non-final chunk {cid} has {rec['n_docs']} docs; only "
                    f"the last chunk may be short")
            with np.load(self.out_dir / rec["file"]) as z:
                chunks.append({k: z[k] for k in _GRID_KEYS + _FLAT_KEYS})
            n_docs += rec["n_docs"]

        grids = {k: np.concatenate([c[k] for c in chunks], axis=1)
                 for k in _GRID_KEYS}
        flat = {k: (np.concatenate([c[k] for c in chunks])
                    if len(chunks) > 1 else chunks[0][k])
                for k in _FLAT_KEYS}

        def reorder(a, counts_key):
            # source spans: term t's block inside chunk c (contiguous);
            # destination order: (t, c) row-major == global term-major
            per_tc = np.stack([c[counts_key].sum(axis=1, dtype=np.int64)
                               for c in chunks], axis=1)  # [n_terms, n_c]
            base = np.zeros(len(chunks), dtype=np.int64)
            np.cumsum([c[counts_key].sum(dtype=np.int64) for c in chunks
                       ][:-1], out=base[1:])
            # per-chunk exclusive cumsum over terms -> source start of
            # term t's block within chunk c
            src0 = np.stack(
                [np.concatenate(([0], np.cumsum(
                    c[counts_key].sum(axis=1, dtype=np.int64))[:-1]))
                 for c in chunks], axis=1) + base[None, :]
            lens = per_tc.reshape(-1)
            src0 = src0.reshape(-1)
            total = int(lens.sum())
            dst0 = np.concatenate(([0], np.cumsum(lens)[:-1]))
            idx = (np.arange(total, dtype=np.int64)
                   - np.repeat(dst0, lens) + np.repeat(src0, lens))
            return a[idx]

        qb = reorder(flat["qb"], "cnt")
        ql = reorder(flat["ql"], "cnt")
        packed = reorder(flat["packed"], "words")

        return from_encoded_grids(
            n_docs, self.n_terms, self.tile_size, grids["cnt"],
            grids["words"], packed, qb, ql, grids["width"], grids["first"],
            grids["scale_b"], grids["zero_b"], grids["scale_l"],
            grids["zero_l"], grids["tile_max_b"], grids["tile_max_l"],
            pad_multiple=pad_multiple, pad_cap=pad_cap,
            orig_of_new=orig_of_new)
