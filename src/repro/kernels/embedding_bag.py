"""Pallas TPU embedding-bag: fused gather + weighted segment-sum.

JAX has no native EmbeddingBag; the jnp substrate implements it as
``take`` + ``segment_sum`` (see ``repro/sparse_ops``). This kernel fuses
both for the serving hot path of the recsys architectures: the *row shard*
of a model-parallel embedding table is VMEM-resident (DLRM tables sharded
over hundreds of chips are ~1 MiB/chip) and each output row accumulates its
bag's rows with dynamic-index reads, never materializing the gathered
[B, L, D] intermediate in HBM.

Padding: slot weight 0 (indices may be any in-range value).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .pallas_env import default_interpret


def _kernel(idx_ref, w_ref, tab_ref, o_ref, *, block_b: int, bag_len: int):
    def body(n, _):
        b = n // bag_len
        j = n % bag_len
        row = tab_ref[idx_ref[b, j], :] * w_ref[b, j]
        o_ref[b, :] += row
        return 0
    o_ref[...] = jnp.zeros_like(o_ref)
    jax.lax.fori_loop(0, block_b * bag_len, body, 0)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def embedding_bag(table, indices, weights, *, block_b: int = 8,
                  interpret: bool | None = None):
    """table: [V, D]; indices, weights: [B, L] -> out [B, D] (weighted sum).

    ``interpret=None``: native lowering on TPU, interpreter elsewhere."""
    if interpret is None:
        interpret = default_interpret()
    v, d = table.shape
    b, l = indices.shape
    block_b = min(block_b, b)
    assert b % block_b == 0
    kern = functools.partial(_kernel, block_b=block_b, bag_len=l)
    return pl.pallas_call(
        kern,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, l), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((block_b, l), lambda i: (i, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((v, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret,
    )(indices, weights, table)
